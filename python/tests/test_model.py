"""L2 correctness: masked forward vs oracle, QAT quantizers, and the
training step's learning behaviour."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def random_mlp_int(rng, n0, h, o):
    l1 = {
        "sign": rng.integers(-1, 2, size=(h, n0)).astype(np.int32),
        "shift": rng.integers(0, 8, size=(h, n0)).astype(np.int32),
        "bias": (rng.integers(-1, 2, size=h) * (1 << rng.integers(0, 8, size=h))).astype(np.int32),
    }
    l2 = {
        "sign": rng.integers(-1, 2, size=(o, h)).astype(np.int32),
        "shift": rng.integers(0, 8, size=(o, h)).astype(np.int32),
        "bias": (rng.integers(-1, 2, size=o) * (1 << rng.integers(0, 8, size=o))).astype(np.int32),
    }
    return l1, l2


def test_masked_accuracy_counts_vs_oracle():
    rng = np.random.default_rng(0)
    n0, h, o, b, p = 6, 3, 3, 12, 4
    l1, l2 = random_mlp_int(rng, n0, h, o)
    x = rng.integers(0, 16, size=(b, n0), dtype=np.int32)
    labels = rng.integers(0, o, size=b).astype(np.int32)
    m1 = rng.integers(0, 16, size=(p, h, n0), dtype=np.int32)
    m2 = rng.integers(0, 256, size=(p, o, h), dtype=np.int32)
    mb1 = rng.integers(0, 2, size=(p, h), dtype=np.int32)
    mb2 = rng.integers(0, 2, size=(p, o), dtype=np.int32)
    act_shift = 3

    counts = np.asarray(
        model.masked_accuracy_counts(
            jnp.asarray(x), jnp.asarray(labels),
            jnp.asarray(l1["sign"]), jnp.asarray(l1["shift"]), jnp.asarray(l1["bias"]), jnp.asarray(mb1),
            jnp.asarray(l2["sign"]), jnp.asarray(l2["shift"]), jnp.asarray(l2["bias"]), jnp.asarray(mb2),
            jnp.asarray(m1), jnp.asarray(m2), jnp.int32(act_shift),
        )
    )
    # Oracle: numpy loops.
    for pi in range(p):
        correct = 0
        for bi in range(b):
            l1m = dict(l1, mask=m1[pi], bkeep=mb1[pi])
            l2m = dict(l2, mask=m2[pi], bkeep=mb2[pi])
            _, z2 = ref.quant_forward_np(x[bi], l1m, l2m, act_shift)
            if int(np.argmax(z2)) == labels[bi]:
                correct += 1
        assert counts[pi] == correct, f"chromosome {pi}"


def test_padding_labels_never_count():
    rng = np.random.default_rng(1)
    n0, h, o, b, p = 4, 2, 2, 8, 2
    l1, l2 = random_mlp_int(rng, n0, h, o)
    x = rng.integers(0, 16, size=(b, n0), dtype=np.int32)
    labels = np.full(b, -1, dtype=np.int32)  # all padding
    m1 = np.full((p, h, n0), 15, dtype=np.int32)
    m2 = np.full((p, o, h), 255, dtype=np.int32)
    mb = np.ones((p, h), dtype=np.int32)
    mb2 = np.ones((p, o), dtype=np.int32)
    counts = np.asarray(
        model.masked_accuracy_counts(
            jnp.asarray(x), jnp.asarray(labels),
            jnp.asarray(l1["sign"]), jnp.asarray(l1["shift"]), jnp.asarray(l1["bias"]), jnp.asarray(mb),
            jnp.asarray(l2["sign"]), jnp.asarray(l2["shift"]), jnp.asarray(l2["bias"]), jnp.asarray(mb2),
            jnp.asarray(m1), jnp.asarray(m2), jnp.int32(2),
        )
    )
    assert (counts == 0).all()


def test_po2_ste_forward_is_po2_grid():
    w = jnp.asarray([[0.3, -0.7, 0.0, 1.6, 0.001, -0.09]])
    wq = np.asarray(model.po2_ste(w))
    for v in wq.flatten():
        if v == 0.0:
            continue
        assert abs(np.log2(abs(v)) - round(np.log2(abs(v)))) < 1e-6, v


def test_po2_ste_gradient_is_identity():
    f = lambda w: jnp.sum(model.po2_ste(w) * 2.0)
    g = jax.grad(f)(jnp.asarray([0.3, -0.7, 1.1]))
    np.testing.assert_allclose(np.asarray(g), [2.0, 2.0, 2.0], atol=1e-6)


def test_qrelu_ste_range_and_grid():
    act_max = 8.0
    step = act_max / 256.0
    h = jnp.linspace(-2.0, 10.0, 97)
    hq = np.asarray(model.qrelu_ste(h, act_max))
    assert hq.min() >= 0.0
    assert hq.max() <= act_max - step + 1e-9
    steps = hq / step
    np.testing.assert_allclose(steps, np.round(steps), atol=1e-5)


def _toy_problem(rng, n0=4, h=6, o=3, n=256):
    x = rng.uniform(0, 1, size=(n, n0)).astype(np.float32)
    w_true = rng.normal(size=(o, n0))
    y = np.argmax(x @ w_true.T, axis=1).astype(np.int32)
    return x, y


def test_train_step_learns_toy_problem():
    rng = np.random.default_rng(7)
    n0, h, o = 4, 6, 3
    x, y = _toy_problem(rng, n0, h, o)
    params = {
        "w1": jnp.asarray(rng.normal(size=(h, n0)) * 0.5, dtype=jnp.float32),
        "b1": jnp.zeros(h, dtype=jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(o, h)) * 0.5, dtype=jnp.float32),
        "b2": jnp.zeros(o, dtype=jnp.float32),
    }
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    m, v = dict(zeros), dict(zeros)
    step = jnp.int32(0)
    sw = jnp.ones(64, dtype=jnp.float32)
    losses = []
    jit_step = jax.jit(
        lambda p, m, v, s, xb, yb: model.train_step(p, m, v, s, xb, yb, sw, 0.02, 8.0, o)
    )
    for epoch in range(30):
        for k in range(0, 256, 64):
            xb = jnp.asarray(x[k:k+64])
            yb = jnp.asarray(y[k:k+64])
            params, m, v, step, loss = jit_step(params, m, v, step, xb, yb)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.6, losses[::10]
    pred = np.asarray(model.qat_eval(params, jnp.asarray(x), o))
    acc = (pred == y).mean()
    assert acc > 0.8, acc


def test_train_step_flat_matches_dict_version():
    rng = np.random.default_rng(9)
    n0, h, o = 3, 2, 2
    mk = lambda *s: jnp.asarray(rng.normal(size=s), dtype=jnp.float32)
    w1, b1, w2, b2 = mk(h, n0), mk(h), mk(o, h), mk(o)
    z = lambda t: jnp.zeros_like(t)
    x = mk(8, n0)
    y = jnp.asarray(rng.integers(0, o, size=8), dtype=jnp.int32)
    sw = jnp.ones(8, dtype=jnp.float32)
    flat = model.train_step_flat(
        w1, b1, w2, b2,
        z(w1), z(b1), z(w2), z(b2),
        z(w1), z(b1), z(w2), z(b2),
        jnp.int32(0), x, y, sw, jnp.float32(0.01), jnp.float32(8.0),
    )
    params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
    zeros = {k: z(v) for k, v in params.items()}
    p2, _, _, step2, loss2 = model.train_step(
        params, dict(zeros), dict(zeros), jnp.int32(0), x, y, sw, 0.01, 8.0, o
    )
    np.testing.assert_allclose(np.asarray(flat[0]), np.asarray(p2["w1"]), rtol=1e-6)
    np.testing.assert_allclose(float(flat[13]), float(loss2), rtol=1e-6)
    assert int(flat[12]) == int(step2) == 1
