"""L1 correctness: the Pallas masked-MAC kernel against the jnp and
numpy oracles — the core correctness signal of the compile path.
Integer arithmetic: comparisons are exact (assert_array_equal)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.masked_mac import masked_mac, qrelu
from compile.kernels import ref


def make_case(rng, p, b, n, j, in_bits):
    amax = (1 << in_bits) - 1
    x = rng.integers(0, amax + 1, size=(b, j), dtype=np.int32)
    sign = rng.integers(-1, 2, size=(n, j)).astype(np.int32)
    shift = rng.integers(0, 8, size=(n, j), dtype=np.int32)
    mask = rng.integers(0, amax + 1, size=(p, n, j), dtype=np.int32)
    bias = (rng.integers(-1, 2, size=n) * (1 << rng.integers(0, 10, size=n))).astype(np.int32)
    bkeep = rng.integers(0, 2, size=(p, n), dtype=np.int32)
    return x, sign, shift, mask, bias, bkeep


def test_kernel_matches_numpy_oracle_small():
    rng = np.random.default_rng(0)
    args = make_case(rng, p=3, b=5, n=4, j=6, in_bits=4)
    got = np.asarray(masked_mac(*[jnp.asarray(a) for a in args]))
    want = ref.masked_mac_np(*args)
    np.testing.assert_array_equal(got, want.astype(np.int32))


def test_kernel_matches_jnp_ref():
    rng = np.random.default_rng(1)
    args = make_case(rng, p=4, b=16, n=5, j=11, in_bits=8)
    jargs = [jnp.asarray(a) for a in args]
    got = np.asarray(masked_mac(*jargs))
    want = np.asarray(ref.masked_mac_ref(*jargs))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(1, 6),
    b=st.integers(1, 24),
    n=st.integers(1, 8),
    j=st.integers(1, 16),
    in_bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_hypothesis_sweep(p, b, n, j, in_bits, seed):
    """Hypothesis sweep over shapes/bit-widths: pallas == jnp oracle."""
    rng = np.random.default_rng(seed)
    args = make_case(rng, p, b, n, j, in_bits)
    jargs = [jnp.asarray(a) for a in args]
    got = np.asarray(masked_mac(*jargs))
    want = np.asarray(ref.masked_mac_ref(*jargs))
    np.testing.assert_array_equal(got, want)


def test_full_mask_equals_unmasked_matmul():
    """With all-ones masks the kernel is an ordinary po2 MAC."""
    rng = np.random.default_rng(2)
    p, b, n, j = 2, 8, 3, 5
    x, sign, shift, _, bias, _ = make_case(rng, p, b, n, j, 4)
    mask = np.full((p, n, j), 15, dtype=np.int32)
    bkeep = np.ones((p, n), dtype=np.int32)
    got = np.asarray(masked_mac(*[jnp.asarray(a) for a in (x, sign, shift, mask, bias, bkeep)]))
    w = sign.astype(np.int64) * (1 << shift.astype(np.int64))
    want = x.astype(np.int64) @ w.T + bias[None, :]
    for pi in range(p):
        np.testing.assert_array_equal(got[pi], want.astype(np.int32))


def test_zero_mask_kills_everything():
    rng = np.random.default_rng(3)
    p, b, n, j = 1, 4, 2, 3
    x, sign, shift, _, bias, _ = make_case(rng, p, b, n, j, 4)
    mask = np.zeros((p, n, j), dtype=np.int32)
    bkeep = np.zeros((p, n), dtype=np.int32)
    got = np.asarray(masked_mac(*[jnp.asarray(a) for a in (x, sign, shift, mask, bias, bkeep)]))
    np.testing.assert_array_equal(got, np.zeros((p, b, n), dtype=np.int32))


@pytest.mark.parametrize(
    "z,t,expect",
    [
        (-5, 0, 0),
        (0, 0, 0),
        (255, 0, 255),
        (256, 0, 255),
        (256, 1, 128),
        (511, 1, 255),
        (1 << 20, 4, 255),
    ],
)
def test_qrelu_matches_rust_cases(z, t, expect):
    """Same cases as rust/src/model/quantized.rs::qrelu_behaviour."""
    got = int(qrelu(jnp.asarray([z], dtype=jnp.int32), jnp.int32(t))[0])
    assert got == expect


def test_qrelu_matches_numpy_ref():
    rng = np.random.default_rng(4)
    z = rng.integers(-(1 << 20), 1 << 20, size=200).astype(np.int32)
    for t in (0, 2, 5):
        got = np.asarray(qrelu(jnp.asarray(z), jnp.int32(t)))
        want = ref.qrelu_np(z, t)
        np.testing.assert_array_equal(got, want)
