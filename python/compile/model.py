"""Layer-2 JAX model: the quantized masked MLP forward (GA evaluation
path) and the QAT training step (fwd + bwd + Adam), both lowered once by
`aot.py` to HLO text and driven from the Rust coordinator via PJRT.
Python never runs on the optimization hot path.

Integer semantics match `rust/src/model/quantized.rs` bit for bit:
4-bit inputs, power-of-2 weights as (sign, shift) pairs, positive and
negative accumulators subtracted once, QRelu(8) with a static truncation
shift, argmax with ties to the lowest index.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels.masked_mac import masked_mac, qrelu

# ---------------------------------------------------------------------------
# GA evaluation path (integer domain)
# ---------------------------------------------------------------------------


def masked_accuracy_counts(
    x, labels,
    w1_sign, w1_shift, b1, mb1,
    w2_sign, w2_shift, b2, mb2,
    m1, m2, act_shift,
):
    """Count correct predictions per chromosome.

    Args:
      x:        (B, N0) int32 — 4-bit quantized inputs (padded rows ok).
      labels:   (B,)    int32 — class labels; use -1 for padding rows.
      w1_sign/w1_shift: (H, N0) int32 — hidden-layer po2 weights.
      b1:       (H,) int32 — hidden bias integer values.
      mb1:      (P, H) int32 — per-chromosome hidden bias keep flags.
      w2_sign/w2_shift: (O, H) int32.
      b2:       (O,) int32.
      mb2:      (P, O) int32.
      m1:       (P, H, N0) int32 — hidden summand-bit masks.
      m2:       (P, O, H) int32 — output summand-bit masks.
      act_shift: () int32 — QRelu truncation.

    Returns:
      (P,) int32 — number of samples whose argmax equals the label.
    """
    z1 = masked_mac(x, w1_sign, w1_shift, m1, b1, mb1)       # (P, B, H)
    h = qrelu(z1, act_shift)                                  # (P, B, H)
    # Layer 2 is evaluated per chromosome on its own hidden activations:
    # vmap the kernel over the population axis with a singleton P.
    def layer2(h_p, m2_p, mb2_p):
        return masked_mac(h_p, w2_sign, w2_shift, m2_p[None], b2, mb2_p[None])[0]

    z2 = jax.vmap(layer2)(h, m2, mb2)                         # (P, B, O)
    pred = jnp.argmax(z2, axis=-1).astype(jnp.int32)          # ties -> lowest
    correct = (pred == labels[None, :]).astype(jnp.int32)
    return jnp.sum(correct, axis=-1)


def masked_preacts(
    x,
    w1_sign, w1_shift, b1, mb1,
    w2_sign, w2_shift, b2, mb2,
    m1, m2, act_shift,
):
    """Output-layer pre-activations per chromosome: (P, B, O) int32."""
    z1 = masked_mac(x, w1_sign, w1_shift, m1, b1, mb1)
    h = qrelu(z1, act_shift)

    def layer2(h_p, m2_p, mb2_p):
        return masked_mac(h_p, w2_sign, w2_shift, m2_p[None], b2, mb2_p[None])[0]

    return jax.vmap(layer2)(h, m2, mb2)


# ---------------------------------------------------------------------------
# QAT training path (float domain with straight-through quantizers)
# ---------------------------------------------------------------------------

MAX_SHIFT = 15


def po2_ste(w):
    """Straight-through power-of-2 quantizer (QKeras quantized_po2 style).

    Forward: sign(w) * 2^clip(round(log2|w|), a-7, a) with a = per-tensor
    ceil(log2 max|w|); magnitudes below the window flush to zero.
    Backward: identity (STE).
    """
    maxabs = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9)
    a = jnp.ceil(jnp.log2(maxabs))
    log2w = jnp.log2(jnp.maximum(jnp.abs(w), 1e-12))
    e = jnp.clip(jnp.round(log2w), a - MAX_SHIFT, a)
    wq = jnp.sign(w) * jnp.exp2(e)
    # Flush-to-zero below the representable window (match rust
    # `quantize_po2`: log2|w| + 0.5 < a - 7).
    wq = jnp.where(log2w + 0.5 < a - MAX_SHIFT, 0.0, wq)
    return w + jax.lax.stop_gradient(wq - w)


def qrelu_ste(h, act_max):
    """Straight-through QRelu(8): 8-bit grid on the calibrated
    [0, act_max) range (act_max is the Rust-side power-of-2 calibration of
    the maximum hidden pre-activation; matches the integer truncation
    shift of the hardware)."""
    step = act_max / 256.0
    hr = jnp.maximum(h, 0.0)
    hq = jnp.clip(jnp.floor(hr / step) * step, 0.0, act_max - step)
    return hr + jax.lax.stop_gradient(hq - hr)


def qat_forward(params, x, act_max):
    """QAT forward pass: po2 weights, QRelu(8) hidden activations."""
    w1q = po2_ste(params["w1"])
    w2q = po2_ste(params["w2"])
    h = qrelu_ste(x @ w1q.T + params["b1"], act_max)
    return h @ w2q.T + params["b2"]


def _loss(params, x, y, sample_w, act_max, n_out):
    logits = qat_forward(params, x, act_max)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, n_out)
    ce = -jnp.sum(onehot * logp, axis=-1)
    return jnp.sum(ce * sample_w) / jnp.maximum(jnp.sum(sample_w), 1e-9)


ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def train_step(params, adam_m, adam_v, step, x, y, sample_w, lr, act_max, n_out):
    """One QAT Adam step. All state in/out — the Rust trainer owns the loop.

    Args:
      params: dict w1 (H,N0), b1 (H,), w2 (O,H), b2 (O,) — f32.
      adam_m/adam_v: same structure.
      step: () int32 — 1-based after this update.
      x: (Bt, N0) f32 — inputs already scaled to [0,1] 4-bit grid.
      y: (Bt,) int32.
      sample_w: (Bt,) f32 — per-sample (class-balance) weights.
      lr: () f32.
      act_max: () f32 — calibrated QRelu range (power of two).

    Returns: (params, adam_m, adam_v, step, loss).
    """
    loss, grads = jax.value_and_grad(_loss)(params, x, y, sample_w, act_max, n_out)
    step = step + 1
    bc1 = 1.0 - ADAM_B1 ** step.astype(jnp.float32)
    bc2 = 1.0 - ADAM_B2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        p = p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + ADAM_EPS)
        return p, m, v

    new_p, new_m, new_v = {}, {}, {}
    for k in ("w1", "b1", "w2", "b2"):
        new_p[k], new_m[k], new_v[k] = upd(params[k], grads[k], adam_m[k], adam_v[k])
    return new_p, new_m, new_v, step, loss


def train_step_flat(
    w1, b1, w2, b2,
    m_w1, m_b1, m_w2, m_b2,
    v_w1, v_b1, v_w2, v_b2,
    step, x, y, sample_w, lr, act_max,
):
    """Flat-argument wrapper of `train_step` for AOT lowering (the PJRT
    runtime passes positional literals). Returns a flat 14-tuple:
    (w1, b1, w2, b2, m_w1..m_b2, v_w1..v_b2, step, loss)."""
    n_out = w2.shape[0]
    params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
    adam_m = {"w1": m_w1, "b1": m_b1, "w2": m_w2, "b2": m_b2}
    adam_v = {"w1": v_w1, "b1": v_b1, "w2": v_w2, "b2": v_b2}
    p, m, v, step, loss = train_step(
        params, adam_m, adam_v, step, x, y, sample_w, lr, act_max, n_out
    )
    return (
        p["w1"], p["b1"], p["w2"], p["b2"],
        m["w1"], m["b1"], m["w2"], m["b2"],
        v["w1"], v["b1"], v["w2"], v["b2"],
        step, loss,
    )


@functools.partial(jax.jit, static_argnames=("n_out",))
def qat_eval(params, x, n_out, act_max=8.0):
    """QAT-forward predictions (used by build-time self-tests)."""
    logits = qat_forward(params, x, act_max)
    del n_out
    return jnp.argmax(logits, axis=-1)
