"""AOT compilation: lower the Layer-2 JAX programs (which embed the
Layer-1 Pallas kernel) to HLO *text* artifacts for the Rust runtime.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per MLP topology (names match `rust/src/config.rs` builtins):
  masked_acc_<name>.hlo.txt    — GA accuracy counting, population tile P
  masked_preacts_<name>.hlo.txt — per-chromosome output pre-activations
  train_step_<name>.hlo.txt    — one QAT Adam step (fwd+bwd)
plus `manifest.json` recording every artifact's shapes so the Rust side
can marshal literals without guessing.

`python -m compile.aot --out ../artifacts` is idempotent: artifacts are
skipped when the source hash recorded in the manifest is unchanged.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (name, n_in, n_hidden, n_out, eval_batch) — eval_batch is the padded
# train-set size the GA evaluates accuracy on (next multiple of 64 above
# the 70% stratified split).
TOPOLOGIES = [
    ("arrhythmia", 274, 5, 16, 320),
    ("breastcancer", 10, 3, 2, 512),
    ("cardio", 21, 3, 3, 1536),
    ("pendigits", 16, 5, 10, 5248),
    ("redwine", 11, 2, 6, 1152),
    ("whitewine", 11, 4, 7, 3456),
    ("tiny", 6, 3, 3, 256),
]

# Population tile of the GA evaluator (chromosomes per PJRT dispatch).
P_TILE = 16
# Population tile of the pre-activation artifact (analysis path).
P_PRE = 4
# Training minibatch.
BT = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_masked_acc(n0, h, o, b, p=P_TILE):
    """Lower `masked_accuracy_counts` for one topology."""

    def fn(x, labels, w1_sign, w1_shift, b1, mb1, w2_sign, w2_shift, b2, mb2, m1, m2, act_shift):
        return (
            model.masked_accuracy_counts(
                x, labels, w1_sign, w1_shift, b1, mb1,
                w2_sign, w2_shift, b2, mb2, m1, m2, act_shift,
            ),
        )

    return jax.jit(fn).lower(
        i32(b, n0), i32(b),
        i32(h, n0), i32(h, n0), i32(h), i32(p, h),
        i32(o, h), i32(o, h), i32(o), i32(p, o),
        i32(p, h, n0), i32(p, o, h), i32(),
    )


def lower_masked_preacts(n0, h, o, b, p=P_PRE):
    def fn(x, w1_sign, w1_shift, b1, mb1, w2_sign, w2_shift, b2, mb2, m1, m2, act_shift):
        return (
            model.masked_preacts(
                x, w1_sign, w1_shift, b1, mb1,
                w2_sign, w2_shift, b2, mb2, m1, m2, act_shift,
            ),
        )

    return jax.jit(fn).lower(
        i32(b, n0),
        i32(h, n0), i32(h, n0), i32(h), i32(p, h),
        i32(o, h), i32(o, h), i32(o), i32(p, o),
        i32(p, h, n0), i32(p, o, h), i32(),
    )


def lower_train_step(n0, h, o, bt=BT):
    def fn(*args):
        return model.train_step_flat(*args)

    w1, b1, w2, b2 = f32(h, n0), f32(h), f32(o, h), f32(o)
    return jax.jit(fn).lower(
        w1, b1, w2, b2,          # params
        w1, b1, w2, b2,          # adam m
        w1, b1, w2, b2,          # adam v
        i32(),                   # step
        f32(bt, n0), i32(bt),    # batch
        f32(bt),                 # sample weights
        f32(),                   # lr
        f32(),                   # act_max (calibrated QRelu range)
    )


def source_hash() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    hasher = hashlib.sha256()
    for rel in ("model.py", "aot.py", "kernels/masked_mac.py", "kernels/ref.py"):
        with open(os.path.join(here, rel), "rb") as fh:
            hasher.update(fh.read())
    return hasher.hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated topology names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")

    src = source_hash()
    manifest = {"source_hash": src, "p_tile": P_TILE, "p_pre": P_PRE, "bt": BT, "entries": {}}
    old = None
    if os.path.exists(manifest_path):
        with open(manifest_path) as fh:
            old = json.load(fh)
        if old.get("source_hash") == src and not args.only:
            expected = {
                f"{kind}_{name}.hlo.txt"
                for (name, *_ ) in TOPOLOGIES
                for kind in ("masked_acc", "masked_preacts", "train_step")
            }
            have = set(os.listdir(args.out))
            if expected <= have:
                print(f"artifacts up to date (hash {src}); skipping")
                return 0

    only = set(args.only.split(",")) if args.only else None
    for name, n0, h, o, b in TOPOLOGIES:
        if only and name not in only:
            continue
        jobs = [
            (f"masked_acc_{name}.hlo.txt", lower_masked_acc(n0, h, o, b)),
            (f"masked_preacts_{name}.hlo.txt", lower_masked_preacts(n0, h, o, b)),
            (f"train_step_{name}.hlo.txt", lower_train_step(n0, h, o)),
        ]
        for fname, lowered in jobs:
            text = to_hlo_text(lowered)
            with open(os.path.join(args.out, fname), "w") as fh:
                fh.write(text)
            print(f"wrote {fname}: {len(text)} chars")
        manifest["entries"][name] = {
            "n_in": n0, "n_hidden": h, "n_out": o, "eval_batch": b,
        }
    if only and old:
        # Merge previously written entries.
        for k, v in old.get("entries", {}).items():
            manifest["entries"].setdefault(k, v)
    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=2, sort_keys=True)
    print(f"manifest -> {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
