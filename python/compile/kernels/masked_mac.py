"""Layer-1 Pallas kernel: the masked shifted multiply-accumulate.

This is the compute hot-spot of the paper's framework: the genetic
optimizer must evaluate thousands of accumulation-approximation
chromosomes per generation, and each evaluation is a full MLP forward
pass where every summand is ``(activation & mask) << shift`` with the
positive/negative weight split of the bespoke circuit (paper §III-A,
§III-D2: "a bitwise AND between each mask and summand is performed and
then addition is just computed on the masked summands").

The kernel is gridded over the chromosome (population) dimension: each
program instance evaluates one chromosome's masks over the whole
evaluation batch. ``interpret=True`` everywhere — the CPU PJRT client
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md); on a
real TPU the same BlockSpec tiles the mask tile + activation tile into
VMEM (DESIGN.md §6).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_mac_kernel(x_ref, sign_ref, shift_ref, mask_ref, bias_ref, bkeep_ref, o_ref):
    """One chromosome:
    ``o[b, n] = Σ_j sign[n,j]·((x[b,j] & mask[n,j]) << shift[n,j])
                + bkeep[n]·bias[n]``.

    Block shapes inside the kernel:
      x:     (B, J)    int32 — layer inputs (shared across the grid)
      sign:  (N, J)    int32 — weight signs in {-1, 0, +1}
      shift: (N, J)    int32 — power-of-2 shifts in [0, 7]
      mask:  (1, N, J) int32 — this chromosome's summand-bit masks
      bias:  (1, N)    int32 — signed integer bias values
      bkeep: (1, N)    int32 — this chromosome's bias keep flags (0/1)
      o:     (1, B, N) int32 — pre-activations
    """
    x = x_ref[...]
    sign = sign_ref[...]
    shift = shift_ref[...]
    mask = mask_ref[0]
    bias = bias_ref[0]
    bkeep = bkeep_ref[0]
    # (B, 1, J) & (1, N, J) -> (B, N, J): mask the summand bits, apply the
    # power-of-2 shift (wiring in the bespoke circuit), apply the pos/neg
    # tree sign, reduce over the fan-in.
    masked = jnp.bitwise_and(x[:, None, :], mask[None, :, :])
    shifted = jnp.left_shift(masked, shift[None, :, :])
    signed = shifted * sign[None, :, :]
    acc = jnp.sum(signed, axis=-1)
    o_ref[0] = acc + (bias * bkeep)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_mac(x, sign, shift, mask, bias, bkeep, *, interpret=True):
    """Population-batched masked MAC.

    Args:
      x:     (B, J) int32 — layer inputs.
      sign:  (N, J) int32 — weight signs.
      shift: (N, J) int32 — weight shifts.
      mask:  (P, N, J) int32 — per-chromosome summand-bit masks.
      bias:  (N,) int32 — signed bias integer values.
      bkeep: (P, N) int32 — per-chromosome bias keep flags.

    Returns:
      (P, B, N) int32 pre-activations.
    """
    p, n, j = mask.shape
    b = x.shape[0]
    assert x.shape == (b, j), (x.shape, (b, j))
    assert sign.shape == (n, j) and shift.shape == (n, j)
    assert bias.shape == (n,) and bkeep.shape == (p, n)
    return pl.pallas_call(
        _masked_mac_kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((b, j), lambda i: (0, 0)),        # x shared
            pl.BlockSpec((n, j), lambda i: (0, 0)),        # sign shared
            pl.BlockSpec((n, j), lambda i: (0, 0)),        # shift shared
            pl.BlockSpec((1, n, j), lambda i: (i, 0, 0)),  # mask per-chromosome
            pl.BlockSpec((1, n), lambda i: (0, 0)),        # bias shared
            pl.BlockSpec((1, n), lambda i: (i, 0)),        # bkeep per-chromosome
        ],
        out_specs=pl.BlockSpec((1, b, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, b, n), jnp.int32),
        interpret=interpret,
    )(x, sign, shift, mask, bias[None, :], bkeep)


def qrelu(z, act_shift, act_bits=8):
    """QRelu: clamp(z >> t, 0, 2^act_bits - 1) on int32 (paper §III-C1)."""
    shifted = jnp.right_shift(jnp.maximum(z, 0), act_shift)
    return jnp.clip(shifted, 0, (1 << act_bits) - 1)
