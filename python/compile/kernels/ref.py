"""Pure-jnp/numpy correctness oracles for the Layer-1 Pallas kernel and
the Layer-2 quantized forward pass.

These mirror, operation for operation, the golden integer semantics of
the Rust model (`rust/src/model/quantized.rs`): the pytest suite checks
`pallas kernel == jnp ref == numpy ref` exactly (integer arithmetic --
no tolerance), and the Rust integration tests close the chain with
`HLO-via-PJRT == native model`.
"""

import jax.numpy as jnp
import numpy as np


def masked_mac_ref(x, sign, shift, mask, bias, bkeep):
    """jnp reference of `masked_mac` (shapes as in the kernel)."""
    masked = jnp.bitwise_and(x[None, :, None, :], mask[:, None, :, :])
    shifted = jnp.left_shift(masked, shift[None, None, :, :])
    signed = shifted * sign[None, None, :, :]
    acc = jnp.sum(signed, axis=-1)  # (P, B, N)
    return acc + (bias[None, :] * bkeep)[:, None, :]


def masked_mac_np(x, sign, shift, mask, bias, bkeep):
    """numpy scalar-loop reference (deliberately naive -- the oracle)."""
    p, n, j = mask.shape
    b = x.shape[0]
    out = np.zeros((p, b, n), dtype=np.int64)
    for pi in range(p):
        for bi in range(b):
            for ni in range(n):
                acc = 0
                for ji in range(j):
                    if sign[ni, ji] == 0:
                        continue
                    a = int(x[bi, ji]) & int(mask[pi, ni, ji])
                    acc += int(sign[ni, ji]) * (a << int(shift[ni, ji]))
                acc += int(bkeep[pi, ni]) * int(bias[ni])
                out[pi, bi, ni] = acc
    return out


def qrelu_np(z, act_shift, act_bits=8):
    """numpy reference of QRelu."""
    z = np.asarray(z)
    shifted = np.right_shift(np.maximum(z, 0), act_shift)
    return np.clip(shifted, 0, (1 << act_bits) - 1)


def quant_forward_np(x, l1, l2, act_shift):
    """Full integer forward pass of the quantized MLP, numpy loops.

    `l1`/`l2` are dicts with keys sign (N,J), shift (N,J), bias (N,),
    and optional mask (N,J) / bkeep (N,).
    """

    def layer(a, lay):
        n, j = lay["sign"].shape
        mask = lay.get("mask", np.full((n, j), (1 << 30) - 1, dtype=np.int64))
        bkeep = lay.get("bkeep", np.ones(n, dtype=np.int64))
        out = np.zeros(n, dtype=np.int64)
        for ni in range(n):
            acc = 0
            for ji in range(j):
                if lay["sign"][ni, ji] == 0:
                    continue
                av = (int(a[ji]) & int(mask[ni, ji])) << int(lay["shift"][ni, ji])
                acc += int(lay["sign"][ni, ji]) * av
            acc += int(bkeep[ni]) * int(lay["bias"][ni])
            out[ni] = acc
        return out

    z1 = layer(x, l1)
    h = qrelu_np(z1, act_shift)
    z2 = layer(h, l2)
    return h, z2
