//! Configuration system.
//!
//! Every pipeline run is described by a [`RunConfig`]: dataset synthesis
//! parameters, MLP topology, quantization, training hyper-parameters,
//! genetic-optimization settings, and hardware constraints (clock period,
//! supply voltage). Configs serialize to/from JSON (`configs/*.json`) and
//! the six paper MLPs ship as built-ins ([`builtin`]).

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Synthetic-dataset specification (see DESIGN.md §3 — substitutes the
/// UCI datasets with generators matched in dimensionality, class
/// structure and baseline accuracy).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub n_features: usize,
    pub n_classes: usize,
    pub n_samples: usize,
    /// Relative class frequencies (normalized internally).
    pub class_weights: Vec<f64>,
    /// Distance between class centroids in feature space, in units of the
    /// per-cluster noise — the knob that sets achievable accuracy.
    pub separation: f64,
    /// Per-feature Gaussian noise std.
    pub noise: f64,
    /// Sub-clusters per class (multi-modal classes, as in Pendigits).
    pub clusters_per_class: usize,
    /// Fraction of features that carry no class signal (nuisance dims,
    /// as in Arrhythmia's many near-constant channels).
    pub nuisance_frac: f64,
    pub seed: u64,
}

/// MLP topology `(n_in, n_hidden, n_out)` — single hidden layer, as all
/// printed MLPs in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
}

impl Topology {
    pub fn new(n_in: usize, n_hidden: usize, n_out: usize) -> Self {
        Topology { n_in, n_hidden, n_out }
    }
    /// Total weight count (the paper's "parameters" metric for Table V).
    pub fn n_params(&self) -> usize {
        self.n_in * self.n_hidden + self.n_hidden * self.n_out
    }
}

/// Training hyper-parameters for the QAT phase (driven from Rust over the
/// AOT `train_step` artifact).
#[derive(Clone, Debug)]
pub struct TrainSpec {
    pub epochs: usize,
    pub batch_size: usize,
    pub lr: f64,
    pub seed: u64,
}

/// Genetic-optimization settings (paper §III-D1: NSGA-II, population
/// 1000, 30 generations, 15% accuracy-loss bound, init biased toward
/// non-approximated bits).
#[derive(Clone, Debug)]
pub struct GaSpec {
    pub population: usize,
    pub generations: usize,
    /// Per-bit flip probability during mutation.
    pub mutation_rate: f64,
    pub crossover_rate: f64,
    /// Hard bound on accuracy loss vs the QAT model (paper: 15%).
    pub acc_loss_bound: f64,
    /// Probability that a bit starts as kept (=1) in the initial
    /// population (biased toward exact, paper §III-D1).
    pub init_keep_prob: f64,
    pub seed: u64,
}

/// Hardware constraints for synthesis/analysis.
#[derive(Clone, Debug)]
pub struct HwSpec {
    /// Target clock period in milliseconds (paper: 200 except Pendigits
    /// 250 and Arrhythmia 320).
    pub clock_ms: f64,
    /// Supply voltage in volts (1.0 for the main evaluation, 0.6 for the
    /// battery study of Table V).
    pub vdd: f64,
}

/// A complete pipeline run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: DatasetSpec,
    pub topology: Topology,
    pub train: TrainSpec,
    pub ga: GaSpec,
    pub hw: HwSpec,
}

impl RunConfig {
    // ----- JSON ----------------------------------------------------------
    pub fn to_json(&self) -> Json {
        let d = &self.dataset;
        Json::obj(vec![
            (
                "dataset",
                Json::obj(vec![
                    ("name", Json::str(&d.name)),
                    ("n_features", Json::num(d.n_features as f64)),
                    ("n_classes", Json::num(d.n_classes as f64)),
                    ("n_samples", Json::num(d.n_samples as f64)),
                    (
                        "class_weights",
                        Json::arr(d.class_weights.iter().map(|&w| Json::num(w)).collect()),
                    ),
                    ("separation", Json::num(d.separation)),
                    ("noise", Json::num(d.noise)),
                    ("clusters_per_class", Json::num(d.clusters_per_class as f64)),
                    ("nuisance_frac", Json::num(d.nuisance_frac)),
                    ("seed", Json::num(d.seed as f64)),
                ]),
            ),
            (
                "topology",
                Json::arr(vec![
                    Json::num(self.topology.n_in as f64),
                    Json::num(self.topology.n_hidden as f64),
                    Json::num(self.topology.n_out as f64),
                ]),
            ),
            (
                "train",
                Json::obj(vec![
                    ("epochs", Json::num(self.train.epochs as f64)),
                    ("batch_size", Json::num(self.train.batch_size as f64)),
                    ("lr", Json::num(self.train.lr)),
                    ("seed", Json::num(self.train.seed as f64)),
                ]),
            ),
            (
                "ga",
                Json::obj(vec![
                    ("population", Json::num(self.ga.population as f64)),
                    ("generations", Json::num(self.ga.generations as f64)),
                    ("mutation_rate", Json::num(self.ga.mutation_rate)),
                    ("crossover_rate", Json::num(self.ga.crossover_rate)),
                    ("acc_loss_bound", Json::num(self.ga.acc_loss_bound)),
                    ("init_keep_prob", Json::num(self.ga.init_keep_prob)),
                    ("seed", Json::num(self.ga.seed as f64)),
                ]),
            ),
            (
                "hw",
                Json::obj(vec![
                    ("clock_ms", Json::num(self.hw.clock_ms)),
                    ("vdd", Json::num(self.hw.vdd)),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let d = j.get("dataset").ok_or_else(|| anyhow!("missing 'dataset'"))?;
        let topo = j
            .get("topology")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing 'topology'"))?;
        if topo.len() != 3 {
            return Err(anyhow!("topology must be [in, hidden, out]"));
        }
        let t = j.get("train").cloned().unwrap_or(Json::obj(vec![]));
        let g = j.get("ga").cloned().unwrap_or(Json::obj(vec![]));
        let h = j.get("hw").cloned().unwrap_or(Json::obj(vec![]));
        let class_weights = d
            .get("class_weights")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_f64).collect())
            .unwrap_or_default();
        Ok(RunConfig {
            dataset: DatasetSpec {
                name: d.str_or("name", "unnamed").to_string(),
                n_features: d.usize_or("n_features", 8),
                n_classes: d.usize_or("n_classes", 2),
                n_samples: d.usize_or("n_samples", 1000),
                class_weights,
                separation: d.f64_or("separation", 3.0),
                noise: d.f64_or("noise", 0.12),
                clusters_per_class: d.usize_or("clusters_per_class", 1),
                nuisance_frac: d.f64_or("nuisance_frac", 0.0),
                seed: d.usize_or("seed", 1) as u64,
            },
            topology: Topology::new(
                topo[0].as_usize().unwrap_or(0),
                topo[1].as_usize().unwrap_or(0),
                topo[2].as_usize().unwrap_or(0),
            ),
            train: TrainSpec {
                epochs: t.usize_or("epochs", 60),
                batch_size: t.usize_or("batch_size", 64),
                lr: t.f64_or("lr", 0.01),
                seed: t.usize_or("seed", 7) as u64,
            },
            ga: GaSpec {
                population: g.usize_or("population", 100),
                generations: g.usize_or("generations", 10),
                mutation_rate: g.f64_or("mutation_rate", 0.01),
                crossover_rate: g.f64_or("crossover_rate", 0.9),
                acc_loss_bound: g.f64_or("acc_loss_bound", 0.15),
                init_keep_prob: g.f64_or("init_keep_prob", 0.9),
                seed: g.usize_or("seed", 42) as u64,
            },
            hw: HwSpec { clock_ms: h.f64_or("clock_ms", 200.0), vdd: h.f64_or("vdd", 1.0) },
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        RunConfig::from_json(&j)
    }
}

/// The six paper MLPs (+ a tiny CI config) as built-in run configs.
pub mod builtin {
    use super::*;

    /// Look a built-in config up by (case-insensitive) name.
    pub fn by_name(name: &str) -> Option<RunConfig> {
        let n = name.to_lowercase();
        all().into_iter().find(|c| c.dataset.name.to_lowercase() == n)
    }

    /// Names of the six paper datasets in the paper's table order.
    pub fn paper_names() -> Vec<&'static str> {
        vec!["arrhythmia", "breastcancer", "cardio", "pendigits", "redwine", "whitewine"]
    }

    /// All built-in configs (six paper MLPs + `tiny`).
    pub fn all() -> Vec<RunConfig> {
        vec![
            arrhythmia(),
            breastcancer(),
            cardio(),
            pendigits(),
            redwine(),
            whitewine(),
            tiny(),
        ]
    }

    fn base_ga(seed: u64) -> GaSpec {
        GaSpec {
            population: 100,
            generations: 12,
            mutation_rate: 0.008,
            crossover_rate: 0.9,
            acc_loss_bound: 0.15,
            init_keep_prob: 0.92,
            seed,
        }
    }

    fn base_train(seed: u64) -> TrainSpec {
        TrainSpec { epochs: 80, batch_size: 64, lr: 0.02, seed }
    }

    /// Arrhythmia — (274, 5, 16), the paper's largest MLP (1,450 weights;
    /// its battery-powered operation is the headline claim).
    pub fn arrhythmia() -> RunConfig {
        // UCI Arrhythmia: 452 samples, 16 highly imbalanced classes
        // (class 1 = normal dominates), many uninformative channels.
        let mut cw = vec![0.54, 0.10, 0.033, 0.033, 0.03, 0.055, 0.007, 0.005];
        cw.extend(vec![0.02, 0.011, 0.0, 0.0, 0.002, 0.01, 0.05, 0.10]);
        RunConfig {
            dataset: DatasetSpec {
                name: "arrhythmia".into(),
                n_features: 274,
                n_classes: 16,
                n_samples: 452,
                class_weights: cw,
                separation: 5.8,
                noise: 0.17,
                clusters_per_class: 1,
                // UCI Arrhythmia is dominated by near-constant /
                // redundant channels: ~90% of its 274 features carry no
                // class signal — which is exactly what makes the paper's
                // deep accumulation pruning possible on this MLP.
                nuisance_frac: 0.8,
                seed: 101,
            },
            topology: Topology::new(274, 5, 16),
            train: base_train(101),
            ga: base_ga(101),
            hw: HwSpec { clock_ms: 320.0, vdd: 1.0 },
        }
    }

    /// Breast Cancer (Wisconsin original) — (10, 3, 2).
    pub fn breastcancer() -> RunConfig {
        RunConfig {
            dataset: DatasetSpec {
                name: "breastcancer".into(),
                n_features: 10,
                n_classes: 2,
                n_samples: 699,
                class_weights: vec![0.655, 0.345],
                separation: 3.4,
                noise: 0.16,
                clusters_per_class: 1,
                nuisance_frac: 0.0,
                seed: 102,
            },
            topology: Topology::new(10, 3, 2),
            train: base_train(102),
            ga: base_ga(102),
            hw: HwSpec { clock_ms: 200.0, vdd: 1.0 },
        }
    }

    /// Cardiotocography — (21, 3, 3).
    pub fn cardio() -> RunConfig {
        RunConfig {
            dataset: DatasetSpec {
                name: "cardio".into(),
                n_features: 21,
                n_classes: 3,
                n_samples: 2126,
                class_weights: vec![0.78, 0.14, 0.08],
                separation: 3.4,
                noise: 0.15,
                clusters_per_class: 2,
                nuisance_frac: 0.2,
                seed: 103,
            },
            topology: Topology::new(21, 3, 3),
            train: base_train(103),
            ga: base_ga(103),
            hw: HwSpec { clock_ms: 200.0, vdd: 1.0 },
        }
    }

    /// Pendigits — (16, 5, 10).
    pub fn pendigits() -> RunConfig {
        RunConfig {
            dataset: DatasetSpec {
                name: "pendigits".into(),
                n_features: 16,
                n_classes: 10,
                n_samples: 7494,
                class_weights: vec![0.1; 10],
                separation: 4.6,
                noise: 0.13,
                clusters_per_class: 1,
                nuisance_frac: 0.0,
                seed: 104,
            },
            topology: Topology::new(16, 5, 10),
            train: base_train(104),
            ga: base_ga(104),
            hw: HwSpec { clock_ms: 250.0, vdd: 1.0 },
        }
    }

    /// Red Wine quality — (11, 2, 6). Low-separability regression-ish
    /// labels; the paper's baseline accuracy is only 0.564.
    pub fn redwine() -> RunConfig {
        RunConfig {
            dataset: DatasetSpec {
                name: "redwine".into(),
                n_features: 11,
                n_classes: 6,
                n_samples: 1599,
                class_weights: vec![0.006, 0.033, 0.426, 0.399, 0.124, 0.012],
                separation: 1.08,
                noise: 0.16,
                clusters_per_class: 1,
                nuisance_frac: 0.2,
                seed: 105,
            },
            topology: Topology::new(11, 2, 6),
            train: base_train(105),
            ga: base_ga(105),
            hw: HwSpec { clock_ms: 200.0, vdd: 1.0 },
        }
    }

    /// White Wine quality — (11, 4, 7).
    pub fn whitewine() -> RunConfig {
        RunConfig {
            dataset: DatasetSpec {
                name: "whitewine".into(),
                n_features: 11,
                n_classes: 7,
                n_samples: 4898,
                class_weights: vec![0.004, 0.033, 0.297, 0.449, 0.180, 0.036, 0.001],
                separation: 0.92,
                noise: 0.16,
                clusters_per_class: 1,
                nuisance_frac: 0.2,
                seed: 106,
            },
            topology: Topology::new(11, 4, 7),
            train: base_train(106),
            ga: base_ga(106),
            hw: HwSpec { clock_ms: 200.0, vdd: 1.0 },
        }
    }

    /// Tiny config for CI, quickstart, and property tests.
    pub fn tiny() -> RunConfig {
        RunConfig {
            dataset: DatasetSpec {
                name: "tiny".into(),
                n_features: 6,
                n_classes: 3,
                n_samples: 300,
                class_weights: vec![0.4, 0.35, 0.25],
                separation: 4.0,
                noise: 0.12,
                clusters_per_class: 1,
                nuisance_frac: 0.0,
                seed: 100,
            },
            topology: Topology::new(6, 3, 3),
            train: TrainSpec { epochs: 40, batch_size: 32, lr: 0.03, seed: 100 },
            ga: GaSpec {
                population: 40,
                generations: 6,
                mutation_rate: 0.02,
                crossover_rate: 0.9,
                acc_loss_bound: 0.15,
                init_keep_prob: 0.9,
                seed: 100,
            },
            hw: HwSpec { clock_ms: 200.0, vdd: 1.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_topologies_match_paper_table3() {
        let t = |n: &str| builtin::by_name(n).unwrap().topology;
        assert_eq!(t("arrhythmia"), Topology::new(274, 5, 16));
        assert_eq!(t("breastcancer"), Topology::new(10, 3, 2));
        assert_eq!(t("cardio"), Topology::new(21, 3, 3));
        assert_eq!(t("pendigits"), Topology::new(16, 5, 10));
        assert_eq!(t("redwine"), Topology::new(11, 2, 6));
        assert_eq!(t("whitewine"), Topology::new(11, 4, 7));
    }

    #[test]
    fn arrhythmia_param_count_is_1450() {
        // Paper §IV-C: "battery operation of a printed MLP that features
        // 1,450 parameters (weights)".
        assert_eq!(builtin::arrhythmia().topology.n_params(), 1450);
    }

    #[test]
    fn clock_periods_match_paper() {
        assert_eq!(builtin::arrhythmia().hw.clock_ms, 320.0);
        assert_eq!(builtin::pendigits().hw.clock_ms, 250.0);
        assert_eq!(builtin::cardio().hw.clock_ms, 200.0);
    }

    #[test]
    fn json_roundtrip() {
        for cfg in builtin::all() {
            let j = cfg.to_json();
            let back = RunConfig::from_json(&j).unwrap();
            assert_eq!(back.dataset.name, cfg.dataset.name);
            assert_eq!(back.topology, cfg.topology);
            assert_eq!(back.ga.population, cfg.ga.population);
            assert_eq!(back.hw.clock_ms, cfg.hw.clock_ms);
            assert_eq!(back.dataset.class_weights.len(), cfg.dataset.class_weights.len());
        }
    }

    #[test]
    fn by_name_case_insensitive() {
        assert!(builtin::by_name("Cardio").is_some());
        assert!(builtin::by_name("CARDIO").is_some());
        assert!(builtin::by_name("nope").is_none());
    }

    #[test]
    fn save_load_file() {
        let cfg = builtin::tiny();
        let dir = std::env::temp_dir().join("pmlp_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.json");
        cfg.save(&path).unwrap();
        let back = RunConfig::load(&path).unwrap();
        assert_eq!(back.dataset.name, "tiny");
        assert_eq!(back.topology, cfg.topology);
    }
}
