//! Synthesis-lite: the logic-optimization pass that stands in for
//! Synopsys Design Compiler in the paper's flow.
//!
//! The accumulation approximation works by *replacing summand bits with
//! constant zeros* and letting synthesis sweep the constants through the
//! adder trees (paper §III-D: "we fully leverage the IPs and optimization
//! capabilities of the EDA synthesis tool, which among others includes
//! constant propagation"). This pass implements exactly that mechanism:
//!
//! * constant propagation and algebraic simplification
//!   (`x & 0 → 0`, `x ^ 0 → x`, `x & x → x`, `mux(s,a,a) → a`, …),
//! * structural hashing (common-subexpression elimination),
//! * dead-gate elimination (only the output cone survives).
//!
//! The result is functionally equivalent (verified by `crate::sim`-based
//! equivalence tests) and is what the EGFET area/power/timing analysis
//! consumes.

use crate::netlist::{Gate, Netlist, NodeId};
use std::collections::HashMap;

/// What a source node resolved to after optimization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Repr {
    Node(NodeId),
    Const(bool),
}

/// Optimization statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SynthStats {
    pub cells_in: usize,
    pub cells_out: usize,
}

/// Optimize a netlist: constant propagation + structural hashing + DCE.
pub fn optimize(nl: &Netlist) -> (Netlist, SynthStats) {
    let mut out = Netlist::new();
    let mut repr: Vec<Repr> = Vec::with_capacity(nl.gates.len());
    // Structural-hash table over gates already emitted into `out`.
    let mut dedup: HashMap<Gate, NodeId> = HashMap::new();
    // Lazily-created constants in `out`.
    let mut consts: [Option<NodeId>; 2] = [None, None];

    // Emit with hashing.
    let mut emit = |out: &mut Netlist, dedup: &mut HashMap<Gate, NodeId>, g: Gate| -> NodeId {
        let g = canon(g);
        if let Some(&id) = dedup.get(&g) {
            return id;
        }
        let id = match g {
            Gate::Input(_) => {
                // Inputs are pre-created below; unreachable here.
                unreachable!("inputs emitted eagerly")
            }
            _ => {
                out.gates.push(g);
                (out.gates.len() - 1) as NodeId
            }
        };
        dedup.insert(g, id);
        id
    };

    let mut get_const = |out: &mut Netlist, v: bool| -> NodeId {
        let slot = &mut consts[v as usize];
        if let Some(id) = *slot {
            return id;
        }
        out.gates.push(Gate::Const(v));
        let id = (out.gates.len() - 1) as NodeId;
        *slot = Some(id);
        id
    };

    // Pre-create all primary inputs so input indices survive unchanged.
    let mut input_map: HashMap<u32, NodeId> = HashMap::new();
    for g in &nl.gates {
        if let Gate::Input(idx) = g {
            input_map.entry(*idx).or_insert(0);
        }
    }
    out.n_inputs = nl.n_inputs;
    let mut sorted_inputs: Vec<u32> = input_map.keys().copied().collect();
    sorted_inputs.sort_unstable();
    for idx in sorted_inputs {
        out.gates.push(Gate::Input(idx));
        let id = (out.gates.len() - 1) as NodeId;
        input_map.insert(idx, id);
        dedup.insert(Gate::Input(idx), id);
    }

    for g in &nl.gates {
        let r = match *g {
            Gate::Input(idx) => Repr::Node(input_map[&idx]),
            Gate::Const(v) => Repr::Const(v),
            Gate::Not(a) => match repr[a as usize] {
                Repr::Const(v) => Repr::Const(!v),
                Repr::Node(n) => {
                    // NOT(NOT(x)) -> x
                    if let Gate::Not(inner) = out.gates[n as usize] {
                        Repr::Node(inner)
                    } else {
                        Repr::Node(emit(&mut out, &mut dedup, Gate::Not(n)))
                    }
                }
            },
            Gate::And(a, b) => binop(
                repr[a as usize],
                repr[b as usize],
                &mut out,
                &mut dedup,
                &mut emit,
                BinRules {
                    both: |x, y| x & y,
                    with_true: WithConst::Other,
                    with_false: WithConst::Const(false),
                    same: SameRule::Same,
                    build: Gate::And,
                },
            ),
            Gate::Or(a, b) => binop(
                repr[a as usize],
                repr[b as usize],
                &mut out,
                &mut dedup,
                &mut emit,
                BinRules {
                    both: |x, y| x | y,
                    with_true: WithConst::Const(true),
                    with_false: WithConst::Other,
                    same: SameRule::Same,
                    build: Gate::Or,
                },
            ),
            Gate::Xor(a, b) => binop(
                repr[a as usize],
                repr[b as usize],
                &mut out,
                &mut dedup,
                &mut emit,
                BinRules {
                    both: |x, y| x ^ y,
                    with_true: WithConst::NotOther,
                    with_false: WithConst::Other,
                    same: SameRule::Const(false),
                    build: Gate::Xor,
                },
            ),
            Gate::Nand(a, b) => binop(
                repr[a as usize],
                repr[b as usize],
                &mut out,
                &mut dedup,
                &mut emit,
                BinRules {
                    both: |x, y| !(x & y),
                    with_true: WithConst::NotOther,
                    with_false: WithConst::Const(true),
                    same: SameRule::NotSame,
                    build: Gate::Nand,
                },
            ),
            Gate::Nor(a, b) => binop(
                repr[a as usize],
                repr[b as usize],
                &mut out,
                &mut dedup,
                &mut emit,
                BinRules {
                    both: |x, y| !(x | y),
                    with_true: WithConst::Const(false),
                    with_false: WithConst::NotOther,
                    same: SameRule::NotSame,
                    build: Gate::Nor,
                },
            ),
            Gate::Xnor(a, b) => binop(
                repr[a as usize],
                repr[b as usize],
                &mut out,
                &mut dedup,
                &mut emit,
                BinRules {
                    both: |x, y| !(x ^ y),
                    with_true: WithConst::Other,
                    with_false: WithConst::NotOther,
                    same: SameRule::Const(true),
                    build: Gate::Xnor,
                },
            ),
            Gate::Mux(s, a, b) => {
                let (rs, ra, rb) = (repr[s as usize], repr[a as usize], repr[b as usize]);
                match (rs, ra, rb) {
                    (Repr::Const(false), _, _) => ra,
                    (Repr::Const(true), _, _) => rb,
                    (_, Repr::Const(x), Repr::Const(y)) if x == y => Repr::Const(x),
                    // mux(s, 0, 1) = s ; mux(s, 1, 0) = !s
                    (Repr::Node(sn), Repr::Const(false), Repr::Const(true)) => Repr::Node(sn),
                    (Repr::Node(sn), Repr::Const(true), Repr::Const(false)) => {
                        Repr::Node(emit(&mut out, &mut dedup, Gate::Not(sn)))
                    }
                    // Equal-constant arms are covered by the x == y guard
                    // above; rustc cannot see that, so mark unreachable.
                    (Repr::Node(_), Repr::Const(_), Repr::Const(_)) => unreachable!(),
                    // mux(s, 0, b) = s & b ; mux(s, 1, b) = !s | b
                    (Repr::Node(sn), Repr::Const(false), Repr::Node(bn)) => {
                        Repr::Node(emit(&mut out, &mut dedup, Gate::And(sn, bn)))
                    }
                    (Repr::Node(sn), Repr::Const(true), Repr::Node(bn)) => {
                        let ns = emit(&mut out, &mut dedup, Gate::Not(sn));
                        Repr::Node(emit(&mut out, &mut dedup, Gate::Or(ns, bn)))
                    }
                    // mux(s, a, 0) = !s & a ; mux(s, a, 1) = s | a
                    (Repr::Node(sn), Repr::Node(an), Repr::Const(false)) => {
                        let ns = emit(&mut out, &mut dedup, Gate::Not(sn));
                        Repr::Node(emit(&mut out, &mut dedup, Gate::And(ns, an)))
                    }
                    (Repr::Node(sn), Repr::Node(an), Repr::Const(true)) => {
                        Repr::Node(emit(&mut out, &mut dedup, Gate::Or(sn, an)))
                    }
                    (Repr::Node(sn), Repr::Node(an), Repr::Node(bn)) => {
                        if an == bn {
                            Repr::Node(an)
                        } else {
                            Repr::Node(emit(&mut out, &mut dedup, Gate::Mux(sn, an, bn)))
                        }
                    }
                }
            }
        };
        repr.push(r);
    }

    // Rewrite outputs, materializing constants where needed.
    for (name, bus) in &nl.outputs {
        let new_bus: Vec<NodeId> = bus
            .iter()
            .map(|&n| match repr[n as usize] {
                Repr::Node(id) => id,
                Repr::Const(v) => get_const(&mut out, v),
            })
            .collect();
        out.outputs.push((name.clone(), new_bus));
    }

    let out = dce(&out);
    let stats = SynthStats { cells_in: nl.cell_count(), cells_out: out.cell_count() };
    (out, stats)
}

/// How a binary op simplifies against a constant operand.
#[derive(Clone, Copy)]
enum WithConst {
    /// Result is the non-constant operand.
    Other,
    /// Result is NOT of the non-constant operand.
    NotOther,
    /// Result is a constant.
    Const(bool),
}

#[derive(Clone, Copy)]
enum SameRule {
    /// op(x, x) = x
    Same,
    /// op(x, x) = !x
    NotSame,
    /// op(x, x) = const
    Const(bool),
}

struct BinRules {
    both: fn(bool, bool) -> bool,
    with_true: WithConst,
    with_false: WithConst,
    same: SameRule,
    build: fn(NodeId, NodeId) -> Gate,
}

fn binop(
    ra: Repr,
    rb: Repr,
    out: &mut Netlist,
    dedup: &mut HashMap<Gate, NodeId>,
    emit: &mut impl FnMut(&mut Netlist, &mut HashMap<Gate, NodeId>, Gate) -> NodeId,
    rules: BinRules,
) -> Repr {
    match (ra, rb) {
        (Repr::Const(x), Repr::Const(y)) => Repr::Const((rules.both)(x, y)),
        (Repr::Const(c), Repr::Node(n)) | (Repr::Node(n), Repr::Const(c)) => {
            let rule = if c { rules.with_true } else { rules.with_false };
            match rule {
                WithConst::Other => Repr::Node(n),
                WithConst::NotOther => Repr::Node(emit(out, dedup, Gate::Not(n))),
                WithConst::Const(v) => Repr::Const(v),
            }
        }
        (Repr::Node(x), Repr::Node(y)) => {
            if x == y {
                match rules.same {
                    SameRule::Same => Repr::Node(x),
                    SameRule::NotSame => Repr::Node(emit(out, dedup, Gate::Not(x))),
                    SameRule::Const(v) => Repr::Const(v),
                }
            } else {
                Repr::Node(emit(out, dedup, (rules.build)(x, y)))
            }
        }
    }
}

/// Canonicalize commutative gates (sorted operands) for hashing.
fn canon(g: Gate) -> Gate {
    match g {
        Gate::And(a, b) if a > b => Gate::And(b, a),
        Gate::Or(a, b) if a > b => Gate::Or(b, a),
        Gate::Xor(a, b) if a > b => Gate::Xor(b, a),
        Gate::Nand(a, b) if a > b => Gate::Nand(b, a),
        Gate::Nor(a, b) if a > b => Gate::Nor(b, a),
        Gate::Xnor(a, b) if a > b => Gate::Xnor(b, a),
        g => g,
    }
}

/// Dead-code elimination: keep only nodes reachable from outputs (plus
/// all primary inputs, which define the interface).
fn dce(nl: &Netlist) -> Netlist {
    let n = nl.gates.len();
    let mut live = vec![false; n];
    let mut stack: Vec<NodeId> = Vec::new();
    for (_, bus) in &nl.outputs {
        for &b in bus {
            if !live[b as usize] {
                live[b as usize] = true;
                stack.push(b);
            }
        }
    }
    while let Some(id) = stack.pop() {
        for op in nl.gates[id as usize].operands() {
            if !live[op as usize] {
                live[op as usize] = true;
                stack.push(op);
            }
        }
    }
    // Inputs stay (interface stability for the simulator).
    for (i, g) in nl.gates.iter().enumerate() {
        if matches!(g, Gate::Input(_)) {
            live[i] = true;
        }
    }
    let mut remap = vec![0 as NodeId; n];
    let mut out = Netlist::new();
    out.n_inputs = nl.n_inputs;
    for (i, g) in nl.gates.iter().enumerate() {
        if !live[i] {
            continue;
        }
        let g2 = match *g {
            Gate::Input(idx) => Gate::Input(idx),
            Gate::Const(v) => Gate::Const(v),
            Gate::Not(a) => Gate::Not(remap[a as usize]),
            Gate::And(a, b) => Gate::And(remap[a as usize], remap[b as usize]),
            Gate::Or(a, b) => Gate::Or(remap[a as usize], remap[b as usize]),
            Gate::Xor(a, b) => Gate::Xor(remap[a as usize], remap[b as usize]),
            Gate::Nand(a, b) => Gate::Nand(remap[a as usize], remap[b as usize]),
            Gate::Nor(a, b) => Gate::Nor(remap[a as usize], remap[b as usize]),
            Gate::Xnor(a, b) => Gate::Xnor(remap[a as usize], remap[b as usize]),
            Gate::Mux(s, a, b) => {
                Gate::Mux(remap[s as usize], remap[a as usize], remap[b as usize])
            }
        };
        out.gates.push(g2);
        remap[i] = (out.gates.len() - 1) as NodeId;
    }
    for (name, bus) in &nl.outputs {
        out.outputs
            .push((name.clone(), bus.iter().map(|&b| remap[b as usize]).collect()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::build;
    use crate::sim::{eval, u64_to_bits};
    use crate::util::prop;

    #[test]
    fn constants_propagate_through_and() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let zero = nl.constant(false);
        let g = nl.and(a, zero); // == 0
        let h = nl.or(g, a); // == a
        nl.output("y", vec![h]);
        let (opt, stats) = optimize(&nl);
        assert_eq!(stats.cells_out, 0, "everything should fold to a wire");
        assert_eq!(eval(&opt, &[true])["y"][0], true);
        assert_eq!(eval(&opt, &[false])["y"][0], false);
    }

    #[test]
    fn structural_hashing_merges_duplicates() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let g1 = nl.and(a, b);
        let g2 = nl.and(b, a); // same gate, swapped operands
        let y = nl.xor(g1, g2); // x ^ x = 0
        nl.output("y", vec![y]);
        let (opt, _) = optimize(&nl);
        assert_eq!(opt.cell_count(), 0);
        assert_eq!(eval(&opt, &[true, true])["y"][0], false);
    }

    #[test]
    fn double_negation_removed() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let n1 = nl.not(a);
        let n2 = nl.not(n1);
        nl.output("y", vec![n2]);
        let (opt, _) = optimize(&nl);
        assert_eq!(opt.cell_count(), 0);
        assert_eq!(eval(&opt, &[true])["y"][0], true);
    }

    #[test]
    fn dce_removes_unused_logic() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let _unused = nl.xor(a, b);
        let used = nl.and(a, b);
        nl.output("y", vec![used]);
        let (opt, _) = optimize(&nl);
        assert_eq!(opt.cell_count(), 1);
    }

    #[test]
    fn mux_simplifications() {
        let mut nl = Netlist::new();
        let s = nl.input();
        let a = nl.input();
        let zero = nl.constant(false);
        let one = nl.constant(true);
        let m1 = nl.mux(s, zero, one); // = s
        let m2 = nl.mux(s, a, a); // = a
        let m3 = nl.mux(zero, a, one); // = a
        nl.output("y", vec![m1, m2, m3]);
        let (opt, _) = optimize(&nl);
        assert_eq!(opt.cell_count(), 0);
        let out = &eval(&opt, &[true, false])["y"];
        assert_eq!(out.as_slice(), &[true, false, false]);
    }

    #[test]
    fn prop_optimize_preserves_function() {
        // Random adder circuits with some constant inputs: the optimized
        // netlist must compute the same function.
        prop::check("synth preserves semantics", |rng, _| {
            let w = 4u32;
            let mut nl = Netlist::new();
            let a = nl.input_bus(w);
            let kconst = rng.below(16) as u64;
            let kb = build::const_bus(&mut nl, kconst, w);
            let s = build::adder(&mut nl, &a, &kb);
            let m = build::const_mul(&mut nl, &s, rng.below(8) as u64 + 1);
            nl.output("m", m);
            let (opt, stats) = optimize(&nl);
            if stats.cells_out > stats.cells_in {
                return Err("synthesis grew the circuit".to_string());
            }
            for _ in 0..8 {
                let x = rng.below(1 << w) as u64;
                let bits = u64_to_bits(x, w);
                let o1 = &eval(&nl, &bits)["m"];
                let o2 = &eval(&opt, &bits)["m"];
                if crate::sim::bus_to_u64(o1) != crate::sim::bus_to_u64(o2) {
                    return Err(format!("mismatch at x={x} k={kconst}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn masked_zero_bits_shrink_adder_tree() {
        // The paper's core mechanism: replacing summand bits by constant
        // zero must shrink the synthesized adder tree.
        let w = 4u32;
        let build_tree = |mask: u64| -> usize {
            let mut nl = Netlist::new();
            let mut summands = Vec::new();
            for _ in 0..4 {
                let bus = nl.input_bus(w);
                let masked: Vec<_> = bus
                    .iter()
                    .enumerate()
                    .map(|(i, &bit)| {
                        if (mask >> i) & 1 == 1 {
                            bit
                        } else {
                            nl.constant(false)
                        }
                    })
                    .collect();
                summands.push(masked);
            }
            let s = build::csa_tree(&mut nl, &summands);
            nl.output("s", s);
            let (opt, _) = optimize(&nl);
            opt.cell_count()
        };
        let full = build_tree(0xF);
        let half = build_tree(0b0110);
        let none = build_tree(0x0);
        assert!(half < full, "half {half} vs full {full}");
        assert_eq!(none, 0);
    }
}
