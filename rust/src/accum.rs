//! Accumulation approximation: the chromosome encoding of paper §III-D.
//!
//! A chromosome assigns one bit to every *summand bit* of every adder
//! tree in the MLP (eq. 1): `1` keeps the bit, `0` removes it (constant
//! zero in the circuit). This module owns the canonical summand-bit
//! enumeration shared by the genetic optimizer, the area surrogate, the
//! native and PJRT evaluators, and the netlist generator — everyone must
//! agree on which genome bit means which summand bit.
//!
//! Canonical order: layer 1 then layer 2; within a layer, neuron by
//! neuron; within a neuron, inputs `j = 0..n_in` with a non-zero weight,
//! each contributing `in_bits` bits LSB→MSB; the bias bit (if the neuron
//! has one) comes last. Positive- and negative-tree summands interleave
//! naturally in input order — the (tree, column) coordinates are derived
//! from the weight sign and shift.

use crate::config::Topology;
use crate::model::{MaskSet, QuantMlp};
use crate::util::{BitVec, Rng};

/// Where one genome bit lands in the circuit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SummandBit {
    /// 0 = hidden layer, 1 = output layer.
    pub layer: u8,
    /// Neuron index within the layer.
    pub neuron: u16,
    /// Input index within the neuron, or `BIAS` for the bias bit.
    pub input: u16,
    /// Bit position within the (unshifted) input (0 = LSB). 0 for bias.
    pub bit: u8,
    /// Adder-tree column the bit occupies (`shift + bit`).
    pub column: u8,
    /// true → positive tree, false → negative tree.
    pub pos_tree: bool,
}

/// Sentinel input index marking a bias summand.
pub const BIAS: u16 = u16::MAX;

/// Domain-informed GA seeds: LSB-truncated genomes. For every depth pair
/// `(d1, d2)` the seed removes all layer-1 summand bits in adder-tree
/// columns `< d1` and all layer-2 bits in columns `< d2` — the classic
/// coarse truncation the paper's related work applies, which the genetic
/// search then refines per bit. Seeding these gives NSGA-II immediate
/// deep-area anchors without waiting generations for them to emerge.
pub fn truncation_seeds(map: &GenomeMap, depths1: &[u8], depths2: &[u8]) -> Vec<crate::util::BitVec> {
    let mut out = Vec::new();
    for &d1 in depths1 {
        for &d2 in depths2 {
            let mut g = map.exact_genome();
            for (i, sb) in map.bits.iter().enumerate() {
                let depth = if sb.layer == 0 { d1 } else { d2 };
                if sb.column < depth {
                    g.set(i, false);
                }
            }
            out.push(g);
        }
    }
    out
}

/// The genome ⇄ mask mapping for one quantized MLP.
#[derive(Clone, Debug)]
pub struct GenomeMap {
    pub topo: Topology,
    pub bits: Vec<SummandBit>,
    in_bits1: u32,
    in_bits2: u32,
}

impl GenomeMap {
    /// Build the canonical map for a quantized MLP.
    pub fn new(mlp: &QuantMlp) -> GenomeMap {
        let mut bits = Vec::new();
        for (layer_idx, layer) in [&mlp.l1, &mlp.l2].into_iter().enumerate() {
            for n in 0..layer.n_out {
                for j in 0..layer.n_in {
                    let w = layer.weight(n, j);
                    if w.sign == 0 {
                        continue;
                    }
                    for b in 0..layer.in_bits {
                        bits.push(SummandBit {
                            layer: layer_idx as u8,
                            neuron: n as u16,
                            input: j as u16,
                            bit: b as u8,
                            column: w.shift + b as u8,
                            pos_tree: w.sign > 0,
                        });
                    }
                }
                let bias = layer.bias[n];
                if bias.is_nonzero() {
                    bits.push(SummandBit {
                        layer: layer_idx as u8,
                        neuron: n as u16,
                        input: BIAS,
                        bit: 0,
                        column: bias.shift,
                        pos_tree: bias.sign > 0,
                    });
                }
            }
        }
        GenomeMap {
            topo: mlp.topo,
            bits,
            in_bits1: mlp.l1.in_bits,
            in_bits2: mlp.l2.in_bits,
        }
    }

    /// Genome length (number of summand bits in the whole MLP).
    pub fn len(&self) -> usize {
        self.bits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// The exact genome (all bits kept).
    pub fn exact_genome(&self) -> BitVec {
        BitVec::ones(self.len())
    }

    /// Random genome with keep-probability `p` (biased initial population,
    /// paper §III-D1).
    pub fn random_genome(&self, rng: &mut Rng, keep_prob: f64) -> BitVec {
        let bools: Vec<bool> = (0..self.len()).map(|_| rng.chance(keep_prob)).collect();
        BitVec::from_bools(&bools)
    }

    /// Expand a genome into the dense [`MaskSet`] consumed by the
    /// evaluators. Bits of zero weights stay fully masked-in (all-ones) —
    /// they contribute nothing either way.
    pub fn to_masks(&self, genome: &BitVec) -> MaskSet {
        assert_eq!(genome.len(), self.len(), "genome length mismatch");
        let t = &self.topo;
        let mut m = MaskSet {
            m1: vec![(1u32 << self.in_bits1) - 1; t.n_hidden * t.n_in],
            mb1: vec![true; t.n_hidden],
            m2: vec![(1u32 << self.in_bits2) - 1; t.n_out * t.n_hidden],
            mb2: vec![true; t.n_out],
        };
        for (i, sb) in self.bits.iter().enumerate() {
            if genome.get(i) {
                continue; // kept -> mask bit stays 1
            }
            let n = sb.neuron as usize;
            if sb.input == BIAS {
                if sb.layer == 0 {
                    m.mb1[n] = false;
                } else {
                    m.mb2[n] = false;
                }
            } else {
                let j = sb.input as usize;
                if sb.layer == 0 {
                    m.m1[n * t.n_in + j] &= !(1u32 << sb.bit);
                } else {
                    m.m2[n * t.n_hidden + j] &= !(1u32 << sb.bit);
                }
            }
        }
        m
    }

    /// Inverse of [`to_masks`] (used by tests and by importing external
    /// mask configurations).
    pub fn from_masks(&self, masks: &MaskSet) -> BitVec {
        let t = &self.topo;
        let mut g = BitVec::zeros(self.len());
        for (i, sb) in self.bits.iter().enumerate() {
            let n = sb.neuron as usize;
            let kept = if sb.input == BIAS {
                if sb.layer == 0 { masks.mb1[n] } else { masks.mb2[n] }
            } else {
                let j = sb.input as usize;
                let m = if sb.layer == 0 {
                    masks.m1[n * t.n_in + j]
                } else {
                    masks.m2[n * t.n_hidden + j]
                };
                (m >> sb.bit) & 1 == 1
            };
            if kept {
                g.set(i, true);
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;
    use crate::datasets;
    use crate::model::float_mlp::TrainOpts;
    use crate::model::FloatMlp;
    use crate::util::prop;

    fn tiny_qmlp() -> (QuantMlp, crate::datasets::QuantDataset) {
        let cfg = builtin::tiny();
        let (split, qtrain, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 25, ..Default::default() });
        (QuantMlp::from_float(&mlp, &qtrain), qtrain)
    }

    #[test]
    fn genome_length_counts_nonzero_summands() {
        let (qmlp, _) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        let mut expect = 0;
        for layer in [&qmlp.l1, &qmlp.l2] {
            for n in 0..layer.n_out {
                for j in 0..layer.n_in {
                    if layer.weight(n, j).sign != 0 {
                        expect += layer.in_bits as usize;
                    }
                }
                if layer.bias[n].is_nonzero() {
                    expect += 1;
                }
            }
        }
        assert_eq!(map.len(), expect);
        assert!(!map.is_empty());
    }

    #[test]
    fn exact_genome_is_exact_masks() {
        let (qmlp, _) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        let masks = map.to_masks(&map.exact_genome());
        // Exact genome must behave identically to no masks at all.
        let exact = MaskSet::exact(&qmlp.topo);
        // Zero-weight mask entries are all-ones in both.
        assert_eq!(masks, exact);
    }

    #[test]
    fn prop_masks_roundtrip() {
        let (qmlp, _) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        prop::check("genome->masks->genome roundtrip", |rng, _| {
            let g = map.random_genome(rng, 0.7);
            let back = map.from_masks(&map.to_masks(&g));
            if back != g {
                return Err("roundtrip mismatch".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn removed_bit_changes_one_mask_bit() {
        let (qmlp, _) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        let mut g = map.exact_genome();
        // Remove the very first summand bit.
        g.set(0, false);
        let masks = map.to_masks(&g);
        let exact = map.to_masks(&map.exact_genome());
        let diff: u32 = masks
            .m1
            .iter()
            .zip(&exact.m1)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        let sb = map.bits[0];
        if sb.input != BIAS && sb.layer == 0 {
            assert_eq!(diff, 1);
        }
    }

    #[test]
    fn column_is_shift_plus_bit() {
        let (qmlp, _) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        for sb in &map.bits {
            if sb.input == BIAS {
                continue;
            }
            let layer = if sb.layer == 0 { &qmlp.l1 } else { &qmlp.l2 };
            let w = layer.weight(sb.neuron as usize, sb.input as usize);
            assert_eq!(sb.column, w.shift + sb.bit);
            assert_eq!(sb.pos_tree, w.sign > 0);
        }
    }

    #[test]
    fn masked_eval_consistent_with_genome_semantics() {
        // Clearing all genome bits of one neuron's inputs zeroes that
        // neuron's contribution.
        let (qmlp, qtrain) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        let mut g = map.exact_genome();
        for (i, sb) in map.bits.iter().enumerate() {
            if sb.layer == 0 && sb.neuron == 0 {
                g.set(i, false);
            }
        }
        let masks = map.to_masks(&g);
        let (h, _) = qmlp.forward_masked(&qtrain.x[0], Some(&masks));
        assert_eq!(h[0], 0, "fully-masked neuron must output 0");
    }
}
