//! Float single-hidden-layer MLP (Relu hidden, linear output) plus a
//! self-contained Adam trainer with optional straight-through power-of-2
//! QAT — the native counterpart of the Layer-2 JAX `train_step`.
//!
//! The native trainer exists for three reasons: (1) it is a substrate the
//! paper depends on (scikit-learn training); (2) it lets the full
//! pipeline run before `make artifacts`; (3) it cross-checks the
//! PJRT-driven trainer in integration tests.

use crate::config::Topology;
use crate::datasets::Dataset;
use crate::fixedpoint::{dequantize_po2, layer_a_exp, quantize_po2};
use crate::util::Rng;

/// Dense float MLP: `h = relu(W1 x + b1)`, `z = W2 h + b2`.
#[derive(Clone, Debug)]
pub struct FloatMlp {
    pub topo: Topology,
    /// `(n_hidden, n_in)` row-major.
    pub w1: Vec<Vec<f64>>,
    pub b1: Vec<f64>,
    /// `(n_out, n_hidden)` row-major.
    pub w2: Vec<Vec<f64>>,
    pub b2: Vec<f64>,
    /// QRelu clipping range used by QAT forward passes (calibrated to
    /// the maximum hidden pre-activation at QAT start; 8-bit grid on
    /// `[0, act_max)`).
    pub act_max: f64,
}

/// Training options.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub epochs: usize,
    pub batch_size: usize,
    pub lr: f64,
    pub seed: u64,
    /// If true, apply straight-through po2 quantization to the weights
    /// and 8-bit QRelu to the hidden activations in the forward pass
    /// (quantization-aware training, paper §III-B).
    pub qat_po2: bool,
    /// L2 weight decay.
    pub weight_decay: f64,
    /// Apply sqrt-inverse-frequency class balancing to the loss (the
    /// paper's datasets are heavily imbalanced). Disable for QAT
    /// fine-tuning, where re-balancing fights the already-learned
    /// decision boundaries.
    pub class_balance: bool,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            epochs: 60,
            batch_size: 64,
            lr: 0.02,
            seed: 7,
            qat_po2: false,
            weight_decay: 1e-4,
            class_balance: true,
        }
    }
}

/// Adam state for one parameter tensor.
#[derive(Clone, Debug, Default)]
struct Adam {
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
}

impl Adam {
    fn new(n: usize) -> Self {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }
    fn step(&mut self, params: &mut [f64], grads: &[f64], lr: f64) {
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t as i32);
        let bc2 = 1.0 - B2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * grads[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * grads[i] * grads[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + EPS);
        }
    }
}

impl FloatMlp {
    /// He-initialized random MLP.
    pub fn init(topo: Topology, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x4D4C_5000);
        let init_mat = |rng: &mut Rng, rows: usize, cols: usize| -> Vec<Vec<f64>> {
            let scale = (2.0 / cols as f64).sqrt();
            (0..rows)
                .map(|_| (0..cols).map(|_| rng.normal() * scale).collect())
                .collect()
        };
        FloatMlp {
            topo,
            w1: init_mat(&mut rng, topo.n_hidden, topo.n_in),
            b1: vec![0.0; topo.n_hidden],
            w2: init_mat(&mut rng, topo.n_out, topo.n_hidden),
            b2: vec![0.0; topo.n_out],
            act_max: 8.0,
        }
    }

    /// Calibrate `act_max` to the maximum (quantized-weight) hidden
    /// pre-activation over a dataset — run before QAT fine-tuning so
    /// the float QRelu grid matches the integer truncation the hardware
    /// will use.
    pub fn calibrate_act_max(&mut self, ds: &Dataset) {
        let (w1, _) = self.eff_weights(true);
        let mut maxh = 1e-6f64;
        for x in &ds.x {
            for n in 0..self.topo.n_hidden {
                let mut acc = self.b1[n];
                for (j, &xj) in x.iter().enumerate() {
                    acc += w1[n][j] * xj;
                }
                maxh = maxh.max(acc);
            }
        }
        // Round up to a power of two (the integer QRelu truncation is a
        // power-of-2 shift).
        self.act_max = (2f64).powi(maxh.log2().ceil() as i32);
    }

    /// Effective weights as seen by the forward pass (po2-quantized under
    /// QAT, raw otherwise).
    fn eff_weights(&self, qat: bool) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
        if !qat {
            return (self.w1.clone(), self.w2.clone());
        }
        let q = |w: &Vec<Vec<f64>>| -> Vec<Vec<f64>> {
            let flat: Vec<f64> = w.iter().flatten().copied().collect();
            let a = layer_a_exp(&flat);
            w.iter()
                .map(|row| row.iter().map(|&v| dequantize_po2(quantize_po2(v, a), a)).collect())
                .collect()
        };
        (q(&self.w1), q(&self.w2))
    }

    /// Forward pass for one sample; returns (hidden, logits).
    /// `qat` applies po2 weight quantization + 8-bit QRelu clipping.
    pub fn forward(&self, x: &[f64], qat: bool) -> (Vec<f64>, Vec<f64>) {
        let (w1, w2) = self.eff_weights(qat);
        self.forward_with(&w1, &w2, x, qat)
    }

    fn forward_with(
        &self,
        w1: &[Vec<f64>],
        w2: &[Vec<f64>],
        x: &[f64],
        qat: bool,
    ) -> (Vec<f64>, Vec<f64>) {
        let mut h = vec![0.0; self.topo.n_hidden];
        for (n, hn) in h.iter_mut().enumerate() {
            let mut acc = self.b1[n];
            for (j, &xj) in x.iter().enumerate() {
                acc += w1[n][j] * xj;
            }
            let mut a = acc.max(0.0);
            if qat {
                // QRelu(8): 8-bit grid on the calibrated [0, act_max)
                // range (matches the L2 JAX model and the integer
                // truncation shift of the hardware).
                let step = self.act_max / 256.0;
                a = ((a / step).floor() * step).min(self.act_max - step);
            }
            *hn = a;
        }
        let mut z = vec![0.0; self.topo.n_out];
        for (m, zm) in z.iter_mut().enumerate() {
            let mut acc = self.b2[m];
            for (n, &hn) in h.iter().enumerate() {
                acc += w2[m][n] * hn;
            }
            *zm = acc;
        }
        (h, z)
    }

    /// Predicted class (argmax of logits, ties to the lowest index —
    /// matching the hardware comparator-tree convention).
    pub fn predict(&self, x: &[f64], qat: bool) -> usize {
        let (_, z) = self.forward(x, qat);
        argmax_f(&z)
    }

    /// Classification accuracy on a dataset.
    pub fn accuracy(&self, ds: &Dataset, qat: bool) -> f64 {
        if ds.y.is_empty() {
            return 0.0;
        }
        let correct = ds
            .x
            .iter()
            .zip(&ds.y)
            .filter(|(x, &y)| self.predict(x, qat) == y)
            .count();
        correct as f64 / ds.y.len() as f64
    }

    /// Train with Adam on softmax cross-entropy. Gradients flow through
    /// the straight-through estimator when `opts.qat_po2` is set: the
    /// forward uses quantized weights/activations, the backward treats
    /// the quantizers as identity.
    pub fn train(&mut self, ds: &Dataset, opts: &TrainOpts) {
        let topo = self.topo;
        let (ni, nh, no) = (topo.n_in, topo.n_hidden, topo.n_out);
        let mut rng = Rng::new(opts.seed ^ 0x5452_4149);
        if opts.qat_po2 {
            self.calibrate_act_max(ds);
        }
        let mut adam_w1 = Adam::new(nh * ni);
        let mut adam_b1 = Adam::new(nh);
        let mut adam_w2 = Adam::new(no * nh);
        let mut adam_b2 = Adam::new(no);

        // Inverse-frequency class weighting: the paper's datasets are
        // heavily imbalanced (e.g. wines, arrhythmia) and sklearn's MLP
        // with balanced sampling is approximated this way.
        let mut class_counts = vec![0usize; no];
        for &y in &ds.y {
            class_counts[y] += 1;
        }
        let n_present = class_counts.iter().filter(|&&c| c > 0).count().max(1);
        let class_w: Vec<f64> = class_counts
            .iter()
            .map(|&c| {
                if c == 0 {
                    0.0
                } else if opts.class_balance {
                    // Soft balancing: sqrt of inverse frequency.
                    (ds.y.len() as f64 / (n_present as f64 * c as f64)).sqrt()
                } else {
                    1.0
                }
            })
            .collect();

        let n = ds.y.len();
        let mut order: Vec<usize> = (0..n).collect();
        for _epoch in 0..opts.epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(opts.batch_size) {
                let (w1e, w2e) = self.eff_weights(opts.qat_po2);
                let mut gw1 = vec![0.0; nh * ni];
                let mut gb1 = vec![0.0; nh];
                let mut gw2 = vec![0.0; no * nh];
                let mut gb2 = vec![0.0; no];
                let mut total_w = 0.0;
                for &i in chunk {
                    let x = &ds.x[i];
                    let y = ds.y[i];
                    let cw = class_w[y];
                    total_w += cw;
                    let (h, z) = self.forward_with(&w1e, &w2e, x, opts.qat_po2);
                    // Softmax CE gradient on logits.
                    let maxz = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    let exps: Vec<f64> = z.iter().map(|&v| (v - maxz).exp()).collect();
                    let sum: f64 = exps.iter().sum();
                    let mut dz: Vec<f64> = exps.iter().map(|&e| e / sum).collect();
                    dz[y] -= 1.0;
                    for d in dz.iter_mut() {
                        *d *= cw;
                    }
                    // Output layer grads.
                    for m in 0..no {
                        gb2[m] += dz[m];
                        for nn in 0..nh {
                            gw2[m * nh + nn] += dz[m] * h[nn];
                        }
                    }
                    // Backprop into hidden (STE: through quantized relu as
                    // identity on the active region).
                    for nn in 0..nh {
                        if h[nn] <= 0.0 {
                            continue;
                        }
                        let mut dh = 0.0;
                        for m in 0..no {
                            dh += dz[m] * w2e[m][nn];
                        }
                        gb1[nn] += dh;
                        for (j, &xj) in x.iter().enumerate() {
                            gw1[nn * ni + j] += dh * xj;
                        }
                    }
                }
                let scale = 1.0 / total_w.max(1e-9);
                for g in gw1.iter_mut().chain(&mut gb1).chain(&mut gw2).chain(&mut gb2) {
                    *g *= scale;
                }
                // Weight decay on the raw (latent) weights.
                for (idx, g) in gw1.iter_mut().enumerate() {
                    *g += opts.weight_decay * self.w1[idx / ni][idx % ni];
                }
                for (idx, g) in gw2.iter_mut().enumerate() {
                    *g += opts.weight_decay * self.w2[idx / nh][idx % nh];
                }
                // Adam updates on flattened views.
                let mut w1_flat: Vec<f64> = self.w1.iter().flatten().copied().collect();
                adam_w1.step(&mut w1_flat, &gw1, opts.lr);
                for (idx, v) in w1_flat.into_iter().enumerate() {
                    self.w1[idx / ni][idx % ni] = v;
                }
                let mut w2_flat: Vec<f64> = self.w2.iter().flatten().copied().collect();
                adam_w2.step(&mut w2_flat, &gw2, opts.lr);
                for (idx, v) in w2_flat.into_iter().enumerate() {
                    self.w2[idx / nh][idx % nh] = v;
                }
                adam_b1.step(&mut self.b1, &gb1, opts.lr);
                adam_b2.step(&mut self.b2, &gb2, opts.lr);
            }
        }
    }
}

/// Argmax with ties resolved to the lowest index (hardware convention:
/// the comparator tree keeps the earlier neuron on equality).
pub fn argmax_f(z: &[f64]) -> usize {
    let mut best = 0;
    for (i, &v) in z.iter().enumerate().skip(1) {
        if v > z[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;
    use crate::datasets;

    #[test]
    fn trains_tiny_dataset_above_chance() {
        let cfg = builtin::tiny();
        let (split, _, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        let before = mlp.accuracy(&split.test, false);
        mlp.train(
            &split.train,
            &TrainOpts { epochs: 40, ..Default::default() },
        );
        let after = mlp.accuracy(&split.test, false);
        assert!(after > 0.85, "before={before} after={after}");
    }

    #[test]
    fn qat_training_keeps_accuracy_close() {
        let cfg = builtin::tiny();
        let (split, _, _) = datasets::load(&cfg.dataset);
        let mut float = FloatMlp::init(cfg.topology, 1);
        float.train(&split.train, &TrainOpts { epochs: 40, ..Default::default() });
        let base = float.accuracy(&split.test, false);
        let mut qat = float.clone();
        qat.train(
            &split.train,
            &TrainOpts { epochs: 25, qat_po2: true, lr: 0.01, ..Default::default() },
        );
        let qacc = qat.accuracy(&split.test, true);
        assert!(
            qacc > base - 0.10,
            "QAT accuracy collapsed: base={base} qat={qacc}"
        );
    }

    #[test]
    fn argmax_ties_to_lowest() {
        assert_eq!(argmax_f(&[1.0, 1.0, 0.5]), 0);
        assert_eq!(argmax_f(&[0.1, 0.9, 0.9]), 1);
        assert_eq!(argmax_f(&[2.0]), 0);
    }

    #[test]
    fn forward_shapes() {
        let mlp = FloatMlp::init(crate::config::Topology::new(4, 3, 2), 9);
        let (h, z) = mlp.forward(&[0.1, 0.2, 0.3, 0.4], false);
        assert_eq!(h.len(), 3);
        assert_eq!(z.len(), 2);
    }

    #[test]
    fn qat_forward_hits_po2_grid() {
        let mut mlp = FloatMlp::init(crate::config::Topology::new(3, 2, 2), 5);
        mlp.w1[0][0] = 0.3; // quantizes to 0.25
        let (w1, _) = mlp.eff_weights(true);
        let v = w1[0][0];
        // Must be a power of two (or zero).
        assert!(v > 0.0 && (v.log2() - v.log2().round()).abs() < 1e-12, "v={v}");
    }
}
