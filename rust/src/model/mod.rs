//! MLP models: the float model produced by training and the quantized
//! integer model that every hardware stage (GA, netlist, PJRT evaluator)
//! consumes. The integer model is the *golden reference* of the
//! equivalence chain (DESIGN.md §2).

pub mod float_mlp;
pub mod quantized;

pub use float_mlp::FloatMlp;
pub use quantized::{BiasQ, MaskSet, QuantLayer, QuantMlp};
