//! The quantized integer MLP — the exact arithmetic the bespoke printed
//! circuit implements, and the golden reference every other evaluation
//! path (Pallas kernel, HLO-via-PJRT, gate-level netlist) must match
//! bit-for-bit.
//!
//! Arithmetic per neuron (paper §III-A/B/C):
//! * power-of-2 weights → each product is `input << shift` (pure wiring);
//! * positive and negative weights accumulate in two separate unsigned
//!   adder trees; the two sums are subtracted once at the end;
//! * hidden layer applies QRelu(8): arithmetic right shift by the static
//!   layer truncation `t`, clip to `[0, 255]`;
//! * the output layer's pre-activations go to (approximate) Argmax.
//!
//! The accumulation approximation (paper §III-D) masks individual summand
//! bits: `summand = (input & mask) << shift`. Masking before the shift is
//! equivalent to masking the aligned summand bit in the adder tree.

use crate::config::Topology;
use crate::datasets::QuantDataset;
use crate::fixedpoint::{bits_for, layer_a_exp, quantize_po2, QWeight, ACT_BITS, INPUT_BITS, MAX_SHIFT};
use crate::model::FloatMlp;

/// A power-of-2 quantized bias in the layer's column-scale units:
/// `sign * 2^shift` (`sign == 0` → no bias summand).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BiasQ {
    pub sign: i8,
    pub shift: u8,
}

impl BiasQ {
    pub const ZERO: BiasQ = BiasQ { sign: 0, shift: 0 };
    #[inline]
    pub fn is_nonzero(&self) -> bool {
        self.sign != 0
    }
    #[inline]
    pub fn int_value(&self) -> i64 {
        self.sign as i64 * (1i64 << self.shift)
    }
}

/// One quantized layer: po2 weight matrix + po2 biases.
#[derive(Clone, Debug)]
pub struct QuantLayer {
    pub n_in: usize,
    pub n_out: usize,
    /// Flat `(n_out, n_in)` row-major.
    pub w: Vec<QWeight>,
    pub bias: Vec<BiasQ>,
    /// Layer weight-scale exponent (`2^a_exp >= max|w_float|`).
    pub a_exp: i32,
    /// Bits of the unsigned integer inputs of this layer.
    pub in_bits: u32,
}

impl QuantLayer {
    #[inline]
    pub fn weight(&self, n: usize, j: usize) -> QWeight {
        self.w[n * self.n_in + j]
    }

    /// Worst-case (unmasked) positive/negative tree sums for neuron `n` —
    /// determines accumulator and comparator widths in the netlist.
    pub fn tree_max(&self, n: usize) -> (u64, u64) {
        let amax = (1u64 << self.in_bits) - 1;
        let mut pos = 0u64;
        let mut neg = 0u64;
        for j in 0..self.n_in {
            let w = self.weight(n, j);
            match w.sign {
                1 => pos += amax << w.shift,
                -1 => neg += amax << w.shift,
                _ => {}
            }
        }
        let b = self.bias[n];
        match b.sign {
            1 => pos += 1u64 << b.shift,
            -1 => neg += 1u64 << b.shift,
            _ => {}
        }
        (pos, neg)
    }

    /// Bit width of the signed pre-activation of neuron `n` (two's
    /// complement width able to hold `[-neg_max, pos_max]`).
    pub fn preact_width(&self, n: usize) -> u32 {
        let (pos, neg) = self.tree_max(n);
        bits_for(pos.max(neg)) + 1
    }
}

/// Per-summand-bit masks for the accumulation approximation. `1` bits
/// keep the summand bit, `0` bits remove it (constant zero in hardware).
/// Flat layouts mirror [`QuantLayer::w`].
#[derive(Clone, Debug, PartialEq)]
pub struct MaskSet {
    /// Hidden-layer input masks `(n_hidden, n_in)`, `in_bits` wide each.
    pub m1: Vec<u32>,
    /// Hidden-layer bias keep flags.
    pub mb1: Vec<bool>,
    /// Output-layer input masks `(n_out, n_hidden)`, `ACT_BITS` wide.
    pub m2: Vec<u32>,
    /// Output-layer bias keep flags.
    pub mb2: Vec<bool>,
}

impl MaskSet {
    /// The exact (nothing removed) mask set.
    pub fn exact(topo: &Topology) -> MaskSet {
        MaskSet {
            m1: vec![(1u32 << INPUT_BITS) - 1; topo.n_hidden * topo.n_in],
            mb1: vec![true; topo.n_hidden],
            m2: vec![(1u32 << ACT_BITS) - 1; topo.n_out * topo.n_hidden],
            mb2: vec![true; topo.n_out],
        }
    }
}

/// The full quantized MLP.
#[derive(Clone, Debug)]
pub struct QuantMlp {
    pub topo: Topology,
    pub l1: QuantLayer,
    pub l2: QuantLayer,
    /// QRelu truncation: hidden activation = `clamp(z >> act_shift, 0, 255)`.
    pub act_shift: u32,
}

impl QuantMlp {
    /// Quantize a trained float MLP and calibrate the QRelu truncation on
    /// the (quantized) train set.
    pub fn from_float(float: &FloatMlp, calib: &QuantDataset) -> QuantMlp {
        let topo = float.topo;
        let flat1: Vec<f64> = float.w1.iter().flatten().copied().collect();
        let flat2: Vec<f64> = float.w2.iter().flatten().copied().collect();
        let a1 = layer_a_exp(&flat1);
        let a2 = layer_a_exp(&flat2);

        // Layer 1: inputs are 4-bit with real scale 2^-INPUT_BITS.
        let col1_log2 = -(INPUT_BITS as i32) + a1 - MAX_SHIFT as i32;
        let w1: Vec<QWeight> = flat1.iter().map(|&w| quantize_po2(w, a1)).collect();
        let bias1: Vec<BiasQ> =
            float.b1.iter().map(|&b| quantize_bias(b, col1_log2)).collect();
        let mut l1 = QuantLayer {
            n_in: topo.n_in,
            n_out: topo.n_hidden,
            w: w1,
            bias: bias1,
            a_exp: a1,
            in_bits: INPUT_BITS,
        };

        // Calibrate QRelu truncation: smallest t such that the maximum
        // positive pre-activation over the calibration set fits ACT_BITS.
        let mut max_z: i64 = 0;
        for row in &calib.x {
            for n in 0..topo.n_hidden {
                let z = neuron_preact(&l1, n, row, None, None);
                max_z = max_z.max(z);
            }
        }
        let act_shift = (bits_for(max_z.max(0) as u64)).saturating_sub(ACT_BITS);

        // Layer 2: inputs are the 8-bit hidden activations with real
        // scale col1 * 2^act_shift.
        let col2_in_log2 = col1_log2 + act_shift as i32;
        let col2_log2 = col2_in_log2 + a2 - MAX_SHIFT as i32;
        let w2: Vec<QWeight> = flat2.iter().map(|&w| quantize_po2(w, a2)).collect();
        let bias2: Vec<BiasQ> =
            float.b2.iter().map(|&b| quantize_bias(b, col2_log2)).collect();
        let l2 = QuantLayer {
            n_in: topo.n_hidden,
            n_out: topo.n_out,
            w: w2,
            bias: bias2,
            a_exp: a2,
            in_bits: ACT_BITS,
        };

        // Dead-bias cleanup for layer 1: a bias whose entire magnitude is
        // truncated away by QRelu contributes nothing but area.
        for b in l1.bias.iter_mut() {
            if b.is_nonzero() && (b.shift as u32) < act_shift.saturating_sub(4) {
                *b = BiasQ::ZERO;
            }
        }

        QuantMlp { topo, l1, l2, act_shift }
    }

    /// Exact integer forward: returns (hidden activations, output-layer
    /// pre-activations).
    pub fn forward(&self, x: &[u32]) -> (Vec<u32>, Vec<i64>) {
        self.forward_masked(x, None)
    }

    /// Masked integer forward (the accumulation approximation). `None`
    /// masks mean exact.
    pub fn forward_masked(&self, x: &[u32], masks: Option<&MaskSet>) -> (Vec<u32>, Vec<i64>) {
        debug_assert_eq!(x.len(), self.topo.n_in);
        let mut h = vec![0u32; self.topo.n_hidden];
        for (n, hn) in h.iter_mut().enumerate() {
            let z = neuron_preact(
                &self.l1,
                n,
                x,
                masks.map(|m| &m.m1[..]),
                masks.map(|m| &m.mb1[..]),
            );
            *hn = qrelu(z, self.act_shift);
        }
        let mut z2 = vec![0i64; self.topo.n_out];
        for (m_idx, zm) in z2.iter_mut().enumerate() {
            *zm = neuron_preact(
                &self.l2,
                m_idx,
                &h,
                masks.map(|m| &m.m2[..]),
                masks.map(|m| &m.mb2[..]),
            );
        }
        (h, z2)
    }

    /// Predicted class of one sample.
    pub fn predict(&self, x: &[u32], masks: Option<&MaskSet>) -> usize {
        let (_, z) = self.forward_masked(x, masks);
        argmax_i(&z)
    }

    /// Accuracy over a quantized dataset.
    pub fn accuracy(&self, ds: &QuantDataset, masks: Option<&MaskSet>) -> f64 {
        if ds.y.is_empty() {
            return 0.0;
        }
        self.count_correct(ds, masks) as f64 / ds.y.len() as f64
    }

    /// Allocation-free correct-prediction count — the native GA
    /// evaluator's hot loop (EXPERIMENTS.md §Perf): hidden/output
    /// buffers are reused across samples and the argmax is computed
    /// in-line instead of materializing the logits vector per call.
    pub fn count_correct(&self, ds: &QuantDataset, masks: Option<&MaskSet>) -> usize {
        let mut h = vec![0u32; self.topo.n_hidden];
        let m1 = masks.map(|m| &m.m1[..]);
        let mb1 = masks.map(|m| &m.mb1[..]);
        let m2 = masks.map(|m| &m.m2[..]);
        let mb2 = masks.map(|m| &m.mb2[..]);
        let mut correct = 0usize;
        for (x, &y) in ds.x.iter().zip(&ds.y) {
            for n in 0..self.topo.n_hidden {
                h[n] = qrelu(neuron_preact(&self.l1, n, x, m1, mb1), self.act_shift);
            }
            let mut best = 0usize;
            let mut best_z = i64::MIN;
            for m_idx in 0..self.topo.n_out {
                let z = neuron_preact(&self.l2, m_idx, &h, m2, mb2);
                if z > best_z {
                    best_z = z;
                    best = m_idx;
                }
            }
            correct += usize::from(best == y);
        }
        correct
    }

    /// Output-layer pre-activations for a whole dataset (used by the
    /// approximate-Argmax search, which needs the neuron outputs).
    pub fn output_preacts(&self, ds: &QuantDataset, masks: Option<&MaskSet>) -> Vec<Vec<i64>> {
        ds.x.iter().map(|x| self.forward_masked(x, masks).1).collect()
    }

    /// Maximum output-layer pre-activation width (bits, signed) across
    /// neurons — the exact-Argmax comparator width.
    pub fn output_width(&self) -> u32 {
        (0..self.topo.n_out).map(|n| self.l2.preact_width(n)).max().unwrap_or(2)
    }
}

/// Pre-activation of one neuron with optional summand-bit masks:
/// two unsigned accumulators (positive / negative trees) subtracted once.
#[inline]
pub fn neuron_preact(
    layer: &QuantLayer,
    n: usize,
    x: &[u32],
    masks: Option<&[u32]>,
    bias_keep: Option<&[bool]>,
) -> i64 {
    let row = n * layer.n_in;
    let mut pos: i64 = 0;
    let mut neg: i64 = 0;
    for j in 0..layer.n_in {
        let w = layer.w[row + j];
        if w.sign == 0 {
            continue;
        }
        let mut a = x[j] as i64;
        if let Some(m) = masks {
            a &= m[row + j] as i64;
        }
        let s = a << w.shift;
        if w.sign > 0 {
            pos += s;
        } else {
            neg += s;
        }
    }
    let b = layer.bias[n];
    if b.is_nonzero() && bias_keep.map(|k| k[n]).unwrap_or(true) {
        if b.sign > 0 {
            pos += 1i64 << b.shift;
        } else {
            neg += 1i64 << b.shift;
        }
    }
    pos - neg
}

/// QRelu(8): truncate `t` LSBs, clip to `[0, 255]`.
#[inline]
pub fn qrelu(z: i64, t: u32) -> u32 {
    if z <= 0 {
        return 0;
    }
    ((z >> t) as u64).min((1u64 << ACT_BITS) - 1) as u32
}

/// Integer argmax, ties to the lowest index (hardware convention).
pub fn argmax_i(z: &[i64]) -> usize {
    let mut best = 0;
    for (i, &v) in z.iter().enumerate().skip(1) {
        if v > z[best] {
            best = i;
        }
    }
    best
}

fn quantize_bias(b: f64, col_log2: i32) -> BiasQ {
    if b == 0.0 || !b.is_finite() {
        return BiasQ::ZERO;
    }
    // Integer magnitude in column-scale units, then round to po2.
    let mag = b.abs() / (2f64).powi(col_log2);
    if mag < 0.5 {
        return BiasQ::ZERO;
    }
    let shift = mag.log2().round().clamp(0.0, 30.0) as u8;
    BiasQ { sign: if b > 0.0 { 1 } else { -1 }, shift }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;
    use crate::datasets;
    use crate::model::float_mlp::TrainOpts;
    use crate::util::prop;

    fn trained_tiny() -> (QuantMlp, QuantDataset, QuantDataset) {
        let cfg = builtin::tiny();
        let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 40, ..Default::default() });
        mlp.train(
            &split.train,
            &TrainOpts { epochs: 20, qat_po2: true, lr: 0.008, ..Default::default() },
        );
        (QuantMlp::from_float(&mlp, &qtrain), qtrain, qtest)
    }

    #[test]
    fn quantized_model_keeps_accuracy() {
        let (qmlp, _, qtest) = trained_tiny();
        let acc = qmlp.accuracy(&qtest, None);
        assert!(acc > 0.75, "quantized accuracy {acc}");
    }

    #[test]
    fn exact_masks_equal_no_masks() {
        let (qmlp, qtrain, _) = trained_tiny();
        let exact = MaskSet::exact(&qmlp.topo);
        for row in qtrain.x.iter().take(50) {
            let a = qmlp.forward_masked(row, None);
            let b = qmlp.forward_masked(row, Some(&exact));
            assert_eq!(a, b);
        }
    }

    #[test]
    fn all_zero_masks_zero_everything() {
        let (qmlp, qtrain, _) = trained_tiny();
        let zero = MaskSet {
            m1: vec![0; qmlp.topo.n_hidden * qmlp.topo.n_in],
            mb1: vec![false; qmlp.topo.n_hidden],
            m2: vec![0; qmlp.topo.n_out * qmlp.topo.n_hidden],
            mb2: vec![false; qmlp.topo.n_out],
        };
        let (h, z) = qmlp.forward_masked(&qtrain.x[0], Some(&zero));
        assert!(h.iter().all(|&v| v == 0));
        assert!(z.iter().all(|&v| v == 0));
    }

    #[test]
    fn qrelu_behaviour() {
        assert_eq!(qrelu(-5, 0), 0);
        assert_eq!(qrelu(0, 0), 0);
        assert_eq!(qrelu(255, 0), 255);
        assert_eq!(qrelu(256, 0), 255); // clip
        assert_eq!(qrelu(256, 1), 128); // truncate
        assert_eq!(qrelu(511, 1), 255);
        assert_eq!(qrelu(1 << 20, 4), 255);
    }

    #[test]
    fn argmax_ties_low() {
        assert_eq!(argmax_i(&[5, 5, 3]), 0);
        assert_eq!(argmax_i(&[1, 7, 7]), 1);
        assert_eq!(argmax_i(&[-3, -1, -2]), 1);
    }

    #[test]
    fn tree_max_bounds_preacts() {
        // Property: |pre-activation| never exceeds the analytic tree max.
        let (qmlp, qtrain, _) = trained_tiny();
        for row in qtrain.x.iter().take(100) {
            for n in 0..qmlp.topo.n_hidden {
                let z = neuron_preact(&qmlp.l1, n, row, None, None);
                let (pos, neg) = qmlp.l1.tree_max(n);
                assert!(z <= pos as i64 && z >= -(neg as i64));
            }
        }
    }

    #[test]
    fn prop_masking_only_lowers_tree_sums() {
        // Removing summand bits can only reduce each unsigned tree sum —
        // the monotonicity the area/accuracy trade-off builds on.
        let (qmlp, qtrain, _) = trained_tiny();
        prop::check("masking monotone per tree", |rng, _| {
            let topo = qmlp.topo;
            let masks = MaskSet {
                m1: (0..topo.n_hidden * topo.n_in)
                    .map(|_| rng.below(16) as u32)
                    .collect(),
                mb1: (0..topo.n_hidden).map(|_| rng.chance(0.5)).collect(),
                m2: (0..topo.n_out * topo.n_hidden)
                    .map(|_| rng.below(256) as u32)
                    .collect(),
                mb2: (0..topo.n_out).map(|_| rng.chance(0.5)).collect(),
            };
            let x = &qtrain.x[rng.below(qtrain.x.len())];
            for n in 0..topo.n_hidden {
                // Compare pos/neg trees separately via two synthetic
                // evaluations: masked vs exact with the bias stripped.
                let exact = neuron_preact(&qmlp.l1, n, x, None, None);
                let masked =
                    neuron_preact(&qmlp.l1, n, x, Some(&masks.m1), Some(&masks.mb1));
                // The *difference* pos-neg may move either way; what must
                // hold is the width bound:
                let (pos, neg) = qmlp.l1.tree_max(n);
                if masked > pos as i64 || masked < -(neg as i64) {
                    return Err(format!("masked preact out of range: {masked} vs {exact}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hidden_activations_are_8bit() {
        let (qmlp, qtrain, _) = trained_tiny();
        for row in &qtrain.x {
            let (h, _) = qmlp.forward(row);
            assert!(h.iter().all(|&v| v <= 255));
        }
    }

    #[test]
    fn bias_quantization() {
        assert_eq!(quantize_bias(0.0, -4), BiasQ::ZERO);
        // b=0.5 with column scale 2^-4 -> integer 8 -> shift 3.
        let b = quantize_bias(0.5, -4);
        assert_eq!((b.sign, b.shift), (1, 3));
        let b = quantize_bias(-0.5, -4);
        assert_eq!((b.sign, b.shift), (-1, 3));
        // Sub-half magnitudes flush to zero.
        assert_eq!(quantize_bias(0.02, -4), BiasQ::ZERO);
    }
}
