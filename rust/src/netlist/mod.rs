//! Gate-level netlist IR and combinational circuit builders.
//!
//! This is the "RTL + synthesis front-end" substrate that replaces the
//! commercial EDA flow of the paper (Synopsys DC + VCS): the bespoke MLP
//! circuit generators emit gates directly, `crate::synth` optimizes them
//! (constant propagation, structural hashing, dead-gate elimination —
//! the mechanisms the paper's approximation explicitly leans on), the
//! EGFET library (`crate::egfet`) provides area/power/delay, and
//! `crate::sim` provides functional simulation for equivalence checking.
//!
//! Invariant: gate operands always refer to earlier node ids, so the
//! gate list is topologically ordered by construction — simulation and
//! timing are single forward passes. The same invariant is what lets the
//! bit-parallel wave engine (`crate::sim::wave`, DESIGN.md §2) evaluate
//! 64 vectors per pass with one `u64` word per node.

pub mod build;
pub mod mlp;

/// Node id in a netlist.
pub type NodeId = u32;

/// A combinational gate (2-input cells + inverter + mux, matching the
/// printed EGFET standard-cell library).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Gate {
    /// Primary input (index into the input vector).
    Input(u32),
    /// Constant 0/1 (hardwired — free after synthesis).
    Const(bool),
    /// Boolean literal site of a [`Template`]: bound to a concrete
    /// `Const` at instantiation time (one site per mask-controlled
    /// summand bit). Never appears in an instantiated/synthesized
    /// netlist — the simulators reject it.
    Param(u32),
    Not(NodeId),
    And(NodeId, NodeId),
    Or(NodeId, NodeId),
    Xor(NodeId, NodeId),
    Nand(NodeId, NodeId),
    Nor(NodeId, NodeId),
    Xnor(NodeId, NodeId),
    /// `Mux(sel, a, b)` = `sel ? b : a`.
    Mux(NodeId, NodeId, NodeId),
}

impl Gate {
    /// Operand ids of this gate.
    pub fn operands(&self) -> impl Iterator<Item = NodeId> {
        let (a, b, c) = match *self {
            Gate::Input(_) | Gate::Const(_) | Gate::Param(_) => (None, None, None),
            Gate::Not(x) => (Some(x), None, None),
            Gate::And(x, y)
            | Gate::Or(x, y)
            | Gate::Xor(x, y)
            | Gate::Nand(x, y)
            | Gate::Nor(x, y)
            | Gate::Xnor(x, y) => (Some(x), Some(y), None),
            Gate::Mux(s, x, y) => (Some(s), Some(x), Some(y)),
        };
        [a, b, c].into_iter().flatten()
    }

    /// True for nodes that occupy silicon (not inputs/constants/params).
    pub fn is_cell(&self) -> bool {
        !matches!(self, Gate::Input(_) | Gate::Const(_) | Gate::Param(_))
    }
}

/// A combinational netlist.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub gates: Vec<Gate>,
    /// Named output buses: `(name, bits LSB-first)`.
    pub outputs: Vec<(String, Vec<NodeId>)>,
    pub n_inputs: u32,
}

/// A bus is a vector of node ids, LSB first.
pub type Bus = Vec<NodeId>;

impl Netlist {
    pub fn new() -> Netlist {
        Netlist::default()
    }

    fn push(&mut self, g: Gate) -> NodeId {
        debug_assert!(g.operands().all(|o| (o as usize) < self.gates.len()));
        self.gates.push(g);
        (self.gates.len() - 1) as NodeId
    }

    /// Allocate the next primary input bit.
    pub fn input(&mut self) -> NodeId {
        let idx = self.n_inputs;
        self.n_inputs += 1;
        self.push(Gate::Input(idx))
    }

    /// Allocate an input bus of `width` bits (LSB first).
    pub fn input_bus(&mut self, width: u32) -> Bus {
        (0..width).map(|_| self.input()).collect()
    }

    pub fn constant(&mut self, v: bool) -> NodeId {
        self.push(Gate::Const(v))
    }

    /// Allocate a [`Gate::Param`] literal site (template construction —
    /// callers assign indices; [`Template::new`] checks density).
    pub fn param(&mut self, p: u32) -> NodeId {
        self.push(Gate::Param(p))
    }

    pub fn not(&mut self, a: NodeId) -> NodeId {
        self.push(Gate::Not(a))
    }
    pub fn and(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(Gate::And(a, b))
    }
    pub fn or(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(Gate::Or(a, b))
    }
    pub fn xor(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(Gate::Xor(a, b))
    }
    pub fn nand(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(Gate::Nand(a, b))
    }
    pub fn nor(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(Gate::Nor(a, b))
    }
    pub fn xnor(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(Gate::Xnor(a, b))
    }
    pub fn mux(&mut self, sel: NodeId, a: NodeId, b: NodeId) -> NodeId {
        self.push(Gate::Mux(sel, a, b))
    }

    /// Register an output bus.
    pub fn output(&mut self, name: &str, bus: Bus) {
        self.outputs.push((name.to_string(), bus));
    }

    /// Total gate nodes including inputs/constants.
    pub fn len(&self) -> usize {
        self.gates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gates.is_empty()
    }

    /// Number of real cells (excluding inputs and constants).
    pub fn cell_count(&self) -> usize {
        self.gates.iter().filter(|g| g.is_cell()).count()
    }

    /// Per-gate-kind cell counts `(not, and, or, xor, nand, nor, xnor, mux)`.
    pub fn cell_histogram(&self) -> CellCounts {
        let mut c = CellCounts::default();
        for g in &self.gates {
            c.add(g);
        }
        c
    }

    /// Logic depth (levels) per node; level of inputs/constants is 0.
    pub fn levels(&self) -> Vec<u32> {
        let mut lv = vec![0u32; self.gates.len()];
        for (i, g) in self.gates.iter().enumerate() {
            if g.is_cell() {
                lv[i] = g.operands().map(|o| lv[o as usize]).max().unwrap_or(0) + 1;
            }
        }
        lv
    }

    /// Maximum logic depth over the output cone.
    pub fn depth(&self) -> u32 {
        let lv = self.levels();
        self.outputs
            .iter()
            .flat_map(|(_, bus)| bus.iter())
            .map(|&n| lv[n as usize])
            .max()
            .unwrap_or(0)
    }
}

/// Cell counts per kind.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CellCounts {
    pub not: usize,
    pub and: usize,
    pub or: usize,
    pub xor: usize,
    pub nand: usize,
    pub nor: usize,
    pub xnor: usize,
    pub mux: usize,
}

impl CellCounts {
    pub fn total(&self) -> usize {
        self.not + self.and + self.or + self.xor + self.nand + self.nor + self.xnor + self.mux
    }

    /// Count one gate (no-op for inputs/constants/params) — shared by
    /// [`Netlist::cell_histogram`] and the incremental survivor census
    /// of `synth::incremental`, so the two bucketings can never drift.
    pub fn add(&mut self, g: &Gate) {
        match g {
            Gate::Not(_) => self.not += 1,
            Gate::And(..) => self.and += 1,
            Gate::Or(..) => self.or += 1,
            Gate::Xor(..) => self.xor += 1,
            Gate::Nand(..) => self.nand += 1,
            Gate::Nor(..) => self.nor += 1,
            Gate::Xnor(..) => self.xnor += 1,
            Gate::Mux(..) => self.mux += 1,
            Gate::Input(_) | Gate::Const(_) | Gate::Param(_) => {}
        }
    }
}

/// One *cone group* of a [`Template`]: a contiguous run of template
/// nodes (one bespoke neuron: preactivation adder tree + activation)
/// together with the contiguous run of `Param` sites bound inside it.
///
/// Cone groups are the sharing unit of cross-chromosome evaluation
/// (`synth::incremental`'s generation-scoped memo): given identical
/// frontier representatives and an identical group param binding, the
/// re-synthesized interior of the group is identical too — sibling
/// chromosomes that differ only in *other* neurons' mask bits can reuse
/// the whole group verbatim. The builder registers groups; the template
/// validates the ranges.
#[derive(Clone, Debug)]
pub struct ConeGroup {
    /// Template nodes `node_lo..node_hi` (contiguous, exclusive end).
    pub node_lo: NodeId,
    pub node_hi: NodeId,
    /// Param indices `param_lo..param_hi` — exactly the `Param` sites
    /// whose nodes lie inside the node range.
    pub param_lo: u32,
    pub param_hi: u32,
    /// External operand nodes (ids `< node_lo`) read by the group's
    /// gates — deduped, ascending. The group's interior is a pure
    /// function of these nodes' representatives plus the param binding.
    pub frontier: Vec<NodeId>,
}

/// A parameterized netlist: a fixed gate graph whose [`Gate::Param`]
/// leaves are boolean literal sites bound at instantiation time.
///
/// This is the once-per-(dataset, quantized model) form of the bespoke
/// MLP circuits: every mask-controlled summand bit is a `Param` site, so
/// one chromosome differs from the next only in the constants bound to a
/// handful of leaves — which is what lets `synth::incremental` re-run
/// simplification over just the fanout cones of the flipped literals.
/// The template also carries the fanout adjacency (CSR: consumers of
/// each node) that cone traversal needs.
#[derive(Clone, Debug)]
pub struct Template {
    /// The parameterized gate graph (topologically ordered, like every
    /// [`Netlist`]).
    pub nl: Netlist,
    /// Number of `Param` sites; indices are dense in `0..n_params`.
    pub n_params: usize,
    /// Node id of `Param(p)`, indexed by `p`.
    pub param_nodes: Vec<NodeId>,
    /// Builder-registered cone groups, ascending and non-overlapping
    /// (empty when the builder declared none — sharing is then simply
    /// unavailable).
    pub cone_groups: Vec<ConeGroup>,
    /// CSR fanout: consumers of node `i` are
    /// `fan_dst[fan_off[i]..fan_off[i + 1]]`.
    fan_off: Vec<u32>,
    fan_dst: Vec<NodeId>,
}

impl Template {
    /// Wrap a netlist containing `Param` gates. Every index in
    /// `0..n_params` must appear exactly once.
    pub fn new(nl: Netlist, n_params: usize) -> Template {
        let mut param_nodes = vec![NodeId::MAX; n_params];
        for (i, g) in nl.gates.iter().enumerate() {
            if let Gate::Param(p) = *g {
                let slot = &mut param_nodes[p as usize];
                assert_eq!(*slot, NodeId::MAX, "duplicate Param({p})");
                *slot = i as NodeId;
            }
        }
        assert!(
            param_nodes.iter().all(|&n| n != NodeId::MAX),
            "template param indices must be dense in 0..{n_params}"
        );

        // CSR fanout: count consumer degrees, prefix-sum, fill.
        let n = nl.gates.len();
        let mut fan_off = vec![0u32; n + 1];
        for g in &nl.gates {
            for op in g.operands() {
                fan_off[op as usize + 1] += 1;
            }
        }
        for i in 0..n {
            fan_off[i + 1] += fan_off[i];
        }
        let mut fan_dst: Vec<NodeId> = vec![0; fan_off[n] as usize];
        let mut cursor: Vec<u32> = fan_off[..n].to_vec();
        for (i, g) in nl.gates.iter().enumerate() {
            for op in g.operands() {
                let c = &mut cursor[op as usize];
                fan_dst[*c as usize] = i as NodeId;
                *c += 1;
            }
        }
        Template { nl, n_params, param_nodes, cone_groups: Vec::new(), fan_off, fan_dst }
    }

    /// Register a cone group covering template nodes
    /// `node_lo..node_hi` and param indices `param_lo..param_hi`.
    /// Groups must be registered in ascending, non-overlapping order;
    /// the param range must be exactly the `Param` sites inside the
    /// node range. Computes the group's frontier (external operands).
    pub fn register_cone_group(
        &mut self,
        node_lo: NodeId,
        node_hi: NodeId,
        param_lo: u32,
        param_hi: u32,
    ) {
        assert!(
            node_lo <= node_hi && (node_hi as usize) <= self.nl.gates.len(),
            "cone group node range {node_lo}..{node_hi} out of bounds"
        );
        assert!(param_lo <= param_hi && (param_hi as usize) <= self.n_params);
        if let Some(prev) = self.cone_groups.last() {
            assert!(
                prev.node_hi <= node_lo && prev.param_hi <= param_lo,
                "cone groups must be ascending and non-overlapping"
            );
        }
        let mut frontier: Vec<NodeId> = Vec::new();
        let mut params_seen = 0u32;
        for id in node_lo..node_hi {
            let g = &self.nl.gates[id as usize];
            if let Gate::Param(p) = *g {
                assert!(
                    (param_lo..param_hi).contains(&p),
                    "Param({p}) inside node range but outside param range \
                     {param_lo}..{param_hi}"
                );
                params_seen += 1;
            }
            for op in g.operands() {
                if op < node_lo {
                    frontier.push(op);
                }
            }
        }
        assert_eq!(
            params_seen,
            param_hi - param_lo,
            "param range {param_lo}..{param_hi} not fully inside node range"
        );
        frontier.sort_unstable();
        frontier.dedup();
        self.cone_groups.push(ConeGroup { node_lo, node_hi, param_lo, param_hi, frontier });
    }

    /// Overwrite one CSR fanout destination slot with `dst`, returning
    /// the previous destination. `#[doc(hidden)]` corruption-injection
    /// hook for the invariant verifier's test suite
    /// (`rust/tests/verify_lint.rs`) — the only way to seed a dangling
    /// CSR edge, since the adjacency arrays are private. Not part of
    /// the API.
    #[doc(hidden)]
    pub fn corrupt_fanout_slot(&mut self, slot: usize, dst: NodeId) -> NodeId {
        std::mem::replace(&mut self.fan_dst[slot], dst)
    }

    /// Consumers of node `id` (each consumer id is > `id` by the
    /// topological invariant).
    pub fn consumers(&self, id: NodeId) -> &[NodeId] {
        let lo = self.fan_off[id as usize] as usize;
        let hi = self.fan_off[id as usize + 1] as usize;
        &self.fan_dst[lo..hi]
    }

    /// Bind every `Param(p)` to `Const(params[p])`, yielding an ordinary
    /// netlist ready for from-scratch synthesis — the reference the
    /// incremental engine is pinned against.
    pub fn instantiate(&self, params: &crate::util::BitVec) -> Netlist {
        assert_eq!(params.len(), self.n_params, "param count mismatch");
        let mut out = self.nl.clone();
        for (p, &id) in self.param_nodes.iter().enumerate() {
            out.gates[id as usize] = Gate::Const(params.get(p));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topological_invariant() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let c = nl.and(a, b);
        let d = nl.xor(c, a);
        nl.output("y", vec![d]);
        for (i, g) in nl.gates.iter().enumerate() {
            for o in g.operands() {
                assert!((o as usize) < i);
            }
        }
    }

    #[test]
    fn cell_counts_exclude_io() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let k = nl.constant(true);
        let c = nl.and(a, b);
        let d = nl.or(c, k);
        nl.output("y", vec![d]);
        assert_eq!(nl.cell_count(), 2);
        let h = nl.cell_histogram();
        assert_eq!(h.and, 1);
        assert_eq!(h.or, 1);
        assert_eq!(h.total(), 2);
    }

    #[test]
    fn depth_computation() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let c = nl.and(a, b); // level 1
        let d = nl.or(c, b); // level 2
        let e = nl.xor(d, c); // level 3
        nl.output("y", vec![e]);
        assert_eq!(nl.depth(), 3);
    }

    #[test]
    fn template_fanout_and_instantiation() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let p0 = nl.param(0);
        let p1 = nl.param(1);
        let g = nl.and(a, p0);
        let h = nl.or(g, p1);
        nl.output("y", vec![h]);
        let tpl = Template::new(nl, 2);
        assert_eq!(tpl.param_nodes, vec![p0, p1]);
        assert_eq!(tpl.consumers(a), &[g]);
        assert_eq!(tpl.consumers(p0), &[g]);
        assert_eq!(tpl.consumers(g), &[h]);
        assert_eq!(tpl.consumers(h), &[] as &[NodeId]);

        let params = crate::util::BitVec::from_bools(&[true, false]);
        let inst = tpl.instantiate(&params);
        assert_eq!(inst.gates[p0 as usize], Gate::Const(true));
        assert_eq!(inst.gates[p1 as usize], Gate::Const(false));
        // Cell structure untouched; only the literal sites were bound.
        assert_eq!(inst.cell_count(), tpl.nl.cell_count());
    }

    #[test]
    fn cone_group_registration_computes_frontier() {
        // Two "neurons" sharing an input: each group's frontier is the
        // external nodes it reads, params split contiguously.
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let g0_lo = nl.len() as NodeId;
        let p0 = nl.param(0);
        let y0 = nl.and(a, p0);
        let g0_hi = nl.len() as NodeId;
        let p1 = nl.param(1);
        let y1 = nl.mux(b, y0, p1);
        let g1_hi = nl.len() as NodeId;
        nl.output("y", vec![y0, y1]);
        let mut tpl = Template::new(nl, 2);
        tpl.register_cone_group(g0_lo, g0_hi, 0, 1);
        tpl.register_cone_group(g0_hi, g1_hi, 1, 2);
        assert_eq!(tpl.cone_groups.len(), 2);
        assert_eq!(tpl.cone_groups[0].frontier, vec![a]);
        // Group 1 reads input b and group 0's output y0.
        assert_eq!(tpl.cone_groups[1].frontier, vec![b, y0]);
        let _ = p1;
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn cone_groups_must_not_overlap() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let p0 = nl.param(0);
        nl.and(a, p0);
        let hi = nl.len() as NodeId;
        let mut tpl = Template::new(nl, 1);
        tpl.register_cone_group(0, hi, 0, 1);
        tpl.register_cone_group(0, hi, 0, 1);
    }

    #[test]
    #[should_panic(expected = "not fully inside")]
    fn cone_group_param_range_must_match_nodes() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let p0 = nl.param(0);
        let x = nl.and(a, p0);
        nl.param(1);
        let _ = x;
        let mut tpl = Template::new(nl, 2);
        // Claims both params but the node range only contains Param(0).
        tpl.register_cone_group(1, 3, 0, 2);
    }

    #[test]
    #[should_panic(expected = "dense")]
    fn template_rejects_sparse_params() {
        let mut nl = Netlist::new();
        nl.param(1); // index 0 missing
        Template::new(nl, 2);
    }

    #[test]
    fn params_are_not_cells() {
        let mut nl = Netlist::new();
        let p = nl.param(0);
        assert!(!nl.gates[p as usize].is_cell());
        assert_eq!(nl.cell_count(), 0);
    }

    #[test]
    fn input_indices_sequential() {
        let mut nl = Netlist::new();
        let bus = nl.input_bus(4);
        assert_eq!(bus.len(), 4);
        assert_eq!(nl.n_inputs, 4);
        match nl.gates[bus[3] as usize] {
            Gate::Input(3) => {}
            ref g => panic!("expected Input(3), got {g:?}"),
        }
    }
}
