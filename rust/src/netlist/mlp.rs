//! Bespoke MLP circuit generator — the "HDL description" stage of the
//! paper's flow (Fig. 1), at the gate level.
//!
//! Generates the fully-parallel (one inference per cycle) bespoke circuit
//! of a quantized MLP: per neuron, the positive and negative power-of-2
//! summands feed two carry-save adder trees (shifts are wiring), the two
//! sums meet in one subtractor, the hidden layer applies QRelu(8), and
//! the output layer ends in an (exact or approximate) Argmax comparator
//! tree. Summand bits removed by the accumulation approximation become
//! `Const(false)` wires for `crate::synth` to sweep — exactly the
//! mechanism of paper §III-D.

use crate::argmax::ArgmaxPlan;
use crate::fixedpoint::{bits_for, ACT_BITS};
use crate::model::{MaskSet, QuantLayer, QuantMlp};
use crate::netlist::build::{
    bias_signed, const_bus, masked_gt, mux_bus, param_masked, qrelu, resize, shl, sign_extend,
    subtractor,
};
use crate::netlist::{build, Bus, Netlist, Template};

/// How the circuit terminates.
#[derive(Clone, Debug)]
pub enum ArgmaxMode {
    /// Expose the raw output-layer pre-activations (for equivalence
    /// tests and for the argmax search itself).
    Raw,
    /// Exact comparator tree (adjacent pairing, full width).
    Exact,
    /// Approximate plan from `crate::argmax`.
    Plan(ArgmaxPlan),
}

/// Circuit generation options.
#[derive(Clone, Debug)]
pub struct MlpCircuitOpts {
    /// Summand-bit masks (accumulation approximation); `None` = exact.
    pub masks: Option<MaskSet>,
    pub argmax: ArgmaxMode,
}

impl Default for MlpCircuitOpts {
    fn default() -> Self {
        MlpCircuitOpts { masks: None, argmax: ArgmaxMode::Exact }
    }
}

/// Build the bespoke circuit of a quantized MLP.
///
/// Inputs: `n_in` 4-bit buses in feature order (LSB first each).
/// Outputs: `class` (the argmax index) and, in `Raw` mode, one signed
/// `z<m>` bus per output neuron.
pub fn build_mlp_circuit(mlp: &QuantMlp, opts: &MlpCircuitOpts) -> Netlist {
    let mut nl = Netlist::new();
    let x: Vec<Bus> = (0..mlp.topo.n_in).map(|_| nl.input_bus(mlp.l1.in_bits)).collect();

    // ---- hidden layer ---------------------------------------------------
    let mut h: Vec<Bus> = Vec::with_capacity(mlp.topo.n_hidden);
    for n in 0..mlp.topo.n_hidden {
        let z = neuron_preact_bus(
            &mut nl,
            &mlp.l1,
            n,
            &x,
            opts.masks.as_ref().map(|m| (&m.m1[..], &m.mb1[..])),
        );
        h.push(qrelu(&mut nl, &z, mlp.act_shift, ACT_BITS));
    }

    // ---- output layer ----------------------------------------------------
    let width = mlp.output_width();
    let mut z2: Vec<Bus> = Vec::with_capacity(mlp.topo.n_out);
    for m in 0..mlp.topo.n_out {
        let z = neuron_preact_bus(
            &mut nl,
            &mlp.l2,
            m,
            &h,
            opts.masks.as_ref().map(|ms| (&ms.m2[..], &ms.mb2[..])),
        );
        z2.push(sign_extend(&mut nl, &z, width));
    }

    // ---- activation of the output layer (argmax) -------------------------
    match &opts.argmax {
        ArgmaxMode::Raw => {
            for (m, z) in z2.iter().enumerate() {
                nl.output(&format!("z{m}"), z.clone());
            }
        }
        ArgmaxMode::Exact => {
            let plan = ArgmaxPlan::exact(mlp.topo.n_out, width);
            let class = argmax_tree(&mut nl, &z2, &plan);
            nl.output("class", class);
        }
        ArgmaxMode::Plan(plan) => {
            assert_eq!(plan.n, mlp.topo.n_out);
            assert_eq!(plan.width, width, "plan width must match circuit width");
            let class = argmax_tree(&mut nl, &z2, plan);
            nl.output("class", class);
        }
    }
    nl
}

/// Build the parameterized *template* of a quantized MLP: structurally
/// the same bespoke circuit as [`build_mlp_circuit`], but every
/// mask-controlled summand bit is guarded by a [`crate::netlist::Gate::Param`]
/// literal instead of being resolved against a concrete mask.
///
/// Param index `p` is exactly genome bit `p` of
/// [`crate::accum::GenomeMap`]'s canonical order (layer 1 then 2; neuron
/// by neuron; non-zero-weight inputs `j` ascending, bits LSB→MSB; bias
/// bit last) — the two enumerations below and in `GenomeMap::new` must
/// stay in lockstep, and the evaluator asserts their lengths agree.
/// Instantiating the template with a chromosome therefore reproduces the
/// masked build's function (pinned by tests), which is what lets
/// `synth::incremental` re-synthesize only the cones of flipped bits.
pub fn build_mlp_template(mlp: &QuantMlp, argmax: &ArgmaxMode) -> Template {
    let mut nl = Netlist::new();
    let mut next_param = 0u32;
    let x: Vec<Bus> = (0..mlp.topo.n_in).map(|_| nl.input_bus(mlp.l1.in_bits)).collect();

    // One cone group per neuron (adder trees + activation), recorded as
    // `(node_lo, node_hi, param_lo, param_hi)` while building and
    // registered on the template below — the sharing unit of the
    // cross-chromosome cone memo (`synth::incremental`).
    let mut groups: Vec<(u32, u32, u32, u32)> = Vec::new();

    // ---- hidden layer ---------------------------------------------------
    let mut h: Vec<Bus> = Vec::with_capacity(mlp.topo.n_hidden);
    for n in 0..mlp.topo.n_hidden {
        let (node_lo, param_lo) = (nl.len() as u32, next_param);
        let z = neuron_preact_template(&mut nl, &mlp.l1, n, &x, &mut next_param);
        h.push(qrelu(&mut nl, &z, mlp.act_shift, ACT_BITS));
        groups.push((node_lo, nl.len() as u32, param_lo, next_param));
    }

    // ---- output layer ----------------------------------------------------
    let width = mlp.output_width();
    let mut z2: Vec<Bus> = Vec::with_capacity(mlp.topo.n_out);
    for m in 0..mlp.topo.n_out {
        let (node_lo, param_lo) = (nl.len() as u32, next_param);
        let z = neuron_preact_template(&mut nl, &mlp.l2, m, &h, &mut next_param);
        z2.push(sign_extend(&mut nl, &z, width));
        groups.push((node_lo, nl.len() as u32, param_lo, next_param));
    }

    // ---- activation of the output layer (argmax) -------------------------
    match argmax {
        ArgmaxMode::Raw => {
            for (m, z) in z2.iter().enumerate() {
                nl.output(&format!("z{m}"), z.clone());
            }
        }
        ArgmaxMode::Exact => {
            let plan = ArgmaxPlan::exact(mlp.topo.n_out, width);
            let class = argmax_tree(&mut nl, &z2, &plan);
            nl.output("class", class);
        }
        ArgmaxMode::Plan(plan) => {
            assert_eq!(plan.n, mlp.topo.n_out);
            assert_eq!(plan.width, width, "plan width must match circuit width");
            let class = argmax_tree(&mut nl, &z2, plan);
            nl.output("class", class);
        }
    }
    let mut tpl = Template::new(nl, next_param as usize);
    for (node_lo, node_hi, param_lo, param_hi) in groups {
        tpl.register_cone_group(node_lo, node_hi, param_lo, param_hi);
    }
    tpl
}

/// One neuron's pre-activation bus: two CSA trees (pos/neg) + subtract.
fn neuron_preact_bus(
    nl: &mut Netlist,
    layer: &QuantLayer,
    n: usize,
    inputs: &[Bus],
    masks: Option<(&[u32], &[bool])>,
) -> Bus {
    let mut pos: Vec<Bus> = Vec::new();
    let mut neg: Vec<Bus> = Vec::new();
    for (j, input) in inputs.iter().enumerate() {
        let w = layer.weight(n, j);
        if w.sign == 0 {
            continue;
        }
        // Apply the summand-bit mask: removed bits become constant zero.
        let mask = masks.map(|(m, _)| m[n * layer.n_in + j]).unwrap_or(u32::MAX);
        let masked: Bus = input
            .iter()
            .enumerate()
            .map(|(b, &bit)| if (mask >> b) & 1 == 1 { bit } else { nl.constant(false) })
            .collect();
        let summand = shl(nl, &masked, w.shift as u32);
        if w.sign > 0 {
            pos.push(summand);
        } else {
            neg.push(summand);
        }
    }
    let bias = layer.bias[n];
    let bias_kept = masks.map(|(_, bk)| bk[n]).unwrap_or(true);
    if bias.is_nonzero() && bias_kept {
        let bus = const_bus(nl, 1u64 << bias.shift, bias.shift as u32 + 1);
        if bias.sign > 0 {
            pos.push(bus);
        } else {
            neg.push(bus);
        }
    }
    let psum = build::csa_tree(nl, &pos);
    let nsum = build::csa_tree(nl, &neg);
    // Width: enough for the worst-case unmasked sums (masking only
    // shrinks values, so this is always sufficient).
    let (pmax, nmax) = layer.tree_max(n);
    let w = bits_for(pmax.max(nmax)).max(1);
    let psum = resize(nl, &psum, w);
    let nsum = resize(nl, &nsum, w);
    subtractor(nl, &psum, &nsum)
}

/// Template twin of [`neuron_preact_bus`]: identical tree structure, but
/// every summand bit is `input & Param(p)` and the bias summand's set
/// bit is `Param(p)` itself (an all-zero bus when the bias is dropped —
/// arithmetically the legacy omitted bus). The two functions must
/// enumerate summands in the same order; `build_mlp_template` documents
/// the shared canonical order.
fn neuron_preact_template(
    nl: &mut Netlist,
    layer: &QuantLayer,
    n: usize,
    inputs: &[Bus],
    next_param: &mut u32,
) -> Bus {
    let mut pos: Vec<Bus> = Vec::new();
    let mut neg: Vec<Bus> = Vec::new();
    for (j, input) in inputs.iter().enumerate() {
        let w = layer.weight(n, j);
        if w.sign == 0 {
            continue;
        }
        let masked = param_masked(nl, input, next_param);
        let summand = shl(nl, &masked, w.shift as u32);
        if w.sign > 0 {
            pos.push(summand);
        } else {
            neg.push(summand);
        }
    }
    let bias = layer.bias[n];
    if bias.is_nonzero() {
        let p = nl.param(*next_param);
        *next_param += 1;
        let bus = shl(nl, &vec![p], bias.shift as u32);
        if bias.sign > 0 {
            pos.push(bus);
        } else {
            neg.push(bus);
        }
    }
    let psum = build::csa_tree(nl, &pos);
    let nsum = build::csa_tree(nl, &neg);
    let (pmax, nmax) = layer.tree_max(n);
    let w = bits_for(pmax.max(nmax)).max(1);
    let psum = resize(nl, &psum, w);
    let nsum = resize(nl, &nsum, w);
    subtractor(nl, &psum, &nsum)
}

/// Instantiate an argmax comparator tree per an [`ArgmaxPlan`]: slots
/// carry (biased value bus, index bus); each comparator is a masked
/// unsigned comparator + value/index muxes.
fn argmax_tree(nl: &mut Netlist, z: &[Bus], plan: &ArgmaxPlan) -> Bus {
    let idx_width = bits_for((z.len().max(2) - 1) as u64);
    let mut slots: Vec<(Bus, Bus)> = z
        .iter()
        .enumerate()
        .map(|(i, bus)| {
            let biased = bias_signed(nl, bus);
            let index = const_bus(nl, i as u64, idx_width);
            (biased, index)
        })
        .collect();
    for stage in &plan.stages {
        let mut used = vec![false; slots.len()];
        let mut next: Vec<(Bus, Bus)> = Vec::with_capacity(stage.len() + 1);
        for cmp in stage {
            let (va, ia) = slots[cmp.a].clone();
            let (vb, ib) = slots[cmp.b].clone();
            used[cmp.a] = true;
            used[cmp.b] = true;
            let sel = masked_gt(nl, &va, &vb, cmp.mask); // sel=1 -> b wins
            let val = mux_bus(nl, sel, &va, &vb);
            let idx = mux_bus(nl, sel, &ia, &ib);
            next.push((val, idx));
        }
        for (k, slot) in slots.iter().enumerate() {
            if !used[k] {
                next.push(slot.clone());
            }
        }
        slots = next;
    }
    slots[0].1.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accum::GenomeMap;
    use crate::config::builtin;
    use crate::datasets;
    use crate::model::float_mlp::TrainOpts;
    use crate::model::FloatMlp;
    use crate::sim::{bus_to_i64, bus_to_u64, eval};
    use crate::synth::optimize;
    use crate::util::Rng;

    fn tiny_qmlp() -> (QuantMlp, crate::datasets::QuantDataset) {
        let cfg = builtin::tiny();
        let (split, qtrain, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 25, ..Default::default() });
        (QuantMlp::from_float(&mlp, &qtrain), qtrain)
    }

    fn encode_inputs(x: &[u32], bits: u32) -> Vec<bool> {
        let mut v = Vec::new();
        for &xi in x {
            for b in 0..bits {
                v.push((xi >> b) & 1 == 1);
            }
        }
        v
    }

    #[test]
    fn raw_circuit_matches_integer_model() {
        let (qmlp, qtrain) = tiny_qmlp();
        let nl = build_mlp_circuit(
            &qmlp,
            &MlpCircuitOpts { masks: None, argmax: ArgmaxMode::Raw },
        );
        for row in qtrain.x.iter().take(30) {
            let (_, z_model) = qmlp.forward(row);
            let out = eval(&nl, &encode_inputs(row, 4));
            for (m, &zm) in z_model.iter().enumerate() {
                let z_hw = bus_to_i64(&out[&format!("z{m}")]);
                assert_eq!(z_hw, zm, "neuron {m} sample mismatch");
            }
        }
    }

    #[test]
    fn exact_argmax_circuit_matches_predict() {
        let (qmlp, qtrain) = tiny_qmlp();
        let nl = build_mlp_circuit(&qmlp, &MlpCircuitOpts::default());
        for row in qtrain.x.iter().take(30) {
            let expect = qmlp.predict(row, None);
            let out = eval(&nl, &encode_inputs(row, 4));
            assert_eq!(bus_to_u64(&out["class"]) as usize, expect);
        }
    }

    #[test]
    fn masked_circuit_matches_masked_model() {
        let (qmlp, qtrain) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        let mut rng = Rng::new(9);
        for trial in 0..5 {
            let genome = map.random_genome(&mut rng, 0.7);
            let masks = map.to_masks(&genome);
            let nl = build_mlp_circuit(
                &qmlp,
                &MlpCircuitOpts {
                    masks: Some(masks.clone()),
                    argmax: ArgmaxMode::Raw,
                },
            );
            let (opt, _) = optimize(&nl);
            for row in qtrain.x.iter().take(10) {
                let (_, z_model) = qmlp.forward_masked(row, Some(&masks));
                let out = eval(&opt, &encode_inputs(row, 4));
                for (m, &zm) in z_model.iter().enumerate() {
                    assert_eq!(
                        bus_to_i64(&out[&format!("z{m}")]),
                        zm,
                        "trial {trial} neuron {m}"
                    );
                }
            }
        }
    }

    #[test]
    fn template_params_match_genome_map() {
        let (qmlp, _) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        let tpl = build_mlp_template(&qmlp, &ArgmaxMode::Exact);
        assert_eq!(tpl.n_params, map.len(), "param sites must be genome bits");
    }

    #[test]
    fn template_cone_groups_cover_every_param_site() {
        // One group per neuron; param ranges tile the genome exactly
        // (the shared-cone memo keys on group-local bindings, so a gap
        // or overlap would silently break sharing).
        let (qmlp, _) = tiny_qmlp();
        let tpl = build_mlp_template(&qmlp, &ArgmaxMode::Exact);
        assert_eq!(tpl.cone_groups.len(), qmlp.topo.n_hidden + qmlp.topo.n_out);
        let mut next = 0u32;
        for g in &tpl.cone_groups {
            assert_eq!(g.param_lo, next, "param ranges must tile the genome");
            assert!(g.node_lo < g.node_hi);
            assert!(!g.frontier.is_empty(), "every neuron reads external inputs");
            next = g.param_hi;
        }
        assert_eq!(next as usize, tpl.n_params);
    }

    #[test]
    fn template_instantiation_matches_masked_build() {
        // The template bound to a chromosome must compute exactly what
        // the legacy masked build computes — neuron by neuron against
        // the masked integer model, plus matching class outputs.
        let (qmlp, qtrain) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        let tpl = build_mlp_template(&qmlp, &ArgmaxMode::Raw);
        let tpl_class = build_mlp_template(&qmlp, &ArgmaxMode::Exact);
        let mut rng = Rng::new(31);
        for trial in 0..4 {
            let genome = if trial == 0 {
                map.exact_genome()
            } else {
                map.random_genome(&mut rng, 0.6)
            };
            let masks = map.to_masks(&genome);
            let (opt, _) = optimize(&tpl.instantiate(&genome));
            let legacy_nl = build_mlp_circuit(
                &qmlp,
                &MlpCircuitOpts { masks: Some(masks.clone()), argmax: ArgmaxMode::Exact },
            );
            let (legacy_opt, _) = optimize(&legacy_nl);
            let (class_opt, _) = optimize(&tpl_class.instantiate(&genome));
            for row in qtrain.x.iter().take(10) {
                let (_, z_model) = qmlp.forward_masked(row, Some(&masks));
                let out = eval(&opt, &encode_inputs(row, 4));
                for (m, &zm) in z_model.iter().enumerate() {
                    assert_eq!(
                        bus_to_i64(&out[&format!("z{m}")]),
                        zm,
                        "trial {trial} neuron {m}"
                    );
                }
                let inputs = encode_inputs(row, 4);
                assert_eq!(
                    bus_to_u64(&eval(&class_opt, &inputs)["class"]),
                    bus_to_u64(&eval(&legacy_opt, &inputs)["class"]),
                    "trial {trial}: class output diverged from masked build"
                );
            }
        }
    }

    #[test]
    fn synthesis_shrinks_masked_circuits() {
        let (qmlp, _) = tiny_qmlp();
        let map = GenomeMap::new(&qmlp);
        let exact_nl = build_mlp_circuit(&qmlp, &MlpCircuitOpts::default());
        let (exact_opt, _) = optimize(&exact_nl);
        // Remove half the summand bits.
        let mut rng = Rng::new(4);
        let genome = map.random_genome(&mut rng, 0.5);
        let masks = map.to_masks(&genome);
        let approx_nl = build_mlp_circuit(
            &qmlp,
            &MlpCircuitOpts { masks: Some(masks), argmax: ArgmaxMode::Exact },
        );
        let (approx_opt, _) = optimize(&approx_nl);
        assert!(
            approx_opt.cell_count() < exact_opt.cell_count(),
            "approx {} !< exact {}",
            approx_opt.cell_count(),
            exact_opt.cell_count()
        );
    }

    #[test]
    fn approximate_argmax_circuit_matches_plan() {
        let (qmlp, qtrain) = tiny_qmlp();
        let preacts = qmlp.output_preacts(&qtrain, None);
        let plan = crate::argmax::build_plan(
            &preacts,
            &qtrain.y,
            qmlp.output_width(),
            &crate::argmax::ArgmaxSearchOpts::default(),
        );
        let nl = build_mlp_circuit(
            &qmlp,
            &MlpCircuitOpts { masks: None, argmax: ArgmaxMode::Plan(plan.clone()) },
        );
        let (opt, _) = optimize(&nl);
        for (row, z) in qtrain.x.iter().zip(&preacts).take(50) {
            let expect = plan.predict(z);
            let out = eval(&opt, &encode_inputs(row, 4));
            assert_eq!(bus_to_u64(&out["class"]) as usize, expect);
        }
    }
}
