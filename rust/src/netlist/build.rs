//! Arithmetic circuit builders: adders, carry-save trees, subtractors,
//! shifters, constant-coefficient multipliers, QRelu, masked comparators,
//! and argmax trees — the building blocks of the bespoke MLP circuits.
//!
//! Builders are *naive on purpose*: they instantiate generic structures
//! with `Const(false)` wires where the power-of-2 shifts or the
//! accumulation approximation place known zeros, and rely on
//! `crate::synth` to sweep the constants through — exactly how the paper
//! uses the EDA tool's constant propagation (§III-D).

use super::{Bus, Netlist, NodeId};

/// Constant bus of `width` bits holding `value`.
pub fn const_bus(nl: &mut Netlist, value: u64, width: u32) -> Bus {
    (0..width).map(|i| nl.constant((value >> i) & 1 == 1)).collect()
}

/// Zero-extend (or truncate) a bus to `width`.
pub fn resize(nl: &mut Netlist, bus: &Bus, width: u32) -> Bus {
    let mut out = bus.clone();
    while (out.len() as u32) < width {
        out.push(nl.constant(false));
    }
    out.truncate(width as usize);
    out
}

/// Sign-extend a two's-complement bus to `width`.
pub fn sign_extend(nl: &mut Netlist, bus: &Bus, width: u32) -> Bus {
    assert!(!bus.is_empty());
    let _ = nl;
    let mut out = bus.clone();
    let msb = *bus.last().unwrap();
    while (out.len() as u32) < width {
        out.push(msb);
    }
    out.truncate(width as usize);
    out
}

/// Left shift by a constant: pure wiring (`shift` zero LSBs).
pub fn shl(nl: &mut Netlist, bus: &Bus, shift: u32) -> Bus {
    let mut out: Bus = (0..shift).map(|_| nl.constant(false)).collect();
    out.extend_from_slice(bus);
    out
}

/// Guard each bit of `bus` behind a fresh `Param` literal: bit `i`
/// becomes `bus[i] & Param(next + i)`, and `next` advances past the
/// allocated indices. Binding a param to 1 makes the AND fold to a wire;
/// binding it to 0 yields the constant zero the accumulation
/// approximation plants — so one template instantiation per chromosome
/// reproduces the masked-summand construction after the constant sweep.
pub fn param_masked(nl: &mut Netlist, bus: &Bus, next: &mut u32) -> Bus {
    bus.iter()
        .map(|&bit| {
            let p = nl.param(*next);
            *next += 1;
            nl.and(bit, p)
        })
        .collect()
}

/// Half adder: returns (sum, carry).
pub fn half_adder(nl: &mut Netlist, a: NodeId, b: NodeId) -> (NodeId, NodeId) {
    (nl.xor(a, b), nl.and(a, b))
}

/// Full adder: returns (sum, carry).
pub fn full_adder(nl: &mut Netlist, a: NodeId, b: NodeId, c: NodeId) -> (NodeId, NodeId) {
    let axb = nl.xor(a, b);
    let sum = nl.xor(axb, c);
    let t1 = nl.and(axb, c);
    let t2 = nl.and(a, b);
    let carry = nl.or(t1, t2);
    (sum, carry)
}

/// Ripple-carry adder; output has `max(len)+1` bits.
pub fn adder(nl: &mut Netlist, a: &Bus, b: &Bus) -> Bus {
    let width = a.len().max(b.len()) as u32;
    let a = resize(nl, a, width);
    let b = resize(nl, b, width);
    let mut out = Vec::with_capacity(width as usize + 1);
    let mut carry = nl.constant(false);
    for i in 0..width as usize {
        let (s, c) = full_adder(nl, a[i], b[i], carry);
        out.push(s);
        carry = c;
    }
    out.push(carry);
    out
}

/// Carry-save reduction of many unsigned summands to a single sum bus.
///
/// Column-wise 3:2 / 2:2 compression (Wallace-style) until every column
/// holds ≤ 2 bits, then one final ripple-carry add — the same carry-save
/// operation the paper's area surrogate assumes (§III-D3).
pub fn csa_tree(nl: &mut Netlist, summands: &[Bus]) -> Bus {
    if summands.is_empty() {
        return vec![nl.constant(false)];
    }
    let width = summands.iter().map(Vec::len).max().unwrap() as u32;
    // Columns of live bits.
    let mut cols: Vec<Vec<NodeId>> = vec![Vec::new(); width as usize + 2];
    for s in summands {
        for (i, &bit) in s.iter().enumerate() {
            cols[i].push(bit);
        }
    }
    // Reduce until every column has at most 2 entries.
    loop {
        let maxh = cols.iter().map(Vec::len).max().unwrap();
        if maxh <= 2 {
            break;
        }
        let mut next: Vec<Vec<NodeId>> = vec![Vec::new(); cols.len() + 1];
        for (k, col) in cols.iter().enumerate() {
            let mut it = col.iter().copied();
            loop {
                let chunk: Vec<NodeId> = it.by_ref().take(3).collect();
                match chunk.len() {
                    3 => {
                        let (s, c) = full_adder(nl, chunk[0], chunk[1], chunk[2]);
                        next[k].push(s);
                        next[k + 1].push(c);
                    }
                    2 => {
                        let (s, c) = half_adder(nl, chunk[0], chunk[1]);
                        next[k].push(s);
                        next[k + 1].push(c);
                    }
                    1 => next[k].push(chunk[0]),
                    _ => break,
                }
            }
        }
        while next.last().map(|c| c.is_empty()).unwrap_or(false) {
            next.pop();
        }
        cols = next;
    }
    // Final two rows -> ripple-carry adder.
    let width = cols.len() as u32;
    let zero = nl.constant(false);
    let mut row_a: Bus = Vec::with_capacity(width as usize);
    let mut row_b: Bus = Vec::with_capacity(width as usize);
    for col in &cols {
        row_a.push(col.first().copied().unwrap_or(zero));
        row_b.push(col.get(1).copied().unwrap_or(zero));
    }
    adder(nl, &row_a, &row_b)
}

/// Two's-complement subtraction `a - b`, output width `w+1` where
/// `w = max(len)` (signed result, MSB = sign).
pub fn subtractor(nl: &mut Netlist, a: &Bus, b: &Bus) -> Bus {
    let width = (a.len().max(b.len()) + 1) as u32;
    let a = resize(nl, a, width);
    let b = resize(nl, b, width);
    let mut out = Vec::with_capacity(width as usize);
    let mut carry = nl.constant(true); // +1 of the two's complement
    for i in 0..width as usize {
        let nb = nl.not(b[i]);
        let (s, c) = full_adder(nl, a[i], nb, carry);
        out.push(s);
        carry = c;
    }
    out
}

/// Constant-coefficient unsigned multiplier `x * k` (shift-add over the
/// set bits of `k`) — the bespoke multiplier of the exact baseline [8].
pub fn const_mul(nl: &mut Netlist, x: &Bus, k: u64) -> Bus {
    if k == 0 {
        return vec![nl.constant(false)];
    }
    let mut partials: Vec<Bus> = Vec::new();
    for bit in 0..64 {
        if (k >> bit) & 1 == 1 {
            partials.push(shl(nl, x, bit));
        }
    }
    if partials.len() == 1 {
        return partials.pop().unwrap();
    }
    csa_tree(nl, &partials)
}

/// QRelu(8) on a signed bus: `clamp(z >> t, 0, 255)`.
///
/// out_i = ~sign & (overflow | z_{t+i}), overflow = OR of magnitude bits
/// above the 8-bit window (nullification ANDs + clipping ORs — the "few
/// AND/OR gates" of paper §III-C1).
pub fn qrelu(nl: &mut Netlist, z: &Bus, t: u32, act_bits: u32) -> Bus {
    let w = z.len();
    assert!(w >= 2, "qrelu needs a signed bus");
    let sign = z[w - 1];
    let not_sign = nl.not(sign);
    // Overflow: any magnitude bit above the window (excluding sign).
    let hi_lo = (t + act_bits) as usize;
    let mut overflow = nl.constant(false);
    for &bit in z.iter().take(w - 1).skip(hi_lo.min(w - 1)) {
        overflow = nl.or(overflow, bit);
    }
    let zero = nl.constant(false);
    (0..act_bits)
        .map(|i| {
            let idx = (t + i) as usize;
            let v = if idx < w - 1 { z[idx] } else { zero };
            let v_or_ovf = nl.or(v, overflow);
            nl.and(not_sign, v_or_ovf)
        })
        .collect()
}

/// Unsigned masked comparator: `sel = (B > A)` comparing only the bit
/// positions set in `mask` (the approximate-Argmax comparator).
///
/// Ripple from LSB to MSB over the masked positions:
/// `gt = b & ~a | (b ⊙ a) & gt_prev` — one stage per compared bit, so a
/// 4-bit subset instantiates a 4-bit comparator (Table IV's size cut).
pub fn masked_gt(nl: &mut Netlist, a: &Bus, b: &Bus, mask: u64) -> NodeId {
    let mut gt = nl.constant(false);
    for i in 0..a.len().max(b.len()) {
        if (mask >> i) & 1 == 0 {
            continue;
        }
        let zero = nl.constant(false);
        let ai = a.get(i).copied().unwrap_or(zero);
        let bi = b.get(i).copied().unwrap_or(zero);
        let na = nl.not(ai);
        let b_gt = nl.and(bi, na);
        let eq = nl.xnor(ai, bi);
        let keep = nl.and(eq, gt);
        gt = nl.or(b_gt, keep);
    }
    gt
}

/// 2:1 bus mux: `sel ? b : a`.
pub fn mux_bus(nl: &mut Netlist, sel: NodeId, a: &Bus, b: &Bus) -> Bus {
    let width = a.len().max(b.len()) as u32;
    let a = resize(nl, a, width);
    let b = resize(nl, b, width);
    (0..width as usize).map(|i| nl.mux(sel, a[i], b[i])).collect()
}

/// Convert a signed two's-complement bus to the biased (offset-binary)
/// form used by the argmax comparators: flip the sign bit.
pub fn bias_signed(nl: &mut Netlist, z: &Bus) -> Bus {
    let mut out = z.clone();
    let w = out.len();
    out[w - 1] = nl.not(z[w - 1]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::eval;
    use crate::util::prop;

    fn bus_value(bits: &[bool]) -> u64 {
        bits.iter().enumerate().map(|(i, &b)| (b as u64) << i).sum()
    }

    fn to_bits(v: u64, w: u32) -> Vec<bool> {
        (0..w).map(|i| (v >> i) & 1 == 1).collect()
    }

    #[test]
    fn adder_exhaustive_4bit() {
        let mut nl = Netlist::new();
        let a = nl.input_bus(4);
        let b = nl.input_bus(4);
        let s = adder(&mut nl, &a, &b);
        nl.output("s", s);
        for x in 0..16u64 {
            for y in 0..16u64 {
                let mut inputs = to_bits(x, 4);
                inputs.extend(to_bits(y, 4));
                let out = eval(&nl, &inputs);
                assert_eq!(bus_value(&out["s"]), x + y, "{x}+{y}");
            }
        }
    }

    #[test]
    fn prop_csa_tree_sums() {
        prop::check("csa tree sums", |rng, _| {
            let n = 1 + rng.below(8);
            let w = 3 + rng.below(5) as u32;
            let vals: Vec<u64> = (0..n).map(|_| rng.below(1 << w) as u64).collect();
            let mut nl = Netlist::new();
            let buses: Vec<Bus> = vals.iter().map(|_| nl.input_bus(w)).collect();
            let s = csa_tree(&mut nl, &buses);
            nl.output("s", s);
            let mut inputs = Vec::new();
            for &v in &vals {
                inputs.extend(to_bits(v, w));
            }
            let out = eval(&nl, &inputs);
            let expect: u64 = vals.iter().sum();
            if bus_value(&out["s"]) != expect {
                return Err(format!("{vals:?} -> {} != {expect}", bus_value(&out["s"])));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_subtractor_signed() {
        prop::check("subtractor", |rng, _| {
            let w = 6u32;
            let x = rng.below(1 << w) as i64;
            let y = rng.below(1 << w) as i64;
            let mut nl = Netlist::new();
            let a = nl.input_bus(w);
            let b = nl.input_bus(w);
            let d = subtractor(&mut nl, &a, &b);
            nl.output("d", d.clone());
            let mut inputs = to_bits(x as u64, w);
            inputs.extend(to_bits(y as u64, w));
            let out = eval(&nl, &inputs);
            let raw = bus_value(&out["d"]);
            // Interpret as signed (w+1 bits).
            let width = d.len() as u32;
            let signed = if (raw >> (width - 1)) & 1 == 1 {
                raw as i64 - (1i64 << width)
            } else {
                raw as i64
            };
            if signed != x - y {
                return Err(format!("{x}-{y} = {signed}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_const_mul() {
        prop::check("const mul", |rng, _| {
            let w = 4u32;
            let x = rng.below(1 << w) as u64;
            let k = rng.below(256) as u64;
            let mut nl = Netlist::new();
            let a = nl.input_bus(w);
            let p = const_mul(&mut nl, &a, k);
            nl.output("p", p);
            let out = eval(&nl, &to_bits(x, w));
            if bus_value(&out["p"]) != x * k {
                return Err(format!("{x}*{k} = {}", bus_value(&out["p"])));
            }
            Ok(())
        });
    }

    #[test]
    fn qrelu_matches_model() {
        // 10-bit signed z, t=2, 4-bit activations for a compact check.
        let w = 10u32;
        let t = 2u32;
        let act = 4u32;
        let mut nl = Netlist::new();
        let z = nl.input_bus(w);
        let h = qrelu(&mut nl, &z, t, act);
        nl.output("h", h);
        for val in -512i64..512 {
            let raw = (val & ((1i64 << w) - 1)) as u64;
            let out = eval(&nl, &to_bits(raw, w));
            let got = bus_value(&out["h"]);
            let expect = if val <= 0 {
                0
            } else {
                ((val >> t) as u64).min((1 << act) - 1)
            };
            assert_eq!(got, expect, "val={val}");
        }
    }

    #[test]
    fn prop_masked_gt() {
        prop::check("masked comparator", |rng, _| {
            let w = 8u32;
            let x = rng.below(1 << w) as u64;
            let y = rng.below(1 << w) as u64;
            let mask = rng.below(1 << w) as u64;
            let mut nl = Netlist::new();
            let a = nl.input_bus(w);
            let b = nl.input_bus(w);
            let gt = masked_gt(&mut nl, &a, &b, mask);
            nl.output("gt", vec![gt]);
            let mut inputs = to_bits(x, w);
            inputs.extend(to_bits(y, w));
            let out = eval(&nl, &inputs);
            let expect = (y & mask) > (x & mask);
            if out["gt"][0] != expect {
                return Err(format!("x={x} y={y} mask={mask:#b}"));
            }
            Ok(())
        });
    }

    #[test]
    fn mux_bus_selects() {
        let mut nl = Netlist::new();
        let sel = nl.input();
        let a = nl.input_bus(3);
        let b = nl.input_bus(3);
        let m = mux_bus(&mut nl, sel, &a, &b);
        nl.output("m", m);
        // sel=0 -> a (=5), sel=1 -> b (=2)
        let mut inputs = vec![false];
        inputs.extend(to_bits(5, 3));
        inputs.extend(to_bits(2, 3));
        assert_eq!(bus_value(&eval(&nl, &inputs)["m"]), 5);
        inputs[0] = true;
        assert_eq!(bus_value(&eval(&nl, &inputs)["m"]), 2);
    }

    #[test]
    fn param_masked_matches_mask_semantics() {
        // Instantiating the param-guarded bus must equal masking by the
        // same bits, for every mask value.
        use crate::netlist::Template;
        use crate::util::BitVec;
        let w = 3usize;
        let mut nl = Netlist::new();
        let a = nl.input_bus(w as u32);
        let mut next = 0u32;
        let guarded = param_masked(&mut nl, &a, &mut next);
        assert_eq!(next, w as u32);
        nl.output("g", guarded);
        let tpl = Template::new(nl, w);
        for mask in 0..1u64 << w {
            let params = BitVec::from_bools(
                &(0..w).map(|i| (mask >> i) & 1 == 1).collect::<Vec<_>>(),
            );
            let inst = tpl.instantiate(&params);
            for x in 0..1u64 << w {
                let out = eval(&inst, &to_bits(x, w as u32));
                assert_eq!(bus_value(&out["g"]), x & mask, "x={x} mask={mask}");
            }
        }
    }

    #[test]
    fn shl_is_wiring() {
        let mut nl = Netlist::new();
        let a = nl.input_bus(3);
        let before = nl.cell_count();
        let s = shl(&mut nl, &a, 4);
        assert_eq!(nl.cell_count(), before, "shift must not add cells");
        assert_eq!(s.len(), 7);
    }
}
