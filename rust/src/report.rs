//! Reporting: fixed-width table rendering, normalization against the
//! exact baseline [8], and JSON export of pipeline results — the output
//! side of the framework (what the paper presents as Tables II-V and
//! Figs. 4-5).

use crate::coordinator::PipelineResult;
use crate::egfet::HwReport;
use crate::util::json::Json;

/// Render a fixed-width text table.
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            line.push_str(&format!("{:<width$}  ", cell, width = widths[i]));
        }
        line.trim_end().to_string()
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// `x` formatted as a gain factor ("12.5x") against a reference.
pub fn factor(reference: f64, value: f64) -> String {
    if value <= 0.0 {
        return "inf".to_string();
    }
    format!("{:.1}x", reference / value)
}

/// Compact hardware summary cell.
pub fn hw_cell(hw: &HwReport) -> String {
    format!("{:.3} cm2 / {:.3} mW", hw.area_cm2, hw.power_mw)
}

/// Serialize a pipeline result for downstream tooling.
pub fn result_to_json(r: &PipelineResult) -> Json {
    let designs: Vec<Json> = r
        .designs
        .iter()
        .map(|d| {
            let mut fields = vec![
                ("acc_test_accum", Json::num(d.acc_test_accum)),
                ("acc_test_full", Json::num(d.acc_test_full)),
                ("acc_train", Json::num(d.acc_train)),
                ("area_fa", Json::num(d.area_fa as f64)),
                // The design's full GA objective vector: [loss, cost...]
                // — two entries for fa/area/power, three (loss, area,
                // power) for the joint objective.
                ("objs", Json::arr(d.objs.iter().map(|&v| Json::num(v)).collect())),
            ];
            // "cost" keeps its pre-arity-refactor shape — a scalar — and
            // therefore only exists on single-cost runs; joint-run
            // consumers read the unambiguous "objs" vector instead of a
            // key whose type would have to change under them.
            if d.objs.len() == 2 {
                fields.push(("cost", Json::num(d.objs[1])));
            }
            fields.extend([
                ("area_cm2", Json::num(d.hw_full.area_cm2)),
                ("power_mw", Json::num(d.hw_full.power_mw)),
                ("delay_ms", Json::num(d.hw_full.delay_ms)),
                ("area_cm2_0p6v", Json::num(d.hw_0p6v.area_cm2)),
                ("power_mw_0p6v", Json::num(d.hw_0p6v.power_mw)),
                ("power_source", Json::str(d.power_source.label())),
                (
                    "argmax_avg_bits",
                    Json::num(d.argmax_plan.comparator_stats().0),
                ),
                ("kept_bits", Json::num(d.genome.count_ones() as f64)),
                ("genome_len", Json::num(d.genome.len() as f64)),
            ]);
            Json::obj(fields)
        })
        .collect();
    let mut fields = vec![
        ("dataset", Json::str(&r.cfg.dataset.name)),
        (
            "topology",
            Json::arr(vec![
                Json::num(r.cfg.topology.n_in as f64),
                Json::num(r.cfg.topology.n_hidden as f64),
                Json::num(r.cfg.topology.n_out as f64),
            ]),
        ),
        ("backend", Json::str(r.backend_used)),
        ("objective", Json::str(r.objective.label())),
        ("acc_float_test", Json::num(r.trained.acc_float_test)),
        ("acc_qat_test", Json::num(r.trained.acc_q_test)),
        ("baseline_acc_test", Json::num(r.baseline_acc_test)),
        (
            "qat_hw",
            Json::obj(vec![
                ("area_cm2", Json::num(r.qat_hw.area_cm2)),
                ("power_mw", Json::num(r.qat_hw.power_mw)),
                ("delay_ms", Json::num(r.qat_hw.delay_ms)),
            ]),
        ),
        ("designs", Json::arr(designs)),
        (
            "front",
            // Each member's full objective vector — length 2 for single
            // cost objectives, 3 for the joint area+power front, 4 for
            // area+power+delay (every member meets the --max-delay cap).
            Json::arr(
                r.front
                    .iter()
                    .map(|i| Json::arr(i.objs.iter().map(|&v| Json::num(v)).collect()))
                    .collect(),
            ),
        ),
        (
            "front_hw",
            // Aligned with `front`: measured survivor hardware rolled up
            // warm from the circuit evaluator's parked census state
            // (null per member on non-circuit backends or from-scratch
            // synthesis — nothing is re-synthesized for this field).
            Json::arr(
                r.front_hw
                    .iter()
                    .map(|hw| match hw {
                        Some((area, power, delay)) => Json::obj(vec![
                            ("area_cm2", Json::num(*area)),
                            ("power_mw", Json::num(*power)),
                            ("delay_ms", Json::num(*delay)),
                        ]),
                        None => Json::Null,
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some(hw) = &r.baseline_hw {
        fields.push((
            "baseline_hw",
            Json::obj(vec![
                ("area_cm2", Json::num(hw.area_cm2)),
                ("power_mw", Json::num(hw.power_mw)),
                ("delay_ms", Json::num(hw.delay_ms)),
            ]),
        ));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            "demo",
            &["name", "value"],
            &[
                vec!["cardio".into(), "1.0".into()],
                vec!["breastcancer".into(), "22".into()],
            ],
        );
        assert!(t.contains("== demo =="));
        assert!(t.contains("cardio"));
        // Header padded to the longest cell.
        let lines: Vec<&str> = t.lines().collect();
        let head_idx = lines.iter().position(|l| l.starts_with("name")).unwrap();
        assert!(lines[head_idx].contains("value"));
    }

    #[test]
    fn factor_formatting() {
        assert_eq!(factor(100.0, 10.0), "10.0x");
        assert_eq!(factor(5.0, 2.0), "2.5x");
        assert_eq!(factor(1.0, 0.0), "inf");
    }
}
