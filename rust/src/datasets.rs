//! Synthetic dataset substrate.
//!
//! The paper evaluates on six UCI datasets; this environment has no
//! network access, so we synthesize datasets matched to each UCI set in
//! dimensionality, class count, sample count, class imbalance, and —
//! through the `separation`/`noise`/`nuisance_frac` knobs — achievable
//! classifier accuracy (tuned against the paper's Table III baseline
//! accuracies; see DESIGN.md §3).
//!
//! Generator model: each class owns `clusters_per_class` Gaussian
//! centroids placed on a scaled simplex-like arrangement in the subspace
//! of informative features; samples draw a centroid, add isotropic noise,
//! and are min-max normalized to `[0,1]` exactly like the paper
//! normalizes the UCI features. The split is 70/30 train/test
//! (paper §III-A), stratified, deterministic in the config seed.

use crate::config::DatasetSpec;
use crate::fixedpoint::{quantize_input, INPUT_BITS};
use crate::util::Rng;

/// A dataset in normalized float form.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// Row-major `(n_samples, n_features)`, values in `[0,1]`.
    pub x: Vec<Vec<f64>>,
    pub y: Vec<usize>,
    pub n_classes: usize,
}

/// Train/test split of a [`Dataset`].
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Dataset,
    pub test: Dataset,
}

/// A dataset quantized to unsigned `bits`-bit integer features — the form
/// every hardware model consumes.
#[derive(Clone, Debug)]
pub struct QuantDataset {
    pub x: Vec<Vec<u32>>,
    pub y: Vec<usize>,
    pub n_classes: usize,
    pub bits: u32,
}

impl Dataset {
    pub fn n_samples(&self) -> usize {
        self.y.len()
    }
    pub fn n_features(&self) -> usize {
        self.x.first().map(|r| r.len()).unwrap_or(0)
    }

    /// Quantize features to `bits`-bit unsigned integers (paper: 4).
    pub fn quantize(&self, bits: u32) -> QuantDataset {
        QuantDataset {
            x: self
                .x
                .iter()
                .map(|row| row.iter().map(|&v| quantize_input(v, bits)).collect())
                .collect(),
            y: self.y.clone(),
            n_classes: self.n_classes,
            bits,
        }
    }

    /// Default 4-bit quantization.
    pub fn quantize4(&self) -> QuantDataset {
        self.quantize(INPUT_BITS)
    }
}

impl QuantDataset {
    pub fn n_samples(&self) -> usize {
        self.y.len()
    }
    pub fn n_features(&self) -> usize {
        self.x.first().map(|r| r.len()).unwrap_or(0)
    }
}

/// Generate the synthetic dataset described by `spec`.
pub fn generate(spec: &DatasetSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed.wrapping_mul(0x9E37_79B9).wrapping_add(17));
    let d = spec.n_features;
    let c = spec.n_classes;
    let k = spec.clusters_per_class.max(1);

    // Normalize class weights; zero-weight classes get no samples (the
    // UCI Arrhythmia set genuinely has empty classes).
    let mut weights: Vec<f64> = if spec.class_weights.len() == c {
        spec.class_weights.clone()
    } else {
        vec![1.0; c]
    };
    let wsum: f64 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= wsum;
    }

    // Informative vs nuisance features.
    let n_nuisance = ((d as f64) * spec.nuisance_frac).round() as usize;
    let n_info = d - n_nuisance;

    // Class-cluster centroids in the informative subspace: random unit
    // directions scaled by separation * noise, around a shared origin.
    // The same RNG stream makes the geometry deterministic per seed.
    let radius = spec.separation * spec.noise;
    let mut centroids = vec![vec![vec![0.0f64; n_info]; k]; c];
    for class in centroids.iter_mut() {
        for cluster in class.iter_mut() {
            // Random direction.
            let mut norm = 0.0;
            for v in cluster.iter_mut() {
                *v = rng.normal();
                norm += *v * *v;
            }
            let norm = norm.sqrt().max(1e-9);
            for v in cluster.iter_mut() {
                *v = *v / norm * radius * (0.75 + 0.5 * rng.f64());
            }
        }
    }

    // Per-class sample counts (largest remainder keeps totals exact).
    let mut counts: Vec<usize> = weights
        .iter()
        .map(|&w| (w * spec.n_samples as f64).floor() as usize)
        .collect();
    let mut assigned: usize = counts.iter().sum();
    let mut i = 0;
    while assigned < spec.n_samples {
        if weights[i % c] > 0.0 {
            counts[i % c] += 1;
            assigned += 1;
        }
        i += 1;
    }

    let mut x = Vec::with_capacity(spec.n_samples);
    let mut y = Vec::with_capacity(spec.n_samples);
    for (class, &n) in counts.iter().enumerate() {
        for _ in 0..n {
            let cluster = &centroids[class][rng.below(k)];
            let mut row = Vec::with_capacity(d);
            for f in 0..d {
                let base = if f < n_info { cluster[f] } else { 0.0 };
                row.push(base + spec.noise * rng.normal());
            }
            x.push(row);
            y.push(class);
        }
    }

    // Shuffle sample order.
    let mut order: Vec<usize> = (0..x.len()).collect();
    rng.shuffle(&mut order);
    let x: Vec<Vec<f64>> = order.iter().map(|&i| x[i].clone()).collect();
    let y: Vec<usize> = order.iter().map(|&i| y[i]).collect();

    // Min-max normalize each feature to [0,1] (paper §III-A).
    let mut x = x;
    normalize_minmax(&mut x);

    Dataset { name: spec.name.clone(), x, y, n_classes: c }
}

/// In-place per-feature min-max normalization to `[0,1]`.
pub fn normalize_minmax(x: &mut [Vec<f64>]) {
    if x.is_empty() {
        return;
    }
    let d = x[0].len();
    for f in 0..d {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for row in x.iter() {
            lo = lo.min(row[f]);
            hi = hi.max(row[f]);
        }
        let span = (hi - lo).max(1e-12);
        for row in x.iter_mut() {
            row[f] = (row[f] - lo) / span;
        }
    }
}

/// Stratified 70/30 train/test split, deterministic in `seed`.
pub fn split_70_30(ds: &Dataset, seed: u64) -> Split {
    let mut rng = Rng::new(seed ^ 0x5357_4F52);
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.n_classes];
    for (i, &label) in ds.y.iter().enumerate() {
        by_class[label].push(i);
    }
    let mut train_idx = Vec::new();
    let mut test_idx = Vec::new();
    for idxs in by_class.iter_mut() {
        rng.shuffle(idxs);
        let n_train = (idxs.len() as f64 * 0.7).round() as usize;
        train_idx.extend_from_slice(&idxs[..n_train]);
        test_idx.extend_from_slice(&idxs[n_train..]);
    }
    rng.shuffle(&mut train_idx);
    rng.shuffle(&mut test_idx);
    let take = |idx: &[usize]| Dataset {
        name: ds.name.clone(),
        x: idx.iter().map(|&i| ds.x[i].clone()).collect(),
        y: idx.iter().map(|&i| ds.y[i]).collect(),
        n_classes: ds.n_classes,
    };
    Split { train: take(&train_idx), test: take(&test_idx) }
}

/// Convenience: generate + split + quantize in one call.
pub fn load(spec: &DatasetSpec) -> (Split, QuantDataset, QuantDataset) {
    let ds = generate(spec);
    let split = split_70_30(&ds, spec.seed);
    let qtrain = split.train.quantize4();
    let qtest = split.test.quantize4();
    (split, qtrain, qtest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;

    #[test]
    fn shapes_match_spec() {
        let cfg = builtin::cardio();
        let ds = generate(&cfg.dataset);
        assert_eq!(ds.n_samples(), 2126);
        assert_eq!(ds.n_features(), 21);
        assert_eq!(ds.n_classes, 3);
    }

    #[test]
    fn values_normalized() {
        let ds = generate(&builtin::tiny().dataset);
        for row in &ds.x {
            for &v in row {
                assert!((0.0..=1.0).contains(&v), "v={v}");
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let spec = builtin::tiny().dataset;
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn different_seed_differs() {
        let mut spec = builtin::tiny().dataset;
        let a = generate(&spec);
        spec.seed += 1;
        let b = generate(&spec);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn class_imbalance_respected() {
        let spec = builtin::cardio().dataset; // 78/14/8
        let ds = generate(&spec);
        let mut counts = vec![0usize; 3];
        for &label in &ds.y {
            counts[label] += 1;
        }
        let frac0 = counts[0] as f64 / ds.n_samples() as f64;
        assert!((frac0 - 0.78).abs() < 0.02, "frac0={frac0}");
    }

    #[test]
    fn split_is_70_30_and_stratified() {
        let ds = generate(&builtin::pendigits().dataset);
        let split = split_70_30(&ds, 1);
        let total = ds.n_samples() as f64;
        let tf = split.train.n_samples() as f64 / total;
        assert!((tf - 0.7).abs() < 0.02, "train frac {tf}");
        // Stratification: every class present in both splits.
        for class in 0..ds.n_classes {
            assert!(split.train.y.iter().any(|&y| y == class));
            assert!(split.test.y.iter().any(|&y| y == class));
        }
        // No overlap in size bookkeeping.
        assert_eq!(split.train.n_samples() + split.test.n_samples(), ds.n_samples());
    }

    #[test]
    fn quantization_is_4bit() {
        let ds = generate(&builtin::tiny().dataset);
        let q = ds.quantize4();
        assert_eq!(q.bits, 4);
        for row in &q.x {
            for &v in row {
                assert!(v <= 15);
            }
        }
    }

    #[test]
    fn arrhythmia_scale() {
        let spec = builtin::arrhythmia().dataset;
        let ds = generate(&spec);
        assert_eq!(ds.n_features(), 274);
        assert_eq!(ds.n_classes, 16);
        assert_eq!(ds.n_samples(), 452);
        // Empty classes allowed (class weights include zeros).
        let mut counts = vec![0usize; 16];
        for &label in &ds.y {
            counts[label] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 452);
        assert!(counts[0] > 200, "dominant class should dominate: {counts:?}");
    }

    #[test]
    fn separable_dataset_is_linearly_separable_enough() {
        // With high separation a nearest-centroid rule on the train split
        // should beat 85% on tiny — guards the generator's signal path.
        let (split, _, _) = load(&builtin::tiny().dataset);
        let train = &split.train;
        let d = train.n_features();
        let mut centroids = vec![vec![0.0; d]; train.n_classes];
        let mut counts = vec![0usize; train.n_classes];
        for (row, &label) in train.x.iter().zip(&train.y) {
            for f in 0..d {
                centroids[label][f] += row[f];
            }
            counts[label] += 1;
        }
        for (cent, &n) in centroids.iter_mut().zip(&counts) {
            for v in cent.iter_mut() {
                *v /= n.max(1) as f64;
            }
        }
        let test = &split.test;
        let mut correct = 0;
        for (row, &label) in test.x.iter().zip(&test.y) {
            let mut best = 0;
            let mut bestd = f64::INFINITY;
            for (cl, cent) in centroids.iter().enumerate() {
                let dist: f64 =
                    row.iter().zip(cent).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < bestd {
                    bestd = dist;
                    best = cl;
                }
            }
            if best == label {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.n_samples() as f64;
        assert!(acc > 0.85, "nearest-centroid acc {acc}");
    }
}
