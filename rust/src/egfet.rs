//! Printed EGFET (Electrolyte-Gated FET) technology library and hardware
//! analysis — the substitute for the paper's EGFET PDK [2] + Synopsys
//! PrimeTime flow (see DESIGN.md §3).
//!
//! Printed EGFET circuits have feature sizes of several microns, gate
//! delays in the millisecond range, and per-gate power in the µW range
//! (ring-oscillator measurements in the EGFET literature [5], [30]); cell
//! areas are in the 10⁻³–10⁻² cm² range, which is why even a 3-neuron
//! MLP occupies tens of cm² (paper Table III). The library below uses
//! conventional relative cell sizes (INV < NAND < XOR < MUX) with
//! absolute constants calibrated once so the exact bespoke baselines land
//! at the scale of Table III, then frozen for every experiment.
//!
//! Two corners are provided, matching the paper's methodology:
//! * `1.0 V` — the main evaluation corner (§IV-A/B);
//! * `0.6 V` — the battery study corner (§IV-C): ~72% lower power,
//!   ~2.6× slower. If a design misses timing at 0.6 V it is re-mapped
//!   with upsized cells (larger, faster, roughly half the 1 V power) —
//!   reproducing the paper's Pendigits re-synthesis narrative.

use crate::netlist::{CellCounts, Gate, Netlist};

/// Per-cell physical characteristics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cell {
    pub area_cm2: f64,
    /// Total (static + average dynamic at nominal activity) power, µW.
    pub power_uw: f64,
    pub delay_ms: f64,
}

/// A technology corner: one [`Cell`] per gate kind.
#[derive(Clone, Debug)]
pub struct Library {
    pub name: String,
    pub vdd: f64,
    pub not: Cell,
    pub and: Cell,
    pub or: Cell,
    pub xor: Cell,
    pub nand: Cell,
    pub nor: Cell,
    pub xnor: Cell,
    pub mux: Cell,
}

/// Base NAND2-equivalent constants at 1 V. Calibrated against the
/// paper's Table III baseline rows (Cardio exact bespoke ≈ 33 cm² /
/// 124 mW at a 200 ms clock) and then FROZEN — every experiment uses the
/// same constants, so all relative results are calibration-free.
const NAND2_AREA_CM2: f64 = 0.00383;
const NAND2_POWER_UW: f64 = 15.3;
const NAND2_DELAY_MS: f64 = 0.65;

impl Library {
    fn scaled(name: &str, vdd: f64, area_k: f64, power_k: f64, delay_k: f64) -> Library {
        let mk = |a: f64, p: f64, d: f64| Cell {
            area_cm2: NAND2_AREA_CM2 * a * area_k,
            power_uw: NAND2_POWER_UW * p * power_k,
            delay_ms: NAND2_DELAY_MS * d * delay_k,
        };
        Library {
            name: name.to_string(),
            vdd,
            // Relative sizes follow conventional standard-cell ratios.
            not: mk(0.67, 0.6, 0.6),
            and: mk(1.33, 1.2, 1.2),
            or: mk(1.33, 1.2, 1.2),
            xor: mk(2.0, 1.9, 1.6),
            nand: mk(1.0, 1.0, 1.0),
            nor: mk(1.0, 1.0, 1.0),
            xnor: mk(2.0, 1.9, 1.6),
            mux: mk(2.33, 2.1, 1.8),
        }
    }

    /// The 1 V evaluation corner.
    pub fn egfet_1v() -> Library {
        Library::scaled("EGFET 1.0V", 1.0, 1.0, 1.0, 1.0)
    }

    /// The 0.6 V battery corner: power ≈ 0.28× (V² plus leakage
    /// reduction), delay ≈ 2.6×, same cell footprints.
    pub fn egfet_0p6v() -> Library {
        Library::scaled("EGFET 0.6V", 0.6, 1.0, 0.28, 2.6)
    }

    /// The 0.6 V corner with upsized (higher-drive) cells: ≈1.45× area,
    /// delay ≈ 1.55× of 1 V, power ≈ 0.5× of 1 V.
    pub fn egfet_0p6v_upsized() -> Library {
        Library::scaled("EGFET 0.6V upsized", 0.6, 1.45, 0.5, 1.55)
    }

    /// The library cell implementing `g`, if `g` is a cell at all
    /// (`Input`/`Const`/`Param` leaves occupy no silicon).
    pub fn cell(&self, g: &Gate) -> Option<&Cell> {
        match g {
            Gate::Not(_) => Some(&self.not),
            Gate::And(..) => Some(&self.and),
            Gate::Or(..) => Some(&self.or),
            Gate::Xor(..) => Some(&self.xor),
            Gate::Nand(..) => Some(&self.nand),
            Gate::Nor(..) => Some(&self.nor),
            Gate::Xnor(..) => Some(&self.xnor),
            Gate::Mux(..) => Some(&self.mux),
            Gate::Input(_) | Gate::Const(_) | Gate::Param(_) => None,
        }
    }

    /// Propagation delay of `g` in this corner (0 for non-cell leaves).
    /// The per-gate term of the arrival-time recurrence — shared by
    /// [`arrival_times`] and the incremental engine's arena-aligned
    /// arrival table (`crate::synth::incremental`), so the two delay
    /// models can never drift.
    pub fn delay_of(&self, g: &Gate) -> f64 {
        self.cell(g).map_or(0.0, |c| c.delay_ms)
    }
}

/// Which cost(s) the GA minimizes next to the accuracy loss
/// (`pmlp run --objective fa|area|power|delay|area+power|area+power+delay`).
///
/// `fa` is the paper's full-adder surrogate ([`crate::area::AreaModel`]) —
/// the default, and the only choice the native/PJRT backends support
/// (their fronts stay unit-compatible across backends). The measured
/// objectives require `--backend circuit`: every chromosome is
/// synthesized anyway, so the evaluator can score it on the EGFET
/// [`Library`] roll-up of its actual survivor netlist
/// ([`analyze_histogram`]) instead of the surrogate — area in cm²,
/// dynamic power in mW under the train-set stimulus's measured toggle
/// activity (the quantity the paper's NSGA-II actually selects on), or
/// the survivor's critical-path delay in ms ([`critical_path_ms`],
/// maintained incrementally as per-node arrival times in the synthesis
/// arena — `crate::synth::incremental`). `area+power` is the joint mode
/// (3-D front, `M = 3`); `area+power+delay` adds timing closure as the
/// fourth axis ([`crate::ga::Nsga2`] at `M = 4`), usually together with
/// the `--max-delay` hard constraint defaulting to the dataset's
/// `HwSpec.clock_ms` budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostObjective {
    /// Full-adder surrogate count (unitless; backend-portable).
    Fa,
    /// Measured EGFET cell area of the synthesized survivor, cm².
    Area,
    /// Measured power of the synthesized survivor, mW, with the dynamic
    /// share scaled by wave-measured toggle activity.
    Power,
    /// Measured critical-path delay of the synthesized survivor, ms —
    /// the longest register-free path through the EGFET cells, a max
    /// over paths rather than a sum over cells.
    Delay,
    /// Joint measured area *and* power — both axes of one
    /// [`analyze_histogram`] roll-up, optimized as a 3-D Pareto front.
    AreaPower,
    /// Joint measured area, power *and* delay — the timing-closure mode:
    /// a 4-D (loss, area, power, delay) front where the delay axis falls
    /// out of the incremental arena's live-output arrival max.
    AreaPowerDelay,
}

/// The canonical `--objective` / `PMLP_OBJECTIVE` option list — the one
/// source of truth every parse error, panic, and help text derives from
/// (CLI `--objective`, the bench harnesses' env readers, `pmlp serve`
/// request validation). Adding a variant means updating
/// [`CostObjective::parse_detailed`] and this string together; the label
/// round-trip test pins them against each other.
pub const OBJECTIVE_OPTIONS: &str = "fa|area|power|delay|area+power|area+power+delay";

impl CostObjective {
    /// Parse an objective name. Compound objectives are order- and
    /// case-insensitive (`power+area`, `AREA+POWER+DELAY`), so env-var
    /// driven harnesses can't silently fall back to the default over a
    /// spelling that names the right axes. Thin wrapper over
    /// [`CostObjective::parse_detailed`] for callers that only need the
    /// yes/no answer.
    pub fn parse(s: &str) -> Option<CostObjective> {
        CostObjective::parse_detailed(s).ok()
    }

    /// Parse an objective name with a structured diagnostic: the error
    /// names the offending `+`-segment — empty (`area++power`), unknown
    /// (`area+watts`), or duplicated (`area+area`) — or the unsupported
    /// axis combination, and always carries [`OBJECTIVE_OPTIONS`].
    pub fn parse_detailed(s: &str) -> Result<CostObjective, String> {
        let lower = s.to_lowercase();
        let mut parts: Vec<&str> = lower.split('+').map(str::trim).collect();
        for part in &parts {
            if part.is_empty() {
                return Err(format!(
                    "empty axis segment in '{s}' (expected {OBJECTIVE_OPTIONS})"
                ));
            }
            if !matches!(*part, "fa" | "area" | "power" | "delay") {
                return Err(format!(
                    "unknown axis '{part}' in '{s}' (expected {OBJECTIVE_OPTIONS})"
                ));
            }
        }
        parts.sort_unstable();
        if let Some(w) = parts.windows(2).find(|w| w[0] == w[1]) {
            return Err(format!(
                "duplicate axis '{}' in '{s}' (expected {OBJECTIVE_OPTIONS})",
                w[0]
            ));
        }
        match parts.as_slice() {
            ["fa"] => Ok(CostObjective::Fa),
            ["area"] => Ok(CostObjective::Area),
            ["power"] => Ok(CostObjective::Power),
            ["delay"] => Ok(CostObjective::Delay),
            ["area", "power"] => Ok(CostObjective::AreaPower),
            ["area", "delay", "power"] => Ok(CostObjective::AreaPowerDelay),
            _ => Err(format!(
                "unsupported axis combination '{s}' (expected {OBJECTIVE_OPTIONS})"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CostObjective::Fa => "fa",
            CostObjective::Area => "area",
            CostObjective::Power => "power",
            CostObjective::Delay => "delay",
            CostObjective::AreaPower => "area+power",
            CostObjective::AreaPowerDelay => "area+power+delay",
        }
    }

    /// True for the objectives measured on the synthesized survivor
    /// (which only the circuit backend can provide).
    pub fn is_measured(&self) -> bool {
        !matches!(self, CostObjective::Fa)
    }

    /// Total GA objective arity: the accuracy-loss axis plus this
    /// objective's cost axes. This is the `M` the const-generic
    /// [`crate::ga::Nsga2`] must be instantiated with.
    pub fn arity(&self) -> usize {
        match self {
            CostObjective::AreaPowerDelay => 4,
            CostObjective::AreaPower => 3,
            _ => 2,
        }
    }

    /// True when scoring needs a toggle-activity factor (any objective
    /// with a power axis; area and delay are activity-independent).
    pub fn needs_activity(&self) -> bool {
        matches!(
            self,
            CostObjective::Power | CostObjective::AreaPower | CostObjective::AreaPowerDelay
        )
    }

    /// The objective-vector index of the delay axis, if this objective
    /// scores one — where the `--max-delay` hard constraint applies.
    pub fn delay_axis(&self) -> Option<usize> {
        match self {
            CostObjective::Delay => Some(1),
            CostObjective::AreaPowerDelay => Some(3),
            _ => None,
        }
    }
}

/// Result of the hardware analysis of one synthesized netlist.
#[derive(Clone, Debug)]
pub struct HwReport {
    pub area_cm2: f64,
    pub power_mw: f64,
    /// Critical-path delay, ms.
    pub delay_ms: f64,
    pub cells: usize,
    pub cell_counts: CellCounts,
    /// True if `delay_ms <= clock_ms`.
    pub meets_timing: bool,
    pub clock_ms: f64,
    pub library: String,
}

/// Analyze a (synthesized) netlist against a library and clock period.
///
/// `activity` is the average toggle activity per cell (from
/// [`crate::sim::toggle_activity`]); it scales the dynamic share (~55%)
/// of the per-cell power around the nominal activity of 0.25.
pub fn analyze(nl: &Netlist, lib: &Library, clock_ms: f64, activity: f64) -> HwReport {
    let mut area = 0.0f64;
    let mut power_uw = 0.0f64;
    let act_scale = activity_scale(activity);
    for g in nl.gates.iter() {
        if let Some(cell) = lib.cell(g) {
            area += cell.area_cm2;
            power_uw += cell.power_uw * act_scale;
        }
    }
    let delay_ms = critical_path_ms(nl, lib);
    HwReport {
        area_cm2: area,
        power_mw: power_uw / 1000.0,
        delay_ms,
        cells: nl.cell_count(),
        cell_counts: nl.cell_histogram(),
        meets_timing: delay_ms <= clock_ms,
        clock_ms,
        library: lib.name.clone(),
    }
}

/// Per-node arrival times of a netlist under a library: the longest-path
/// recurrence `arrival[i] = max over operands + cell delay` in node (=
/// topological) order; non-cell leaves arrive at 0. This is THE delay
/// model of the framework — [`analyze`], [`critical_path_ms`] and the
/// incremental engine's arena-aligned arrival table
/// (`crate::synth::incremental`) all compute exactly this recurrence,
/// which is what makes the GA's incremental delay axis bit-identical to
/// the from-scratch analysis (pinned by the oracle suites).
pub fn arrival_times(nl: &Netlist, lib: &Library) -> Vec<f64> {
    let mut arrival = vec![0.0f64; nl.gates.len()];
    for (i, g) in nl.gates.iter().enumerate() {
        if let Some(cell) = lib.cell(g) {
            let in_arrival =
                g.operands().map(|o| arrival[o as usize]).fold(0.0f64, f64::max);
            arrival[i] = in_arrival + cell.delay_ms;
        }
    }
    arrival
}

/// Critical-path delay of a netlist (ms): the max [`arrival_times`]
/// entry over every declared output node.
pub fn critical_path_ms(nl: &Netlist, lib: &Library) -> f64 {
    let arrival = arrival_times(nl, lib);
    nl.outputs
        .iter()
        .flat_map(|(_, bus)| bus.iter())
        .map(|&n| arrival[n as usize])
        .fold(0.0f64, f64::max)
}

/// Scale factor applied to each cell's nominal power: the dynamic share
/// (~55%) grows linearly with toggle activity around the nominal 0.25.
/// Shared by [`analyze`] and [`analyze_histogram`] so the two power
/// models can never drift.
fn activity_scale(activity: f64) -> f64 {
    let dyn_share = 0.55;
    1.0 - dyn_share + dyn_share * (activity / 0.25).min(4.0)
}

/// Allocation-free area/power roll-up over a survivor **cell histogram**
/// — the measured-objective core of the circuit-in-the-loop GA. Returns
/// `(area_cm2, power_mw)`.
///
/// Computes the same sums as [`analyze`] grouped by cell kind instead of
/// walking the netlist (and skips the timing pass), so the evaluator can
/// score a chromosome from the incremental synthesizer's survivor census
/// without materializing the netlist. Values agree with [`analyze`] up
/// to floating-point summation order (grouped-by-kind here vs gate order
/// there — last-ulp differences only; pinned at 1e-9 relative by tests).
pub fn analyze_histogram(counts: &CellCounts, lib: &Library, activity: f64) -> (f64, f64) {
    let act_scale = activity_scale(activity);
    let mut area = 0.0f64;
    let mut power_uw = 0.0f64;
    for (n, cell) in [
        (counts.not, &lib.not),
        (counts.and, &lib.and),
        (counts.or, &lib.or),
        (counts.xor, &lib.xor),
        (counts.nand, &lib.nand),
        (counts.nor, &lib.nor),
        (counts.xnor, &lib.xnor),
        (counts.mux, &lib.mux),
    ] {
        area += n as f64 * cell.area_cm2;
        power_uw += n as f64 * cell.power_uw * act_scale;
    }
    (area, power_uw / 1000.0)
}

/// Analyze at 0.6 V with the paper's Table V policy: try the low-power
/// 0.6 V mapping; if timing fails, re-map with upsized cells (larger
/// area, roughly half the 1 V power — the Pendigits case).
pub fn analyze_0p6v(nl: &Netlist, clock_ms: f64, activity: f64) -> HwReport {
    let low = analyze(nl, &Library::egfet_0p6v(), clock_ms, activity);
    if low.meets_timing {
        return low;
    }
    analyze(nl, &Library::egfet_0p6v_upsized(), clock_ms, activity)
}

/// Nominal activity factor assumed when no vectors are simulated.
pub const NOMINAL_ACTIVITY: f64 = 0.25;

/// Toggle activity of a netlist under a concrete stimulus, via the
/// bit-parallel wave simulator; falls back to [`NOMINAL_ACTIVITY`] when
/// fewer than two vectors are supplied (activity needs transitions).
pub fn measured_activity(nl: &Netlist, vectors: &[Vec<bool>]) -> f64 {
    if vectors.len() < 2 {
        return NOMINAL_ACTIVITY;
    }
    crate::sim::toggle_activity(nl, vectors)
}

/// [`analyze`] with the activity factor *measured* by wave-simulating
/// `vectors` (the paper's VCS-reported switching activity step) instead
/// of the nominal constant.
pub fn analyze_measured(
    nl: &Netlist,
    lib: &Library,
    clock_ms: f64,
    vectors: &[Vec<bool>],
) -> HwReport {
    analyze(nl, lib, clock_ms, measured_activity(nl, vectors))
}

/// [`analyze_0p6v`] driven by measured toggle activity.
pub fn analyze_0p6v_measured(nl: &Netlist, clock_ms: f64, vectors: &[Vec<bool>]) -> HwReport {
    analyze_0p6v(nl, clock_ms, measured_activity(nl, vectors))
}

/// Printed power sources of the paper's Table V narrative.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PowerSource {
    /// Printed energy harvester (sub-mW).
    Harvester,
    /// Blue Spark printed battery, 3 mW.
    BlueSpark3mW,
    /// Molex printed battery, 30 mW.
    Molex30mW,
    /// No printed source can power this circuit.
    None,
}

impl PowerSource {
    pub fn budget_mw(self) -> f64 {
        match self {
            PowerSource::Harvester => 0.1,
            PowerSource::BlueSpark3mW => 3.0,
            PowerSource::Molex30mW => 30.0,
            PowerSource::None => f64::INFINITY,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PowerSource::Harvester => "energy harvester",
            PowerSource::BlueSpark3mW => "Blue Spark 3mW",
            PowerSource::Molex30mW => "Molex 30mW",
            PowerSource::None => "none (wall power)",
        }
    }
}

/// Smallest printed power source able to supply `power_mw`.
pub fn classify_power_source(power_mw: f64) -> PowerSource {
    if power_mw <= PowerSource::Harvester.budget_mw() {
        PowerSource::Harvester
    } else if power_mw <= PowerSource::BlueSpark3mW.budget_mw() {
        PowerSource::BlueSpark3mW
    } else if power_mw <= PowerSource::Molex30mW.budget_mw() {
        PowerSource::Molex30mW
    } else {
        PowerSource::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::Netlist;

    fn small_netlist() -> Netlist {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let c = nl.and(a, b);
        let d = nl.xor(c, b);
        let e = nl.not(d);
        nl.output("y", vec![e]);
        nl
    }

    #[test]
    fn area_power_positive_and_additive() {
        let nl = small_netlist();
        let lib = Library::egfet_1v();
        let r = analyze(&nl, &lib, 200.0, 0.25);
        assert!(r.area_cm2 > 0.0);
        assert!(r.power_mw > 0.0);
        let expect_area = lib.and.area_cm2 + lib.xor.area_cm2 + lib.not.area_cm2;
        assert!((r.area_cm2 - expect_area).abs() < 1e-12);
        assert_eq!(r.cells, 3);
    }

    #[test]
    fn delay_is_critical_path() {
        let nl = small_netlist();
        let lib = Library::egfet_1v();
        let r = analyze(&nl, &lib, 200.0, 0.25);
        let expect = lib.and.delay_ms + lib.xor.delay_ms + lib.not.delay_ms;
        assert!((r.delay_ms - expect).abs() < 1e-12);
        assert!(r.meets_timing);
    }

    #[test]
    fn voltage_scaling_direction() {
        let nl = small_netlist();
        let r1 = analyze(&nl, &Library::egfet_1v(), 200.0, 0.25);
        let r06 = analyze(&nl, &Library::egfet_0p6v(), 200.0, 0.25);
        assert!(r06.power_mw < r1.power_mw * 0.4);
        assert!(r06.delay_ms > r1.delay_ms * 2.0);
        assert!((r06.area_cm2 - r1.area_cm2).abs() < 1e-12);
    }

    #[test]
    fn upsized_trades_area_for_speed() {
        let nl = small_netlist();
        let low = analyze(&nl, &Library::egfet_0p6v(), 200.0, 0.25);
        let up = analyze(&nl, &Library::egfet_0p6v_upsized(), 200.0, 0.25);
        assert!(up.area_cm2 > low.area_cm2);
        assert!(up.delay_ms < low.delay_ms);
        assert!(up.power_mw > low.power_mw);
    }

    #[test]
    fn analyze_0p6v_falls_back_to_upsized() {
        // A deep chain that misses a tight clock at plain 0.6 V.
        let mut nl = Netlist::new();
        let a = nl.input();
        let mut cur = a;
        for _ in 0..100 {
            cur = nl.not(cur);
        }
        nl.output("y", vec![cur]);
        let plain = analyze(&nl, &Library::egfet_0p6v(), 1.0, 0.25);
        assert!(!plain.meets_timing);
        let chosen = analyze_0p6v(&nl, 1.0, 0.25);
        assert_eq!(chosen.library, "EGFET 0.6V upsized");
    }

    #[test]
    fn power_source_classification() {
        assert_eq!(classify_power_source(0.05), PowerSource::Harvester);
        assert_eq!(classify_power_source(1.5), PowerSource::BlueSpark3mW);
        assert_eq!(classify_power_source(25.0), PowerSource::Molex30mW);
        assert_eq!(classify_power_source(100.0), PowerSource::None);
    }

    #[test]
    fn activity_scales_power() {
        let nl = small_netlist();
        let lib = Library::egfet_1v();
        let quiet = analyze(&nl, &lib, 200.0, 0.0);
        let busy = analyze(&nl, &lib, 200.0, 0.5);
        assert!(busy.power_mw > quiet.power_mw);
    }

    #[test]
    fn histogram_rollup_matches_full_analysis() {
        // Same sums, grouped by kind: the roll-up must agree with the
        // netlist walk to float summation order on both corners and
        // across activity factors.
        let nl = small_netlist();
        let hist = nl.cell_histogram();
        for lib in [Library::egfet_1v(), Library::egfet_0p6v(), Library::egfet_0p6v_upsized()] {
            for act in [0.0, 0.25, 0.5, 1.5] {
                let full = analyze(&nl, &lib, 200.0, act);
                let (area, power) = analyze_histogram(&hist, &lib, act);
                assert!(
                    (area - full.area_cm2).abs() <= 1e-12 * full.area_cm2.max(1.0),
                    "area {} vs {}",
                    area,
                    full.area_cm2
                );
                assert!(
                    (power - full.power_mw).abs() <= 1e-12 * full.power_mw.max(1.0),
                    "power {} vs {}",
                    power,
                    full.power_mw
                );
            }
        }
    }

    #[test]
    fn histogram_rollup_empty_is_zero() {
        let (area, power) =
            analyze_histogram(&Default::default(), &Library::egfet_1v(), 0.25);
        assert_eq!(area, 0.0);
        assert_eq!(power, 0.0);
    }

    #[test]
    fn cost_objective_parsing() {
        assert_eq!(CostObjective::parse("fa"), Some(CostObjective::Fa));
        assert_eq!(CostObjective::parse("AREA"), Some(CostObjective::Area));
        assert_eq!(CostObjective::parse("power"), Some(CostObjective::Power));
        assert_eq!(CostObjective::parse("delay"), Some(CostObjective::Delay));
        assert_eq!(CostObjective::parse("area+power"), Some(CostObjective::AreaPower));
        assert_eq!(CostObjective::parse("Area+Power"), Some(CostObjective::AreaPower));
        // Compound objectives are order- and case-insensitive.
        assert_eq!(CostObjective::parse("power+area"), Some(CostObjective::AreaPower));
        assert_eq!(
            CostObjective::parse("area+power+delay"),
            Some(CostObjective::AreaPowerDelay)
        );
        assert_eq!(
            CostObjective::parse("delay+power+area"),
            Some(CostObjective::AreaPowerDelay)
        );
        assert_eq!(
            CostObjective::parse("AREA+POWER+DELAY"),
            Some(CostObjective::AreaPowerDelay)
        );
        assert_eq!(CostObjective::parse("watts"), None);
        assert_eq!(CostObjective::parse("area+delay"), None);
        assert_eq!(CostObjective::parse("area+area"), None);
        assert_eq!(CostObjective::parse("fa+power"), None);
        assert!(!CostObjective::Fa.is_measured());
        assert!(CostObjective::Area.is_measured());
        assert!(CostObjective::Power.is_measured());
        assert!(CostObjective::Delay.is_measured());
        assert!(CostObjective::AreaPower.is_measured());
        assert!(CostObjective::AreaPowerDelay.is_measured());
        assert_eq!(CostObjective::Power.label(), "power");
        assert_eq!(CostObjective::AreaPower.label(), "area+power");
        assert_eq!(CostObjective::AreaPowerDelay.label(), "area+power+delay");
        // Round trip: every label parses back to its own variant, and
        // appears verbatim in the canonical option list.
        for o in [
            CostObjective::Fa,
            CostObjective::Area,
            CostObjective::Power,
            CostObjective::Delay,
            CostObjective::AreaPower,
            CostObjective::AreaPowerDelay,
        ] {
            assert_eq!(CostObjective::parse(o.label()), Some(o), "{o:?}");
            assert!(
                OBJECTIVE_OPTIONS.split('|').any(|opt| opt == o.label()),
                "{o:?} label missing from OBJECTIVE_OPTIONS"
            );
        }
    }

    #[test]
    fn cost_objective_parse_diagnostics() {
        let err = |s: &str| CostObjective::parse_detailed(s).unwrap_err();
        // Every diagnostic names the offending segment and the canonical
        // option list, so no env/CLI consumer ever reports a bare "no".
        let e = err("area+area");
        assert!(e.contains("duplicate axis 'area'"), "{e}");
        let e = err("area++power");
        assert!(e.contains("empty axis segment"), "{e}");
        let e = err("");
        assert!(e.contains("empty axis segment"), "{e}");
        let e = err("area+watts");
        assert!(e.contains("unknown axis 'watts'"), "{e}");
        let e = err("fa+power");
        assert!(e.contains("unsupported axis combination 'fa+power'"), "{e}");
        let e = err("area+delay");
        assert!(e.contains("unsupported axis combination"), "{e}");
        for s in ["area+area", "area++power", "watts", "fa+power", ""] {
            assert!(err(s).contains(OBJECTIVE_OPTIONS), "option list missing for '{s}'");
        }
        // Case/order insensitivity holds on the detailed surface too.
        assert_eq!(
            CostObjective::parse_detailed("Delay+POWER+area"),
            Ok(CostObjective::AreaPowerDelay)
        );
        // Duplicates are reported case-insensitively.
        let e = err("Area+AREA");
        assert!(e.contains("duplicate axis 'area'"), "{e}");
    }

    #[test]
    fn cost_objective_arity_and_activity() {
        for o in [
            CostObjective::Fa,
            CostObjective::Area,
            CostObjective::Power,
            CostObjective::Delay,
        ] {
            assert_eq!(o.arity(), 2, "{o:?}");
        }
        assert_eq!(CostObjective::AreaPower.arity(), 3);
        assert_eq!(CostObjective::AreaPowerDelay.arity(), 4);
        assert!(!CostObjective::Fa.needs_activity());
        assert!(!CostObjective::Area.needs_activity());
        assert!(!CostObjective::Delay.needs_activity());
        assert!(CostObjective::Power.needs_activity());
        assert!(CostObjective::AreaPower.needs_activity());
        assert!(CostObjective::AreaPowerDelay.needs_activity());
        assert_eq!(CostObjective::Fa.delay_axis(), None);
        assert_eq!(CostObjective::AreaPower.delay_axis(), None);
        assert_eq!(CostObjective::Delay.delay_axis(), Some(1));
        assert_eq!(CostObjective::AreaPowerDelay.delay_axis(), Some(3));
    }

    #[test]
    fn critical_path_matches_analyze() {
        let nl = small_netlist();
        for lib in [Library::egfet_1v(), Library::egfet_0p6v(), Library::egfet_0p6v_upsized()] {
            let r = analyze(&nl, &lib, 200.0, 0.25);
            assert_eq!(critical_path_ms(&nl, &lib), r.delay_ms, "{}", lib.name);
            // The arrival table itself obeys the longest-path recurrence.
            let arr = arrival_times(&nl, &lib);
            for (i, g) in nl.gates.iter().enumerate() {
                match lib.cell(g) {
                    None => assert_eq!(arr[i], 0.0),
                    Some(cell) => {
                        let want = g
                            .operands()
                            .map(|o| arr[o as usize])
                            .fold(0.0f64, f64::max)
                            + cell.delay_ms;
                        assert_eq!(arr[i], want, "node {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn measured_activity_uses_wave_sim() {
        let nl = small_netlist();
        // Constant stimulus -> zero activity; fewer than 2 vectors -> the
        // nominal fallback.
        let quiet = vec![vec![true, true]; 8];
        assert_eq!(measured_activity(&nl, &quiet), 0.0);
        assert_eq!(measured_activity(&nl, &[]), NOMINAL_ACTIVITY);
        // Alternating stimulus toggles cells, and the measured report
        // burns more power than the quiet one.
        let busy: Vec<Vec<bool>> =
            (0..8).map(|i| vec![i % 2 == 0, i % 3 == 0]).collect();
        let lib = Library::egfet_1v();
        let r_busy = analyze_measured(&nl, &lib, 200.0, &busy);
        let r_quiet = analyze_measured(&nl, &lib, 200.0, &quiet);
        assert!(r_busy.power_mw > r_quiet.power_mw);
    }
}
