//! Experiment harnesses: one function per table/figure of the paper's
//! evaluation section, shared by the `pmlp repro` CLI and the
//! `benches/*.rs` targets (criterion is not vendored; benches are
//! `harness = false` binaries that call into this module and self-time).
//!
//! Every harness prints the same rows the paper reports, next to the
//! paper's reference numbers where they exist, so shape comparisons are
//! immediate (EXPERIMENTS.md records paper-vs-measured for each).

use crate::accum::GenomeMap;
use crate::area::AreaModel;
use crate::baselines::exact::Int8Mlp;
use crate::baselines::prune;
use crate::baselines::truncation::TruncMlp;
use crate::config::{builtin, RunConfig};
use crate::coordinator::{EvalBackend, FrontPoint, Pipeline, PipelineOpts, PipelineResult};
use crate::datasets;
use crate::egfet::{analyze, CostObjective, Library};
use crate::model::QuantMlp;
use crate::netlist::mlp::{build_mlp_circuit, ArgmaxMode, MlpCircuitOpts};
use crate::report::render_table;
use crate::sc::ScMlp;
use crate::sim::wave::LaneWidth;
use crate::synth::optimize;
use crate::train;
use crate::util::json::Json;
use crate::util::stats::{mean, spearman};
use crate::util::{threads, Rng};
// detlint: allow-file(std-hash) — study memo keyed by config label, point
// lookups only. allow-file(wallclock) — this module IS the timing harness;
// wall-clock readings land in reports, never in scored results.
use std::collections::HashMap;

/// Experiment scale: how close to the paper's settings a run is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// CI-sized (tiny dataset only, minimal GA) — seconds.
    Smoke,
    /// All six MLPs with a scaled-down GA — minutes. The default.
    Small,
    /// The paper's settings (population 1000, 30 generations).
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s.to_lowercase().as_str() {
            "smoke" => Some(Scale::Smoke),
            "small" => Some(Scale::Small),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    pub fn dataset_names(self) -> Vec<&'static str> {
        match self {
            Scale::Smoke => vec!["tiny"],
            _ => builtin::paper_names(),
        }
    }

    fn ga_population(self) -> usize {
        match self {
            Scale::Smoke => 24,
            Scale::Small => 120,
            Scale::Paper => 1000,
        }
    }

    fn ga_generations(self) -> usize {
        match self {
            Scale::Smoke => 3,
            Scale::Small => 16,
            Scale::Paper => 30,
        }
    }

    fn table2_chromosomes(self) -> usize {
        match self {
            Scale::Smoke => 24,
            Scale::Small => 150,
            Scale::Paper => 1000,
        }
    }
}

/// Paper reference numbers (Table III) for side-by-side printing.
fn paper_table3(name: &str) -> Option<(f64, f64, f64, f64, f64, f64)> {
    // (base_acc, base_area, base_power, qat_acc, qat_area, qat_power)
    match name {
        "arrhythmia" => Some((0.620, 266.0, 998.0, 0.610, 92.5, 258.0)),
        "breastcancer" => Some((0.980, 12.0, 40.0, 0.965, 4.6, 16.6)),
        "cardio" => Some((0.881, 33.4, 124.0, 0.884, 8.8, 34.1)),
        "pendigits" => Some((0.937, 67.0, 213.0, 0.893, 19.5, 77.3)),
        "redwine" => Some((0.564, 17.6, 73.5, 0.568, 3.4, 13.7)),
        "whitewine" => Some((0.537, 31.2, 126.0, 0.524, 8.1, 31.3)),
        _ => None,
    }
}

/// A study caches pipeline results across experiments in one process.
pub struct Study {
    pub scale: Scale,
    pub backend: EvalBackend,
    pub objective: CostObjective,
    results: HashMap<String, PipelineResult>,
}

impl Study {
    pub fn new(scale: Scale, backend: EvalBackend) -> Study {
        Study {
            scale,
            backend,
            objective: CostObjective::Fa,
            results: HashMap::new(),
        }
    }

    /// Select the GA cost objective the study's pipelines optimize
    /// (`pmlp repro --objective …`, env `PMLP_OBJECTIVE` for the bench
    /// binaries; `area+power` runs the joint three-objective front,
    /// `area+power+delay` the four-objective one with the delay axis
    /// capped at the dataset's clock budget).
    /// Measured objectives require the circuit backend — checked here so
    /// harnesses fail at construction with a clear message instead of
    /// deep inside the first pipeline run.
    pub fn with_objective(mut self, objective: CostObjective) -> Study {
        assert!(
            !objective.is_measured() || self.backend == EvalBackend::Circuit,
            "objective '{}' requires the circuit backend (got {:?})",
            objective.label(),
            self.backend
        );
        self.objective = objective;
        self
    }

    /// Scaled run config for a dataset.
    pub fn cfg(&self, name: &str) -> RunConfig {
        let mut cfg = builtin::by_name(name).expect("unknown dataset");
        cfg.ga.population = self.scale.ga_population();
        cfg.ga.generations = self.scale.ga_generations();
        cfg
    }

    /// Run (or fetch) the full pipeline for a dataset.
    pub fn pipeline(&mut self, name: &str) -> &PipelineResult {
        if !self.results.contains_key(name) {
            let cfg = self.cfg(name);
            let opts = PipelineOpts {
                backend: self.backend,
                objective: self.objective,
                max_hw_points: 4,
                verbose: std::env::var("PMLP_VERBOSE").is_ok(),
                ..Default::default()
            };
            let result = Pipeline::new(cfg, opts).run().expect("pipeline");
            self.results.insert(name.to_string(), result);
        }
        &self.results[name]
    }
}

/// One throughput sample of an evaluator bench case — the structured
/// side of `benches/perf_evaluators.rs`, serialized to
/// `BENCH_evaluators.json` so CI can track the perf trajectory.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Which harness produced the sample (`ablation` / `jobs_scaling`).
    pub bench: &'static str,
    pub dataset: String,
    /// Case label, e.g. `circuit/incr/power` or `jobs=8`.
    pub case: String,
    /// Genomes (chromosomes) evaluated per second.
    pub genomes_per_sec: f64,
}

/// Serialize bench records (plus the scale they ran at) for the CI
/// artifact.
pub fn records_to_json(scale: Scale, records: &[BenchRecord]) -> Json {
    Json::obj(vec![
        ("bench", Json::str("perf_evaluators")),
        ("scale", Json::str(&format!("{scale:?}").to_lowercase())),
        (
            "records",
            Json::arr(
                records
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("bench", Json::str(r.bench)),
                            ("dataset", Json::str(&r.dataset)),
                            ("case", Json::str(&r.case)),
                            ("genomes_per_sec", Json::num(r.genomes_per_sec)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The `(loss, objs[axis])` 2-D projection of an arity-erased Pareto
/// front, reduced to its non-dominated subset and sorted by loss.
///
/// A member of a 3-D `(loss, area, power)` or 4-D `(loss, area, power,
/// delay)` front can be *dominated* in a 2-D slice — it earns its place
/// on an axis the slice drops — so projecting is filter-then-sort, not
/// just a coordinate pick. This is how the fig4/table5 harnesses turn
/// the joint fronts back into the paper's two-axis views (loss×area,
/// loss×power and, for `area+power+delay`, loss×delay).
pub fn front_projection(front: &[FrontPoint], axis: usize) -> Vec<(f64, f64)> {
    let pts: Vec<(f64, f64)> = front.iter().map(|p| (p.objs[0], p.objs[axis])).collect();
    let dominated = |a: (f64, f64), b: (f64, f64)| {
        (b.0 <= a.0 && b.1 <= a.1) && (b.0 < a.0 || b.1 < a.1)
    };
    let mut out: Vec<(f64, f64)> = pts
        .iter()
        .copied()
        .filter(|&a| !pts.iter().any(|&b| dominated(a, b)))
        .collect();
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out.dedup();
    out
}

/// Render one 2-D projection of a joint-front run as a table section
/// (no-op text for 2-D runs — the projection equals the front itself
/// there, which fig4/table5 already print through the designs).
fn projection_section(r: &PipelineResult, name: &str, axis: usize, axis_label: &str) -> String {
    let rows: Vec<Vec<String>> = front_projection(&r.front, axis)
        .into_iter()
        .map(|(loss, cost)| vec![format!("{loss:.4}"), format!("{cost:.4}")])
        .collect();
    render_table(
        &format!(
            "[{name}] (loss, {axis_label}) projection of the {}-D {} front",
            r.objective.arity(),
            r.objective.label()
        ),
        &["acc loss (train)", axis_label],
        &rows,
    )
}

// ---------------------------------------------------------------------------
// Table II — Spearman rank correlation of the area surrogate
// ---------------------------------------------------------------------------

/// Regenerate Table II: FA-count estimate vs synthesized area over N
/// random chromosomes per MLP. The paper reports ≥0.96 per dataset.
pub fn table2(scale: Scale) -> String {
    let mut rows = Vec::new();
    let mut all_corr = Vec::new();
    for name in scale.dataset_names() {
        let cfg = builtin::by_name(name).unwrap();
        let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
        let tm = train::train_native(&cfg, &split, &qtrain, &qtest);
        let qmlp = &tm.qmlp;
        let map = GenomeMap::new(qmlp);
        let area_model = AreaModel::new(&map);
        let n = scale.table2_chromosomes();
        let mut rng = Rng::new(0xA0EA ^ cfg.dataset.seed);
        let genomes: Vec<_> = (0..n)
            .map(|_| {
                let keep = 0.35 + 0.6 * rng.f64();
                map.random_genome(&mut rng, keep)
            })
            .collect();
        // Estimate + synthesize in parallel.
        let qmlp_ref = &qmlp;
        let map_ref = &map;
        let pairs = threads::par_map(n, threads::default_threads(), |i| {
            let est = area_model.estimate(&genomes[i]) as f64;
            let masks = map_ref.to_masks(&genomes[i]);
            let nl = build_mlp_circuit(
                qmlp_ref,
                &MlpCircuitOpts { masks: Some(masks), argmax: ArgmaxMode::Raw },
            );
            let (opt, _) = optimize(&nl);
            let hw = analyze(&opt, &Library::egfet_1v(), cfg.hw.clock_ms, 0.25);
            (est, hw.area_cm2)
        });
        let ests: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        let areas: Vec<f64> = pairs.iter().map(|p| p.1).collect();
        let corr = spearman(&ests, &areas);
        all_corr.push(corr);
        rows.push(vec![
            name.to_string(),
            format!("{corr:.3}"),
            "0.96-0.99".to_string(),
            format!("{n}"),
        ]);
    }
    rows.push(vec![
        "AVERAGE".to_string(),
        format!("{:.3}", mean(&all_corr)),
        "0.97".to_string(),
        String::new(),
    ]);
    render_table(
        "Table II — Spearman rank correlation of the area surrogate",
        &["dataset", "spearman (ours)", "paper", "designs"],
        &rows,
    )
}

// ---------------------------------------------------------------------------
// Table III — baseline vs QAT-only
// ---------------------------------------------------------------------------

/// Regenerate Table III: exact bespoke baseline [8] vs power-of-2 +
/// QRelu (QAT only), accuracy / area / power per MLP.
pub fn table3(study: &mut Study) -> String {
    let mut rows = Vec::new();
    for name in study.scale.dataset_names() {
        let r = study.pipeline(name);
        let base_hw = r.baseline_hw.as_ref().expect("baseline synthesized");
        let paper = paper_table3(name);
        let paper_cell = |f: fn((f64, f64, f64, f64, f64, f64)) -> f64| -> String {
            paper.map(|p| format!("{:.3}", f(p))).unwrap_or_default()
        };
        rows.push(vec![
            name.to_string(),
            format!(
                "({},{},{})",
                r.cfg.topology.n_in, r.cfg.topology.n_hidden, r.cfg.topology.n_out
            ),
            format!("{:.3}", r.baseline_acc_test),
            paper_cell(|p| p.0),
            format!("{:.1}", base_hw.area_cm2),
            paper_cell(|p| p.1),
            format!("{:.0}", base_hw.power_mw),
            paper_cell(|p| p.2),
            format!("{:.3}", r.trained.acc_q_test),
            paper_cell(|p| p.3),
            format!("{:.2}", r.qat_hw.area_cm2),
            paper_cell(|p| p.4),
            format!("{:.1}", r.qat_hw.power_mw),
            paper_cell(|p| p.5),
        ]);
    }
    render_table(
        "Table III — baseline [8] vs QAT-only (po2 + QRelu)",
        &[
            "dataset", "topology", "acc", "(paper)", "area cm2", "(paper)", "power mW",
            "(paper)", "QAT acc", "(paper)", "QAT area", "(paper)", "QAT mW", "(paper)",
        ],
        &rows,
    )
}

// ---------------------------------------------------------------------------
// Fig. 4 — accumulation-approximation Pareto fronts
// ---------------------------------------------------------------------------

/// Regenerate Fig. 4: Pareto points (accuracy loss vs area normalized to
/// the QAT-only design), up to 5% loss. The paper reports ~2.4x average
/// area reduction at <2% extra loss.
pub fn fig4(study: &mut Study) -> String {
    let mut out = String::new();
    let mut avg_red_2pct = Vec::new();
    for name in study.scale.dataset_names() {
        let r = study.pipeline(name);
        let qat_area = r.qat_hw.area_cm2;
        let qat_acc = r.trained.acc_q_test;
        let mut rows = Vec::new();
        for d in &r.designs {
            let loss = qat_acc - d.acc_test_accum;
            if loss > 0.05 {
                continue;
            }
            let norm = d.hw_exact_argmax.area_cm2 / qat_area;
            // The exact-genome fallback (norm == 1) is not an
            // approximated design; exclude it from the average.
            if loss <= 0.02 && norm > 0.0 && norm < 0.999 {
                avg_red_2pct.push(1.0 / norm);
            }
            rows.push(vec![
                format!("{:.3}", d.acc_test_accum),
                format!("{:+.3}", -loss),
                format!("{:.3}", norm),
                format!("{}", d.area_fa),
            ]);
        }
        out.push_str(&render_table(
            &format!("Fig. 4 [{name}] — accuracy vs area (normalized to QAT-only)"),
            &["test acc", "Δacc vs QAT", "area/QAT", "FA est"],
            &rows,
        ));
        // A joint-objective run carries a 3-D (loss, area, power) or
        // 4-D (loss, area, power, delay) front; Fig. 4's view of it is
        // the loss×area slice.
        if r.objective.arity() >= 3 {
            out.push_str(&projection_section(r, name, 1, "area cm2"));
        }
    }
    if !avg_red_2pct.is_empty() {
        out.push_str(&format!(
            "\naverage area reduction at <=2% extra loss: {:.1}x (paper: ~2.4x avg, worst 1.3x)\n",
            mean(&avg_red_2pct)
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Table IV — Argmax approximation
// ---------------------------------------------------------------------------

/// Regenerate Table IV: impact of the approximate Argmax on the
/// (QAT + approximate-accumulation) designs.
pub fn table4(study: &mut Study) -> String {
    let mut rows = Vec::new();
    for name in study.scale.dataset_names() {
        let r = study.pipeline(name);
        let mut acc_losses = Vec::new();
        let mut area_reds = Vec::new();
        let mut cmp_reds = Vec::new();
        for d in &r.designs {
            acc_losses.push(d.acc_test_accum - d.acc_test_full);
            if d.hw_exact_argmax.area_cm2 > 0.0 {
                area_reds.push(1.0 - d.hw_full.area_cm2 / d.hw_exact_argmax.area_cm2);
            }
            cmp_reds.push(d.argmax_plan.comparator_stats().1);
        }
        rows.push(vec![
            name.to_string(),
            format!("{:+.3}", mean(&acc_losses)),
            format!("{:.0}%", 100.0 * mean(&area_reds)),
            format!("{:.1}x", mean(&cmp_reds)),
        ]);
    }
    rows.push(vec![
        "(paper avg)".to_string(),
        "~0.001".to_string(),
        "14%".to_string(),
        "7.6x".to_string(),
    ]);
    render_table(
        "Table IV — Argmax approximation (vs QAT + approx accumulation)",
        &["dataset", "avg acc loss", "avg area reduction", "avg comparator size cut"],
        &rows,
    )
}

// ---------------------------------------------------------------------------
// Fig. 5 — comparison against the state of the art
// ---------------------------------------------------------------------------

/// Regenerate Fig. 5: area and power of ours vs [7] (truncation), [10]
/// (pruning + VOS), [14] (stochastic), all normalized to the exact
/// baseline [8], at <=5% accuracy loss.
pub fn fig5(study: &mut Study) -> String {
    let mut rows = Vec::new();
    let names: Vec<&str> = study
        .scale
        .dataset_names()
        .into_iter()
        .filter(|n| *n != "arrhythmia") // the paper's SOTA rows exclude it
        .collect();
    for name in &names {
        let scale = study.scale;
        let r = study.pipeline(name);
        let cfg = r.cfg.clone();
        let base_hw = r.baseline_hw.clone().expect("baseline");
        let base_acc = r.baseline_acc_test;
        let float = r.trained.float.clone();
        let ours = r.best_within_loss(0.05).map(|d| {
            (d.hw_full.area_cm2 / base_hw.area_cm2, d.hw_full.power_mw / base_hw.power_mw)
        });

        // Rebuild the shared substrate for the baselines.
        let (_, qtrain, qtest) = datasets::load(&cfg.dataset);
        let int8 = Int8Mlp::from_float(&float);

        // --- [7]: multiplier approx + coarse truncation sweep.
        let mut best7: Option<(f64, f64)> = None;
        for t in 0..8u32 {
            let m = TruncMlp::new(int8.clone(), t, t);
            if m.accuracy(&qtest) < base_acc - 0.05 {
                continue;
            }
            let (opt, _) = optimize(&m.build_circuit(ArgmaxMode::Exact));
            let hw = analyze(&opt, &Library::egfet_1v(), cfg.hw.clock_ms, 0.25);
            let cand = (hw.area_cm2 / base_hw.area_cm2, hw.power_mw / base_hw.power_mw);
            if best7.map(|b| cand.0 < b.0).unwrap_or(true) {
                best7 = Some(cand);
            }
        }

        // --- [10]: pruning sweep on the multiplier-approximated design
        // (the paper's [10] rows skip Pendigits; so do we — gate-level
        // simulation over its test set would dominate the harness).
        let best10: Option<(f64, f64)> = if *name != "pendigits" && scale != Scale::Smoke {
            let m = TruncMlp::new(int8.clone(), 1, 1);
            let sweep = prune::run_sweep(&m, &qtrain, &[0.02, 0.08, 0.15]);
            sweep
                .iter()
                .filter(|p| p.accuracy >= base_acc - 0.05)
                .map(|p| {
                    let hw = analyze(&p.netlist, &Library::egfet_1v(), cfg.hw.clock_ms, 0.25);
                    (
                        hw.area_cm2 / base_hw.area_cm2,
                        hw.power_mw * prune::VOS_POWER_FACTOR / base_hw.power_mw,
                    )
                })
                .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
        } else {
            None
        };

        // --- [14]: stochastic computing.
        let sc = ScMlp::from_float(&float, cfg.dataset.seed);
        let sc_acc = sc.accuracy(&qtest, 150);
        let sc_hw = sc.hardware(&Library::egfet_1v(), cfg.hw.clock_ms);
        let sc_norm =
            (sc_hw.area_cm2 / base_hw.area_cm2, sc_hw.power_mw / base_hw.power_mw);

        let cell = |v: Option<(f64, f64)>| -> (String, String) {
            match v {
                Some((a, p)) => (format!("{a:.4}"), format!("{p:.4}")),
                None => ("-".to_string(), "-".to_string()),
            }
        };
        let (oa, op) = cell(ours);
        let (a7, p7) = cell(best7);
        let (a10, p10) = cell(best10);
        rows.push(vec![
            name.to_string(),
            oa,
            op,
            a7,
            p7,
            a10,
            p10,
            format!("{:.4}", sc_norm.0),
            format!("{:.4}", sc_norm.1),
            format!("{sc_acc:.2}"),
        ]);
    }
    let mut out = render_table(
        "Fig. 5 — area/power normalized to the exact baseline [8] (<=5% acc loss)",
        &[
            "dataset", "ours A", "ours P", "[7] A", "[7] P", "[10] A", "[10] P",
            "[14] A", "[14] P", "[14] acc",
        ],
        &rows,
    );
    out.push_str(
        "\npaper: ours ~10x/12.5x better than [7], ~96x/86x than [10], ~9x/11x than [14];\n[14]'s accuracy collapses (paper: 35% avg loss).\n",
    );
    out
}

// ---------------------------------------------------------------------------
// Table V — battery operation at 0.6 V
// ---------------------------------------------------------------------------

/// Regenerate Table V: the best <=5%-loss design per MLP at the 0.6 V
/// corner, with area/power reductions vs the baseline and the printed
/// power source able to drive it.
pub fn table5(study: &mut Study) -> String {
    let mut rows = Vec::new();
    let mut projections = String::new();
    for name in study.scale.dataset_names() {
        let r = study.pipeline(name);
        // Battery operation is a power story: on a joint-objective run,
        // also print the loss×power slice of the front the GA actually
        // selected on — plus the loss×delay slice when the run carried
        // the 4-D timing axis (every member of it meets `--max-delay`).
        if r.objective.arity() >= 3 {
            projections.push_str(&projection_section(r, name, 2, "power mW"));
        }
        if r.objective == CostObjective::AreaPowerDelay {
            projections.push_str(&projection_section(r, name, 3, "delay ms"));
        }
        let base_hw = r.baseline_hw.as_ref().expect("baseline");
        // The paper's own Table V rows sit at up to ~5.2% loss
        // (Arrhythmia: 0.588 vs baseline 0.620); designs between 5% and
        // 8% are reported with a '*' rather than dropped.
        let min_loss_design = r
            .designs
            .iter()
            .filter(|d| d.area_fa > 0)
            .max_by(|a, b| a.acc_test_full.partial_cmp(&b.acc_test_full).unwrap());
        let (d, flag) = match r.best_within_loss(0.05) {
            Some(d) => (d, ""),
            None => match r.best_within_loss(0.08) {
                Some(d) => (d, "*"),
                // On substitutes whose QAT gap alone exceeds the budget
                // (synthetic-arrhythmia artifact, see EXPERIMENTS.md),
                // report the best approximated design transparently.
                None => match min_loss_design {
                    Some(d) => (d, "**"),
                    None => {
                        rows.push(vec![name.to_string(), "no design".to_string()]);
                        continue;
                    }
                },
            },
        };
        rows.push(vec![
            name.to_string(),
            format!("{:.3}{flag}", d.acc_test_full),
            format!("{:.3}", d.hw_0p6v.area_cm2),
            format!("{:.3}", d.hw_0p6v.power_mw),
            crate::report::factor(base_hw.area_cm2, d.hw_0p6v.area_cm2),
            crate::report::factor(base_hw.power_mw, d.hw_0p6v.power_mw),
            d.power_source.label().to_string(),
            d.hw_0p6v.library.clone(),
        ]);
    }
    let mut out = render_table(
        "Table V — battery operation at 0.6 V (<=5% accuracy loss)",
        &[
            "dataset", "accuracy", "area cm2", "power mW", "area cut", "power cut",
            "power source", "corner",
        ],
        &rows,
    );
    out.push_str("\n'*' = loss in (5%, 8%] of baseline; '**' = best approximated design (loss above 8%; the synthetic-dataset QAT gap exceeds the budget).\npaper: avg 151x area / 808x power vs [8]; Arrhythmia (1450 params) battery-powered -> 20x larger than SOTA's largest (72 params).\n");
    out.push_str(&projections);
    out
}

// ---------------------------------------------------------------------------
// Ablation — PJRT vs native evaluator (design-choice bench)
// ---------------------------------------------------------------------------

/// Throughput of the GA evaluators on one dataset (chromosomes/s):
/// native integer model, circuit-in-the-loop in both synthesis modes
/// (from-scratch per chromosome vs template + incremental cone-local
/// re-synthesis), and PJRT when artifacts are present.
///
/// The circuit rows run on a GA-like *mutation chain* (each genome is a
/// few bit flips from its predecessor) — the workload the incremental
/// engine targets and the population structure NSGA-II actually
/// produces; the native row keeps the independent random stream.
pub fn ablation_evaluators(name: &str, n_genomes: usize) -> String {
    ablation_evaluators_recorded(name, n_genomes, &mut Vec::new())
}

/// [`ablation_evaluators`] that also appends one [`BenchRecord`] per
/// measured rate (the JSON side of `benches/perf_evaluators.rs`).
pub fn ablation_evaluators_recorded(
    name: &str,
    n_genomes: usize,
    records: &mut Vec<BenchRecord>,
) -> String {
    use crate::ga::{evaluate_parallel, Evaluator};
    use crate::synth::SynthMode;
    let mut record = |case: String, rate: f64| {
        records.push(BenchRecord {
            bench: "ablation",
            dataset: name.to_string(),
            case,
            genomes_per_sec: rate,
        });
    };
    let cfg = builtin::by_name(name).expect("dataset");
    let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
    let tm = train::train_native(&cfg, &split, &qtrain, &qtest);
    let qmlp: &QuantMlp = &tm.qmlp;
    let base = tm.acc_q_train;
    let native = crate::runtime::evaluator::NativeEvaluator::new(qmlp, &qtrain, base);
    let mut rng = Rng::new(1);
    let genomes: Vec<_> =
        (0..n_genomes).map(|_| native.map.random_genome(&mut rng, 0.8)).collect();

    let t0 = std::time::Instant::now();
    let objs_native = native.evaluate(&genomes);
    let native_rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
    record("native".to_string(), native_rate);

    let mut rows = vec![vec![
        "native".to_string(),
        format!("{native_rate:.0}"),
        format!("{}", objs_native.len()),
    ]];

    // GA-like mutation chain for the circuit backends.
    let chain: Vec<crate::util::BitVec> = {
        let mut g = native.map.random_genome(&mut rng, 0.8);
        let mut v = Vec::with_capacity(n_genomes);
        v.push(g.clone());
        while v.len() < n_genomes {
            for _ in 0..4 {
                g.flip(rng.below(native.map.len()));
            }
            v.push(g.clone());
        }
        v
    };
    let objs_chain_native = native.evaluate(&chain);

    // From-scratch circuit evaluation on a chain prefix (each genome is
    // a full build + synthesis + wave classification of the train set).
    // Both circuit chain rows run at jobs=1 on purpose: they measure the
    // *serial chain-locality* cost (one arena walking the mutation
    // chain), not machine-width scaling — `jobs_scaling` covers that.
    let n_full = n_genomes.min(16);
    let full_ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base)
        .with_mode(SynthMode::Full);
    let t0 = std::time::Instant::now();
    let objs_full = evaluate_parallel(&full_ev, &chain[..n_full], 1);
    let full_rate = n_full as f64 / t0.elapsed().as_secs_f64();
    let agree_native = objs_chain_native
        .iter()
        .zip(&objs_full)
        .all(|(a, b)| (a[0] - b[0]).abs() < 1e-9 && a[1] == b[1]);
    record("circuit/full/fa".to_string(), full_rate);
    rows.push(vec![
        "circuit/full".to_string(),
        format!("{full_rate:.1}"),
        format!("netlist-equal over {n_full}: {agree_native}"),
    ]);

    // Incremental: one worker's template arena + wave cache across the
    // whole chain (jobs=1, see above).
    let incr_ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base);
    let t0 = std::time::Instant::now();
    let objs_incr = evaluate_parallel(&incr_ev, &chain, 1);
    let incr_rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
    let agree_full = objs_incr[..n_full] == objs_full[..];
    record("circuit/incr/fa".to_string(), incr_rate);
    rows.push(vec![
        "circuit/incr".to_string(),
        format!("{incr_rate:.1}"),
        format!(
            "== full over {n_full}: {agree_full}; speedup {:.1}x",
            incr_rate / full_rate
        ),
    ]);

    // Lane-width / shared-cone ablation on the same chain at the same
    // jobs=1 worker discipline. The 64-lane row (sharing off) is the
    // pre-block engine — the committed baseline the acceptance target
    // is measured against; the 256-lane row isolates the `[u64; 4]`
    // block win; shared-cones stacks the generation-scoped cone memo on
    // top. All three must agree bit-exactly with the default incr run.
    // CI asserts shared-cones >= 2x the 64-lane row (smoke bench leg).
    let w64_ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base)
        .with_lane_width(LaneWidth::W64)
        .with_cone_sharing(false);
    let t0 = std::time::Instant::now();
    let objs_w64 = evaluate_parallel(&w64_ev, &chain, 1);
    let w64_rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
    record("circuit/incr/64-lane".to_string(), w64_rate);
    rows.push(vec![
        "circuit/incr/64-lane".to_string(),
        format!("{w64_rate:.1}"),
        format!("legacy width, sharing off; == incr: {}", objs_w64 == objs_incr),
    ]);
    let w256_ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base)
        .with_lane_width(LaneWidth::W256)
        .with_cone_sharing(false);
    let t0 = std::time::Instant::now();
    let objs_w256 = evaluate_parallel(&w256_ev, &chain, 1);
    let w256_rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
    record("circuit/incr/256-lane".to_string(), w256_rate);
    rows.push(vec![
        "circuit/incr/256-lane".to_string(),
        format!("{w256_rate:.1}"),
        format!(
            "block engine, sharing off; == incr: {}; {:.1}x of 64-lane",
            objs_w256 == objs_incr,
            w256_rate / w64_rate
        ),
    ]);
    let shared_ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base)
        .with_lane_width(LaneWidth::W256)
        .with_cone_sharing(true);
    let t0 = std::time::Instant::now();
    let objs_shared = evaluate_parallel(&shared_ev, &chain, 1);
    let shared_rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
    record("circuit/incr/shared-cones".to_string(), shared_rate);
    rows.push(vec![
        "circuit/incr/shared-cones".to_string(),
        format!("{shared_rate:.1}"),
        format!(
            "blocks + cone memo; == incr: {}; {:.1}x of 64-lane (target >=2x)",
            objs_shared == objs_incr,
            shared_rate / w64_rate
        ),
    ]);

    // Measured-hardware objective (`--objective power`) on the same
    // mutation chain: full mode pays a from-scratch template synthesis
    // plus a dedicated toggle-activity simulation per genome, while the
    // incremental census + WaveCache toggle totals ride the passes the
    // evaluator runs anyway — the acceptance target is incremental ≥ 2×
    // full on this chain.
    let fullp_ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base)
        .with_mode(SynthMode::Full)
        .with_objective(CostObjective::Power);
    let t0 = std::time::Instant::now();
    let objs_fullp = evaluate_parallel(&fullp_ev, &chain[..n_full], 1);
    let fullp_rate = n_full as f64 / t0.elapsed().as_secs_f64();
    record("circuit/full/power".to_string(), fullp_rate);
    rows.push(vec![
        "circuit/full/power".to_string(),
        format!("{fullp_rate:.1}"),
        format!("measured-power objective over {n_full}"),
    ]);
    let incrp_ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base)
        .with_objective(CostObjective::Power);
    let t0 = std::time::Instant::now();
    let objs_incrp = evaluate_parallel(&incrp_ev, &chain, 1);
    let incrp_rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
    let agree_power = objs_incrp[..n_full] == objs_fullp[..];
    record("circuit/incr/power".to_string(), incrp_rate);
    rows.push(vec![
        "circuit/incr/power".to_string(),
        format!("{incrp_rate:.1}"),
        format!(
            "== full over {n_full}: {agree_power}; speedup {:.1}x (target >=2x)",
            incrp_rate / fullp_rate
        ),
    ]);

    // Joint three-objective (`--objective area+power`) on the same
    // mutation chain: identical roll-up, one extra axis filled — this
    // row tracks the const-generic arity generalization's overhead
    // against the single measured objective (target: < 10%, i.e. the
    // extra objective is bookkeeping, not re-synthesis). The loss and
    // power axes must match the dedicated power run exactly.
    let incrj_ev =
        crate::runtime::evaluator::CircuitEvaluator::new_joint(qmlp, &qtrain, base);
    let t0 = std::time::Instant::now();
    let objs_incrj = evaluate_parallel(&incrj_ev, &chain, 1);
    let incrj_rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
    let agree_joint = objs_incrj
        .iter()
        .zip(&objs_incrp)
        .all(|(j, p)| j[0] == p[0] && j[2] == p[1]);
    record("circuit/incr/area+power".to_string(), incrj_rate);
    rows.push(vec![
        "circuit/incr/area+power".to_string(),
        format!("{incrj_rate:.1}"),
        format!(
            "3-objective; axes == incr/power: {agree_joint}; {:.2}x of incr/power (target >=0.9x)",
            incrj_rate / incrp_rate
        ),
    ]);

    // Joint four-objective (`--objective area+power+delay`) on the same
    // mutation chain: the delay axis is read off the incremental
    // arena's per-node arrival table, settled once per emitted node —
    // no extra synthesis or simulation — so the arity-4 overhead vs the
    // 3-objective row is bookkeeping only (target: < 15%, CI asserts
    // >= 0.85x). The first three axes must match the area+power run
    // exactly and the delay axis must be positive.
    let incrd_ev =
        crate::runtime::evaluator::CircuitEvaluator::new_joint_delay(qmlp, &qtrain, base);
    let t0 = std::time::Instant::now();
    let objs_incrd = evaluate_parallel(&incrd_ev, &chain, 1);
    let incrd_rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
    let agree_delay = objs_incrd
        .iter()
        .zip(&objs_incrj)
        .all(|(d, j)| d[0] == j[0] && d[1] == j[1] && d[2] == j[2] && d[3] > 0.0);
    record("circuit/incr/area+power+delay".to_string(), incrd_rate);
    rows.push(vec![
        "circuit/incr/area+power+delay".to_string(),
        format!("{incrd_rate:.1}"),
        format!(
            "4-objective; axes == incr/area+power: {agree_delay}; {:.2}x of area+power (target >=0.85x)",
            incrd_rate / incrj_rate
        ),
    ]);

    if let Ok(rt) = crate::runtime::Runtime::new(&crate::runtime::Runtime::default_dir()) {
        if rt.manifest.entries.contains_key(name) {
            if let Ok(pjrt) = crate::runtime::PjrtEvaluator::new(&rt, name, qmlp, &qtrain, base) {
                // Warm up the executable cache before timing.
                let _ = pjrt.evaluate(&genomes[..genomes.len().min(16)]);
                let t0 = std::time::Instant::now();
                let objs_pjrt = pjrt.evaluate(&genomes);
                let rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
                let agree = objs_native
                    .iter()
                    .zip(&objs_pjrt)
                    .all(|(a, b)| (a[0] - b[0]).abs() < 1e-9 && a[1] == b[1]);
                record("pjrt".to_string(), rate);
                rows.push(vec![
                    "pjrt".to_string(),
                    format!("{rate:.0}"),
                    format!("bit-equal: {agree}"),
                ]);
            }
        }
    }
    render_table(
        &format!("Evaluator ablation [{name}] ({n_genomes} chromosomes)"),
        &["backend", "chromosomes/s", "notes"],
        &rows,
    )
}

/// Genomes/sec of the circuit backend's population-parallel fan-out at
/// increasing `--jobs` widths (incremental synthesis, per-worker arenas)
/// — the scaling row of `benches/perf_evaluators.rs`.
///
/// Each width gets a *fresh* evaluator: the cross-generation memo is
/// shared state, and reusing it would let the second run answer from
/// cache. The workload is independent semi-random chromosomes (the
/// initial-population shape — large cone deltas, so per-genome work is
/// substantial and the fan-out has something to win on). Objectives are
/// asserted bit-identical across widths.
pub fn jobs_scaling(name: &str, n_genomes: usize, jobs_list: &[usize]) -> String {
    jobs_scaling_recorded(name, n_genomes, jobs_list, &mut Vec::new())
}

/// [`jobs_scaling`] that also appends one [`BenchRecord`] per width.
pub fn jobs_scaling_recorded(
    name: &str,
    n_genomes: usize,
    jobs_list: &[usize],
    records: &mut Vec<BenchRecord>,
) -> String {
    use crate::ga::evaluate_parallel;
    let cfg = builtin::by_name(name).expect("dataset");
    let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
    let tm = train::train_native(&cfg, &split, &qtrain, &qtest);
    let qmlp: &QuantMlp = &tm.qmlp;
    let base = tm.acc_q_train;
    let map = GenomeMap::new(qmlp);
    let mut rng = Rng::new(9);
    let genomes: Vec<_> = (0..n_genomes)
        .map(|_| {
            let keep = 0.6 + 0.35 * rng.f64();
            map.random_genome(&mut rng, keep)
        })
        .collect();
    let mut rows = Vec::new();
    let mut base_rate: Option<f64> = None;
    let mut reference: Option<Vec<[f64; 2]>> = None;
    for &jobs in jobs_list {
        let ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base);
        let t0 = std::time::Instant::now();
        let objs = evaluate_parallel(&ev, &genomes, jobs);
        let rate = n_genomes as f64 / t0.elapsed().as_secs_f64();
        let agree = match &reference {
            None => {
                reference = Some(objs);
                true
            }
            Some(r) => *r == objs,
        };
        let speedup = base_rate.map(|b| rate / b).unwrap_or(1.0);
        if base_rate.is_none() {
            base_rate = Some(rate);
        }
        records.push(BenchRecord {
            bench: "jobs_scaling",
            dataset: name.to_string(),
            case: format!("jobs={jobs}"),
            genomes_per_sec: rate,
        });
        rows.push(vec![
            format!("{jobs}"),
            format!("{rate:.1}"),
            format!("{speedup:.2}x"),
            format!("bit-identical: {agree}"),
        ]);
    }
    render_table(
        &format!(
            "Circuit-backend jobs scaling [{name}] ({n_genomes} chromosomes, incremental synth)"
        ),
        &["jobs", "genomes/s", "vs jobs=1", "notes"],
        &rows,
    )
}

/// Telemetry overhead row pair: the `circuit/incr` mutation-chain
/// workload of the evaluator ablation, once with collection disabled
/// (`telemetry::set_enabled(false)`) and once enabled — pinning the
/// instrumentation cost on the hottest path (acceptance target: < 5%).
/// Fresh evaluator per arm (own memo + arena pool) and identical
/// objectives asserted, so the pair measures instrumentation, not cache
/// luck.
pub fn telemetry_overhead(name: &str, n_genomes: usize) -> String {
    telemetry_overhead_recorded(name, n_genomes, &mut Vec::new())
}

/// [`telemetry_overhead`] that also appends one [`BenchRecord`] per arm.
pub fn telemetry_overhead_recorded(
    name: &str,
    n_genomes: usize,
    records: &mut Vec<BenchRecord>,
) -> String {
    use crate::ga::evaluate_parallel;
    use crate::util::telemetry;
    let cfg = builtin::by_name(name).expect("dataset");
    let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
    let tm = train::train_native(&cfg, &split, &qtrain, &qtest);
    let qmlp: &QuantMlp = &tm.qmlp;
    let base = tm.acc_q_train;
    let map = GenomeMap::new(qmlp);
    let mut rng = Rng::new(7);
    // Same GA-like mutation chain shape as `ablation_evaluators` — the
    // workload where per-genome work is smallest and the relative
    // instrumentation cost therefore largest.
    let chain: Vec<crate::util::BitVec> = {
        let mut g = map.random_genome(&mut rng, 0.8);
        let mut v = Vec::with_capacity(n_genomes);
        v.push(g.clone());
        while v.len() < n_genomes {
            for _ in 0..4 {
                g.flip(rng.below(map.len()));
            }
            v.push(g.clone());
        }
        v
    };
    let was_enabled = telemetry::enabled();
    let arm = |enabled: bool| -> (f64, Vec<[f64; 2]>) {
        telemetry::set_enabled(enabled);
        let ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base);
        let t0 = std::time::Instant::now();
        let objs = evaluate_parallel(&ev, &chain, 1);
        (n_genomes as f64 / t0.elapsed().as_secs_f64(), objs)
    };
    let (off_rate, objs_off) = arm(false);
    let (on_rate, objs_on) = arm(true);
    telemetry::set_enabled(was_enabled);
    let agree = objs_off == objs_on;
    let overhead_pct = (off_rate / on_rate - 1.0) * 100.0;
    for (case, rate) in
        [("circuit/incr/fa/telemetry=off", off_rate), ("circuit/incr/fa/telemetry=on", on_rate)]
    {
        records.push(BenchRecord {
            bench: "telemetry",
            dataset: name.to_string(),
            case: case.to_string(),
            genomes_per_sec: rate,
        });
    }
    render_table(
        &format!("Telemetry overhead [{name}] ({n_genomes} chromosomes, circuit/incr, jobs=1)"),
        &["case", "chromosomes/s", "notes"],
        &[
            vec![
                "telemetry=off".to_string(),
                format!("{off_rate:.1}"),
                String::new(),
            ],
            vec![
                "telemetry=on".to_string(),
                format!("{on_rate:.1}"),
                format!("objectives equal: {agree}; overhead {overhead_pct:.1}% (target < 5%)"),
            ],
        ],
    )
}

/// Verify overhead row pair: the same `circuit/incr` mutation-chain
/// workload once with `--verify off` (the default) and once with
/// `--verify boundaries` — pinning the cost of the invariant
/// checkpoints (one full arena verification per worker teardown) on the
/// hottest path (acceptance target: < 5%; `off` is zero-cost by
/// construction — the mode is checked before any check object is even
/// built). Fresh evaluator per arm and identical objectives asserted:
/// verification is read-only, so any divergence is itself a bug.
pub fn verify_overhead(name: &str, n_genomes: usize) -> String {
    verify_overhead_recorded(name, n_genomes, &mut Vec::new())
}

/// [`verify_overhead`] that also appends one [`BenchRecord`] per arm.
pub fn verify_overhead_recorded(
    name: &str,
    n_genomes: usize,
    records: &mut Vec<BenchRecord>,
) -> String {
    use crate::ga::evaluate_parallel;
    use crate::synth::verify::VerifyMode;
    let cfg = builtin::by_name(name).expect("dataset");
    let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
    let tm = train::train_native(&cfg, &split, &qtrain, &qtest);
    let qmlp: &QuantMlp = &tm.qmlp;
    let base = tm.acc_q_train;
    let map = GenomeMap::new(qmlp);
    let mut rng = Rng::new(7);
    // The telemetry-overhead chain shape: smallest per-genome work,
    // largest relative checkpoint cost.
    let chain: Vec<crate::util::BitVec> = {
        let mut g = map.random_genome(&mut rng, 0.8);
        let mut v = Vec::with_capacity(n_genomes);
        v.push(g.clone());
        while v.len() < n_genomes {
            for _ in 0..4 {
                g.flip(rng.below(map.len()));
            }
            v.push(g.clone());
        }
        v
    };
    let arm = |mode: VerifyMode| -> (f64, Vec<[f64; 2]>) {
        let ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, base)
            .with_verify(mode);
        let t0 = std::time::Instant::now();
        let objs = evaluate_parallel(&ev, &chain, 1);
        (n_genomes as f64 / t0.elapsed().as_secs_f64(), objs)
    };
    let (off_rate, objs_off) = arm(VerifyMode::Off);
    let (bound_rate, objs_bound) = arm(VerifyMode::Boundaries);
    let agree = objs_off == objs_bound;
    let overhead_pct = (off_rate / bound_rate - 1.0) * 100.0;
    let cases = [
        ("circuit/incr/fa/verify=off", off_rate),
        ("circuit/incr/fa/verify=boundaries", bound_rate),
    ];
    for (case, rate) in cases {
        records.push(BenchRecord {
            bench: "verify",
            dataset: name.to_string(),
            case: case.to_string(),
            genomes_per_sec: rate,
        });
    }
    render_table(
        &format!("Verify overhead [{name}] ({n_genomes} chromosomes, circuit/incr, jobs=1)"),
        &["case", "chromosomes/s", "notes"],
        &[
            vec!["verify=off".to_string(), format!("{off_rate:.1}"), String::new()],
            vec![
                "verify=boundaries".to_string(),
                format!("{bound_rate:.1}"),
                format!("objectives equal: {agree}; overhead {overhead_pct:.1}% (target < 5%)"),
            ],
        ],
    )
}

/// Spearman rank correlation of the FA surrogate against the *measured*
/// EGFET area objective (`--objective area`) on sampled genomes — the
/// Table II harness re-targeted at the circuit-in-the-loop cost axis
/// (same keep-probability sampling). A high rank correlation is what
/// keeps `fa` an acceptable default objective: the surrogate walks the
/// same Pareto-ordering the measured objective would, at none of the
/// synthesis cost on the native/PJRT backends.
pub fn spearman_fa_vs_measured(name: &str, n: usize) -> f64 {
    let cfg = builtin::by_name(name).expect("dataset");
    let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
    let tm = train::train_native(&cfg, &split, &qtrain, &qtest);
    let qmlp: &QuantMlp = &tm.qmlp;
    let map = GenomeMap::new(qmlp);
    let area_model = AreaModel::new(&map);
    let ev = crate::runtime::evaluator::CircuitEvaluator::new(qmlp, &qtrain, tm.acc_q_train)
        .with_objective(CostObjective::Area);
    let mut rng = Rng::new(0xA0EA ^ cfg.dataset.seed);
    let genomes: Vec<_> = (0..n)
        .map(|_| {
            let keep = 0.35 + 0.6 * rng.f64();
            map.random_genome(&mut rng, keep)
        })
        .collect();
    let fa: Vec<f64> = genomes.iter().map(|g| area_model.estimate(g) as f64).collect();
    use crate::ga::Evaluator;
    let measured: Vec<f64> = ev.evaluate(&genomes).iter().map(|o| o[1]).collect();
    spearman(&fa, &measured)
}
