//! Sharded concurrent hash map — the cross-generation fitness memo of
//! the GA evaluation fan-out.
//!
//! NSGA-II's crossover/mutation streams revisit identical chromosomes
//! across generations, so every evaluator memoizes genome → objectives.
//! With population-parallel evaluation the memo is shared by all worker
//! threads; a single `Mutex<HashMap>` would serialize them on every
//! lookup. This map splits the key space over many independently locked
//! shards (the Fx hash of the key picks the shard), so concurrent
//! workers contend only when they hash to the same shard — "lock-free
//! enough" for a memo whose critical sections are single probes.
//!
//! The key is stored **in full** (e.g. the entire genome `BitVec`) and
//! compared by `Eq` on lookup, exactly like any `HashMap`. Hashing is
//! only ever used to route to a shard/bucket — never as a substitute for
//! the key itself, so two distinct genomes can never alias each other's
//! fitness, no matter how they hash.

use crate::util::fxhash::{FxHashMap, FxHasher};
use crate::util::telemetry::{self, Counter};
use std::hash::{Hash, Hasher};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Number of shards (power of two; modest — the map serves tens of
/// worker threads, not thousands).
const DEFAULT_SHARDS: usize = 64;

/// A concurrent map sharded over independently locked Fx hash tables.
pub struct ShardedMap<K, V> {
    shards: Vec<Mutex<FxHashMap<K, V>>>,
    mask: u64,
}

impl<K: Eq + Hash, V: Clone> ShardedMap<K, V> {
    pub fn new() -> ShardedMap<K, V> {
        Self::with_shards(DEFAULT_SHARDS)
    }

    /// Build with an explicit shard count (rounded up to a power of two).
    pub fn with_shards(n: usize) -> ShardedMap<K, V> {
        let n = n.max(1).next_power_of_two();
        ShardedMap {
            shards: (0..n).map(|_| Mutex::new(FxHashMap::default())).collect(),
            mask: (n - 1) as u64,
        }
    }

    fn shard(&self, key: &K) -> &Mutex<FxHashMap<K, V>> {
        let mut h = FxHasher::default();
        key.hash(&mut h);
        // Route on the hash's *upper* word: the inner FxHashMap buckets on
        // the low bits of the same hash, so using those here would make
        // every key within a shard collide into the same bucket group.
        &self.shards[((h.finish() >> 32) & self.mask) as usize]
    }

    /// Lock a shard, recovering from poisoning. A shard can only be
    /// poisoned by a panic inside one of the single-probe critical
    /// sections below — in practice a panicking `V::Clone` during `get`,
    /// since our key types' `Hash`/`Eq` don't panic — which leaves the
    /// underlying map untouched and structurally sound. Inheriting the
    /// poison would turn one worker's panic into a panic storm across
    /// every thread that shares the memo (and, worse, into an abort when
    /// a waiting worker's cleanup runs during unwinding), so the memo
    /// deliberately keeps serving after a worker dies; the original
    /// panic still propagates through `util::threads`' scope join.
    fn lock_shard(shard: &Mutex<FxHashMap<K, V>>) -> MutexGuard<'_, FxHashMap<K, V>> {
        shard.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Clone out the value for `key`, if present.
    pub fn get(&self, key: &K) -> Option<V> {
        telemetry::count(Counter::ShardedGets, 1);
        let v = Self::lock_shard(self.shard(key)).get(key).cloned();
        if v.is_some() {
            telemetry::count(Counter::ShardedHits, 1);
        }
        v
    }

    /// Insert (or overwrite) `key`.
    pub fn insert(&self, key: K, value: V) {
        telemetry::count(Counter::ShardedInserts, 1);
        Self::lock_shard(self.shard(&key)).insert(key, value);
    }

    /// Total entries across all shards (locks each shard once).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| Self::lock_shard(s).len()).sum()
    }

    /// Count entries whose value satisfies `pred` (locks each shard
    /// once; a diagnostic walk, not a hot-path operation).
    pub fn count_values(&self, pred: impl Fn(&V) -> bool) -> usize {
        self.shards
            .iter()
            .map(|s| Self::lock_shard(s).values().filter(|v| pred(v)).count())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<K: Eq + Hash, V: Clone> Default for ShardedMap<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{threads, BitVec, Rng};

    #[test]
    fn insert_get_roundtrip() {
        let m: ShardedMap<u64, u64> = ShardedMap::with_shards(4);
        assert!(m.is_empty());
        for i in 0..1000u64 {
            m.insert(i, i * 7);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m.get(&i), Some(i * 7));
        }
        assert_eq!(m.get(&1000), None);
    }

    #[test]
    fn full_key_semantics_no_aliasing() {
        // The memo must key on the *entire* genome: near-identical bit
        // vectors (Hamming distance 1) get independent entries, and every
        // lookup returns exactly the value stored under that exact key.
        let mut rng = Rng::new(21);
        let m: ShardedMap<BitVec, usize> = ShardedMap::new();
        let mut genomes = Vec::new();
        let base: Vec<bool> = (0..300).map(|_| rng.chance(0.5)).collect();
        for i in 0..300 {
            let mut g = BitVec::from_bools(&base);
            g.flip(i);
            m.insert(g.clone(), i);
            genomes.push(g);
        }
        assert_eq!(m.len(), 300);
        for (i, g) in genomes.iter().enumerate() {
            assert_eq!(m.get(g), Some(i), "genome {i} aliased another entry");
        }
    }

    #[test]
    fn concurrent_inserts_all_land() {
        let m: ShardedMap<usize, usize> = ShardedMap::new();
        threads::par_map(512, 8, |i| m.insert(i, i + 1));
        assert_eq!(m.len(), 512);
        for i in 0..512 {
            assert_eq!(m.get(&i), Some(i + 1));
        }
    }

    #[test]
    fn poisoned_shard_keeps_serving() {
        // A worker that dies mid-probe (here: a panicking `Clone` during
        // `get`) poisons its shard; the memo must keep working for every
        // other worker instead of cascading the panic — the
        // panic-in-worker audit of the GA evaluation fan-out.
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        #[derive(Debug)]
        struct Fragile(Arc<AtomicBool>);
        impl Clone for Fragile {
            fn clone(&self) -> Fragile {
                if self.0.load(Ordering::SeqCst) {
                    panic!("armed clone");
                }
                Fragile(self.0.clone())
            }
        }

        let armed = Arc::new(AtomicBool::new(false));
        let m: ShardedMap<u64, Fragile> = ShardedMap::with_shards(1);
        m.insert(1, Fragile(armed.clone()));
        armed.store(true, Ordering::SeqCst);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| m.get(&1)));
        assert!(r.is_err(), "armed clone must panic");
        armed.store(false, Ordering::SeqCst);
        // The (single) shard is now poisoned; probes must still work.
        assert!(m.get(&1).is_some());
        m.insert(2, Fragile(armed.clone()));
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn count_values_walks_all_shards() {
        let m: ShardedMap<u64, u64> = ShardedMap::with_shards(4);
        for i in 0..100u64 {
            m.insert(i, i);
        }
        assert_eq!(m.count_values(|v| v % 2 == 0), 50);
        assert_eq!(m.count_values(|_| true), 100);
        assert_eq!(m.count_values(|_| false), 0);
    }

    #[test]
    fn shard_count_rounds_up() {
        let m: ShardedMap<u32, u32> = ShardedMap::with_shards(3);
        m.insert(1, 2);
        assert_eq!(m.get(&1), Some(2));
        assert_eq!(m.len(), 1);
    }
}
