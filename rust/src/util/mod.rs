//! In-tree infrastructure: PRNG, JSON, bit vectors, statistics, a tiny
//! thread-pool helper, and a property-testing harness.
//!
//! The offline vendored crate set only provides `xla` + `anyhow`, so the
//! usual ecosystem crates (`rand`, `serde`, `proptest`, `rayon`,
//! `criterion`) are replaced by these small, well-tested std-only modules.

pub mod rng;
pub mod json;
pub mod bitvec;
pub mod fxhash;
pub mod stats;
pub mod threads;
pub mod sharded;
pub mod telemetry;
pub mod prop;

pub use bitvec::BitVec;
pub use fxhash::{FxHashMap, FxHashSet};
pub use rng::Rng;
pub use sharded::ShardedMap;
