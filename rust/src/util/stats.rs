//! Small statistics toolbox: mean/std, Spearman rank correlation (the
//! metric of the paper's Table II), Pearson correlation, percentiles, and
//! a welford accumulator for streaming benchmark timing.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..n {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx * dy).sqrt()
}

/// Fractional ranks with ties averaged (the convention Spearman requires).
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        // Average rank for the tie group [i, j].
        let r = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            out[k] = r;
        }
        i = j + 1;
    }
    out
}

/// Spearman's rank correlation coefficient — the fidelity metric of the
/// paper's area surrogate (Table II reports ≥0.96 across all six MLPs).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (p / 100.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Streaming mean/variance (Welford) — used by the bench harness.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_with_ties() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_perfect_monotone() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x * x + 3.0).collect(); // monotone, nonlinear
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
        let ys_rev: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((spearman(&xs, &ys_rev) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_uncorrelated_near_zero() {
        use crate::util::Rng;
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..5000).map(|_| r.f64()).collect();
        let ys: Vec<f64> = (0..5000).map(|_| r.f64()).collect();
        assert!(spearman(&xs, &ys).abs() < 0.05);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn pearson_linear() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
    }
}
