//! FxHash-style hasher (std-only reimplementation of the rustc/Firefox
//! `FxHasher` mixing function).
//!
//! The synthesis hot path hashes millions of tiny fixed-size keys
//! (`netlist::Gate` is a 12-byte enum) per optimized netlist; SipHash's
//! keyed, DoS-resistant rounds are wasted work there. Fx folds each word
//! with one rotate + xor + multiply, which is both faster and good
//! enough: the keys are program-internal node ids, never attacker
//! controlled. Use [`FxHashMap`]/[`FxHashSet`] for such tables; keep the
//! std default hasher for anything keyed by external data.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// A `HashMap` using the Fx mixing function.
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// A `HashSet` using the Fx mixing function.
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The Fx streaming hasher: one rotate-xor-multiply per word.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut word = [0u8; 8];
            word[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(word));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_of<T: Hash>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_and_discriminating() {
        assert_eq!(hash_of(&42u64), hash_of(&42u64));
        assert_ne!(hash_of(&42u64), hash_of(&43u64));
        assert_ne!(hash_of(&(1u32, 2u32)), hash_of(&(2u32, 1u32)));
    }

    #[test]
    fn byte_stream_tail_handled() {
        let mut a = FxHasher::default();
        a.write(&[1, 2, 3]);
        let mut b = FxHasher::default();
        b.write(&[1, 2, 3, 0]);
        // Different lengths zero-pad to the same word here; the point is
        // only that short tails hash without panicking and spread bits.
        assert_ne!(a.finish(), 0);
        assert_ne!(b.finish(), 0);
    }

    #[test]
    fn works_as_map_hasher_with_gate_like_keys() {
        #[derive(PartialEq, Eq, Hash)]
        enum K {
            A(u32, u32),
            B(u32),
        }
        let mut m: FxHashMap<K, usize> = FxHashMap::default();
        m.insert(K::A(1, 2), 10);
        m.insert(K::B(1), 20);
        m.insert(K::A(2, 1), 30);
        assert_eq!(m[&K::A(1, 2)], 10);
        assert_eq!(m[&K::B(1)], 20);
        assert_eq!(m.len(), 3);
    }

    #[test]
    fn set_dedups() {
        let mut s: FxHashSet<u64> = FxHashSet::default();
        for i in 0..100 {
            s.insert(i % 10);
        }
        assert_eq!(s.len(), 10);
    }
}
