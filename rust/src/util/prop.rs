//! Property-testing harness (proptest-lite).
//!
//! The vendored crate set has no `proptest`, so this module provides the
//! 20% that covers our needs: run a property over N random cases drawn
//! from explicit generators, and on failure report the seed + case index
//! so the exact counterexample replays deterministically.

use crate::util::Rng;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Env overrides let CI crank the case count up.
        let cases = std::env::var("PMLP_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("PMLP_PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xC0FFEE);
        PropConfig { cases, seed }
    }
}

/// Run `prop(rng, case_index)`; panic with a replayable seed on failure.
///
/// `prop` returns `Result<(), String>` — `Err` describes the violation.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    check_with(PropConfig::default(), name, prop)
}

/// Like [`check`] with explicit config.
pub fn check_with<F>(cfg: PropConfig, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // Each case gets an independent deterministic stream so a failure
        // replays without re-running earlier cases.
        let mut rng = Rng::new(cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case}/{} (seed {:#x}): {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Generator helpers used by property tests across the crate.
pub mod gen {
    use crate::util::{BitVec, Rng};

    /// Random vector of `n` integers in `[lo, hi)`.
    pub fn ints(rng: &mut Rng, n: usize, lo: i64, hi: i64) -> Vec<i64> {
        (0..n).map(|_| rng.range(lo, hi)).collect()
    }

    /// Random f64 vector in `[lo, hi)`.
    pub fn floats(rng: &mut Rng, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| lo + (hi - lo) * rng.f64()).collect()
    }

    /// Random bit vector of length `n` with density `p` of ones.
    pub fn bits(rng: &mut Rng, n: usize, p: f64) -> BitVec {
        let bools: Vec<bool> = (0..n).map(|_| rng.chance(p)).collect();
        BitVec::from_bools(&bools)
    }

    /// Random square cost matrix with entries in `[0, max)`.
    pub fn cost_matrix(rng: &mut Rng, n: usize, max: f64) -> Vec<Vec<f64>> {
        (0..n).map(|_| floats(rng, n, 0.0, max)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum commutes", |rng, _| {
            let a = rng.range(-1000, 1000);
            let b = rng.range(-1000, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a}+{b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_context() {
        check_with(
            PropConfig { cases: 3, seed: 1 },
            "always fails",
            |_, _| Err("nope".to_string()),
        );
    }

    #[test]
    fn cases_deterministic() {
        let mut first = Vec::new();
        check_with(PropConfig { cases: 5, seed: 7 }, "collect", |rng, _| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check_with(PropConfig { cases: 5, seed: 7 }, "collect", |rng, _| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
