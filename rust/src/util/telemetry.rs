//! Std-only observability: counters, gauges, spans, and a leveled
//! logging facade for the GA/synthesis/wave pipeline.
//!
//! Three kinds of signal, with *different determinism contracts*
//! (documented in DESIGN.md §6 and pinned by `rust/tests/telemetry.rs`
//! plus the counter suite in `rust/tests/ga_determinism.rs`):
//!
//! * **Counters** ([`Counter`]) count logical events that are a pure
//!   function of the evaluated work — genomes scored, memo probes,
//!   classify passes. Every instrumented event happens exactly once per
//!   logical item regardless of how items are scheduled across workers,
//!   so counter totals are **bit-identical between `--jobs 1` and
//!   `--jobs N`**, exactly like the `GaResult` itself.
//! * **Work stats** ([`Work`]) attribute *physical* work — dirty-cone
//!   sizes, rewrites, convergence prunes, lane-words simulated. These
//!   depend on which worker's arena served which genome, i.e. on
//!   scheduling, and are explicitly **not** part of the determinism
//!   contract (same as wall time). They are the per-stage cost
//!   attribution the perf roadmap items feed on.
//! * **Timers** — hierarchical spans ([`span`] / the `span!` macro)
//!   roll wall time up per dotted phase path. Wall time is never
//!   deterministic.
//!
//! ## Collection model (the per-worker counter blocks)
//!
//! Hot-path increments go to a plain thread-local [`Block`] — no atomics,
//! no locks, a few nanoseconds each. `util::threads::par_map_with`
//! merges every worker's block into the *calling thread's* block at the
//! writeback barrier (after the scope joins, before results are
//! returned), so totals always flow up the fan-out tree to the thread
//! that started the work. Because counter events are pure per item and
//! the merge is a commutative sum, the merged totals are independent of
//! worker count and scheduling. Tests read their own thread's block
//! ([`thread_block`]) and are therefore immune to concurrently running
//! tests in the same process.
//!
//! The global registry (relaxed atomics for counters/work/gauges, a
//! mutex-protected map for timers) is only touched by [`flush_thread`] /
//! [`snapshot`] / span drops — never on the per-genome hot path.
//!
//! ## Run report
//!
//! [`snapshot`] + [`metrics_json`] produce the stable-schema
//! `metrics.json` document (`pmlp run --metrics-out`, env
//! `PMLP_METRICS_OUT`); every counter/work/gauge name is always present
//! (zeros included) so downstream tooling can rely on the keys.
//!
//! ## Logging facade
//!
//! `PMLP_LOG=off|info|debug` (default `info`) gates [`info`]/[`debug`],
//! which absorb the pipeline's scattered `eprintln!`s. The default level
//! keeps the CLI's stderr byte-identical to the pre-facade output.

use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

/// Version tag of the `metrics.json` schema (see DESIGN.md §6).
pub const SCHEMA: &str = "pmlp.metrics/1";

// ---------------------------------------------------------------------------
// registry layout
// ---------------------------------------------------------------------------

/// Deterministic counters: totals are bit-identical for any `--jobs`
/// width (pure per logical item; see the module docs). Keep the enum,
/// [`N_COUNTERS`] and [`COUNTER_NAMES`] in lockstep — pinned by a test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// NSGA-II generations completed.
    GaGenerations,
    /// `ga::evaluate_parallel` invocations.
    GaEvaluateCalls,
    /// Genomes submitted for evaluation (pre-dedup).
    GaGenomesIn,
    /// Unique genomes actually fanned out (post-dedup).
    GaGenomesUnique,
    /// Circuit-evaluator fitness-memo hits.
    MemoHits,
    /// Circuit-evaluator fitness-memo misses (paid synthesis+sim).
    MemoMisses,
    /// `ShardedMap::get` probes.
    ShardedGets,
    /// `ShardedMap::get` probes that found an entry.
    ShardedHits,
    /// `ShardedMap::insert` calls.
    ShardedInserts,
    /// `IncrementalSynth::set_params` bindings (one per memo miss).
    SynthSetParams,
    /// Wave classification passes (`classify` / `classify_bus`).
    WaveClassifyCalls,
    /// Input vectors classified across all passes.
    WaveVectorsClassified,
    /// Dedicated toggle-activity simulations.
    WaveActivitySims,
    /// Final designs synthesized + analyzed by the coordinator.
    CoordDesignsSynthesized,
    /// Evaluated objective vectors that violated the `--max-delay`
    /// constraint. Deterministic: a pure function of the genome stream
    /// (every genome is counted once, on the GA thread, after its
    /// objectives come back), independent of worker scheduling.
    GaConstraintViolations,
}

pub const N_COUNTERS: usize = 15;

/// Dotted counter names, indexed by `Counter as usize` — the keys of the
/// `counters` section of `metrics.json`.
pub const COUNTER_NAMES: [&str; N_COUNTERS] = [
    "ga.generations",
    "ga.evaluate_calls",
    "ga.genomes_in",
    "ga.genomes_unique",
    "evaluator.memo_hits",
    "evaluator.memo_misses",
    "sharded.gets",
    "sharded.hits",
    "sharded.inserts",
    "synth.set_params",
    "wave.classify_calls",
    "wave.vectors_classified",
    "wave.activity_sims",
    "coordinator.designs_synthesized",
    "ga.constraint_violations",
];

/// Scheduling-dependent work attribution (NOT covered by the jobs
/// determinism contract — which worker's arena serves a genome decides
/// how much physical work it costs). Reported under `work` in
/// `metrics.json`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Work {
    /// From-scratch template passes (first binding of a worker state).
    SynthFullPasses,
    /// Cone-local re-synthesis passes (non-empty param deltas).
    SynthConePasses,
    /// Template nodes popped off dirty-cone worklists.
    SynthConeNodes,
    /// Popped nodes whose representative actually changed.
    SynthRewrites,
    /// Popped nodes whose representative converged (consumers pruned).
    SynthConvergencePrunes,
    /// Arena nodes newly wave-simulated (cache extensions).
    WaveNodesSimulated,
    /// `WaveCache` extensions that evaluated at least one new node.
    WaveCacheExtends,
    /// `WaveCache` extensions fully served from cached lane words.
    WaveCacheHits,
    /// Fresh incremental worker states constructed (pool misses).
    EvalStatesCreated,
    /// Worker states dropped by the arena-growth backstop.
    EvalArenaResets,
    /// Wave forward/extension passes over one lane block (any width).
    WaveBlockPasses,
    /// Shared-cone memo hits: cone groups whose repr vector was copied
    /// from a structurally-identical sibling instead of re-synthesized.
    SynthSharedConeHits,
    /// Shared-cone memo misses (cone group synthesized and memoized).
    SynthSharedConeMisses,
    /// Arena nodes whose arrival time was computed (once each, at emit
    /// time). Scheduling-dependent: which worker's arena first emits a
    /// node decides where its arrival is paid.
    SynthArrivalRecomputes,
    /// Invariant checks executed by `synth::verify` (`--verify
    /// boundaries|every-gen`, `pmlp lint`). Scheduling-dependent:
    /// boundary checkpoints fire once per evaluator worker, and the
    /// worker count follows `--jobs`.
    VerifyChecksRun,
    /// Violations those checks reported. Zero on every healthy run —
    /// the CI verify smoke leg asserts exactly that.
    VerifyViolations,
}

pub const N_WORK: usize = 16;

/// Dotted work-stat names, indexed by `Work as usize`.
pub const WORK_NAMES: [&str; N_WORK] = [
    "synth.full_passes",
    "synth.cone_passes",
    "synth.cone_nodes",
    "synth.rewrites",
    "synth.convergence_prunes",
    "wave.nodes_simulated",
    "wave.cache_extends",
    "wave.cache_hits",
    "evaluator.states_created",
    "evaluator.arena_resets",
    "wave.block_passes",
    "synth.shared_cone_hits",
    "synth.shared_cone_misses",
    "synth.arrival_recomputes",
    "verify.checks_run",
    "verify.violations",
];

/// Power-of-two buckets of the dirty-cone size histogram: bucket 0
/// counts empty cones, bucket `k >= 1` counts cones with
/// `2^(k-1) ..= 2^k - 1` recomputed nodes (last bucket absorbs the
/// overflow). Serialized as the `synth.cone_hist` array under `work`.
pub const CONE_HIST_BUCKETS: usize = 16;

/// Last-value gauges (relaxed atomics; no determinism claim — they are
/// point-in-time readings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// Population size after the most recent generation.
    GaPopulation,
    /// Size of the most recent GA Pareto front.
    GaFrontSize,
    /// Entries in the circuit evaluator's fitness memo after its GA run.
    MemoEntries,
}

pub const N_GAUGES: usize = 3;

/// Gauge names, indexed by `Gauge as usize`.
pub const GAUGE_NAMES: [&str; N_GAUGES] =
    ["ga.population", "ga.front_size", "evaluator.memo_entries"];

// ---------------------------------------------------------------------------
// per-worker counter blocks
// ---------------------------------------------------------------------------

/// One thread's accumulated counts — the per-worker counter block.
/// `util::threads::par_map_with` sums worker blocks into the caller's
/// at writeback; [`flush_thread`] sums a thread's block into the global
/// registry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Block {
    pub counters: [u64; N_COUNTERS],
    pub work: [u64; N_WORK],
    pub cone_hist: [u64; CONE_HIST_BUCKETS],
}

impl Default for Block {
    fn default() -> Block {
        Block {
            counters: [0; N_COUNTERS],
            work: [0; N_WORK],
            cone_hist: [0; CONE_HIST_BUCKETS],
        }
    }
}

impl Block {
    /// Elementwise sum — the (commutative, order-independent) merge.
    pub fn add(&mut self, other: &Block) {
        for (a, b) in self.counters.iter_mut().zip(&other.counters) {
            *a += *b;
        }
        for (a, b) in self.work.iter_mut().zip(&other.work) {
            *a += *b;
        }
        for (a, b) in self.cone_hist.iter_mut().zip(&other.cone_hist) {
            *a += *b;
        }
    }

    /// Elementwise difference vs an earlier copy of the same block —
    /// how tests capture exactly their own run's counts.
    pub fn delta(&self, since: &Block) -> Block {
        let mut out = Block::default();
        for (o, (a, b)) in out.counters.iter_mut().zip(self.counters.iter().zip(&since.counters))
        {
            *o = a.wrapping_sub(*b);
        }
        for (o, (a, b)) in out.work.iter_mut().zip(self.work.iter().zip(&since.work)) {
            *o = a.wrapping_sub(*b);
        }
        for (o, (a, b)) in
            out.cone_hist.iter_mut().zip(self.cone_hist.iter().zip(&since.cone_hist))
        {
            *o = a.wrapping_sub(*b);
        }
        out
    }

    /// The deterministic counters, paired with their names (what the
    /// jobs-determinism tests compare).
    pub fn counters_named(&self) -> Vec<(&'static str, u64)> {
        COUNTER_NAMES.iter().zip(&self.counters).map(|(n, v)| (*n, *v)).collect()
    }
}

thread_local! {
    static BLOCK: RefCell<Block> = RefCell::new(Block::default());
    /// Dotted path of the currently open span stack on this thread.
    static SPAN_PATH: RefCell<String> = const { RefCell::new(String::new()) };
}

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Toggle collection (counters, work stats, gauges, spans). Logging is
/// governed by `PMLP_LOG`, not by this switch. Used by the overhead
/// bench row pair; collection is on by default.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Bump a deterministic counter by `n` (thread-local; merged upward at
/// the `par_map_with` writeback).
#[inline]
pub fn count(c: Counter, n: u64) {
    if !enabled() {
        return;
    }
    BLOCK.with(|b| b.borrow_mut().counters[c as usize] += n);
}

/// Bump a scheduling-dependent work stat by `n`.
#[inline]
pub fn work(w: Work, n: u64) {
    if !enabled() {
        return;
    }
    BLOCK.with(|b| b.borrow_mut().work[w as usize] += n);
}

/// Record one dirty-cone pass of `nodes` recomputed nodes into the
/// power-of-two size histogram.
#[inline]
pub fn cone_size(nodes: usize) {
    if !enabled() {
        return;
    }
    let bucket = ((usize::BITS - nodes.leading_zeros()) as usize).min(CONE_HIST_BUCKETS - 1);
    BLOCK.with(|b| b.borrow_mut().cone_hist[bucket] += 1);
}

/// Copy of the current thread's block (tests: capture before/after a
/// run and [`Block::delta`] the two).
pub fn thread_block() -> Block {
    BLOCK.with(|b| b.borrow().clone())
}

/// Take (and zero) the current thread's block — the worker side of the
/// `par_map_with` merge.
pub fn take_thread_block() -> Block {
    BLOCK.with(|b| std::mem::take(&mut *b.borrow_mut()))
}

/// Sum a merged delta into the current thread's block — the caller side
/// of the `par_map_with` merge.
pub fn merge_into_thread(delta: &Block) {
    BLOCK.with(|b| b.borrow_mut().add(delta));
}

// ---------------------------------------------------------------------------
// global registry (relaxed atomics + timer map)
// ---------------------------------------------------------------------------

fn counter_totals() -> &'static [AtomicU64; N_COUNTERS] {
    static T: OnceLock<[AtomicU64; N_COUNTERS]> = OnceLock::new();
    T.get_or_init(|| std::array::from_fn(|_| AtomicU64::new(0)))
}

fn work_totals() -> &'static [AtomicU64; N_WORK] {
    static T: OnceLock<[AtomicU64; N_WORK]> = OnceLock::new();
    T.get_or_init(|| std::array::from_fn(|_| AtomicU64::new(0)))
}

fn cone_totals() -> &'static [AtomicU64; CONE_HIST_BUCKETS] {
    static T: OnceLock<[AtomicU64; CONE_HIST_BUCKETS]> = OnceLock::new();
    T.get_or_init(|| std::array::from_fn(|_| AtomicU64::new(0)))
}

fn gauge_cells() -> &'static [AtomicU64; N_GAUGES] {
    static T: OnceLock<[AtomicU64; N_GAUGES]> = OnceLock::new();
    T.get_or_init(|| std::array::from_fn(|_| AtomicU64::new(0)))
}

/// `(calls, total_ns)` per dotted span path.
fn timers() -> &'static Mutex<BTreeMap<String, (u64, u64)>> {
    static T: OnceLock<Mutex<BTreeMap<String, (u64, u64)>>> = OnceLock::new();
    T.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Lock the timer map, recovering from poisoning (a span drop during a
/// worker's unwind must never double-panic; the map is structurally
/// sound under any interleaving — same policy as the sharded memo).
fn lock_timers() -> MutexGuard<'static, BTreeMap<String, (u64, u64)>> {
    timers().lock().unwrap_or_else(PoisonError::into_inner)
}

/// Set a last-value gauge (relaxed store into the global registry).
pub fn gauge(g: Gauge, v: u64) {
    if !enabled() {
        return;
    }
    gauge_cells()[g as usize].store(v, Ordering::Relaxed);
}

/// Fold the current thread's block into the global registry (relaxed
/// adds) and zero it. Called by [`snapshot`]; worker threads never call
/// this — their blocks merge into the spawning thread instead.
pub fn flush_thread() {
    let b = take_thread_block();
    for (t, v) in counter_totals().iter().zip(&b.counters) {
        t.fetch_add(*v, Ordering::Relaxed);
    }
    for (t, v) in work_totals().iter().zip(&b.work) {
        t.fetch_add(*v, Ordering::Relaxed);
    }
    for (t, v) in cone_totals().iter().zip(&b.cone_hist) {
        t.fetch_add(*v, Ordering::Relaxed);
    }
}

/// Zero every counter, work stat, gauge, timer, and the current
/// thread's block. Test/bench scaffolding.
pub fn reset() {
    let _ = take_thread_block();
    for t in counter_totals() {
        t.store(0, Ordering::Relaxed);
    }
    for t in work_totals() {
        t.store(0, Ordering::Relaxed);
    }
    for t in cone_totals() {
        t.store(0, Ordering::Relaxed);
    }
    for t in gauge_cells() {
        t.store(0, Ordering::Relaxed);
    }
    lock_timers().clear();
}

// ---------------------------------------------------------------------------
// spans
// ---------------------------------------------------------------------------

/// An open span; records `(calls += 1, total_ns += elapsed)` under its
/// dotted path when dropped. Created by [`span`] / the `span!` macro.
pub struct Span {
    armed: bool,
    prev_len: usize,
    start: Instant,
}

/// Open a hierarchical span. Nesting builds the dotted path: a span
/// `"ga"` opened while `"pipeline"` is active rolls up under
/// `"pipeline.ga"`. Keep the guard alive for the phase:
/// `let _sp = span!("train");`.
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { armed: false, prev_len: 0, start: Instant::now() };
    }
    let prev_len = SPAN_PATH.with(|p| {
        let mut p = p.borrow_mut();
        let prev = p.len();
        if !p.is_empty() {
            p.push('.');
        }
        p.push_str(name);
        prev
    });
    Span { armed: true, prev_len, start: Instant::now() }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let elapsed_ns = self.start.elapsed().as_nanos() as u64;
        let path = SPAN_PATH.with(|p| {
            let mut p = p.borrow_mut();
            let full = p.clone();
            p.truncate(self.prev_len);
            full
        });
        let mut t = lock_timers();
        let cell = t.entry(path).or_insert((0, 0));
        cell.0 += 1;
        cell.1 += elapsed_ns;
    }
}

/// `span!("phase")` — sugar for [`span`], usable anywhere in the crate
/// (`crate::span!`) and by downstream users (`printed_mlp::span!`).
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::util::telemetry::span($name)
    };
}

// ---------------------------------------------------------------------------
// leveled logging facade
// ---------------------------------------------------------------------------

/// Log level of the facade (`PMLP_LOG`). Ordered: `Off < Info < Debug`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off,
    Info,
    Debug,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_lowercase().as_str() {
            "off" | "none" | "0" => Some(Level::Off),
            "info" | "1" => Some(Level::Info),
            "debug" | "2" => Some(Level::Debug),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// The facade's level: `PMLP_LOG` if set (warns loudly on a bad value,
/// per the env-reader policy), else `info` — which keeps the CLI's
/// stderr byte-identical to the pre-facade output.
pub fn log_level() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(|| match std::env::var("PMLP_LOG") {
        Ok(v) => Level::parse(&v).unwrap_or_else(|| {
            eprintln!("warning: bad PMLP_LOG '{v}' (off|info|debug); using info");
            Level::Info
        }),
        Err(_) => Level::Info,
    })
}

/// Whether messages at `level` are emitted.
pub fn log_enabled(level: Level) -> bool {
    level != Level::Off && level <= log_level()
}

/// Emit `[tag] msg` to stderr at info level.
pub fn info(tag: &str, msg: &str) {
    if log_enabled(Level::Info) {
        eprintln!("[{tag}] {msg}");
    }
}

/// Emit `[tag] msg` to stderr at debug level.
pub fn debug(tag: &str, msg: &str) {
    if log_enabled(Level::Debug) {
        eprintln!("[{tag}] {msg}");
    }
}

// ---------------------------------------------------------------------------
// snapshot + run report
// ---------------------------------------------------------------------------

/// A point-in-time reading of the whole registry.
#[derive(Clone, Debug)]
pub struct Metrics {
    pub counters: Vec<(&'static str, u64)>,
    pub work: Vec<(&'static str, u64)>,
    pub cone_hist: Vec<u64>,
    pub gauges: Vec<(&'static str, u64)>,
    /// `(dotted path, calls, total wall milliseconds)`.
    pub timers: Vec<(String, u64, f64)>,
}

impl Metrics {
    /// Subtract a `baseline` reading taken earlier in the same process,
    /// producing a *run-scoped* reading: counters, work stats, the cone
    /// histogram, and timer roll-ups become elementwise differences,
    /// while gauges keep their current (last-value) readings. Timer
    /// paths whose call count did not advance since the baseline are
    /// dropped, so phases that only ran in an earlier run don't appear.
    ///
    /// Both readings must come from [`snapshot`] in this process (the
    /// counter/work/gauge sections then share one fixed name order).
    pub fn minus(&self, baseline: &Metrics) -> Metrics {
        fn sub(
            cur: &[(&'static str, u64)],
            base: &[(&'static str, u64)],
        ) -> Vec<(&'static str, u64)> {
            cur.iter()
                .zip(base)
                .map(|((n, v), (bn, bv))| {
                    debug_assert_eq!(n, bn, "snapshot sections share one name order");
                    (*n, v.wrapping_sub(*bv))
                })
                .collect()
        }
        let cone_hist = self
            .cone_hist
            .iter()
            .zip(&baseline.cone_hist)
            .map(|(a, b)| a.wrapping_sub(*b))
            .collect();
        let timers = self
            .timers
            .iter()
            .filter_map(|(path, calls, ms)| {
                let (bc, bms) = baseline
                    .timers
                    .iter()
                    .find(|(p, _, _)| p == path)
                    .map(|(_, c, m)| (*c, *m))
                    .unwrap_or((0, 0.0));
                let dcalls = calls.wrapping_sub(bc);
                if dcalls == 0 {
                    None
                } else {
                    Some((path.clone(), dcalls, (ms - bms).max(0.0)))
                }
            })
            .collect();
        Metrics {
            counters: sub(&self.counters, &baseline.counters),
            work: sub(&self.work, &baseline.work),
            cone_hist,
            gauges: self.gauges.clone(),
            timers,
        }
    }
}

/// Capture the registry as the *baseline* of a run about to start (the
/// calling thread's block is flushed first, so earlier work on this
/// thread lands on the baseline side of the split). Pair with
/// [`snapshot_since`] to report per-run numbers: the global totals are
/// process-lifetime accumulators, so a second in-process run — a bench
/// loop, a repeated `run_pipeline`, every `pmlp serve` request — would
/// otherwise report everything since process start.
pub fn baseline() -> Metrics {
    snapshot()
}

/// [`snapshot`] scoped to the run that started at `baseline`: counters,
/// work stats, the cone histogram, and timers are since-the-baseline
/// deltas; gauges are current last-value readings (see
/// [`Metrics::minus`]).
pub fn snapshot_since(baseline: &Metrics) -> Metrics {
    snapshot().minus(baseline)
}

/// Flush the calling thread's block into the global registry and read
/// everything back. All fan-out work started (and joined) by this
/// thread is included — worker blocks merged upward at each
/// `par_map_with` writeback.
pub fn snapshot() -> Metrics {
    flush_thread();
    let counters = COUNTER_NAMES
        .iter()
        .zip(counter_totals())
        .map(|(n, a)| (*n, a.load(Ordering::Relaxed)))
        .collect();
    let work = WORK_NAMES
        .iter()
        .zip(work_totals())
        .map(|(n, a)| (*n, a.load(Ordering::Relaxed)))
        .collect();
    let cone_hist = cone_totals().iter().map(|a| a.load(Ordering::Relaxed)).collect();
    let gauges = GAUGE_NAMES
        .iter()
        .zip(gauge_cells())
        .map(|(n, a)| (*n, a.load(Ordering::Relaxed)))
        .collect();
    let timers = lock_timers()
        .iter()
        .map(|(path, (calls, ns))| (path.clone(), *calls, *ns as f64 / 1e6))
        .collect();
    Metrics { counters, work, cone_hist, gauges, timers }
}

/// Serialize a snapshot as the stable-schema `metrics.json` document
/// (schema [`SCHEMA`], layout documented in DESIGN.md §6). Every
/// counter/work/gauge key is always present; objects are `BTreeMap`s,
/// so the byte output is deterministic for a given snapshot.
pub fn metrics_json(m: &Metrics) -> Json {
    let pairs = |v: &[(&'static str, u64)]| -> Json {
        Json::Obj(v.iter().map(|(n, x)| (n.to_string(), Json::Num(*x as f64))).collect())
    };
    let mut work_obj = match pairs(&m.work) {
        Json::Obj(o) => o,
        _ => unreachable!(),
    };
    work_obj.insert(
        "synth.cone_hist".to_string(),
        Json::Arr(m.cone_hist.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    let timers = Json::Obj(
        m.timers
            .iter()
            .map(|(path, calls, ms)| {
                (
                    path.clone(),
                    Json::obj(vec![
                        ("calls", Json::Num(*calls as f64)),
                        ("total_ms", Json::Num(*ms)),
                    ]),
                )
            })
            .collect(),
    );
    Json::obj(vec![
        ("schema", Json::str(SCHEMA)),
        ("counters", pairs(&m.counters)),
        ("work", Json::Obj(work_obj)),
        ("gauges", pairs(&m.gauges)),
        ("timers_ms", timers),
        ("log_level", Json::str(log_level().label())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_tables_match_enum_arity() {
        // The last variant of each enum must index the last name slot.
        assert_eq!(Counter::GaConstraintViolations as usize, N_COUNTERS - 1);
        assert_eq!(Work::VerifyViolations as usize, N_WORK - 1);
        assert_eq!(Gauge::MemoEntries as usize, N_GAUGES - 1);
        assert_eq!(COUNTER_NAMES.len(), N_COUNTERS);
        assert_eq!(WORK_NAMES.len(), N_WORK);
        assert_eq!(GAUGE_NAMES.len(), N_GAUGES);
    }

    #[test]
    fn block_add_and_delta_are_elementwise() {
        let mut a = Block::default();
        a.counters[Counter::MemoHits as usize] = 3;
        a.work[Work::SynthRewrites as usize] = 5;
        a.cone_hist[2] = 7;
        let mut b = Block::default();
        b.counters[Counter::MemoHits as usize] = 10;
        b.add(&a);
        assert_eq!(b.counters[Counter::MemoHits as usize], 13);
        assert_eq!(b.work[Work::SynthRewrites as usize], 5);
        assert_eq!(b.cone_hist[2], 7);
        let d = b.delta(&a);
        assert_eq!(d.counters[Counter::MemoHits as usize], 10);
        assert_eq!(d.work[Work::SynthRewrites as usize], 0);
        assert_eq!(d.cone_hist[2], 0);
    }

    #[test]
    fn thread_block_captures_counts() {
        let before = thread_block();
        count(Counter::GaGenomesIn, 4);
        count(Counter::GaGenomesIn, 2);
        work(Work::WaveCacheHits, 1);
        let d = thread_block().delta(&before);
        assert_eq!(d.counters[Counter::GaGenomesIn as usize], 6);
        assert_eq!(d.work[Work::WaveCacheHits as usize], 1);
    }

    #[test]
    fn cone_hist_buckets_by_power_of_two() {
        let before = thread_block();
        cone_size(0); // bucket 0
        cone_size(1); // bucket 1
        cone_size(2); // bucket 2
        cone_size(3); // bucket 2
        cone_size(8); // bucket 4
        cone_size(usize::MAX); // clamped into the last bucket
        let d = thread_block().delta(&before);
        assert_eq!(d.cone_hist[0], 1);
        assert_eq!(d.cone_hist[1], 1);
        assert_eq!(d.cone_hist[2], 2);
        assert_eq!(d.cone_hist[4], 1);
        assert_eq!(d.cone_hist[CONE_HIST_BUCKETS - 1], 1);
    }

    #[test]
    fn spans_roll_up_under_nested_paths() {
        {
            let _outer = span("tspan_outer");
            {
                let _inner = span("tspan_inner");
            }
        }
        let t = lock_timers();
        let (calls, ns) = t.get("tspan_outer").copied().expect("outer span recorded");
        assert!(calls >= 1);
        let (icalls, _) = t.get("tspan_outer.tspan_inner").copied().expect("nested path");
        assert!(icalls >= 1);
        // Elapsed is monotonic (can be 0 ns on coarse clocks, never bogus).
        let _ = ns;
    }

    #[test]
    fn span_path_restored_after_drop() {
        {
            let _a = span("tspan_a");
        }
        // Path must be back to this thread's pre-span state, so a later
        // span roots at the same depth.
        {
            let _b = span("tspan_b");
        }
        let t = lock_timers();
        assert!(t.contains_key("tspan_b"), "second span must not nest under a dropped one");
    }

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("off"), Some(Level::Off));
        assert_eq!(Level::parse("INFO"), Some(Level::Info));
        assert_eq!(Level::parse("Debug"), Some(Level::Debug));
        assert_eq!(Level::parse("2"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Off < Level::Info && Level::Info < Level::Debug);
    }

    #[test]
    fn metrics_minus_scopes_to_the_run() {
        let base = Metrics {
            counters: COUNTER_NAMES.iter().map(|n| (*n, 10u64)).collect(),
            work: WORK_NAMES.iter().map(|n| (*n, 20u64)).collect(),
            cone_hist: vec![5; CONE_HIST_BUCKETS],
            gauges: GAUGE_NAMES.iter().map(|n| (*n, 7u64)).collect(),
            timers: vec![
                ("pipeline".to_string(), 1, 100.0),
                ("old_phase".to_string(), 3, 9.0),
            ],
        };
        let now = Metrics {
            counters: COUNTER_NAMES.iter().map(|n| (*n, 14u64)).collect(),
            work: WORK_NAMES.iter().map(|n| (*n, 26u64)).collect(),
            cone_hist: vec![8; CONE_HIST_BUCKETS],
            gauges: GAUGE_NAMES.iter().map(|n| (*n, 9u64)).collect(),
            timers: vec![
                ("pipeline".to_string(), 2, 150.0),
                ("new_phase".to_string(), 1, 2.5),
                // Ran only before the baseline: calls unchanged.
                ("old_phase".to_string(), 3, 9.0),
            ],
        };
        let d = now.minus(&base);
        // Counters/work/cone_hist subtract elementwise ...
        assert!(d.counters.iter().all(|(_, v)| *v == 4));
        assert!(d.work.iter().all(|(_, v)| *v == 6));
        assert!(d.cone_hist.iter().all(|&v| v == 3));
        // ... gauges stay last-value ...
        assert!(d.gauges.iter().all(|(_, v)| *v == 9));
        // ... and timers subtract per path, dropping stale phases.
        assert_eq!(
            d.timers,
            vec![("pipeline".to_string(), 1, 50.0), ("new_phase".to_string(), 1, 2.5)]
        );
    }

    #[test]
    fn snapshot_since_reports_per_run_counts() {
        // Two simulated in-process "runs" on this thread: each must see
        // only its own counts — the accumulation bug this API fixes.
        // Only this thread's block is flushed, so concurrent tests in
        // the binary can't perturb the deltas of counters they don't
        // flush; we still restrict the assertions to our own increments.
        let b1 = baseline();
        count(Counter::CoordDesignsSynthesized, 3);
        let r1 = snapshot_since(&b1);
        let b2 = baseline();
        count(Counter::CoordDesignsSynthesized, 5);
        let r2 = snapshot_since(&b2);
        let of = |m: &Metrics| {
            m.counters
                .iter()
                .find(|(n, _)| *n == "coordinator.designs_synthesized")
                .map(|(_, v)| *v)
                .unwrap()
        };
        assert_eq!(of(&r1), 3, "first run sees only its own counts");
        assert_eq!(of(&r2), 5, "second run must not accumulate the first");
    }

    #[test]
    fn metrics_json_has_stable_sections() {
        let m = Metrics {
            counters: COUNTER_NAMES.iter().map(|n| (*n, 1u64)).collect(),
            work: WORK_NAMES.iter().map(|n| (*n, 2u64)).collect(),
            cone_hist: vec![0; CONE_HIST_BUCKETS],
            gauges: GAUGE_NAMES.iter().map(|n| (*n, 3u64)).collect(),
            timers: vec![("pipeline.ga".to_string(), 4, 5.5)],
        };
        let j = metrics_json(&m);
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(SCHEMA));
        let counters = j.get("counters").and_then(Json::as_obj).expect("counters obj");
        assert_eq!(counters.len(), N_COUNTERS);
        let work = j.get("work").and_then(Json::as_obj).expect("work obj");
        assert_eq!(work.len(), N_WORK + 1, "work stats + the cone histogram");
        assert_eq!(
            work.get("synth.cone_hist").and_then(Json::as_arr).map(<[Json]>::len),
            Some(CONE_HIST_BUCKETS)
        );
        let timers = j.get("timers_ms").and_then(Json::as_obj).expect("timers obj");
        assert_eq!(
            timers.get("pipeline.ga").and_then(|t| t.get("calls")).and_then(Json::as_f64),
            Some(4.0)
        );
        // Round-trip through the serializer/parser pair is lossless.
        let back = Json::parse(&j.to_string_pretty()).expect("parses");
        assert_eq!(back, j);
    }
}
