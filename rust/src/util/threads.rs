//! Thread-parallel map over an index range using `std::thread::scope`.
//!
//! This is the parallel substrate of the GA evaluation loop and of the
//! Table II synthesis sweep (no rayon in the vendored crate set). Work is
//! distributed through a shared atomic cursor (dynamic scheduling);
//! results come back in index order, so any reduction over them is
//! deterministic regardless of how items were interleaved across workers.
//!
//! Two entry points:
//!
//! * [`par_map`] — stateless `f(i)` per item;
//! * [`par_map_with`] — each worker thread first builds its own scratch
//!   state via `init()` and threads it through every item it claims.
//!   This is what lets each GA evaluation worker own a private
//!   incremental-synthesis arena + wave cache (`runtime::evaluator`)
//!   without any locking on the hot path.

use crate::util::telemetry;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};

/// Number of worker threads to use (env `PMLP_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PMLP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Worker count of the GA evaluation fan-out when the caller asked for
/// "auto" (`--jobs 0`): env `PMLP_JOBS` overrides (CI uses this to run
/// the whole test suite at fixed serial/concurrent widths), otherwise
/// [`default_threads`].
pub fn default_jobs() -> usize {
    if let Ok(v) = std::env::var("PMLP_JOBS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    default_threads()
}

/// Parallel map `f(i)` for `i in 0..n`, preserving order of results.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_with(n, threads, || (), move |_, i| f(i))
}

/// Parallel map with per-worker scratch state: every worker thread calls
/// `init()` once, then evaluates `f(&mut state, i)` for each index it
/// claims off the shared cursor. Results preserve index order.
///
/// `S` needs no `Send`/`Sync` bound — each state is created, used and
/// dropped entirely on its worker thread. With `threads <= 1` (or a
/// single item) everything runs on the caller's thread through one
/// state, so serial and parallel execution traverse identical per-item
/// code paths.
///
/// Panic behavior: a panicking `init`/`f` kills only its own worker; the
/// remaining workers keep draining the cursor, and `std::thread::scope`
/// re-raises the panic on the calling thread after every worker has
/// joined. The map can therefore never hang on a panic — callers see it
/// propagate (pinned by tests here and in `crate::ga`). Worker states
/// whose `Drop` runs during that unwinding must not panic themselves
/// (a second panic aborts the process) — which is why the evaluator
/// pool leases and the sharded memo recover from mutex poisoning
/// instead of unwrapping.
// One of the crate's two sanctioned `unsafe` sites (the crate root is
// `#![deny(unsafe_code)]`): the disjoint-slot writes through `SendPtr`
// below, justified at the block.
#[allow(unsafe_code)]
pub fn par_map_with<S, T, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let cursor = AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    // Writeback point of the telemetry counter blocks: each worker's
    // thread-local block is summed in here when it finishes draining the
    // cursor, and the total flows into the *calling* thread's block after
    // the scope joins. Counter events are pure per item and the sum is
    // commutative, so the merged totals are identical for any worker
    // count — the jobs-1 == jobs-N contract of `util::telemetry`.
    // (A panicking worker's block is lost, but the panic re-raises on the
    // caller anyway, so no run report is ever built from it.)
    let merged = Mutex::new(telemetry::Block::default());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let iref = &init;
            let fref = &f;
            let cref = &cursor;
            let optr = &out_ptr;
            let mref = &merged;
            scope.spawn(move || {
                let mut state = iref();
                loop {
                    let i = cref.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = fref(&mut state, i);
                    // SAFETY: each index i is claimed exactly once by the
                    // atomic fetch_add, so no two threads write the same
                    // slot, and the scope guarantees the vec outlives the
                    // workers.
                    unsafe {
                        *optr.0.add(i) = Some(v);
                    }
                }
                let block = telemetry::take_thread_block();
                mref.lock().unwrap_or_else(PoisonError::into_inner).add(&block);
            });
        }
    });
    let merged = merged.into_inner().unwrap_or_else(PoisonError::into_inner);
    telemetry::merge_into_thread(&merged);
    out.into_iter().map(|x| x.expect("worker filled slot")).collect()
}

struct SendPtr<T>(*mut T);
// SAFETY: only `par_map_with` constructs a `SendPtr`, and its workers
// write disjoint slots claimed via the atomic cursor (see the block's
// SAFETY note); the pointee vec outlives the thread scope.
#[allow(unsafe_code)]
unsafe impl<T> Sync for SendPtr<T> {}
#[allow(unsafe_code)]
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let serial: Vec<usize> = (0..1000).map(|i| i * i).collect();
        let parallel = par_map(1000, 8, |i| i * i);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_map_single_thread() {
        let v = par_map(10, 1, |i| i + 1);
        assert_eq!(v, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty() {
        let v: Vec<usize> = par_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn par_map_uneven_work() {
        // Items with wildly different cost still produce ordered results.
        let v = par_map(64, 8, |i| {
            let mut acc = 0u64;
            for k in 0..(i * 1000) {
                acc = acc.wrapping_add(k as u64);
            }
            (i, acc)
        });
        for (i, item) in v.iter().enumerate() {
            assert_eq!(item.0, i);
        }
    }

    #[test]
    fn par_map_with_threads_state() {
        // Per-worker accumulators: every item is tagged with a state that
        // only its own worker mutated, and results stay index-ordered.
        let v = par_map_with(
            200,
            4,
            || 0usize,
            |seen, i| {
                *seen += 1;
                (i, *seen)
            },
        );
        let mut per_worker_total = 0;
        for (i, item) in v.iter().enumerate() {
            assert_eq!(item.0, i);
            per_worker_total = per_worker_total.max(item.1);
        }
        // Some worker processed at least ceil(200/4) items.
        assert!(per_worker_total >= 200 / 4);
    }

    #[test]
    fn par_map_with_serial_uses_one_state() {
        let v = par_map_with(5, 1, || 0usize, |s, i| {
            *s += 1;
            (*s, i)
        });
        assert_eq!(v.iter().map(|p| p.0).collect::<Vec<_>>(), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn par_map_with_matches_serial_results() {
        let serial = par_map_with(300, 1, || (), |_, i| i * 3);
        let parallel = par_map_with(300, 8, || (), |_, i| i * 3);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn worker_panic_propagates_instead_of_hanging() {
        // One poisoned item out of many: the panic must reach the caller
        // (scope join re-raises it) rather than deadlocking the map, and
        // a subsequent map on the same thread must be unaffected.
        let r = std::panic::catch_unwind(|| {
            par_map(64, 4, |i| {
                if i == 37 {
                    panic!("poisoned item");
                }
                i * 2
            })
        });
        assert!(r.is_err(), "worker panic must propagate");
        let v = par_map(8, 4, |i| i + 1);
        assert_eq!(v, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn init_panic_propagates() {
        let r = std::panic::catch_unwind(|| {
            par_map_with(16, 4, || panic!("init bomb"), |_: &mut (), i| i)
        });
        assert!(r.is_err());
    }

    #[test]
    fn telemetry_counts_merge_width_independent() {
        // Worker counter blocks merge into the calling thread's block at
        // writeback, so the caller-visible delta is identical whether the
        // 257 items ran serially or across 8 workers.
        use crate::util::telemetry::{self, Counter};
        let run = |threads: usize| {
            let before = telemetry::thread_block();
            par_map(257, threads, |i| {
                telemetry::count(Counter::MemoHits, 1);
                i
            });
            telemetry::thread_block().delta(&before)
        };
        let serial = run(1);
        let parallel = run(8);
        assert_eq!(serial, parallel);
        assert_eq!(serial.counters[Counter::MemoHits as usize], 257);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
        assert!(default_jobs() >= 1);
    }
}
