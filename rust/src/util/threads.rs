//! Thread-parallel map over an index range using `std::thread::scope`.
//!
//! This is the parallel substrate of the GA evaluation loop and of the
//! Table II synthesis sweep (no rayon in the vendored crate set). Work is
//! distributed by chunking the index space; results come back in order.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (env `PMLP_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PMLP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel map `f(i)` for `i in 0..n`, preserving order of results.
///
/// Uses dynamic (work-stealing-ish) scheduling through a shared atomic
/// cursor so unevenly sized items (e.g. netlist synthesis of different
/// chromosomes) balance well.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let cursor = AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let fref = &f;
            let cref = &cursor;
            let optr = &out_ptr;
            scope.spawn(move || loop {
                let i = cref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = fref(i);
                // SAFETY: each index i is claimed exactly once by the
                // atomic fetch_add, so no two threads write the same slot,
                // and the scope guarantees the vec outlives the workers.
                unsafe {
                    *optr.0.add(i) = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|x| x.expect("worker filled slot")).collect()
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let serial: Vec<usize> = (0..1000).map(|i| i * i).collect();
        let parallel = par_map(1000, 8, |i| i * i);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_map_single_thread() {
        let v = par_map(10, 1, |i| i + 1);
        assert_eq!(v, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty() {
        let v: Vec<usize> = par_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn par_map_uneven_work() {
        // Items with wildly different cost still produce ordered results.
        let v = par_map(64, 8, |i| {
            let mut acc = 0u64;
            for k in 0..(i * 1000) {
                acc = acc.wrapping_add(k as u64);
            }
            (i, acc)
        });
        for (i, item) in v.iter().enumerate() {
            assert_eq!(item.0, i);
        }
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
