//! Minimal JSON value model, parser, and pretty-printer.
//!
//! Used by the config system (`configs/*.json`), run manifests, and the
//! reporting harnesses. Supports the full JSON grammar except `\u` escapes
//! for astral-plane surrogate pairs are combined pairwise.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- constructors -------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ----- accessors ----------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `get` chained with f64 extraction, with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    // ----- serialization ------------------------------------------------
    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ----- parsing ------------------------------------------------------
    /// Parse a JSON document (must consume the whole input).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos:?}")),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {}", *pos));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {}", *pos))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {}", *pos));
    }
    *pos += 1;
    let mut out = String::new();
    let mut pending_hi: Option<u16> = None;
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                let esc = *b.get(*pos).ok_or("truncated escape")?;
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .map_err(|_| "bad \\u escape")?;
                        let code = u16::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape")?;
                        *pos += 4;
                        if let Some(hi) = pending_hi.take() {
                            let c = 0x10000
                                + ((hi as u32 - 0xD800) << 10)
                                + (code as u32 - 0xDC00);
                            out.push(char::from_u32(c).ok_or("bad surrogate pair")?);
                        } else if (0xD800..0xDC00).contains(&code) {
                            pending_hi = Some(code);
                        } else {
                            out.push(char::from_u32(code as u32).ok_or("bad codepoint")?);
                        }
                    }
                    _ => return Err(format!("bad escape \\{}", esc as char)),
                }
            }
            _ => {
                // Consume one UTF-8 scalar.
                let s = std::str::from_utf8(&b[*pos..]).map_err(|_| "invalid utf-8")?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && (b[*pos].is_ascii_digit() || matches!(b[*pos], b'.' | b'e' | b'E' | b'+' | b'-')) {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad number")?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("invalid number '{text}' at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("name", Json::str("cardio")),
            ("layers", Json::arr(vec![Json::num(21), Json::num(3), Json::num(3)])),
            ("lr", Json::num(0.01)),
            ("qat", Json::Bool(true)),
            ("note", Json::Null),
        ]);
        let s = v.to_string_pretty();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested() {
        let s = r#"{"a": [1, 2, {"b": -3.5e2}], "c": "x\ny\"z"}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_f64(), Some(-350.0));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny\"z"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} garbage").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("{\"a\": }").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v, Json::Str("é😀".to_string()));
    }

    #[test]
    fn compact_is_parseable() {
        let v = Json::arr(vec![Json::str("a"), Json::num(1), Json::Bool(false)]);
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn accessor_defaults() {
        let v = Json::parse(r#"{"x": 5}"#).unwrap();
        assert_eq!(v.usize_or("x", 0), 5);
        assert_eq!(v.usize_or("missing", 7), 7);
        assert_eq!(v.str_or("missing", "d"), "d");
    }

    #[test]
    fn integer_format_stable() {
        // Integers must not be serialized with a fractional part: the
        // python side reads these configs too.
        assert_eq!(Json::num(1000).to_string_compact(), "1000");
        assert_eq!(Json::num(0.5).to_string_compact(), "0.5");
    }
}
