//! Packed bit vector — the representation of accumulation-approximation
//! chromosomes (one bit per summand bit of every adder tree in the MLP;
//! `1` = keep the summand bit, `0` = replace it by constant zero).

/// A fixed-length vector of bits packed into `u64` words.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    /// All-zero bit vector of length `len`.
    pub fn zeros(len: usize) -> Self {
        BitVec { words: vec![0; len.div_ceil(64)], len }
    }

    /// All-one bit vector of length `len`.
    pub fn ones(len: usize) -> Self {
        let mut v = BitVec { words: vec![!0u64; len.div_ceil(64)], len };
        v.mask_tail();
        v
    }

    /// Build from a bool slice.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut v = BitVec::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i, true);
            }
        }
        v
    }

    /// Zero out the unused bits of the last word (invariant keeper).
    fn mask_tail(&mut self) {
        let rem = self.len % 64;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    /// Number of bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if the vector has length zero.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Get bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Set bit `i` to `b`.
    #[inline]
    pub fn set(&mut self, i: usize, b: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i / 64];
        if b {
            *w |= 1u64 << (i % 64);
        } else {
            *w &= !(1u64 << (i % 64));
        }
    }

    /// Flip bit `i`.
    #[inline]
    pub fn flip(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] ^= 1u64 << (i % 64);
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Number of cleared bits.
    pub fn count_zeros(&self) -> usize {
        self.len - self.count_ones()
    }

    /// Iterator over all bits.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }

    /// Hamming distance to another vector of the same length.
    pub fn hamming(&self, other: &BitVec) -> usize {
        assert_eq!(self.len, other.len);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a ^ b).count_ones() as usize)
            .sum()
    }

    /// Raw words (read-only; tail bits beyond `len` are zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

impl std::fmt::Debug for BitVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BitVec[{}; ", self.len)?;
        for i in 0..self.len.min(64) {
            write!(f, "{}", if self.get(i) { '1' } else { '0' })?;
        }
        if self.len > 64 {
            write!(f, "…")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zeros_and_ones() {
        let z = BitVec::zeros(130);
        assert_eq!(z.count_ones(), 0);
        assert_eq!(z.len(), 130);
        let o = BitVec::ones(130);
        assert_eq!(o.count_ones(), 130);
        assert_eq!(o.count_zeros(), 0);
    }

    #[test]
    fn set_get_flip() {
        let mut v = BitVec::zeros(100);
        v.set(0, true);
        v.set(63, true);
        v.set(64, true);
        v.set(99, true);
        assert!(v.get(0) && v.get(63) && v.get(64) && v.get(99));
        assert_eq!(v.count_ones(), 4);
        v.flip(63);
        assert!(!v.get(63));
        assert_eq!(v.count_ones(), 3);
    }

    #[test]
    fn ones_tail_is_masked() {
        let o = BitVec::ones(65);
        assert_eq!(o.count_ones(), 65);
        assert_eq!(o.words()[1], 1);
    }

    #[test]
    fn from_bools_roundtrip() {
        let mut r = Rng::new(1);
        let bits: Vec<bool> = (0..300).map(|_| r.chance(0.5)).collect();
        let v = BitVec::from_bools(&bits);
        let back: Vec<bool> = v.iter().collect();
        assert_eq!(bits, back);
    }

    #[test]
    fn hamming_distance() {
        let a = BitVec::from_bools(&[true, false, true, true]);
        let b = BitVec::from_bools(&[true, true, true, false]);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.hamming(&a), 0);
    }
}
