//! Deterministic pseudo-random number generation (xoshiro256**).
//!
//! All stochastic stages of the framework (dataset synthesis, weight
//! initialization, genetic operators, stochastic-computing bitstreams)
//! draw from this generator so that every experiment is reproducible from
//! a single seed recorded in the run config.

/// xoshiro256** 1.0 by Blackman & Vigna (public domain reference
/// implementation, ported). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state, as
/// recommended by the xoshiro authors.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the (astronomically unlikely) all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (n > 0), via Lemire's method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 64-bit multiply-shift; bias is negligible for n << 2^64 and the
        // framework never draws ranges anywhere near that.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (no caching; simplicity over speed —
    /// dataset generation is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should be hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let mut t = s.clone();
        t.sort();
        t.dedup();
        assert_eq!(t.len(), 10);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(17);
        assert!((0..100).all(|_| !r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}
