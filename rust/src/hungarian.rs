//! Kuhn–Munkres (Hungarian) assignment solver — paper §III-C2 uses it to
//! pick which output-neuron pair is compared at each comparator of the
//! approximate Argmax tree, minimizing the total number of compared bits.
//!
//! O(n³) shortest-augmenting-path formulation (Jonker-Volgenant style
//! potentials). Minimizes total cost of a perfect matching on a square
//! cost matrix. The paper's matrices are at most 16×16, but the solver is
//! exact and general.

/// Solve the min-cost assignment problem.
///
/// `cost[i][j]` = cost of assigning row `i` to column `j`. Returns
/// `(assignment, total)` where `assignment[i]` is the column matched to
/// row `i`.
pub fn solve(cost: &[Vec<f64>]) -> (Vec<usize>, f64) {
    let n = cost.len();
    if n == 0 {
        return (Vec::new(), 0.0);
    }
    for row in cost {
        assert_eq!(row.len(), n, "cost matrix must be square");
    }
    const INF: f64 = f64::INFINITY;
    // 1-indexed potentials per the classic formulation.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    // p[j] = row matched to column j (0 = none yet).
    let mut p = vec![0usize; n + 1];
    let mut way = vec![0usize; n + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // Augment along the path.
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assignment = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            assignment[p[j] - 1] = j - 1;
        }
    }
    let total = (0..n).map(|i| cost[i][assignment[i]]).sum();
    (assignment, total)
}

/// Brute-force solver for testing (n ≤ 9).
#[cfg(test)]
pub fn solve_brute(cost: &[Vec<f64>]) -> f64 {
    let n = cost.len();
    let mut cols: Vec<usize> = (0..n).collect();
    let mut best = f64::INFINITY;
    permute(&mut cols, 0, &mut |perm| {
        let total: f64 = perm.iter().enumerate().map(|(i, &j)| cost[i][j]).sum();
        if total < best {
            best = total;
        }
    });
    best
}

#[cfg(test)]
fn permute(xs: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
    if k == xs.len() {
        f(xs);
        return;
    }
    for i in k..xs.len() {
        xs.swap(k, i);
        permute(xs, k + 1, f);
        xs.swap(k, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, gen};

    #[test]
    fn identity_matrix_prefers_diagonal_zeros() {
        let cost = vec![
            vec![0.0, 1.0, 1.0],
            vec![1.0, 0.0, 1.0],
            vec![1.0, 1.0, 0.0],
        ];
        let (a, total) = solve(&cost);
        assert_eq!(a, vec![0, 1, 2]);
        assert_eq!(total, 0.0);
    }

    #[test]
    fn known_small_case() {
        // Classic example: optimal is 1+2+2 = 5? Verify by brute force.
        let cost = vec![
            vec![4.0, 1.0, 3.0],
            vec![2.0, 0.0, 5.0],
            vec![3.0, 2.0, 2.0],
        ];
        let (_, total) = solve(&cost);
        assert_eq!(total, solve_brute(&cost));
        assert_eq!(total, 5.0);
    }

    #[test]
    fn empty_matrix() {
        let (a, t) = solve(&[]);
        assert!(a.is_empty());
        assert_eq!(t, 0.0);
    }

    #[test]
    fn single_cell() {
        let (a, t) = solve(&[vec![7.5]]);
        assert_eq!(a, vec![0]);
        assert_eq!(t, 7.5);
    }

    #[test]
    fn assignment_is_permutation() {
        let cost = vec![
            vec![9.0, 2.0, 7.0, 8.0],
            vec![6.0, 4.0, 3.0, 7.0],
            vec![5.0, 8.0, 1.0, 8.0],
            vec![7.0, 6.0, 9.0, 4.0],
        ];
        let (a, _) = solve(&cost);
        let mut s = a.clone();
        s.sort();
        assert_eq!(s, vec![0, 1, 2, 3]);
    }

    #[test]
    fn prop_matches_brute_force() {
        prop::check("hungarian == brute force", |rng, _| {
            let n = 2 + rng.below(5); // up to 6x6
            let cost = gen::cost_matrix(rng, n, 100.0);
            let (a, total) = solve(&cost);
            // Assignment must be a permutation.
            let mut s = a.clone();
            s.sort();
            if s != (0..n).collect::<Vec<_>>() {
                return Err(format!("not a permutation: {a:?}"));
            }
            let brute = solve_brute(&cost);
            if (total - brute).abs() > 1e-9 {
                return Err(format!("total {total} != brute {brute}"));
            }
            Ok(())
        });
    }

    #[test]
    fn handles_16x16_fast() {
        // The paper's largest matrix (Arrhythmia: 16 output neurons).
        let mut rng = crate::util::Rng::new(3);
        let cost = gen::cost_matrix(&mut rng, 16, 50.0);
        let (a, total) = solve(&cost);
        assert_eq!(a.len(), 16);
        assert!(total.is_finite());
    }
}
