//! `pmlp` — the printed-MLP approximation framework CLI (Layer-3 leader
//! entrypoint).
//!
//! Subcommands:
//!   list                         show built-in dataset configs
//!   run      --dataset <name>    full pipeline (train → GA → argmax →
//!                                synthesis → battery report)
//!   train    --dataset <name>    training + QAT only
//!   gen-data --dataset <name>    dump the synthetic dataset as CSV
//!   repro    --exp <id>          regenerate a paper table/figure
//!                                (table2|table3|table4|table5|fig4|fig5|all)
//!   ablation --dataset <name>    PJRT-vs-native evaluator throughput
//!   serve    [--addr HOST:PORT]  resident design service: line-delimited
//!                                JSON requests over stdio (default) or
//!                                TCP, warm studies across requests,
//!                                per-request pmlp.metrics/1 deltas
//!   lint     --dataset <name>    standalone invariant verification: run
//!                                every `synth::verify` check over the
//!                                dataset's template and a deterministic
//!                                chain of incremental re-synthesis states
//!
//! Shared flags: --scale smoke|small|paper,
//! --backend auto|pjrt|native|circuit (`circuit` scores GA fitness on the
//! synthesized netlist via the bit-parallel wave simulator),
//! --synth incremental|full (circuit backend: template + cone-local
//! incremental re-synthesis, the default, or from-scratch per
//! chromosome — bit-identical outputs),
//! --jobs N (GA evaluation worker threads; 0 = auto; any value yields
//! bit-identical results),
//! --islands K (deterministic GA evaluation islands with ring migration
//! at fixed generation boundaries; results and telemetry counters are
//! bit-identical for every K — default 1),
//! --lane-width 64|256 (circuit backend: wave-simulator lanes per pass —
//! 256-lane blocks by default, 64 is the legacy width; bit-identical),
//! --share-cones on|off (circuit backend: generation-scoped shared-cone
//! evaluation in the incremental engine, default on; bit-identical),
//! --verify off|boundaries|every-gen (circuit backend: structural
//! invariant checks — off by default, at generation boundaries, or after
//! every chromosome re-synthesis; violations are counted and logged,
//! never panicked on),
//! --objective fa|area|power|delay|area+power|area+power+delay (GA cost
//! axes; measured ones need the circuit backend),
//! --max-delay <ms> (hard timing cap on the delay axis; defaults to the
//! dataset clock budget when a delay objective is active),
//! --out <file> (JSON for `run`, text otherwise), --pop/--gens overrides.

use anyhow::{anyhow, bail, Result};
use printed_mlp::accum::GenomeMap;
use printed_mlp::bench::{self, Scale, Study};
use printed_mlp::config::{builtin, RunConfig};
use printed_mlp::coordinator::{EvalBackend, Pipeline, PipelineOpts};
use printed_mlp::datasets;
use printed_mlp::egfet::CostObjective;
use printed_mlp::netlist::mlp::{build_mlp_template, ArgmaxMode};
use printed_mlp::report;
use printed_mlp::sim::wave;
use printed_mlp::synth::incremental::IncrementalSynth;
use printed_mlp::synth::verify::{self, VerifyMode};
use printed_mlp::synth::SynthMode;
use printed_mlp::util::telemetry;
use printed_mlp::util::Rng;
use std::collections::HashMap; // detlint: allow-file(std-hash) — CLI flag map, point lookups only

/// The `--profile` stderr report: counters, work stats, the dirty-cone
/// histogram, and span wall-time roll-ups, as aligned tables.
fn render_profile(m: &telemetry::Metrics) -> String {
    let mut out = String::new();
    let kv = |pairs: &[(&'static str, u64)]| -> Vec<Vec<String>> {
        pairs.iter().map(|(n, v)| vec![n.to_string(), v.to_string()]).collect()
    };
    out.push_str(&report::render_table(
        "profile: counters (deterministic across --jobs)",
        &["counter", "count"],
        &kv(&m.counters),
    ));
    out.push_str(&report::render_table(
        "profile: work (scheduling-dependent)",
        &["stat", "count"],
        &kv(&m.work),
    ));
    let hist: Vec<Vec<String>> = m
        .cone_hist
        .iter()
        .enumerate()
        .filter(|(_, &v)| v > 0)
        .map(|(k, &v)| {
            let range = match k {
                0 => "0".to_string(),
                _ if k == telemetry::CONE_HIST_BUCKETS - 1 => format!("{}+", 1u64 << (k - 1)),
                _ => format!("{}..{}", 1u64 << (k - 1), (1u64 << k) - 1),
            };
            vec![range, v.to_string()]
        })
        .collect();
    if !hist.is_empty() {
        out.push_str(&report::render_table(
            "profile: dirty-cone size histogram (nodes recomputed per pass)",
            &["cone size", "passes"],
            &hist,
        ));
    }
    let timers: Vec<Vec<String>> = m
        .timers
        .iter()
        .map(|(path, calls, ms)| vec![path.clone(), calls.to_string(), format!("{ms:.1}")])
        .collect();
    if !timers.is_empty() {
        out.push_str(&report::render_table(
            "profile: spans (wall clock, non-deterministic)",
            &["span", "calls", "total ms"],
            &timers,
        ));
    }
    out
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1).peekable();
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{k}'"))?
                .to_string();
            // Valueless flags (`--profile`, `--no-baseline`) must not
            // swallow a following `--flag` as their value.
            let val = match it.peek() {
                Some(next) if !next.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            flags.insert(key, val);
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn scale(&self) -> Result<Scale> {
        let s = self.get("scale").unwrap_or("small");
        Scale::parse(s).ok_or_else(|| anyhow!("bad --scale '{s}' (smoke|small|paper)"))
    }

    fn backend(&self) -> Result<EvalBackend> {
        let s = self.get("backend").unwrap_or("auto");
        EvalBackend::parse(s)
            .ok_or_else(|| anyhow!("bad --backend '{s}' (auto|pjrt|native|circuit)"))
    }

    fn synth(&self) -> Result<SynthMode> {
        let s = self.get("synth").unwrap_or("incremental");
        SynthMode::parse(s).ok_or_else(|| anyhow!("bad --synth '{s}' (incremental|full)"))
    }

    fn objective(&self) -> Result<CostObjective> {
        let s = self.get("objective").unwrap_or("fa");
        // The detailed parser names the offending segment and carries
        // the canonical option list (egfet::OBJECTIVE_OPTIONS) — one
        // source of truth, no hand-kept copies of the choices here.
        CostObjective::parse_detailed(s).map_err(|e| anyhow!("bad --objective: {e}"))
    }

    fn max_delay(&self) -> Result<Option<f64>> {
        match self.get("max-delay") {
            None => Ok(None),
            Some(s) => {
                let ms: f64 =
                    s.parse().map_err(|_| anyhow!("bad --max-delay '{s}' (milliseconds)"))?;
                if !(ms > 0.0) {
                    bail!("bad --max-delay '{s}' (must be a positive number of milliseconds)");
                }
                Ok(Some(ms))
            }
        }
    }

    fn jobs(&self) -> Result<usize> {
        Ok(self.get("jobs").map(|v| v.parse()).transpose()?.unwrap_or(0))
    }

    fn islands(&self) -> Result<usize> {
        let k: usize = self.get("islands").map(|v| v.parse()).transpose()?.unwrap_or(1);
        if k == 0 {
            bail!("bad --islands '0' (need at least one island)");
        }
        Ok(k)
    }

    fn lane_width(&self) -> Result<wave::LaneWidth> {
        match self.get("lane-width") {
            None => Ok(wave::LaneWidth::default()),
            Some(s) => {
                wave::LaneWidth::parse(s).ok_or_else(|| anyhow!("bad --lane-width '{s}' (64|256)"))
            }
        }
    }

    fn verify(&self) -> Result<VerifyMode> {
        let s = self.get("verify").unwrap_or("off");
        VerifyMode::parse(s)
            .ok_or_else(|| anyhow!("bad --verify '{s}' (off|boundaries|every-gen)"))
    }

    fn share_cones(&self) -> Result<bool> {
        match self.get("share-cones").unwrap_or("on") {
            "on" | "true" => Ok(true),
            "off" | "false" => Ok(false),
            s => Err(anyhow!("bad --share-cones '{s}' (on|off)")),
        }
    }

    fn cfg(&self) -> Result<RunConfig> {
        let name = self.get("dataset").unwrap_or("cardio");
        let mut cfg = if let Some(path) = self.get("config") {
            RunConfig::load(std::path::Path::new(path))?
        } else {
            builtin::by_name(name).ok_or_else(|| {
                anyhow!(
                    "unknown dataset '{name}' (try: {})",
                    builtin::paper_names().join(", ")
                )
            })?
        };
        if let Some(p) = self.get("pop") {
            cfg.ga.population = p.parse()?;
        }
        if let Some(g) = self.get("gens") {
            cfg.ga.generations = g.parse()?;
        }
        Ok(cfg)
    }

    fn emit(&self, text: &str) -> Result<()> {
        println!("{text}");
        if let Some(path) = self.get("out") {
            std::fs::write(path, text)?;
            eprintln!("(written to {path})");
        }
        Ok(())
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "list" => {
            let mut rows = Vec::new();
            for cfg in builtin::all() {
                rows.push(vec![
                    cfg.dataset.name.clone(),
                    format!(
                        "({},{},{})",
                        cfg.topology.n_in, cfg.topology.n_hidden, cfg.topology.n_out
                    ),
                    format!("{}", cfg.topology.n_params()),
                    format!("{}", cfg.dataset.n_samples),
                    format!("{}", cfg.dataset.n_classes),
                    format!("{} ms", cfg.hw.clock_ms),
                ]);
            }
            args.emit(&report::render_table(
                "built-in configurations",
                &["name", "topology", "params", "samples", "classes", "clock"],
                &rows,
            ))
        }
        "run" => {
            let cfg = args.cfg()?;
            let opts = PipelineOpts {
                backend: args.backend()?,
                synth: args.synth()?,
                objective: args.objective()?,
                max_delay_ms: args.max_delay()?,
                jobs: args.jobs()?,
                islands: args.islands()?,
                lane_width: args.lane_width()?,
                share_cones: args.share_cones()?,
                verify: args.verify()?,
                max_hw_points: args
                    .get("hw-points")
                    .map(|v| v.parse())
                    .transpose()?
                    .unwrap_or(4),
                synth_baseline: args.get("no-baseline").is_none(),
                approx_argmax: args.get("no-argmax").is_none(),
                verbose: true,
            };
            // Baseline the telemetry store before the pipeline so the
            // metrics document is scoped to *this* run — in-process
            // embedders (and `pmlp serve`) get per-run deltas instead of
            // ever-accumulating process totals.
            let metrics_base = telemetry::baseline();
            let result = Pipeline::new(cfg, opts).run()?;
            // Human summary.
            let mut rows = Vec::new();
            if let Some(hw) = &result.baseline_hw {
                rows.push(vec![
                    "baseline [8]".to_string(),
                    format!("{:.3}", result.baseline_acc_test),
                    report::hw_cell(hw),
                    String::new(),
                ]);
            }
            rows.push(vec![
                "QAT only".to_string(),
                format!("{:.3}", result.trained.acc_q_test),
                report::hw_cell(&result.qat_hw),
                String::new(),
            ]);
            for d in &result.designs {
                rows.push(vec![
                    format!("ours (FA {})", d.area_fa),
                    format!("{:.3}", d.acc_test_full),
                    report::hw_cell(&d.hw_full),
                    format!(
                        "0.6V: {:.3} mW -> {}",
                        d.hw_0p6v.power_mw,
                        d.power_source.label()
                    ),
                ]);
            }
            let summary = report::render_table(
                &format!(
                    "pipeline [{}] (backend: {}, objective: {})",
                    result.cfg.dataset.name,
                    result.backend_used,
                    result.objective.label()
                ),
                &["design", "test acc", "1V hardware", "battery"],
                &rows,
            );
            println!("{summary}");
            if let Some(path) = args.get("out") {
                std::fs::write(path, report::result_to_json(&result).to_string_pretty())?;
                eprintln!("(JSON written to {path})");
            }
            // Structured run report: --metrics-out <file> (or env
            // PMLP_METRICS_OUT), plus --profile for the human table.
            let metrics_path = args
                .get("metrics-out")
                .map(str::to_string)
                .or_else(|| std::env::var("PMLP_METRICS_OUT").ok().filter(|s| !s.is_empty()));
            let want_profile = args.get("profile").is_some();
            if metrics_path.is_some() || want_profile {
                let metrics = telemetry::snapshot_since(&metrics_base);
                if let Some(path) = &metrics_path {
                    let doc = telemetry::metrics_json(&metrics).to_string_pretty();
                    std::fs::write(path, doc)?;
                    eprintln!("(metrics written to {path})");
                }
                if want_profile {
                    eprint!("{}", render_profile(&metrics));
                }
            }
            Ok(())
        }
        "train" => {
            let cfg = args.cfg()?;
            let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
            let tm = printed_mlp::train::train_native(&cfg, &split, &qtrain, &qtest);
            args.emit(&format!(
                "dataset {}: float test acc {:.3}, QAT train acc {:.3}, QAT test acc {:.3}, act_shift {}",
                cfg.dataset.name,
                tm.acc_float_test,
                tm.acc_q_train,
                tm.acc_q_test,
                tm.qmlp.act_shift
            ))
        }
        "gen-data" => {
            let cfg = args.cfg()?;
            let ds = datasets::generate(&cfg.dataset);
            let mut csv = String::new();
            for (row, &y) in ds.x.iter().zip(&ds.y) {
                for v in row {
                    csv.push_str(&format!("{v:.5},"));
                }
                csv.push_str(&format!("{y}\n"));
            }
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &csv)?;
                    println!("wrote {} samples to {path}", ds.y.len());
                }
                None => print!("{csv}"),
            }
            Ok(())
        }
        "repro" => {
            let exp = args.get("exp").unwrap_or("all");
            let scale = args.scale()?;
            let backend = args.backend()?;
            let objective = args.objective()?;
            if objective.is_measured() && backend != EvalBackend::Circuit {
                bail!(
                    "--objective {} requires --backend circuit",
                    objective.label()
                );
            }
            let mut study = Study::new(scale, backend).with_objective(objective);
            let mut out = String::new();
            let want = |id: &str| exp == "all" || exp == id;
            if want("table2") {
                out.push_str(&bench::table2(scale));
            }
            if want("table3") {
                out.push_str(&bench::table3(&mut study));
            }
            if want("fig4") {
                out.push_str(&bench::fig4(&mut study));
            }
            if want("table4") {
                out.push_str(&bench::table4(&mut study));
            }
            if want("fig5") {
                out.push_str(&bench::fig5(&mut study));
            }
            if want("table5") {
                out.push_str(&bench::table5(&mut study));
            }
            if out.is_empty() {
                bail!("unknown --exp '{exp}' (table2|table3|table4|table5|fig4|fig5|all)");
            }
            args.emit(&out)
        }
        "ablation" => {
            let name = args.get("dataset").unwrap_or("cardio");
            let n = args.get("n").map(|v| v.parse()).transpose()?.unwrap_or(64);
            args.emit(&bench::ablation_evaluators(name, n))
        }
        "serve" => {
            // Resident design service: line-delimited JSON requests,
            // one response line each (Pareto report + per-request
            // pmlp.metrics/1 delta), over stdio by default or a TCP
            // listener with --addr. Studies (trained model, synthesis
            // template, evaluator memos, design kernels) stay warm
            // across requests; EOF / peer close is the clean shutdown.
            match args.get("addr") {
                Some(addr) => printed_mlp::coordinator::serve::serve_tcp(addr)?,
                None => printed_mlp::coordinator::serve::serve_stdio()?,
            }
            Ok(())
        }
        "lint" => {
            // Standalone invariant verification: every `synth::verify`
            // check over the dataset's MLP template and a deterministic
            // GA-like chain of incremental re-synthesis states (exact
            // genome, one random genome, then `--rounds` triple-bit-flip
            // mutations). Exit status is the result: 0 clean, 1 if any
            // structural invariant is violated.
            let cfg = args.cfg()?;
            let name = cfg.dataset.name.clone();
            let rounds =
                args.get("rounds").map(|v| v.parse::<usize>()).transpose()?.unwrap_or(12);
            let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
            let tm = printed_mlp::train::train_native(&cfg, &split, &qtrain, &qtest);
            let qmlp = &tm.qmlp;
            let map = GenomeMap::new(qmlp);
            let tpl = build_mlp_template(qmlp, &ArgmaxMode::Exact);
            let mut violations = verify::verify_template(&tpl, Some(map.len()));
            let mut states = 1usize;
            let mut synth = IncrementalSynth::new(tpl);
            synth.set_share_cones(true);
            let mut rng = Rng::new(7);
            let mut g = map.exact_genome();
            synth.set_params(&g);
            violations.extend(verify::verify_arena(&synth, Some(map.len())));
            states += 1;
            g = map.random_genome(&mut rng, 0.75);
            synth.set_params(&g);
            violations.extend(verify::verify_arena(&synth, Some(map.len())));
            states += 1;
            for _ in 0..rounds {
                for _ in 0..3 {
                    g.flip(rng.below(map.len()));
                }
                synth.set_params(&g);
                violations.extend(verify::verify_arena(&synth, Some(map.len())));
                states += 1;
            }
            if violations.is_empty() {
                args.emit(&format!(
                    "lint [{name}]: clean — all invariant checks passed over \
                     {states} template/arena states ({} genome bits)",
                    map.len()
                ))
            } else {
                for v in &violations {
                    eprintln!("{v}");
                }
                bail!(
                    "lint [{name}]: {} violation(s) across {states} states",
                    violations.len()
                );
            }
        }
        "help" | "--help" | "-h" => {
            println!(
                "pmlp — printed-MLP holistic approximation framework (ICCAD'23 reproduction)\n\n\
                 usage: pmlp <command> [--flags]\n\n\
                 commands:\n  \
                 list                      built-in dataset configs\n  \
                 run --dataset <name>      full pipeline [--backend auto|pjrt|native|circuit] [--jobs N] [--pop N] [--gens N] [--out r.json]\n                            \
                 [--metrics-out m.json] [--profile]\n                            \
                 (--metrics-out / env PMLP_METRICS_OUT writes the stable-schema\n                            \
                 telemetry document [counters, work stats, span wall times —\n                            \
                 schema 'pmlp.metrics/1', see DESIGN.md §6]; --profile prints\n                            \
                 the same as human tables on stderr; env PMLP_LOG=off|info|debug\n                            \
                 sets the log level [default info]; counters are bit-identical\n                            \
                 for any --jobs width, wall times are not;\n                            \
                 (backend 'circuit' = circuit-in-the-loop: GA fitness measured on the\n                            \
                 synthesized gate-level netlist via the bit-parallel wave\n                            \
                 simulator — 256 vectors per pass in [u64;4] lane blocks;\n                            \
                 --lane-width 64|256 selects the lanes per pass [256 default,\n                            \
                 64 = legacy single-word engine; bit-identical results];\n                            \
                 --synth incremental|full selects template cone-local re-synthesis\n                            \
                 [default, same bits, re-synth cost scales with mutation size]\n                            \
                 or from-scratch synthesis per chromosome;\n                            \
                 --share-cones on|off [default on] shares structurally identical\n                            \
                 dirty-cone results across a generation's chromosomes in the\n                            \
                 incremental engine — work-saving only, bit-identical results;\n                            \
                 --verify off|boundaries|every-gen [default off] runs the\n                            \
                 structural invariant checks of synth::verify on the circuit\n                            \
                 backend: never, on each worker's arena at generation\n                            \
                 boundaries, or after every chromosome re-synthesis —\n                            \
                 violations are counted in the 'verify.violations' work stat\n                            \
                 and logged, never panicked on; results stay bit-identical;\n                            \
                 --objective fa|area|power|delay|area+power|area+power+delay\n                            \
                 selects the GA's cost axes: the full-adder surrogate\n                            \
                 [default, backend-portable] or — circuit backend only —\n                            \
                 measured EGFET cell area / dynamic power / critical-path\n                            \
                 delay of each chromosome's synthesized survivor\n                            \
                 (toggle activity measured on the train stimulus, paper's\n                            \
                 VCS step; delay read off the incremental arena's arrival\n                            \
                 table, bit-identical to from-scratch timing); compound\n                            \
                 objectives are order-insensitive ('power+area' == \n                            \
                 'area+power') and optimize the measured axes jointly as a\n                            \
                 3- or 4-objective front from the same synthesis pass;\n                            \
                 --max-delay <ms> [delay objectives only] caps the delay\n                            \
                 axis via constrained domination so every front member\n                            \
                 meets timing [default: the dataset's clock budget];\n                            \
                 --jobs N = GA evaluation worker threads, 0/auto by default —\n                            \
                 each worker owns its own synth arena + wave cache and any\n                            \
                 width produces bit-identical results;\n                            \
                 --islands K [default 1] shards each generation's unique\n                            \
                 genomes over K evaluation islands with deterministic\n                            \
                 ring migration at fixed generation boundaries and a\n                            \
                 Pareto-union merge — results and telemetry counters are\n                            \
                 bit-identical for every K and every --jobs)\n  \
                 train --dataset <name>    training + QAT only\n  \
                 serve [--addr HOST:PORT]  resident design service: one JSON request per line\n                            \
                 ({{\"dataset\": ..., \"objective\": ..., \"ga\": {{...}},\n                            \
                 \"max_delay_ms\": ..., \"jobs\": ..., \"islands\": ...}}), one\n                            \
                 response line each (Pareto front + designs + per-request\n                            \
                 pmlp.metrics/1 delta); stdio by default, TCP with --addr\n                            \
                 (port 0 announces the bound port); studies — trained\n                            \
                 model, synthesis template, evaluator fitness memos with\n                            \
                 parked survivor hardware, design kernels — stay warm\n                            \
                 across requests, so a repeated request reports\n                            \
                 designs_synthesized = 0; EOF is the clean shutdown\n  \
                 gen-data --dataset <name> dump synthetic dataset CSV [--out f.csv]\n  \
                 repro --exp <id>          regenerate table2|table3|table4|table5|fig4|fig5|all [--scale smoke|small|paper]\n  \
                 ablation --dataset <name> evaluator throughput (native vs PJRT vs circuit) [--n N]\n  \
                 lint --dataset <name>     standalone invariant verification [--rounds N, default 12]:\n                            \
                 every synth::verify check over the dataset's template and a\n                            \
                 deterministic chain of incremental re-synthesis states;\n                            \
                 exits 1 and prints each violation if any check fires\n                            \
                 (source-level determinism lint is the separate `detlint` binary)"
            );
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `pmlp help`)"),
    }
}
