//! Approximate Argmax (paper §III-C2).
//!
//! The output layer's activation is an argmax implemented as a tree of
//! comparators. The approximation (a) selects, per comparison, the
//! minimum *subset of bits* that keeps train accuracy within 0.5%
//! (greedy, MSB first), and (b) chooses *which* neurons meet at each
//! comparator with the Hungarian algorithm on the bits-kept cost matrix,
//! exploiting correlations between neuron outputs. The procedure repeats
//! stage by stage down the tree.
//!
//! Comparators operate on the biased (offset-binary) form of the signed
//! pre-activations — `u = z + 2^(W-1)` — so an unsigned masked compare is
//! hardware-exact. Ties keep the lower-index operand, which makes the
//! exact tree equivalent to `argmax` with ties-to-lowest.

use crate::hungarian;
use crate::util::stats::mean;

/// One comparator: compares previous-stage slots `a` and `b` (slot
/// indices) using only the bits set in `mask` (full width = exact).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CmpNode {
    pub a: usize,
    pub b: usize,
    pub mask: u64,
}

/// A full (possibly approximate) argmax comparator tree.
#[derive(Clone, Debug)]
pub struct ArgmaxPlan {
    /// Number of competitors (output neurons).
    pub n: usize,
    /// Comparator bit width (two's-complement width of the inputs).
    pub width: u32,
    /// Stages of comparators. Stage `s` consumes the slot list of stage
    /// `s-1` (stage 0 consumes the neurons); a slot not referenced by any
    /// comparator in a stage gets a bye into the next stage, in order.
    pub stages: Vec<Vec<CmpNode>>,
}

impl ArgmaxPlan {
    /// The exact tree: adjacent pairing `(0,1), (2,3)…`, full-width masks.
    pub fn exact(n: usize, width: u32) -> ArgmaxPlan {
        let full = full_mask(width);
        let mut stages = Vec::new();
        let mut slots = n;
        while slots > 1 {
            let stage: Vec<CmpNode> = (0..slots / 2)
                .map(|k| CmpNode { a: 2 * k, b: 2 * k + 1, mask: full })
                .collect();
            let next = stage.len() + slots % 2;
            stages.push(stage);
            slots = next;
        }
        ArgmaxPlan { n, width, stages }
    }

    /// Winner (original neuron index) for one vector of pre-activations.
    pub fn predict(&self, z: &[i64]) -> usize {
        debug_assert_eq!(z.len(), self.n);
        let bias = 1i64 << (self.width - 1);
        // Slots carry (neuron id, biased value).
        let mut slots: Vec<(usize, u64)> = z
            .iter()
            .enumerate()
            .map(|(i, &v)| (i, (v + bias) as u64))
            .collect();
        for stage in &self.stages {
            let mut used = vec![false; slots.len()];
            let mut next = Vec::with_capacity(stage.len() + 1);
            for cmp in stage {
                let (ia, va) = slots[cmp.a];
                let (ib, vb) = slots[cmp.b];
                used[cmp.a] = true;
                used[cmp.b] = true;
                // Masked compare; ties keep the lower slot (a).
                if (vb & cmp.mask) > (va & cmp.mask) {
                    next.push((ib, vb));
                } else {
                    next.push((ia, va));
                }
            }
            for (k, slot) in slots.iter().enumerate() {
                if !used[k] {
                    next.push(*slot); // bye
                }
            }
            slots = next;
        }
        slots[0].0
    }

    /// Accuracy of the plan over precomputed output pre-activations.
    pub fn accuracy(&self, preacts: &[Vec<i64>], labels: &[usize]) -> f64 {
        if labels.is_empty() {
            return 0.0;
        }
        let correct = preacts
            .iter()
            .zip(labels)
            .filter(|(z, &y)| self.predict(z) == y)
            .count();
        correct as f64 / labels.len() as f64
    }

    /// Total number of compared bits across all comparators.
    pub fn total_bits(&self) -> u64 {
        self.stages
            .iter()
            .flatten()
            .map(|c| c.mask.count_ones() as u64)
            .sum()
    }

    /// Number of comparators.
    pub fn n_comparators(&self) -> usize {
        self.stages.iter().map(Vec::len).sum()
    }

    /// Average comparator size (bits), and the reduction factor vs the
    /// full width (Table IV's "Avg. Comparator Size Reduction").
    pub fn comparator_stats(&self) -> (f64, f64) {
        let sizes: Vec<f64> = self
            .stages
            .iter()
            .flatten()
            .map(|c| c.mask.count_ones() as f64)
            .collect();
        if sizes.is_empty() {
            return (0.0, 1.0);
        }
        let avg = mean(&sizes);
        (avg, self.width as f64 / avg.max(1.0))
    }
}

fn full_mask(width: u32) -> u64 {
    if width >= 64 {
        !0u64
    } else {
        (1u64 << width) - 1
    }
}

/// Search configuration.
#[derive(Clone, Debug)]
pub struct ArgmaxSearchOpts {
    /// Maximum train-accuracy drop tolerated while discarding a bit
    /// (paper: 0.5%).
    pub acc_guard: f64,
}

impl Default for ArgmaxSearchOpts {
    fn default() -> Self {
        ArgmaxSearchOpts { acc_guard: 0.005 }
    }
}

/// Build an approximate argmax plan from the train-set output
/// pre-activations (paper §III-C2, run after the accumulation
/// approximation because it depends on the output distribution).
pub fn build_plan(
    preacts: &[Vec<i64>],
    labels: &[usize],
    width: u32,
    opts: &ArgmaxSearchOpts,
) -> ArgmaxPlan {
    let n = preacts.first().map(Vec::len).unwrap_or(0);
    let mut plan = ArgmaxPlan::exact(n, width);
    if n < 2 {
        return plan;
    }
    let base_acc = plan.accuracy(preacts, labels);

    // Stage by stage: choose pairing + per-pair masks.
    for stage_idx in 0..plan.stages.len() {
        let n_slots = stage_slot_count(&plan, stage_idx);
        // --- 1. per-ordered-pair greedy minimum bit subsets
        // cost[i][j] = bits kept when slots i and j are compared
        // approximately (everything else exact).
        let mut masks = vec![vec![full_mask(width); n_slots]; n_slots];
        let mut cost = vec![vec![f64::INFINITY; n_slots]; n_slots];
        for i in 0..n_slots {
            for j in (i + 1)..n_slots {
                let mask = greedy_mask(
                    &plan, stage_idx, i, j, width, preacts, labels, base_acc, opts,
                );
                masks[i][j] = mask;
                masks[j][i] = mask;
                let bits = mask.count_ones() as f64;
                cost[i][j] = bits;
                cost[j][i] = bits;
            }
        }
        // Self-assignment is forbidden.
        for (i, row) in cost.iter_mut().enumerate() {
            row[i] = 1e9;
        }
        // --- 2. Hungarian assignment -> minimum-cost pairing
        let (assignment, _) = hungarian::solve(&cost);
        let pairs = assignment_to_pairs(&assignment);
        // --- 3. rewrite this stage with the chosen pairs + masks
        let mut stage: Vec<CmpNode> = pairs
            .iter()
            .take(n_slots / 2)
            .map(|&(a, b)| CmpNode { a, b, mask: masks[a][b] })
            .collect();
        stage.sort_by_key(|c| c.a);
        plan.stages[stage_idx] = stage;
    }
    plan
}

/// Number of input slots of stage `stage_idx`.
fn stage_slot_count(plan: &ArgmaxPlan, stage_idx: usize) -> usize {
    let mut slots = plan.n;
    for s in 0..stage_idx {
        slots = plan.stages[s].len() + (slots - 2 * plan.stages[s].len());
    }
    slots
}

/// Greedy MSB-first bit discarding for the comparison of slots `i`,`j`
/// at stage `stage_idx`, keeping all other comparisons as currently
/// planned (paper: "the rest comparisons are performed accurately").
#[allow(clippy::too_many_arguments)]
fn greedy_mask(
    plan: &ArgmaxPlan,
    stage_idx: usize,
    i: usize,
    j: usize,
    width: u32,
    preacts: &[Vec<i64>],
    labels: &[usize],
    base_acc: f64,
    opts: &ArgmaxSearchOpts,
) -> u64 {
    // Trial plan: current plan with stage `stage_idx` re-paired so that
    // (i, j) meet; remaining slots pair adjacently (exact masks).
    let mut trial = plan.clone();
    let n_slots = stage_slot_count(plan, stage_idx);
    let mut rest: Vec<usize> = (0..n_slots).filter(|&s| s != i && s != j).collect();
    let mut stage = vec![CmpNode { a: i.min(j), b: i.max(j), mask: full_mask(width) }];
    while rest.len() >= 2 {
        let a = rest.remove(0);
        let b = rest.remove(0);
        stage.push(CmpNode { a, b, mask: full_mask(width) });
    }
    stage.sort_by_key(|c| c.a);
    // Later stages revert to exact adjacent pairing of the right size.
    let tail = ArgmaxPlan::exact(stage.len() + n_slots % 2, width).stages;
    trial.stages.truncate(stage_idx);
    trial.stages.push(stage);
    trial.stages.extend(tail);

    let target_idx = trial.stages[stage_idx]
        .iter()
        .position(|c| c.a == i.min(j) && c.b == i.max(j))
        .expect("pair present");

    let mut mask = full_mask(width);
    for bit in (0..width).rev() {
        let candidate = mask & !(1u64 << bit);
        trial.stages[stage_idx][target_idx].mask = candidate;
        let acc = trial.accuracy(preacts, labels);
        if acc >= base_acc - opts.acc_guard {
            mask = candidate;
        }
    }
    // Never return an empty mask: a 0-bit comparator is a constant, keep
    // at least one bit so the node remains a comparator.
    if mask == 0 {
        mask = 1;
    }
    mask
}

/// Turn a Hungarian assignment (a permutation) into disjoint pairs:
/// mutual assignments pair directly; longer cycles pair consecutive
/// members. Each slot appears in at most one pair.
fn assignment_to_pairs(assignment: &[usize]) -> Vec<(usize, usize)> {
    let n = assignment.len();
    let mut visited = vec![false; n];
    let mut pairs = Vec::new();
    for start in 0..n {
        if visited[start] {
            continue;
        }
        // Walk the cycle containing `start`.
        let mut cycle = Vec::new();
        let mut cur = start;
        while !visited[cur] {
            visited[cur] = true;
            cycle.push(cur);
            cur = assignment[cur];
        }
        // Pair consecutive members of the cycle.
        let mut k = 0;
        while k + 1 < cycle.len() {
            pairs.push((cycle[k].min(cycle[k + 1]), cycle[k].max(cycle[k + 1])));
            k += 2;
        }
    }
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_preacts(rng: &mut Rng, n_samples: usize, n: usize, width: u32) -> Vec<Vec<i64>> {
        let span = 1i64 << (width - 1);
        (0..n_samples)
            .map(|_| (0..n).map(|_| rng.range(-span + 1, span)).collect())
            .collect()
    }

    #[test]
    fn exact_plan_matches_argmax() {
        let mut rng = Rng::new(1);
        let width = 12;
        let plan = ArgmaxPlan::exact(5, width);
        for _ in 0..500 {
            let z: Vec<i64> = (0..5).map(|_| rng.range(-2000, 2000)).collect();
            assert_eq!(plan.predict(&z), crate::model::quantized::argmax_i(&z));
        }
    }

    #[test]
    fn exact_plan_handles_ties_like_argmax() {
        let plan = ArgmaxPlan::exact(4, 8);
        assert_eq!(plan.predict(&[5, 5, 5, 5]), 0);
        assert_eq!(plan.predict(&[1, 7, 7, 2]), 1);
        assert_eq!(plan.predict(&[-1, -1, 0, 0]), 2);
    }

    #[test]
    fn exact_plan_structure() {
        let plan = ArgmaxPlan::exact(10, 8);
        // 10 -> 5 -> (2 cmps + bye) 3 -> (1 + bye) 2 -> 1.
        let sizes: Vec<usize> = plan.stages.iter().map(Vec::len).collect();
        assert_eq!(sizes, vec![5, 2, 1, 1]);
        assert_eq!(plan.n_comparators(), 9); // n-1 comparators always
    }

    #[test]
    fn n_comparators_is_n_minus_1() {
        for n in 2..=16 {
            let plan = ArgmaxPlan::exact(n, 8);
            assert_eq!(plan.n_comparators(), n - 1, "n={n}");
        }
    }

    #[test]
    fn single_class_trivial() {
        let plan = ArgmaxPlan::exact(1, 8);
        assert_eq!(plan.predict(&[42]), 0);
        assert!(plan.stages.is_empty());
    }

    #[test]
    fn build_plan_keeps_accuracy_within_guard() {
        // Synthetic task: neuron y has the max for label y, with margins
        // drawn wide so many LSBs are discardable.
        let mut rng = Rng::new(7);
        let n = 4;
        let width = 14;
        let n_samples = 400;
        let mut preacts = Vec::new();
        let mut labels = Vec::new();
        for _ in 0..n_samples {
            let y = rng.below(n);
            let mut z: Vec<i64> = (0..n).map(|_| rng.range(-2000, 1000)).collect();
            z[y] = rng.range(2500, 6000); // clear winner
            preacts.push(z);
            labels.push(y);
        }
        let exact = ArgmaxPlan::exact(n, width);
        let base = exact.accuracy(&preacts, &labels);
        assert!(base > 0.99);
        let plan = build_plan(&preacts, &labels, width, &ArgmaxSearchOpts::default());
        let acc = plan.accuracy(&preacts, &labels);
        assert!(acc >= base - 0.03, "acc {acc} vs base {base}");
        // With wide margins the comparators must have shrunk a lot.
        assert!(
            plan.total_bits() < exact.total_bits() / 2,
            "bits {} vs exact {}",
            plan.total_bits(),
            exact.total_bits()
        );
    }

    #[test]
    fn build_plan_structure_valid() {
        let mut rng = Rng::new(3);
        let preacts = random_preacts(&mut rng, 200, 6, 10);
        let labels: Vec<usize> = (0..200).map(|_| rng.below(6)).collect();
        let plan = build_plan(&preacts, &labels, 10, &ArgmaxSearchOpts::default());
        assert_eq!(plan.n_comparators(), 5);
        // Every comparator mask is non-empty.
        for stage in &plan.stages {
            for cmp in stage {
                assert!(cmp.mask != 0);
                assert!(cmp.a < cmp.b);
            }
        }
        // Predictions stay in range.
        for z in preacts.iter().take(20) {
            assert!(plan.predict(z) < 6);
        }
    }

    #[test]
    fn assignment_to_pairs_mutual_and_cycles() {
        // Permutation (0<->1)(2<->3): mutual pairs.
        assert_eq!(assignment_to_pairs(&[1, 0, 3, 2]), vec![(0, 1), (2, 3)]);
        // 3-cycle 0->1->2->0: pairs (0,1), 2 left over.
        assert_eq!(assignment_to_pairs(&[1, 2, 0]), vec![(0, 1)]);
        // 4-cycle 0->1->2->3->0: pairs (0,1),(2,3).
        assert_eq!(assignment_to_pairs(&[1, 2, 3, 0]), vec![(0, 1), (2, 3)]);
    }

    #[test]
    fn comparator_stats_reduction() {
        let mut plan = ArgmaxPlan::exact(4, 16);
        // Shrink all masks to 4 bits -> reduction 4x.
        for stage in plan.stages.iter_mut() {
            for c in stage.iter_mut() {
                c.mask = 0xF;
            }
        }
        let (avg, red) = plan.comparator_stats();
        assert_eq!(avg, 4.0);
        assert_eq!(red, 4.0);
    }

    #[test]
    fn masked_compare_uses_only_masked_bits() {
        let mut plan = ArgmaxPlan::exact(2, 8);
        plan.stages[0][0].mask = 0b1100_0000; // top 2 bits of biased form
        // z = [3, 5]: biased 131 vs 133 -> both 0b1000_00xx -> masked equal
        // -> tie keeps slot 0.
        assert_eq!(plan.predict(&[3, 5]), 0);
        // Large difference visible in the top bits.
        assert_eq!(plan.predict(&[-100, 100]), 1);
    }
}
