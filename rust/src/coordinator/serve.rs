//! Design-as-a-service: a resident `pmlp serve` process that accepts
//! design requests as line-delimited JSON and answers each with the
//! full Pareto report plus a per-request `pmlp.metrics/1` telemetry
//! delta — over stdio (default) or a TCP listener, std-only.
//!
//! ## Protocol
//!
//! One request per line, one response line per request, in order. A
//! request is a JSON object:
//!
//! ```json
//! {"dataset": "cardio",
//!  "objective": "area+power+delay", "max_delay_ms": 180.0,
//!  "ga": {"population": 200, "generations": 12, "seed": 7},
//!  "jobs": 8, "islands": 4, "max_hw_points": 4, "id": 1}
//! ```
//!
//! - `dataset`: a built-in config name, **or** `config`: a full
//!   [`RunConfig`] JSON object (same schema as `pmlp gen-data`/`--config`
//!   files) for bespoke datasets.
//! - `ga`: overrides applied on top of the config's GA spec — the
//!   request's search budget.
//! - `backend` (default `circuit`), `objective`, `synth`, `lane_width`,
//!   `share_cones`, `verify`, `max_delay_ms`, `jobs`, `islands`,
//!   `max_hw_points`, `synth_baseline`, `approx_argmax`, `verbose`: the
//!   per-request [`PipelineOpts`], same names and defaults as the CLI
//!   (except the backend default — a resident designer is the
//!   circuit-in-the-loop service).
//! - `id`: echoed verbatim in the response, for client-side matching.
//!
//! The response carries `ok`, the echoed `id`, `warm_study` (whether
//! the request hit a parked study), `designs_synthesized` (kernel-cache
//! misses — `0` on a repeated request), `result` (the
//! [`crate::report::result_to_json`] report, Pareto front + `front_hw`
//! warm survivor roll-ups included) and `metrics` (the request-scoped
//! telemetry delta, schema `pmlp.metrics/1`). Errors answer
//! `{"ok": false, "error": ...}` on their own line; the server keeps
//! serving.
//!
//! ## Warm state
//!
//! The server keys [`Study`]s by everything *except* the GA spec (plus
//! the backend), so requests that agree on dataset, topology, training
//! and hardware constraints — but explore different objectives, budgets
//! or constraint vectors — share one study: one trained model, one
//! synthesis template, per-objective circuit evaluators with their
//! cross-generation fitness memos and parked arena fleets, and one
//! design-kernel cache. Every answer is bit-identical to what a fresh
//! process would produce for the same request — warm state only ever
//! skips re-computation, never changes results (pinned by
//! `rust/tests/serve_requests.rs`).
//!
//! EOF on the input (stdio) or the peer closing the connection (TCP) is
//! the clean shutdown path: the loop drains, flushes and returns.

use super::{DesignRequest, EvalBackend, PipelineOpts, Study};
use crate::config::{builtin, GaSpec, RunConfig};
use crate::egfet::CostObjective;
use crate::report;
use crate::sim::wave;
use crate::synth::verify::VerifyMode;
use crate::synth::SynthMode;
use crate::util::json::Json;
use crate::util::telemetry;
use std::io::{self, BufRead, BufReader, Write};
use std::net::TcpListener;

/// The resident design server: a keyed cache of warm [`Study`]s plus a
/// request counter. One server instance serves one stdio session or
/// every connection of one TCP listener, sequentially — studies stay
/// warm across connections.
pub struct Server {
    studies: Vec<(String, Study)>,
    served: u64,
}

impl Default for Server {
    fn default() -> Self {
        Self::new()
    }
}

impl Server {
    pub fn new() -> Server {
        Server { studies: Vec::new(), served: 0 }
    }

    /// Handle one request line; never fails — malformed input or a
    /// pipeline error becomes an `{"ok": false, ...}` response.
    pub fn handle_line(&mut self, line: &str) -> Json {
        let parsed = Json::parse(line);
        let id = parsed
            .as_ref()
            .ok()
            .and_then(|j| j.get("id").cloned())
            .unwrap_or(Json::Null);
        let body = match &parsed {
            Err(e) => Err(format!("bad request JSON: {e}")),
            Ok(j) => self.handle_request(j),
        };
        match body {
            Ok(mut resp) => {
                if let Json::Obj(map) = &mut resp {
                    map.insert("id".to_string(), id);
                }
                resp
            }
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("id", id),
                ("error", Json::str(&e)),
            ]),
        }
    }

    fn handle_request(&mut self, j: &Json) -> Result<Json, String> {
        let mut cfg = if let Some(c) = j.get("config") {
            RunConfig::from_json(c).map_err(|e| e.to_string())?
        } else {
            let name = j.get("dataset").and_then(Json::as_str).ok_or_else(|| {
                "request needs \"dataset\" (a built-in name) or \"config\" (a full run config)"
                    .to_string()
            })?;
            builtin::by_name(name).ok_or_else(|| {
                format!(
                    "unknown dataset '{name}' (built-ins: {}, tiny)",
                    builtin::paper_names().join(", ")
                )
            })?
        };
        apply_ga_overrides(&mut cfg.ga, j);
        let opts = parse_opts(j)?;
        let req = DesignRequest { ga: cfg.ga.clone(), opts };

        let base = telemetry::baseline();
        let _sp = crate::span!("pipeline");
        let key = study_key(&cfg, req.opts.backend);
        let (warm_study, idx) = match self.studies.iter().position(|(k, _)| *k == key) {
            Some(i) => (true, i),
            None => {
                let study = Study::new(cfg, &req.opts).map_err(|e| e.to_string())?;
                self.studies.push((key, study));
                (false, self.studies.len() - 1)
            }
        };
        let study = &mut self.studies[idx].1;
        // Kernel-cache growth is the process-local ground truth for how
        // many designs this request actually synthesized (the
        // `coordinator.designs_synthesized` counter says the same, but
        // the telemetry delta is process-global).
        let kernels_before = study.design_cache.len();
        let result = study.design(&req).map_err(|e| e.to_string())?;
        let synthesized = study.design_cache.len() - kernels_before;
        drop(_sp);
        let metrics = telemetry::metrics_json(&telemetry::snapshot_since(&base));
        self.served += 1;
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("request", Json::num(self.served as f64)),
            ("warm_study", Json::Bool(warm_study)),
            ("designs_synthesized", Json::num(synthesized as f64)),
            ("result", report::result_to_json(&result)),
            ("metrics", metrics),
        ]))
    }
}

/// The study cache key: the run config with its GA spec neutralized
/// (the GA budget is per-request, not per-study), plus the backend the
/// study trains for. Deterministic — `RunConfig::to_json` writes
/// `BTreeMap`-ordered objects.
fn study_key(cfg: &RunConfig, backend: EvalBackend) -> String {
    let mut keyed = cfg.clone();
    keyed.ga = GaSpec {
        population: 0,
        generations: 0,
        mutation_rate: 0.0,
        crossover_rate: 0.0,
        acc_loss_bound: 0.0,
        init_keep_prob: 0.0,
        seed: 0,
    };
    format!("{:?}|{}", backend, keyed.to_json().to_string_compact())
}

/// Apply a request's `ga` object on top of the config's GA spec.
fn apply_ga_overrides(ga: &mut GaSpec, j: &Json) {
    if let Some(g) = j.get("ga") {
        ga.population = g.usize_or("population", ga.population);
        ga.generations = g.usize_or("generations", ga.generations);
        ga.mutation_rate = g.f64_or("mutation_rate", ga.mutation_rate);
        ga.crossover_rate = g.f64_or("crossover_rate", ga.crossover_rate);
        ga.acc_loss_bound = g.f64_or("acc_loss_bound", ga.acc_loss_bound);
        ga.init_keep_prob = g.f64_or("init_keep_prob", ga.init_keep_prob);
        ga.seed = g.usize_or("seed", ga.seed as usize) as u64;
    }
}

/// Parse the request's per-request [`PipelineOpts`] (CLI names and
/// defaults, except `backend` which defaults to `circuit` here).
fn parse_opts(j: &Json) -> Result<PipelineOpts, String> {
    let d = PipelineOpts::default();
    let backend = match j.get("backend").and_then(Json::as_str) {
        None => EvalBackend::Circuit,
        Some(s) => EvalBackend::parse(s)
            .ok_or_else(|| format!("unknown backend '{s}' (auto|pjrt|native|circuit)"))?,
    };
    let objective = match j.get("objective").and_then(Json::as_str) {
        None => d.objective,
        Some(s) => CostObjective::parse_detailed(s)?,
    };
    let synth = match j.get("synth").and_then(Json::as_str) {
        None => d.synth,
        Some(s) => {
            SynthMode::parse(s).ok_or_else(|| format!("unknown synth mode '{s}' (incr|full)"))?
        }
    };
    let lane_width = match j.get("lane_width").and_then(Json::as_str) {
        None => d.lane_width,
        Some(s) => {
            wave::LaneWidth::parse(s).ok_or_else(|| format!("unknown lane width '{s}' (64|256)"))?
        }
    };
    let verify = match j.get("verify").and_then(Json::as_str) {
        None => d.verify,
        Some(s) => VerifyMode::parse(s)
            .ok_or_else(|| format!("unknown verify mode '{s}' (off|boundaries|every-gen)"))?,
    };
    Ok(PipelineOpts {
        backend,
        synth,
        objective,
        max_delay_ms: j.get("max_delay_ms").and_then(Json::as_f64),
        jobs: j.usize_or("jobs", d.jobs),
        islands: j.usize_or("islands", d.islands).max(1),
        lane_width,
        share_cones: j.bool_or("share_cones", d.share_cones),
        verify,
        max_hw_points: j.usize_or("max_hw_points", d.max_hw_points),
        synth_baseline: j.bool_or("synth_baseline", d.synth_baseline),
        approx_argmax: j.bool_or("approx_argmax", d.approx_argmax),
        verbose: j.bool_or("verbose", false),
    })
}

/// Serve requests from `input` until EOF, one response line per request
/// (flushed after each so pipe-connected clients can stream).
pub fn serve_lines<R: BufRead, W: Write>(
    server: &mut Server,
    input: R,
    mut output: W,
) -> io::Result<()> {
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = server.handle_line(&line);
        writeln!(output, "{}", resp.to_string_compact())?;
        output.flush()?;
    }
    Ok(())
}

/// `pmlp serve` over stdin/stdout. Returns on EOF — the clean shutdown.
pub fn serve_stdio() -> io::Result<()> {
    let stdin = io::stdin();
    let stdout = io::stdout();
    let mut server = Server::new();
    serve_lines(&mut server, stdin.lock(), stdout.lock())
}

/// `pmlp serve --addr HOST:PORT`: accept connections sequentially on
/// one listener, sharing the warm study cache across them. A
/// connection-level I/O error is reported and the listener keeps
/// accepting; a listener-level error returns.
pub fn serve_tcp(addr: &str) -> io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    // Announce the bound address (stdout, one JSON line) so callers
    // binding port 0 can discover the port.
    println!(
        "{}",
        Json::obj(vec![("ok", Json::Bool(true)), ("listening", Json::str(&local.to_string()))])
            .to_string_compact()
    );
    io::stdout().flush()?;
    serve_listener(listener, &mut Server::new())
}

/// The accept loop behind [`serve_tcp`], factored out so tests can bind
/// their own listener.
pub fn serve_listener(listener: TcpListener, server: &mut Server) -> io::Result<()> {
    for conn in listener.incoming() {
        let stream = conn?;
        let reader = BufReader::new(stream.try_clone()?);
        if let Err(e) = serve_lines(server, reader, stream) {
            telemetry::info("serve", &format!("connection error: {e}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Pipeline;

    const REQ: &str = r#"{"dataset":"tiny","backend":"circuit","ga":{"population":16,"generations":2},"max_hw_points":2,"synth_baseline":false,"id":7}"#;

    #[test]
    fn serve_round_trip_warm_repeat_and_isolation() {
        let mut server = Server::new();
        let input = format!("{REQ}\n\n{REQ}\n");
        let mut out = Vec::new();
        serve_lines(&mut server, input.as_bytes(), &mut out).expect("serve");
        let text = String::from_utf8(out).expect("utf8");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "one response per request, blank lines skipped");
        let a = Json::parse(lines[0]).expect("first response");
        let b = Json::parse(lines[1]).expect("second response");

        for r in [&a, &b] {
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
            assert_eq!(r.get("id").and_then(Json::as_f64), Some(7.0), "id echoed");
            assert_eq!(
                r.get("metrics").and_then(|m| m.get("schema")).and_then(Json::as_str),
                Some("pmlp.metrics/1")
            );
            assert!(r.get("result").and_then(|x| x.get("front")).is_some());
        }
        // Cold request builds the study and synthesizes every design;
        // the repeat runs entirely from parked state.
        assert_eq!(a.get("warm_study").and_then(Json::as_bool), Some(false));
        assert_eq!(b.get("warm_study").and_then(Json::as_bool), Some(true));
        assert!(a.get("designs_synthesized").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(b.get("designs_synthesized").and_then(Json::as_f64), Some(0.0));
        // Request isolation: the warm answer is bit-identical to the
        // cold one (fronts, warm survivor hardware, designs).
        assert_eq!(a.get("result"), b.get("result"));
        assert_eq!(server.studies.len(), 1);
    }

    #[test]
    fn serve_matches_one_shot_pipeline() {
        // The serve layer must answer exactly what `Pipeline::run`
        // reports for the same spec — warm plumbing changes nothing.
        let mut cfg = builtin::tiny();
        cfg.ga.population = 16;
        cfg.ga.generations = 2;
        let opts = PipelineOpts {
            backend: EvalBackend::Circuit,
            max_hw_points: 2,
            synth_baseline: false,
            ..Default::default()
        };
        let direct = Pipeline::new(cfg, opts).run().expect("pipeline");
        let direct_json = report::result_to_json(&direct);

        let mut server = Server::new();
        let resp = server.handle_line(REQ);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("result"), Some(&direct_json));
    }

    #[test]
    fn serve_reports_errors_inline_and_keeps_serving() {
        let mut server = Server::new();
        let bad = server.handle_line("{nonsense");
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
        assert!(bad.get("error").and_then(Json::as_str).unwrap().contains("bad request JSON"));

        let unknown = server.handle_line(r#"{"dataset":"nope","id":"x"}"#);
        assert_eq!(unknown.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(unknown.get("id").and_then(Json::as_str), Some("x"));
        assert!(unknown.get("error").and_then(Json::as_str).unwrap().contains("unknown dataset"));

        let invalid = server
            .handle_line(r#"{"dataset":"tiny","backend":"native","objective":"area+power"}"#);
        assert_eq!(
            invalid.get("ok").and_then(Json::as_bool),
            Some(false),
            "measured objective needs circuit"
        );

        // Still serves after three errors.
        let ok = server.handle_line(REQ);
        assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn study_key_ignores_ga_budget_but_not_backend() {
        let a = builtin::tiny();
        let mut b = builtin::tiny();
        b.ga.population = 999;
        b.ga.seed = 123;
        assert_eq!(study_key(&a, EvalBackend::Circuit), study_key(&b, EvalBackend::Circuit));
        assert_ne!(study_key(&a, EvalBackend::Circuit), study_key(&a, EvalBackend::Native));
        let mut c = builtin::tiny();
        c.hw.clock_ms += 1.0;
        assert_ne!(study_key(&a, EvalBackend::Circuit), study_key(&c, EvalBackend::Circuit));
    }
}
