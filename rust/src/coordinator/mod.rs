//! The Layer-3 coordinator: the paper's automated framework end to end
//! (Fig. 1).
//!
//! Pipeline: synthesize dataset → train float MLP → po2 + QRelu QAT →
//! NSGA-II accumulation approximation (accuracy × area surrogate) →
//! Pareto set → approximate Argmax per design → gate-level synthesis →
//! EGFET hardware analysis (1 V and 0.6 V) → final Pareto report.
//!
//! The GA accuracy evaluator is pluggable: the PJRT path (AOT-compiled
//! Layer-2/Layer-1 programs) when artifacts are present, the native
//! integer model otherwise — both verified bit-equivalent in
//! `rust/tests/pjrt_integration.rs` — or, with
//! [`EvalBackend::Circuit`], the circuit-in-the-loop evaluator that
//! synthesizes every chromosome and classifies the train set on the
//! gate-level netlist through the bit-parallel wave simulator
//! (`crate::sim::wave`). All hardware reports use toggle activity
//! measured by wave-simulating a shared train-set stimulus.

use crate::accum::GenomeMap;
use crate::argmax::{build_plan, ArgmaxPlan, ArgmaxSearchOpts};
use crate::baselines::Int8Mlp;
use crate::config::{GaSpec, RunConfig};
use crate::datasets::{self, QuantDataset};
use crate::egfet::{
    analyze_0p6v_measured, analyze_measured, classify_power_source, CostObjective, HwReport,
    Library, PowerSource,
};
use crate::ga::{self, Nsga2};
use crate::model::QuantMlp;
use crate::netlist::mlp::{build_mlp_circuit, ArgmaxMode, MlpCircuitOpts};
use crate::netlist::Template;
use crate::runtime::evaluator::{CircuitEvaluator, NativeEvaluator};
use crate::runtime::{PjrtEvaluator, Runtime};
use crate::sim::wave;
use crate::synth::verify::VerifyMode;
use crate::synth::{optimize, SynthMode};
use crate::train::{self, TrainedModel};
use crate::util::fxhash::FxHashMap;
use crate::util::telemetry::{self, Counter, Gauge};
use crate::util::BitVec;
use anyhow::Result;
use std::sync::Arc;

pub mod serve;

/// Which GA evaluator the pipeline uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalBackend {
    /// PJRT if artifacts exist, native otherwise.
    Auto,
    Pjrt,
    Native,
    /// Circuit-in-the-loop: fitness on the synthesized gate-level netlist
    /// through the bit-parallel wave simulator.
    Circuit,
}

impl EvalBackend {
    /// The one backend-name parser, shared by the CLI (`--backend`) and
    /// the bench harnesses' `PMLP_BACKEND` env reader.
    pub fn parse(s: &str) -> Option<EvalBackend> {
        match s.to_lowercase().as_str() {
            "auto" => Some(EvalBackend::Auto),
            "pjrt" => Some(EvalBackend::Pjrt),
            "native" => Some(EvalBackend::Native),
            "circuit" => Some(EvalBackend::Circuit),
            _ => None,
        }
    }
}

/// Pipeline options.
#[derive(Clone, Debug)]
pub struct PipelineOpts {
    pub backend: EvalBackend,
    /// Synthesis strategy of the circuit backend (`--synth`): template +
    /// incremental cone-local re-synthesis (default) or from-scratch per
    /// chromosome. Classification output is bit-identical either way.
    pub synth: SynthMode,
    /// Cost axes of the GA (`--objective`): the FA surrogate (default —
    /// unit-compatible across all backends), or, with the circuit
    /// backend only, measured EGFET area, power and/or critical-path
    /// delay of each chromosome's synthesized survivor (`area+power`
    /// runs the joint three-objective front, `area+power+delay` the
    /// four-objective one with the delay axis read off the incremental
    /// arena's arrival table).
    pub objective: CostObjective,
    /// Hard timing cap in milliseconds (`--max-delay`) applied to the
    /// objective's delay axis via constrained domination — every
    /// reported front member meets it. `None` with a delay objective
    /// defaults to the dataset's clock budget (`HwSpec::clock_ms`);
    /// setting it without a delay objective is an error.
    pub max_delay_ms: Option<f64>,
    /// Worker threads of the GA evaluation fan-out (`--jobs`); `0` =
    /// auto (env `PMLP_JOBS`, else the machine's parallelism). Results
    /// are bit-identical for every value — jobs only sets how wide each
    /// generation evaluates.
    pub jobs: usize,
    /// Evaluation islands of the GA (`--islands`, default 1): each
    /// generation's unique genomes are sharded over `K` islands whose
    /// attribution rotates ring-wise at fixed migration boundaries, and
    /// the per-island fronts re-merge by Pareto union. Deterministic by
    /// construction — results and telemetry counter totals are
    /// bit-identical for every `K` and every `--jobs`.
    pub islands: usize,
    /// Wave-simulator lane width of the circuit backend
    /// (`--lane-width 64|256`): 256-lane `[u64; 4]` blocks (default) or
    /// the legacy 64-lane single-word engine. Classifications are
    /// bit-identical at either width — a pure throughput knob.
    pub lane_width: wave::LaneWidth,
    /// Generation-scoped shared-cone evaluation in the incremental
    /// circuit backend (`--share-cones`, default on): structurally
    /// identical dirty cones across a generation's chromosomes are
    /// settled once per worker. Exact — affects work, never results.
    pub share_cones: bool,
    /// Invariant verification of the circuit backend (`--verify
    /// off|boundaries|every-gen`, default off): run the structural
    /// checks of [`crate::synth::verify`] never, at generation
    /// boundaries (each worker's arena as it parks), or after every
    /// chromosome re-synthesis. Violations are counted in
    /// `verify.violations` and logged — never panicked on. Exact: any
    /// mode leaves objectives bit-identical.
    pub verify: VerifyMode,
    /// Synthesize + analyze at most this many Pareto designs (the
    /// hardware step dominates runtime for large MLPs).
    pub max_hw_points: usize,
    /// Skip the (expensive) exact-baseline synthesis when false.
    pub synth_baseline: bool,
    /// Apply the approximate-Argmax step (paper: yes).
    pub approx_argmax: bool,
    pub verbose: bool,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        PipelineOpts {
            backend: EvalBackend::Auto,
            synth: SynthMode::Incremental,
            objective: CostObjective::Fa,
            max_delay_ms: None,
            jobs: 0,
            islands: 1,
            lane_width: wave::LaneWidth::default(),
            share_cones: true,
            verify: VerifyMode::Off,
            max_hw_points: 4,
            synth_baseline: true,
            approx_argmax: true,
            verbose: false,
        }
    }
}

/// One Pareto-front member with the GA's const-generic objective arity
/// erased to a runtime-length vector: `objs[0]` is the accuracy loss,
/// `objs[1..]` the cost axes in [`PipelineResult::objective`]'s units —
/// one axis for `fa|area|power|delay`, `[area_cm2, power_mw]` for the
/// joint `area+power` mode and `[area_cm2, power_mw, delay_ms]` for
/// `area+power+delay`. The GA core stays `[f64; M]`-typed; the erasure
/// happens only at this reporting boundary, so one `PipelineResult`
/// type carries fronts of any arity.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontPoint {
    pub genome: BitVec,
    pub objs: Vec<f64>,
}

impl FrontPoint {
    /// Erase a typed GA individual's objective array.
    fn from_individual<const M: usize>(ind: &ga::Individual<M>) -> FrontPoint {
        FrontPoint { genome: ind.genome.clone(), objs: ind.objs.to_vec() }
    }
}

/// Erase a whole typed front/population.
fn erase_front<const M: usize>(inds: &[ga::Individual<M>]) -> Vec<FrontPoint> {
    inds.iter().map(FrontPoint::from_individual).collect()
}

/// Run the circuit-backend GA at arity `M` and erase the result:
/// `(front, population, exact_genome_objs)`. One definition for every
/// objective arity, so the run/score/erase flow can never diverge
/// between the joint and single-cost modes; the exact genome is scored
/// through the same evaluator so the coordinator's zero-approximation
/// fallback carries the active objective's units.
fn run_circuit_ga<const M: usize>(
    ev: &CircuitEvaluator<M>,
    spec: GaSpec,
    genome_len: usize,
    seeds: Vec<BitVec>,
    jobs: usize,
    islands: usize,
    max_delay: Option<(usize, f64)>,
    exact: &BitVec,
    log_hist: &dyn Fn(usize, &[(f64, f64)]),
) -> (Vec<FrontPoint>, Vec<FrontPoint>, Vec<f64>) {
    let ga = Nsga2::new(spec, genome_len, ev)
        .with_seeds(seeds)
        .with_jobs(jobs)
        .with_islands(islands)
        .with_max_delay(max_delay);
    let result = ga.run(|g, snap| log_hist(g, &snap.history));
    let exact_objs = ga::evaluate_parallel(ev, std::slice::from_ref(exact), 1)[0];
    telemetry::gauge(Gauge::MemoEntries, ev.memo_len() as u64);
    (erase_front(&result.front), erase_front(&result.population), exact_objs.to_vec())
}

/// A fully analyzed final design.
#[derive(Clone, Debug)]
pub struct FinalDesign {
    pub genome: BitVec,
    /// Test accuracy with accumulation approximation only.
    pub acc_test_accum: f64,
    /// Test accuracy with accumulation + argmax approximation.
    pub acc_test_full: f64,
    /// Train accuracy (the GA's objective view).
    pub acc_train: f64,
    /// FA-surrogate estimate (recomputed for every design, whatever the
    /// GA's cost objective was — keeps reports backend-comparable).
    pub area_fa: u64,
    /// The design's full GA objective vector (`objs[0]` = train
    /// accuracy loss, `objs[1..]` = cost axes in
    /// [`PipelineResult::objective`]'s units — FA count, cm² and/or mW).
    pub objs: Vec<f64>,
    pub argmax_plan: ArgmaxPlan,
    /// Synthesized hardware without the argmax approximation (exact
    /// comparator tree) — Table IV's reference point.
    pub hw_exact_argmax: HwReport,
    /// Synthesized hardware with the full holistic approximation, 1 V.
    pub hw_full: HwReport,
    /// Same netlist at the 0.6 V battery corner (Table V policy).
    pub hw_0p6v: HwReport,
    pub power_source: PowerSource,
}

/// Everything a pipeline run produces.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    pub cfg: RunConfig,
    pub trained: TrainedModel,
    pub baseline_acc_test: f64,
    /// Exact bespoke baseline [8] hardware (1 V).
    pub baseline_hw: Option<HwReport>,
    /// QAT-only (po2 + QRelu, exact accumulation/argmax) hardware (1 V).
    pub qat_hw: HwReport,
    /// GA Pareto front as (accuracy-loss vs QAT train, cost axes) — the
    /// cost axes are in `objective`'s units; arity-erased
    /// ([`FrontPoint`]), 3-D for the joint `area+power` objective, 4-D
    /// for `area+power+delay`.
    pub front: Vec<FrontPoint>,
    /// Measured survivor hardware for each front member, aligned with
    /// `front` and served warm from the circuit evaluator's parked
    /// `(CellCounts, toggle-sum)` memo state — `(area_cm2, power_mw,
    /// delay_ms)` per entry, `None` for non-circuit backends or
    /// from-scratch synthesis (which parks no census). No re-synthesis
    /// happens to produce these.
    pub front_hw: Vec<Option<(f64, f64, f64)>>,
    pub designs: Vec<FinalDesign>,
    /// Which evaluator actually ran.
    pub backend_used: &'static str,
    /// Which cost objective(s) the GA minimized.
    pub objective: CostObjective,
}

/// The coordinator's one-shot face: one config + options, one fresh
/// [`Study`], one [`DesignRequest`] — exactly the pre-study pipeline,
/// including its telemetry (a fresh study has empty caches, so every
/// selected design is synthesized and counted).
pub struct Pipeline {
    pub cfg: RunConfig,
    pub opts: PipelineOpts,
}

impl Pipeline {
    pub fn new(cfg: RunConfig, opts: PipelineOpts) -> Pipeline {
        Pipeline { cfg, opts }
    }

    /// Run the full framework.
    pub fn run(&self) -> Result<PipelineResult> {
        validate_opts(&self.opts)?;
        let _sp_pipeline = crate::span!("pipeline");
        let mut study = Study::new(self.cfg.clone(), &self.opts)?;
        study.design(&DesignRequest { ga: self.cfg.ga.clone(), opts: self.opts.clone() })
    }
}

/// Bail early on option combinations the pipeline can't honor — shared
/// by the one-shot CLI path and every serve request.
fn validate_opts(opts: &PipelineOpts) -> Result<()> {
    if opts.objective.is_measured() && opts.backend != EvalBackend::Circuit {
        anyhow::bail!(
            "--objective {} is measured on the synthesized survivor and requires \
             --backend circuit",
            opts.objective.label()
        );
    }
    if opts.max_delay_ms.is_some() && opts.objective.delay_axis().is_none() {
        anyhow::bail!(
            "--max-delay constrains the delay axis and requires --objective delay \
             or area+power+delay (got {})",
            opts.objective.label()
        );
    }
    Ok(())
}

/// Cache key of a warm circuit evaluator: every option that changes the
/// evaluator's identity. Requests agreeing on these share one evaluator
/// — and with it the cross-generation fitness memo, the parked arena
/// fleet and the synthesis template.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct EvKey {
    objective: CostObjective,
    synth: SynthMode,
    lane_width: wave::LaneWidth,
    share_cones: bool,
    verify: VerifyMode,
}

/// A cached circuit evaluator with its const-generic objective arity
/// erased at the study boundary (the GA core underneath stays
/// `[f64; M]`-typed).
enum CircuitEv {
    M2(CircuitEvaluator<2>),
    M3(CircuitEvaluator<3>),
    M4(CircuitEvaluator<4>),
}

impl CircuitEv {
    fn template_arc(&self) -> Arc<Template> {
        match self {
            CircuitEv::M2(ev) => ev.template_arc().clone(),
            CircuitEv::M3(ev) => ev.template_arc().clone(),
            CircuitEv::M4(ev) => ev.template_arc().clone(),
        }
    }

    fn warm_survivor_hw(&self, genome: &BitVec) -> Option<(f64, f64, f64)> {
        match self {
            CircuitEv::M2(ev) => ev.warm_survivor_hw(genome),
            CircuitEv::M3(ev) => ev.warm_survivor_hw(genome),
            CircuitEv::M4(ev) => ev.warm_survivor_hw(genome),
        }
    }
}

/// The request-independent part of a [`FinalDesign`]: the argmax plan
/// and every synthesized/analyzed artifact for one
/// `(genome, approx-argmax)` pair. The study caches these so a repeated
/// request reassembles its designs without synthesizing anything —
/// `coordinator.designs_synthesized` counts only cache misses.
#[derive(Clone, Debug)]
struct DesignKernel {
    acc_test_accum: f64,
    acc_test_full: f64,
    argmax_plan: ArgmaxPlan,
    hw_exact_argmax: HwReport,
    hw_full: HwReport,
    hw_0p6v: HwReport,
    power_source: PowerSource,
}

/// Stage-5 body for one genome: argmax plan search, accuracy scoring,
/// gate-level synthesis and EGFET analysis at both voltage corners.
#[allow(clippy::too_many_arguments)]
fn build_design_kernel(
    qmlp: &QuantMlp,
    qtrain: &QuantDataset,
    qtest: &QuantDataset,
    stimulus: &[Vec<bool>],
    clock_ms: f64,
    approx_argmax: bool,
    map: &GenomeMap,
    genome: &BitVec,
) -> DesignKernel {
    let masks = map.to_masks(genome);
    let acc_test_accum = qmlp.accuracy(qtest, Some(&masks));
    // Argmax approximation on the *train* outputs of this design
    // (paper: performed last, depends on the output distribution).
    let width = qmlp.output_width();
    let plan = if approx_argmax && qmlp.topo.n_out >= 2 {
        let preacts = qmlp.output_preacts(qtrain, Some(&masks));
        build_plan(&preacts, &qtrain.y, width, &ArgmaxSearchOpts::default())
    } else {
        ArgmaxPlan::exact(qmlp.topo.n_out, width)
    };
    // Test accuracy with the full holistic approximation.
    let test_preacts = qmlp.output_preacts(qtest, Some(&masks));
    let acc_test_full = plan.accuracy(&test_preacts, &qtest.y);

    // Hardware: exact-argmax reference and full design.
    let nl_exact = build_mlp_circuit(
        qmlp,
        &MlpCircuitOpts { masks: Some(masks.clone()), argmax: ArgmaxMode::Exact },
    );
    let (opt_exact, _) = optimize(&nl_exact);
    let hw_exact_argmax = analyze_measured(&opt_exact, &Library::egfet_1v(), clock_ms, stimulus);
    let nl_full = build_mlp_circuit(
        qmlp,
        &MlpCircuitOpts { masks: Some(masks), argmax: ArgmaxMode::Plan(plan.clone()) },
    );
    let (opt_full, _) = optimize(&nl_full);
    let hw_full = analyze_measured(&opt_full, &Library::egfet_1v(), clock_ms, stimulus);
    let hw_0p6v = analyze_0p6v_measured(&opt_full, clock_ms, stimulus);
    let power_source = classify_power_source(hw_0p6v.power_mw);

    DesignKernel {
        acc_test_accum,
        acc_test_full,
        argmax_plan: plan,
        hw_exact_argmax,
        hw_full,
        hw_0p6v,
        power_source,
    }
}

/// One design request against a (possibly warm) [`Study`]: the GA
/// budget plus the per-request pipeline options.
#[derive(Clone, Debug)]
pub struct DesignRequest {
    /// This request's GA spec (population, generations, rates,
    /// accuracy-loss bound, seed) — the budget knob of the service.
    pub ga: GaSpec,
    /// Per-request options (objective, constraints, jobs/islands, …).
    /// `backend` must match the study's.
    pub opts: PipelineOpts,
}

/// One warm design study: everything about a `(config, backend)` pair
/// that is independent of any particular design request — the trained
/// and quantized model, the shared hardware-analysis stimulus, the
/// baseline/QAT reference hardware, the GA genome map and truncation
/// seeds — plus the state that makes repeat requests cheap: the shared
/// synthesis template, the keyed circuit-evaluator cache (each entry
/// carrying its cross-generation fitness memo with parked survivor
/// hardware and its leased arena fleet) and the design-kernel cache.
///
/// [`Pipeline::run`] builds a fresh study per call (the one-shot CLI
/// path); `pmlp serve` keeps studies in a keyed cache and replays
/// [`DesignRequest`]s against them, so a repeated request runs entirely
/// from parked state (`coordinator.designs_synthesized == 0`).
pub struct Study {
    pub cfg: RunConfig,
    backend: EvalBackend,
    runtime: Option<Runtime>,
    have_artifact: bool,
    qtrain: QuantDataset,
    qtest: QuantDataset,
    pub trained: TrainedModel,
    /// Shared stimulus for every hardware analysis: a slice of the
    /// quantized train set in the circuits' common 4-bit input
    /// encoding. Each netlist is wave-simulated on it so the dynamic
    /// power estimate uses *measured* toggle activity (the paper's
    /// VCS-reported switching activity), not a nominal constant.
    stimulus: Vec<Vec<bool>>,
    int8: Int8Mlp,
    pub baseline_acc_test: f64,
    baseline_hw: Option<HwReport>,
    qat_hw: HwReport,
    map: GenomeMap,
    seeds: Vec<BitVec>,
    exact: BitVec,
    exact_fa: f64,
    /// The one parameterized netlist every circuit evaluator of this
    /// study shares: harvested from the first evaluator built, injected
    /// into each later one ([`CircuitEvaluator::with_template`]).
    template: Option<Arc<Template>>,
    evaluators: Vec<(EvKey, CircuitEv)>,
    design_cache: FxHashMap<(BitVec, bool), DesignKernel>,
}

impl Study {
    /// Stages 1–3: dataset, training + QAT, reference hardware. The
    /// result can serve any number of [`DesignRequest`]s whose backend
    /// matches `opts.backend`.
    pub fn new(cfg: RunConfig, opts: &PipelineOpts) -> Result<Study> {
        validate_opts(opts)?;
        let name = cfg.dataset.name.clone();
        // `verbose` keeps its pre-facade meaning (pipeline progress is
        // opt-in per call site); `PMLP_LOG` gates the whole facade, so
        // default-level output is byte-identical to the old `eprintln!`s.
        let log = |msg: &str| {
            if opts.verbose {
                telemetry::info(&name, msg);
            }
        };

        // ---- 1. dataset ------------------------------------------------
        let (split, qtrain, qtest) = {
            let _sp = crate::span!("dataset");
            datasets::load(&cfg.dataset)
        };
        log(&format!(
            "dataset: {} train / {} test samples, {} features, {} classes",
            qtrain.n_samples(),
            qtest.n_samples(),
            qtrain.n_features(),
            qtrain.n_classes
        ));

        // ---- 2. training + QAT -----------------------------------------
        let runtime = match opts.backend {
            EvalBackend::Native | EvalBackend::Circuit => None,
            _ => Runtime::new(&Runtime::default_dir()).ok(),
        };
        let have_artifact = runtime
            .as_ref()
            .map(|rt| rt.manifest.entries.contains_key(&cfg.dataset.name))
            .unwrap_or(false);
        if matches!(opts.backend, EvalBackend::Pjrt) && !have_artifact {
            anyhow::bail!("PJRT backend requested but artifacts missing (run `make artifacts`)");
        }

        let _sp_train = crate::span!("train");
        let trained = if have_artifact {
            // Float pre-train natively with the same restart search as
            // the native path, QAT via the AOT train_step (Layer-2
            // fwd/bwd through PJRT). The native QAT engine joins the
            // learning-rate/seed search as one more candidate; the best
            // integer model (train accuracy) wins — on the fragile
            // 2-neuron MLPs the engines land in different basins.
            let float = train::train_float_search(&cfg, &split);
            let rt = runtime.as_ref().unwrap();
            let pjrt_tm = crate::train::PjrtTrainer::new(rt, &cfg.dataset.name)
                .train(&cfg, &float, &split, &qtrain, &qtest)?;
            let native_tm = train::train_native(&cfg, &split, &qtrain, &qtest);
            if native_tm.acc_q_train > pjrt_tm.acc_q_train {
                native_tm
            } else {
                pjrt_tm
            }
        } else {
            train::train_native(&cfg, &split, &qtrain, &qtest)
        };
        drop(_sp_train);
        log(&format!(
            "trained: float test acc {:.3}, QAT test acc {:.3}",
            trained.acc_float_test, trained.acc_q_test
        ));

        // ---- 3. baseline + QAT-only hardware ----------------------------
        let qmlp = &trained.qmlp;
        let stimulus: Vec<Vec<bool>> = qtrain
            .x
            .iter()
            .take(192)
            .map(|row| wave::encode_features(row, qmlp.l1.in_bits))
            .collect();
        let int8 = Int8Mlp::from_float(&trained.float);
        let baseline_acc_test = int8.accuracy(&qtest);
        let (baseline_hw, qat_hw) = {
            let _sp = crate::span!("baseline_hw");
            let baseline_hw = if opts.synth_baseline {
                let nl = int8.build_circuit(ArgmaxMode::Exact);
                let (opt, _) = optimize(&nl);
                Some(analyze_measured(&opt, &Library::egfet_1v(), cfg.hw.clock_ms, &stimulus))
            } else {
                None
            };
            let qat_nl = build_mlp_circuit(qmlp, &MlpCircuitOpts::default());
            let (qat_opt, _) = optimize(&qat_nl);
            let qat_hw =
                analyze_measured(&qat_opt, &Library::egfet_1v(), cfg.hw.clock_ms, &stimulus);
            (baseline_hw, qat_hw)
        };
        if let Some(hw) = &baseline_hw {
            log(&format!(
                "baseline: {:.1} cm2 / {:.1} mW; QAT-only: {:.2} cm2 / {:.2} mW",
                hw.area_cm2, hw.power_mw, qat_hw.area_cm2, qat_hw.power_mw
            ));
        }

        // Request-independent GA scaffolding. LSB-truncation seeds:
        // column depths spanning the QRelu shift for layer 1 and the
        // low columns of layer 2.
        let map = GenomeMap::new(qmlp);
        let t = qmlp.act_shift as u8;
        let depths1: Vec<u8> = vec![t / 2, t, t.saturating_add(2), t.saturating_add(4)];
        let depths2: Vec<u8> = vec![0, 2, 4, 6];
        let seeds = crate::accum::truncation_seeds(&map, &depths1, &depths2);
        let exact = map.exact_genome();
        let exact_fa = crate::area::AreaModel::new(&map).exact_estimate() as f64;

        Ok(Study {
            cfg,
            backend: opts.backend,
            runtime,
            have_artifact,
            qtrain,
            qtest,
            trained,
            stimulus,
            int8,
            baseline_acc_test,
            baseline_hw,
            qat_hw,
            map,
            seeds,
            exact,
            exact_fa,
            template: None,
            evaluators: Vec::new(),
            design_cache: FxHashMap::default(),
        })
    }

    /// Synthesize the exact bespoke baseline on demand (skipped at
    /// build time when the building request had `synth_baseline` off; a
    /// later request that wants it triggers it once).
    fn ensure_baseline_hw(&mut self) {
        if self.baseline_hw.is_some() {
            return;
        }
        let _sp = crate::span!("baseline_hw");
        let nl = self.int8.build_circuit(ArgmaxMode::Exact);
        let (opt, _) = optimize(&nl);
        self.baseline_hw = Some(analyze_measured(
            &opt,
            &Library::egfet_1v(),
            self.cfg.hw.clock_ms,
            &self.stimulus,
        ));
    }

    /// Find or build the circuit evaluator for `key` (returns its index
    /// in the cache). New evaluators get the study's shared template
    /// injected; the first one built donates its template to the study.
    fn circuit_evaluator(&mut self, key: EvKey) -> usize {
        if let Some(i) = self.evaluators.iter().position(|(k, _)| *k == key) {
            return i;
        }
        fn outfit<const M: usize>(
            ev: CircuitEvaluator<M>,
            key: &EvKey,
            tpl: &Option<Arc<Template>>,
        ) -> CircuitEvaluator<M> {
            let ev = ev
                .with_mode(key.synth)
                .with_lane_width(key.lane_width)
                .with_cone_sharing(key.share_cones)
                .with_verify(key.verify);
            match tpl {
                Some(t) => ev.with_template(t.clone()),
                None => ev,
            }
        }
        let qmlp = &self.trained.qmlp;
        let base = self.trained.acc_q_train;
        let ev = match key.objective {
            CostObjective::AreaPowerDelay => CircuitEv::M4(outfit(
                CircuitEvaluator::new_joint_delay(qmlp, &self.qtrain, base),
                &key,
                &self.template,
            )),
            CostObjective::AreaPower => CircuitEv::M3(outfit(
                CircuitEvaluator::new_joint(qmlp, &self.qtrain, base),
                &key,
                &self.template,
            )),
            _ => CircuitEv::M2(outfit(
                CircuitEvaluator::new(qmlp, &self.qtrain, base).with_objective(key.objective),
                &key,
                &self.template,
            )),
        };
        if self.template.is_none() {
            self.template = Some(ev.template_arc());
        }
        self.evaluators.push((key, ev));
        self.evaluators.len() - 1
    }

    /// Stages 4–5 for one request: the NSGA-II accumulation search,
    /// then argmax planning + synthesis of the selected designs — warm
    /// wherever the study has parked state, bit-identical to a cold run
    /// either way.
    pub fn design(&mut self, req: &DesignRequest) -> Result<PipelineResult> {
        let opts = &req.opts;
        validate_opts(opts)?;
        anyhow::ensure!(
            opts.backend == self.backend,
            "study was built for backend {:?} and cannot serve a {:?} request",
            self.backend,
            opts.backend
        );
        if opts.synth_baseline {
            self.ensure_baseline_hw();
        }
        let name = self.cfg.dataset.name.clone();
        let log = |msg: &str| {
            if opts.verbose {
                telemetry::info(&name, msg);
            }
        };

        // ---- 4. genetic accumulation approximation ----------------------
        // One generation logger shared by every arity — the history pair
        // is (best cost@2%, best cost@5%) regardless of M.
        let verbose = opts.verbose;
        let log_hist = |generation: usize, history: &[(f64, f64)]| {
            if verbose {
                let (b2, b5) = history.last().copied().unwrap_or((0.0, 0.0));
                telemetry::info(
                    &name,
                    &format!("gen {generation}: best cost @2% loss = {b2:.4}, @5% = {b5:.4}"),
                );
            }
        };
        let jobs = opts.jobs;
        let use_circuit = opts.backend == EvalBackend::Circuit;
        let _sp_ga = crate::span!("ga");
        let (front, population, backend_used, exact_objs, front_hw) = if use_circuit {
            // Circuit-in-the-loop: every chromosome is synthesized and
            // classified at the gate level through the wave engine,
            // incrementally (template cone-patch) or from scratch. The
            // GA fans each generation across `jobs` workers (sharded
            // over `islands` evaluation islands), each worker owning
            // its own synthesis arena + wave cache — including the
            // measured-objective census/toggle state, so `--objective
            // area|power|area+power` stays bit-identical across widths,
            // job counts and island counts. The joint objectives
            // instantiate the const-generic GA at arity 3 ([loss, area,
            // power]) or 4 ([loss, area, power, delay]); everything
            // else at 2. Delay axes ride a hard timing cap through
            // constrained domination: `--max-delay` if given, else the
            // dataset's clock budget. The exact genome is scored
            // through the same evaluator so the zero-approximation
            // fallback injected below carries the active objective's
            // units (FA, cm², mW and/or ms) — note the fallback is
            // injected for accuracy coverage and is exempt from the
            // cap.
            let delay_cap = opts
                .objective
                .delay_axis()
                .map(|axis| (axis, opts.max_delay_ms.unwrap_or(self.cfg.hw.clock_ms)));
            let key = EvKey {
                objective: opts.objective,
                synth: opts.synth,
                lane_width: opts.lane_width,
                share_cones: opts.share_cones,
                verify: opts.verify,
            };
            let i = self.circuit_evaluator(key);
            let ev = &self.evaluators[i].1;
            let (front, population, exact_objs) = match ev {
                CircuitEv::M4(ev) => run_circuit_ga(
                    ev,
                    req.ga.clone(),
                    self.map.len(),
                    self.seeds.clone(),
                    jobs,
                    opts.islands,
                    delay_cap,
                    &self.exact,
                    &log_hist,
                ),
                CircuitEv::M3(ev) => run_circuit_ga(
                    ev,
                    req.ga.clone(),
                    self.map.len(),
                    self.seeds.clone(),
                    jobs,
                    opts.islands,
                    delay_cap,
                    &self.exact,
                    &log_hist,
                ),
                CircuitEv::M2(ev) => run_circuit_ga(
                    ev,
                    req.ga.clone(),
                    self.map.len(),
                    self.seeds.clone(),
                    jobs,
                    opts.islands,
                    delay_cap,
                    &self.exact,
                    &log_hist,
                ),
            };
            let front_hw = front.iter().map(|p| ev.warm_survivor_hw(&p.genome)).collect();
            (front, population, "circuit", exact_objs, front_hw)
        } else if self.have_artifact {
            let rt = self.runtime.as_ref().unwrap();
            let ev = PjrtEvaluator::new(
                rt,
                &self.cfg.dataset.name,
                &self.trained.qmlp,
                &self.qtrain,
                self.trained.acc_q_train,
            )?;
            let ga = Nsga2::<2>::new(req.ga.clone(), self.map.len(), &ev)
                .with_seeds(self.seeds.clone())
                .with_jobs(jobs)
                .with_islands(opts.islands);
            let result = ga.run(|g, snap| log_hist(g, &snap.history));
            (
                erase_front(&result.front),
                erase_front(&result.population),
                "pjrt",
                vec![0.0, self.exact_fa],
                vec![None; result.front.len()],
            )
        } else {
            let ev =
                NativeEvaluator::new(&self.trained.qmlp, &self.qtrain, self.trained.acc_q_train);
            let ga = Nsga2::<2>::new(req.ga.clone(), self.map.len(), &ev)
                .with_seeds(self.seeds.clone())
                .with_jobs(jobs)
                .with_islands(opts.islands);
            let result = ga.run(|g, snap| log_hist(g, &snap.history));
            (
                erase_front(&result.front),
                erase_front(&result.population),
                "native",
                vec![0.0, self.exact_fa],
                vec![None; result.front.len()],
            )
        };
        drop(_sp_ga);
        telemetry::gauge(Gauge::GaFrontSize, front.len() as u64);
        log(&format!(
            "GA: front size {} (population {})",
            front.len(),
            population.len()
        ));

        // ---- 5. argmax approximation + synthesis of selected designs ----
        let mut selected = select_designs(&front, opts.max_hw_points);
        // Always include the exact (QAT-only accumulation) genome as a
        // zero-approximation fallback so a <=5%-vs-baseline design exists
        // whenever QAT itself is within budget.
        if !selected.iter().any(|i| i.genome == self.exact) {
            selected.push(FrontPoint { genome: self.exact.clone(), objs: exact_objs });
        }
        let area_model = crate::area::AreaModel::new(&self.map);
        let mut designs = Vec::new();
        let mut synthesized = 0u64;
        let _sp_designs = crate::span!("designs");
        for ind in selected {
            let cache_key = (ind.genome.clone(), opts.approx_argmax);
            let kernel = match self.design_cache.get(&cache_key) {
                Some(k) => k.clone(),
                None => {
                    synthesized += 1;
                    let k = build_design_kernel(
                        &self.trained.qmlp,
                        &self.qtrain,
                        &self.qtest,
                        &self.stimulus,
                        self.cfg.hw.clock_ms,
                        opts.approx_argmax,
                        &self.map,
                        &ind.genome,
                    );
                    self.design_cache.insert(cache_key, k.clone());
                    k
                }
            };
            designs.push(FinalDesign {
                genome: ind.genome.clone(),
                acc_test_accum: kernel.acc_test_accum,
                acc_test_full: kernel.acc_test_full,
                acc_train: self.trained.acc_q_train - ind.objs[0],
                area_fa: area_model.estimate(&ind.genome),
                objs: ind.objs.clone(),
                argmax_plan: kernel.argmax_plan,
                hw_exact_argmax: kernel.hw_exact_argmax,
                hw_full: kernel.hw_full,
                hw_0p6v: kernel.hw_0p6v,
                power_source: kernel.power_source,
            });
        }
        drop(_sp_designs);
        telemetry::count(Counter::CoordDesignsSynthesized, synthesized);
        log(&format!(
            "synthesized {synthesized} of {} final designs (rest warm from the kernel cache)",
            designs.len()
        ));

        Ok(PipelineResult {
            cfg: self.cfg.clone(),
            trained: self.trained.clone(),
            baseline_acc_test: self.baseline_acc_test,
            baseline_hw: self.baseline_hw.clone(),
            qat_hw: self.qat_hw.clone(),
            front,
            front_hw,
            designs,
            backend_used,
            objective: opts.objective,
        })
    }
}

/// Pick a spread of designs along the front for hardware synthesis:
/// always the best-primary-cost feasible point, plus evenly spaced
/// others (spread along objective 1 whatever the front's arity).
fn select_designs(front: &[FrontPoint], max_points: usize) -> Vec<FrontPoint> {
    if front.is_empty() {
        return Vec::new();
    }
    let mut sorted: Vec<FrontPoint> = front.to_vec();
    sorted.sort_by(|a, b| a.objs[1].partial_cmp(&b.objs[1]).unwrap());
    if sorted.len() <= max_points {
        return sorted;
    }
    let mut out = Vec::with_capacity(max_points);
    for k in 0..max_points {
        let idx = k * (sorted.len() - 1) / (max_points - 1).max(1);
        out.push(sorted[idx].clone());
    }
    out.dedup_by(|a, b| a.objs == b.objs);
    out
}

impl PipelineResult {
    /// The best design within `loss` of the baseline test accuracy
    /// (the paper's 5% selection rule), by full-circuit area.
    pub fn best_within_loss(&self, loss: f64) -> Option<&FinalDesign> {
        self.designs
            .iter()
            .filter(|d| d.acc_test_full >= self.baseline_acc_test - loss)
            .min_by(|a, b| a.hw_full.area_cm2.partial_cmp(&b.hw_full.area_cm2).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;

    #[test]
    fn pipeline_tiny_native_end_to_end() {
        let mut cfg = builtin::tiny();
        cfg.ga.population = 24;
        cfg.ga.generations = 4;
        let opts = PipelineOpts {
            backend: EvalBackend::Native,
            max_hw_points: 2,
            ..Default::default()
        };
        let result = Pipeline::new(cfg, opts).run().expect("pipeline");
        assert!(result.trained.acc_q_test > 0.6);
        assert!(!result.front.is_empty());
        assert!(!result.designs.is_empty());
        let baseline = result.baseline_hw.as_ref().unwrap();
        for d in &result.designs {
            // Holistic approximation must beat the exact baseline.
            assert!(d.hw_full.area_cm2 < baseline.area_cm2);
            assert!(d.hw_full.power_mw < baseline.power_mw);
            // 0.6V corner saves power over 1V.
            assert!(d.hw_0p6v.power_mw < d.hw_full.power_mw);
            assert!(d.hw_full.meets_timing);
        }
        assert_eq!(result.backend_used, "native");
    }

    #[test]
    fn study_repeat_request_is_warm_and_identical() {
        // The serve-layer contract on one study: a repeated request
        // reuses the parked evaluator (fitness memo + arena fleet) and
        // the design-kernel cache — zero new kernels, one evaluator —
        // and both answers are bit-identical to a cold study's.
        let mut cfg = builtin::tiny();
        cfg.ga.population = 16;
        cfg.ga.generations = 2;
        let opts = PipelineOpts {
            backend: EvalBackend::Circuit,
            synth_baseline: false,
            max_hw_points: 2,
            ..Default::default()
        };
        let req = DesignRequest { ga: cfg.ga.clone(), opts: opts.clone() };
        let mut study = Study::new(cfg.clone(), &opts).expect("study");
        let first = study.design(&req).expect("first request");
        assert_eq!(study.evaluators.len(), 1);
        let kernels = study.design_cache.len();
        assert_eq!(kernels as u64, first.designs.len() as u64, "cold run synthesizes every design");
        let second = study.design(&req).expect("repeat request");
        assert_eq!(study.evaluators.len(), 1, "repeat must reuse the warm evaluator");
        assert_eq!(
            study.design_cache.len(),
            kernels,
            "repeat request must reassemble designs from the kernel cache"
        );
        assert_eq!(first.front, second.front);
        assert_eq!(first.front_hw, second.front_hw);
        assert!(
            first.front_hw.iter().all(|hw| hw.is_some()),
            "incremental circuit runs park survivor hardware for every front member"
        );
        assert_eq!(first.designs.len(), second.designs.len());
        for (a, b) in first.designs.iter().zip(&second.designs) {
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.objs, b.objs);
            assert_eq!(a.acc_test_full, b.acc_test_full);
            assert_eq!(a.hw_full.area_cm2, b.hw_full.area_cm2);
            assert_eq!(a.hw_full.power_mw, b.hw_full.power_mw);
        }
        // Warm-vs-cold determinism: a fresh study answers identically.
        let mut cold = Study::new(cfg, &opts).expect("cold study");
        let third = cold.design(&req).expect("cold request");
        assert_eq!(first.front, third.front);
        assert_eq!(first.front_hw, third.front_hw);
    }

    #[test]
    fn select_designs_spreads() {
        let mk = |a: f64, ar: f64| FrontPoint {
            genome: crate::util::BitVec::zeros(4),
            objs: vec![a, ar],
        };
        let front: Vec<_> = (0..10).map(|i| mk(i as f64 * 0.01, 100.0 - i as f64)).collect();
        let sel = select_designs(&front, 3);
        assert_eq!(sel.len(), 3);
        // Sorted by area: first is the smallest-area point.
        assert_eq!(sel[0].objs[1], 91.0);
        assert_eq!(sel[2].objs[1], 100.0);
    }
}
