//! The exact bespoke baseline [8] (Mubarik et al., MICRO'20): 8-bit
//! fixed-point weights hardwired into bespoke constant-coefficient
//! multipliers, 4-bit inputs, two unsigned accumulators per neuron
//! (positive/negative weights), full-precision Relu in the hidden layer,
//! exact argmax at the output. This is the normalization baseline of
//! every table and figure in the paper.

use crate::config::Topology;
use crate::datasets::QuantDataset;
use crate::fixedpoint::{bits_for, INPUT_BITS};
use crate::model::FloatMlp;
use crate::netlist::build::{const_mul, csa_tree, resize, sign_extend, subtractor};
use crate::netlist::mlp::ArgmaxMode;
use crate::netlist::{Bus, Netlist};

/// 8-bit fixed-point quantized MLP (the baseline's arithmetic model).
#[derive(Clone, Debug)]
pub struct Int8Mlp {
    pub topo: Topology,
    /// `(n_hidden, n_in)` flat, values in `[-127, 127]`.
    pub w1: Vec<i32>,
    pub b1: Vec<i64>,
    /// `(n_out, n_hidden)` flat.
    pub w2: Vec<i32>,
    pub b2: Vec<i64>,
}

/// Quantize a float weight matrix to symmetric 8-bit integers with a
/// power-of-2 scale (so the circuit needs no rescaling logic).
fn quantize_w8(w: &[Vec<f64>]) -> (Vec<i32>, f64) {
    let maxabs = w
        .iter()
        .flatten()
        .fold(0.0f64, |m, &v| m.max(v.abs()))
        .max(1e-9);
    // Power-of-2 scale covering maxabs at 7 magnitude bits.
    let scale = (2f64).powi((maxabs / 127.0).log2().ceil() as i32);
    let q = w
        .iter()
        .flatten()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i32)
        .collect();
    (q, scale)
}

impl Int8Mlp {
    /// Quantize a trained float MLP to the baseline's 8-bit format.
    pub fn from_float(float: &FloatMlp) -> Int8Mlp {
        let topo = float.topo;
        let (w1, s1) = quantize_w8(&float.w1);
        let (w2, s2) = quantize_w8(&float.w2);
        // Bias in layer-1 accumulator units: input scale 2^-4, weight
        // scale s1 -> column scale s1 / 16.
        let c1 = s1 / (1u64 << INPUT_BITS) as f64;
        let b1 = float.b1.iter().map(|&b| (b / c1).round() as i64).collect();
        // Hidden activations stay in layer-1 accumulator units (full
        // precision Relu), so layer-2 columns scale by s2 on top.
        let c2 = c1 * s2;
        let b2 = float.b2.iter().map(|&b| (b / c2).round() as i64).collect();
        Int8Mlp { topo, w1, b1, w2, b2 }
    }

    /// Integer forward pass; returns (hidden Relu outputs, logits).
    pub fn forward(&self, x: &[u32]) -> (Vec<i64>, Vec<i64>) {
        let t = self.topo;
        let mut h = vec![0i64; t.n_hidden];
        for (n, hn) in h.iter_mut().enumerate() {
            let mut acc = self.b1[n];
            for (j, &xj) in x.iter().enumerate() {
                acc += self.w1[n * t.n_in + j] as i64 * xj as i64;
            }
            *hn = acc.max(0); // full-precision Relu
        }
        let mut z = vec![0i64; t.n_out];
        for (m, zm) in z.iter_mut().enumerate() {
            let mut acc = self.b2[m];
            for (n, &hn) in h.iter().enumerate() {
                acc += self.w2[m * t.n_hidden + n] as i64 * hn;
            }
            *zm = acc;
        }
        (h, z)
    }

    pub fn predict(&self, x: &[u32]) -> usize {
        crate::model::quantized::argmax_i(&self.forward(x).1)
    }

    pub fn accuracy(&self, ds: &QuantDataset) -> f64 {
        if ds.y.is_empty() {
            return 0.0;
        }
        let ok = ds.x.iter().zip(&ds.y).filter(|(x, &y)| self.predict(x) == y).count();
        ok as f64 / ds.y.len() as f64
    }

    /// Worst-case hidden activation magnitude (determines bus widths).
    pub fn hidden_max(&self) -> u64 {
        let t = self.topo;
        let amax = ((1u32 << INPUT_BITS) - 1) as i64;
        (0..t.n_hidden)
            .map(|n| {
                let mut pos = self.b1[n].max(0);
                for j in 0..t.n_in {
                    let w = self.w1[n * t.n_in + j] as i64;
                    if w > 0 {
                        pos += w * amax;
                    }
                }
                pos as u64
            })
            .max()
            .unwrap_or(1)
            .max(1)
    }

    /// Build the bespoke gate-level circuit of the baseline.
    ///
    /// Weight magnitudes instantiate shift-add constant multipliers, the
    /// products accumulate in pos/neg carry-save trees, hidden Relu is a
    /// sign-controlled AND mask, and the output is an exact argmax tree
    /// (or the raw logits in [`ArgmaxMode::Raw`]).
    pub fn build_circuit(&self, argmax: ArgmaxMode) -> Netlist {
        let t = self.topo;
        let mut nl = Netlist::new();
        let x: Vec<Bus> = (0..t.n_in).map(|_| nl.input_bus(INPUT_BITS)).collect();

        // Hidden layer.
        let hwidth = bits_for(self.hidden_max());
        let mut h: Vec<Bus> = Vec::with_capacity(t.n_hidden);
        for n in 0..t.n_hidden {
            let z = self.neuron_bus(&mut nl, &x, &self.w1, self.b1[n], n, t.n_in);
            // Relu: AND every magnitude bit with ~sign.
            let sign = *z.last().unwrap();
            let not_sign = nl.not(sign);
            let relu: Bus =
                z[..z.len() - 1].iter().map(|&bit| nl.and(not_sign, bit)).collect();
            h.push(resize(&mut nl, &relu, hwidth));
        }

        // Output layer.
        let mut z2: Vec<Bus> = Vec::with_capacity(t.n_out);
        let mut zwidth = 2;
        for m in 0..t.n_out {
            let z = self.neuron_bus(&mut nl, &h, &self.w2, self.b2[m], m, t.n_hidden);
            zwidth = zwidth.max(z.len() as u32);
            z2.push(z);
        }
        let z2: Vec<Bus> = z2.iter().map(|z| sign_extend(&mut nl, z, zwidth)).collect();

        match argmax {
            ArgmaxMode::Raw => {
                for (m, z) in z2.iter().enumerate() {
                    nl.output(&format!("z{m}"), z.clone());
                }
            }
            _ => {
                let plan = crate::argmax::ArgmaxPlan::exact(t.n_out, zwidth);
                let class = exact_argmax_tree(&mut nl, &z2, &plan);
                nl.output("class", class);
            }
        }
        nl
    }

    /// One baseline neuron: constant multipliers + pos/neg trees + sub.
    fn neuron_bus(
        &self,
        nl: &mut Netlist,
        inputs: &[Bus],
        w: &[i32],
        bias: i64,
        n: usize,
        n_in: usize,
    ) -> Bus {
        let mut pos: Vec<Bus> = Vec::new();
        let mut neg: Vec<Bus> = Vec::new();
        for (j, input) in inputs.iter().enumerate() {
            let wv = w[n * n_in + j];
            if wv == 0 {
                continue;
            }
            let product = const_mul(nl, input, wv.unsigned_abs() as u64);
            if wv > 0 {
                pos.push(product);
            } else {
                neg.push(product);
            }
        }
        if bias != 0 {
            let mag = bias.unsigned_abs();
            let bus = crate::netlist::build::const_bus(nl, mag, bits_for(mag));
            if bias > 0 {
                pos.push(bus);
            } else {
                neg.push(bus);
            }
        }
        let psum = csa_tree(nl, &pos);
        let nsum = csa_tree(nl, &neg);
        let w = psum.len().max(nsum.len()) as u32;
        let psum = resize(nl, &psum, w);
        let nsum = resize(nl, &nsum, w);
        subtractor(nl, &psum, &nsum)
    }
}

/// Exact/approximate argmax comparator tree over raw logits buses
/// (shared by the baseline generators).
pub fn exact_argmax_tree(
    nl: &mut Netlist,
    z: &[Bus],
    plan: &crate::argmax::ArgmaxPlan,
) -> Bus {
    use crate::netlist::build::{bias_signed, const_bus, masked_gt, mux_bus};
    let idx_width = bits_for((z.len().max(2) - 1) as u64);
    let mut slots: Vec<(Bus, Bus)> = z
        .iter()
        .enumerate()
        .map(|(i, bus)| {
            let biased = bias_signed(nl, bus);
            (biased, const_bus(nl, i as u64, idx_width))
        })
        .collect();
    for stage in &plan.stages {
        let mut used = vec![false; slots.len()];
        let mut next = Vec::with_capacity(stage.len() + 1);
        for cmp in stage {
            let (va, ia) = slots[cmp.a].clone();
            let (vb, ib) = slots[cmp.b].clone();
            used[cmp.a] = true;
            used[cmp.b] = true;
            let sel = masked_gt(nl, &va, &vb, cmp.mask);
            next.push((mux_bus(nl, sel, &va, &vb), mux_bus(nl, sel, &ia, &ib)));
        }
        for (k, s) in slots.iter().enumerate() {
            if !used[k] {
                next.push(s.clone());
            }
        }
        slots = next;
    }
    slots[0].1.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;
    use crate::datasets;
    use crate::model::float_mlp::TrainOpts;
    use crate::sim::{bus_to_i64, bus_to_u64, eval, u64_to_bits};
    use crate::synth::optimize;

    fn trained() -> (Int8Mlp, crate::datasets::QuantDataset) {
        let cfg = builtin::tiny();
        let (split, qtrain, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 25, ..Default::default() });
        (Int8Mlp::from_float(&mlp), qtrain)
    }

    #[test]
    fn baseline_keeps_float_accuracy() {
        let cfg = builtin::tiny();
        let (split, _, qtest) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 25, ..Default::default() });
        let float_acc = mlp.accuracy(&split.test, false);
        let int8 = Int8Mlp::from_float(&mlp);
        let int_acc = int8.accuracy(&qtest);
        assert!(
            int_acc > float_acc - 0.08,
            "8-bit baseline collapsed: {int_acc} vs {float_acc}"
        );
    }

    #[test]
    fn circuit_matches_model_raw() {
        let (int8, qtrain) = trained();
        let nl = int8.build_circuit(ArgmaxMode::Raw);
        let (opt, _) = optimize(&nl);
        for row in qtrain.x.iter().take(25) {
            let (_, z) = int8.forward(row);
            let mut bits = Vec::new();
            for &xi in row {
                bits.extend(u64_to_bits(xi as u64, INPUT_BITS));
            }
            let out = eval(&opt, &bits);
            for (m, &zm) in z.iter().enumerate() {
                assert_eq!(bus_to_i64(&out[&format!("z{m}")]), zm, "neuron {m}");
            }
        }
    }

    #[test]
    fn circuit_matches_model_class() {
        let (int8, qtrain) = trained();
        let nl = int8.build_circuit(ArgmaxMode::Exact);
        let (opt, _) = optimize(&nl);
        for row in qtrain.x.iter().take(25) {
            let expect = int8.predict(row);
            let mut bits = Vec::new();
            for &xi in row {
                bits.extend(u64_to_bits(xi as u64, INPUT_BITS));
            }
            let out = eval(&opt, &bits);
            assert_eq!(bus_to_u64(&out["class"]) as usize, expect);
        }
    }

    #[test]
    fn baseline_is_much_larger_than_po2() {
        // Table III's story: po2 + QRelu cuts the baseline area by
        // 2.5-5x. Check the direction on the tiny config.
        let cfg = builtin::tiny();
        let (split, qtrain, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 25, ..Default::default() });
        let int8 = Int8Mlp::from_float(&mlp);
        let (base_nl, _) = optimize(&int8.build_circuit(ArgmaxMode::Exact));
        let qmlp = crate::model::QuantMlp::from_float(&mlp, &qtrain);
        let po2_nl = crate::netlist::mlp::build_mlp_circuit(
            &qmlp,
            &crate::netlist::mlp::MlpCircuitOpts::default(),
        );
        let (po2_opt, _) = optimize(&po2_nl);
        assert!(
            base_nl.cell_count() as f64 > 1.5 * po2_opt.cell_count() as f64,
            "baseline {} vs po2 {}",
            base_nl.cell_count(),
            po2_opt.cell_count()
        );
    }

    #[test]
    fn quantize_w8_range() {
        let w = vec![vec![0.5, -1.0, 0.124], vec![0.0, 2.0, -0.3]];
        let (q, scale) = quantize_w8(&w);
        assert!(q.iter().all(|&v| (-127..=127).contains(&v)));
        // Max magnitude must map near the top of the range.
        let maxq = q.iter().map(|v| v.abs()).max().unwrap();
        assert!(maxq >= 64, "scale wastes range: maxq={maxq} scale={scale}");
    }
}
