//! The [10] baseline (Armeniakos et al., IEEE TCAD 2023):
//! model-to-circuit cross-approximation — multiplier approximation at
//! the model level plus *generic gate-level pruning* at the circuit
//! level, with voltage over-scaling (VOS) on top.
//!
//! Gate-level pruning: simulate the synthesized circuit over the train
//! set, find cells whose output is (almost) constant (`p(1) ≤ ε` or
//! `≥ 1-ε`), replace them with that constant, and let synthesis sweep
//! the constants — trading classification error for area. VOS is modeled
//! as a supply-scaling power bonus on the already-relaxed circuit
//! (the paper's [10] rows sit between [7] and our framework in Fig. 5).

use crate::baselines::truncation::TruncMlp;
use crate::datasets::QuantDataset;
use crate::netlist::mlp::ArgmaxMode;
use crate::netlist::{Gate, Netlist};
use crate::sim::{bus_to_u64, eval_nodes, u64_to_bits};
use crate::synth::optimize;

/// Power factor granted by voltage over-scaling (the [10] designs run
/// below nominal VDD and absorb sporadic timing errors in the accuracy
/// budget).
pub const VOS_POWER_FACTOR: f64 = 0.8;

/// Result of a pruning run.
#[derive(Clone, Debug)]
pub struct PrunedCircuit {
    pub netlist: Netlist,
    pub accuracy: f64,
    /// Number of cells replaced by constants.
    pub pruned_cells: usize,
    pub epsilon: f64,
}

/// Prune near-constant gates of `nl` at threshold `epsilon`, measuring
/// constancy and accuracy over `ds` (paper [9]/[10] use the train set).
pub fn prune_netlist(nl: &Netlist, ds: &QuantDataset, epsilon: f64) -> PrunedCircuit {
    let bits_per_sample = |row: &[u32]| -> Vec<bool> {
        let mut v = Vec::with_capacity(row.len() * ds.bits as usize);
        for &xi in row {
            v.extend(u64_to_bits(xi as u64, ds.bits));
        }
        v
    };

    // Pass 1: signal probabilities per node.
    let n_nodes = nl.gates.len();
    let mut ones = vec![0u32; n_nodes];
    let sample_cap = ds.x.len().min(256);
    for row in ds.x.iter().take(sample_cap) {
        let vals = eval_nodes(nl, &bits_per_sample(row));
        for (i, &v) in vals.iter().enumerate() {
            ones[i] += v as u32;
        }
    }
    let total = sample_cap as f64;

    // Pass 2: rewrite near-constant cells as constants.
    let mut pruned = nl.clone();
    let mut pruned_cells = 0;
    for (i, g) in nl.gates.iter().enumerate() {
        if !g.is_cell() {
            continue;
        }
        let p1 = ones[i] as f64 / total;
        if p1 <= epsilon {
            pruned.gates[i] = Gate::Const(false);
            pruned_cells += 1;
        } else if p1 >= 1.0 - epsilon {
            pruned.gates[i] = Gate::Const(true);
            pruned_cells += 1;
        }
    }
    let (opt, _) = optimize(&pruned);

    // Accuracy of the pruned circuit on the dataset.
    let mut correct = 0usize;
    for (row, &y) in ds.x.iter().zip(&ds.y) {
        let vals = eval_nodes(&opt, &bits_per_sample(row));
        let class_bus = &opt.outputs.iter().find(|(n, _)| n == "class").expect("class out").1;
        let bits: Vec<bool> = class_bus.iter().map(|&b| vals[b as usize]).collect();
        if bus_to_u64(&bits) as usize == y {
            correct += 1;
        }
    }
    PrunedCircuit {
        netlist: opt,
        accuracy: correct as f64 / ds.y.len().max(1) as f64,
        pruned_cells,
        epsilon,
    }
}

/// The full [10] pipeline: multiplier-approximated model, synthesized
/// circuit, pruning sweep; returns the candidates (caller picks the
/// best within its accuracy budget).
pub fn run_sweep(
    model: &TruncMlp,
    ds: &QuantDataset,
    epsilons: &[f64],
) -> Vec<PrunedCircuit> {
    let nl = model.build_circuit(ArgmaxMode::Exact);
    let (opt, _) = optimize(&nl);
    epsilons.iter().map(|&e| prune_netlist(&opt, ds, e)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::exact::Int8Mlp;
    use crate::config::builtin;
    use crate::datasets;
    use crate::model::float_mlp::TrainOpts;
    use crate::model::FloatMlp;

    fn trained() -> (TruncMlp, crate::datasets::QuantDataset) {
        let cfg = builtin::tiny();
        let (split, qtrain, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 25, ..Default::default() });
        (TruncMlp::new(Int8Mlp::from_float(&mlp), 0, 0), qtrain)
    }

    #[test]
    fn zero_epsilon_prunes_only_stuck_gates() {
        let (model, qtrain) = trained();
        let nl = model.build_circuit(ArgmaxMode::Exact);
        let (opt, _) = optimize(&nl);
        let base_acc = {
            let mut correct = 0;
            for (row, &y) in qtrain.x.iter().zip(&qtrain.y) {
                if model.predict(row) == y {
                    correct += 1;
                }
            }
            correct as f64 / qtrain.y.len() as f64
        };
        let pruned = prune_netlist(&opt, &qtrain, 0.0);
        // ε=0 only replaces gates constant across the sampled vectors —
        // accuracy may move slightly (sample- vs full-set constancy) but
        // must stay close.
        assert!(
            (pruned.accuracy - base_acc).abs() < 0.05,
            "ε=0 accuracy moved: {} vs {base_acc}",
            pruned.accuracy
        );
    }

    #[test]
    fn aggressive_pruning_shrinks_circuit() {
        let (model, qtrain) = trained();
        let nl = model.build_circuit(ArgmaxMode::Exact);
        let (opt, _) = optimize(&nl);
        let mild = prune_netlist(&opt, &qtrain, 0.01);
        let hard = prune_netlist(&opt, &qtrain, 0.20);
        assert!(hard.pruned_cells > mild.pruned_cells);
        assert!(hard.netlist.cell_count() <= mild.netlist.cell_count());
    }

    #[test]
    fn sweep_produces_monotone_cells() {
        let (model, qtrain) = trained();
        let res = run_sweep(&model, &qtrain, &[0.0, 0.05, 0.15]);
        assert_eq!(res.len(), 3);
        assert!(res[2].netlist.cell_count() <= res[0].netlist.cell_count());
        for r in &res {
            assert!(r.accuracy >= 0.0 && r.accuracy <= 1.0);
        }
    }
}
