//! The [7] baseline (Armeniakos et al., IEEE TC 2023): co-designed
//! approximate MLPs with (a) *multiplier approximation* — weights are
//! replaced by hardware-friendly values whose bespoke multipliers are
//! cheap (we round each 8-bit magnitude to its nearest ≤2-set-bit
//! value), and (b) *coarse-grain truncation* of the accumulators — the
//! bottom `T` columns of every adder tree are dropped wholesale (the
//! paper contrasts this coarse approach with our per-bit removal:
//! "[7] applied only coarse-grain truncation on the accumulators,
//! limiting thus the potential gains").
//!
//! The sweep over `T` produces this baseline's accuracy/area trade-off
//! curve for Fig. 5.

use crate::baselines::exact::{exact_argmax_tree, Int8Mlp};
use crate::datasets::QuantDataset;
use crate::fixedpoint::bits_for;
use crate::model::quantized::argmax_i;
use crate::netlist::build::{const_bus, const_mul, csa_tree, resize, sign_extend, shl, subtractor};
use crate::netlist::mlp::ArgmaxMode;
use crate::netlist::{Bus, Netlist};

/// Round an 8-bit magnitude to the nearest value with at most two set
/// bits (the class of cheap bespoke multipliers [7] retains).
pub fn round_to_2set_bits(v: u32) -> u32 {
    if v.count_ones() <= 2 {
        return v;
    }
    let mut best = 0u32;
    let mut best_err = i64::MAX;
    for hi in 0..12u32 {
        // single power
        let c = 1u32 << hi;
        let err = (c as i64 - v as i64).abs();
        if err < best_err {
            best_err = err;
            best = c;
        }
        for lo in 0..hi {
            let c = (1u32 << hi) | (1u32 << lo);
            let err = (c as i64 - v as i64).abs();
            if err < best_err {
                best_err = err;
                best = c;
            }
        }
    }
    best
}

/// The [7]-style approximate MLP: 8-bit weights rounded to ≤2-set-bit
/// magnitudes + accumulator truncation depth `t1`/`t2` per layer.
#[derive(Clone, Debug)]
pub struct TruncMlp {
    pub base: Int8Mlp,
    /// Truncated LSB columns of the hidden-layer accumulators.
    pub t1: u32,
    /// Truncated LSB columns of the output-layer accumulators.
    pub t2: u32,
}

impl TruncMlp {
    /// Apply the multiplier approximation to an [`Int8Mlp`] and set the
    /// truncation depths.
    pub fn new(mut base: Int8Mlp, t1: u32, t2: u32) -> TruncMlp {
        for w in base.w1.iter_mut().chain(base.w2.iter_mut()) {
            let mag = round_to_2set_bits(w.unsigned_abs());
            *w = if *w < 0 { -(mag as i32) } else { mag as i32 };
        }
        TruncMlp { base, t1, t2 }
    }

    /// Integer forward with truncated accumulation: every summand drops
    /// its bottom `t` bits (coarse column truncation).
    pub fn forward(&self, x: &[u32]) -> Vec<i64> {
        let t = self.base.topo;
        let m1 = !0i64 << self.t1;
        let m2 = !0i64 << self.t2;
        // Truncation acts on magnitudes (the circuit zeroes the bottom
        // columns of each unsigned summand before the pos/neg trees).
        let trunc = |v: i64, m: i64| if v >= 0 { v & m } else { -((-v) & m) };
        let mut h = vec![0i64; t.n_hidden];
        for (n, hn) in h.iter_mut().enumerate() {
            let mut acc = trunc(self.base.b1[n], m1);
            for (j, &xj) in x.iter().enumerate() {
                let p = self.base.w1[n * t.n_in + j] as i64 * xj as i64;
                // Truncate the product magnitude's low columns.
                acc += trunc(p, m1);
            }
            *hn = acc.max(0);
        }
        let mut z = vec![0i64; t.n_out];
        for (m, zm) in z.iter_mut().enumerate() {
            let mut acc = trunc(self.base.b2[m], m2);
            for (n, &hn) in h.iter().enumerate() {
                let p = self.base.w2[m * t.n_hidden + n] as i64 * hn;
                acc += trunc(p, m2);
            }
            *zm = acc;
        }
        z
    }

    pub fn predict(&self, x: &[u32]) -> usize {
        argmax_i(&self.forward(x))
    }

    pub fn accuracy(&self, ds: &QuantDataset) -> f64 {
        if ds.y.is_empty() {
            return 0.0;
        }
        let ok = ds.x.iter().zip(&ds.y).filter(|(x, &y)| self.predict(x) == y).count();
        ok as f64 / ds.y.len() as f64
    }

    /// Bespoke circuit: cheap 2-set-bit multipliers; truncated columns
    /// become constant zeros for synthesis to sweep.
    pub fn build_circuit(&self, argmax: ArgmaxMode) -> Netlist {
        let t = self.base.topo;
        let mut nl = Netlist::new();
        let x: Vec<Bus> =
            (0..t.n_in).map(|_| nl.input_bus(crate::fixedpoint::INPUT_BITS)).collect();
        let hwidth = bits_for(self.base.hidden_max());
        let mut h: Vec<Bus> = Vec::with_capacity(t.n_hidden);
        for n in 0..t.n_hidden {
            let z = self.neuron_bus(&mut nl, &x, true, n);
            let sign = *z.last().unwrap();
            let not_sign = nl.not(sign);
            let relu: Bus =
                z[..z.len() - 1].iter().map(|&b| nl.and(not_sign, b)).collect();
            h.push(resize(&mut nl, &relu, hwidth));
        }
        let mut z2: Vec<Bus> = Vec::new();
        let mut zwidth = 2;
        for m in 0..t.n_out {
            let z = self.neuron_bus(&mut nl, &h, false, m);
            zwidth = zwidth.max(z.len() as u32);
            z2.push(z);
        }
        let z2: Vec<Bus> = z2.iter().map(|z| sign_extend(&mut nl, z, zwidth)).collect();
        match argmax {
            ArgmaxMode::Raw => {
                for (m, z) in z2.iter().enumerate() {
                    nl.output(&format!("z{m}"), z.clone());
                }
            }
            _ => {
                let plan = crate::argmax::ArgmaxPlan::exact(t.n_out, zwidth);
                let class = exact_argmax_tree(&mut nl, &z2, &plan);
                nl.output("class", class);
            }
        }
        nl
    }

    fn neuron_bus(&self, nl: &mut Netlist, inputs: &[Bus], layer1: bool, n: usize) -> Bus {
        let t = self.base.topo;
        let (w, bias, n_in, trunc) = if layer1 {
            (&self.base.w1, self.base.b1[n], t.n_in, self.t1)
        } else {
            (&self.base.w2, self.base.b2[n], t.n_hidden, self.t2)
        };
        let mut pos: Vec<Bus> = Vec::new();
        let mut neg: Vec<Bus> = Vec::new();
        let mut push = |nl: &mut Netlist, bus: Bus, positive: bool| {
            // Coarse truncation: zero the bottom `trunc` columns.
            let mut tb = bus;
            for b in tb.iter_mut().take(trunc as usize) {
                *b = nl.constant(false);
            }
            if positive {
                pos.push(tb);
            } else {
                neg.push(tb);
            }
        };
        for (j, input) in inputs.iter().enumerate() {
            let wv = w[n * n_in + j];
            if wv == 0 {
                continue;
            }
            // ≤2-set-bit magnitude -> at most one adder per product.
            let mag = wv.unsigned_abs() as u64;
            let product = if mag.count_ones() == 1 {
                shl(nl, input, mag.trailing_zeros())
            } else {
                const_mul(nl, input, mag)
            };
            push(nl, product, wv > 0);
        }
        if bias != 0 {
            let magb = bias.unsigned_abs();
            let bus = const_bus(nl, magb, bits_for(magb));
            push(nl, bus, bias > 0);
        }
        let psum = csa_tree(nl, &pos);
        let nsum = csa_tree(nl, &neg);
        let w = psum.len().max(nsum.len()) as u32;
        let psum = resize(nl, &psum, w);
        let nsum = resize(nl, &nsum, w);
        subtractor(nl, &psum, &nsum)
    }
}

/// Sweep truncation depths and return `(t1, t2, accuracy)` candidates
/// sorted by aggressiveness — the baseline's design space for Fig. 5.
pub fn sweep(base: &Int8Mlp, ds: &QuantDataset, max_t: u32) -> Vec<(u32, u32, f64)> {
    let mut out = Vec::new();
    for t1 in 0..=max_t {
        for t2 in 0..=max_t {
            let m = TruncMlp::new(base.clone(), t1, t2);
            out.push((t1, t2, m.accuracy(ds)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;
    use crate::datasets;
    use crate::model::float_mlp::TrainOpts;
    use crate::model::FloatMlp;
    use crate::sim::{bus_to_i64, eval, u64_to_bits};
    use crate::synth::optimize;

    fn trained() -> (Int8Mlp, crate::datasets::QuantDataset) {
        let cfg = builtin::tiny();
        let (split, qtrain, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 25, ..Default::default() });
        (Int8Mlp::from_float(&mlp), qtrain)
    }

    #[test]
    fn round_to_2set_bits_cases() {
        assert_eq!(round_to_2set_bits(0), 0);
        assert_eq!(round_to_2set_bits(5), 5); // 101 already 2 bits
        assert_eq!(round_to_2set_bits(7), 6); // 111 -> 110 (or 1000; 6 closer)
        assert_eq!(round_to_2set_bits(127), 128); // 1111111 -> 10000000
        assert_eq!(round_to_2set_bits(100), 96); // 1100100 -> 1100000
        for v in 0..=255u32 {
            assert!(round_to_2set_bits(v).count_ones() <= 2);
        }
    }

    #[test]
    fn zero_truncation_close_to_base() {
        let (base, qtrain) = trained();
        let t = TruncMlp::new(base.clone(), 0, 0);
        // Only the weight rounding differs; accuracy should stay close.
        let a_base = base.accuracy(&qtrain);
        let a_t = t.accuracy(&qtrain);
        assert!(a_t > a_base - 0.15, "rounding destroyed accuracy: {a_t} vs {a_base}");
    }

    #[test]
    fn deeper_truncation_smaller_circuit() {
        let (base, _) = trained();
        let shallow = TruncMlp::new(base.clone(), 0, 0);
        let deep = TruncMlp::new(base, 4, 4);
        let (s, _) = optimize(&shallow.build_circuit(ArgmaxMode::Exact));
        let (d, _) = optimize(&deep.build_circuit(ArgmaxMode::Exact));
        assert!(
            d.cell_count() < s.cell_count(),
            "deep {} !< shallow {}",
            d.cell_count(),
            s.cell_count()
        );
    }

    #[test]
    fn circuit_matches_model() {
        let (base, qtrain) = trained();
        let t = TruncMlp::new(base, 2, 1);
        let nl = t.build_circuit(ArgmaxMode::Raw);
        let (opt, _) = optimize(&nl);
        for row in qtrain.x.iter().take(20) {
            let z = t.forward(row);
            let mut bits = Vec::new();
            for &xi in row {
                bits.extend(u64_to_bits(xi as u64, 4));
            }
            let out = eval(&opt, &bits);
            for (m, &zm) in z.iter().enumerate() {
                assert_eq!(bus_to_i64(&out[&format!("z{m}")]), zm, "neuron {m}");
            }
        }
    }

    #[test]
    fn sweep_accuracy_trends_down() {
        let (base, qtrain) = trained();
        let sw = sweep(&base, &qtrain, 3);
        let a00 = sw.iter().find(|&&(a, b, _)| a == 0 && b == 0).unwrap().2;
        let a33 = sw.iter().find(|&&(a, b, _)| a == 3 && b == 3).unwrap().2;
        assert!(a33 <= a00 + 0.05, "truncation should not help: {a33} vs {a00}");
        assert_eq!(sw.len(), 16);
    }
}
