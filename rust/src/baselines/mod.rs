//! The comparison systems of the paper's evaluation (Table I / Fig. 5):
//!
//! * [`exact`] — the exact bespoke baseline of Mubarik et al. [8]:
//!   8-bit fixed-point weights, 4-bit inputs, real multipliers,
//!   full-precision Relu. Every number in the paper is normalized
//!   against this design.
//! * [`truncation`] — Armeniakos et al. [7]: multiplier approximation
//!   (hardware-friendly weight replacement) plus *coarse-grain* LSB
//!   truncation of the accumulators.
//! * [`prune`] — Armeniakos et al. [10]: model-to-circuit
//!   cross-approximation — multiplier approximation plus gate-level
//!   pruning of near-constant gates (with a voltage-overscaling power
//!   bonus).
//! * [`crate::sc`] — Weller et al. [14]: stochastic-computing MLP with
//!   1024-bit bitstreams.

pub mod exact;
pub mod truncation;
pub mod prune;

pub use exact::Int8Mlp;
