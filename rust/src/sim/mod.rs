//! Gate-level logic simulation.
//!
//! Replaces the commercial simulation step (Synopsys VCS) of the paper's
//! flow: every generated circuit is functionally verified against the
//! integer model on concrete vectors (the equivalence chain of
//! DESIGN.md §2), and the toggle activity it reports feeds the dynamic
//! power estimate in `crate::egfet`.
//!
//! Two engines share the node/netlist model:
//!
//! * the scalar engine below — one `bool` per node, one vector at a time;
//!   simple, and the golden reference for the wave engine;
//! * [`wave`] — the bit-parallel engine: one `u64` lane word per node, 64
//!   vectors per forward pass, popcount-based toggle counting and
//!   thread-parallel batch dispatch. All batch workloads (toggle
//!   activity, dataset classification, equivalence sweeps) run on it.

pub mod wave;

use crate::netlist::{Gate, Netlist, NodeId};
// detlint: allow-file(std-hash) — reference interpreter returns buses
// keyed by output name; consumers index by name, never iterate.
use std::collections::HashMap;

/// Evaluate a netlist on one input vector; returns named output buses as
/// bit vectors (LSB first).
///
/// Convenience wrapper that allocates per call — hot paths should use
/// [`eval_nodes_into`] + [`gather_bus`] with reused buffers, or the
/// [`wave`] engine for batches.
pub fn eval(nl: &Netlist, inputs: &[bool]) -> HashMap<String, Vec<bool>> {
    let values = eval_nodes(nl, inputs);
    nl.outputs
        .iter()
        .map(|(name, bus)| {
            (name.clone(), bus.iter().map(|&n| values[n as usize]).collect())
        })
        .collect()
}

/// Evaluate and return the value of every node (single forward pass —
/// the gate list is topologically ordered by construction).
pub fn eval_nodes(nl: &Netlist, inputs: &[bool]) -> Vec<bool> {
    let mut v = Vec::new();
    eval_nodes_into(nl, inputs, &mut v);
    v
}

/// [`eval_nodes`] through a caller-owned buffer: `values` is cleared and
/// refilled, so repeated simulation performs no per-vector allocation.
pub fn eval_nodes_into(nl: &Netlist, inputs: &[bool], values: &mut Vec<bool>) {
    values.clear();
    values.reserve(nl.gates.len());
    for g in &nl.gates {
        let v = match *g {
            Gate::Input(idx) => {
                *inputs.get(idx as usize).unwrap_or_else(|| {
                    panic!("input {idx} missing ({} provided)", inputs.len())
                })
            }
            Gate::Const(c) => c,
            Gate::Param(p) => panic!("Param({p}) in simulation — instantiate first"),
            Gate::Not(a) => !values[a as usize],
            Gate::And(a, b) => values[a as usize] & values[b as usize],
            Gate::Or(a, b) => values[a as usize] | values[b as usize],
            Gate::Xor(a, b) => values[a as usize] ^ values[b as usize],
            Gate::Nand(a, b) => !(values[a as usize] & values[b as usize]),
            Gate::Nor(a, b) => !(values[a as usize] | values[b as usize]),
            Gate::Xnor(a, b) => !(values[a as usize] ^ values[b as usize]),
            Gate::Mux(s, a, b) => {
                if values[s as usize] {
                    values[b as usize]
                } else {
                    values[a as usize]
                }
            }
        };
        values.push(v);
    }
}

/// Gather an output bus out of a node-value slice into a caller-owned
/// buffer (cleared first) — the zero-allocation companion of [`eval`].
pub fn gather_bus(values: &[bool], bus: &[NodeId], out: &mut Vec<bool>) {
    out.clear();
    out.extend(bus.iter().map(|&n| values[n as usize]));
}

/// Interpret an output bus as an unsigned integer.
pub fn bus_to_u64(bits: &[bool]) -> u64 {
    bits.iter().enumerate().map(|(i, &b)| (b as u64) << i).sum()
}

/// Interpret an output bus as a signed (two's-complement) integer.
pub fn bus_to_i64(bits: &[bool]) -> i64 {
    let raw = bus_to_u64(bits) as i64;
    let w = bits.len() as u32;
    if w < 64 && bits.last() == Some(&true) {
        raw - (1i64 << w)
    } else {
        raw
    }
}

/// Pack an unsigned integer into an input bit vector (LSB first).
pub fn u64_to_bits(v: u64, width: u32) -> Vec<bool> {
    (0..width).map(|i| (v >> i) & 1 == 1).collect()
}

/// Average toggle activity per cell over a set of input vectors —
/// the activity factor used by the dynamic power model. Returns the
/// fraction of (cell, consecutive-vector) pairs whose value flipped.
///
/// Runs on the wave engine: consecutive vectors occupy adjacent lanes,
/// so each cell's toggles over a 64-vector window are two word ops and a
/// popcount (see [`wave::toggle_activity`]).
pub fn toggle_activity(nl: &Netlist, vectors: &[Vec<bool>]) -> f64 {
    wave::toggle_activity(nl, vectors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::Netlist;

    #[test]
    fn gate_truth_tables() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let and = nl.and(a, b);
        let or = nl.or(a, b);
        let xor = nl.xor(a, b);
        let nand = nl.nand(a, b);
        let nor = nl.nor(a, b);
        let xnor = nl.xnor(a, b);
        let not = nl.not(a);
        nl.output("all", vec![and, or, xor, nand, nor, xnor, not]);
        for (va, vb) in [(false, false), (false, true), (true, false), (true, true)] {
            let out = &eval(&nl, &[va, vb])["all"];
            assert_eq!(out[0], va & vb);
            assert_eq!(out[1], va | vb);
            assert_eq!(out[2], va ^ vb);
            assert_eq!(out[3], !(va & vb));
            assert_eq!(out[4], !(va | vb));
            assert_eq!(out[5], !(va ^ vb));
            assert_eq!(out[6], !va);
        }
    }

    #[test]
    fn mux_truth_table() {
        let mut nl = Netlist::new();
        let s = nl.input();
        let a = nl.input();
        let b = nl.input();
        let m = nl.mux(s, a, b);
        nl.output("m", vec![m]);
        assert_eq!(eval(&nl, &[false, true, false])["m"][0], true); // sel=0 -> a
        assert_eq!(eval(&nl, &[true, true, false])["m"][0], false); // sel=1 -> b
    }

    #[test]
    fn signed_conversion() {
        assert_eq!(bus_to_i64(&[true, true, true]), -1);
        assert_eq!(bus_to_i64(&[false, true, false]), 2);
        assert_eq!(bus_to_i64(&[true, false, false]), 1);
        assert_eq!(bus_to_u64(&[true, false, true]), 5);
    }

    #[test]
    fn roundtrip_bits() {
        for v in 0..64u64 {
            assert_eq!(bus_to_u64(&u64_to_bits(v, 6)), v);
        }
    }

    #[test]
    fn toggle_activity_bounds() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let n = nl.not(a);
        nl.output("y", vec![n]);
        // Alternating input -> the NOT gate toggles every step.
        let vectors = vec![vec![false], vec![true], vec![false], vec![true]];
        assert_eq!(toggle_activity(&nl, &vectors), 1.0);
        // Constant input -> no toggles.
        let vectors = vec![vec![true]; 4];
        assert_eq!(toggle_activity(&nl, &vectors), 0.0);
    }

    #[test]
    fn eval_nodes_into_reuses_buffer() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let x = nl.xor(a, b);
        nl.output("y", vec![x]);
        let mut values = Vec::new();
        let mut out = Vec::new();
        for (va, vb) in [(false, true), (true, true), (false, false)] {
            eval_nodes_into(&nl, &[va, vb], &mut values);
            assert_eq!(values.len(), nl.len());
            gather_bus(&values, &nl.outputs[0].1, &mut out);
            assert_eq!(out.as_slice(), &[va ^ vb]);
        }
    }

    #[test]
    fn eval_wrapper_matches_buffer_api() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let c = nl.and(a, b);
        let d = nl.nor(c, a);
        nl.output("y", vec![c, d]);
        let mut values = Vec::new();
        let mut out = Vec::new();
        for bits in [[false, false], [true, false], [true, true]] {
            let via_map = &eval(&nl, &bits)["y"];
            eval_nodes_into(&nl, &bits, &mut values);
            gather_bus(&values, &nl.outputs[0].1, &mut out);
            assert_eq!(via_map, &out);
        }
    }
}
