//! Bit-parallel "wave" simulation engine.
//!
//! Every netlist node holds one *lane block* `[u64; W]`: bit `L % 64` of
//! word `L / 64` is the node's value under input vector `L` of the
//! current batch, so a single forward pass over the (topologically
//! ordered) gate list advances `W * 64` vectors at once. Gate evaluation
//! is plain word arithmetic — `Gate::And` is `a & b`, `Gate::Mux(s, a, b)`
//! is `(s & b) | (!s & a)` — applied element-wise over the block; the
//! block width is a `const` generic, so the per-word loops unroll and
//! auto-vectorize. The production width is [`BLOCK_WORDS`] `= 4`
//! (256 vectors per pass, [`BLOCK_LANES`]); the original single-word
//! engine is exactly the `W = 1` instantiation, and every legacy `u64`
//! entry point below is a thin wrapper over it, so the two widths can
//! never diverge.
//!
//! On top of the core pass:
//! * [`classify_blocks`] / [`classify`] — thread-parallel batched output
//!   extraction for whole datasets (the circuit-in-the-loop GA
//!   evaluator's hot path);
//! * [`toggle_activity`] — popcount toggle counting: consecutive vectors
//!   sit in adjacent lanes, so a cell's toggles within one word are
//!   `popcount((w ^ (w >> 1)) & mask)`, with one bit carried across each
//!   word boundary inside a block and one across each batch boundary.
//!
//! Lanes `>= n_lanes` of a partial batch hold unspecified values (e.g.
//! `Const(true)` fills every lane of the block); every consumer masks to
//! the active lanes, so they never leak into results.

use crate::netlist::{Gate, Netlist, NodeId};
use crate::util::telemetry::{self, Counter, Work};
use crate::util::threads;

/// Lane count of one wave word.
pub const LANES: usize = 64;

/// Words per production lane block (the `--lane-width 256` engine).
pub const BLOCK_WORDS: usize = 4;

/// Lane count of one production lane block.
pub const BLOCK_LANES: usize = BLOCK_WORDS * LANES;

/// Runtime selector between the two compiled lane widths
/// (`pmlp run --lane-width 64|256`). `W256` is the default; `W64` is the
/// escape hatch that runs the exact legacy single-word engine. Both
/// widths are bit-identical in every result — all outputs are
/// per-vector integers — so the flag is a pure throughput knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneWidth {
    /// One `u64` word per node: 64 vectors per pass (`W = 1`).
    W64,
    /// One `[u64; 4]` block per node: 256 vectors per pass (`W = 4`).
    W256,
}

impl Default for LaneWidth {
    fn default() -> LaneWidth {
        LaneWidth::W256
    }
}

impl LaneWidth {
    pub fn parse(s: &str) -> Option<LaneWidth> {
        match s {
            "64" => Some(LaneWidth::W64),
            "256" => Some(LaneWidth::W256),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LaneWidth::W64 => "64",
            LaneWidth::W256 => "256",
        }
    }

    /// Lanes per batch at this width.
    pub fn lanes(&self) -> usize {
        match self {
            LaneWidth::W64 => LANES,
            LaneWidth::W256 => BLOCK_LANES,
        }
    }
}

/// One packed batch of up to `W * 64` input vectors: `blocks[i]` holds
/// primary-input bit `i` across lanes (bit `L % 64` of word `L / 64` =
/// vector `L`).
#[derive(Clone, Debug)]
pub struct BlockWave<const W: usize> {
    pub blocks: Vec<[u64; W]>,
    /// Number of active lanes (`1..=W * 64`).
    pub n_lanes: usize,
}

/// One packed batch of up to [`LANES`] input vectors: `words[i]` holds
/// primary-input bit `i` across lanes (bit `L` = vector `L`). The legacy
/// single-word form of [`BlockWave`]`<1>`.
#[derive(Clone, Debug)]
pub struct InputWave {
    pub words: Vec<u64>,
    /// Number of active lanes (`1..=64`).
    pub n_lanes: usize,
}

impl InputWave {
    /// View this batch as a width-1 block wave (the generic engine's
    /// input type).
    pub fn to_block(&self) -> BlockWave<1> {
        BlockWave {
            blocks: self.words.iter().map(|&w| [w]).collect(),
            n_lanes: self.n_lanes,
        }
    }
}

/// Pack a slice of up to `W * 64` equal-length input vectors into lane
/// blocks.
pub fn pack_wave<V: AsRef<[bool]>, const W: usize>(vectors: &[V]) -> BlockWave<W> {
    assert!(
        !vectors.is_empty() && vectors.len() <= W * LANES,
        "pack_wave takes 1..={} vectors, got {}",
        W * LANES,
        vectors.len()
    );
    let n_bits = vectors[0].as_ref().len();
    let mut blocks = vec![[0u64; W]; n_bits];
    for (lane, v) in vectors.iter().enumerate() {
        let v = v.as_ref();
        assert_eq!(v.len(), n_bits, "ragged input vectors");
        let (word, bit) = (lane / LANES, lane % LANES);
        for (i, &b) in v.iter().enumerate() {
            if b {
                blocks[i][word] |= 1u64 << bit;
            }
        }
    }
    BlockWave { blocks, n_lanes: vectors.len() }
}

/// [`pack_wave`] at the production width (256 lanes per batch).
pub fn pack_block<V: AsRef<[bool]>>(vectors: &[V]) -> BlockWave<BLOCK_WORDS> {
    pack_wave(vectors)
}

/// Pack a slice of up to 64 equal-length input vectors into lane words
/// (thin wrapper over the `W = 1` block packer).
pub fn pack_vectors<V: AsRef<[bool]>>(vectors: &[V]) -> InputWave {
    assert!(
        !vectors.is_empty() && vectors.len() <= LANES,
        "pack_vectors takes 1..=64 vectors, got {}",
        vectors.len()
    );
    let bw: BlockWave<1> = pack_wave(vectors);
    InputWave {
        words: bw.blocks.iter().map(|b| b[0]).collect(),
        n_lanes: bw.n_lanes,
    }
}

/// Encode a feature row into the circuits' primary-input bit order
/// (feature-major, LSB first within each `bits`-wide bus) — the layout
/// every generated MLP netlist uses.
pub fn encode_features(features: &[u32], bits: u32) -> Vec<bool> {
    let mut v = Vec::with_capacity(features.len() * bits as usize);
    for &x in features {
        for b in 0..bits {
            v.push((x >> b) & 1 == 1);
        }
    }
    v
}

/// One wave forward pass: fill `values` with every node's lane block.
/// `inputs[i]` is the lane block of primary input `i`. The buffer is
/// cleared and refilled, so batch loops perform no per-batch allocation.
pub fn eval_blocks_into<const W: usize>(
    nl: &Netlist,
    inputs: &[[u64; W]],
    values: &mut Vec<[u64; W]>,
) {
    values.clear();
    extend_blocks_into(nl, inputs, values);
}

/// Cone-local block re-evaluation: extend a lane-block buffer over a
/// netlist that *grew* since the buffer was filled. Nodes
/// `0..values.len()` keep their cached blocks; only `values.len()..` are
/// evaluated.
///
/// Sound only for append-only netlists under a fixed stimulus — exactly
/// the synthesis arena of `synth::incremental`, where a node's gate and
/// operands never change after creation, so its lane block under the
/// fixed train-set batch is a constant. This is what lets the
/// circuit-in-the-loop evaluator reuse every unchanged node's words
/// across chromosomes and simulate only the re-synthesized cone.
pub fn extend_blocks_into<const W: usize>(
    nl: &Netlist,
    inputs: &[[u64; W]],
    values: &mut Vec<[u64; W]>,
) {
    let done = values.len();
    assert!(done <= nl.gates.len(), "lane-block cache longer than netlist");
    if done == nl.gates.len() {
        return;
    }
    telemetry::work(Work::WaveBlockPasses, 1);
    values.reserve(nl.gates.len() - done);
    for g in &nl.gates[done..] {
        let w: [u64; W] = match *g {
            Gate::Input(idx) => {
                *inputs.get(idx as usize).unwrap_or_else(|| {
                    panic!("input {idx} missing ({} provided)", inputs.len())
                })
            }
            Gate::Const(c) => {
                if c {
                    [!0u64; W]
                } else {
                    [0u64; W]
                }
            }
            Gate::Param(p) => panic!("Param({p}) in simulation — instantiate first"),
            Gate::Not(a) => {
                let x = values[a as usize];
                std::array::from_fn(|k| !x[k])
            }
            Gate::And(a, b) => {
                let (x, y) = (values[a as usize], values[b as usize]);
                std::array::from_fn(|k| x[k] & y[k])
            }
            Gate::Or(a, b) => {
                let (x, y) = (values[a as usize], values[b as usize]);
                std::array::from_fn(|k| x[k] | y[k])
            }
            Gate::Xor(a, b) => {
                let (x, y) = (values[a as usize], values[b as usize]);
                std::array::from_fn(|k| x[k] ^ y[k])
            }
            Gate::Nand(a, b) => {
                let (x, y) = (values[a as usize], values[b as usize]);
                std::array::from_fn(|k| !(x[k] & y[k]))
            }
            Gate::Nor(a, b) => {
                let (x, y) = (values[a as usize], values[b as usize]);
                std::array::from_fn(|k| !(x[k] | y[k]))
            }
            Gate::Xnor(a, b) => {
                let (x, y) = (values[a as usize], values[b as usize]);
                std::array::from_fn(|k| !(x[k] ^ y[k]))
            }
            Gate::Mux(s, a, b) => {
                let (sel, x, y) =
                    (values[s as usize], values[a as usize], values[b as usize]);
                std::array::from_fn(|k| (sel[k] & y[k]) | (!sel[k] & x[k]))
            }
        };
        values.push(w);
    }
}

/// One wave forward pass over a single-word batch — thin wrapper over
/// the `W = 1` block engine.
pub fn eval_wave_into(nl: &Netlist, inputs: &[u64], values: &mut Vec<u64>) {
    values.clear();
    extend_wave_into(nl, inputs, values);
}

/// [`extend_blocks_into`] for the legacy single-word buffers — converts
/// to `W = 1` blocks, extends through the generic engine, and converts
/// back, so the two code paths cannot diverge.
pub fn extend_wave_into(nl: &Netlist, inputs: &[u64], values: &mut Vec<u64>) {
    let block_inputs: Vec<[u64; 1]> = inputs.iter().map(|&w| [w]).collect();
    let mut blocks: Vec<[u64; 1]> = values.iter().map(|&w| [w]).collect();
    extend_blocks_into(nl, &block_inputs, &mut blocks);
    values.clear();
    values.extend(blocks.iter().map(|b| b[0]));
}

/// Allocating convenience wrapper around [`eval_wave_into`].
pub fn eval_wave(nl: &Netlist, batch: &InputWave) -> Vec<u64> {
    let mut values = Vec::new();
    eval_wave_into(nl, &batch.words, &mut values);
    values
}

/// Read one lane of an output bus as an unsigned integer (LSB first).
pub fn lane_bus_block<const W: usize>(
    values: &[[u64; W]],
    bus: &[NodeId],
    lane: usize,
) -> u64 {
    debug_assert!(bus.len() <= 64 && lane < W * LANES);
    let (word, bit) = (lane / LANES, lane % LANES);
    bus.iter()
        .enumerate()
        .map(|(i, &n)| ((values[n as usize][word] >> bit) & 1) << i)
        .sum()
}

/// Read one lane of an output bus from single-word values (LSB first).
pub fn lane_bus_u64(values: &[u64], bus: &[NodeId], lane: usize) -> u64 {
    debug_assert!(bus.len() <= 64 && lane < LANES);
    bus.iter()
        .enumerate()
        .map(|(i, &n)| ((values[n as usize] >> lane) & 1) << i)
        .sum()
}

/// Evaluate the named output bus for every vector of a packed dataset,
/// dispatching batches across `n_threads` workers. Results come back in
/// dataset order, one `u64` bus value per input vector.
pub fn classify_blocks<const W: usize>(
    nl: &Netlist,
    batches: &[BlockWave<W>],
    out_bus: &str,
    n_threads: usize,
) -> Vec<u64> {
    telemetry::count(Counter::WaveClassifyCalls, 1);
    telemetry::count(
        Counter::WaveVectorsClassified,
        batches.iter().map(|b| b.n_lanes as u64).sum(),
    );
    let bus = &nl
        .outputs
        .iter()
        .find(|(name, _)| name == out_bus)
        .unwrap_or_else(|| panic!("no output bus '{out_bus}'"))
        .1;
    let per_batch = threads::par_map(batches.len(), n_threads, |bi| {
        let batch = &batches[bi];
        let mut values = Vec::new();
        eval_blocks_into(nl, &batch.blocks, &mut values);
        (0..batch.n_lanes)
            .map(|lane| lane_bus_block(&values, bus, lane))
            .collect::<Vec<u64>>()
    });
    per_batch.into_iter().flatten().collect()
}

/// [`classify_blocks`] over legacy single-word batches (thin wrapper).
pub fn classify(nl: &Netlist, batches: &[InputWave], out_bus: &str, n_threads: usize) -> Vec<u64> {
    let blocks: Vec<BlockWave<1>> = batches.iter().map(InputWave::to_block).collect();
    classify_blocks(nl, &blocks, out_bus, n_threads)
}

/// Toggle count of one lane block *inside* a batch of `n` active lanes:
/// per word, `popcount((w ^ (w >> 1)) & mask)` over the word's active
/// transitions, plus one carried bit per fully-active word boundary
/// inside the block. Lane `L -> L+1` transitions only exist for
/// `L + 1 < n`, so the tail word's mask shrinks with the residue and
/// garbage lanes never count.
#[inline]
fn block_internal_toggles<const W: usize>(w: &[u64; W], n: usize) -> u64 {
    let mut t = 0u64;
    for k in 0..W {
        let lo = k * LANES;
        if n <= lo {
            break;
        }
        let active = (n - lo).min(LANES);
        if active >= 2 {
            let mask = !0u64 >> (LANES - (active - 1));
            t += ((w[k] ^ (w[k] >> 1)) & mask).count_ones() as u64;
        }
        // The word-boundary transition (lane 64k+63 -> 64k+64) exists
        // when word k is fully active and word k+1 holds active lanes.
        if active == LANES && n > lo + LANES {
            t += ((w[k] >> (LANES - 1)) ^ w[k + 1]) & 1;
        }
    }
    t
}

/// The last *active* lane's bit of a block with `n` active lanes — the
/// value carried into the next batch's lane-0 comparison.
#[inline]
fn block_last_bit<const W: usize>(w: &[u64; W], n: usize) -> u64 {
    debug_assert!(n >= 1 && n <= W * LANES);
    (w[(n - 1) / LANES] >> ((n - 1) % LANES)) & 1
}

/// Persistent lane-block caches over a monotonically growing netlist —
/// the simulation half of incremental re-synthesis.
///
/// One buffer per packed input batch, each aligned with the synthesis
/// arena's node ids. [`BlockCache::classify_bus`] extends every buffer
/// to the arena's current length (evaluating only nodes appended since
/// the last call — see [`extend_blocks_into`]) and then reads the
/// requested output bus per lane. Across a GA run this makes simulation
/// cost scale with the re-synthesized cone, not the netlist: a node's
/// blocks are computed once, ever, per batch.
pub struct BlockCache<const W: usize> {
    batches: Vec<BlockWave<W>>,
    values: Vec<Vec<[u64; W]>>,
    /// Per-node toggle totals over the whole vector sequence, aligned
    /// with netlist/arena node ids like `values`. Each node's count is
    /// computed exactly once, when the node is first extended into the
    /// cache: the block-internal transitions per batch
    /// ([`block_internal_toggles`]) plus one carried transition per
    /// batch boundary — the same integers `toggle_activity` counts, so
    /// summing over a survivor's cells reproduces its activity
    /// bit-exactly.
    toggles: Vec<u64>,
}

impl<const W: usize> BlockCache<W> {
    pub fn new(batches: Vec<BlockWave<W>>) -> BlockCache<W> {
        let values = batches.iter().map(|_| Vec::new()).collect();
        BlockCache { batches, values, toggles: Vec::new() }
    }

    /// Total number of input vectors across all batches.
    pub fn n_vectors(&self) -> usize {
        self.batches.iter().map(|b| b.n_lanes).sum()
    }

    /// Blocks cached per batch (== the arena length last seen).
    pub fn cached_nodes(&self) -> usize {
        self.values.first().map(Vec::len).unwrap_or(0)
    }

    /// Per-node toggle totals over the full batch sequence (indexed by
    /// node id, valid up to [`Self::cached_nodes`]). Sum over a live
    /// cone's cells and divide by `cells * (n_vectors - 1)` to get the
    /// exact [`toggle_activity`] of the corresponding survivor netlist —
    /// the measured dynamic-power path of the circuit-in-the-loop GA.
    pub fn node_toggles(&self) -> &[u64] {
        &self.toggles
    }

    /// Evaluate `bus` for every vector. `nl` must be the same
    /// append-only netlist on every call (longer is fine, shorter or
    /// rewritten is not — node ids are the cache key). Extends the
    /// lane-block and toggle caches to `nl`'s length as a side effect.
    pub fn classify_bus(&mut self, nl: &Netlist, bus: &[NodeId]) -> Vec<u64> {
        telemetry::count(Counter::WaveClassifyCalls, 1);
        telemetry::count(Counter::WaveVectorsClassified, self.n_vectors() as u64);
        self.extend(nl);
        let mut out = Vec::with_capacity(self.n_vectors());
        for (batch, values) in self.batches.iter().zip(&self.values) {
            for lane in 0..batch.n_lanes {
                out.push(lane_bus_block(values, bus, lane));
            }
        }
        out
    }

    /// Extend every per-batch lane-block buffer to `nl`'s current length
    /// (evaluating only appended nodes) and accumulate the new nodes'
    /// toggle counts across the batch sequence.
    fn extend(&mut self, nl: &Netlist) {
        let done = self.toggles.len();
        // How far this cache extends is a function of the worker arena's
        // history (which genomes this worker happened to serve), so these
        // are scheduling-dependent `Work` stats, not `Counter`s.
        let fresh = nl.gates.len().saturating_sub(done);
        if fresh > 0 {
            telemetry::work(Work::WaveCacheExtends, 1);
            telemetry::work(Work::WaveNodesSimulated, fresh as u64);
        } else {
            telemetry::work(Work::WaveCacheHits, 1);
        }
        for (batch, values) in self.batches.iter().zip(&mut self.values) {
            extend_blocks_into(nl, &batch.blocks, values);
        }
        let len = nl.gates.len();
        self.toggles.resize(len, 0);
        for i in done..len {
            let mut t = 0u64;
            let mut prev_last = 0u64;
            let mut first = true;
            for (batch, values) in self.batches.iter().zip(&self.values) {
                let w = &values[i];
                let n = batch.n_lanes;
                t += block_internal_toggles(w, n);
                if !first {
                    t += prev_last ^ (w[0] & 1);
                }
                prev_last = block_last_bit(w, n);
                first = false;
            }
            self.toggles[i] = t;
        }
    }
}

/// Persistent lane-word caches at the legacy 64-lane width — a thin
/// wrapper over [`BlockCache`]`<1>` with the original `InputWave` API.
pub struct WaveCache(BlockCache<1>);

impl WaveCache {
    pub fn new(batches: Vec<InputWave>) -> WaveCache {
        WaveCache(BlockCache::new(batches.iter().map(InputWave::to_block).collect()))
    }

    /// Total number of input vectors across all batches.
    pub fn n_vectors(&self) -> usize {
        self.0.n_vectors()
    }

    /// Words cached per batch (== the arena length last seen).
    pub fn cached_nodes(&self) -> usize {
        self.0.cached_nodes()
    }

    /// See [`BlockCache::node_toggles`].
    pub fn node_toggles(&self) -> &[u64] {
        self.0.node_toggles()
    }

    /// See [`BlockCache::classify_bus`].
    pub fn classify_bus(&mut self, nl: &Netlist, bus: &[NodeId]) -> Vec<u64> {
        self.0.classify_bus(nl, bus)
    }
}

/// Average toggle activity per cell over a vector sequence — bit-exact
/// replacement of the scalar implementation: the toggle and slot counts
/// are identical integers, only computed [`BLOCK_LANES`] lanes at a
/// time.
pub fn toggle_activity(nl: &Netlist, vectors: &[Vec<bool>]) -> f64 {
    let batches: Vec<BlockWave<BLOCK_WORDS>> =
        vectors.chunks(BLOCK_LANES).map(pack_block).collect();
    toggle_activity_blocks(nl, &batches)
}

/// [`toggle_activity`] over already-packed lane blocks (consecutive
/// vectors in adjacent lanes, dataset order across batches) — callers
/// that keep a packed train stimulus (the circuit-in-the-loop evaluator)
/// measure activity without materializing per-vector `Vec<bool>` rows.
/// Same integers, same division: bit-identical to the unpacked entry
/// point at any `W`.
pub fn toggle_activity_blocks<const W: usize>(
    nl: &Netlist,
    batches: &[BlockWave<W>],
) -> f64 {
    telemetry::count(Counter::WaveActivitySims, 1);
    let n_vec: usize = batches.iter().map(|b| b.n_lanes).sum();
    if n_vec < 2 || nl.cell_count() == 0 {
        return 0.0;
    }
    let cells: Vec<usize> = nl
        .gates
        .iter()
        .enumerate()
        .filter(|(_, g)| g.is_cell())
        .map(|(i, _)| i)
        .collect();
    let mut cur: Vec<[u64; W]> = Vec::new();
    let mut prev: Vec<[u64; W]> = Vec::new();
    let mut prev_lanes = 0usize;
    let mut toggles = 0u64;
    for batch in batches {
        eval_blocks_into(nl, &batch.blocks, &mut cur);
        let n = batch.n_lanes;
        for &ci in &cells {
            let w = &cur[ci];
            toggles += block_internal_toggles(w, n);
            if prev_lanes > 0 {
                // Cross-batch transition: last active lane of the
                // previous batch against lane 0 of this one.
                toggles += block_last_bit(&prev[ci], prev_lanes) ^ (w[0] & 1);
            }
        }
        std::mem::swap(&mut cur, &mut prev);
        prev_lanes = n;
    }
    let slots = cells.len() as u64 * (n_vec as u64 - 1);
    toggles as f64 / slots as f64
}

/// [`toggle_activity_blocks`] over legacy single-word batches (thin
/// wrapper).
pub fn toggle_activity_batches(nl: &Netlist, batches: &[InputWave]) -> f64 {
    let blocks: Vec<BlockWave<1>> = batches.iter().map(InputWave::to_block).collect();
    toggle_activity_blocks(nl, &blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::eval_nodes;
    use crate::util::{prop, Rng};

    /// Random topologically-valid netlist mixing every gate kind
    /// (including `Mux` and constants), with a few declared outputs.
    fn random_netlist(rng: &mut Rng) -> Netlist {
        let mut nl = Netlist::new();
        let n_in = 1 + rng.below(5);
        for _ in 0..n_in {
            nl.input();
        }
        if rng.chance(0.5) {
            nl.constant(rng.chance(0.5));
        }
        let n_gates = 5 + rng.below(60);
        for _ in 0..n_gates {
            let len = nl.len();
            let pick = |r: &mut Rng| r.below(len) as NodeId;
            let (a, b) = (pick(rng), pick(rng));
            match rng.below(9) {
                0 => nl.not(a),
                1 => nl.and(a, b),
                2 => nl.or(a, b),
                3 => nl.xor(a, b),
                4 => nl.nand(a, b),
                5 => nl.nor(a, b),
                6 => nl.xnor(a, b),
                7 => nl.constant(rng.chance(0.5)),
                _ => {
                    let s = pick(rng);
                    nl.mux(s, a, b)
                }
            };
        }
        let len = nl.len();
        let bus: Vec<NodeId> =
            (0..1 + rng.below(4)).map(|_| rng.below(len) as NodeId).collect();
        nl.output("y", bus);
        nl
    }

    fn random_vectors(rng: &mut Rng, n_vec: usize, n_bits: usize) -> Vec<Vec<bool>> {
        (0..n_vec)
            .map(|_| (0..n_bits).map(|_| rng.chance(0.5)).collect())
            .collect()
    }

    /// The scalar reference implementation of toggle activity (the
    /// pre-wave engine's definition, kept verbatim as the golden model).
    fn toggle_activity_scalar(nl: &Netlist, vectors: &[Vec<bool>]) -> f64 {
        if vectors.len() < 2 || nl.cell_count() == 0 {
            return 0.0;
        }
        let mut prev = eval_nodes(nl, &vectors[0]);
        let mut toggles = 0u64;
        let mut slots = 0u64;
        for vec in &vectors[1..] {
            let cur = eval_nodes(nl, vec);
            for (i, g) in nl.gates.iter().enumerate() {
                if g.is_cell() {
                    slots += 1;
                    if cur[i] != prev[i] {
                        toggles += 1;
                    }
                }
            }
            prev = cur;
        }
        toggles as f64 / slots as f64
    }

    #[test]
    fn prop_wave_lanes_bit_match_scalar() {
        prop::check("wave lanes == eval_nodes", |rng, _| {
            let nl = random_netlist(rng);
            let n_vec = 1 + rng.below(150);
            let vectors = random_vectors(rng, n_vec, nl.n_inputs as usize);
            for (ci, chunk) in vectors.chunks(LANES).enumerate() {
                let batch = pack_vectors(chunk);
                let values = eval_wave(&nl, &batch);
                for (lane, v) in chunk.iter().enumerate() {
                    let scalar = eval_nodes(&nl, v);
                    for (i, w) in values.iter().enumerate() {
                        let wave_bit = (w >> lane) & 1 == 1;
                        if wave_bit != scalar[i] {
                            return Err(format!(
                                "batch {ci} lane {lane} node {i}: wave {wave_bit} != scalar {}",
                                scalar[i]
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_block_lanes_bit_match_scalar() {
        // The 256-lane engine lane-by-lane against the scalar reference,
        // over vector counts that fill multiple blocks.
        prop::check("block lanes == eval_nodes", |rng, _| {
            let nl = random_netlist(rng);
            let n_vec = 1 + rng.below(600);
            let vectors = random_vectors(rng, n_vec, nl.n_inputs as usize);
            for (ci, chunk) in vectors.chunks(BLOCK_LANES).enumerate() {
                let batch = pack_block(chunk);
                let mut values = Vec::new();
                eval_blocks_into(&nl, &batch.blocks, &mut values);
                for (lane, v) in chunk.iter().enumerate() {
                    let scalar = eval_nodes(&nl, v);
                    let (word, bit) = (lane / LANES, lane % LANES);
                    for (i, w) in values.iter().enumerate() {
                        let wave_bit = (w[word] >> bit) & 1 == 1;
                        if wave_bit != scalar[i] {
                            return Err(format!(
                                "block {ci} lane {lane} node {i}: wave {wave_bit} != scalar {}",
                                scalar[i]
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_toggle_activity_matches_scalar() {
        prop::check("wave toggle == scalar toggle", |rng, _| {
            let nl = random_netlist(rng);
            let n_vec = 2 + rng.below(600);
            let vectors = random_vectors(rng, n_vec, nl.n_inputs as usize);
            let fast = toggle_activity(&nl, &vectors);
            let slow = toggle_activity_scalar(&nl, &vectors);
            if (fast - slow).abs() > 1e-12 {
                return Err(format!("wave {fast} vs scalar {slow} over {n_vec} vectors"));
            }
            // And the legacy 64-lane packing counts the same integers.
            let batches: Vec<InputWave> =
                vectors.chunks(LANES).map(pack_vectors).collect();
            let legacy = toggle_activity_batches(&nl, &batches);
            if legacy != fast {
                return Err(format!("64-lane {legacy} != 256-lane {fast}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_classify_matches_scalar_outputs() {
        prop::check("classify == per-vector bus values", |rng, _| {
            let nl = random_netlist(rng);
            let n_vec = 1 + rng.below(200);
            let vectors = random_vectors(rng, n_vec, nl.n_inputs as usize);
            let batches: Vec<InputWave> =
                vectors.chunks(LANES).map(pack_vectors).collect();
            let got = classify(&nl, &batches, "y", 2);
            if got.len() != n_vec {
                return Err(format!("expected {n_vec} results, got {}", got.len()));
            }
            let block_batches: Vec<BlockWave<BLOCK_WORDS>> =
                vectors.chunks(BLOCK_LANES).map(pack_block).collect();
            let got_blocks = classify_blocks(&nl, &block_batches, "y", 2);
            if got_blocks != got {
                return Err("block classify diverges from 64-lane classify".to_string());
            }
            let bus = &nl.outputs[0].1;
            for (k, v) in vectors.iter().enumerate() {
                let values = eval_nodes(&nl, v);
                let expect: u64 = bus
                    .iter()
                    .enumerate()
                    .map(|(i, &n)| ((values[n as usize] as u64) << i))
                    .sum();
                if got[k] != expect {
                    return Err(format!("vector {k}: {} != {expect}", got[k]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn partial_batches_ignore_garbage_lanes() {
        // A NOT of a constant keeps every inactive lane at 1; toggle and
        // classify results must still only reflect the active lanes.
        let mut nl = Netlist::new();
        let a = nl.input();
        let one = nl.constant(true);
        let na = nl.not(a);
        let y = nl.and(na, one);
        nl.output("y", vec![y]);
        let vectors = vec![vec![false], vec![false], vec![true]]; // 3 lanes of 64
        let batch = pack_vectors(&vectors);
        assert_eq!(batch.n_lanes, 3);
        let got = classify(&nl, &[batch], "y", 1);
        assert_eq!(got, vec![1, 1, 0]);
        // NOT and AND each toggle once (between vectors 2 and 3).
        let act = toggle_activity(&nl, &vectors);
        assert!((act - 0.5).abs() < 1e-12, "activity {act}");
    }

    #[test]
    fn cross_word_boundary_toggles_counted() {
        // 65 alternating vectors around a NOT gate: 64 toggles over 64
        // transitions, one of which crosses the 64-lane word boundary
        // inside a single 256-lane block.
        let mut nl = Netlist::new();
        let a = nl.input();
        let n = nl.not(a);
        nl.output("y", vec![n]);
        let vectors: Vec<Vec<bool>> = (0..65).map(|i| vec![i % 2 == 1]).collect();
        assert_eq!(toggle_activity(&nl, &vectors), 1.0);
        // And a constant sequence crossing the boundary stays at zero.
        let vectors = vec![vec![true]; 130];
        assert_eq!(toggle_activity(&nl, &vectors), 0.0);
    }

    #[test]
    fn cross_block_boundary_toggles_counted() {
        // 257 alternating vectors: 256 toggles over 256 transitions, 3 of
        // which cross word boundaries inside the first block and one of
        // which crosses the 256-lane block boundary.
        let mut nl = Netlist::new();
        let a = nl.input();
        let n = nl.not(a);
        nl.output("y", vec![n]);
        let vectors: Vec<Vec<bool>> = (0..257).map(|i| vec![i % 2 == 1]).collect();
        assert_eq!(toggle_activity(&nl, &vectors), 1.0);
        let vectors = vec![vec![true]; 513];
        assert_eq!(toggle_activity(&nl, &vectors), 0.0);
    }

    #[test]
    fn extend_wave_reuses_cached_words() {
        // Grow a netlist after a first pass: cached words must be kept
        // verbatim and only the appended nodes evaluated.
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let x = nl.xor(a, b);
        let vectors = vec![vec![false, true], vec![true, true], vec![true, false]];
        let batch = pack_vectors(&vectors);
        let mut values = Vec::new();
        extend_wave_into(&nl, &batch.words, &mut values);
        assert_eq!(values.len(), 3);
        let cached = values.clone();
        // Append more logic, then extend.
        let n = nl.not(x);
        let y = nl.and(n, a);
        extend_wave_into(&nl, &batch.words, &mut values);
        assert_eq!(values.len(), 5);
        assert_eq!(&values[..3], cached.as_slice());
        let full = eval_wave(&nl, &batch);
        assert_eq!(values, full);
        let _ = (n, y);
    }

    #[test]
    fn wave_cache_tracks_growing_netlist() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let x = nl.xor(a, b);
        let vectors: Vec<Vec<bool>> =
            (0..70u64).map(|v| crate::sim::u64_to_bits(v % 4, 2)).collect();
        let batches: Vec<InputWave> = vectors.chunks(LANES).map(pack_vectors).collect();
        let mut cache = WaveCache::new(batches.clone());
        assert_eq!(cache.n_vectors(), 70);
        // First query on the small netlist.
        let got = cache.classify_bus(&nl, &[x]);
        let expect: Vec<u64> =
            (0..70u64).map(|v| ((v % 4) ^ ((v % 4) >> 1)) & 1).collect();
        assert_eq!(got, expect);
        assert_eq!(cache.cached_nodes(), nl.len());
        // Grow the netlist (append-only) and query a new bus: the cache
        // extends instead of recomputing, and stays consistent with a
        // cold full evaluation.
        let n = nl.not(x);
        let got2 = cache.classify_bus(&nl, &[n, a]);
        let cold: Vec<u64> = batches
            .iter()
            .flat_map(|bt| {
                let values = eval_wave(&nl, bt);
                (0..bt.n_lanes)
                    .map(|lane| lane_bus_u64(&values, &[n, a], lane))
                    .collect::<Vec<u64>>()
            })
            .collect();
        assert_eq!(got2, cold);
        assert_eq!(cache.cached_nodes(), nl.len());
    }

    /// Netlist whose every gate holds 1 in *all* inactive lanes: a
    /// `Const(true)` feeds ORs, so any garbage-lane leak inflates both
    /// toggle counts and bus reads. Used by the tail-lane regressions.
    fn garbage_prone_netlist() -> Netlist {
        let mut nl = Netlist::new();
        let a = nl.input();
        let one = nl.constant(true);
        let na = nl.not(a); // inactive lanes: !0 = 1
        let o1 = nl.or(na, one); // constant 1 everywhere
        let y0 = nl.xor(na, o1); // = !na in active lanes
        let y1 = nl.and(na, one); // = na
        nl.output("y", vec![y0, y1]);
        nl
    }

    #[test]
    fn tail_lanes_do_not_leak_for_any_residue() {
        // Train-set sizes congruent to 0, 1, 63 (mod 64) — the exact
        // boundary shapes the circuit evaluator's packed batches hit —
        // must classify and toggle-count identically to the scalar
        // engine, even though every inactive lane holds garbage ones.
        let nl = garbage_prone_netlist();
        for n_vec in [1usize, 2, 63, 64, 65, 127, 128, 129, 191] {
            let vectors: Vec<Vec<bool>> =
                (0..n_vec).map(|i| vec![i % 3 == 0]).collect();
            let batches: Vec<InputWave> =
                vectors.chunks(LANES).map(pack_vectors).collect();
            let got = classify(&nl, &batches, "y", 1);
            assert_eq!(got.len(), n_vec, "n_vec={n_vec}");
            for (k, v) in vectors.iter().enumerate() {
                let scalar = eval_nodes(&nl, v);
                let expect: u64 = nl.outputs[0]
                    .1
                    .iter()
                    .enumerate()
                    .map(|(i, &n)| ((scalar[n as usize] as u64) << i))
                    .sum();
                assert_eq!(got[k], expect, "n_vec={n_vec} vector {k}");
            }
            if n_vec >= 2 {
                let fast = toggle_activity(&nl, &vectors);
                let slow = toggle_activity_scalar(&nl, &vectors);
                assert!(
                    (fast - slow).abs() < 1e-12,
                    "n_vec={n_vec}: wave {fast} != scalar {slow}"
                );
            }
        }
    }

    #[test]
    fn block_tail_lanes_do_not_leak_for_any_residue() {
        // The 256-lane analogue: sizes congruent to 0, 1, 63, 64, 65 and
        // 255 (mod 256) — every word boundary inside a block plus the
        // block boundary itself, with constant-poisoned garbage lanes.
        let nl = garbage_prone_netlist();
        for n_vec in [
            1usize, 2, 63, 64, 65, 255, 256, 257, 319, 320, 321, 511, 512, 513, 767,
        ] {
            let vectors: Vec<Vec<bool>> =
                (0..n_vec).map(|i| vec![i % 3 == 0]).collect();
            let batches: Vec<BlockWave<BLOCK_WORDS>> =
                vectors.chunks(BLOCK_LANES).map(pack_block).collect();
            let got = classify_blocks(&nl, &batches, "y", 1);
            assert_eq!(got.len(), n_vec, "n_vec={n_vec}");
            for (k, v) in vectors.iter().enumerate() {
                let scalar = eval_nodes(&nl, v);
                let expect: u64 = nl.outputs[0]
                    .1
                    .iter()
                    .enumerate()
                    .map(|(i, &n)| ((scalar[n as usize] as u64) << i))
                    .sum();
                assert_eq!(got[k], expect, "n_vec={n_vec} vector {k}");
            }
            if n_vec >= 2 {
                let fast = toggle_activity_blocks(&nl, &batches);
                let slow = toggle_activity_scalar(&nl, &vectors);
                assert!(
                    (fast - slow).abs() < 1e-12,
                    "n_vec={n_vec}: block {fast} != scalar {slow}"
                );
            }
        }
    }

    #[test]
    fn wave_cache_tail_lanes_clean_across_extension() {
        // WaveCache over a 65-vector stimulus (64 + 1-lane tail batch):
        // growing the arena and re-querying must keep tail lanes out of
        // the results, with garbage-prone constants in the appended cone.
        let mut nl = Netlist::new();
        let a = nl.input();
        let x = nl.not(a);
        let vectors: Vec<Vec<bool>> = (0..65).map(|i| vec![i % 2 == 1]).collect();
        let batches: Vec<InputWave> = vectors.chunks(LANES).map(pack_vectors).collect();
        assert_eq!(batches.last().unwrap().n_lanes, 1);
        let mut cache = WaveCache::new(batches);
        let got = cache.classify_bus(&nl, &[x]);
        let expect: Vec<u64> = (0..65u64).map(|i| (i + 1) % 2).collect();
        assert_eq!(got, expect);
        // Append garbage-prone logic and re-query through the cache.
        let one = nl.constant(true);
        let y = nl.and(x, one);
        let got2 = cache.classify_bus(&nl, &[y]);
        assert_eq!(got2, expect);
    }

    #[test]
    fn block_cache_tail_lanes_clean_across_extension() {
        // The 256-lane analogue: a 257-vector stimulus (one full block +
        // a 1-lane tail block), extended twice with constant-poisoned
        // logic; classification and per-node toggles must stay scalar-
        // exact at every step.
        let mut nl = Netlist::new();
        let a = nl.input();
        let x = nl.not(a);
        let vectors: Vec<Vec<bool>> = (0..257).map(|i| vec![i % 2 == 1]).collect();
        let batches: Vec<BlockWave<BLOCK_WORDS>> =
            vectors.chunks(BLOCK_LANES).map(pack_block).collect();
        assert_eq!(batches.last().unwrap().n_lanes, 1);
        let mut cache = BlockCache::new(batches);
        assert_eq!(cache.n_vectors(), 257);
        let got = cache.classify_bus(&nl, &[x]);
        let expect: Vec<u64> = (0..257u64).map(|i| (i + 1) % 2).collect();
        assert_eq!(got, expect);
        // Append garbage-prone logic and re-query through the cache.
        let one = nl.constant(true);
        let y = nl.and(x, one);
        let got2 = cache.classify_bus(&nl, &[y]);
        assert_eq!(got2, expect);
        assert_eq!(cache.cached_nodes(), nl.len());
        // The NOT toggles on every one of the 256 transitions; the
        // garbage-prone AND mirrors it exactly.
        let want = node_toggles_scalar(&nl, &vectors);
        assert_eq!(cache.node_toggles(), want.as_slice());
    }

    /// Scalar golden model of per-node toggle counts: evaluate every
    /// vector and count value flips node by node.
    fn node_toggles_scalar(nl: &Netlist, vectors: &[Vec<bool>]) -> Vec<u64> {
        let mut out = vec![0u64; nl.len()];
        if vectors.len() < 2 {
            return out;
        }
        let mut prev = eval_nodes(nl, &vectors[0]);
        for v in &vectors[1..] {
            let cur = eval_nodes(nl, v);
            for (i, t) in out.iter_mut().enumerate() {
                *t += (cur[i] != prev[i]) as u64;
            }
            prev = cur;
        }
        out
    }

    #[test]
    fn prop_wave_cache_node_toggles_match_scalar() {
        // The measured-power substrate: per-node toggle totals the cache
        // accumulates at extension time must equal the scalar per-node
        // flip counts — for every node, any batch-boundary residue, and
        // across append-only netlist growth. Checked at both widths.
        prop::check("wave-cache node toggles == scalar", |rng, _| {
            let mut nl = random_netlist(rng);
            let n_vec = 2 + rng.below(600);
            let vectors = random_vectors(rng, n_vec, nl.n_inputs as usize);
            let batches: Vec<InputWave> =
                vectors.chunks(LANES).map(pack_vectors).collect();
            let mut cache = WaveCache::new(batches);
            let block_batches: Vec<BlockWave<BLOCK_WORDS>> =
                vectors.chunks(BLOCK_LANES).map(pack_block).collect();
            let mut block_cache = BlockCache::new(block_batches);
            let first_len = nl.len();
            let out0 = nl.outputs[0].1.clone();
            cache.classify_bus(&nl, &out0);
            block_cache.classify_bus(&nl, &out0);
            // Grow the netlist (append-only) and re-query: the appended
            // nodes' toggles are computed on extension, the old ones kept.
            let len = nl.len();
            let a = rng.below(len) as NodeId;
            let b = rng.below(len) as NodeId;
            let x = nl.xor(a, b);
            let y = nl.not(x);
            cache.classify_bus(&nl, &[y]);
            block_cache.classify_bus(&nl, &[y]);
            let got = cache.node_toggles();
            let want = node_toggles_scalar(&nl, &vectors);
            if got.len() != nl.len() {
                return Err(format!("toggle table len {} != {}", got.len(), nl.len()));
            }
            for i in 0..nl.len() {
                if got[i] != want[i] {
                    return Err(format!(
                        "node {i}: cache {} != scalar {} over {n_vec} vectors \
                         (first extension at len {first_len})",
                        got[i], want[i]
                    ));
                }
            }
            if block_cache.node_toggles() != want.as_slice() {
                return Err(format!(
                    "256-lane cache toggles diverge from scalar over {n_vec} vectors"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn wave_cache_activity_matches_toggle_activity_exactly() {
        // Summing cached per-cell toggles and dividing by
        // cells * (n_vec - 1) must be bit-identical (f64 ==) to
        // `toggle_activity` — the equality the measured power objective
        // rests on. Garbage-prone netlist + tail batches, both widths.
        let nl = garbage_prone_netlist();
        for n_vec in [2usize, 63, 64, 65, 129, 255, 256, 257, 513] {
            let vectors: Vec<Vec<bool>> =
                (0..n_vec).map(|i| vec![i % 3 == 0]).collect();
            let cells: Vec<usize> = nl
                .gates
                .iter()
                .enumerate()
                .filter(|(_, g)| g.is_cell())
                .map(|(i, _)| i)
                .collect();
            let slots = cells.len() as u64 * (n_vec as u64 - 1);
            let batches: Vec<InputWave> =
                vectors.chunks(LANES).map(pack_vectors).collect();
            let mut cache = WaveCache::new(batches);
            cache.classify_bus(&nl, &nl.outputs[0].1.clone());
            let total: u64 = cells.iter().map(|&i| cache.node_toggles()[i]).sum();
            let from_cache = total as f64 / slots as f64;
            assert_eq!(
                from_cache,
                toggle_activity(&nl, &vectors),
                "n_vec={n_vec}"
            );
            let block_batches: Vec<BlockWave<BLOCK_WORDS>> =
                vectors.chunks(BLOCK_LANES).map(pack_block).collect();
            let mut block_cache = BlockCache::new(block_batches);
            block_cache.classify_bus(&nl, &nl.outputs[0].1.clone());
            let total: u64 = cells.iter().map(|&i| block_cache.node_toggles()[i]).sum();
            assert_eq!(
                total as f64 / slots as f64,
                toggle_activity(&nl, &vectors),
                "n_vec={n_vec} (256-lane)"
            );
        }
    }

    #[test]
    fn encode_features_layout() {
        // Feature-major, LSB first: [x0 b0..b3, x1 b0..b3, ...]
        let bits = encode_features(&[0b1010, 0b0001], 4);
        assert_eq!(
            bits,
            vec![false, true, false, true, true, false, false, false]
        );
    }

    #[test]
    fn lane_extraction_round_trips() {
        let mut nl = Netlist::new();
        let bus_in = nl.input_bus(6);
        nl.output("v", bus_in.clone());
        let vectors: Vec<Vec<bool>> =
            (0..40u64).map(|v| crate::sim::u64_to_bits(v, 6)).collect();
        let batch = pack_vectors(&vectors);
        let values = eval_wave(&nl, &batch);
        for (lane, _) in vectors.iter().enumerate() {
            assert_eq!(lane_bus_u64(&values, &nl.outputs[0].1, lane), lane as u64);
        }
    }

    #[test]
    fn block_lane_extraction_round_trips() {
        // 300 vectors span two blocks; every lane of both blocks must
        // read back its own index through `lane_bus_block`.
        let mut nl = Netlist::new();
        let bus_in = nl.input_bus(9);
        nl.output("v", bus_in.clone());
        let vectors: Vec<Vec<bool>> =
            (0..300u64).map(|v| crate::sim::u64_to_bits(v, 9)).collect();
        let mut k = 0usize;
        for chunk in vectors.chunks(BLOCK_LANES) {
            let batch = pack_block(chunk);
            let mut values = Vec::new();
            eval_blocks_into(&nl, &batch.blocks, &mut values);
            for lane in 0..batch.n_lanes {
                assert_eq!(
                    lane_bus_block(&values, &nl.outputs[0].1, lane),
                    k as u64
                );
                k += 1;
            }
        }
        assert_eq!(k, 300);
    }

    #[test]
    fn lane_width_parses_and_describes() {
        assert_eq!(LaneWidth::parse("64"), Some(LaneWidth::W64));
        assert_eq!(LaneWidth::parse("256"), Some(LaneWidth::W256));
        assert_eq!(LaneWidth::parse("128"), None);
        assert_eq!(LaneWidth::default(), LaneWidth::W256);
        assert_eq!(LaneWidth::W64.lanes(), 64);
        assert_eq!(LaneWidth::W256.lanes(), 256);
        assert_eq!(LaneWidth::W256.label(), "256");
    }
}
