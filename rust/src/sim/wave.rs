//! Bit-parallel "wave" simulation engine.
//!
//! Every netlist node holds one `u64` *lane word*: bit `L` of the word is
//! the node's value under input vector `L` of the current batch, so a
//! single forward pass over the (topologically ordered) gate list
//! advances 64 vectors at once. Gate evaluation is plain word arithmetic
//! — `Gate::And` is `a & b`, `Gate::Mux(s, a, b)` is
//! `(s & b) | (!s & a)` — which makes the pass memory-bound rather than
//! branch-bound and is where the ≥20× speedup over the scalar engine
//! comes from (`benches/perf_synth.rs` tracks it).
//!
//! On top of the core pass:
//! * [`classify`] — thread-parallel batched output extraction for whole
//!   datasets (the circuit-in-the-loop GA evaluator's hot path);
//! * [`toggle_activity`] — popcount toggle counting: consecutive vectors
//!   sit in adjacent lanes, so a cell's toggles within a batch are
//!   `popcount((w ^ (w >> 1)) & mask)`, with one cross-word bit carried
//!   between batches.
//!
//! Lanes `>= n_lanes` of a partial batch hold unspecified values (e.g.
//! `Const(true)` fills all 64 lanes); every consumer masks to the active
//! lanes, so they never leak into results.

use crate::netlist::{Gate, Netlist, NodeId};
use crate::util::telemetry::{self, Counter, Work};
use crate::util::threads;

/// Lane count of one wave word.
pub const LANES: usize = 64;

/// One packed batch of up to [`LANES`] input vectors: `words[i]` holds
/// primary-input bit `i` across lanes (bit `L` = vector `L`).
#[derive(Clone, Debug)]
pub struct InputWave {
    pub words: Vec<u64>,
    /// Number of active lanes (`1..=64`).
    pub n_lanes: usize,
}

/// Pack a slice of up to 64 equal-length input vectors into lane words.
pub fn pack_vectors<V: AsRef<[bool]>>(vectors: &[V]) -> InputWave {
    assert!(
        !vectors.is_empty() && vectors.len() <= LANES,
        "pack_vectors takes 1..=64 vectors, got {}",
        vectors.len()
    );
    let n_bits = vectors[0].as_ref().len();
    let mut words = vec![0u64; n_bits];
    for (lane, v) in vectors.iter().enumerate() {
        let v = v.as_ref();
        assert_eq!(v.len(), n_bits, "ragged input vectors");
        for (i, &b) in v.iter().enumerate() {
            if b {
                words[i] |= 1u64 << lane;
            }
        }
    }
    InputWave { words, n_lanes: vectors.len() }
}

/// Encode a feature row into the circuits' primary-input bit order
/// (feature-major, LSB first within each `bits`-wide bus) — the layout
/// every generated MLP netlist uses.
pub fn encode_features(features: &[u32], bits: u32) -> Vec<bool> {
    let mut v = Vec::with_capacity(features.len() * bits as usize);
    for &x in features {
        for b in 0..bits {
            v.push((x >> b) & 1 == 1);
        }
    }
    v
}

/// One wave forward pass: fill `values` with every node's lane word.
/// `inputs[i]` is the lane word of primary input `i`. The buffer is
/// cleared and refilled, so batch loops perform no per-batch allocation.
pub fn eval_wave_into(nl: &Netlist, inputs: &[u64], values: &mut Vec<u64>) {
    values.clear();
    extend_wave_into(nl, inputs, values);
}

/// Cone-local word re-evaluation: extend a lane-word buffer over a
/// netlist that *grew* since the buffer was filled. Nodes
/// `0..values.len()` keep their cached words; only `values.len()..` are
/// evaluated.
///
/// Sound only for append-only netlists under a fixed stimulus — exactly
/// the synthesis arena of `synth::incremental`, where a node's gate and
/// operands never change after creation, so its lane word under the
/// fixed train-set batch is a constant. This is what lets the
/// circuit-in-the-loop evaluator reuse every unchanged node's words
/// across chromosomes and simulate only the re-synthesized cone.
pub fn extend_wave_into(nl: &Netlist, inputs: &[u64], values: &mut Vec<u64>) {
    let done = values.len();
    assert!(done <= nl.gates.len(), "lane-word cache longer than netlist");
    values.reserve(nl.gates.len() - done);
    for g in &nl.gates[done..] {
        let w = match *g {
            Gate::Input(idx) => {
                *inputs.get(idx as usize).unwrap_or_else(|| {
                    panic!("input {idx} missing ({} provided)", inputs.len())
                })
            }
            Gate::Const(c) => {
                if c {
                    !0u64
                } else {
                    0
                }
            }
            Gate::Param(p) => panic!("Param({p}) in simulation — instantiate first"),
            Gate::Not(a) => !values[a as usize],
            Gate::And(a, b) => values[a as usize] & values[b as usize],
            Gate::Or(a, b) => values[a as usize] | values[b as usize],
            Gate::Xor(a, b) => values[a as usize] ^ values[b as usize],
            Gate::Nand(a, b) => !(values[a as usize] & values[b as usize]),
            Gate::Nor(a, b) => !(values[a as usize] | values[b as usize]),
            Gate::Xnor(a, b) => !(values[a as usize] ^ values[b as usize]),
            Gate::Mux(s, a, b) => {
                let sel = values[s as usize];
                (sel & values[b as usize]) | (!sel & values[a as usize])
            }
        };
        values.push(w);
    }
}

/// Allocating convenience wrapper around [`eval_wave_into`].
pub fn eval_wave(nl: &Netlist, batch: &InputWave) -> Vec<u64> {
    let mut values = Vec::new();
    eval_wave_into(nl, &batch.words, &mut values);
    values
}

/// Read one lane of an output bus as an unsigned integer (LSB first).
pub fn lane_bus_u64(values: &[u64], bus: &[NodeId], lane: usize) -> u64 {
    debug_assert!(bus.len() <= 64 && lane < LANES);
    bus.iter()
        .enumerate()
        .map(|(i, &n)| ((values[n as usize] >> lane) & 1) << i)
        .sum()
}

/// Evaluate the named output bus for every vector of a packed dataset,
/// dispatching batches across `n_threads` workers. Results come back in
/// dataset order, one `u64` bus value per input vector.
pub fn classify(nl: &Netlist, batches: &[InputWave], out_bus: &str, n_threads: usize) -> Vec<u64> {
    telemetry::count(Counter::WaveClassifyCalls, 1);
    telemetry::count(
        Counter::WaveVectorsClassified,
        batches.iter().map(|b| b.n_lanes as u64).sum(),
    );
    let bus = &nl
        .outputs
        .iter()
        .find(|(name, _)| name == out_bus)
        .unwrap_or_else(|| panic!("no output bus '{out_bus}'"))
        .1;
    let per_batch = threads::par_map(batches.len(), n_threads, |bi| {
        let batch = &batches[bi];
        let mut values = Vec::new();
        eval_wave_into(nl, &batch.words, &mut values);
        (0..batch.n_lanes)
            .map(|lane| lane_bus_u64(&values, bus, lane))
            .collect::<Vec<u64>>()
    });
    per_batch.into_iter().flatten().collect()
}

/// Persistent lane-word caches over a monotonically growing netlist —
/// the simulation half of incremental re-synthesis.
///
/// One buffer per packed input batch, each aligned with the synthesis
/// arena's node ids. [`WaveCache::classify_bus`] extends every buffer to
/// the arena's current length (evaluating only nodes appended since the
/// last call — see [`extend_wave_into`]) and then reads the requested
/// output bus per lane. Across a GA run this makes simulation cost scale
/// with the re-synthesized cone, not the netlist: a node's words are
/// computed once, ever, per batch.
pub struct WaveCache {
    batches: Vec<InputWave>,
    values: Vec<Vec<u64>>,
    /// Per-node toggle totals over the whole vector sequence, aligned
    /// with netlist/arena node ids like `values`. Each node's count is
    /// computed exactly once, when the node is first extended into the
    /// cache: `n_lanes - 1` internal transitions per batch (popcount of
    /// `(w ^ (w >> 1)) & mask`) plus one carried transition per batch
    /// boundary — the same integers `toggle_activity` counts, so summing
    /// over a survivor's cells reproduces its activity bit-exactly.
    toggles: Vec<u64>,
}

impl WaveCache {
    pub fn new(batches: Vec<InputWave>) -> WaveCache {
        let values = batches.iter().map(|_| Vec::new()).collect();
        WaveCache { batches, values, toggles: Vec::new() }
    }

    /// Total number of input vectors across all batches.
    pub fn n_vectors(&self) -> usize {
        self.batches.iter().map(|b| b.n_lanes).sum()
    }

    /// Words cached per batch (== the arena length last seen).
    pub fn cached_nodes(&self) -> usize {
        self.values.first().map(Vec::len).unwrap_or(0)
    }

    /// Per-node toggle totals over the full batch sequence (indexed by
    /// node id, valid up to [`Self::cached_nodes`]). Sum over a live
    /// cone's cells and divide by `cells * (n_vectors - 1)` to get the
    /// exact [`toggle_activity`] of the corresponding survivor netlist —
    /// the measured dynamic-power path of the circuit-in-the-loop GA.
    pub fn node_toggles(&self) -> &[u64] {
        &self.toggles
    }

    /// Evaluate `bus` for every vector. `nl` must be the same
    /// append-only netlist on every call (longer is fine, shorter or
    /// rewritten is not — node ids are the cache key). Extends the
    /// lane-word and toggle caches to `nl`'s length as a side effect.
    pub fn classify_bus(&mut self, nl: &Netlist, bus: &[NodeId]) -> Vec<u64> {
        telemetry::count(Counter::WaveClassifyCalls, 1);
        telemetry::count(Counter::WaveVectorsClassified, self.n_vectors() as u64);
        self.extend(nl);
        let mut out = Vec::with_capacity(self.n_vectors());
        for (batch, values) in self.batches.iter().zip(&self.values) {
            for lane in 0..batch.n_lanes {
                out.push(lane_bus_u64(values, bus, lane));
            }
        }
        out
    }

    /// Extend every per-batch lane-word buffer to `nl`'s current length
    /// (evaluating only appended nodes) and accumulate the new nodes'
    /// toggle counts across the batch sequence.
    fn extend(&mut self, nl: &Netlist) {
        let done = self.toggles.len();
        // How far this cache extends is a function of the worker arena's
        // history (which genomes this worker happened to serve), so these
        // are scheduling-dependent `Work` stats, not `Counter`s.
        let fresh = nl.gates.len().saturating_sub(done);
        if fresh > 0 {
            telemetry::work(Work::WaveCacheExtends, 1);
            telemetry::work(Work::WaveNodesSimulated, fresh as u64);
        } else {
            telemetry::work(Work::WaveCacheHits, 1);
        }
        for (batch, values) in self.batches.iter().zip(&mut self.values) {
            extend_wave_into(nl, &batch.words, values);
        }
        let len = nl.gates.len();
        self.toggles.resize(len, 0);
        for i in done..len {
            let mut t = 0u64;
            let mut prev_last = 0u64;
            let mut first = true;
            for (batch, values) in self.batches.iter().zip(&self.values) {
                let w = values[i];
                let n = batch.n_lanes;
                // Transition lane L -> L+1 sits at bit L of w ^ (w >> 1);
                // n lanes have n-1 internal transitions (cf.
                // `toggle_activity`, kept in lockstep).
                let mask = if n >= 2 { !0u64 >> (64 - (n - 1)) } else { 0 };
                t += ((w ^ (w >> 1)) & mask).count_ones() as u64;
                if !first {
                    t += (prev_last ^ w) & 1;
                }
                prev_last = w >> (n - 1);
                first = false;
            }
            self.toggles[i] = t;
        }
    }
}

/// Average toggle activity per cell over a vector sequence — bit-exact
/// replacement of the scalar implementation: the toggle and slot counts
/// are identical integers, only computed 64 lanes at a time.
pub fn toggle_activity(nl: &Netlist, vectors: &[Vec<bool>]) -> f64 {
    let batches: Vec<InputWave> = vectors.chunks(LANES).map(pack_vectors).collect();
    toggle_activity_batches(nl, &batches)
}

/// [`toggle_activity`] over already-packed batches (consecutive vectors
/// in adjacent lanes, dataset order across batches) — callers that keep
/// a packed train stimulus (the circuit-in-the-loop evaluator) measure
/// activity without materializing per-vector `Vec<bool>` rows. Same
/// integers, same division: bit-identical to the unpacked entry point.
pub fn toggle_activity_batches(nl: &Netlist, batches: &[InputWave]) -> f64 {
    telemetry::count(Counter::WaveActivitySims, 1);
    let n_vec: usize = batches.iter().map(|b| b.n_lanes).sum();
    if n_vec < 2 || nl.cell_count() == 0 {
        return 0.0;
    }
    let cells: Vec<usize> = nl
        .gates
        .iter()
        .enumerate()
        .filter(|(_, g)| g.is_cell())
        .map(|(i, _)| i)
        .collect();
    let mut cur: Vec<u64> = Vec::new();
    let mut prev: Vec<u64> = Vec::new();
    let mut prev_lanes = 0usize;
    let mut toggles = 0u64;
    for batch in batches {
        eval_wave_into(nl, &batch.words, &mut cur);
        let n = batch.n_lanes;
        // Transition lane L -> L+1 appears at bit L of (w ^ (w >> 1));
        // a batch of n lanes has n-1 internal transitions.
        let mask = if n >= 2 { !0u64 >> (64 - (n - 1)) } else { 0 };
        for &ci in &cells {
            let w = cur[ci];
            toggles += ((w ^ (w >> 1)) & mask).count_ones() as u64;
            if prev_lanes > 0 {
                // Cross-batch transition: last lane of the previous batch
                // against lane 0 of this one.
                toggles += ((prev[ci] >> (prev_lanes - 1)) ^ w) & 1;
            }
        }
        std::mem::swap(&mut cur, &mut prev);
        prev_lanes = n;
    }
    let slots = cells.len() as u64 * (n_vec as u64 - 1);
    toggles as f64 / slots as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::eval_nodes;
    use crate::util::{prop, Rng};

    /// Random topologically-valid netlist mixing every gate kind
    /// (including `Mux` and constants), with a few declared outputs.
    fn random_netlist(rng: &mut Rng) -> Netlist {
        let mut nl = Netlist::new();
        let n_in = 1 + rng.below(5);
        for _ in 0..n_in {
            nl.input();
        }
        if rng.chance(0.5) {
            nl.constant(rng.chance(0.5));
        }
        let n_gates = 5 + rng.below(60);
        for _ in 0..n_gates {
            let len = nl.len();
            let pick = |r: &mut Rng| r.below(len) as NodeId;
            let (a, b) = (pick(rng), pick(rng));
            match rng.below(9) {
                0 => nl.not(a),
                1 => nl.and(a, b),
                2 => nl.or(a, b),
                3 => nl.xor(a, b),
                4 => nl.nand(a, b),
                5 => nl.nor(a, b),
                6 => nl.xnor(a, b),
                7 => nl.constant(rng.chance(0.5)),
                _ => {
                    let s = pick(rng);
                    nl.mux(s, a, b)
                }
            };
        }
        let len = nl.len();
        let bus: Vec<NodeId> =
            (0..1 + rng.below(4)).map(|_| rng.below(len) as NodeId).collect();
        nl.output("y", bus);
        nl
    }

    fn random_vectors(rng: &mut Rng, n_vec: usize, n_bits: usize) -> Vec<Vec<bool>> {
        (0..n_vec)
            .map(|_| (0..n_bits).map(|_| rng.chance(0.5)).collect())
            .collect()
    }

    /// The scalar reference implementation of toggle activity (the
    /// pre-wave engine's definition, kept verbatim as the golden model).
    fn toggle_activity_scalar(nl: &Netlist, vectors: &[Vec<bool>]) -> f64 {
        if vectors.len() < 2 || nl.cell_count() == 0 {
            return 0.0;
        }
        let mut prev = eval_nodes(nl, &vectors[0]);
        let mut toggles = 0u64;
        let mut slots = 0u64;
        for vec in &vectors[1..] {
            let cur = eval_nodes(nl, vec);
            for (i, g) in nl.gates.iter().enumerate() {
                if g.is_cell() {
                    slots += 1;
                    if cur[i] != prev[i] {
                        toggles += 1;
                    }
                }
            }
            prev = cur;
        }
        toggles as f64 / slots as f64
    }

    #[test]
    fn prop_wave_lanes_bit_match_scalar() {
        prop::check("wave lanes == eval_nodes", |rng, _| {
            let nl = random_netlist(rng);
            let n_vec = 1 + rng.below(150);
            let vectors = random_vectors(rng, n_vec, nl.n_inputs as usize);
            for (ci, chunk) in vectors.chunks(LANES).enumerate() {
                let batch = pack_vectors(chunk);
                let values = eval_wave(&nl, &batch);
                for (lane, v) in chunk.iter().enumerate() {
                    let scalar = eval_nodes(&nl, v);
                    for (i, w) in values.iter().enumerate() {
                        let wave_bit = (w >> lane) & 1 == 1;
                        if wave_bit != scalar[i] {
                            return Err(format!(
                                "batch {ci} lane {lane} node {i}: wave {wave_bit} != scalar {}",
                                scalar[i]
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_toggle_activity_matches_scalar() {
        prop::check("wave toggle == scalar toggle", |rng, _| {
            let nl = random_netlist(rng);
            let n_vec = 2 + rng.below(200);
            let vectors = random_vectors(rng, n_vec, nl.n_inputs as usize);
            let fast = toggle_activity(&nl, &vectors);
            let slow = toggle_activity_scalar(&nl, &vectors);
            if (fast - slow).abs() > 1e-12 {
                return Err(format!("wave {fast} vs scalar {slow} over {n_vec} vectors"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_classify_matches_scalar_outputs() {
        prop::check("classify == per-vector bus values", |rng, _| {
            let nl = random_netlist(rng);
            let n_vec = 1 + rng.below(200);
            let vectors = random_vectors(rng, n_vec, nl.n_inputs as usize);
            let batches: Vec<InputWave> =
                vectors.chunks(LANES).map(pack_vectors).collect();
            let got = classify(&nl, &batches, "y", 2);
            if got.len() != n_vec {
                return Err(format!("expected {n_vec} results, got {}", got.len()));
            }
            let bus = &nl.outputs[0].1;
            for (k, v) in vectors.iter().enumerate() {
                let values = eval_nodes(&nl, v);
                let expect: u64 = bus
                    .iter()
                    .enumerate()
                    .map(|(i, &n)| ((values[n as usize] as u64) << i))
                    .sum();
                if got[k] != expect {
                    return Err(format!("vector {k}: {} != {expect}", got[k]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn partial_batches_ignore_garbage_lanes() {
        // A NOT of a constant keeps every inactive lane at 1; toggle and
        // classify results must still only reflect the active lanes.
        let mut nl = Netlist::new();
        let a = nl.input();
        let one = nl.constant(true);
        let na = nl.not(a);
        let y = nl.and(na, one);
        nl.output("y", vec![y]);
        let vectors = vec![vec![false], vec![false], vec![true]]; // 3 lanes of 64
        let batch = pack_vectors(&vectors);
        assert_eq!(batch.n_lanes, 3);
        let got = classify(&nl, &[batch], "y", 1);
        assert_eq!(got, vec![1, 1, 0]);
        // NOT and AND each toggle once (between vectors 2 and 3).
        let act = toggle_activity(&nl, &vectors);
        assert!((act - 0.5).abs() < 1e-12, "activity {act}");
    }

    #[test]
    fn cross_word_boundary_toggles_counted() {
        // 65 alternating vectors around a NOT gate: 64 toggles over 64
        // transitions, one of which crosses the 64-lane word boundary.
        let mut nl = Netlist::new();
        let a = nl.input();
        let n = nl.not(a);
        nl.output("y", vec![n]);
        let vectors: Vec<Vec<bool>> = (0..65).map(|i| vec![i % 2 == 1]).collect();
        assert_eq!(toggle_activity(&nl, &vectors), 1.0);
        // And a constant sequence crossing the boundary stays at zero.
        let vectors = vec![vec![true]; 130];
        assert_eq!(toggle_activity(&nl, &vectors), 0.0);
    }

    #[test]
    fn extend_wave_reuses_cached_words() {
        // Grow a netlist after a first pass: cached words must be kept
        // verbatim and only the appended nodes evaluated.
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let x = nl.xor(a, b);
        let vectors = vec![vec![false, true], vec![true, true], vec![true, false]];
        let batch = pack_vectors(&vectors);
        let mut values = Vec::new();
        extend_wave_into(&nl, &batch.words, &mut values);
        assert_eq!(values.len(), 3);
        let cached = values.clone();
        // Append more logic, then extend.
        let n = nl.not(x);
        let y = nl.and(n, a);
        extend_wave_into(&nl, &batch.words, &mut values);
        assert_eq!(values.len(), 5);
        assert_eq!(&values[..3], cached.as_slice());
        let full = eval_wave(&nl, &batch);
        assert_eq!(values, full);
        let _ = (n, y);
    }

    #[test]
    fn wave_cache_tracks_growing_netlist() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let x = nl.xor(a, b);
        let vectors: Vec<Vec<bool>> =
            (0..70u64).map(|v| crate::sim::u64_to_bits(v % 4, 2)).collect();
        let batches: Vec<InputWave> = vectors.chunks(LANES).map(pack_vectors).collect();
        let mut cache = WaveCache::new(batches.clone());
        assert_eq!(cache.n_vectors(), 70);
        // First query on the small netlist.
        let got = cache.classify_bus(&nl, &[x]);
        let expect: Vec<u64> =
            (0..70u64).map(|v| ((v % 4) ^ ((v % 4) >> 1)) & 1).collect();
        assert_eq!(got, expect);
        assert_eq!(cache.cached_nodes(), nl.len());
        // Grow the netlist (append-only) and query a new bus: the cache
        // extends instead of recomputing, and stays consistent with a
        // cold full evaluation.
        let n = nl.not(x);
        let got2 = cache.classify_bus(&nl, &[n, a]);
        let cold: Vec<u64> = batches
            .iter()
            .flat_map(|bt| {
                let values = eval_wave(&nl, bt);
                (0..bt.n_lanes)
                    .map(|lane| lane_bus_u64(&values, &[n, a], lane))
                    .collect::<Vec<u64>>()
            })
            .collect();
        assert_eq!(got2, cold);
        assert_eq!(cache.cached_nodes(), nl.len());
    }

    /// Netlist whose every gate holds 1 in *all* inactive lanes: a
    /// `Const(true)` feeds ORs, so any garbage-lane leak inflates both
    /// toggle counts and bus reads. Used by the tail-lane regressions.
    fn garbage_prone_netlist() -> Netlist {
        let mut nl = Netlist::new();
        let a = nl.input();
        let one = nl.constant(true);
        let na = nl.not(a); // inactive lanes: !0 = 1
        let o1 = nl.or(na, one); // constant 1 everywhere
        let y0 = nl.xor(na, o1); // = !na in active lanes
        let y1 = nl.and(na, one); // = na
        nl.output("y", vec![y0, y1]);
        nl
    }

    #[test]
    fn tail_lanes_do_not_leak_for_any_residue() {
        // Train-set sizes congruent to 0, 1, 63 (mod 64) — the exact
        // boundary shapes the circuit evaluator's packed batches hit —
        // must classify and toggle-count identically to the scalar
        // engine, even though every inactive lane holds garbage ones.
        let nl = garbage_prone_netlist();
        for n_vec in [1usize, 2, 63, 64, 65, 127, 128, 129, 191] {
            let vectors: Vec<Vec<bool>> =
                (0..n_vec).map(|i| vec![i % 3 == 0]).collect();
            let batches: Vec<InputWave> =
                vectors.chunks(LANES).map(pack_vectors).collect();
            let got = classify(&nl, &batches, "y", 1);
            assert_eq!(got.len(), n_vec, "n_vec={n_vec}");
            for (k, v) in vectors.iter().enumerate() {
                let scalar = eval_nodes(&nl, v);
                let expect: u64 = nl.outputs[0]
                    .1
                    .iter()
                    .enumerate()
                    .map(|(i, &n)| ((scalar[n as usize] as u64) << i))
                    .sum();
                assert_eq!(got[k], expect, "n_vec={n_vec} vector {k}");
            }
            if n_vec >= 2 {
                let fast = toggle_activity(&nl, &vectors);
                let slow = toggle_activity_scalar(&nl, &vectors);
                assert!(
                    (fast - slow).abs() < 1e-12,
                    "n_vec={n_vec}: wave {fast} != scalar {slow}"
                );
            }
        }
    }

    #[test]
    fn wave_cache_tail_lanes_clean_across_extension() {
        // WaveCache over a 65-vector stimulus (64 + 1-lane tail batch):
        // growing the arena and re-querying must keep tail lanes out of
        // the results, with garbage-prone constants in the appended cone.
        let mut nl = Netlist::new();
        let a = nl.input();
        let x = nl.not(a);
        let vectors: Vec<Vec<bool>> = (0..65).map(|i| vec![i % 2 == 1]).collect();
        let batches: Vec<InputWave> = vectors.chunks(LANES).map(pack_vectors).collect();
        assert_eq!(batches.last().unwrap().n_lanes, 1);
        let mut cache = WaveCache::new(batches);
        let got = cache.classify_bus(&nl, &[x]);
        let expect: Vec<u64> = (0..65u64).map(|i| (i + 1) % 2).collect();
        assert_eq!(got, expect);
        // Append garbage-prone logic and re-query through the cache.
        let one = nl.constant(true);
        let y = nl.and(x, one);
        let got2 = cache.classify_bus(&nl, &[y]);
        assert_eq!(got2, expect);
    }

    /// Scalar golden model of per-node toggle counts: evaluate every
    /// vector and count value flips node by node.
    fn node_toggles_scalar(nl: &Netlist, vectors: &[Vec<bool>]) -> Vec<u64> {
        let mut out = vec![0u64; nl.len()];
        if vectors.len() < 2 {
            return out;
        }
        let mut prev = eval_nodes(nl, &vectors[0]);
        for v in &vectors[1..] {
            let cur = eval_nodes(nl, v);
            for (i, t) in out.iter_mut().enumerate() {
                *t += (cur[i] != prev[i]) as u64;
            }
            prev = cur;
        }
        out
    }

    #[test]
    fn prop_wave_cache_node_toggles_match_scalar() {
        // The measured-power substrate: per-node toggle totals the cache
        // accumulates at extension time must equal the scalar per-node
        // flip counts — for every node, any batch-boundary residue, and
        // across append-only netlist growth.
        prop::check("wave-cache node toggles == scalar", |rng, _| {
            let mut nl = random_netlist(rng);
            let n_vec = 2 + rng.below(200);
            let vectors = random_vectors(rng, n_vec, nl.n_inputs as usize);
            let batches: Vec<InputWave> =
                vectors.chunks(LANES).map(pack_vectors).collect();
            let mut cache = WaveCache::new(batches);
            let first_len = nl.len();
            cache.classify_bus(&nl, &nl.outputs[0].1.clone());
            // Grow the netlist (append-only) and re-query: the appended
            // nodes' toggles are computed on extension, the old ones kept.
            let len = nl.len();
            let a = rng.below(len) as NodeId;
            let b = rng.below(len) as NodeId;
            let x = nl.xor(a, b);
            let y = nl.not(x);
            cache.classify_bus(&nl, &[y]);
            let got = cache.node_toggles();
            let want = node_toggles_scalar(&nl, &vectors);
            if got.len() != nl.len() {
                return Err(format!("toggle table len {} != {}", got.len(), nl.len()));
            }
            for i in 0..nl.len() {
                if got[i] != want[i] {
                    return Err(format!(
                        "node {i}: cache {} != scalar {} over {n_vec} vectors \
                         (first extension at len {first_len})",
                        got[i], want[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wave_cache_activity_matches_toggle_activity_exactly() {
        // Summing cached per-cell toggles and dividing by
        // cells * (n_vec - 1) must be bit-identical (f64 ==) to
        // `toggle_activity` — the equality the measured power objective
        // rests on. Garbage-prone netlist + 65-vector tail batch.
        let nl = garbage_prone_netlist();
        for n_vec in [2usize, 63, 64, 65, 129] {
            let vectors: Vec<Vec<bool>> =
                (0..n_vec).map(|i| vec![i % 3 == 0]).collect();
            let batches: Vec<InputWave> =
                vectors.chunks(LANES).map(pack_vectors).collect();
            let mut cache = WaveCache::new(batches);
            cache.classify_bus(&nl, &nl.outputs[0].1.clone());
            let cells: Vec<usize> = nl
                .gates
                .iter()
                .enumerate()
                .filter(|(_, g)| g.is_cell())
                .map(|(i, _)| i)
                .collect();
            let total: u64 = cells.iter().map(|&i| cache.node_toggles()[i]).sum();
            let slots = cells.len() as u64 * (n_vec as u64 - 1);
            let from_cache = total as f64 / slots as f64;
            assert_eq!(
                from_cache,
                toggle_activity(&nl, &vectors),
                "n_vec={n_vec}"
            );
        }
    }

    #[test]
    fn encode_features_layout() {
        // Feature-major, LSB first: [x0 b0..b3, x1 b0..b3, ...]
        let bits = encode_features(&[0b1010, 0b0001], 4);
        assert_eq!(
            bits,
            vec![false, true, false, true, true, false, false, false]
        );
    }

    #[test]
    fn lane_extraction_round_trips() {
        let mut nl = Netlist::new();
        let bus_in = nl.input_bus(6);
        nl.output("v", bus_in.clone());
        let vectors: Vec<Vec<bool>> =
            (0..40u64).map(|v| crate::sim::u64_to_bits(v, 6)).collect();
        let batch = pack_vectors(&vectors);
        let values = eval_wave(&nl, &batch);
        for (lane, _) in vectors.iter().enumerate() {
            assert_eq!(lane_bus_u64(&values, &nl.outputs[0].1, lane), lane as u64);
        }
    }
}
