//! High-level area estimation — the paper's full-adder surrogate model
//! (§III-D3, eq. 2–3).
//!
//! After po2 quantization the multipliers are gone and the adder trees
//! dominate the MLP's area, so counting the full adders needed to reduce
//! every adder-tree column to two rows (carry-save operation) ranks
//! candidate approximations accurately: the paper reports ≥ 0.96 Spearman
//! rank correlation against synthesized area (Table II), which
//! `benches/table2_spearman.rs` regenerates against our synthesis
//! substrate.
//!
//! For column `k` with `L_k` live summand bits and `FA_{k-1}` carries
//! arriving from the right:  `FA_k = ceil((L_k + FA_{k-1} - 2) / 2)`,
//! clamped at zero, with `FA_{-1} = 0` (eq. 2). The MLP estimate is the
//! sum over all trees (eq. 3).

use crate::accum::{GenomeMap, SummandBit};
use crate::util::BitVec;

/// Column occupancy of one adder tree (index = column, value = number of
/// live summand bits in that column).
pub type TreeColumns = Vec<u32>;

/// Number of full adders to reduce one tree to two rows (eq. 2).
pub fn tree_fa_count(columns: &TreeColumns) -> u64 {
    let mut total = 0u64;
    let mut carry = 0i64;
    for &l in columns {
        let fa = ((l as i64 + carry - 2).max(0) + 1) / 2;
        total += fa as u64;
        carry = fa;
    }
    total
}

/// The area estimator bound to one MLP's genome map. Pre-groups summand
/// bits by tree so per-genome evaluation is a single linear pass.
pub struct AreaModel {
    /// For every genome bit: (tree index, column).
    bit_tree: Vec<(u32, u8)>,
    /// Number of columns of each tree.
    tree_cols: Vec<u8>,
    n_trees: usize,
}

impl AreaModel {
    /// Build from the genome map. Trees are identified by
    /// (layer, neuron, pos/neg).
    pub fn new(map: &GenomeMap) -> AreaModel {
        let tree_id = |sb: &SummandBit| -> u64 {
            ((sb.layer as u64) << 32)
                | ((sb.neuron as u64) << 1)
                | (sb.pos_tree as u64)
        };
        let mut ids: Vec<u64> = map.bits.iter().map(tree_id).collect();
        let mut uniq = ids.clone();
        uniq.sort_unstable();
        uniq.dedup();
        let lookup = |id: u64| uniq.binary_search(&id).unwrap() as u32;
        for id in ids.iter_mut() {
            *id = lookup(*id) as u64;
        }
        let n_trees = uniq.len();
        let mut tree_cols = vec![0u8; n_trees];
        let bit_tree: Vec<(u32, u8)> = map
            .bits
            .iter()
            .zip(&ids)
            .map(|(sb, &tid)| {
                let t = tid as usize;
                tree_cols[t] = tree_cols[t].max(sb.column + 1);
                (tid as u32, sb.column)
            })
            .collect();
        AreaModel { bit_tree, tree_cols, n_trees }
    }

    /// Estimated FA count for a genome (eq. 3). Lower is smaller circuit.
    pub fn estimate(&self, genome: &BitVec) -> u64 {
        assert_eq!(genome.len(), self.bit_tree.len());
        // Column occupancy per tree, then eq. 2 per tree.
        let mut occupancy: Vec<Vec<u32>> = self
            .tree_cols
            .iter()
            .map(|&c| vec![0u32; c as usize])
            .collect();
        for (i, &(t, col)) in self.bit_tree.iter().enumerate() {
            if genome.get(i) {
                occupancy[t as usize][col as usize] += 1;
            }
        }
        occupancy.iter().map(|cols| tree_fa_count(cols)).sum()
    }

    /// FA estimate of the exact (unmasked) design.
    pub fn exact_estimate(&self) -> u64 {
        self.estimate(&BitVec::ones(self.bit_tree.len()))
    }

    pub fn n_trees(&self) -> usize {
        self.n_trees
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accum::GenomeMap;
    use crate::config::builtin;
    use crate::datasets;
    use crate::model::float_mlp::TrainOpts;
    use crate::model::{FloatMlp, QuantMlp};
    use crate::util::prop;

    #[test]
    fn paper_fig3_example() {
        // Fig. 3: summing four 4-bit operands (columns all holding 4 bits)
        // needs 6 FAs + 2 HAs exactly; our FA-only model (paper: "assumes
        // only full-adders and no half-adders") counts the reduction FAs.
        // Occupancy: 4 operands aligned -> columns [4,4,4,4].
        let cols = vec![4, 4, 4, 4];
        let fa = tree_fa_count(&cols);
        // col0: ceil((4-2)/2)=1, col1: ceil((4+1-2)/2)=2 (ceil 1.5),
        // col2: ceil((4+2-2)/2)=2, col3: ceil((4+2-2)/2)=2 -> 7.
        assert_eq!(fa, 7);
    }

    #[test]
    fn empty_and_trivial_columns() {
        assert_eq!(tree_fa_count(&vec![]), 0);
        assert_eq!(tree_fa_count(&vec![0, 0, 0]), 0);
        assert_eq!(tree_fa_count(&vec![1]), 0);
        assert_eq!(tree_fa_count(&vec![2]), 0);
        assert_eq!(tree_fa_count(&vec![3]), 1);
        assert_eq!(tree_fa_count(&vec![4]), 1);
        assert_eq!(tree_fa_count(&vec![5]), 2);
    }

    #[test]
    fn carries_propagate() {
        // Two columns of 4: col0 -> 1 FA, col1 gets 4+1 -> ceil(3/2)=2.
        assert_eq!(tree_fa_count(&vec![4, 4]), 3);
    }

    fn tiny_model() -> (QuantMlp, GenomeMap, AreaModel) {
        let cfg = builtin::tiny();
        let (split, qtrain, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 25, ..Default::default() });
        let qmlp = QuantMlp::from_float(&mlp, &qtrain);
        let map = GenomeMap::new(&qmlp);
        let area = AreaModel::new(&map);
        (qmlp, map, area)
    }

    #[test]
    fn exact_design_has_positive_area() {
        let (_, map, area) = tiny_model();
        assert!(area.exact_estimate() > 0);
        assert!(area.n_trees() > 0);
        assert!(area.n_trees() <= 2 * (3 + 3)); // pos+neg per neuron
        assert_eq!(area.estimate(&map.exact_genome()), area.exact_estimate());
    }

    #[test]
    fn prop_removing_bits_never_increases_area() {
        // Monotonicity: clearing genome bits cannot increase the FA count
        // (the property the genetic search exploits).
        let (_, map, area) = tiny_model();
        prop::check("area monotone under bit removal", |rng, _| {
            let g = map.random_genome(rng, 0.8);
            let base = area.estimate(&g);
            let mut g2 = g.clone();
            // Clear a random kept bit (if any).
            let kept: Vec<usize> = (0..g.len()).filter(|&i| g.get(i)).collect();
            if kept.is_empty() {
                return Ok(());
            }
            g2.set(kept[rng.below(kept.len())], false);
            let after = area.estimate(&g2);
            if after > base {
                return Err(format!("area increased {base} -> {after}"));
            }
            Ok(())
        });
    }

    #[test]
    fn all_removed_is_zero_area() {
        let (_, map, area) = tiny_model();
        assert_eq!(area.estimate(&crate::util::BitVec::zeros(map.len())), 0);
    }

    #[test]
    fn prop_single_tree_formula_matches_naive() {
        // Cross-check eq. 2 against a naive simulation of 3:2 compression.
        prop::check("fa count vs naive csa sim", |rng, _| {
            let ncols = 1 + rng.below(10);
            let cols: Vec<u32> = (0..ncols).map(|_| rng.below(12) as u32).collect();
            let fast = tree_fa_count(&cols);
            // Naive: repeatedly apply FAs column by column with carries.
            let mut naive = 0u64;
            let mut carry = 0u32;
            for &l in &cols {
                let mut live = l + carry;
                let mut fas = 0u32;
                while live > 2 {
                    live -= 2; // FA replaces 3 bits by 1 sum (+1 carry to left)
                    fas += 1;
                }
                naive += fas as u64;
                carry = fas;
            }
            if fast != naive {
                return Err(format!("cols {cols:?}: {fast} vs naive {naive}"));
            }
            Ok(())
        });
    }
}
