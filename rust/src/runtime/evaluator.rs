//! GA chromosome evaluators — the hot path of the framework.
//!
//! Three interchangeable implementations of [`crate::ga::Evaluator`]:
//!
//! * [`PjrtEvaluator`] — the three-layer architecture's path: batches of
//!   chromosomes are packed into mask tensors and dispatched to the
//!   AOT-compiled `masked_acc_<ds>` program (Layer-2 JAX calling the
//!   Layer-1 Pallas masked-MAC kernel) through PJRT. Python is not
//!   involved at run time. Parallelism lives inside XLA, so this backend
//!   takes the whole-batch fast path (`evaluate_batch`) instead of the
//!   worker fan-out.
//! * [`NativeEvaluator`] — the pure-Rust integer model. Used for
//!   cross-checking the PJRT path bit-exactly and as the fallback when
//!   artifacts are absent.
//! * [`CircuitEvaluator`] — circuit-in-the-loop: every chromosome is
//!   synthesized to its bespoke gate-level netlist and the whole
//!   evaluation set is classified through the bit-parallel wave simulator
//!   (`crate::sim::wave`), so the GA's accuracy objective is measured on
//!   the *actual hardware function*, not the integer model. Affordable
//!   because the wave engine advances one `[u64; 4]` lane block — 256
//!   vectors — per pass (64 under `--lane-width 64`, the debug width)
//!   and, in the default [`SynthMode::Incremental`], because chromosomes
//!   are deltas against a shared template: synthesis and simulation only
//!   revisit the fanout cones of the flipped mask bits, and structurally
//!   identical cones repeated across a generation's chromosomes are
//!   settled once per worker via the generation-scoped shared-cone memo
//!   (see `synth::incremental`).
//!
//! ## Population-parallel execution model
//!
//! The evaluators split into shared read-only state (the struct itself —
//! model, genome map, area surrogate, packed train batches, the shared
//! fitness memo) and per-worker scratch ([`crate::ga::EvalWorker`]).
//! `Nsga2` fans each generation across a worker pool; every worker of
//! the circuit backend *owns* an [`IncrementalSynth`] arena and a
//! lane-block [`BlockCache`] (leased from a parked pool so they persist
//! across generations), so the hot path takes no locks except single
//! memo probes. Objectives are a pure function of the genome, which keeps
//! parallel runs bit-identical to serial ones (`--jobs 1` == `--jobs N`,
//! pinned by `rust/tests/ga_determinism.rs`).
//!
//! All return the objective vector `[accuracy_loss, cost, ...]` the
//! const-generic NSGA-II optimizer minimizes (paper §III-D1/D2/D3). The
//! native and PJRT evaluators are fixed at arity 2 (loss + FA area
//! surrogate); [`CircuitEvaluator`] is generic over the objective arity
//! `M` and can score *measured* EGFET area, dynamic power, and/or
//! critical-path delay of each chromosome's synthesized survivor
//! (`--objective`, [`CostObjective`]): arity 2 for
//! `fa|area|power|delay`, arity 3 for the joint `area+power` mode, and
//! arity 4 for `area+power+delay`, whose `[loss, area, power, delay]`
//! axes all fall out of one incremental pass — the delay axis reads the
//! arena's live-output arrival max
//! ([`IncrementalSynth::output_delay_ms`]), maintained at emit time, so
//! timing costs nothing beyond the synthesis the chromosome already
//! paid.

use crate::accum::GenomeMap;
use crate::area::AreaModel;
use crate::datasets::QuantDataset;
use crate::egfet::{self, CostObjective, Library};
use crate::ga::{EvalWorker, Evaluator};
use crate::model::QuantMlp;
use crate::netlist::mlp::{build_mlp_circuit, build_mlp_template, ArgmaxMode, MlpCircuitOpts};
use crate::netlist::{CellCounts, Netlist, NodeId, Template};
use crate::runtime::{lit_i32, lit_i32_scalar, Executable, Literal, Runtime};
use crate::sim::wave::{self, BlockCache, BlockWave, LaneWidth, BLOCK_WORDS};
use crate::synth::incremental::IncrementalSynth;
use crate::synth::verify::{self, VerifyMode, Violation};
use crate::synth::{optimize, SynthMode};
use crate::util::telemetry::{self, Counter, Work};
use crate::util::{BitVec, ShardedMap};
use anyhow::Result;
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

/// Flattened i32 views of a quantized MLP (what the artifacts consume).
#[derive(Clone, Debug)]
pub struct QuantInts {
    pub w1_sign: Vec<i32>,
    pub w1_shift: Vec<i32>,
    pub b1_val: Vec<i32>,
    pub w2_sign: Vec<i32>,
    pub w2_shift: Vec<i32>,
    pub b2_val: Vec<i32>,
    pub act_shift: i32,
}

impl QuantInts {
    pub fn from_mlp(mlp: &QuantMlp) -> QuantInts {
        let conv = |layer: &crate::model::QuantLayer| {
            let sign: Vec<i32> = layer.w.iter().map(|w| w.sign as i32).collect();
            let shift: Vec<i32> = layer.w.iter().map(|w| w.shift as i32).collect();
            let bias: Vec<i32> = layer.bias.iter().map(|b| b.int_value() as i32).collect();
            (sign, shift, bias)
        };
        let (w1_sign, w1_shift, b1_val) = conv(&mlp.l1);
        let (w2_sign, w2_shift, b2_val) = conv(&mlp.l2);
        QuantInts {
            w1_sign,
            w1_shift,
            b1_val,
            w2_sign,
            w2_shift,
            b2_val,
            act_shift: mlp.act_shift as i32,
        }
    }
}

/// The PJRT-backed evaluator.
///
/// Shared-state thread safety (`ga::Evaluator: Sync`): the struct holds
/// only plain data plus the `Executable` handle (a unit stub in default
/// builds; `Sync` by an explicit impl over the thread-safe PJRT C API
/// under the `xla` feature). Argument literals are materialized per
/// dispatch rather than cached, so no PJRT literal handles live in
/// shared state. The batch fast path is dispatched from one thread at a
/// time by the GA anyway.
pub struct PjrtEvaluator {
    exe: Arc<Executable>,
    /// Population tile of the artifact.
    p: usize,
    n_real: usize,
    mlp: QuantMlp,
    map: GenomeMap,
    area: AreaModel,
    base_acc: f64,
    /// Padded input matrix (B x N0, row-major), rebuilt into a literal
    /// per dispatch.
    x_flat: Vec<i32>,
    /// Padded labels (-1 rows are never correct).
    labels: Vec<i32>,
    /// Integer views of the quantized model.
    ints: QuantInts,
    dims: (usize, usize, usize, usize), // (B, N0, H, O)
}

impl PjrtEvaluator {
    /// Build an evaluator for `name` over the quantized train set.
    ///
    /// `base_acc` is the exact (unmasked) train accuracy the loss is
    /// measured against.
    pub fn new(
        runtime: &Runtime,
        name: &str,
        mlp: &QuantMlp,
        train: &QuantDataset,
        base_acc: f64,
    ) -> Result<PjrtEvaluator> {
        let entry = runtime.entry(name)?.clone();
        anyhow::ensure!(
            entry.n_in == mlp.topo.n_in
                && entry.n_hidden == mlp.topo.n_hidden
                && entry.n_out == mlp.topo.n_out,
            "artifact topology mismatch for '{name}'"
        );
        let b = entry.eval_batch;
        anyhow::ensure!(
            train.n_samples() <= b,
            "train set ({}) exceeds artifact eval batch ({b})",
            train.n_samples()
        );
        let exe = runtime.load(&format!("masked_acc_{name}"))?;
        let (n0, h, o) = (entry.n_in, entry.n_hidden, entry.n_out);

        // Pad inputs to B rows; padding labels are -1 (never correct).
        let mut x_flat = vec![0i32; b * n0];
        let mut labels = vec![-1i32; b];
        for (i, row) in train.x.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                x_flat[i * n0 + j] = v as i32;
            }
            labels[i] = train.y[i] as i32;
        }

        let ints = QuantInts::from_mlp(mlp);
        let map = GenomeMap::new(mlp);
        let area = AreaModel::new(&map);
        Ok(PjrtEvaluator {
            exe,
            p: runtime.manifest.p_tile,
            n_real: train.n_samples(),
            mlp: mlp.clone(),
            map,
            area,
            base_acc,
            x_flat,
            labels,
            ints,
            dims: (b, n0, h, o),
        })
    }

    /// The genome map (shared with the coordinator for mask decoding).
    pub fn genome_map(&self) -> &GenomeMap {
        &self.map
    }

    /// Evaluate one tile of up to `p` genomes; returns train accuracies.
    fn eval_tile(&self, genomes: &[&BitVec]) -> Result<Vec<f64>> {
        let (b, n0, h, o) = self.dims;
        let p = self.p;
        assert!(genomes.len() <= p);
        let exact = self.map.exact_genome();
        let mut m1 = vec![0i32; p * h * n0];
        let mut mb1 = vec![0i32; p * h];
        let mut m2 = vec![0i32; p * o * h];
        let mut mb2 = vec![0i32; p * o];
        for pi in 0..p {
            let genome = genomes.get(pi).copied().unwrap_or(&exact);
            let masks = self.map.to_masks(genome);
            for (k, &m) in masks.m1.iter().enumerate() {
                m1[pi * h * n0 + k] = m as i32;
            }
            for (k, &keep) in masks.mb1.iter().enumerate() {
                mb1[pi * h + k] = keep as i32;
            }
            for (k, &m) in masks.m2.iter().enumerate() {
                m2[pi * o * h + k] = m as i32;
            }
            for (k, &keep) in masks.mb2.iter().enumerate() {
                mb2[pi * o + k] = keep as i32;
            }
        }
        // Positional argument order fixed by aot.py::lower_masked_acc.
        // All literals (fixed tensors included) are materialized per
        // dispatch — see the struct docs on `Sync`.
        let x_lit = lit_i32(&self.x_flat, &[b as i64, n0 as i64])?;
        let y_lit = lit_i32(&self.labels, &[b as i64])?;
        let w1s_lit = lit_i32(&self.ints.w1_sign, &[h as i64, n0 as i64])?;
        let w1k_lit = lit_i32(&self.ints.w1_shift, &[h as i64, n0 as i64])?;
        let b1_lit = lit_i32(&self.ints.b1_val, &[h as i64])?;
        let w2s_lit = lit_i32(&self.ints.w2_sign, &[o as i64, h as i64])?;
        let w2k_lit = lit_i32(&self.ints.w2_shift, &[o as i64, h as i64])?;
        let b2_lit = lit_i32(&self.ints.b2_val, &[o as i64])?;
        let mb1_lit = lit_i32(&mb1, &[p as i64, h as i64])?;
        let mb2_lit = lit_i32(&mb2, &[p as i64, o as i64])?;
        let m1_lit = lit_i32(&m1, &[p as i64, h as i64, n0 as i64])?;
        let m2_lit = lit_i32(&m2, &[p as i64, o as i64, h as i64])?;
        let act_lit = lit_i32_scalar(self.act_shift());
        let all: Vec<&Literal> = vec![
            &x_lit, &y_lit, &w1s_lit, &w1k_lit, &b1_lit, &mb1_lit, &w2s_lit, &w2k_lit,
            &b2_lit, &mb2_lit, &m1_lit, &m2_lit, &act_lit,
        ];
        let outs = self.exe.run(&all)?;
        let counts = outs[0].to_vec::<i32>()?;
        Ok(counts
            .iter()
            .take(genomes.len())
            .map(|&c| c as f64 / self.n_real as f64)
            .collect())
    }

    fn act_shift(&self) -> i32 {
        self.mlp.act_shift as i32
    }

    /// Tile-batched evaluation of an arbitrary genome slice.
    fn eval_all(&self, genomes: &[BitVec]) -> Vec<[f64; 2]> {
        let mut objs = Vec::with_capacity(genomes.len());
        for chunk in genomes.chunks(self.p) {
            let refs: Vec<&BitVec> = chunk.iter().collect();
            let accs = self
                .eval_tile(&refs)
                .expect("PJRT evaluation failed (artifacts stale?)");
            for (genome, acc) in chunk.iter().zip(accs) {
                let loss = (self.base_acc - acc).max(0.0);
                let area = self.area.estimate(genome) as f64;
                objs.push([loss, area]);
            }
        }
        objs
    }
}

struct PjrtWorker<'a> {
    ev: &'a PjrtEvaluator,
}

impl EvalWorker<2> for PjrtWorker<'_> {
    fn eval_one(&mut self, genome: &BitVec) -> [f64; 2] {
        self.ev.eval_all(std::slice::from_ref(genome))[0]
    }
}

impl Evaluator<2> for PjrtEvaluator {
    fn worker(&self) -> Box<dyn EvalWorker<2> + '_> {
        Box::new(PjrtWorker { ev: self })
    }

    /// Whole-population fast path: tiles go to XLA, which parallelizes
    /// internally — fanning single genomes across threads would only
    /// shrink the tiles.
    fn evaluate_batch(&self, genomes: &[BitVec]) -> Option<Vec<[f64; 2]>> {
        Some(self.eval_all(genomes))
    }
}

/// The pure-Rust evaluator. Stateless per worker — all scratch it needs
/// is the mask expansion, rebuilt per genome.
pub struct NativeEvaluator {
    pub mlp: QuantMlp,
    pub train: QuantDataset,
    pub map: GenomeMap,
    pub area: AreaModel,
    pub base_acc: f64,
}

impl NativeEvaluator {
    pub fn new(mlp: &QuantMlp, train: &QuantDataset, base_acc: f64) -> NativeEvaluator {
        let map = GenomeMap::new(mlp);
        let area = AreaModel::new(&map);
        NativeEvaluator {
            mlp: mlp.clone(),
            train: train.clone(),
            map,
            area,
            base_acc,
        }
    }
}

struct NativeWorker<'a> {
    ev: &'a NativeEvaluator,
}

impl EvalWorker<2> for NativeWorker<'_> {
    fn eval_one(&mut self, genome: &BitVec) -> [f64; 2] {
        let ev = self.ev;
        let masks = ev.map.to_masks(genome);
        let acc = ev.mlp.accuracy(&ev.train, Some(&masks));
        let loss = (ev.base_acc - acc).max(0.0);
        [loss, ev.area.estimate(genome) as f64]
    }
}

impl Evaluator<2> for NativeEvaluator {
    fn worker(&self) -> Box<dyn EvalWorker<2> + '_> {
        Box::new(NativeWorker { ev: self })
    }
}

/// Circuit-in-the-loop evaluator: fitness on the synthesized netlist.
///
/// Every chromosome is scored on the *actual gate-level function* the
/// design tapes out with, closing the loop the paper leaves open between
/// the GA's integer surrogate and the hardware. Two synthesis strategies
/// ([`SynthMode`], `--synth` on the CLI) produce bit-identical
/// classifications:
///
/// * [`SynthMode::Full`] — the from-scratch path: per chromosome, build
///   the bespoke circuit ([`build_mlp_circuit`]), run
///   [`crate::synth::optimize`] (the constant sweep that realizes the
///   approximation) and wave-classify the train set, one lane block
///   (256 samples at the default width) per pass. Workers are
///   stateless; parallelism is across genomes.
/// * [`SynthMode::Incremental`] — the template path (the default): one
///   parameterized netlist ([`build_mlp_template`], `Param` site `p` =
///   genome bit `p`) is built lazily on first use and shared read-only;
///   **each worker owns** an [`IncrementalSynth`] arena plus an
///   arena-aligned lane-block [`BlockCache`], so every chromosome is a
///   [`IncrementalSynth::set_params`] delta that re-simplifies and
///   re-simulates only the fanout cones of its flipped mask bits —
///   lock-free after the state is leased. Within a generation, workers
///   additionally share structurally-identical cone results through the
///   engine's shared-cone memo (flushed at worker drop — the generation
///   boundary). Worker states park in a pool between generations, so
///   arenas and lane-block caches keep amortizing across the whole GA
///   run.
///
/// The cost objective defaults to the FA surrogate of [`AreaModel`] so
/// fronts from all three backends are directly comparable (and the
/// coordinator's exact-genome fallback injects the same units). Because
/// this backend synthesizes every chromosome anyway, it can instead
/// select on what the paper's NSGA-II actually measures
/// ([`CostObjective`], `--objective area|power`): the EGFET cell area or
/// dynamic power of the synthesized survivor, rolled up allocation-free
/// from the incremental census ([`egfet::analyze_histogram`]) with
/// toggle activity read off the worker's [`BlockCache`] (per-node toggle
/// totals accumulate as a side effect of classification — no extra
/// simulation). Both synthesis modes score measured objectives on the
/// *template* synthesis flow (`optimize(template.instantiate(g))` is the
/// full-mode reference), so `--synth full` and `--synth incremental`
/// stay bit-identical, and the cost equals `egfet::analyze` of the
/// survivor up to float summation order (pinned by tests).
///
/// Results are memoized across generations in a [`ShardedMap`] keyed on
/// the **full genome bit vector** — never a truncated hash, which could
/// silently return another chromosome's fitness on collision. Each cache
/// hit skips synthesis + simulation entirely.
///
/// The const parameter `M` is the GA objective arity the evaluator
/// scores at (axis 0 = accuracy loss, axes 1.. = cost). It must match
/// the configured [`CostObjective`]'s [`CostObjective::arity`] —
/// enforced at construction, so an evaluator can never hand the
/// optimizer a half-filled objective vector: [`CircuitEvaluator::new`]
/// builds the classic two-objective evaluator,
/// [`CircuitEvaluator::new_joint`] the three-objective
/// `[loss, area, power]` one (`--objective area+power`), whose two cost
/// axes fall out of the *same* [`egfet::analyze_histogram`] roll-up of
/// the same single incremental pass, and
/// [`CircuitEvaluator::new_joint_delay`] the four-objective
/// `[loss, area, power, delay]` one (`--objective area+power+delay`),
/// which additionally reads the incremental engine's arrival table —
/// bit-identical to from-scratch `egfet::analyze` timing of the
/// survivor (full mode computes exactly that).
pub struct CircuitEvaluator<const M: usize = 2> {
    pub mlp: QuantMlp,
    pub map: GenomeMap,
    pub area: AreaModel,
    pub base_acc: f64,
    mode: SynthMode,
    /// Which cost(s) objectives 1.. report ([`CostObjective::Fa`] by
    /// default; fixed for the evaluator's lifetime — the memo caches it,
    /// and its arity is pinned to `M` at construction).
    objective: CostObjective,
    /// EGFET corner the measured objectives roll up against.
    lib: Library,
    /// Encoded train rows (circuit primary-input bit order), kept so the
    /// stimulus can be re-packed when [`Self::with_lane_width`] changes
    /// the wave width.
    encoded: Vec<Vec<bool>>,
    /// Train samples packed once at the evaluator's lane width —
    /// classify batches and (for measured scoring) the activity
    /// stimulus.
    batches: Stimulus,
    /// Simulator lane width (throughput knob only — classifications are
    /// per-vector integers, so widths are bit-identical by construction
    /// and pinned so by tests).
    lane_width: LaneWidth,
    /// Whether incremental workers share structurally-identical cone
    /// results within a generation (`--share-cones`; default on — exact,
    /// work-saving only).
    share_cones: bool,
    labels: Vec<usize>,
    /// When the invariant verifier (`synth::verify`) checkpoints the
    /// template and the workers' live arenas (`--verify`; default
    /// [`VerifyMode::Off`] — zero cost on the hot path). Violations are
    /// counted (`verify.violations`) and logged, never panicked on.
    verify: VerifyMode,
    /// Cross-generation fitness memo (full-genome keys). Each entry
    /// parks the survivor's hardware state next to the objective vector
    /// ([`MemoEntry`]) so warm consumers — the serve layer's repeated
    /// requests — can roll hardware reports up from the census without
    /// re-synthesizing anything.
    memo: ShardedMap<BitVec, MemoEntry<M>>,
    /// The shared parameterized netlist, built on first incremental use
    /// or injected up front by a warm-state owner
    /// ([`Self::with_template`] — the serve layer shares one template
    /// across evaluator arities through its keyed cache).
    template: OnceLock<Arc<Template>>,
    /// Parked per-worker incremental states, reused across generations.
    incr_pool: Mutex<Vec<IncrState>>,
}

/// The packed train set at one of the two supported lane widths. The
/// width is fixed per evaluator, so the enum is matched once per
/// classify/activity call — the generic block engine underneath is
/// monomorphized per width.
enum Stimulus {
    W64(Vec<BlockWave<1>>),
    W256(Vec<BlockWave<BLOCK_WORDS>>),
}

impl Stimulus {
    fn pack(encoded: &[Vec<bool>], width: LaneWidth) -> Stimulus {
        match width {
            LaneWidth::W64 => {
                Stimulus::W64(encoded.chunks(wave::LANES).map(|c| wave::pack_wave(c)).collect())
            }
            LaneWidth::W256 => Stimulus::W256(
                encoded.chunks(wave::BLOCK_LANES).map(|c| wave::pack_wave(c)).collect(),
            ),
        }
    }

    fn classify(&self, nl: &Netlist, out_bus: &str, n_threads: usize) -> Vec<u64> {
        match self {
            Stimulus::W64(b) => wave::classify_blocks(nl, b, out_bus, n_threads),
            Stimulus::W256(b) => wave::classify_blocks(nl, b, out_bus, n_threads),
        }
    }

    fn toggle_activity(&self, nl: &Netlist) -> f64 {
        match self {
            Stimulus::W64(b) => wave::toggle_activity_blocks(nl, b),
            Stimulus::W256(b) => wave::toggle_activity_blocks(nl, b),
        }
    }

    /// A fresh arena-aligned wave cache over this stimulus.
    fn cache(&self) -> EvalCache {
        match self {
            Stimulus::W64(b) => EvalCache::W64(BlockCache::new(b.clone())),
            Stimulus::W256(b) => EvalCache::W256(BlockCache::new(b.clone())),
        }
    }
}

/// A worker's lane-block cache at the evaluator's width (the width-erased
/// face of [`BlockCache`] the lease pool stores).
enum EvalCache {
    W64(BlockCache<1>),
    W256(BlockCache<BLOCK_WORDS>),
}

impl EvalCache {
    fn classify_bus(&mut self, nl: &Netlist, bus: &[NodeId]) -> Vec<u64> {
        match self {
            EvalCache::W64(c) => c.classify_bus(nl, bus),
            EvalCache::W256(c) => c.classify_bus(nl, bus),
        }
    }

    fn node_toggles(&self) -> &[u64] {
        match self {
            EvalCache::W64(c) => c.node_toggles(),
            EvalCache::W256(c) => c.node_toggles(),
        }
    }
}

struct IncrState {
    synth: IncrementalSynth,
    wave: EvalCache,
}

/// One survivor's parked hardware state: the cell census, the raw
/// toggle total over the live cells and their count (the two integers
/// the activity ratio divides), and the measured critical path — the
/// emit-time by-products of the incremental pass the chromosome already
/// paid for. Everything a warm consumer needs to re-derive the measured
/// axes (`analyze_histogram` plus the activity division, bit-identical
/// to the evaluation-time roll-up) without leasing a synthesis arena.
#[derive(Clone, Debug)]
pub struct HwMemo {
    pub census: CellCounts,
    pub toggle_sum: u64,
    pub live_cells: usize,
    pub delay_ms: f64,
}

/// A fitness-memo entry: the objective vector the GA consumes plus the
/// optionally parked hardware state. `hw` is filled by the incremental
/// path (whatever the objective — FA runs park it too, so a later
/// measured query over the same study starts warm) and `None` in full
/// mode, whose from-scratch survivor is dropped after scoring. Behind
/// an [`Arc`] because every memo probe clones the entry out of the
/// shard.
#[derive(Clone, Debug)]
struct MemoEntry<const M: usize> {
    objs: [f64; M],
    hw: Option<Arc<HwMemo>>,
}

/// Reset a worker's incremental state when its append-only arena (and
/// the per-batch lane-block caches riding on it) outgrows the template by
/// this factor. Dedup makes growth decelerate sharply on GA streams, so
/// the cap is a memory backstop for pathologically diverse genome
/// sequences; a reset costs one from-scratch pass on that worker's next
/// genome, and the shared memo survives it.
const ARENA_GROWTH_LIMIT: usize = 8;

/// Surface verifier findings without aborting the run: the checkpoints
/// are diagnostics, and the telemetry (`verify.violations`, bumped by
/// the verifier itself) is what CI gates on. A healthy run logs nothing.
fn report_violations(violations: &[Violation]) {
    for v in violations {
        telemetry::info("verify", &v.to_string());
    }
}

impl CircuitEvaluator<2> {
    /// The classic two-objective evaluator (loss + one cost axis).
    /// Defaults to [`SynthMode::Incremental`] and [`CostObjective::Fa`];
    /// see [`Self::with_mode`] / [`Self::with_objective`].
    pub fn new(mlp: &QuantMlp, train: &QuantDataset, base_acc: f64) -> CircuitEvaluator<2> {
        CircuitEvaluator::with_arity(mlp, train, base_acc, CostObjective::Fa)
    }
}

impl CircuitEvaluator<3> {
    /// The joint three-objective evaluator (`--objective area+power`):
    /// `[loss, area_cm2, power_mw]`, both cost axes measured on the
    /// synthesized survivor from the same single roll-up.
    pub fn new_joint(mlp: &QuantMlp, train: &QuantDataset, base_acc: f64) -> CircuitEvaluator<3> {
        CircuitEvaluator::with_arity(mlp, train, base_acc, CostObjective::AreaPower)
    }
}

impl CircuitEvaluator<4> {
    /// The joint four-objective evaluator (`--objective
    /// area+power+delay`): `[loss, area_cm2, power_mw, delay_ms]`. Area
    /// and power roll up from the same census as the 3-D mode; the
    /// delay axis is the survivor's measured critical path — the
    /// incremental arena's live-output arrival max, or (full mode)
    /// `egfet::critical_path_ms` of the from-scratch survivor, which
    /// are bit-identical by construction.
    pub fn new_joint_delay(
        mlp: &QuantMlp,
        train: &QuantDataset,
        base_acc: f64,
    ) -> CircuitEvaluator<4> {
        CircuitEvaluator::with_arity(mlp, train, base_acc, CostObjective::AreaPowerDelay)
    }
}

impl<const M: usize> CircuitEvaluator<M> {
    /// Shared constructor; the objective's arity must equal `M`.
    fn with_arity(
        mlp: &QuantMlp,
        train: &QuantDataset,
        base_acc: f64,
        objective: CostObjective,
    ) -> CircuitEvaluator<M> {
        assert_eq!(
            objective.arity(),
            M,
            "objective '{}' scores {} axes, evaluator is arity {M}",
            objective.label(),
            objective.arity()
        );
        let map = GenomeMap::new(mlp);
        let area = AreaModel::new(&map);
        let encoded: Vec<Vec<bool>> = train
            .x
            .iter()
            .map(|row| wave::encode_features(row, mlp.l1.in_bits))
            .collect();
        let lane_width = LaneWidth::default();
        let batches = Stimulus::pack(&encoded, lane_width);
        CircuitEvaluator {
            mlp: mlp.clone(),
            map,
            area,
            base_acc,
            mode: SynthMode::Incremental,
            objective,
            lib: Library::egfet_1v(),
            encoded,
            batches,
            lane_width,
            share_cones: true,
            labels: train.y.clone(),
            verify: VerifyMode::Off,
            memo: ShardedMap::new(),
            template: OnceLock::new(),
            incr_pool: Mutex::new(Vec::new()),
        }
    }

    /// Select the synthesis strategy (both are bit-identical in output).
    pub fn with_mode(mut self, mode: SynthMode) -> CircuitEvaluator<M> {
        self.mode = mode;
        self
    }

    /// Select the cost objective (`--objective`). Measured objectives are
    /// scored at the 1 V evaluation corner. The objective's arity must
    /// match the evaluator's — `area+power` lives on
    /// [`CircuitEvaluator::new_joint`]'s arity-3 evaluator only.
    pub fn with_objective(mut self, objective: CostObjective) -> CircuitEvaluator<M> {
        assert_eq!(
            objective.arity(),
            M,
            "objective '{}' scores {} axes, evaluator is arity {M}",
            objective.label(),
            objective.arity()
        );
        self.objective = objective;
        self
    }

    /// Select the simulator lane width (`--lane-width`). Defaults to the
    /// 256-lane production blocks; 64 is the legacy/debug width. Pure
    /// throughput knob: every scoring path reduces to per-vector
    /// integers, so both widths are bit-identical (pinned by tests and
    /// `rust/tests/ga_determinism.rs`). Re-packs the stimulus; call
    /// before the first evaluation (parked worker caches are built at
    /// the width current when they lease).
    pub fn with_lane_width(mut self, width: LaneWidth) -> CircuitEvaluator<M> {
        if width != self.lane_width {
            self.lane_width = width;
            self.batches = Stimulus::pack(&self.encoded, width);
        }
        self
    }

    /// Enable/disable generation-scoped shared-cone evaluation in the
    /// incremental engine (`--share-cones`; default on). Exact — memo
    /// hits replay the byte-identical cone result a re-synthesis would
    /// derive — so this only changes work counters, never objectives
    /// (pinned by `rust/tests/ga_determinism.rs`).
    pub fn with_cone_sharing(mut self, on: bool) -> CircuitEvaluator<M> {
        self.share_cones = on;
        self
    }

    /// Select when the invariant verifier checkpoints (`--verify
    /// off|boundaries|every-gen`; default off). Checks are read-only
    /// analyses over the template and the workers' live arenas
    /// (`synth::verify`), so they change work stats and diagnostics but
    /// never objectives.
    pub fn with_verify(mut self, mode: VerifyMode) -> CircuitEvaluator<M> {
        self.verify = mode;
        self
    }

    /// Inject a pre-built shared template instead of building one lazily
    /// on first incremental use. This is how the serve layer promotes
    /// the per-evaluator `OnceLock` to a keyed cache: one
    /// `Arc<Template>` per study, shared across requests and across
    /// objective arities (a 2-, 3- and 4-objective evaluator over the
    /// same model instantiate the identical template). The injected
    /// template must match this evaluator's genome map — same pin the
    /// lazy build asserts. No-op if the template was already built.
    pub fn with_template(self, tpl: Arc<Template>) -> CircuitEvaluator<M> {
        assert_eq!(
            tpl.n_params,
            self.map.len(),
            "injected template param sites must match the genome map"
        );
        let _ = self.template.set(tpl);
        self
    }

    pub fn mode(&self) -> SynthMode {
        self.mode
    }

    pub fn verify(&self) -> VerifyMode {
        self.verify
    }

    pub fn objective(&self) -> CostObjective {
        self.objective
    }

    pub fn lane_width(&self) -> LaneWidth {
        self.lane_width
    }

    pub fn cone_sharing(&self) -> bool {
        self.share_cones
    }

    /// Entries in the cross-generation fitness memo.
    pub fn memo_len(&self) -> usize {
        self.memo.len()
    }

    /// The shared template (built once; read-only afterwards). With
    /// verification on, the freshly built template is vetted once here —
    /// every later checkpoint re-verifies it alongside a live arena.
    fn template(&self) -> &Template {
        self.template_arc()
    }

    /// The template behind its shared handle — what warm-state owners
    /// clone into their keyed cache so later evaluators (any arity) can
    /// skip the build via [`Self::with_template`].
    pub fn template_arc(&self) -> &Arc<Template> {
        self.template.get_or_init(|| {
            let tpl = build_mlp_template(&self.mlp, &ArgmaxMode::Exact);
            assert_eq!(
                tpl.n_params,
                self.map.len(),
                "template param sites drifted from the genome map"
            );
            if self.verify != VerifyMode::Off {
                report_violations(&verify::verify_template(&tpl, Some(self.map.len())));
            }
            Arc::new(tpl)
        })
    }

    /// The single definition of the accuracy-loss objective, shared by
    /// every scoring path so the full-vs-incremental bit-identity pin
    /// can never drift on a one-sided edit.
    fn loss_of(&self, acc: f64) -> f64 {
        (self.base_acc - acc).max(0.0)
    }

    /// Pack loss + the FA surrogate into the objective vector (the
    /// non-measured path; only reachable on arity-2 evaluators — the
    /// constructor pins `Fa` to `M == 2`).
    fn objectives(&self, genome: &BitVec, acc: f64) -> [f64; M] {
        let mut o = [0.0f64; M];
        o[0] = self.loss_of(acc);
        o[1..].copy_from_slice(&[self.area.estimate(genome) as f64]);
        o
    }

    fn accuracy_of(&self, preds: &[u64]) -> f64 {
        let correct = preds
            .iter()
            .zip(&self.labels)
            .filter(|(&p, &y)| p as usize == y)
            .count();
        correct as f64 / self.labels.len().max(1) as f64
    }

    /// The toggle-activity ratio of a survivor given its live cell ids
    /// and the arena-aligned toggle table. Formed from the exact
    /// integers `sim::toggle_activity` counts (total toggles over
    /// `cells * (n_vectors - 1)` slots), so measured costs are
    /// bit-identical to `analyze_histogram` fed by
    /// `egfet::measured_activity` of the materialized survivor.
    fn toggle_ratio(&self, live: &[NodeId], toggles: &[u64]) -> f64 {
        let total: u64 = live.iter().map(|&i| toggles[i as usize]).sum();
        self.activity_of(total, live.len())
    }

    /// The activity division itself, shared by the evaluation-time ratio
    /// above and the warm roll-up ([`Self::warm_survivor_hw`]) so the
    /// two can never drift — warm reports must be bit-identical to what
    /// the evaluation pass computed.
    fn activity_of(&self, toggle_sum: u64, live_cells: usize) -> f64 {
        let n_vec = self.labels.len();
        if n_vec < 2 {
            egfet::NOMINAL_ACTIVITY
        } else if live_cells == 0 {
            0.0
        } else {
            let slots = live_cells as u64 * (n_vec as u64 - 1);
            toggle_sum as f64 / slots as f64
        }
    }

    /// Warm hardware roll-up of a previously evaluated genome:
    /// `(area_cm2, power_mw, delay_ms)` re-derived from the parked
    /// census/toggle state — one `analyze_histogram` call, no synthesis,
    /// no simulation. `None` if the genome was never scored on this
    /// evaluator or was scored through the full-mode path (which parks
    /// no arena census). On measured-objective evaluators the returned
    /// axes are bit-identical to the memoized objectives (pinned by
    /// tests); on FA evaluators this is the only measured view of a
    /// survivor and is what the serve layer annotates warm fronts with.
    pub fn warm_survivor_hw(&self, genome: &BitVec) -> Option<(f64, f64, f64)> {
        let hw = self.memo.get(genome)?.hw?;
        let act = self.activity_of(hw.toggle_sum, hw.live_cells);
        let (area_cm2, power_mw) = egfet::analyze_histogram(&hw.census, &self.lib, act);
        Some((area_cm2, power_mw, hw.delay_ms))
    }

    /// Entries in the memo that carry parked hardware state — the warm
    /// coverage the serve layer reports (`coordinator.designs_synthesized
    /// == 0` on a repeat request requires the survivors it reuses to be
    /// parked here or in the study's design cache).
    pub fn memo_hw_len(&self) -> usize {
        self.memo.count_values(|e| e.hw.is_some())
    }

    /// Roll a census + activity + measured delay up into the objective
    /// vector: one [`egfet::analyze_histogram`] call yields both area
    /// and power, `delay_ms` is the survivor's critical path (callers
    /// pass 0 when the objective has no delay axis — it is never read),
    /// and the configured objective selects which of them fill axes 1..
    /// (all three, for the joint `area+power+delay` mode). The slice
    /// copies keep the packing arity-checked at runtime instead of
    /// indexing past a narrower `M` (the constructor already pins `M`
    /// to the objective).
    fn measured_objs(
        &self,
        loss: f64,
        hist: &CellCounts,
        activity: f64,
        delay_ms: f64,
    ) -> [f64; M] {
        let (area_cm2, power_mw) = egfet::analyze_histogram(hist, &self.lib, activity);
        let mut o = [0.0f64; M];
        o[0] = loss;
        match self.objective {
            CostObjective::Area => o[1..].copy_from_slice(&[area_cm2]),
            CostObjective::Power => o[1..].copy_from_slice(&[power_mw]),
            CostObjective::Delay => o[1..].copy_from_slice(&[delay_ms]),
            CostObjective::AreaPower => o[1..].copy_from_slice(&[area_cm2, power_mw]),
            CostObjective::AreaPowerDelay => {
                o[1..].copy_from_slice(&[area_cm2, power_mw, delay_ms]);
            }
            CostObjective::Fa => unreachable!("measured objectives with FA objective"),
        }
        o
    }

    /// From-scratch scoring: build + optimize the chromosome's netlist
    /// and classify the train set through it (single-threaded:
    /// parallelism is across genomes).
    ///
    /// With the FA objective this path builds the *masked* circuit
    /// ([`build_mlp_circuit`]) — deliberately independent of the template
    /// IR, so full-vs-incremental agreement cross-checks the template.
    /// Measured objectives instead synthesize from scratch through the
    /// shared template (`optimize(template.instantiate(g))` — the
    /// reference the incremental engine is pinned against), because the
    /// cost axis is defined on that survivor; the masked build is only
    /// function-identical, not cell-identical (e.g. dropped biases leave
    /// a folded zero row in the template's CSA trees).
    fn score_full(&self, genome: &BitVec) -> [f64; M] {
        if !self.objective.is_measured() {
            let masks = self.map.to_masks(genome);
            let nl = build_mlp_circuit(
                &self.mlp,
                &MlpCircuitOpts { masks: Some(masks), argmax: ArgmaxMode::Exact },
            );
            let (opt, _) = optimize(&nl);
            let preds = self.batches.classify(&opt, "class", 1);
            return self.objectives(genome, self.accuracy_of(&preds));
        }
        let (opt, _) = optimize(&self.template().instantiate(genome));
        let preds = self.batches.classify(&opt, "class", 1);
        let loss = self.loss_of(self.accuracy_of(&preds));
        // Area ignores the activity factor entirely, so only objectives
        // with a power axis pay the dedicated toggle-activity simulation.
        let activity = if self.objective.needs_activity() && self.labels.len() >= 2 {
            self.batches.toggle_activity(&opt)
        } else {
            egfet::NOMINAL_ACTIVITY
        };
        // Full mode *is* the from-scratch reference the incremental
        // arrival table is pinned against: timing analysis of the
        // freshly synthesized survivor.
        let delay_ms = if self.objective.delay_axis().is_some() {
            egfet::critical_path_ms(&opt, &self.lib)
        } else {
            0.0
        };
        self.measured_objs(loss, &opt.cell_histogram(), activity, delay_ms)
    }
}

/// One evaluation worker of the circuit backend. In incremental mode it
/// leases an [`IncrState`] (arena + wave cache) from the evaluator's
/// pool on first use and parks it back on drop, so states survive across
/// generations without being shared between concurrent workers.
struct CircuitWorker<'a, const M: usize> {
    ev: &'a CircuitEvaluator<M>,
    st: Option<IncrState>,
}

impl<const M: usize> CircuitWorker<'_, M> {
    fn state(&mut self) -> &mut IncrState {
        if self.st.is_none() {
            // Lease a parked state; the lock guard drops before the
            // (expensive) fresh construction below. Poisoning is
            // recovered from, not inherited: the pool Vec is always
            // structurally sound (push/pop only), and inheriting would
            // turn one worker's panic into a cascade across the pool —
            // see the panic-in-worker audit in `util::threads`.
            let parked = self
                .ev
                .incr_pool
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .pop();
            let st = parked.unwrap_or_else(|| {
                telemetry::work(Work::EvalStatesCreated, 1);
                let mut synth = IncrementalSynth::new(self.ev.template().clone());
                synth.set_share_cones(self.ev.share_cones);
                IncrState { synth, wave: self.ev.batches.cache() }
            });
            self.st = Some(st);
        }
        self.st.as_mut().unwrap()
    }
}

impl<const M: usize> EvalWorker<M> for CircuitWorker<'_, M> {
    fn eval_one(&mut self, genome: &BitVec) -> [f64; M] {
        let ev = self.ev;
        if let Some(hit) = ev.memo.get(genome) {
            // Batch dedup means each unique genome is probed once per
            // batch and insertions land at batch boundaries, so hit/miss
            // totals are a pure function of the genome stream — these
            // stay `Counter`s despite living on worker threads.
            telemetry::count(Counter::MemoHits, 1);
            return hit.objs;
        }
        telemetry::count(Counter::MemoMisses, 1);
        let mut parked_hw = None;
        let objs = match ev.mode {
            SynthMode::Full => ev.score_full(genome),
            SynthMode::Incremental => {
                let IncrState { synth, wave } = self.state();
                synth.set_params(genome);
                // Exhaustive verification: re-derive every arena
                // invariant after each instantiation. Read-only, so
                // objectives are untouched; violations are logged and
                // land in `verify.violations`.
                if ev.verify == VerifyMode::EveryGen {
                    report_violations(&verify::verify_arena(synth, Some(ev.map.len())));
                }
                let arena = synth.arena();
                let bus = &arena
                    .outputs
                    .iter()
                    .find(|(name, _)| name == "class")
                    .expect("template has a class output")
                    .1;
                let preds = wave.classify_bus(arena, bus);
                let acc = ev.accuracy_of(&preds);
                // Park the survivor's hardware state next to the
                // objectives whatever the objective mode: the census
                // fell out of `set_params`' survivor walk, the toggle
                // totals out of classification and the delay out of the
                // arena's emit-time arrival table — all by-products of
                // the pass this chromosome already paid for. Warm
                // consumers (serve repeats, front annotation) roll
                // reports up from this without re-synthesis.
                let live = synth.live_cell_ids();
                let toggles = wave.node_toggles();
                let toggle_sum: u64 = live.iter().map(|&i| toggles[i as usize]).sum();
                let hw = HwMemo {
                    census: synth.survivor_histogram().clone(),
                    toggle_sum,
                    live_cells: live.len(),
                    delay_ms: synth.output_delay_ms(),
                };
                let objs = if ev.objective.is_measured() {
                    // The measured axes are a pure roll-up of the parked
                    // state (the joint area+power mode fills both axes
                    // from the same call); the delay axis reads the
                    // parked arrival max.
                    let act = ev.activity_of(hw.toggle_sum, hw.live_cells);
                    let delay_ms = if ev.objective.delay_axis().is_some() {
                        hw.delay_ms
                    } else {
                        0.0
                    };
                    ev.measured_objs(ev.loss_of(acc), &hw.census, act, delay_ms)
                } else {
                    ev.objectives(genome, acc)
                };
                parked_hw = Some(Arc::new(hw));
                objs
            }
        };
        ev.memo.insert(genome.clone(), MemoEntry { objs, hw: parked_hw });
        // Memory backstop: drop (and later re-lease) this worker's state
        // if the arena grew far beyond the template.
        let oversized = self.st.as_ref().is_some_and(|st| {
            st.synth.arena().len()
                > ARENA_GROWTH_LIMIT * st.synth.template().nl.len().max(1)
        });
        if oversized {
            telemetry::work(Work::EvalArenaResets, 1);
            self.st = None;
        }
        objs
    }
}

impl<const M: usize> Drop for CircuitWorker<'_, M> {
    fn drop(&mut self) {
        let Some(mut st) = self.st.take() else { return };
        // A worker unwinding out of its own panic may hold a
        // half-mutated arena (e.g. `set_params` interrupted after the
        // binding was recorded but before the cone was re-simplified);
        // re-parking it would let a later lease diff against the
        // already-updated binding, skip the stale cones, and serve
        // silently wrong fitness. Discard the state instead —
        // correctness over amortization; the next lease pays one
        // from-scratch pass.
        if std::thread::panicking() {
            return;
        }
        // Generation-boundary invariant checkpoint (`--verify
        // boundaries`, also taken under `every-gen`): the worker's arena
        // in its settled end-of-generation state, before the memo flush
        // below touches it.
        if self.ev.verify != VerifyMode::Off {
            report_violations(&verify::verify_arena(&st.synth, Some(self.ev.map.len())));
        }
        // Worker drop is the generation boundary (`evaluate_parallel`
        // creates and drops workers per call), so flush the shared-cone
        // memo here: sharing amortizes *within* a generation, and the
        // flush bounds memo memory without affecting results (hits are
        // exact replays, so flush timing only changes work counters).
        st.synth.flush_shared_cones();
        // Never unwrap in drop: a sibling worker's panic can poison the
        // pool lock while *this* worker exits cleanly, and a panic here
        // during that sibling's unwind would be a double panic — an
        // immediate abort. The pool Vec itself is always structurally
        // sound (push/pop only).
        self.ev
            .incr_pool
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(st);
    }
}

impl<const M: usize> Evaluator<M> for CircuitEvaluator<M> {
    fn worker(&self) -> Box<dyn EvalWorker<M> + '_> {
        Box::new(CircuitWorker { ev: self, st: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;
    use crate::datasets;
    use crate::ga::evaluate_parallel;
    use crate::model::float_mlp::TrainOpts;
    use crate::model::FloatMlp;
    use crate::util::Rng;

    fn tiny_setup() -> (QuantMlp, crate::datasets::QuantDataset, f64) {
        let cfg = builtin::tiny();
        let (split, qtrain, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 20, ..Default::default() });
        let qmlp = QuantMlp::from_float(&mlp, &qtrain);
        let base = qmlp.accuracy(&qtrain, None);
        (qmlp, qtrain, base)
    }

    #[test]
    fn native_evaluator_exact_genome_has_zero_loss() {
        let (qmlp, qtrain, base) = tiny_setup();
        let ev = NativeEvaluator::new(&qmlp, &qtrain, base);
        let exact = ev.map.exact_genome();
        let objs = ev.evaluate(&[exact]);
        assert_eq!(objs.len(), 1);
        assert_eq!(objs[0][0], 0.0);
        assert!(objs[0][1] > 0.0);
    }

    #[test]
    fn native_evaluator_batch_matches_single() {
        let (qmlp, qtrain, base) = tiny_setup();
        let ev = NativeEvaluator::new(&qmlp, &qtrain, base);
        let mut rng = Rng::new(5);
        let genomes: Vec<_> = (0..7).map(|_| ev.map.random_genome(&mut rng, 0.8)).collect();
        let batch = ev.evaluate(&genomes);
        for (i, genome) in genomes.iter().enumerate() {
            let single = ev.evaluate(std::slice::from_ref(genome));
            assert_eq!(batch[i], single[0]);
        }
    }

    #[test]
    fn circuit_evaluator_matches_native_on_tiny() {
        // The gate-level netlists are verified equivalent to the masked
        // integer model, so the circuit evaluator's objectives must equal
        // the native evaluator's on every genome.
        let (qmlp, qtrain, base) = tiny_setup();
        let native = NativeEvaluator::new(&qmlp, &qtrain, base);
        let circuit = CircuitEvaluator::new(&qmlp, &qtrain, base);
        let mut rng = Rng::new(11);
        let mut genomes = vec![native.map.exact_genome()];
        for _ in 0..5 {
            genomes.push(native.map.random_genome(&mut rng, 0.7));
        }
        let a = native.evaluate(&genomes);
        let b = circuit.evaluate(&genomes);
        for (i, (na, ci)) in a.iter().zip(&b).enumerate() {
            assert!(
                (na[0] - ci[0]).abs() < 1e-12,
                "genome {i}: native loss {} vs circuit loss {}",
                na[0],
                ci[0]
            );
            assert_eq!(na[1], ci[1], "genome {i}: area objective differs");
        }
    }

    #[test]
    fn circuit_evaluator_cache_is_stable() {
        let (qmlp, qtrain, base) = tiny_setup();
        let circuit = CircuitEvaluator::new(&qmlp, &qtrain, base);
        let mut rng = Rng::new(3);
        let g = circuit.map.random_genome(&mut rng, 0.6);
        let first = circuit.evaluate(std::slice::from_ref(&g));
        assert_eq!(circuit.memo_len(), 1, "memo must persist across calls");
        let second = circuit.evaluate(std::slice::from_ref(&g));
        assert_eq!(first, second);
        assert_eq!(circuit.memo_len(), 1);
    }

    #[test]
    fn circuit_evaluator_modes_agree() {
        // `--synth full` and `--synth incremental` must yield identical
        // objectives on a GA-like mutation stream (the acceptance
        // criterion's bit-identical requirement, at evaluator level).
        let (qmlp, qtrain, base) = tiny_setup();
        let full = CircuitEvaluator::new(&qmlp, &qtrain, base).with_mode(SynthMode::Full);
        let incr = CircuitEvaluator::new(&qmlp, &qtrain, base);
        assert_eq!(full.mode(), SynthMode::Full);
        assert_eq!(incr.mode(), SynthMode::Incremental);
        let mut rng = Rng::new(17);
        let mut genomes = vec![full.map.exact_genome()];
        let mut g = full.map.random_genome(&mut rng, 0.7);
        genomes.push(g.clone());
        for _ in 0..6 {
            for _ in 0..3 {
                g.flip(rng.below(full.map.len()));
            }
            genomes.push(g.clone());
        }
        let a = full.evaluate(&genomes);
        let b = incr.evaluate(&genomes);
        assert_eq!(a, b, "full and incremental objectives must be identical");
    }

    #[test]
    fn circuit_parallel_matches_serial_both_modes() {
        // Per-worker arenas must not change objectives: 8-way fan-out ==
        // one serial worker, bit for bit, in both synthesis modes. Fresh
        // evaluators per jobs width so the memo cannot mask divergence.
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(29);
        let map = GenomeMap::new(&qmlp);
        let mut genomes = vec![map.exact_genome()];
        let mut g = map.random_genome(&mut rng, 0.75);
        genomes.push(g.clone());
        for _ in 0..10 {
            for _ in 0..2 {
                g.flip(rng.below(map.len()));
            }
            genomes.push(g.clone());
        }
        for mode in [SynthMode::Incremental, SynthMode::Full] {
            let serial_ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_mode(mode);
            let par_ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_mode(mode);
            let serial = evaluate_parallel(&serial_ev, &genomes, 1);
            let parallel = evaluate_parallel(&par_ev, &genomes, 8);
            assert_eq!(serial, parallel, "mode {mode:?}: jobs must not change results");
        }
    }

    /// A GA-like mutation chain starting from the exact genome.
    fn mutation_chain(map: &GenomeMap, rng: &mut Rng, n: usize) -> Vec<BitVec> {
        let mut genomes = vec![map.exact_genome()];
        let mut g = map.random_genome(rng, 0.75);
        genomes.push(g.clone());
        while genomes.len() < n {
            for _ in 0..3 {
                g.flip(rng.below(map.len()));
            }
            genomes.push(g.clone());
        }
        genomes
    }

    #[test]
    fn lane_widths_and_cone_sharing_are_bit_identical() {
        // The tentpole's two switches are pure throughput knobs: every
        // (lane width, cone sharing) combination must produce
        // byte-identical objectives on the same GA-like stream — here
        // against a serial 64-lane sharing-off reference, fanned 4 wide.
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(97);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 10);
        let reference = CircuitEvaluator::new(&qmlp, &qtrain, base)
            .with_lane_width(LaneWidth::W64)
            .with_cone_sharing(false);
        let want = evaluate_parallel(&reference, &genomes, 1);
        for width in [LaneWidth::W64, LaneWidth::W256] {
            for share in [false, true] {
                let ev = CircuitEvaluator::new(&qmlp, &qtrain, base)
                    .with_lane_width(width)
                    .with_cone_sharing(share);
                assert_eq!(ev.lane_width(), width);
                assert_eq!(ev.cone_sharing(), share);
                let got = evaluate_parallel(&ev, &genomes, 4);
                assert_eq!(got, want, "width {width:?} share {share}");
            }
        }
    }

    #[test]
    fn measured_objectives_full_and_incremental_agree() {
        // The measured cost is defined on the template synthesis flow, so
        // from-scratch and cone-local re-synthesis must produce exactly
        // the same [loss, cost] pairs on a mutation chain — for both
        // measured objectives.
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(53);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 8);
        for objective in [CostObjective::Area, CostObjective::Power] {
            let full = CircuitEvaluator::new(&qmlp, &qtrain, base)
                .with_mode(SynthMode::Full)
                .with_objective(objective);
            let incr =
                CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(objective);
            assert_eq!(incr.objective(), objective);
            let a = full.evaluate(&genomes);
            let b = incr.evaluate(&genomes);
            assert_eq!(a, b, "objective {objective:?}: modes must be bit-identical");
        }
    }

    #[test]
    fn measured_cost_equals_fresh_survivor_rollup() {
        // The acceptance pin at evaluator level: the cost of every genome
        // equals `analyze_histogram` of a from-scratch synthesized
        // survivor under wave-measured activity (bit-exact), and matches
        // `egfet::analyze` of that survivor to float summation order.
        use crate::egfet::{analyze, analyze_histogram, measured_activity, Library};
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(61);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 6);
        let vectors: Vec<Vec<bool>> = qtrain
            .x
            .iter()
            .map(|row| wave::encode_features(row, qmlp.l1.in_bits))
            .collect();
        let tpl = build_mlp_template(&qmlp, &ArgmaxMode::Exact);
        let lib = Library::egfet_1v();
        for objective in [CostObjective::Area, CostObjective::Power] {
            let ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(objective);
            let objs = ev.evaluate(&genomes);
            for (genome, o) in genomes.iter().zip(&objs) {
                let (surv, _) = optimize(&tpl.instantiate(genome));
                let act = measured_activity(&surv, &vectors);
                let (area_cm2, power_mw) =
                    analyze_histogram(&surv.cell_histogram(), &lib, act);
                let want = match objective {
                    CostObjective::Area => area_cm2,
                    CostObjective::Power => power_mw,
                    _ => unreachable!(),
                };
                assert_eq!(o[1], want, "{objective:?} cost must be bit-exact");
                let hw = analyze(&surv, &lib, 200.0, act);
                let full = match objective {
                    CostObjective::Area => hw.area_cm2,
                    CostObjective::Power => hw.power_mw,
                    _ => unreachable!(),
                };
                assert!(
                    (o[1] - full).abs() <= 1e-9 * full.max(1.0),
                    "{objective:?}: {} vs analyze {}",
                    o[1],
                    full
                );
            }
        }
    }

    #[test]
    fn measured_parallel_matches_serial() {
        // --jobs determinism with the measured state living in the
        // per-worker arena/cache lease. Fresh evaluators per width so the
        // shared memo cannot mask divergence.
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(67);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 12);
        for mode in [SynthMode::Incremental, SynthMode::Full] {
            let serial_ev = CircuitEvaluator::new(&qmlp, &qtrain, base)
                .with_mode(mode)
                .with_objective(CostObjective::Power);
            let par_ev = CircuitEvaluator::new(&qmlp, &qtrain, base)
                .with_mode(mode)
                .with_objective(CostObjective::Power);
            let serial = evaluate_parallel(&serial_ev, &genomes, 1);
            let parallel = evaluate_parallel(&par_ev, &genomes, 8);
            assert_eq!(serial, parallel, "mode {mode:?}");
        }
    }

    #[test]
    fn joint_objective_modes_agree_and_axes_match_single_runs() {
        // The 3-objective evaluator must (a) be bit-identical between
        // synthesis modes, and (b) score exactly the axes the dedicated
        // single-objective evaluators score: objs == [loss, area-run
        // cost, power-run cost] for every genome — the joint mode is the
        // same roll-up, just not thrown half away.
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(83);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 8);
        let joint_full = CircuitEvaluator::new_joint(&qmlp, &qtrain, base)
            .with_mode(SynthMode::Full);
        let joint_incr = CircuitEvaluator::new_joint(&qmlp, &qtrain, base);
        assert_eq!(joint_incr.objective(), CostObjective::AreaPower);
        let a = joint_full.evaluate(&genomes);
        let b = joint_incr.evaluate(&genomes);
        assert_eq!(a, b, "joint objective: modes must be bit-identical");

        let area_ev =
            CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(CostObjective::Area);
        let power_ev =
            CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(CostObjective::Power);
        let area_objs = area_ev.evaluate(&genomes);
        let power_objs = power_ev.evaluate(&genomes);
        for (k, j) in b.iter().enumerate() {
            assert_eq!(j[0], area_objs[k][0], "genome {k}: loss axis");
            assert_eq!(j[1], area_objs[k][1], "genome {k}: area axis");
            assert_eq!(j[2], power_objs[k][1], "genome {k}: power axis");
        }
    }

    #[test]
    fn joint_parallel_matches_serial() {
        // --jobs determinism at arity 3: the joint census/toggle state
        // rides the same per-worker lease, so any width is bit-identical
        // to serial. Fresh evaluators per width (own memo + pool).
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(89);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 12);
        for mode in [SynthMode::Incremental, SynthMode::Full] {
            let serial_ev =
                CircuitEvaluator::new_joint(&qmlp, &qtrain, base).with_mode(mode);
            let par_ev = CircuitEvaluator::new_joint(&qmlp, &qtrain, base).with_mode(mode);
            let serial = evaluate_parallel(&serial_ev, &genomes, 1);
            let parallel = evaluate_parallel(&par_ev, &genomes, 8);
            assert_eq!(serial, parallel, "mode {mode:?}");
        }
    }

    #[test]
    fn delay_objective_modes_agree_and_pin_to_analyze() {
        // The timing tentpole at evaluator level: the delay axis must be
        // bit-identical between synthesis modes, and equal from-scratch
        // `egfet` timing analysis of the freshly synthesized survivor
        // exactly — both `critical_path_ms` and the `analyze` roll-up.
        use crate::egfet::{analyze, critical_path_ms, Library};
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(101);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 8);
        let full = CircuitEvaluator::new(&qmlp, &qtrain, base)
            .with_mode(SynthMode::Full)
            .with_objective(CostObjective::Delay);
        let incr =
            CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(CostObjective::Delay);
        assert_eq!(incr.objective(), CostObjective::Delay);
        let a = full.evaluate(&genomes);
        let b = incr.evaluate(&genomes);
        assert_eq!(a, b, "delay objective: modes must be bit-identical");

        let tpl = build_mlp_template(&qmlp, &ArgmaxMode::Exact);
        let lib = Library::egfet_1v();
        for (genome, o) in genomes.iter().zip(&b) {
            let (surv, _) = optimize(&tpl.instantiate(genome));
            assert_eq!(o[1], critical_path_ms(&surv, &lib), "delay must be bit-exact");
            assert_eq!(o[1], analyze(&surv, &lib, 200.0, 0.25).delay_ms);
        }
    }

    #[test]
    fn joint_delay_axes_match_single_runs() {
        // The 4-objective evaluator must (a) be bit-identical between
        // synthesis modes and (b) score exactly the axes the 3-D joint
        // and the dedicated delay evaluators score — the 4-D mode is the
        // same census roll-up plus the arrival-table read.
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(103);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 8);
        let full = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base)
            .with_mode(SynthMode::Full);
        let incr = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base);
        assert_eq!(incr.objective(), CostObjective::AreaPowerDelay);
        let a = full.evaluate(&genomes);
        let b = incr.evaluate(&genomes);
        assert_eq!(a, b, "4-D objective: modes must be bit-identical");

        let joint = CircuitEvaluator::new_joint(&qmlp, &qtrain, base);
        let delay_ev =
            CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(CostObjective::Delay);
        let joint_objs = joint.evaluate(&genomes);
        let delay_objs = delay_ev.evaluate(&genomes);
        for (k, j) in b.iter().enumerate() {
            assert_eq!(j[0], joint_objs[k][0], "genome {k}: loss axis");
            assert_eq!(j[1], joint_objs[k][1], "genome {k}: area axis");
            assert_eq!(j[2], joint_objs[k][2], "genome {k}: power axis");
            assert_eq!(j[3], delay_objs[k][1], "genome {k}: delay axis");
        }
    }

    #[test]
    fn joint_delay_parallel_matches_serial() {
        // --jobs determinism at arity 4: the arrival table rides the
        // same per-worker arena lease as the census, so any fan-out
        // width is bit-identical to serial, in both synthesis modes.
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(107);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 12);
        for mode in [SynthMode::Incremental, SynthMode::Full] {
            let serial_ev =
                CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base).with_mode(mode);
            let par_ev =
                CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base).with_mode(mode);
            let serial = evaluate_parallel(&serial_ev, &genomes, 1);
            let parallel = evaluate_parallel(&par_ev, &genomes, 8);
            assert_eq!(serial, parallel, "mode {mode:?}");
        }
    }

    #[test]
    #[should_panic(expected = "objective 'area+power' scores 3 axes")]
    fn joint_objective_rejected_on_two_objective_evaluator() {
        let (qmlp, qtrain, base) = tiny_setup();
        let _ = CircuitEvaluator::new(&qmlp, &qtrain, base)
            .with_objective(CostObjective::AreaPower);
    }

    #[test]
    fn warm_survivor_hw_matches_measured_objectives() {
        // The parked census/toggle/delay state must reproduce the
        // measured axes bit-identically — the warm roll-up IS the
        // evaluation-time roll-up, minus the arena.
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(113);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 8);
        let ev = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base);
        let objs = ev.evaluate(&genomes);
        assert_eq!(
            ev.memo_hw_len(),
            ev.memo_len(),
            "incremental mode parks hw state on every memo entry"
        );
        for (g, o) in genomes.iter().zip(&objs) {
            let (area, power, delay) = ev.warm_survivor_hw(g).expect("parked");
            assert_eq!(area, o[1], "warm area must be bit-identical");
            assert_eq!(power, o[2], "warm power must be bit-identical");
            assert_eq!(delay, o[3], "warm delay must be bit-identical");
        }
    }

    #[test]
    fn warm_survivor_hw_parked_on_fa_runs_and_absent_in_full_mode() {
        use crate::egfet::{analyze_histogram, measured_activity};
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(127);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 6);
        // The FA objective still parks survivor state — warm consumers
        // need measured views of surrogate-scored fronts too...
        let fa = CircuitEvaluator::new(&qmlp, &qtrain, base);
        fa.evaluate(&genomes);
        assert_eq!(fa.memo_hw_len(), fa.memo_len());
        let warm = fa.warm_survivor_hw(&genomes[0]).expect("parked on FA run");
        // ...and the roll-up equals a from-scratch analyze of the
        // template survivor under wave-measured activity.
        let tpl = build_mlp_template(&qmlp, &ArgmaxMode::Exact);
        let (surv, _) = optimize(&tpl.instantiate(&genomes[0]));
        let vectors: Vec<Vec<bool>> = qtrain
            .x
            .iter()
            .map(|row| wave::encode_features(row, qmlp.l1.in_bits))
            .collect();
        let act = measured_activity(&surv, &vectors);
        let (area, power) =
            analyze_histogram(&surv.cell_histogram(), &Library::egfet_1v(), act);
        assert_eq!(warm.0, area, "warm FA-run area must match fresh analysis");
        assert_eq!(warm.1, power, "warm FA-run power must match fresh analysis");
        // Full mode drops its survivor after scoring: nothing parks.
        let full = CircuitEvaluator::new(&qmlp, &qtrain, base).with_mode(SynthMode::Full);
        full.evaluate(&genomes);
        assert_eq!(full.memo_hw_len(), 0);
        assert!(full.warm_survivor_hw(&genomes[0]).is_none());
        // An unseen genome has nothing parked either.
        assert!(fa.warm_survivor_hw(&map.random_genome(&mut rng, 0.5)).is_none());
    }

    #[test]
    fn injected_template_is_shared_and_bit_identical() {
        // `with_template` short-circuits the lazy build (the serve
        // layer's keyed template cache): the handle must be shared, the
        // objectives unchanged.
        let (qmlp, qtrain, base) = tiny_setup();
        let mut rng = Rng::new(131);
        let map = GenomeMap::new(&qmlp);
        let genomes = mutation_chain(&map, &mut rng, 6);
        let lazy = CircuitEvaluator::new(&qmlp, &qtrain, base);
        let want = lazy.evaluate(&genomes);
        let tpl = lazy.template_arc().clone();
        let warm = CircuitEvaluator::new(&qmlp, &qtrain, base).with_template(tpl.clone());
        assert!(
            Arc::ptr_eq(&tpl, warm.template_arc()),
            "injected template must be the shared instance, not a rebuild"
        );
        let got = warm.evaluate(&genomes);
        assert_eq!(got, want, "injected template must not change objectives");
    }

    #[test]
    fn poisoned_worker_pool_recovers() {
        // Deliberately poison the lease pool (as a panicking worker
        // would), then evaluate: leasing must recover instead of
        // cascading the panic, and results stay correct.
        let (qmlp, qtrain, base) = tiny_setup();
        let ev = CircuitEvaluator::new(&qmlp, &qtrain, base)
            .with_objective(CostObjective::Power);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = ev.incr_pool.lock().unwrap();
            panic!("poison the pool");
        }));
        assert!(ev.incr_pool.lock().is_err(), "pool must be poisoned");
        let mut rng = Rng::new(71);
        let genomes: Vec<_> =
            (0..4).map(|_| ev.map.random_genome(&mut rng, 0.8)).collect();
        let a = evaluate_parallel(&ev, &genomes, 3);
        let fresh = CircuitEvaluator::new(&qmlp, &qtrain, base)
            .with_objective(CostObjective::Power);
        let b = evaluate_parallel(&fresh, &genomes, 1);
        assert_eq!(a, b, "poisoned pool must not change results");
    }

    #[test]
    fn incremental_worker_states_park_and_reuse() {
        // After a parallel evaluation the leased arenas return to the
        // pool; a later evaluation leases them again instead of paying
        // fresh from-scratch passes.
        let (qmlp, qtrain, base) = tiny_setup();
        let ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
        let mut rng = Rng::new(41);
        let genomes: Vec<_> = (0..6).map(|_| ev.map.random_genome(&mut rng, 0.8)).collect();
        evaluate_parallel(&ev, &genomes, 3);
        let parked = ev.incr_pool.lock().unwrap().len();
        assert!(
            (1..=3).contains(&parked),
            "expected 1..=3 parked states, got {parked}"
        );
        let more: Vec<_> = (0..4).map(|_| ev.map.random_genome(&mut rng, 0.8)).collect();
        evaluate_parallel(&ev, &more, 3);
        let parked_after = ev.incr_pool.lock().unwrap().len();
        assert!(parked_after <= 3, "pool bounded by max concurrent workers");
    }
}
