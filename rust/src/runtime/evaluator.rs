//! GA chromosome evaluators — the hot path of the framework.
//!
//! Three interchangeable implementations of [`crate::ga::Evaluator`]:
//!
//! * [`PjrtEvaluator`] — the three-layer architecture's path: batches of
//!   chromosomes are packed into mask tensors and dispatched to the
//!   AOT-compiled `masked_acc_<ds>` program (Layer-2 JAX calling the
//!   Layer-1 Pallas masked-MAC kernel) through PJRT. Python is not
//!   involved at run time.
//! * [`NativeEvaluator`] — the pure-Rust integer model, thread-parallel.
//!   Used for cross-checking the PJRT path bit-exactly and as the
//!   fallback when artifacts are absent.
//! * [`CircuitEvaluator`] — circuit-in-the-loop: every chromosome is
//!   synthesized to its bespoke gate-level netlist and the whole
//!   evaluation set is classified through the bit-parallel wave simulator
//!   (`crate::sim::wave`), so the GA's accuracy objective is measured on
//!   the *actual hardware function*, not the integer model. Affordable
//!   because the wave engine advances 64 vectors per pass and, in the
//!   default [`SynthMode::Incremental`], because chromosomes are deltas
//!   against a shared template: synthesis and simulation only revisit
//!   the fanout cones of the flipped mask bits.
//!
//! All return the objective pair `[accuracy_loss, estimated_area]` the
//! NSGA-II optimizer minimizes (paper §III-D1/D2/D3).

use crate::accum::GenomeMap;
use crate::area::AreaModel;
use crate::datasets::QuantDataset;
use crate::ga::Evaluator;
use crate::model::QuantMlp;
use crate::netlist::mlp::{build_mlp_circuit, build_mlp_template, ArgmaxMode, MlpCircuitOpts};
use crate::runtime::{lit_i32, lit_i32_scalar, Executable, Literal, Runtime};
use crate::sim::wave::{self, InputWave, WaveCache};
use crate::synth::incremental::IncrementalSynth;
use crate::synth::{optimize, SynthMode};
use crate::util::{threads, BitVec};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Flattened i32 views of a quantized MLP (what the artifacts consume).
#[derive(Clone, Debug)]
pub struct QuantInts {
    pub w1_sign: Vec<i32>,
    pub w1_shift: Vec<i32>,
    pub b1_val: Vec<i32>,
    pub w2_sign: Vec<i32>,
    pub w2_shift: Vec<i32>,
    pub b2_val: Vec<i32>,
    pub act_shift: i32,
}

impl QuantInts {
    pub fn from_mlp(mlp: &QuantMlp) -> QuantInts {
        let conv = |layer: &crate::model::QuantLayer| {
            let sign: Vec<i32> = layer.w.iter().map(|w| w.sign as i32).collect();
            let shift: Vec<i32> = layer.w.iter().map(|w| w.shift as i32).collect();
            let bias: Vec<i32> = layer.bias.iter().map(|b| b.int_value() as i32).collect();
            (sign, shift, bias)
        };
        let (w1_sign, w1_shift, b1_val) = conv(&mlp.l1);
        let (w2_sign, w2_shift, b2_val) = conv(&mlp.l2);
        QuantInts {
            w1_sign,
            w1_shift,
            b1_val,
            w2_sign,
            w2_shift,
            b2_val,
            act_shift: mlp.act_shift as i32,
        }
    }
}

/// The PJRT-backed evaluator.
pub struct PjrtEvaluator {
    exe: Arc<Executable>,
    /// Population tile of the artifact.
    p: usize,
    n_real: usize,
    mlp: QuantMlp,
    map: GenomeMap,
    area: AreaModel,
    base_acc: f64,
    // Pre-built literals reused across every dispatch.
    fixed_args: Vec<Literal>,
    dims: (usize, usize, usize, usize), // (B, N0, H, O)
}

impl PjrtEvaluator {
    /// Build an evaluator for `name` over the quantized train set.
    ///
    /// `base_acc` is the exact (unmasked) train accuracy the loss is
    /// measured against.
    pub fn new(
        runtime: &Runtime,
        name: &str,
        mlp: &QuantMlp,
        train: &QuantDataset,
        base_acc: f64,
    ) -> Result<PjrtEvaluator> {
        let entry = runtime.entry(name)?.clone();
        anyhow::ensure!(
            entry.n_in == mlp.topo.n_in
                && entry.n_hidden == mlp.topo.n_hidden
                && entry.n_out == mlp.topo.n_out,
            "artifact topology mismatch for '{name}'"
        );
        let b = entry.eval_batch;
        anyhow::ensure!(
            train.n_samples() <= b,
            "train set ({}) exceeds artifact eval batch ({b})",
            train.n_samples()
        );
        let exe = runtime.load(&format!("masked_acc_{name}"))?;
        let (n0, h, o) = (entry.n_in, entry.n_hidden, entry.n_out);

        // Pad inputs to B rows; padding labels are -1 (never correct).
        let mut x_flat = vec![0i32; b * n0];
        let mut labels = vec![-1i32; b];
        for (i, row) in train.x.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                x_flat[i * n0 + j] = v as i32;
            }
            labels[i] = train.y[i] as i32;
        }

        let ints = QuantInts::from_mlp(mlp);
        let fixed_args = vec![
            lit_i32(&x_flat, &[b as i64, n0 as i64])?,
            lit_i32(&labels, &[b as i64])?,
            lit_i32(&ints.w1_sign, &[h as i64, n0 as i64])?,
            lit_i32(&ints.w1_shift, &[h as i64, n0 as i64])?,
            lit_i32(&ints.b1_val, &[h as i64])?,
            // mb1 slot is per-batch (index 5) — placeholder replaced per call.
            lit_i32(&ints.w2_sign, &[o as i64, h as i64])?,
            lit_i32(&ints.w2_shift, &[o as i64, h as i64])?,
            lit_i32(&ints.b2_val, &[o as i64])?,
        ];
        let map = GenomeMap::new(mlp);
        let area = AreaModel::new(&map);
        Ok(PjrtEvaluator {
            exe,
            p: runtime.manifest.p_tile,
            n_real: train.n_samples(),
            mlp: mlp.clone(),
            map,
            area,
            base_acc,
            fixed_args,
            dims: (b, n0, h, o),
        })
    }

    /// The genome map (shared with the coordinator for mask decoding).
    pub fn genome_map(&self) -> &GenomeMap {
        &self.map
    }

    /// Evaluate one tile of up to `p` genomes; returns train accuracies.
    fn eval_tile(&self, genomes: &[&BitVec]) -> Result<Vec<f64>> {
        let (_, n0, h, o) = self.dims;
        let p = self.p;
        assert!(genomes.len() <= p);
        let exact = self.map.exact_genome();
        let mut m1 = vec![0i32; p * h * n0];
        let mut mb1 = vec![0i32; p * h];
        let mut m2 = vec![0i32; p * o * h];
        let mut mb2 = vec![0i32; p * o];
        for pi in 0..p {
            let genome = genomes.get(pi).copied().unwrap_or(&exact);
            let masks = self.map.to_masks(genome);
            for (k, &m) in masks.m1.iter().enumerate() {
                m1[pi * h * n0 + k] = m as i32;
            }
            for (k, &keep) in masks.mb1.iter().enumerate() {
                mb1[pi * h + k] = keep as i32;
            }
            for (k, &m) in masks.m2.iter().enumerate() {
                m2[pi * o * h + k] = m as i32;
            }
            for (k, &keep) in masks.mb2.iter().enumerate() {
                mb2[pi * o + k] = keep as i32;
            }
        }
        // Positional argument order fixed by aot.py::lower_masked_acc.
        let mb1_lit = lit_i32(&mb1, &[p as i64, h as i64])?;
        let mb2_lit = lit_i32(&mb2, &[p as i64, o as i64])?;
        let m1_lit = lit_i32(&m1, &[p as i64, h as i64, n0 as i64])?;
        let m2_lit = lit_i32(&m2, &[p as i64, o as i64, h as i64])?;
        let act_lit = lit_i32_scalar(self.act_shift());
        let f = &self.fixed_args;
        let all: Vec<&Literal> = vec![
            &f[0], &f[1], &f[2], &f[3], &f[4], &mb1_lit, &f[5], &f[6], &f[7], &mb2_lit,
            &m1_lit, &m2_lit, &act_lit,
        ];
        let outs = self.exe.run(&all)?;
        let counts = outs[0].to_vec::<i32>()?;
        Ok(counts
            .iter()
            .take(genomes.len())
            .map(|&c| c as f64 / self.n_real as f64)
            .collect())
    }

    fn act_shift(&self) -> i32 {
        self.mlp.act_shift as i32
    }
}

impl Evaluator for PjrtEvaluator {
    fn evaluate(&self, genomes: &[BitVec]) -> Vec<[f64; 2]> {
        let mut objs = Vec::with_capacity(genomes.len());
        for chunk in genomes.chunks(self.p) {
            let refs: Vec<&BitVec> = chunk.iter().collect();
            let accs = self
                .eval_tile(&refs)
                .expect("PJRT evaluation failed (artifacts stale?)");
            for (genome, acc) in chunk.iter().zip(accs) {
                let loss = (self.base_acc - acc).max(0.0);
                let area = self.area.estimate(genome) as f64;
                objs.push([loss, area]);
            }
        }
        objs
    }
}

/// The pure-Rust evaluator (threaded).
pub struct NativeEvaluator {
    pub mlp: QuantMlp,
    pub train: QuantDataset,
    pub map: GenomeMap,
    pub area: AreaModel,
    pub base_acc: f64,
    pub threads: usize,
}

impl NativeEvaluator {
    pub fn new(mlp: &QuantMlp, train: &QuantDataset, base_acc: f64) -> NativeEvaluator {
        let map = GenomeMap::new(mlp);
        let area = AreaModel::new(&map);
        NativeEvaluator {
            mlp: mlp.clone(),
            train: train.clone(),
            map,
            area,
            base_acc,
            threads: threads::default_threads(),
        }
    }
}

impl Evaluator for NativeEvaluator {
    fn evaluate(&self, genomes: &[BitVec]) -> Vec<[f64; 2]> {
        threads::par_map(genomes.len(), self.threads, |i| {
            let masks = self.map.to_masks(&genomes[i]);
            let acc = self.mlp.accuracy(&self.train, Some(&masks));
            let loss = (self.base_acc - acc).max(0.0);
            let area = self.area.estimate(&genomes[i]) as f64;
            [loss, area]
        })
    }
}

/// Circuit-in-the-loop evaluator: fitness on the synthesized netlist.
///
/// Every chromosome is scored on the *actual gate-level function* the
/// design tapes out with, closing the loop the paper leaves open between
/// the GA's integer surrogate and the hardware. Two synthesis strategies
/// ([`SynthMode`], `--synth` on the CLI) produce bit-identical
/// classifications:
///
/// * [`SynthMode::Full`] — the from-scratch path: per chromosome, build
///   the bespoke circuit ([`build_mlp_circuit`]), run
///   [`crate::synth::optimize`] (the constant sweep that realizes the
///   approximation) and wave-classify the train set, 64 samples per
///   pass; thread-parallel across genomes.
/// * [`SynthMode::Incremental`] — the template path (the default): one
///   parameterized netlist ([`build_mlp_template`], `Param` site `p` =
///   genome bit `p`) is built lazily on first use, then every chromosome
///   is an [`IncrementalSynth::set_params`] delta that re-simplifies
///   only the fanout cones of the flipped mask bits against the
///   persistent structural-hash arena. Simulation rides the same arena
///   through a [`WaveCache`]: a node's lane words are computed once,
///   ever, per train batch, so per-chromosome cost scales with
///   *mutation size* instead of netlist size.
///
/// The area objective stays the FA surrogate of [`AreaModel`] so fronts
/// from all three backends are directly comparable (and the coordinator's
/// exact-genome fallback injects the same units).
///
/// Results are memoized per genome: NSGA-II's crossover/mutation streams
/// revisit identical chromosomes across generations, and each cache hit
/// skips synthesis + simulation entirely.
pub struct CircuitEvaluator {
    pub mlp: QuantMlp,
    pub map: GenomeMap,
    pub area: AreaModel,
    pub base_acc: f64,
    pub threads: usize,
    mode: SynthMode,
    /// Train samples packed once into 64-lane input waves.
    batches: Vec<InputWave>,
    labels: Vec<usize>,
    cache: Mutex<HashMap<BitVec, [f64; 2]>>,
    /// Lazily-built incremental state (template + arena + wave cache);
    /// the engine is a sequential state machine, so incremental batches
    /// are processed under this lock in submission order.
    incr: Mutex<Option<IncrState>>,
}

struct IncrState {
    synth: IncrementalSynth,
    wave: WaveCache,
}

/// Reset the incremental state when the append-only arena (and its
/// per-batch lane-word caches) outgrows the template by this factor.
/// Dedup makes growth decelerate sharply on GA streams, so the cap is a
/// memory backstop for pathologically diverse genome sequences; a reset
/// costs one from-scratch pass on the next batch, and the per-genome
/// memo cache survives it.
const ARENA_GROWTH_LIMIT: usize = 8;

impl CircuitEvaluator {
    /// Defaults to [`SynthMode::Incremental`]; see [`Self::with_mode`].
    pub fn new(mlp: &QuantMlp, train: &QuantDataset, base_acc: f64) -> CircuitEvaluator {
        let map = GenomeMap::new(mlp);
        let area = AreaModel::new(&map);
        let encoded: Vec<Vec<bool>> = train
            .x
            .iter()
            .map(|row| wave::encode_features(row, mlp.l1.in_bits))
            .collect();
        let batches = encoded.chunks(wave::LANES).map(wave::pack_vectors).collect();
        CircuitEvaluator {
            mlp: mlp.clone(),
            map,
            area,
            base_acc,
            threads: threads::default_threads(),
            mode: SynthMode::Incremental,
            batches,
            labels: train.y.clone(),
            cache: Mutex::new(HashMap::new()),
            incr: Mutex::new(None),
        }
    }

    /// Select the synthesis strategy (both are bit-identical in output).
    pub fn with_mode(mut self, mode: SynthMode) -> CircuitEvaluator {
        self.mode = mode;
        self
    }

    pub fn mode(&self) -> SynthMode {
        self.mode
    }

    fn objectives(&self, genome: &BitVec, acc: f64) -> [f64; 2] {
        let loss = (self.base_acc - acc).max(0.0);
        [loss, self.area.estimate(genome) as f64]
    }

    fn accuracy_of(&self, preds: &[u64]) -> f64 {
        let correct = preds
            .iter()
            .zip(&self.labels)
            .filter(|(&p, &y)| p as usize == y)
            .count();
        correct as f64 / self.labels.len().max(1) as f64
    }

    /// From-scratch scoring: build + optimize the chromosome's netlist
    /// and classify the train set through it (single-threaded:
    /// parallelism is across genomes).
    fn score_full(&self, genome: &BitVec) -> [f64; 2] {
        let masks = self.map.to_masks(genome);
        let nl = build_mlp_circuit(
            &self.mlp,
            &MlpCircuitOpts { masks: Some(masks), argmax: ArgmaxMode::Exact },
        );
        let (opt, _) = optimize(&nl);
        let preds = wave::classify(&opt, &self.batches, "class", 1);
        self.objectives(genome, self.accuracy_of(&preds))
    }

    /// Incremental scoring of a deduplicated genome batch, sequential
    /// over the shared template/arena state. The first genome ever seen
    /// pays one from-scratch pass; every later one costs its cone.
    fn score_incremental(&self, uniq: &[&BitVec]) -> Vec<[f64; 2]> {
        let mut guard = self.incr.lock().unwrap();
        let st = guard.get_or_insert_with(|| {
            let tpl = build_mlp_template(&self.mlp, &ArgmaxMode::Exact);
            assert_eq!(
                tpl.n_params,
                self.map.len(),
                "template param sites drifted from the genome map"
            );
            IncrState {
                synth: IncrementalSynth::new(tpl),
                wave: WaveCache::new(self.batches.clone()),
            }
        });
        let IncrState { synth, wave } = st;
        let mut out = Vec::with_capacity(uniq.len());
        for &genome in uniq {
            if let Some(hit) = self.cache.lock().unwrap().get(genome) {
                out.push(*hit);
                continue;
            }
            synth.set_params(genome);
            let arena = synth.arena();
            let bus = &arena
                .outputs
                .iter()
                .find(|(name, _)| name == "class")
                .expect("template has a class output")
                .1;
            let preds = wave.classify_bus(arena, bus);
            let objs = self.objectives(genome, self.accuracy_of(&preds));
            self.cache.lock().unwrap().insert(genome.clone(), objs);
            out.push(objs);
        }
        // Memory backstop: drop (and later rebuild) the state if the
        // arena grew far beyond the template.
        let oversized =
            synth.arena().len() > ARENA_GROWTH_LIMIT * synth.template().nl.len().max(1);
        if oversized {
            *guard = None;
        }
        out
    }
}

impl Evaluator for CircuitEvaluator {
    fn evaluate(&self, genomes: &[BitVec]) -> Vec<[f64; 2]> {
        // Dedup within the batch first: NSGA-II offspring routinely
        // repeat chromosomes, and concurrent workers would otherwise all
        // miss the cache together and each pay a full synthesis.
        let mut uniq: Vec<&BitVec> = Vec::new();
        let mut slot: HashMap<&BitVec, usize> = HashMap::new();
        let mut which = Vec::with_capacity(genomes.len());
        for g in genomes {
            let k = *slot.entry(g).or_insert_with(|| {
                uniq.push(g);
                uniq.len() - 1
            });
            which.push(k);
        }
        let uniq_objs = match self.mode {
            SynthMode::Incremental => self.score_incremental(&uniq),
            SynthMode::Full => threads::par_map(uniq.len(), self.threads, |i| {
                if let Some(hit) = self.cache.lock().unwrap().get(uniq[i]) {
                    return *hit;
                }
                let objs = self.score_full(uniq[i]);
                self.cache.lock().unwrap().insert(uniq[i].clone(), objs);
                objs
            }),
        };
        which.into_iter().map(|k| uniq_objs[k]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;
    use crate::datasets;
    use crate::model::float_mlp::TrainOpts;
    use crate::model::FloatMlp;
    use crate::util::Rng;

    fn tiny_setup() -> (QuantMlp, crate::datasets::QuantDataset, f64) {
        let cfg = builtin::tiny();
        let (split, qtrain, _) = datasets::load(&cfg.dataset);
        let mut mlp = FloatMlp::init(cfg.topology, 1);
        mlp.train(&split.train, &TrainOpts { epochs: 20, ..Default::default() });
        let qmlp = QuantMlp::from_float(&mlp, &qtrain);
        let base = qmlp.accuracy(&qtrain, None);
        (qmlp, qtrain, base)
    }

    #[test]
    fn native_evaluator_exact_genome_has_zero_loss() {
        let (qmlp, qtrain, base) = tiny_setup();
        let ev = NativeEvaluator::new(&qmlp, &qtrain, base);
        let exact = ev.map.exact_genome();
        let objs = ev.evaluate(&[exact]);
        assert_eq!(objs.len(), 1);
        assert_eq!(objs[0][0], 0.0);
        assert!(objs[0][1] > 0.0);
    }

    #[test]
    fn native_evaluator_batch_matches_single() {
        let (qmlp, qtrain, base) = tiny_setup();
        let ev = NativeEvaluator::new(&qmlp, &qtrain, base);
        let mut rng = Rng::new(5);
        let genomes: Vec<_> = (0..7).map(|_| ev.map.random_genome(&mut rng, 0.8)).collect();
        let batch = ev.evaluate(&genomes);
        for (i, genome) in genomes.iter().enumerate() {
            let single = ev.evaluate(std::slice::from_ref(genome));
            assert_eq!(batch[i], single[0]);
        }
    }

    #[test]
    fn circuit_evaluator_matches_native_on_tiny() {
        // The gate-level netlists are verified equivalent to the masked
        // integer model, so the circuit evaluator's objectives must equal
        // the native evaluator's on every genome.
        let (qmlp, qtrain, base) = tiny_setup();
        let native = NativeEvaluator::new(&qmlp, &qtrain, base);
        let circuit = CircuitEvaluator::new(&qmlp, &qtrain, base);
        let mut rng = Rng::new(11);
        let mut genomes = vec![native.map.exact_genome()];
        for _ in 0..5 {
            genomes.push(native.map.random_genome(&mut rng, 0.7));
        }
        let a = native.evaluate(&genomes);
        let b = circuit.evaluate(&genomes);
        for (i, (na, ci)) in a.iter().zip(&b).enumerate() {
            assert!(
                (na[0] - ci[0]).abs() < 1e-12,
                "genome {i}: native loss {} vs circuit loss {}",
                na[0],
                ci[0]
            );
            assert_eq!(na[1], ci[1], "genome {i}: area objective differs");
        }
    }

    #[test]
    fn circuit_evaluator_cache_is_stable() {
        let (qmlp, qtrain, base) = tiny_setup();
        let circuit = CircuitEvaluator::new(&qmlp, &qtrain, base);
        let mut rng = Rng::new(3);
        let g = circuit.map.random_genome(&mut rng, 0.6);
        let first = circuit.evaluate(std::slice::from_ref(&g));
        let second = circuit.evaluate(std::slice::from_ref(&g));
        assert_eq!(first, second);
    }

    #[test]
    fn circuit_evaluator_modes_agree() {
        // `--synth full` and `--synth incremental` must yield identical
        // objectives on a GA-like mutation stream (the acceptance
        // criterion's bit-identical requirement, at evaluator level).
        let (qmlp, qtrain, base) = tiny_setup();
        let full = CircuitEvaluator::new(&qmlp, &qtrain, base).with_mode(SynthMode::Full);
        let incr = CircuitEvaluator::new(&qmlp, &qtrain, base);
        assert_eq!(full.mode(), SynthMode::Full);
        assert_eq!(incr.mode(), SynthMode::Incremental);
        let mut rng = Rng::new(17);
        let mut genomes = vec![full.map.exact_genome()];
        let mut g = full.map.random_genome(&mut rng, 0.7);
        genomes.push(g.clone());
        for _ in 0..6 {
            for _ in 0..3 {
                g.flip(rng.below(full.map.len()));
            }
            genomes.push(g.clone());
        }
        let a = full.evaluate(&genomes);
        let b = incr.evaluate(&genomes);
        assert_eq!(a, b, "full and incremental objectives must be identical");
    }
}
