//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Architecture (DESIGN.md §1): python runs once at build time
//! (`make artifacts`); this module gives the Layer-3 coordinator direct
//! access to the Layer-2/Layer-1 compute graphs through the PJRT C API
//! (`xla` crate). One compiled executable per (program, topology) pair,
//! cached for the lifetime of the runtime.
//!
//! The PJRT bridge is gated behind the `xla` cargo feature: without it
//! (the default offline build) [`Runtime::new`] fails cleanly at run time
//! and the coordinator falls back to the native/circuit evaluators, so
//! every caller compiles unchanged either way.

pub mod evaluator;

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
// detlint: allow-file(std-hash) — artifact manifest/executable cache,
// accessed by dataset-name lookup only; iteration order never matters.
use std::collections::HashMap;
use std::path::Path;

pub use evaluator::{CircuitEvaluator, PjrtEvaluator};
pub use pjrt::{lit_f32, lit_f32_scalar, lit_i32, lit_i32_scalar, Executable, Literal, Runtime};

/// Shape metadata of one topology's artifacts (from `manifest.json`).
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
    /// Padded evaluation-batch size of the `masked_acc` artifact.
    pub eval_batch: usize,
}

/// The artifact manifest written by `aot.py`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub p_tile: usize,
    pub p_pre: usize,
    pub bt: usize,
    pub entries: HashMap<String, ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut entries = HashMap::new();
        if let Some(obj) = j.get("entries").and_then(Json::as_obj) {
            for (name, e) in obj {
                entries.insert(
                    name.clone(),
                    ManifestEntry {
                        n_in: e.usize_or("n_in", 0),
                        n_hidden: e.usize_or("n_hidden", 0),
                        n_out: e.usize_or("n_out", 0),
                        eval_batch: e.usize_or("eval_batch", 0),
                    },
                );
            }
        }
        Ok(Manifest {
            p_tile: j.usize_or("p_tile", 16),
            p_pre: j.usize_or("p_pre", 4),
            bt: j.usize_or("bt", 64),
            entries,
        })
    }
}

/// Default artifacts directory (env `PMLP_ARTIFACTS` or `artifacts/`).
fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var("PMLP_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(feature = "xla")]
mod pjrt {
    //! The real PJRT bridge (requires the `xla` crate).

    use super::{default_artifact_dir, Manifest, ManifestEntry};
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    /// Host-side tensor literal handed to/returned from executables.
    pub type Literal = xla::Literal;

    /// A compiled PJRT executable plus its program name.
    pub struct Executable {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    // SAFETY: a loaded PJRT executable is immutable once compiled, and the
    // PJRT C API specifies execution as thread-safe (the CPU client
    // serializes internally where required); this wrapper adds no interior
    // mutability. Needed so the GA evaluators can be shared across
    // evaluation workers (`ga::Evaluator: Sync`).
    // (One of the crate's two sanctioned `unsafe` sites; the crate root
    // is `#![deny(unsafe_code)]`.)
    #[allow(unsafe_code)]
    unsafe impl Send for Executable {}
    #[allow(unsafe_code)]
    unsafe impl Sync for Executable {}

    impl Executable {
        /// Execute with positional literal arguments; returns the flattened
        /// tuple elements of the (single, tupled) result.
        pub fn run<L: std::borrow::Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Literal>> {
            let bufs = self
                .exe
                .execute::<L>(args)
                .with_context(|| format!("executing {}", self.name))?;
            let lit = bufs[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True.
            Ok(lit.to_tuple()?)
        }
    }

    /// The runtime: a CPU PJRT client + executable cache over an artifacts
    /// directory.
    pub struct Runtime {
        client: xla::PjRtClient,
        pub dir: PathBuf,
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    }

    impl Runtime {
        /// Create a runtime over `dir` (default `artifacts/`).
        pub fn new(dir: &Path) -> Result<Runtime> {
            // Silence the TFRT client's info-level banner on stderr.
            if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
                std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
            }
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu()?;
            Ok(Runtime {
                client,
                dir: dir.to_path_buf(),
                manifest,
                cache: Mutex::new(HashMap::new()),
            })
        }

        /// Default artifacts directory (env `PMLP_ARTIFACTS` or `artifacts/`).
        pub fn default_dir() -> PathBuf {
            default_artifact_dir()
        }

        /// Load + compile (or fetch from cache) an artifact by file stem,
        /// e.g. `masked_acc_tiny`.
        pub fn load(&self, stem: &str) -> Result<std::sync::Arc<Executable>> {
            if let Some(exe) = self.cache.lock().unwrap().get(stem) {
                return Ok(exe.clone());
            }
            let path = self.dir.join(format!("{stem}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .with_context(|| format!("loading {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {stem}"))?;
            let exe = std::sync::Arc::new(Executable { name: stem.to_string(), exe });
            self.cache.lock().unwrap().insert(stem.to_string(), exe.clone());
            Ok(exe)
        }

        pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
            self.manifest
                .entries
                .get(name)
                .ok_or_else(|| anyhow!("topology '{name}' not in artifact manifest"))
        }
    }

    /// Build an i32 literal of the given dimensions (row-major data).
    pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        anyhow::ensure!(expect as usize == data.len(), "lit_i32 shape mismatch");
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// Build an f32 literal of the given dimensions (row-major data).
    pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        anyhow::ensure!(expect as usize == data.len(), "lit_f32 shape mismatch");
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// Scalar literals.
    pub fn lit_i32_scalar(v: i32) -> Literal {
        xla::Literal::scalar(v)
    }
    pub fn lit_f32_scalar(v: f32) -> Literal {
        xla::Literal::scalar(v)
    }
}

#[cfg(not(feature = "xla"))]
mod pjrt {
    //! Stub bridge for builds without the `xla` crate: every constructor
    //! fails at run time with a clear message, so the coordinator's
    //! artifact probing degrades to "no artifacts" and the native/circuit
    //! paths take over. Signatures mirror the real bridge exactly.

    use super::{default_artifact_dir, Manifest, ManifestEntry};
    use anyhow::{bail, Result};
    use std::path::{Path, PathBuf};
    use std::sync::Arc;

    const NO_XLA: &str = "PJRT bridge unavailable: built without the `xla` feature";

    /// Placeholder for `xla::Literal` (never holds data in stub builds).
    pub struct Literal;

    impl Literal {
        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            bail!(NO_XLA)
        }
    }

    /// Placeholder for a compiled PJRT executable.
    pub struct Executable {
        pub name: String,
    }

    impl Executable {
        pub fn run<L: std::borrow::Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Literal>> {
            bail!(NO_XLA)
        }
    }

    /// Stub runtime: construction always fails.
    pub struct Runtime {
        pub dir: PathBuf,
        pub manifest: Manifest,
    }

    impl Runtime {
        pub fn new(_dir: &Path) -> Result<Runtime> {
            bail!(NO_XLA)
        }

        /// Default artifacts directory (env `PMLP_ARTIFACTS` or `artifacts/`).
        pub fn default_dir() -> PathBuf {
            default_artifact_dir()
        }

        pub fn load(&self, _stem: &str) -> Result<Arc<Executable>> {
            bail!(NO_XLA)
        }

        pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
            self.manifest
                .entries
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("topology '{name}' not in artifact manifest"))
        }
    }

    pub fn lit_i32(_data: &[i32], _dims: &[i64]) -> Result<Literal> {
        bail!(NO_XLA)
    }

    pub fn lit_f32(_data: &[f32], _dims: &[i64]) -> Result<Literal> {
        bail!(NO_XLA)
    }

    pub fn lit_i32_scalar(_v: i32) -> Literal {
        Literal
    }

    pub fn lit_f32_scalar(_v: f32) -> Literal {
        Literal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        Runtime::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses_if_present() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&Runtime::default_dir()).unwrap();
        assert!(m.p_tile > 0);
        assert!(!m.entries.is_empty());
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip() {
        let lit = lit_i32(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        let back = lit.to_vec::<i32>().unwrap();
        assert_eq!(back, vec![1, 2, 3, 4, 5, 6]);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_fails_cleanly() {
        let err = Runtime::new(&Runtime::default_dir()).unwrap_err();
        assert!(format!("{err}").contains("xla"));
        assert!(lit_i32(&[1], &[1]).is_err());
    }
}
