//! `detlint` — source-level determinism lint for `rust/src/**`.
//!
//! The crate's determinism contract (jobs-1 == jobs-N, bit-identical
//! reruns) holds by construction: hashed collections in result-affecting
//! paths use `util::fxhash` (fixed keys, fixed iteration order) and
//! wall-clock reads live behind the `util::telemetry` facade. This
//! binary keeps those conventions from eroding. It scans the library
//! source for three patterns:
//!
//! * `std-hash` — `std::collections` hash maps/sets with the default
//!   `RandomState` hasher: per-process iteration order, so a
//!   result-affecting iteration would break bit-determinism;
//! * `wallclock` — monotonic-clock or system-clock reads outside the
//!   telemetry facade: timing must never steer scoring;
//! * `thread-id` — thread-identity reads in library code:
//!   schedule-dependent values must not reach results.
//!
//! Findings are suppressed only by an explicit inline allowlist, so
//! every sanctioned use carries its justification in the source:
//!
//! * `// detlint: allow(<rule>) — <why>` on the offending line, or on a
//!   comment line directly above it, suppresses that one site;
//! * `// detlint: allow-file(<rule>) — <why>` anywhere in a file
//!   suppresses the rule for the whole file (for modules whose job is
//!   the pattern, e.g. the bench harness and wall-clock timing).
//!
//! Two files are exempt structurally rather than by comment:
//! `util/fxhash.rs` (the sanctioned bridge that defines the
//! deterministic aliases) for `std-hash`, and `util/telemetry.rs` (the
//! one timing facade) for `wallclock`.
//!
//! Usage: `detlint [root]`, default root `rust/src`. Output is
//! deterministic (sorted directory walk, in-file line order). Exits 1
//! if any finding survives the allowlist, 0 when the tree is clean.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Scanner configuration. The needle strings are assembled at runtime
/// so this file's own literals never match the patterns it hunts.
struct Rules {
    /// `std-hash` needles (map and set type names).
    std_hash: [String; 2],
    /// `wallclock` needles (monotonic + system clock).
    wallclock: [String; 2],
    /// `thread-id` needle.
    thread_id: String,
    /// Allowlist marker prefix (`detlint: allow`).
    marker: String,
}

impl Rules {
    fn new() -> Rules {
        Rules {
            std_hash: [["Hash", "Map"].concat(), ["Hash", "Set"].concat()],
            wallclock: [["Instant", "::", "now"].concat(), ["System", "Time"].concat()],
            thread_id: ["thread::", "current()", ".id()"].concat(),
            marker: ["detlint", ": ", "allow"].concat(),
        }
    }
}

struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

fn main() -> ExitCode {
    let root = std::env::args().nth(1).unwrap_or_else(|| "rust/src".to_string());
    let rules = Rules::new();
    let mut files = Vec::new();
    collect_rs_files(Path::new(&root), &mut files);
    files.sort();
    if files.is_empty() {
        eprintln!("detlint: no .rs files under '{root}'");
        return ExitCode::from(1);
    }
    let mut findings = Vec::new();
    for path in &files {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("detlint: cannot read {}: {e}", path.display());
                return ExitCode::from(1);
            }
        };
        scan_file(path, &text, &rules, &mut findings);
    }
    if findings.is_empty() {
        println!("detlint: clean ({} files scanned)", files.len());
        return ExitCode::SUCCESS;
    }
    for f in &findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
    }
    println!("detlint: {} finding(s) in {} files scanned", findings.len(), files.len());
    ExitCode::from(1)
}

/// Sorted recursive walk — the lint's own output must be deterministic.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = rd.filter_map(Result::ok).map(|e| e.path()).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// The code portion of a line: everything before a `//` comment. Good
/// enough for a lint — a `//` inside a string literal truncates early,
/// which can only hide a match inside that literal, never invent one.
fn code_of(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Parse every `allow(<rule>)` / `allow-file(<rule>)` marker on a line.
fn parse_allows(line: &str, marker: &str) -> (Vec<String>, Vec<String>) {
    let (mut line_allows, mut file_allows) = (Vec::new(), Vec::new());
    let mut rest = line;
    while let Some(i) = rest.find(marker) {
        rest = &rest[i + marker.len()..];
        let (file_scope, body) = match rest.strip_prefix("-file(") {
            Some(b) => (true, b),
            None => match rest.strip_prefix('(') {
                Some(b) => (false, b),
                None => continue,
            },
        };
        if let Some(end) = body.find(')') {
            let rule = body[..end].to_string();
            if file_scope {
                file_allows.push(rule);
            } else {
                line_allows.push(rule);
            }
        }
    }
    (line_allows, file_allows)
}

fn scan_file(path: &Path, text: &str, rules: &Rules, out: &mut Vec<Finding>) {
    let file = path.to_string_lossy().replace('\\', "/");
    // Structural exemptions: the two facade files whose whole purpose is
    // the pattern in question.
    let exempt_std_hash = file.ends_with("util/fxhash.rs");
    let exempt_wallclock = file.ends_with("util/telemetry.rs");

    // Pass 1: file-scoped allows can sit anywhere.
    let mut file_allows: Vec<String> = Vec::new();
    for line in text.lines() {
        file_allows.extend(parse_allows(line, &rules.marker).1);
    }
    let file_allowed = |rule: &str| file_allows.iter().any(|r| r == rule);

    // Pass 2: scan code lines; `pending` carries line-allows declared on
    // comment-only lines down to the next code line.
    let mut pending: Vec<String> = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        let (line_allows, _) = parse_allows(line, &rules.marker);
        let code = code_of(line);
        if code.trim().is_empty() {
            // Comment-only (or blank) line: stage its allows for the
            // code line below.
            pending.extend(line_allows);
            continue;
        }
        let allowed = |rule: &str| {
            file_allowed(rule)
                || line_allows.iter().any(|r| r == rule)
                || pending.iter().any(|r| r == rule)
        };

        if !exempt_std_hash && !allowed("std-hash") {
            for needle in &rules.std_hash {
                if has_unprefixed(code, needle) {
                    out.push(Finding {
                        file: file.clone(),
                        line: idx + 1,
                        rule: "std-hash",
                        msg: format!(
                            "std {needle} uses the default RandomState hasher \
                             (per-process iteration order) — use util::Fx{needle} \
                             or add a detlint allow comment"
                        ),
                    });
                    break;
                }
            }
        }
        if !exempt_wallclock && !allowed("wallclock") {
            for needle in &rules.wallclock {
                if code.contains(needle.as_str()) {
                    out.push(Finding {
                        file: file.clone(),
                        line: idx + 1,
                        rule: "wallclock",
                        msg: format!(
                            "{needle} outside util::telemetry — wall-clock reads \
                             must stay behind the timing facade"
                        ),
                    });
                    break;
                }
            }
        }
        if !allowed("thread-id") && code.contains(rules.thread_id.as_str()) {
            out.push(Finding {
                file: file.clone(),
                line: idx + 1,
                rule: "thread-id",
                msg: "thread-identity read in library code — schedule-dependent \
                      values must not reach results"
                    .to_string(),
            });
        }
        pending.clear();
    }
}

/// Does `code` contain `needle` not immediately preceded by `Fx` (the
/// deterministic-alias prefix)?
fn has_unprefixed(code: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(needle) {
        let at = start + pos;
        if !code[..at].ends_with("Fx") {
            return true;
        }
        start = at + needle.len();
    }
    false
}
