//! Static verification of the synthesis substrate: structural analyses
//! over [`Template`] netlists and live [`IncrementalSynth`] arenas.
//!
//! Every exactness argument the pipeline leans on — the append-only
//! structural-hash arena behind shared-cone memo hits, the
//! settled-at-emit arrival table behind the delay objective, the
//! param-leaf ↔ genome bijection behind the GA's mask semantics, the
//! cone-group frontier purity behind cross-chromosome sharing, the
//! census the measured objectives consume — is argued in DESIGN.md and
//! pinned by property tests, but nothing checks a *live* state. This
//! module is that checker: a catalog of [`Check`]s that re-derive each
//! invariant from first principles (independent reachability walks,
//! recomputed tables, recomputed adjacency) and report structured
//! [`Violation`]s instead of panicking, so a corrupted state is
//! diagnosable rather than merely fatal.
//!
//! The checks run standalone (`pmlp lint`), at generation boundaries
//! (`--verify boundaries`: once per evaluator worker drop), or after
//! every chromosome instantiation (`--verify every-gen`). Two work
//! stats land in the `pmlp.metrics/1` report: `verify.checks_run` and
//! `verify.violations`. They are scheduling-dependent `Work`, not
//! deterministic `Counter`s — boundary checkpoints fire once per
//! worker, and the worker count follows `--jobs`.
//!
//! Exactness notes (why a clean state reports zero violations):
//!
//! * **acyclic** — `Netlist::push` only ever appends gates whose
//!   operands already exist, so `operand < id` holds for every node;
//!   the arena inherits the invariant because `Rewriter::emit` resolves
//!   operands before pushing.
//! * **csr-fanout** — `Template::new` builds the CSR by
//!   count/prefix-sum/fill over the same gate list the check rescans,
//!   and consumers are filled in ascending consumer order, matching the
//!   check's scan order exactly.
//! * **struct-hash** — `emit` canonicalizes, probes, and inserts under
//!   one key per node, so every hashable arena node (cells and `Param`
//!   leaves; inputs and interned constants bypass the table) maps back
//!   to itself and the table size equals the hashable node count.
//! * **param-bijection / repr** — `Template::new` asserts dense param
//!   indices at construction; `set_params` pins `repr[param_nodes[p]]`
//!   to `Repr::Const(cur[p])` before any consumer is revisited.
//! * **cone-frontier** — `register_cone_group` computes the frontier
//!   from the same gates the check rescans, and group ranges are
//!   asserted ascending/non-overlapping at registration.
//! * **arrival** — arrivals are settled once at emit under the
//!   append-only invariant; the check re-runs the identical recurrence
//!   (same operand order, same `f64::max` fold, same library corner)
//!   so equality is exact, not approximate.
//! * **census** — the stored census is a stamp-based walk from the
//!   arena outputs; the check repeats the walk with its own visited
//!   set and compares sorted live sets, histograms, and totals.

use crate::netlist::{CellCounts, Gate, Netlist, NodeId, Template};
use crate::synth::incremental::IncrementalSynth;
use crate::synth::{canon, Repr};
use crate::util::telemetry::{self, Work};
use std::fmt;

/// When the pipeline runs the invariant verifier
/// (`pmlp run --verify off|boundaries|every-gen`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum VerifyMode {
    /// Never (production default; zero cost on the hot path).
    #[default]
    Off,
    /// Once per evaluator worker at the generation boundary (worker
    /// drop), just before the shared-cone flush.
    Boundaries,
    /// After every chromosome instantiation (`set_params`) — the
    /// exhaustive mode the CI smoke leg runs.
    EveryGen,
}

impl VerifyMode {
    pub fn parse(s: &str) -> Option<VerifyMode> {
        match s.to_lowercase().as_str() {
            "off" | "none" => Some(VerifyMode::Off),
            "boundaries" | "boundary" => Some(VerifyMode::Boundaries),
            "every-gen" | "everygen" | "every" => Some(VerifyMode::EveryGen),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            VerifyMode::Off => "off",
            VerifyMode::Boundaries => "boundaries",
            VerifyMode::EveryGen => "every-gen",
        }
    }
}

/// One invariant breach, as structured diagnostics: the stable check id
/// it tripped, the implicated node ids (template ids for template
/// checks, arena ids for arena checks; capped at eight), and a
/// human-readable explanation of what was expected.
#[derive(Clone, Debug, PartialEq)]
pub struct Violation {
    pub check: &'static str,
    pub nodes: Vec<NodeId>,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.nodes.is_empty() {
            write!(f, "[{}] {}", self.check, self.detail)
        } else {
            write!(f, "[{}] nodes {:?}: {}", self.check, self.nodes, self.detail)
        }
    }
}

/// Cap per-violation node lists so a badly corrupted state stays
/// readable (the detail string carries the full count).
const MAX_NODES: usize = 8;

fn cap_ids(mut ids: Vec<NodeId>) -> Vec<NodeId> {
    ids.truncate(MAX_NODES);
    ids
}

fn fmt_ids(ids: &[NodeId]) -> String {
    if ids.len() <= MAX_NODES {
        format!("{ids:?}")
    } else {
        format!("[{:?}, … {} total]", &ids[..MAX_NODES], ids.len())
    }
}

/// What a check runs against: always a template, plus the live synth
/// state when verifying an arena. `genome_len` is the evaluator's
/// genome width when known (`GenomeMap::len`), used by the bijection
/// check to tie the template to the GA's search space.
pub(crate) struct VerifyCtx<'a> {
    tpl: &'a Template,
    genome_len: Option<usize>,
    synth: Option<&'a IncrementalSynth>,
}

impl VerifyCtx<'_> {
    /// The live synth state, if present *and* instantiated at least
    /// once — arena checks are vacuous before the first `set_params`.
    fn live(&self) -> Option<&IncrementalSynth> {
        self.synth.filter(|s| s.is_ready())
    }
}

/// One structural analysis. `applies` gates on the context (arena
/// checks need a live state); `run` appends violations, never panics —
/// the verifier must survive the states it exists to diagnose, so every
/// index is bounds-guarded.
pub(crate) trait Check {
    fn id(&self) -> &'static str;
    fn applies(&self, cx: &VerifyCtx) -> bool;
    fn run(&self, cx: &VerifyCtx, out: &mut Vec<Violation>);
}

fn all_checks() -> Vec<Box<dyn Check>> {
    vec![
        Box::new(Acyclic),
        Box::new(CsrFanout),
        Box::new(ParamBijection),
        Box::new(ConeFrontier),
        Box::new(StructHash),
        Box::new(Arrival),
        Box::new(Census),
    ]
}

/// Verify a standalone template (no live arena): acyclicity, CSR
/// fanout, param bijection, cone-group frontiers. Returns every
/// violation found; an empty vector is a clean bill.
pub fn verify_template(tpl: &Template, genome_len: Option<usize>) -> Vec<Violation> {
    run_all(&VerifyCtx { tpl, genome_len, synth: None })
}

/// Verify a live incremental-synthesis state: all template checks on
/// its template plus the arena-level analyses (structural-hash
/// soundness, arrival consistency, census cross-check). Before the
/// first `set_params` only the template checks run.
pub fn verify_arena(synth: &IncrementalSynth, genome_len: Option<usize>) -> Vec<Violation> {
    run_all(&VerifyCtx { tpl: synth.template(), genome_len, synth: Some(synth) })
}

fn run_all(cx: &VerifyCtx) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut ran = 0u64;
    for check in all_checks() {
        if check.applies(cx) {
            ran += 1;
            check.run(cx, &mut out);
        }
    }
    telemetry::work(Work::VerifyChecksRun, ran);
    telemetry::work(Work::VerifyViolations, out.len() as u64);
    out
}

// ---------------------------------------------------------------------------
// The checks
// ---------------------------------------------------------------------------

/// Topological soundness: every operand id precedes its gate, every
/// output bit is in bounds — the invariant single-forward-pass
/// simulation, timing, and the worklist's min-heap ordering all assume.
struct Acyclic;

impl Acyclic {
    fn scan(nl: &Netlist, scope: &str, out: &mut Vec<Violation>) {
        for (i, g) in nl.gates.iter().enumerate() {
            for op in g.operands() {
                if op as usize >= i {
                    out.push(Violation {
                        check: "acyclic",
                        nodes: vec![i as NodeId, op],
                        detail: format!(
                            "{scope} node {i} ({g:?}) reads operand {op} >= its own \
                             id — topological order broken (cycle or forward edge)"
                        ),
                    });
                }
            }
        }
        for (name, bus) in &nl.outputs {
            for (k, &b) in bus.iter().enumerate() {
                if b as usize >= nl.gates.len() {
                    out.push(Violation {
                        check: "acyclic",
                        nodes: vec![b],
                        detail: format!(
                            "{scope} output '{name}' bit {k} points at node {b}, \
                             beyond the {}-node gate list",
                            nl.gates.len()
                        ),
                    });
                }
            }
        }
    }
}

impl Check for Acyclic {
    fn id(&self) -> &'static str {
        "acyclic"
    }
    fn applies(&self, _cx: &VerifyCtx) -> bool {
        true
    }
    fn run(&self, cx: &VerifyCtx, out: &mut Vec<Violation>) {
        Acyclic::scan(&cx.tpl.nl, "template", out);
        if let Some(synth) = cx.live() {
            Acyclic::scan(synth.arena(), "arena", out);
        }
    }
}

/// CSR fanout-adjacency consistency: rebuild every node's consumer
/// list from the gate list and require it to match `Template::consumers`
/// exactly — every edge mirrored, no dangling destinations. Cone
/// dirtying walks this adjacency; a bad slot silently truncates or
/// widens dirty cones.
struct CsrFanout;

impl Check for CsrFanout {
    fn id(&self) -> &'static str {
        "csr-fanout"
    }
    fn applies(&self, _cx: &VerifyCtx) -> bool {
        true
    }
    fn run(&self, cx: &VerifyCtx, out: &mut Vec<Violation>) {
        let tpl = cx.tpl;
        let n = tpl.nl.gates.len();
        let mut want: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for (i, g) in tpl.nl.gates.iter().enumerate() {
            for op in g.operands() {
                // Out-of-bounds operands are the acyclic check's case.
                if (op as usize) < n {
                    want[op as usize].push(i as NodeId);
                }
            }
        }
        for (i, want_i) in want.iter().enumerate() {
            let got = tpl.consumers(i as NodeId);
            if got != want_i.as_slice() {
                out.push(Violation {
                    check: "csr-fanout",
                    nodes: cap_ids(
                        std::iter::once(i as NodeId)
                            .chain(got.iter().copied())
                            .chain(want_i.iter().copied())
                            .collect(),
                    ),
                    detail: format!(
                        "node {i}: CSR consumers {} != consumers recomputed from \
                         the gate list {}",
                        fmt_ids(got),
                        fmt_ids(want_i)
                    ),
                });
            }
        }
    }
}

/// Param-leaf ↔ genome bijection: every genome bit addresses exactly
/// one `Param` site and every `Param` gate is genome-addressable. A
/// broken bijection makes GA flips bind the wrong literal — the
/// chromosome no longer means what NSGA-II thinks it means.
struct ParamBijection;

impl Check for ParamBijection {
    fn id(&self) -> &'static str {
        "param-bijection"
    }
    fn applies(&self, _cx: &VerifyCtx) -> bool {
        true
    }
    fn run(&self, cx: &VerifyCtx, out: &mut Vec<Violation>) {
        let tpl = cx.tpl;
        let nl = &tpl.nl;
        if tpl.param_nodes.len() != tpl.n_params {
            out.push(Violation {
                check: "param-bijection",
                nodes: Vec::new(),
                detail: format!(
                    "param_nodes has {} entries for n_params = {}",
                    tpl.param_nodes.len(),
                    tpl.n_params
                ),
            });
        }
        for (p, &pid) in tpl.param_nodes.iter().enumerate() {
            match nl.gates.get(pid as usize) {
                Some(&Gate::Param(q)) if q as usize == p => {}
                other => out.push(Violation {
                    check: "param-bijection",
                    nodes: vec![pid],
                    detail: format!(
                        "genome bit {p} is registered at node {pid}, but that node \
                         is {other:?}, not Param({p}) — the bit binds nothing"
                    ),
                }),
            }
        }
        let mut total = 0usize;
        for (i, g) in nl.gates.iter().enumerate() {
            if let Gate::Param(q) = *g {
                total += 1;
                if q as usize >= tpl.n_params {
                    out.push(Violation {
                        check: "param-bijection",
                        nodes: vec![i as NodeId],
                        detail: format!(
                            "node {i} is Param({q}) but n_params = {} — the site is \
                             not genome-addressable",
                            tpl.n_params
                        ),
                    });
                } else if tpl.param_nodes[q as usize] != i as NodeId {
                    out.push(Violation {
                        check: "param-bijection",
                        nodes: vec![i as NodeId, tpl.param_nodes[q as usize]],
                        detail: format!(
                            "node {i} is Param({q}) but genome bit {q} is registered \
                             at node {} — two sites claim one bit",
                            tpl.param_nodes[q as usize]
                        ),
                    });
                }
            }
        }
        if total != tpl.n_params {
            out.push(Violation {
                check: "param-bijection",
                nodes: Vec::new(),
                detail: format!(
                    "template holds {total} Param gates for {} genome bits",
                    tpl.n_params
                ),
            });
        }
        if let Some(len) = cx.genome_len {
            if len != tpl.n_params {
                out.push(Violation {
                    check: "param-bijection",
                    nodes: Vec::new(),
                    detail: format!(
                        "evaluator genome length {len} != template n_params {}",
                        tpl.n_params
                    ),
                });
            }
        }
    }
}

/// Cone-group frontier soundness: ranges valid, ascending and
/// non-overlapping, the declared param span exactly the `Param` sites
/// inside the node range, and the stored frontier exactly the deduped
/// ascending external operands. The shared-cone memo key is built from
/// the frontier — a stale frontier would alias distinct cones onto one
/// key and serve wrong interiors.
struct ConeFrontier;

impl Check for ConeFrontier {
    fn id(&self) -> &'static str {
        "cone-frontier"
    }
    fn applies(&self, _cx: &VerifyCtx) -> bool {
        true
    }
    fn run(&self, cx: &VerifyCtx, out: &mut Vec<Violation>) {
        let tpl = cx.tpl;
        let n = tpl.nl.gates.len() as NodeId;
        let (mut prev_node, mut prev_param) = (0 as NodeId, 0u32);
        for (gi, grp) in tpl.cone_groups.iter().enumerate() {
            if grp.node_lo > grp.node_hi
                || grp.node_hi > n
                || grp.param_lo > grp.param_hi
                || grp.param_hi as usize > tpl.n_params
            {
                out.push(Violation {
                    check: "cone-frontier",
                    nodes: vec![grp.node_lo, grp.node_hi],
                    detail: format!(
                        "group {gi}: node range {}..{} / param range {}..{} out of \
                         bounds ({} nodes, {} params)",
                        grp.node_lo, grp.node_hi, grp.param_lo, grp.param_hi, n,
                        tpl.n_params
                    ),
                });
                continue;
            }
            if grp.node_lo < prev_node || grp.param_lo < prev_param {
                out.push(Violation {
                    check: "cone-frontier",
                    nodes: vec![grp.node_lo],
                    detail: format!(
                        "group {gi} starts at node {} / param {} inside the previous \
                         group's range (ends {prev_node} / {prev_param})",
                        grp.node_lo, grp.param_lo
                    ),
                });
            }
            prev_node = grp.node_hi;
            prev_param = grp.param_hi;
            let mut frontier: Vec<NodeId> = Vec::new();
            let mut params_in = 0u32;
            for id in grp.node_lo..grp.node_hi {
                let g = &tpl.nl.gates[id as usize];
                if let Gate::Param(p) = *g {
                    if (grp.param_lo..grp.param_hi).contains(&p) {
                        params_in += 1;
                    } else {
                        out.push(Violation {
                            check: "cone-frontier",
                            nodes: vec![id],
                            detail: format!(
                                "group {gi}: Param({p}) at node {id} lies inside the \
                                 node range but outside param range {}..{}",
                                grp.param_lo, grp.param_hi
                            ),
                        });
                    }
                }
                for op in g.operands() {
                    if op < grp.node_lo {
                        frontier.push(op);
                    }
                }
            }
            frontier.sort_unstable();
            frontier.dedup();
            if frontier != grp.frontier {
                out.push(Violation {
                    check: "cone-frontier",
                    nodes: cap_ids(
                        frontier
                            .iter()
                            .chain(grp.frontier.iter())
                            .copied()
                            .collect(),
                    ),
                    detail: format!(
                        "group {gi}: stored frontier {} != recomputed external \
                         operands {} — memo keys would alias distinct cones",
                        fmt_ids(&grp.frontier),
                        fmt_ids(&frontier)
                    ),
                });
            }
            if params_in != grp.param_hi - grp.param_lo {
                out.push(Violation {
                    check: "cone-frontier",
                    nodes: vec![grp.node_lo, grp.node_hi],
                    detail: format!(
                        "group {gi}: node range contains {params_in} of the {} params \
                         the group claims",
                        grp.param_hi - grp.param_lo
                    ),
                });
            }
        }
    }
}

/// Structural-hash-table soundness over the live arena: every hashable
/// node (cells and `Param` leaves) is canonical and maps back to itself
/// through the dedup table, the table holds exactly one entry per
/// hashable node, and the repr table resolves every template node to an
/// in-bounds arena node (or constant) with param leaves pinned to the
/// current binding. Two live nodes sharing a key would break emit-time
/// dedup — the exactness base of shared-cone reuse and arena
/// convergence on revisited bindings.
struct StructHash;

impl Check for StructHash {
    fn id(&self) -> &'static str {
        "struct-hash"
    }
    fn applies(&self, cx: &VerifyCtx) -> bool {
        cx.live().is_some()
    }
    fn run(&self, cx: &VerifyCtx, out: &mut Vec<Violation>) {
        let Some(synth) = cx.live() else { return };
        let rw = synth.rewriter();
        let arena = &rw.out;
        let mut hashable = 0usize;
        for (i, g) in arena.gates.iter().enumerate() {
            if !(g.is_cell() || matches!(g, Gate::Param(_))) {
                continue;
            }
            hashable += 1;
            let key = canon(*g);
            if key != *g {
                out.push(Violation {
                    check: "struct-hash",
                    nodes: vec![i as NodeId],
                    detail: format!(
                        "arena node {i} ({g:?}) is not operand-canonical — emit \
                         always stores canon(g), so probes can never find it"
                    ),
                });
            }
            match rw.dedup.get(&key) {
                Some(&id) if id as usize == i => {}
                Some(&id) => out.push(Violation {
                    check: "struct-hash",
                    nodes: vec![i as NodeId, id],
                    detail: format!(
                        "arena nodes {id} and {i} share the structural key {key:?} — \
                         duplicate live structure defeats dedup"
                    ),
                }),
                None => out.push(Violation {
                    check: "struct-hash",
                    nodes: vec![i as NodeId],
                    detail: format!(
                        "arena node {i} ({g:?}) is missing from the hash table — \
                         a re-emit would duplicate it"
                    ),
                }),
            }
        }
        if rw.dedup.len() != hashable {
            out.push(Violation {
                check: "struct-hash",
                nodes: Vec::new(),
                detail: format!(
                    "hash table holds {} keys but the arena has {hashable} hashable \
                     nodes — stale or duplicate entries",
                    rw.dedup.len()
                ),
            });
        }
        // Repr-table soundness: chains resolve in bounds and terminate
        // (a repr is one hop by construction; "terminates" = the hop
        // lands on a real arena node), params pinned to the binding.
        let tpl = cx.tpl;
        let repr = synth.repr_table();
        if repr.len() != tpl.nl.len() {
            out.push(Violation {
                check: "struct-hash",
                nodes: Vec::new(),
                detail: format!(
                    "repr table covers {} of {} template nodes",
                    repr.len(),
                    tpl.nl.len()
                ),
            });
        }
        for (i, r) in repr.iter().enumerate() {
            if let Repr::Node(id) = *r {
                if id as usize >= arena.len() {
                    out.push(Violation {
                        check: "struct-hash",
                        nodes: vec![i as NodeId, id],
                        detail: format!(
                            "template node {i} resolves to arena node {id}, beyond \
                             the {}-node arena",
                            arena.len()
                        ),
                    });
                }
            }
        }
        let cur = synth.binding();
        for (p, &pid) in tpl.param_nodes.iter().enumerate() {
            if (pid as usize) < repr.len() && p < cur.len() {
                let want = Repr::Const(cur.get(p));
                if repr[pid as usize] != want {
                    out.push(Violation {
                        check: "struct-hash",
                        nodes: vec![pid],
                        detail: format!(
                            "Param({p}) resolves to {:?}, not its bound value {want:?}",
                            repr[pid as usize]
                        ),
                    });
                }
            }
        }
    }
}

/// Arrival-table consistency: the table covers the whole arena, every
/// settled arrival equals the recurrence recomputed from its operands
/// (max over operand arrivals + cell delay — same operand order, same
/// `f64::max` fold, same corner, so equality is exact), and arrivals
/// are monotone along edges. This is the settled-at-emit contract the
/// delay objective reads without recomputation.
struct Arrival;

impl Check for Arrival {
    fn id(&self) -> &'static str {
        "arrival"
    }
    fn applies(&self, cx: &VerifyCtx) -> bool {
        cx.live().is_some()
    }
    fn run(&self, cx: &VerifyCtx, out: &mut Vec<Violation>) {
        let Some(synth) = cx.live() else { return };
        let arena = synth.arena();
        let arr = synth.arrival_table();
        let lib = synth.timing_lib();
        if arr.len() != arena.len() {
            out.push(Violation {
                check: "arrival",
                nodes: Vec::new(),
                detail: format!(
                    "arrival table covers {} of {} arena nodes",
                    arr.len(),
                    arena.len()
                ),
            });
        }
        let n = arr.len().min(arena.len());
        for (i, g) in arena.gates.iter().enumerate().take(n) {
            // Nodes with forward operands are the acyclic check's case;
            // the recurrence below would read unsettled slots.
            if g.operands().any(|op| op as usize >= i) {
                continue;
            }
            let want = match lib.cell(g) {
                None => 0.0,
                Some(cell) => {
                    g.operands().map(|op| arr[op as usize]).fold(0.0f64, f64::max)
                        + cell.delay_ms
                }
            };
            // Exact f64 comparison on purpose: both sides fold the
            // identical max/+ DAG, so any difference is corruption.
            if want != arr[i] {
                out.push(Violation {
                    check: "arrival",
                    nodes: vec![i as NodeId],
                    detail: format!(
                        "arena node {i} ({g:?}) settled arrival {} != {} recomputed \
                         from its operands — the settled-at-emit contract is broken",
                        arr[i], want
                    ),
                });
            }
            if lib.cell(g).is_some() {
                for op in g.operands() {
                    if arr[i] < arr[op as usize] {
                        out.push(Violation {
                            check: "arrival",
                            nodes: vec![op, i as NodeId],
                            detail: format!(
                                "arrival not monotone along edge {op} -> {i}: {} > {}",
                                arr[op as usize], arr[i]
                            ),
                        });
                    }
                }
            }
        }
    }
}

/// Census cross-check: an independent reachability walk from the arena
/// outputs must agree with the stored DCE census — same live cell set,
/// same per-type histogram, and a histogram total equal to the live
/// list length. The measured area/power objectives price exactly this
/// census, so a drifted one mis-costs every chromosome.
struct Census;

impl Check for Census {
    fn id(&self) -> &'static str {
        "census"
    }
    fn applies(&self, cx: &VerifyCtx) -> bool {
        cx.live().is_some()
    }
    fn run(&self, cx: &VerifyCtx, out: &mut Vec<Violation>) {
        let Some(synth) = cx.live() else { return };
        let arena = synth.arena();
        let (hist, live) = synth.census_view();
        let mut seen = vec![false; arena.len()];
        let mut stack: Vec<NodeId> = Vec::new();
        for (_, bus) in &arena.outputs {
            for &b in bus {
                if (b as usize) < seen.len() && !seen[b as usize] {
                    seen[b as usize] = true;
                    stack.push(b);
                }
            }
        }
        let mut walk_hist = CellCounts::default();
        let mut walk_live: Vec<NodeId> = Vec::new();
        while let Some(id) = stack.pop() {
            let g = &arena.gates[id as usize];
            if g.is_cell() {
                walk_hist.add(g);
                walk_live.push(id);
            }
            for op in g.operands() {
                if (op as usize) < seen.len() && !seen[op as usize] {
                    seen[op as usize] = true;
                    stack.push(op);
                }
            }
        }
        let mut stored: Vec<NodeId> = live.to_vec();
        stored.sort_unstable();
        walk_live.sort_unstable();
        if stored != walk_live {
            let diff: Vec<NodeId> = symmetric_diff(&stored, &walk_live);
            out.push(Violation {
                check: "census",
                nodes: cap_ids(diff.clone()),
                detail: format!(
                    "census live set ({} cells) disagrees with an independent \
                     reachability walk ({} cells); differing nodes {}",
                    stored.len(),
                    walk_live.len(),
                    fmt_ids(&diff)
                ),
            });
        }
        if *hist != walk_hist {
            out.push(Violation {
                check: "census",
                nodes: Vec::new(),
                detail: format!(
                    "census histogram {hist:?} != independent walk {walk_hist:?}"
                ),
            });
        }
        if hist.total() != live.len() {
            out.push(Violation {
                check: "census",
                nodes: Vec::new(),
                detail: format!(
                    "census histogram totals {} cells but the live list holds {}",
                    hist.total(),
                    live.len()
                ),
            });
        }
    }
}

/// Elements in exactly one of two sorted, deduped id lists.
fn symmetric_diff(a: &[NodeId], b: &[NodeId]) -> Vec<NodeId> {
    let (mut i, mut j) = (0usize, 0usize);
    let mut out = Vec::new();
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => {
                out.push(a[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push(b[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::BitVec;

    fn grouped_template() -> Template {
        // Two "neurons" over shared inputs plus an ungrouped tail —
        // the same shape build_mlp_template registers.
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let g0_lo = nl.len() as NodeId;
        let p0 = nl.param(0);
        let t0 = nl.and(a, p0);
        let y0 = nl.xor(t0, b);
        let g0_hi = nl.len() as NodeId;
        let p1 = nl.param(1);
        let y1 = nl.mux(p1, y0, a);
        let g1_hi = nl.len() as NodeId;
        let tail = nl.or(y0, y1);
        nl.output("y", vec![y0, y1, tail]);
        let mut tpl = Template::new(nl, 2);
        tpl.register_cone_group(g0_lo, g0_hi, 0, 1);
        tpl.register_cone_group(g0_hi, g1_hi, 1, 2);
        tpl
    }

    #[test]
    fn mode_parse_and_label_round_trip() {
        for mode in [VerifyMode::Off, VerifyMode::Boundaries, VerifyMode::EveryGen] {
            assert_eq!(VerifyMode::parse(mode.label()), Some(mode));
        }
        assert_eq!(VerifyMode::parse("none"), Some(VerifyMode::Off));
        assert_eq!(VerifyMode::parse("boundary"), Some(VerifyMode::Boundaries));
        assert_eq!(VerifyMode::parse("EVERYGEN"), Some(VerifyMode::EveryGen));
        assert_eq!(VerifyMode::parse("bogus"), None);
        assert_eq!(VerifyMode::default(), VerifyMode::Off);
    }

    #[test]
    fn clean_template_has_zero_violations() {
        let tpl = grouped_template();
        let v = verify_template(&tpl, Some(2));
        assert!(v.is_empty(), "clean template flagged: {v:?}");
    }

    #[test]
    fn clean_arena_has_zero_violations_across_flips() {
        let tpl = grouped_template();
        let mut inc = IncrementalSynth::new(tpl);
        inc.set_share_cones(true);
        let mut params = BitVec::zeros(2);
        for flip in [None, Some(0), Some(1), Some(0)] {
            if let Some(p) = flip {
                params.flip(p);
            }
            inc.set_params(&params);
            let v = verify_arena(&inc, Some(2));
            assert!(v.is_empty(), "clean arena flagged after {flip:?}: {v:?}");
        }
    }

    #[test]
    fn unready_synth_runs_template_checks_only() {
        let tpl = grouped_template();
        let inc = IncrementalSynth::new(tpl);
        let before = telemetry::thread_block();
        let v = verify_arena(&inc, Some(2));
        let d = telemetry::thread_block().delta(&before);
        assert!(v.is_empty(), "{v:?}");
        // Arena checks don't apply before the first set_params: only
        // the four template-level analyses run.
        assert_eq!(d.work[Work::VerifyChecksRun as usize], 4);
        assert_eq!(d.work[Work::VerifyViolations as usize], 0);
    }

    #[test]
    fn ready_arena_runs_all_checks_and_counts_work() {
        let tpl = grouped_template();
        let mut inc = IncrementalSynth::new(tpl);
        inc.set_params(&BitVec::zeros(2));
        let before = telemetry::thread_block();
        let v = verify_arena(&inc, Some(2));
        let d = telemetry::thread_block().delta(&before);
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(d.work[Work::VerifyChecksRun as usize], 7);
        assert_eq!(d.work[Work::VerifyViolations as usize], 0);
    }

    #[test]
    fn genome_length_mismatch_is_a_bijection_violation() {
        let tpl = grouped_template();
        let v = verify_template(&tpl, Some(5));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].check, "param-bijection");
        assert!(v[0].detail.contains("genome length 5"), "{}", v[0].detail);
    }

    #[test]
    fn violation_display_is_actionable() {
        let v = Violation {
            check: "arrival",
            nodes: vec![3, 7],
            detail: "example".to_string(),
        };
        assert_eq!(format!("{v}"), "[arrival] nodes [3, 7]: example");
        let v2 = Violation { check: "census", nodes: vec![], detail: "x".into() };
        assert_eq!(format!("{v2}"), "[census] x");
    }

    #[test]
    fn symmetric_diff_merges_both_tails() {
        assert_eq!(symmetric_diff(&[1, 3, 5], &[1, 4, 5, 9]), vec![3, 4, 9]);
        assert_eq!(symmetric_diff(&[], &[2]), vec![2]);
        assert!(symmetric_diff(&[7, 8], &[7, 8]).is_empty());
    }
}
