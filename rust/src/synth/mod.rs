//! Synthesis-lite: the logic-optimization stage that stands in for
//! Synopsys Design Compiler in the paper's flow.
//!
//! The accumulation approximation works by *replacing summand bits with
//! constant zeros* and letting synthesis sweep the constants through the
//! adder trees (paper §III-D: "we fully leverage the IPs and optimization
//! capabilities of the EDA synthesis tool, which among others includes
//! constant propagation"). This module implements exactly that mechanism
//! as a small pass manager over composable [`Pass`]es:
//!
//! * [`ConstProp`] — constant propagation and algebraic simplification
//!   (`x & 0 → 0`, `x ^ 0 → x`, `x & x → x`, `mux(s,a,a) → a`, …);
//! * [`StructHash`] — structural hashing (common-subexpression
//!   elimination over operand-canonicalized gates);
//! * [`Simplify`] — the two fused at node granularity (fold rules see
//!   hashed operands and vice versa), which is strictly stronger than
//!   running them back to back and is the engine the incremental
//!   re-synthesizer ([`incremental`]) shares;
//! * [`Dce`] — dead-gate elimination (only the output cone survives).
//!
//! The public [`optimize`] entry is unchanged: it runs the standard
//! pipeline `[Simplify, Dce]`. The result is functionally equivalent to
//! the input (verified by `crate::sim`-based equivalence tests) and is
//! what the EGFET area/power/timing analysis consumes.
//!
//! All hash tables on the hot path use the std-only Fx hasher
//! (`crate::util::fxhash`): gate keys are tiny fixed-size values, so
//! SipHash's keyed rounds are pure overhead.

pub mod incremental;
pub mod verify;

use crate::netlist::{Gate, Netlist, NodeId};
use crate::util::FxHashMap;

/// What a source node resolved to after rewriting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Repr {
    Node(NodeId),
    Const(bool),
}

/// Optimization statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SynthStats {
    pub cells_in: usize,
    pub cells_out: usize,
}

/// How the circuit-in-the-loop evaluator synthesizes chromosomes
/// (`pmlp run --backend circuit --synth …`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SynthMode {
    /// From-scratch netlist build + [`optimize`] per chromosome.
    Full,
    /// One shared template + [`incremental`] cone-local re-synthesis;
    /// bit-identical classification, cost scales with mutation size.
    Incremental,
}

impl SynthMode {
    pub fn parse(s: &str) -> Option<SynthMode> {
        match s.to_lowercase().as_str() {
            "full" => Some(SynthMode::Full),
            "incremental" | "incr" => Some(SynthMode::Incremental),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SynthMode::Full => "full",
            SynthMode::Incremental => "incremental",
        }
    }
}

/// A composable netlist-to-netlist optimization pass.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, nl: &Netlist) -> Netlist;
}

/// Constant propagation + algebraic simplification (no hashing).
pub struct ConstProp;

/// Structural hashing / CSE only (no constant folding).
pub struct StructHash;

/// Fused constant propagation + structural hashing — the classic
/// "synthesis-lite" rewrite, shared with [`incremental`].
pub struct Simplify;

/// Dead-gate elimination: keep only the output cone (plus all primary
/// inputs, which define the interface).
pub struct Dce;

impl Pass for ConstProp {
    fn name(&self) -> &'static str {
        "const-prop"
    }
    fn run(&self, nl: &Netlist) -> Netlist {
        rewrite_netlist(nl, true, false)
    }
}

impl Pass for StructHash {
    fn name(&self) -> &'static str {
        "struct-hash"
    }
    fn run(&self, nl: &Netlist) -> Netlist {
        rewrite_netlist(nl, false, true)
    }
}

impl Pass for Simplify {
    fn name(&self) -> &'static str {
        "simplify"
    }
    fn run(&self, nl: &Netlist) -> Netlist {
        rewrite_netlist(nl, true, true)
    }
}

impl Pass for Dce {
    fn name(&self) -> &'static str {
        "dce"
    }
    fn run(&self, nl: &Netlist) -> Netlist {
        dce(nl)
    }
}

/// Runs a pass list in order and reports aggregate cell statistics.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    pub fn new(passes: Vec<Box<dyn Pass>>) -> PassManager {
        PassManager { passes }
    }

    /// The default pipeline behind [`optimize`]: fused simplification,
    /// then dead-gate elimination.
    pub fn standard() -> PassManager {
        PassManager::new(vec![Box::new(Simplify), Box::new(Dce)])
    }

    /// Names of the scheduled passes, in order.
    pub fn pass_names(&self) -> Vec<&'static str> {
        self.passes.iter().map(|p| p.name()).collect()
    }

    pub fn run(&self, nl: &Netlist) -> (Netlist, SynthStats) {
        let cells_in = nl.cell_count();
        let mut cur = None;
        for pass in &self.passes {
            let next = pass.run(cur.as_ref().unwrap_or(nl));
            cur = Some(next);
        }
        let out = cur.unwrap_or_else(|| nl.clone());
        let stats = SynthStats { cells_in, cells_out: out.cell_count() };
        (out, stats)
    }
}

/// Optimize a netlist with the standard pipeline (fused constant
/// propagation + structural hashing, then DCE).
pub fn optimize(nl: &Netlist) -> (Netlist, SynthStats) {
    PassManager::standard().run(nl)
}

// ---------------------------------------------------------------------------
// The shared rewriter core
// ---------------------------------------------------------------------------

/// The rewrite engine behind [`ConstProp`], [`StructHash`], [`Simplify`]
/// and the incremental re-synthesizer: maps source gates to
/// representatives in an append-only output arena, optionally applying
/// fold rules (`fold`) and emitting through a structural-hash table
/// (`hash`). The arena is never mutated in place — only appended to —
/// which is what lets the incremental engine keep it (and the per-node
/// lane-word caches of `sim::wave::WaveCache`) alive across
/// instantiations.
pub(crate) struct Rewriter {
    pub(crate) out: Netlist,
    dedup: FxHashMap<Gate, NodeId>,
    consts: [Option<NodeId>; 2],
    input_map: FxHashMap<u32, NodeId>,
    fold: bool,
    hash: bool,
}

impl Rewriter {
    pub(crate) fn new(fold: bool, hash: bool) -> Rewriter {
        Rewriter {
            out: Netlist::new(),
            dedup: FxHashMap::default(),
            consts: [None, None],
            input_map: FxHashMap::default(),
            fold,
            hash,
        }
    }

    /// Pre-create every primary input of `nl` (sorted by input index) so
    /// input node ids are stable and survive DCE/interface-wise.
    pub(crate) fn seed_inputs(&mut self, nl: &Netlist) {
        self.out.n_inputs = nl.n_inputs;
        let mut idxs: Vec<u32> = nl
            .gates
            .iter()
            .filter_map(|g| if let Gate::Input(i) = g { Some(*i) } else { None })
            .collect();
        idxs.sort_unstable();
        idxs.dedup();
        for idx in idxs {
            let id = self.push(Gate::Input(idx));
            self.input_map.insert(idx, id);
        }
    }

    fn push(&mut self, g: Gate) -> NodeId {
        self.out.gates.push(g);
        (self.out.gates.len() - 1) as NodeId
    }

    /// Emit a gate into the arena, deduplicating when hashing is on.
    fn emit(&mut self, g: Gate) -> NodeId {
        debug_assert!(!matches!(g, Gate::Input(_)), "inputs are seeded eagerly");
        let g = canon(g);
        if self.hash {
            if let Some(&id) = self.dedup.get(&g) {
                return id;
            }
        }
        let id = self.push(g);
        if self.hash {
            self.dedup.insert(g, id);
        }
        id
    }

    /// Materialize a constant node in the arena (lazily, one per value).
    pub(crate) fn get_const(&mut self, v: bool) -> NodeId {
        if let Some(id) = self.consts[v as usize] {
            return id;
        }
        let id = self.push(Gate::Const(v));
        self.consts[v as usize] = Some(id);
        id
    }

    /// Rewrite source output buses into the arena through a repr table,
    /// materializing constants where needed. Replaces any previously
    /// resolved outputs — shared by the full passes and the incremental
    /// engine so the two can never diverge on output resolution.
    pub(crate) fn resolve_outputs(
        &mut self,
        outputs: &[(String, Vec<NodeId>)],
        repr: &[Repr],
    ) {
        self.out.outputs.clear();
        for (name, bus) in outputs {
            let new_bus: Vec<NodeId> = bus
                .iter()
                .map(|&n| match repr[n as usize] {
                    Repr::Node(id) => id,
                    Repr::Const(v) => self.get_const(v),
                })
                .collect();
            self.out.outputs.push((name.clone(), new_bus));
        }
    }

    /// Map one source gate to its representative, emitting into the
    /// arena as needed. `r` resolves operand ids to their reprs.
    ///
    /// `Gate::Param` is kept as an opaque (deduplicated) leaf — engines
    /// that bind params to values, like `incremental`, intercept it
    /// before calling here.
    pub(crate) fn rewrite_gate(&mut self, g: &Gate, r: impl Fn(NodeId) -> Repr) -> Repr {
        match *g {
            Gate::Input(idx) => {
                Repr::Node(*self.input_map.get(&idx).expect("input not seeded"))
            }
            Gate::Const(v) => {
                if self.fold {
                    Repr::Const(v)
                } else {
                    Repr::Node(self.get_const(v))
                }
            }
            Gate::Param(p) => Repr::Node(self.emit(Gate::Param(p))),
            Gate::Not(a) => match r(a) {
                Repr::Const(v) => Repr::Const(!v),
                Repr::Node(n) => {
                    // NOT(NOT(x)) -> x
                    if self.fold {
                        if let Gate::Not(inner) = self.out.gates[n as usize] {
                            return Repr::Node(inner);
                        }
                    }
                    Repr::Node(self.emit(Gate::Not(n)))
                }
            },
            Gate::And(a, b) => self.binop(
                r(a),
                r(b),
                BinRules {
                    both: |x, y| x & y,
                    with_true: WithConst::Other,
                    with_false: WithConst::Const(false),
                    same: SameRule::Same,
                    build: Gate::And,
                },
            ),
            Gate::Or(a, b) => self.binop(
                r(a),
                r(b),
                BinRules {
                    both: |x, y| x | y,
                    with_true: WithConst::Const(true),
                    with_false: WithConst::Other,
                    same: SameRule::Same,
                    build: Gate::Or,
                },
            ),
            Gate::Xor(a, b) => self.binop(
                r(a),
                r(b),
                BinRules {
                    both: |x, y| x ^ y,
                    with_true: WithConst::NotOther,
                    with_false: WithConst::Other,
                    same: SameRule::Const(false),
                    build: Gate::Xor,
                },
            ),
            Gate::Nand(a, b) => self.binop(
                r(a),
                r(b),
                BinRules {
                    both: |x, y| !(x & y),
                    with_true: WithConst::NotOther,
                    with_false: WithConst::Const(true),
                    same: SameRule::NotSame,
                    build: Gate::Nand,
                },
            ),
            Gate::Nor(a, b) => self.binop(
                r(a),
                r(b),
                BinRules {
                    both: |x, y| !(x | y),
                    with_true: WithConst::Const(false),
                    with_false: WithConst::NotOther,
                    same: SameRule::NotSame,
                    build: Gate::Nor,
                },
            ),
            Gate::Xnor(a, b) => self.binop(
                r(a),
                r(b),
                BinRules {
                    both: |x, y| !(x ^ y),
                    with_true: WithConst::Other,
                    with_false: WithConst::NotOther,
                    same: SameRule::Const(true),
                    build: Gate::Xnor,
                },
            ),
            Gate::Mux(s, a, b) => self.mux(r(s), r(a), r(b)),
        }
    }

    fn binop(&mut self, ra: Repr, rb: Repr, rules: BinRules) -> Repr {
        if !self.fold {
            // Hash-only mode: reprs are always nodes (constants became
            // arena nodes), so just re-emit through the dedup table.
            let (Repr::Node(x), Repr::Node(y)) = (ra, rb) else {
                unreachable!("const reprs only exist in fold mode")
            };
            return Repr::Node(self.emit((rules.build)(x, y)));
        }
        match (ra, rb) {
            (Repr::Const(x), Repr::Const(y)) => Repr::Const((rules.both)(x, y)),
            (Repr::Const(c), Repr::Node(n)) | (Repr::Node(n), Repr::Const(c)) => {
                let rule = if c { rules.with_true } else { rules.with_false };
                match rule {
                    WithConst::Other => Repr::Node(n),
                    WithConst::NotOther => Repr::Node(self.emit(Gate::Not(n))),
                    WithConst::Const(v) => Repr::Const(v),
                }
            }
            (Repr::Node(x), Repr::Node(y)) => {
                if x == y {
                    match rules.same {
                        SameRule::Same => Repr::Node(x),
                        SameRule::NotSame => Repr::Node(self.emit(Gate::Not(x))),
                        SameRule::Const(v) => Repr::Const(v),
                    }
                } else {
                    Repr::Node(self.emit((rules.build)(x, y)))
                }
            }
        }
    }

    fn mux(&mut self, rs: Repr, ra: Repr, rb: Repr) -> Repr {
        if !self.fold {
            let (Repr::Node(sn), Repr::Node(an), Repr::Node(bn)) = (rs, ra, rb) else {
                unreachable!("const reprs only exist in fold mode")
            };
            return Repr::Node(self.emit(Gate::Mux(sn, an, bn)));
        }
        match (rs, ra, rb) {
            (Repr::Const(false), _, _) => ra,
            (Repr::Const(true), _, _) => rb,
            (_, Repr::Const(x), Repr::Const(y)) if x == y => Repr::Const(x),
            // mux(s, 0, 1) = s ; mux(s, 1, 0) = !s
            (Repr::Node(sn), Repr::Const(false), Repr::Const(true)) => Repr::Node(sn),
            (Repr::Node(sn), Repr::Const(true), Repr::Const(false)) => {
                Repr::Node(self.emit(Gate::Not(sn)))
            }
            // Equal-constant arms are covered by the x == y guard above;
            // rustc cannot see that, so mark unreachable.
            (Repr::Node(_), Repr::Const(_), Repr::Const(_)) => unreachable!(),
            // mux(s, 0, b) = s & b ; mux(s, 1, b) = !s | b
            (Repr::Node(sn), Repr::Const(false), Repr::Node(bn)) => {
                Repr::Node(self.emit(Gate::And(sn, bn)))
            }
            (Repr::Node(sn), Repr::Const(true), Repr::Node(bn)) => {
                let ns = self.emit(Gate::Not(sn));
                Repr::Node(self.emit(Gate::Or(ns, bn)))
            }
            // mux(s, a, 0) = !s & a ; mux(s, a, 1) = s | a
            (Repr::Node(sn), Repr::Node(an), Repr::Const(false)) => {
                let ns = self.emit(Gate::Not(sn));
                Repr::Node(self.emit(Gate::And(ns, an)))
            }
            (Repr::Node(sn), Repr::Node(an), Repr::Const(true)) => {
                Repr::Node(self.emit(Gate::Or(sn, an)))
            }
            (Repr::Node(sn), Repr::Node(an), Repr::Node(bn)) => {
                if an == bn {
                    Repr::Node(an)
                } else {
                    Repr::Node(self.emit(Gate::Mux(sn, an, bn)))
                }
            }
        }
    }
}

/// One full forward rewrite of a netlist (the non-incremental pass body).
fn rewrite_netlist(nl: &Netlist, fold: bool, hash: bool) -> Netlist {
    let mut rw = Rewriter::new(fold, hash);
    rw.seed_inputs(nl);
    let mut repr: Vec<Repr> = Vec::with_capacity(nl.gates.len());
    for g in &nl.gates {
        let r = rw.rewrite_gate(g, |id| repr[id as usize]);
        repr.push(r);
    }
    rw.resolve_outputs(&nl.outputs, &repr);
    rw.out
}

/// How a binary op simplifies against a constant operand.
#[derive(Clone, Copy)]
enum WithConst {
    /// Result is the non-constant operand.
    Other,
    /// Result is NOT of the non-constant operand.
    NotOther,
    /// Result is a constant.
    Const(bool),
}

#[derive(Clone, Copy)]
enum SameRule {
    /// op(x, x) = x
    Same,
    /// op(x, x) = !x
    NotSame,
    /// op(x, x) = const
    Const(bool),
}

struct BinRules {
    both: fn(bool, bool) -> bool,
    with_true: WithConst,
    with_false: WithConst,
    same: SameRule,
    build: fn(NodeId, NodeId) -> Gate,
}

/// Canonicalize commutative gates (sorted operands) for hashing.
fn canon(g: Gate) -> Gate {
    match g {
        Gate::And(a, b) if a > b => Gate::And(b, a),
        Gate::Or(a, b) if a > b => Gate::Or(b, a),
        Gate::Xor(a, b) if a > b => Gate::Xor(b, a),
        Gate::Nand(a, b) if a > b => Gate::Nand(b, a),
        Gate::Nor(a, b) if a > b => Gate::Nor(b, a),
        Gate::Xnor(a, b) if a > b => Gate::Xnor(b, a),
        g => g,
    }
}

/// Dead-code elimination: keep only nodes reachable from outputs (plus
/// all primary inputs, which define the interface).
pub(crate) fn dce(nl: &Netlist) -> Netlist {
    let n = nl.gates.len();
    let mut live = vec![false; n];
    let mut stack: Vec<NodeId> = Vec::new();
    for (_, bus) in &nl.outputs {
        for &b in bus {
            if !live[b as usize] {
                live[b as usize] = true;
                stack.push(b);
            }
        }
    }
    while let Some(id) = stack.pop() {
        for op in nl.gates[id as usize].operands() {
            if !live[op as usize] {
                live[op as usize] = true;
                stack.push(op);
            }
        }
    }
    // Inputs stay (interface stability for the simulator).
    for (i, g) in nl.gates.iter().enumerate() {
        if matches!(g, Gate::Input(_)) {
            live[i] = true;
        }
    }
    let mut remap: Vec<NodeId> = vec![0; n];
    let mut out = Netlist::new();
    out.n_inputs = nl.n_inputs;
    for (i, g) in nl.gates.iter().enumerate() {
        if !live[i] {
            continue;
        }
        let g2 = match *g {
            Gate::Input(idx) => Gate::Input(idx),
            Gate::Const(v) => Gate::Const(v),
            Gate::Param(p) => Gate::Param(p),
            Gate::Not(a) => Gate::Not(remap[a as usize]),
            Gate::And(a, b) => Gate::And(remap[a as usize], remap[b as usize]),
            Gate::Or(a, b) => Gate::Or(remap[a as usize], remap[b as usize]),
            Gate::Xor(a, b) => Gate::Xor(remap[a as usize], remap[b as usize]),
            Gate::Nand(a, b) => Gate::Nand(remap[a as usize], remap[b as usize]),
            Gate::Nor(a, b) => Gate::Nor(remap[a as usize], remap[b as usize]),
            Gate::Xnor(a, b) => Gate::Xnor(remap[a as usize], remap[b as usize]),
            Gate::Mux(s, a, b) => {
                Gate::Mux(remap[s as usize], remap[a as usize], remap[b as usize])
            }
        };
        out.gates.push(g2);
        remap[i] = (out.gates.len() - 1) as NodeId;
    }
    for (name, bus) in &nl.outputs {
        out.outputs
            .push((name.clone(), bus.iter().map(|&b| remap[b as usize]).collect()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::build;
    use crate::sim::{eval, u64_to_bits};
    use crate::util::prop;

    #[test]
    fn constants_propagate_through_and() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let zero = nl.constant(false);
        let g = nl.and(a, zero); // == 0
        let h = nl.or(g, a); // == a
        nl.output("y", vec![h]);
        let (opt, stats) = optimize(&nl);
        assert_eq!(stats.cells_out, 0, "everything should fold to a wire");
        assert_eq!(eval(&opt, &[true])["y"][0], true);
        assert_eq!(eval(&opt, &[false])["y"][0], false);
    }

    #[test]
    fn structural_hashing_merges_duplicates() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let g1 = nl.and(a, b);
        let g2 = nl.and(b, a); // same gate, swapped operands
        let y = nl.xor(g1, g2); // x ^ x = 0
        nl.output("y", vec![y]);
        let (opt, _) = optimize(&nl);
        assert_eq!(opt.cell_count(), 0);
        assert_eq!(eval(&opt, &[true, true])["y"][0], false);
    }

    #[test]
    fn double_negation_removed() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let n1 = nl.not(a);
        let n2 = nl.not(n1);
        nl.output("y", vec![n2]);
        let (opt, _) = optimize(&nl);
        assert_eq!(opt.cell_count(), 0);
        assert_eq!(eval(&opt, &[true])["y"][0], true);
    }

    #[test]
    fn dce_removes_unused_logic() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let _unused = nl.xor(a, b);
        let used = nl.and(a, b);
        nl.output("y", vec![used]);
        let (opt, _) = optimize(&nl);
        assert_eq!(opt.cell_count(), 1);
    }

    #[test]
    fn mux_simplifications() {
        let mut nl = Netlist::new();
        let s = nl.input();
        let a = nl.input();
        let zero = nl.constant(false);
        let one = nl.constant(true);
        let m1 = nl.mux(s, zero, one); // = s
        let m2 = nl.mux(s, a, a); // = a
        let m3 = nl.mux(zero, a, one); // = a
        nl.output("y", vec![m1, m2, m3]);
        let (opt, _) = optimize(&nl);
        assert_eq!(opt.cell_count(), 0);
        let out = &eval(&opt, &[true, false])["y"];
        assert_eq!(out.as_slice(), &[true, false, false]);
    }

    #[test]
    fn prop_optimize_preserves_function() {
        // Random adder circuits with some constant inputs: the optimized
        // netlist must compute the same function.
        prop::check("synth preserves semantics", |rng, _| {
            let w = 4u32;
            let mut nl = Netlist::new();
            let a = nl.input_bus(w);
            let kconst = rng.below(16) as u64;
            let kb = build::const_bus(&mut nl, kconst, w);
            let s = build::adder(&mut nl, &a, &kb);
            let m = build::const_mul(&mut nl, &s, rng.below(8) as u64 + 1);
            nl.output("m", m);
            let (opt, stats) = optimize(&nl);
            if stats.cells_out > stats.cells_in {
                return Err("synthesis grew the circuit".to_string());
            }
            for _ in 0..8 {
                let x = rng.below(1 << w) as u64;
                let bits = u64_to_bits(x, w);
                let o1 = &eval(&nl, &bits)["m"];
                let o2 = &eval(&opt, &bits)["m"];
                if crate::sim::bus_to_u64(o1) != crate::sim::bus_to_u64(o2) {
                    return Err(format!("mismatch at x={x} k={kconst}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn masked_zero_bits_shrink_adder_tree() {
        // The paper's core mechanism: replacing summand bits by constant
        // zero must shrink the synthesized adder tree.
        let w = 4u32;
        let build_tree = |mask: u64| -> usize {
            let mut nl = Netlist::new();
            let mut summands = Vec::new();
            for _ in 0..4 {
                let bus = nl.input_bus(w);
                let masked: Vec<_> = bus
                    .iter()
                    .enumerate()
                    .map(|(i, &bit)| {
                        if (mask >> i) & 1 == 1 {
                            bit
                        } else {
                            nl.constant(false)
                        }
                    })
                    .collect();
                summands.push(masked);
            }
            let s = build::csa_tree(&mut nl, &summands);
            nl.output("s", s);
            let (opt, _) = optimize(&nl);
            opt.cell_count()
        };
        let full = build_tree(0xF);
        let half = build_tree(0b0110);
        let none = build_tree(0x0);
        assert!(half < full, "half {half} vs full {full}");
        assert_eq!(none, 0);
    }

    #[test]
    fn standard_pipeline_names() {
        assert_eq!(PassManager::standard().pass_names(), vec!["simplify", "dce"]);
    }

    #[test]
    fn const_prop_alone_folds_but_keeps_duplicates() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let zero = nl.constant(false);
        let dead = nl.and(a, zero); // folds to const 0
        let g1 = nl.and(a, b);
        let g2 = nl.and(b, a); // duplicate of g1 — const-prop keeps it
        let y = nl.or(g1, g2);
        let z = nl.or(dead, y); // == y
        nl.output("y", vec![z]);
        let out = ConstProp.run(&nl);
        // g1, g2 and the or survive; the masked AND folded away.
        assert_eq!(out.cell_count(), 3);
        assert_eq!(eval(&out, &[true, true])["y"][0], true);
        assert_eq!(eval(&out, &[true, false])["y"][0], false);
    }

    #[test]
    fn struct_hash_alone_merges_but_keeps_constants() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let g1 = nl.and(a, b);
        let g2 = nl.and(b, a); // merges with g1
        let zero = nl.constant(false);
        let dead = nl.or(g1, zero); // hashing alone cannot fold this
        let y = nl.xor(g2, dead);
        nl.output("y", vec![y]);
        let out = StructHash.run(&nl);
        // and (merged), or-with-const, xor: 3 cells, no folding.
        assert_eq!(out.cell_count(), 3);
        for bits in [[false, false], [true, false], [true, true]] {
            assert_eq!(eval(&out, &bits)["y"][0], eval(&nl, &bits)["y"][0]);
        }
    }

    #[test]
    fn prop_custom_pipelines_preserve_function() {
        // Any composition of the passes must be semantics-preserving.
        prop::check("pass pipelines preserve semantics", |rng, _| {
            let w = 3u32;
            let mut nl = Netlist::new();
            let a = nl.input_bus(w);
            let kb = build::const_bus(&mut nl, rng.below(8) as u64, w);
            let s = build::adder(&mut nl, &a, &kb);
            nl.output("s", s);
            let pm = match rng.below(3) {
                0 => PassManager::new(vec![Box::new(ConstProp), Box::new(Dce)]),
                1 => PassManager::new(vec![
                    Box::new(ConstProp),
                    Box::new(StructHash),
                    Box::new(Dce),
                ]),
                _ => PassManager::new(vec![Box::new(StructHash), Box::new(Simplify)]),
            };
            let (out, _) = pm.run(&nl);
            for _ in 0..8 {
                let x = rng.below(1 << w) as u64;
                let bits = u64_to_bits(x, w);
                let o1 = crate::sim::bus_to_u64(&eval(&nl, &bits)["s"]);
                let o2 = crate::sim::bus_to_u64(&eval(&out, &bits)["s"]);
                if o1 != o2 {
                    return Err(format!("pipeline mismatch at x={x}: {o1} != {o2}"));
                }
            }
            Ok(())
        });
    }
}
