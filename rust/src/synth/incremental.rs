//! Incremental cone-local re-synthesis over a [`Template`].
//!
//! The circuit-in-the-loop GA evaluates thousands of chromosomes that
//! differ from their parents in a handful of mask bits, yet from-scratch
//! synthesis pays the full netlist-sized rewrite for each one. This
//! engine exploits the template form: since every chromosome binds the
//! same fixed gate graph and only the `Param` literal values change, the
//! simplification result can only change inside the *fanout cones* of
//! the flipped literals.
//!
//! Mechanics ([`IncrementalSynth`]):
//!
//! * a persistent [`Rewriter`] arena (fused const-prop + structural
//!   hashing) accumulates every survivor gate ever emitted; the arena is
//!   append-only, so node ids — and any lane-word caches keyed on them
//!   (`sim::wave::WaveCache`) — stay valid across instantiations;
//! * a per-template-node `Repr` table remembers what each source node
//!   resolved to under the current parameter binding;
//! * a per-*arena*-node **arrival table** is kept in lockstep with the
//!   arena for timing closure: because the arena is append-only and a
//!   node's operands are immutable after creation, its longest-path
//!   arrival under the evaluation corner
//!   ([`crate::egfet::Library::egfet_1v`], the same corner the
//!   measured objectives use) is computed exactly once — when the node
//!   is first emitted — and stays exact forever. The measured
//!   critical-path delay of the current binding is then just a max over
//!   the arena's live output arrivals
//!   ([`IncrementalSynth::output_delay_ms`]), bit-identical to
//!   from-scratch [`crate::egfet::analyze`] on the survivor: `f64::max`
//!   over non-negative arrivals is order-insensitive, and DCE preserves
//!   operand order, so both sides fold the same `max`/`+` DAG. A cone
//!   re-synthesis that *shortens* the critical path needs no downstream
//!   "un-propagation": the shortened cone's reprs resolve to different
//!   (or pre-existing) arena nodes whose arrivals were settled at emit
//!   time, and the delay max is re-taken over the re-resolved outputs
//!   on every binding. Shared-cone memo hits carry settled arrivals for
//!   free, structurally: a snapshot's reprs point at arena nodes whose
//!   arrivals are already tabled;
//! * on a parameter delta, a min-heap worklist walks the dirty cone in
//!   ascending node id (= topological) order, recomputing reprs and
//!   stopping early where a node's repr converges to its old value —
//!   work scales with *mutation size*, not netlist size;
//! * outputs are re-resolved through the repr table, and the survivor
//!   netlist (or just its live-cell count) falls out of a hash-free DCE
//!   walk over the arena;
//! * optionally ([`IncrementalSynth::set_share_cones`]), a
//!   generation-scoped *shared-cone memo* lets structurally-identical
//!   cones be reused across sibling chromosomes: when the dirty walk
//!   reaches a template [`crate::netlist::ConeGroup`] whose key —
//!   (group id, the group's param binding, the representatives of its
//!   frontier nodes) — was already synthesized this generation, the
//!   memoized interior representatives are copied in verbatim and the
//!   group's worklist entries are discarded. This is exact, not
//!   approximate: given identical frontier reprs and binding, a re-walk
//!   would re-derive exactly the memoized reprs, because the arena's
//!   structural-hash dedup is deterministic and append-only — every
//!   `emit` probe would land on the nodes the first synthesis created.
//!   For the same reason the memo only changes *work*, never results,
//!   so jobs-1 == jobs-N determinism is preserved no matter how genomes
//!   are scheduled; flushing at generation boundaries
//!   ([`IncrementalSynth::flush_shared_cones`], called from the
//!   evaluator's worker `Drop`) merely bounds memo memory and keeps
//!   entries from outliving arena resets.
//!
//! Invariants, pinned by the property suite below:
//!
//! 1. after every `set_params`, the arena output cone computes the same
//!    function as `optimize(template.instantiate(params))`, and
//! 2. `SynthStats::cells_out` matches the from-scratch pass exactly —
//!    the incremental survivor is the same netlist up to node
//!    renumbering (dedup makes both sides emit one node per distinct
//!    canonical structure, and repr convergence never skips a node whose
//!    inputs changed).

use crate::egfet::Library;
use crate::netlist::{CellCounts, Gate, Netlist, NodeId, Template};
use crate::synth::{dce, Repr, Rewriter, SynthStats};
use crate::util::telemetry::{self, Counter, Work};
use crate::util::{BitVec, FxHashMap};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Encode a representative for a shared-cone memo key. Offset node ids
/// past the two constants so the encoding is injective.
#[inline]
fn encode_repr(r: Repr) -> u64 {
    match r {
        Repr::Const(b) => b as u64,
        Repr::Node(id) => 2 + id as u64,
    }
}

/// Build the memo key of cone group `gi` under the binding `cur` and
/// the settled representatives `repr`: `[group id, packed group param
/// bits ..., encoded frontier reprs ...]`. Per group the key length is
/// fixed, and the leading group id separates groups, so distinct
/// (group, binding, frontier) triples never collide.
fn cone_key(tpl: &Template, cur: &BitVec, repr: &[Repr], gi: usize) -> Vec<u64> {
    let g = &tpl.cone_groups[gi];
    let n_params = (g.param_hi - g.param_lo) as usize;
    let mut key = Vec::with_capacity(1 + n_params.div_ceil(64) + g.frontier.len());
    key.push(gi as u64);
    let mut word = 0u64;
    for (k, p) in (g.param_lo..g.param_hi).enumerate() {
        if cur.get(p as usize) {
            word |= 1u64 << (k % 64);
        }
        if k % 64 == 63 {
            key.push(word);
            word = 0;
        }
    }
    if n_params % 64 != 0 {
        key.push(word);
    }
    for &f in &g.frontier {
        key.push(encode_repr(repr[f as usize]));
    }
    key
}

/// Persistent incremental re-synthesizer for one template.
pub struct IncrementalSynth {
    tpl: Template,
    rw: Rewriter,
    /// Representative of each template node under `cur`.
    repr: Vec<Repr>,
    /// Arrival time (ms) of each *arena* node under `lib`, indexed by
    /// arena node id. Append-only in lockstep with the arena: a node's
    /// operands are immutable, so its longest-path arrival is computed
    /// once at emit time and never revisited (module docs).
    arrival: Vec<f64>,
    /// Timing corner for the arrival table. Must match the corner the
    /// evaluator's measured objectives use (`Library::egfet_1v`) so the
    /// delay axis agrees bit-exactly with `egfet::analyze`.
    lib: Library,
    /// Current parameter binding (valid once `ready`).
    cur: BitVec,
    ready: bool,
    /// Worklist de-dup stamps, one slot per template node.
    dirty_stamp: Vec<u32>,
    stamp: u32,
    /// Scratch stamps for live-cone walks over the arena.
    live_stamp: Vec<u32>,
    live_mark: u32,
    /// Survivor census of the current binding, refreshed by the same
    /// hash-free DCE walk that produces `cells_out` (no extra passes):
    /// per-cell-type counts plus the live cell node ids — what the
    /// measured-hardware objectives consume (`egfet::analyze_histogram`
    /// + per-node toggle sums over `sim::wave::WaveCache`).
    hist: CellCounts,
    live_cells: Vec<NodeId>,
    /// Cross-chromosome shared-cone memo (see the module docs): key per
    /// [`cone_key`], value = the group's interior reprs
    /// (`repr[node_lo..node_hi]`) under that key. Generation-scoped —
    /// the evaluator flushes it at worker drop; it also never outlives
    /// an arena reset, because resets drop the whole synth state.
    cone_memo: FxHashMap<Vec<u64>, Vec<Repr>>,
    share_cones: bool,
}

impl IncrementalSynth {
    pub fn new(tpl: Template) -> IncrementalSynth {
        let mut rw = Rewriter::new(true, true);
        rw.seed_inputs(&tpl.nl);
        let n = tpl.nl.len();
        IncrementalSynth {
            rw,
            repr: Vec::with_capacity(n),
            arrival: Vec::new(),
            lib: Library::egfet_1v(),
            cur: BitVec::zeros(tpl.n_params),
            ready: false,
            dirty_stamp: vec![0; n],
            stamp: 0,
            live_stamp: Vec::new(),
            live_mark: 0,
            hist: CellCounts::default(),
            live_cells: Vec::new(),
            cone_memo: FxHashMap::default(),
            share_cones: false,
            tpl,
        }
    }

    /// Enable/disable the cross-chromosome shared-cone memo (default
    /// off — sharing only pays when sibling chromosomes are evaluated
    /// through one synth state, i.e. inside `ga::evaluate_parallel`
    /// workers). Disabling flushes.
    pub fn set_share_cones(&mut self, on: bool) {
        self.share_cones = on;
        if !on {
            self.cone_memo.clear();
        }
    }

    /// Drop every shared-cone memo entry. The evaluator calls this at
    /// generation boundaries (its workers are created and dropped per
    /// `evaluate_parallel` call), which bounds memo memory per
    /// generation. Results are unaffected by *when* this is called —
    /// memo reuse is exact (module docs) — so flushing cannot perturb
    /// the jobs-1 == jobs-N contract.
    pub fn flush_shared_cones(&mut self) {
        self.cone_memo.clear();
    }

    /// Entries currently memoized (diagnostics/tests).
    pub fn shared_cone_entries(&self) -> usize {
        self.cone_memo.len()
    }

    pub fn template(&self) -> &Template {
        &self.tpl
    }

    /// The persistent arena. Append-only across instantiations; its
    /// `outputs` reflect the most recent `set_params` binding.
    pub fn arena(&self) -> &Netlist {
        &self.rw.out
    }

    // --- verifier access (`synth::verify`) -------------------------------
    // Read-only views of the internal state the invariant checks
    // re-derive independently. Crate-internal: the verifier is the only
    // consumer, and exposing these publicly would freeze representation
    // details (stamp arrays, the raw rewriter) into the API.

    pub(crate) fn rewriter(&self) -> &Rewriter {
        &self.rw
    }

    pub(crate) fn repr_table(&self) -> &[Repr] {
        &self.repr
    }

    pub(crate) fn arrival_table(&self) -> &[f64] {
        &self.arrival
    }

    pub(crate) fn binding(&self) -> &BitVec {
        &self.cur
    }

    pub(crate) fn timing_lib(&self) -> &Library {
        &self.lib
    }

    pub(crate) fn census_view(&self) -> (&CellCounts, &[NodeId]) {
        (&self.hist, &self.live_cells)
    }

    /// Whether `set_params` has run at least once (arena checks are
    /// vacuous before that).
    pub(crate) fn is_ready(&self) -> bool {
        self.ready
    }

    // --- corruption-injection hooks (tests only) -------------------------
    // `#[doc(hidden)]` escape hatches for the verifier's
    // corruption-injection suite (`rust/tests/verify_lint.rs`): each one
    // breaks exactly one invariant so the suite can assert that exactly
    // the intended check fires. Not part of the API.

    /// Append a copy of hashable arena node `id` without registering it
    /// in the dedup table — two live nodes then share one structural
    /// key. The copy's arrival is computed correctly and it stays
    /// unreachable from the outputs, so only the struct-hash check
    /// trips. Returns the duplicate's id.
    #[doc(hidden)]
    pub fn corrupt_duplicate_node(&mut self, id: NodeId) -> NodeId {
        let g = self.rw.out.gates[id as usize];
        assert!(
            g.is_cell() || matches!(g, Gate::Param(_)),
            "corrupt_duplicate_node needs a hashable node, got {g:?}"
        );
        self.rw.out.gates.push(g);
        let t = match self.lib.cell(&g) {
            None => 0.0,
            Some(cell) => {
                g.operands().map(|o| self.arrival[o as usize]).fold(0.0f64, f64::max)
                    + cell.delay_ms
            }
        };
        self.arrival.push(t);
        (self.rw.out.gates.len() - 1) as NodeId
    }

    /// Overwrite arena node `id`'s settled arrival with `t_ms`,
    /// returning the true value — a stale-arrival seed for the arrival
    /// consistency check.
    #[doc(hidden)]
    pub fn corrupt_arrival(&mut self, id: NodeId, t_ms: f64) -> f64 {
        std::mem::replace(&mut self.arrival[id as usize], t_ms)
    }

    /// Drop the last entry of the census live-cell list (histogram left
    /// untouched) — a census-drift seed for the cross-check. Returns the
    /// dropped arena node id.
    #[doc(hidden)]
    pub fn corrupt_census_drop_live(&mut self) -> Option<NodeId> {
        self.live_cells.pop()
    }

    /// Bind the parameters to `params` and re-simplify. The first call
    /// is a full from-scratch pass; subsequent calls revisit only the
    /// fanout cones of the flipped literals. Returns survivor stats.
    pub fn set_params(&mut self, params: &BitVec) -> SynthStats {
        assert_eq!(params.len(), self.tpl.n_params, "param count mismatch");
        telemetry::count(Counter::SynthSetParams, 1);
        if !self.ready {
            self.cur = params.clone();
            self.full_pass();
            self.ready = true;
        } else {
            let flipped: Vec<NodeId> = (0..self.tpl.n_params)
                .filter(|&p| params.get(p) != self.cur.get(p))
                .map(|p| self.tpl.param_nodes[p])
                .collect();
            self.cur = params.clone();
            self.cone_pass(&flipped);
        }
        self.refresh_outputs();
        self.sync_arrivals();
        self.census();
        // Mutation-site micro-checks (debug builds only): the side
        // tables must leave every set_params in lockstep with the arena
        // and template — the cheap prefix of what `synth::verify`
        // re-derives in full at checkpoints.
        debug_assert_eq!(
            self.arrival.len(),
            self.rw.out.len(),
            "arrival table out of lockstep with the arena"
        );
        debug_assert_eq!(
            self.repr.len(),
            self.tpl.nl.len(),
            "repr table out of lockstep with the template"
        );
        SynthStats { cells_in: self.tpl.nl.cell_count(), cells_out: self.live_cells.len() }
    }

    /// Per-cell-type counts of the current survivor — exactly
    /// `dce(arena).cell_histogram()` (pinned by the property suite),
    /// without materializing the netlist. Valid after `set_params`.
    pub fn survivor_histogram(&self) -> &CellCounts {
        debug_assert!(self.ready, "set_params before survivor_histogram");
        &self.hist
    }

    /// Arena node ids of the current survivor's cells (the live output
    /// cone, cells only; deterministic walk order, not sorted). Aligned
    /// with any arena-keyed side table — `sim::wave::WaveCache::node_toggles`,
    /// which is how the evaluator sums survivor toggle activity without
    /// re-simulating. Valid after `set_params`.
    pub fn live_cell_ids(&self) -> &[NodeId] {
        debug_assert!(self.ready, "set_params before live_cell_ids");
        &self.live_cells
    }

    /// Arrival time (ms) of arena node `id` under the evaluation
    /// corner. Exact for every node ever emitted, not just live ones
    /// (module docs: arrivals are settled at emit time, forever).
    pub fn arena_arrival(&self, id: NodeId) -> f64 {
        self.arrival[id as usize]
    }

    /// Measured critical-path delay (ms) of the current survivor: the
    /// max arrival over the arena's output bits. Bit-identical to
    /// `egfet::critical_path_ms` on the DCE'd survivor — and therefore
    /// to `egfet::analyze(..).delay_ms` — because DCE preserves operand
    /// order and both sides fold the same `max`/`+` DAG (module docs).
    /// This is the delay axis of `--objective area+power+delay`. Valid
    /// after `set_params`.
    pub fn output_delay_ms(&self) -> f64 {
        debug_assert!(self.ready, "set_params before output_delay_ms");
        self.rw
            .out
            .outputs
            .iter()
            .flat_map(|(_, bus)| bus.iter())
            .map(|&n| self.arrival[n as usize])
            .fold(0.0f64, f64::max)
    }

    /// Materialize the compact survivor netlist of the current binding
    /// (DCE over the arena's live cone) — the same netlist, up to node
    /// renumbering, as `optimize(template.instantiate(params))`.
    pub fn survivor(&self) -> (Netlist, SynthStats) {
        assert!(self.ready, "set_params before survivor");
        let out = dce(&self.rw.out);
        let stats =
            SynthStats { cells_in: self.tpl.nl.cell_count(), cells_out: out.cell_count() };
        (out, stats)
    }

    fn full_pass(&mut self) {
        // Whether a binding needs a full pass depends on whether this
        // worker's state has served before — scheduling-dependent `Work`.
        telemetry::work(Work::SynthFullPasses, 1);
        let IncrementalSynth { tpl, rw, repr, cur, .. } = self;
        repr.clear();
        for g in &tpl.nl.gates {
            let r = match *g {
                Gate::Param(p) => Repr::Const(cur.get(p as usize)),
                _ => rw.rewrite_gate(g, |id| repr[id as usize]),
            };
            repr.push(r);
        }
        if self.share_cones {
            // Seed the memo with this binding's groups: the commonest
            // sibling pattern is a child flipping one group back to its
            // parent's binding while mutating another.
            for gi in 0..self.tpl.cone_groups.len() {
                let key = cone_key(&self.tpl, &self.cur, &self.repr, gi);
                let g = &self.tpl.cone_groups[gi];
                self.cone_memo
                    .insert(key, self.repr[g.node_lo as usize..g.node_hi as usize].to_vec());
            }
        }
    }

    /// Recompute reprs over the fanout cones of `flipped` param nodes.
    /// The min-heap pops in ascending node id order, which by the
    /// topological invariant means every operand repr is final when a
    /// node is recomputed; a node whose repr converges to its old value
    /// does not dirty its consumers.
    ///
    /// With cone sharing on, the walk is partitioned by the template's
    /// cone groups: the heap is drained up to each dirty group's range,
    /// the group's memo key is probed (its frontier reprs are final at
    /// that point — every frontier node precedes the range), and on a
    /// hit the group's worklist entries are discarded in favor of the
    /// memoized reprs (exact; see the module docs). The walk itself,
    /// hit or miss, still settles nodes in ascending order, so results
    /// are identical to the unshared pass.
    fn cone_pass(&mut self, flipped: &[NodeId]) {
        if flipped.is_empty() {
            return;
        }
        self.stamp += 1;
        let stamp = self.stamp;
        let IncrementalSynth {
            tpl, rw, repr, cur, dirty_stamp, cone_memo, share_cones, ..
        } = self;
        let mut heap: BinaryHeap<Reverse<NodeId>> =
            BinaryHeap::with_capacity(flipped.len() * 4);
        for &id in flipped {
            if dirty_stamp[id as usize] != stamp {
                dirty_stamp[id as usize] = stamp;
                heap.push(Reverse(id));
            }
        }
        let (mut pops, mut rewrites) = (0u64, 0u64);

        /// Settle one popped node: recompute its repr; on change, dirty
        /// its consumers (the legacy worklist body, shared by every
        /// drain below).
        fn settle_one(
            tpl: &Template,
            rw: &mut Rewriter,
            repr: &mut [Repr],
            cur: &BitVec,
            dirty_stamp: &mut [u32],
            stamp: u32,
            heap: &mut BinaryHeap<Reverse<NodeId>>,
            id: NodeId,
            pops: &mut u64,
            rewrites: &mut u64,
        ) {
            *pops += 1;
            let g = &tpl.nl.gates[id as usize];
            let new = match *g {
                Gate::Param(p) => Repr::Const(cur.get(p as usize)),
                _ => rw.rewrite_gate(g, |i| repr[i as usize]),
            };
            if new != repr[id as usize] {
                *rewrites += 1;
                repr[id as usize] = new;
                for &c in tpl.consumers(id) {
                    if dirty_stamp[c as usize] != stamp {
                        dirty_stamp[c as usize] = stamp;
                        heap.push(Reverse(c));
                    }
                }
            }
        }

        if *share_cones && !tpl.cone_groups.is_empty() {
            let (mut hits, mut misses) = (0u64, 0u64);
            for gi in 0..tpl.cone_groups.len() {
                let (node_lo, node_hi) =
                    (tpl.cone_groups[gi].node_lo, tpl.cone_groups[gi].node_hi);
                // Settle everything upstream of the group so its
                // frontier reprs are final before the key is built.
                while let Some(&Reverse(id)) = heap.peek() {
                    if id >= node_lo {
                        break;
                    }
                    heap.pop();
                    settle_one(
                        tpl, rw, repr, cur, dirty_stamp, stamp, &mut heap, id, &mut pops,
                        &mut rewrites,
                    );
                }
                match heap.peek() {
                    Some(&Reverse(id)) if id < node_hi => {}
                    _ => continue, // group untouched by this delta
                }
                let key = cone_key(tpl, cur, repr, gi);
                if let Some(snapshot) = cone_memo.get(&key) {
                    hits += 1;
                    // Discard the group's worklist entries: a
                    // structurally-identical sibling already settled
                    // this (binding, frontier) — copy its reprs in and
                    // dirty only consumers *outside* the group (the
                    // interior is final by construction).
                    while let Some(&Reverse(id)) = heap.peek() {
                        if id >= node_hi {
                            break;
                        }
                        heap.pop();
                    }
                    for (off, id) in (node_lo..node_hi).enumerate() {
                        let new = snapshot[off];
                        if new != repr[id as usize] {
                            repr[id as usize] = new;
                            for &c in tpl.consumers(id) {
                                if c >= node_hi && dirty_stamp[c as usize] != stamp {
                                    dirty_stamp[c as usize] = stamp;
                                    heap.push(Reverse(c));
                                }
                            }
                        }
                    }
                } else {
                    misses += 1;
                    while let Some(&Reverse(id)) = heap.peek() {
                        if id >= node_hi {
                            break;
                        }
                        heap.pop();
                        settle_one(
                            tpl, rw, repr, cur, dirty_stamp, stamp, &mut heap, id,
                            &mut pops, &mut rewrites,
                        );
                    }
                    cone_memo
                        .insert(key, repr[node_lo as usize..node_hi as usize].to_vec());
                }
            }
            // Tail past the last group (e.g. the argmax tree).
            while let Some(Reverse(id)) = heap.pop() {
                settle_one(
                    tpl, rw, repr, cur, dirty_stamp, stamp, &mut heap, id, &mut pops,
                    &mut rewrites,
                );
            }
            telemetry::work(Work::SynthSharedConeHits, hits);
            telemetry::work(Work::SynthSharedConeMisses, misses);
        } else {
            while let Some(Reverse(id)) = heap.pop() {
                settle_one(
                    tpl, rw, repr, cur, dirty_stamp, stamp, &mut heap, id, &mut pops,
                    &mut rewrites,
                );
            }
        }
        // Cone shape depends on the worker state's previous binding, so
        // these are scheduling-dependent `Work` stats. One flush per pass
        // keeps the worklist loop itself telemetry-free.
        telemetry::work(Work::SynthConePasses, 1);
        telemetry::work(Work::SynthConeNodes, pops);
        telemetry::work(Work::SynthRewrites, rewrites);
        telemetry::work(Work::SynthConvergencePrunes, pops - rewrites);
        telemetry::cone_size(pops as usize);
    }

    fn refresh_outputs(&mut self) {
        let IncrementalSynth { tpl, rw, repr, .. } = self;
        rw.resolve_outputs(&tpl.nl.outputs, repr);
    }

    /// Extend the arrival table over arena nodes emitted since the last
    /// call. Runs after output resolution (which may intern constant
    /// nodes) so the table always covers the whole arena. Ascending
    /// index order is topological — the arena is append-only, so every
    /// operand of node `i` has id `< i` and its arrival is already
    /// settled. Same recurrence as `egfet::arrival_times`: cells take
    /// the operand max plus the cell delay, non-cells are 0.
    fn sync_arrivals(&mut self) {
        let IncrementalSynth { rw, arrival, lib, .. } = self;
        let arena = &rw.out;
        let lo = arrival.len();
        if lo == arena.len() {
            return;
        }
        arrival.reserve(arena.len() - lo);
        for g in &arena.gates[lo..] {
            let t = match lib.cell(g) {
                None => 0.0,
                Some(cell) => {
                    g.operands().map(|o| arrival[o as usize]).fold(0.0f64, f64::max)
                        + cell.delay_ms
                }
            };
            arrival.push(t);
        }
        telemetry::work(Work::SynthArrivalRecomputes, (arena.len() - lo) as u64);
    }

    /// Census of the current output cone: live cell ids and per-type
    /// counts (the `cells_out` + `cell_histogram` a from-scratch DCE
    /// would report) without materializing the netlist. One hash-free
    /// walk; the stamp array and the live list are reused buffers, so
    /// steady-state re-synthesis stays allocation-free.
    fn census(&mut self) {
        let IncrementalSynth { rw, live_stamp, live_mark, hist, live_cells, .. } = self;
        let arena = &rw.out;
        *live_mark += 1;
        let mark = *live_mark;
        live_stamp.resize(arena.len(), 0);
        *hist = CellCounts::default();
        live_cells.clear();
        let mut stack: Vec<NodeId> = Vec::new();
        for (_, bus) in &arena.outputs {
            for &b in bus {
                if live_stamp[b as usize] != mark {
                    live_stamp[b as usize] = mark;
                    stack.push(b);
                }
            }
        }
        while let Some(id) = stack.pop() {
            let g = &arena.gates[id as usize];
            if g.is_cell() {
                hist.add(g);
                live_cells.push(id);
            }
            for op in g.operands() {
                if live_stamp[op as usize] != mark {
                    live_stamp[op as usize] = mark;
                    stack.push(op);
                }
            }
        }
        debug_assert_eq!(
            hist.total(),
            live_cells.len(),
            "census histogram out of lockstep with the live-cell list"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egfet;
    use crate::sim::wave::{eval_wave, lane_bus_u64, pack_vectors, InputWave, LANES};
    use crate::synth::optimize;
    use crate::util::{prop, Rng};

    /// Pin the arena's timing view against a from-scratch pass: the
    /// delay axis must equal `egfet::critical_path_ms` on the fresh
    /// survivor bit-exactly, and every output bit's arena arrival must
    /// equal the fresh survivor's arrival at the corresponding output
    /// position (output buses correspond 1:1 in declaration order).
    fn check_arrivals(inc: &IncrementalSynth, fresh: &Netlist) -> Result<(), String> {
        let lib = Library::egfet_1v();
        let want = egfet::critical_path_ms(fresh, &lib);
        let got = inc.output_delay_ms();
        if got != want {
            return Err(format!("delay {got} (incremental) != {want} (from-scratch)"));
        }
        let fresh_arr = egfet::arrival_times(fresh, &lib);
        for (oi, (name, busf)) in fresh.outputs.iter().enumerate() {
            let busa = &inc.arena().outputs[oi].1;
            for (k, (&nf, &na)) in busf.iter().zip(busa.iter()).enumerate() {
                let (wf, wa) = (fresh_arr[nf as usize], inc.arena_arrival(na));
                if wa != wf {
                    return Err(format!(
                        "output '{name}' bit {k}: arrival {wa} (arena) != {wf} (fresh)"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Random topologically-valid template: inputs, a dense block of
    /// params, optional constants, then a random gate soup over all of
    /// them, with a few declared outputs.
    fn random_template(rng: &mut Rng) -> Template {
        let mut nl = Netlist::new();
        let n_in = 1 + rng.below(4);
        for _ in 0..n_in {
            nl.input();
        }
        let n_params = 1 + rng.below(8);
        for p in 0..n_params as u32 {
            nl.param(p);
        }
        if rng.chance(0.5) {
            nl.constant(rng.chance(0.5));
        }
        let n_gates = 5 + rng.below(60);
        for _ in 0..n_gates {
            let len = nl.len();
            let pick = |r: &mut Rng| r.below(len) as NodeId;
            let (a, b) = (pick(rng), pick(rng));
            match rng.below(9) {
                0 => nl.not(a),
                1 => nl.and(a, b),
                2 => nl.or(a, b),
                3 => nl.xor(a, b),
                4 => nl.nand(a, b),
                5 => nl.nor(a, b),
                6 => nl.xnor(a, b),
                7 => nl.constant(rng.chance(0.5)),
                _ => {
                    let s = pick(rng);
                    nl.mux(s, a, b)
                }
            };
        }
        let len = nl.len();
        for k in 0..1 + rng.below(3) {
            let bus: Vec<NodeId> =
                (0..1 + rng.below(4)).map(|_| rng.below(len) as NodeId).collect();
            nl.output(&format!("y{k}"), bus);
        }
        Template::new(nl, n_params)
    }

    fn random_batch(rng: &mut Rng, n_inputs: u32, n_vec: usize) -> InputWave {
        let vectors: Vec<Vec<bool>> = (0..n_vec)
            .map(|_| (0..n_inputs).map(|_| rng.chance(0.5)).collect())
            .collect();
        pack_vectors(&vectors)
    }

    /// Compare every output bus of `fresh` (from-scratch) against both
    /// incremental views (survivor + arena), lane by lane.
    fn check_equiv(
        inc: &IncrementalSynth,
        fresh: &Netlist,
        batch: &InputWave,
    ) -> Result<(), String> {
        let (surv, _) = inc.survivor();
        let vf = eval_wave(fresh, batch);
        let vs = eval_wave(&surv, batch);
        let va = eval_wave(inc.arena(), batch);
        for (oi, (name, busf)) in fresh.outputs.iter().enumerate() {
            let buss = &surv.outputs[oi].1;
            let busa = &inc.arena().outputs[oi].1;
            for lane in 0..batch.n_lanes {
                let want = lane_bus_u64(&vf, busf, lane);
                let got_s = lane_bus_u64(&vs, buss, lane);
                let got_a = lane_bus_u64(&va, busa, lane);
                if got_s != want || got_a != want {
                    return Err(format!(
                        "output '{name}' lane {lane}: fresh {want}, survivor {got_s}, arena {got_a}"
                    ));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn prop_incremental_matches_from_scratch() {
        // The tentpole invariant: across random mask-flip sequences on
        // random templates, the incremental engine's output cone is
        // function-identical (wave-simulated, lane by lane) to
        // from-scratch `optimize`, with matching `cells_out`.
        prop::check("incremental == from-scratch synth", |rng, _| {
            let tpl = random_template(rng);
            let n_params = tpl.n_params;
            let mut params = prop::gen::bits(rng, n_params, 0.5);
            let mut inc = IncrementalSynth::new(tpl.clone());
            let n_vec = (8 + rng.below(56)).min(LANES);
            let batch = random_batch(rng, tpl.nl.n_inputs, n_vec);
            for step in 0..6 {
                if step > 0 {
                    let flips = 1 + rng.below(n_params);
                    for _ in 0..flips {
                        params.flip(rng.below(n_params));
                    }
                }
                let stats_inc = inc.set_params(&params);
                let (fresh, stats_fresh) = optimize(&tpl.instantiate(&params));
                if stats_inc.cells_out != stats_fresh.cells_out {
                    return Err(format!(
                        "step {step}: cells_out {} (incremental) != {} (from-scratch)",
                        stats_inc.cells_out, stats_fresh.cells_out
                    ));
                }
                let (surv, sstats) = inc.survivor();
                if sstats != stats_fresh {
                    return Err(format!(
                        "step {step}: survivor stats {sstats:?} != fresh {stats_fresh:?}"
                    ));
                }
                // The measured-objective census: per-type counts must
                // match a from-scratch DCE'd census exactly (both the
                // materialized survivor's and the fresh pass's — the
                // survivor is the same netlist up to renumbering), and
                // the live-cell list must agree with `cells_out`.
                let hist = *inc.survivor_histogram();
                if hist != surv.cell_histogram() {
                    return Err(format!(
                        "step {step}: census {hist:?} != survivor {:?}",
                        surv.cell_histogram()
                    ));
                }
                if hist != fresh.cell_histogram() {
                    return Err(format!(
                        "step {step}: census {hist:?} != fresh {:?}",
                        fresh.cell_histogram()
                    ));
                }
                if hist.total() != stats_inc.cells_out
                    || inc.live_cell_ids().len() != stats_inc.cells_out
                {
                    return Err(format!(
                        "step {step}: census totals drifted from cells_out {}",
                        stats_inc.cells_out
                    ));
                }
                check_equiv(&inc, &fresh, &batch)
                    .map_err(|e| format!("step {step}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_arrivals_match_from_scratch() {
        // The timing tentpole invariant: across random mask-flip
        // sequences on random templates, the arena's arrival table pins
        // bit-exactly to from-scratch `egfet` timing analysis of the
        // fresh survivor — delay axis and per-output-bit arrivals both.
        // The chain ends by flipping back to the recorded initial
        // binding, so every case exercises a critical path that
        // *shrinks* back to a previously-seen value (the "max can
        // decrease" direction) and must land on the identical f64.
        prop::check("incremental arrivals == from-scratch timing", |rng, _| {
            let tpl = random_template(rng);
            let n_params = tpl.n_params;
            let initial = prop::gen::bits(rng, n_params, 0.5);
            let mut params = initial.clone();
            let mut inc = IncrementalSynth::new(tpl.clone());
            inc.set_params(&params);
            let initial_delay = inc.output_delay_ms();
            {
                let (fresh, _) = optimize(&tpl.instantiate(&params));
                check_arrivals(&inc, &fresh).map_err(|e| format!("step 0: {e}"))?;
            }
            for step in 1..7 {
                let flips = 1 + rng.below(n_params);
                for _ in 0..flips {
                    params.flip(rng.below(n_params));
                }
                inc.set_params(&params);
                let (fresh, _) = optimize(&tpl.instantiate(&params));
                check_arrivals(&inc, &fresh).map_err(|e| format!("step {step}: {e}"))?;
            }
            // Revert to the initial binding: arrivals must settle back
            // to the exact initial delay, not merely a close one.
            inc.set_params(&initial);
            let back = inc.output_delay_ms();
            if back != initial_delay {
                return Err(format!(
                    "revert: delay {back} != initial {initial_delay}"
                ));
            }
            let (fresh, _) = optimize(&tpl.instantiate(&initial));
            check_arrivals(&inc, &fresh).map_err(|e| format!("revert: {e}"))?;
            Ok(())
        });
    }

    #[test]
    fn delay_shortening_flip_is_exact() {
        // Deterministic "max decreases" coverage: a param muxes the
        // output between a 6-deep NAND chain and a bare input, so
        // flipping it collapses the critical path from six cell delays
        // to zero. Both directions must pin to the from-scratch oracle.
        let mut nl = Netlist::new();
        let x = nl.input();
        let y = nl.input();
        let p = nl.param(0);
        let mut t = x;
        for _ in 0..6 {
            t = nl.nand(t, y);
        }
        let m = nl.mux(p, t, x);
        nl.output("y", vec![m]);
        let tpl = Template::new(nl, 1);
        let mut inc = IncrementalSynth::new(tpl.clone());
        let lib = Library::egfet_1v();

        let mut delays = [0.0f64; 2];
        for (i, params) in [BitVec::zeros(1), BitVec::ones(1)].iter().enumerate() {
            inc.set_params(params);
            let (fresh, _) = optimize(&tpl.instantiate(params));
            check_arrivals(&inc, &fresh).unwrap();
            assert_eq!(inc.output_delay_ms(), egfet::critical_path_ms(&fresh, &lib));
            delays[i] = inc.output_delay_ms();
        }
        let (short, long) = (delays[0].min(delays[1]), delays[0].max(delays[1]));
        assert_eq!(short, 0.0, "wire side must have zero delay");
        assert!(long > 0.0, "chain side must have positive delay");
        // Flip back to the long side: the arena must re-report the
        // identical maximum after having settled on the short one.
        let long_binding = if delays[1] > delays[0] { BitVec::ones(1) } else { BitVec::zeros(1) };
        inc.set_params(&long_binding);
        assert_eq!(inc.output_delay_ms(), long);
    }

    /// Random template with registered cone groups: inputs, then a few
    /// contiguous "neuron" groups (dense params + a random gate soup
    /// over everything built so far), then an ungrouped tail and
    /// outputs — the same shape `build_mlp_template` registers.
    fn random_grouped_template(rng: &mut Rng) -> Template {
        let mut nl = Netlist::new();
        let n_in = 2 + rng.below(3);
        for _ in 0..n_in {
            nl.input();
        }
        let mut groups: Vec<(u32, u32, u32, u32)> = Vec::new();
        let mut next_param = 0u32;
        for _ in 0..2 + rng.below(3) {
            let (node_lo, param_lo) = (nl.len() as u32, next_param);
            for _ in 0..1 + rng.below(3) {
                nl.param(next_param);
                next_param += 1;
            }
            for _ in 0..4 + rng.below(12) {
                let len = nl.len();
                let pick = |r: &mut Rng| r.below(len) as NodeId;
                let (a, b) = (pick(rng), pick(rng));
                match rng.below(8) {
                    0 => nl.not(a),
                    1 => nl.and(a, b),
                    2 => nl.or(a, b),
                    3 => nl.xor(a, b),
                    4 => nl.nand(a, b),
                    5 => nl.nor(a, b),
                    6 => nl.xnor(a, b),
                    _ => {
                        let s = pick(rng);
                        nl.mux(s, a, b)
                    }
                };
            }
            groups.push((node_lo, nl.len() as u32, param_lo, next_param));
        }
        // Ungrouped tail over everything (the argmax-tree analogue).
        for _ in 0..rng.below(6) {
            let len = nl.len();
            let (a, b) = (rng.below(len) as NodeId, rng.below(len) as NodeId);
            nl.xor(a, b);
        }
        let len = nl.len();
        for k in 0..1 + rng.below(2) {
            let bus: Vec<NodeId> =
                (0..1 + rng.below(4)).map(|_| rng.below(len) as NodeId).collect();
            nl.output(&format!("y{k}"), bus);
        }
        let mut tpl = Template::new(nl, next_param as usize);
        for (a, b, c, d) in groups {
            tpl.register_cone_group(a, b, c, d);
        }
        tpl
    }

    #[test]
    fn prop_shared_cones_bit_identical_to_plain() {
        // The sharing tentpole invariant: a sharing engine and a plain
        // engine fed the same binding sequence stay bit-identical in
        // *everything* downstream consumers can observe — stats, the
        // arena's gates and outputs (so WaveCache extension lengths
        // match), the census, the live-cell list — and both match
        // from-scratch synthesis functionally. Sibling-style deltas
        // (re-flipping one group's bits) maximize memo hits.
        prop::check("shared cones == plain incremental", |rng, _| {
            let tpl = random_grouped_template(rng);
            let n_params = tpl.n_params;
            let mut params = prop::gen::bits(rng, n_params, 0.5);
            let base = params.clone();
            let mut plain = IncrementalSynth::new(tpl.clone());
            let mut shared = IncrementalSynth::new(tpl.clone());
            shared.set_share_cones(true);
            let n_vec = (8 + rng.below(56)).min(LANES);
            let batch = random_batch(rng, tpl.nl.n_inputs, n_vec);
            for step in 0..8 {
                if step > 0 {
                    // Mutate within one random group (sibling pattern),
                    // occasionally revert to the base binding entirely.
                    if rng.chance(0.25) {
                        params = base.clone();
                    }
                    let g = &tpl.cone_groups[rng.below(tpl.cone_groups.len())];
                    let span = (g.param_hi - g.param_lo) as usize;
                    for _ in 0..1 + rng.below(span) {
                        params.flip(g.param_lo as usize + rng.below(span));
                    }
                    if rng.chance(0.3) {
                        params.flip(rng.below(n_params));
                    }
                }
                let sp = plain.set_params(&params);
                let ss = shared.set_params(&params);
                if sp != ss {
                    return Err(format!("step {step}: stats {ss:?} != plain {sp:?}"));
                }
                if shared.arena().gates != plain.arena().gates {
                    return Err(format!(
                        "step {step}: arenas diverged ({} vs {} nodes)",
                        shared.arena().len(),
                        plain.arena().len()
                    ));
                }
                if shared.arena().outputs != plain.arena().outputs {
                    return Err(format!("step {step}: arena outputs diverged"));
                }
                if shared.survivor_histogram() != plain.survivor_histogram() {
                    return Err(format!("step {step}: census diverged"));
                }
                if shared.live_cell_ids() != plain.live_cell_ids() {
                    return Err(format!("step {step}: live-cell ids diverged"));
                }
                if shared.output_delay_ms() != plain.output_delay_ms() {
                    return Err(format!(
                        "step {step}: delay {} (shared) != {} (plain)",
                        shared.output_delay_ms(),
                        plain.output_delay_ms()
                    ));
                }
                let (fresh, _) = optimize(&tpl.instantiate(&params));
                check_equiv(&shared, &fresh, &batch)
                    .map_err(|e| format!("step {step} (shared): {e}"))?;
                check_arrivals(&shared, &fresh)
                    .map_err(|e| format!("step {step} (shared arrivals): {e}"))?;
            }
            // A mid-run flush only costs future hits, never results.
            shared.flush_shared_cones();
            assert_eq!(shared.shared_cone_entries(), 0);
            params.flip(rng.below(n_params));
            let sp = plain.set_params(&params);
            let ss = shared.set_params(&params);
            if sp != ss || shared.arena().gates != plain.arena().gates {
                return Err("post-flush divergence".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn sibling_rebinding_hits_the_memo() {
        // parent A -> child1 (flip group 0) -> child2 (group 0 back to
        // A's binding, flip group 1): child2's group-0 rebinding must be
        // served from the memo seeded by A's full pass, and the result
        // must still match from-scratch synthesis.
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let g0_lo = nl.len() as NodeId;
        let p0 = nl.param(0);
        let t0 = nl.and(a, p0);
        let y0 = nl.xor(t0, b);
        let g0_hi = nl.len() as NodeId;
        let p1 = nl.param(1);
        let y1 = nl.mux(p1, y0, a);
        let g1_hi = nl.len() as NodeId;
        nl.output("y", vec![y0, y1]);
        let mut tpl = Template::new(nl, 2);
        tpl.register_cone_group(g0_lo, g0_hi, 0, 1);
        tpl.register_cone_group(g0_hi, g1_hi, 1, 2);

        let mut inc = IncrementalSynth::new(tpl.clone());
        inc.set_share_cones(true);
        let genome_a = BitVec::zeros(2);
        let mut child1 = genome_a.clone();
        child1.flip(0);
        let mut child2 = genome_a.clone();
        child2.flip(1);

        inc.set_params(&genome_a); // full pass seeds both groups
        assert_eq!(inc.shared_cone_entries(), 2);
        inc.set_params(&child1); // group 0 re-synthesized (miss)
        let before = telemetry::thread_block();
        inc.set_params(&child2); // group 0 back to A -> memo hit
        let d = telemetry::thread_block().delta(&before);
        assert_eq!(d.work[Work::SynthSharedConeHits as usize], 1, "group-0 hit");
        assert_eq!(d.work[Work::SynthSharedConeMisses as usize], 1, "group-1 miss");

        let batch = pack_vectors(&[
            vec![false, false],
            vec![false, true],
            vec![true, false],
            vec![true, true],
        ]);
        let (fresh, stats_fresh) = optimize(&tpl.instantiate(&child2));
        let (_, stats_inc) = inc.survivor();
        assert_eq!(stats_inc, stats_fresh);
        check_equiv(&inc, &fresh, &batch).unwrap();
    }

    #[test]
    fn single_param_gate_folds_both_ways() {
        // and(x, p): p=1 -> wire to x (0 cells); p=0 -> constant 0.
        let mut nl = Netlist::new();
        let x = nl.input();
        let p = nl.param(0);
        let g = nl.and(x, p);
        nl.output("y", vec![g]);
        let tpl = Template::new(nl, 1);
        let mut inc = IncrementalSynth::new(tpl.clone());

        let on = BitVec::ones(1);
        let stats = inc.set_params(&on);
        assert_eq!(stats.cells_out, 0);
        let batch = pack_vectors(&[vec![false], vec![true]]);
        let (fresh, _) = optimize(&tpl.instantiate(&on));
        check_equiv(&inc, &fresh, &batch).unwrap();

        let off = BitVec::zeros(1);
        let stats = inc.set_params(&off);
        assert_eq!(stats.cells_out, 0);
        let (fresh, _) = optimize(&tpl.instantiate(&off));
        check_equiv(&inc, &fresh, &batch).unwrap();
    }

    #[test]
    fn arena_converges_on_revisited_bindings() {
        // Flipping a binding A -> B -> A must not grow the arena on the
        // second visit: every cone re-emission dedups onto existing
        // nodes. This is the property that keeps long GA runs bounded.
        let mut rng = Rng::new(42);
        let tpl = random_template(&mut rng);
        let a = prop::gen::bits(&mut rng, tpl.n_params, 0.5);
        let mut b = a.clone();
        b.flip(0);
        let mut inc = IncrementalSynth::new(tpl);
        inc.set_params(&a);
        inc.set_params(&b);
        inc.set_params(&a);
        let len_after_first_cycle = inc.arena().len();
        let stats_a = inc.set_params(&a);
        inc.set_params(&b);
        let stats_a2 = inc.set_params(&a);
        assert_eq!(inc.arena().len(), len_after_first_cycle, "arena must not grow");
        assert_eq!(stats_a, stats_a2, "stats must be reproducible");
    }

    #[test]
    fn no_flip_resynth_is_stable() {
        let mut rng = Rng::new(7);
        let tpl = random_template(&mut rng);
        let params = prop::gen::bits(&mut rng, tpl.n_params, 0.5);
        let mut inc = IncrementalSynth::new(tpl);
        let s1 = inc.set_params(&params);
        let len = inc.arena().len();
        let s2 = inc.set_params(&params);
        assert_eq!(s1, s2);
        assert_eq!(inc.arena().len(), len);
    }
}
