//! Incremental cone-local re-synthesis over a [`Template`].
//!
//! The circuit-in-the-loop GA evaluates thousands of chromosomes that
//! differ from their parents in a handful of mask bits, yet from-scratch
//! synthesis pays the full netlist-sized rewrite for each one. This
//! engine exploits the template form: since every chromosome binds the
//! same fixed gate graph and only the `Param` literal values change, the
//! simplification result can only change inside the *fanout cones* of
//! the flipped literals.
//!
//! Mechanics ([`IncrementalSynth`]):
//!
//! * a persistent [`Rewriter`] arena (fused const-prop + structural
//!   hashing) accumulates every survivor gate ever emitted; the arena is
//!   append-only, so node ids — and any lane-word caches keyed on them
//!   (`sim::wave::WaveCache`) — stay valid across instantiations;
//! * a per-template-node `Repr` table remembers what each source node
//!   resolved to under the current parameter binding;
//! * on a parameter delta, a min-heap worklist walks the dirty cone in
//!   ascending node id (= topological) order, recomputing reprs and
//!   stopping early where a node's repr converges to its old value —
//!   work scales with *mutation size*, not netlist size;
//! * outputs are re-resolved through the repr table, and the survivor
//!   netlist (or just its live-cell count) falls out of a hash-free DCE
//!   walk over the arena.
//!
//! Invariants, pinned by the property suite below:
//!
//! 1. after every `set_params`, the arena output cone computes the same
//!    function as `optimize(template.instantiate(params))`, and
//! 2. `SynthStats::cells_out` matches the from-scratch pass exactly —
//!    the incremental survivor is the same netlist up to node
//!    renumbering (dedup makes both sides emit one node per distinct
//!    canonical structure, and repr convergence never skips a node whose
//!    inputs changed).

use crate::netlist::{CellCounts, Gate, Netlist, NodeId, Template};
use crate::synth::{dce, Repr, Rewriter, SynthStats};
use crate::util::telemetry::{self, Counter, Work};
use crate::util::BitVec;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Persistent incremental re-synthesizer for one template.
pub struct IncrementalSynth {
    tpl: Template,
    rw: Rewriter,
    /// Representative of each template node under `cur`.
    repr: Vec<Repr>,
    /// Current parameter binding (valid once `ready`).
    cur: BitVec,
    ready: bool,
    /// Worklist de-dup stamps, one slot per template node.
    dirty_stamp: Vec<u32>,
    stamp: u32,
    /// Scratch stamps for live-cone walks over the arena.
    live_stamp: Vec<u32>,
    live_mark: u32,
    /// Survivor census of the current binding, refreshed by the same
    /// hash-free DCE walk that produces `cells_out` (no extra passes):
    /// per-cell-type counts plus the live cell node ids — what the
    /// measured-hardware objectives consume (`egfet::analyze_histogram`
    /// + per-node toggle sums over `sim::wave::WaveCache`).
    hist: CellCounts,
    live_cells: Vec<NodeId>,
}

impl IncrementalSynth {
    pub fn new(tpl: Template) -> IncrementalSynth {
        let mut rw = Rewriter::new(true, true);
        rw.seed_inputs(&tpl.nl);
        let n = tpl.nl.len();
        IncrementalSynth {
            rw,
            repr: Vec::with_capacity(n),
            cur: BitVec::zeros(tpl.n_params),
            ready: false,
            dirty_stamp: vec![0; n],
            stamp: 0,
            live_stamp: Vec::new(),
            live_mark: 0,
            hist: CellCounts::default(),
            live_cells: Vec::new(),
            tpl,
        }
    }

    pub fn template(&self) -> &Template {
        &self.tpl
    }

    /// The persistent arena. Append-only across instantiations; its
    /// `outputs` reflect the most recent `set_params` binding.
    pub fn arena(&self) -> &Netlist {
        &self.rw.out
    }

    /// Bind the parameters to `params` and re-simplify. The first call
    /// is a full from-scratch pass; subsequent calls revisit only the
    /// fanout cones of the flipped literals. Returns survivor stats.
    pub fn set_params(&mut self, params: &BitVec) -> SynthStats {
        assert_eq!(params.len(), self.tpl.n_params, "param count mismatch");
        telemetry::count(Counter::SynthSetParams, 1);
        if !self.ready {
            self.cur = params.clone();
            self.full_pass();
            self.ready = true;
        } else {
            let flipped: Vec<NodeId> = (0..self.tpl.n_params)
                .filter(|&p| params.get(p) != self.cur.get(p))
                .map(|p| self.tpl.param_nodes[p])
                .collect();
            self.cur = params.clone();
            self.cone_pass(&flipped);
        }
        self.refresh_outputs();
        self.census();
        SynthStats { cells_in: self.tpl.nl.cell_count(), cells_out: self.live_cells.len() }
    }

    /// Per-cell-type counts of the current survivor — exactly
    /// `dce(arena).cell_histogram()` (pinned by the property suite),
    /// without materializing the netlist. Valid after `set_params`.
    pub fn survivor_histogram(&self) -> &CellCounts {
        debug_assert!(self.ready, "set_params before survivor_histogram");
        &self.hist
    }

    /// Arena node ids of the current survivor's cells (the live output
    /// cone, cells only; deterministic walk order, not sorted). Aligned
    /// with any arena-keyed side table — `sim::wave::WaveCache::node_toggles`,
    /// which is how the evaluator sums survivor toggle activity without
    /// re-simulating. Valid after `set_params`.
    pub fn live_cell_ids(&self) -> &[NodeId] {
        debug_assert!(self.ready, "set_params before live_cell_ids");
        &self.live_cells
    }

    /// Materialize the compact survivor netlist of the current binding
    /// (DCE over the arena's live cone) — the same netlist, up to node
    /// renumbering, as `optimize(template.instantiate(params))`.
    pub fn survivor(&self) -> (Netlist, SynthStats) {
        assert!(self.ready, "set_params before survivor");
        let out = dce(&self.rw.out);
        let stats =
            SynthStats { cells_in: self.tpl.nl.cell_count(), cells_out: out.cell_count() };
        (out, stats)
    }

    fn full_pass(&mut self) {
        // Whether a binding needs a full pass depends on whether this
        // worker's state has served before — scheduling-dependent `Work`.
        telemetry::work(Work::SynthFullPasses, 1);
        let IncrementalSynth { tpl, rw, repr, cur, .. } = self;
        repr.clear();
        for g in &tpl.nl.gates {
            let r = match *g {
                Gate::Param(p) => Repr::Const(cur.get(p as usize)),
                _ => rw.rewrite_gate(g, |id| repr[id as usize]),
            };
            repr.push(r);
        }
    }

    /// Recompute reprs over the fanout cones of `flipped` param nodes.
    /// The min-heap pops in ascending node id order, which by the
    /// topological invariant means every operand repr is final when a
    /// node is recomputed; a node whose repr converges to its old value
    /// does not dirty its consumers.
    fn cone_pass(&mut self, flipped: &[NodeId]) {
        if flipped.is_empty() {
            return;
        }
        self.stamp += 1;
        let stamp = self.stamp;
        let IncrementalSynth { tpl, rw, repr, cur, dirty_stamp, .. } = self;
        let mut heap: BinaryHeap<Reverse<NodeId>> =
            BinaryHeap::with_capacity(flipped.len() * 4);
        for &id in flipped {
            if dirty_stamp[id as usize] != stamp {
                dirty_stamp[id as usize] = stamp;
                heap.push(Reverse(id));
            }
        }
        let (mut pops, mut rewrites) = (0u64, 0u64);
        while let Some(Reverse(id)) = heap.pop() {
            pops += 1;
            let g = &tpl.nl.gates[id as usize];
            let new = match *g {
                Gate::Param(p) => Repr::Const(cur.get(p as usize)),
                _ => rw.rewrite_gate(g, |i| repr[i as usize]),
            };
            if new != repr[id as usize] {
                rewrites += 1;
                repr[id as usize] = new;
                for &c in tpl.consumers(id) {
                    if dirty_stamp[c as usize] != stamp {
                        dirty_stamp[c as usize] = stamp;
                        heap.push(Reverse(c));
                    }
                }
            }
        }
        // Cone shape depends on the worker state's previous binding, so
        // these are scheduling-dependent `Work` stats. One flush per pass
        // keeps the worklist loop itself telemetry-free.
        telemetry::work(Work::SynthConePasses, 1);
        telemetry::work(Work::SynthConeNodes, pops);
        telemetry::work(Work::SynthRewrites, rewrites);
        telemetry::work(Work::SynthConvergencePrunes, pops - rewrites);
        telemetry::cone_size(pops as usize);
    }

    fn refresh_outputs(&mut self) {
        let IncrementalSynth { tpl, rw, repr, .. } = self;
        rw.resolve_outputs(&tpl.nl.outputs, repr);
    }

    /// Census of the current output cone: live cell ids and per-type
    /// counts (the `cells_out` + `cell_histogram` a from-scratch DCE
    /// would report) without materializing the netlist. One hash-free
    /// walk; the stamp array and the live list are reused buffers, so
    /// steady-state re-synthesis stays allocation-free.
    fn census(&mut self) {
        let IncrementalSynth { rw, live_stamp, live_mark, hist, live_cells, .. } = self;
        let arena = &rw.out;
        *live_mark += 1;
        let mark = *live_mark;
        live_stamp.resize(arena.len(), 0);
        *hist = CellCounts::default();
        live_cells.clear();
        let mut stack: Vec<NodeId> = Vec::new();
        for (_, bus) in &arena.outputs {
            for &b in bus {
                if live_stamp[b as usize] != mark {
                    live_stamp[b as usize] = mark;
                    stack.push(b);
                }
            }
        }
        while let Some(id) = stack.pop() {
            let g = &arena.gates[id as usize];
            if g.is_cell() {
                hist.add(g);
                live_cells.push(id);
            }
            for op in g.operands() {
                if live_stamp[op as usize] != mark {
                    live_stamp[op as usize] = mark;
                    stack.push(op);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::wave::{eval_wave, lane_bus_u64, pack_vectors, InputWave, LANES};
    use crate::synth::optimize;
    use crate::util::{prop, Rng};

    /// Random topologically-valid template: inputs, a dense block of
    /// params, optional constants, then a random gate soup over all of
    /// them, with a few declared outputs.
    fn random_template(rng: &mut Rng) -> Template {
        let mut nl = Netlist::new();
        let n_in = 1 + rng.below(4);
        for _ in 0..n_in {
            nl.input();
        }
        let n_params = 1 + rng.below(8);
        for p in 0..n_params as u32 {
            nl.param(p);
        }
        if rng.chance(0.5) {
            nl.constant(rng.chance(0.5));
        }
        let n_gates = 5 + rng.below(60);
        for _ in 0..n_gates {
            let len = nl.len();
            let pick = |r: &mut Rng| r.below(len) as NodeId;
            let (a, b) = (pick(rng), pick(rng));
            match rng.below(9) {
                0 => nl.not(a),
                1 => nl.and(a, b),
                2 => nl.or(a, b),
                3 => nl.xor(a, b),
                4 => nl.nand(a, b),
                5 => nl.nor(a, b),
                6 => nl.xnor(a, b),
                7 => nl.constant(rng.chance(0.5)),
                _ => {
                    let s = pick(rng);
                    nl.mux(s, a, b)
                }
            };
        }
        let len = nl.len();
        for k in 0..1 + rng.below(3) {
            let bus: Vec<NodeId> =
                (0..1 + rng.below(4)).map(|_| rng.below(len) as NodeId).collect();
            nl.output(&format!("y{k}"), bus);
        }
        Template::new(nl, n_params)
    }

    fn random_batch(rng: &mut Rng, n_inputs: u32, n_vec: usize) -> InputWave {
        let vectors: Vec<Vec<bool>> = (0..n_vec)
            .map(|_| (0..n_inputs).map(|_| rng.chance(0.5)).collect())
            .collect();
        pack_vectors(&vectors)
    }

    /// Compare every output bus of `fresh` (from-scratch) against both
    /// incremental views (survivor + arena), lane by lane.
    fn check_equiv(
        inc: &IncrementalSynth,
        fresh: &Netlist,
        batch: &InputWave,
    ) -> Result<(), String> {
        let (surv, _) = inc.survivor();
        let vf = eval_wave(fresh, batch);
        let vs = eval_wave(&surv, batch);
        let va = eval_wave(inc.arena(), batch);
        for (oi, (name, busf)) in fresh.outputs.iter().enumerate() {
            let buss = &surv.outputs[oi].1;
            let busa = &inc.arena().outputs[oi].1;
            for lane in 0..batch.n_lanes {
                let want = lane_bus_u64(&vf, busf, lane);
                let got_s = lane_bus_u64(&vs, buss, lane);
                let got_a = lane_bus_u64(&va, busa, lane);
                if got_s != want || got_a != want {
                    return Err(format!(
                        "output '{name}' lane {lane}: fresh {want}, survivor {got_s}, arena {got_a}"
                    ));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn prop_incremental_matches_from_scratch() {
        // The tentpole invariant: across random mask-flip sequences on
        // random templates, the incremental engine's output cone is
        // function-identical (wave-simulated, lane by lane) to
        // from-scratch `optimize`, with matching `cells_out`.
        prop::check("incremental == from-scratch synth", |rng, _| {
            let tpl = random_template(rng);
            let n_params = tpl.n_params;
            let mut params = prop::gen::bits(rng, n_params, 0.5);
            let mut inc = IncrementalSynth::new(tpl.clone());
            let n_vec = (8 + rng.below(56)).min(LANES);
            let batch = random_batch(rng, tpl.nl.n_inputs, n_vec);
            for step in 0..6 {
                if step > 0 {
                    let flips = 1 + rng.below(n_params);
                    for _ in 0..flips {
                        params.flip(rng.below(n_params));
                    }
                }
                let stats_inc = inc.set_params(&params);
                let (fresh, stats_fresh) = optimize(&tpl.instantiate(&params));
                if stats_inc.cells_out != stats_fresh.cells_out {
                    return Err(format!(
                        "step {step}: cells_out {} (incremental) != {} (from-scratch)",
                        stats_inc.cells_out, stats_fresh.cells_out
                    ));
                }
                let (surv, sstats) = inc.survivor();
                if sstats != stats_fresh {
                    return Err(format!(
                        "step {step}: survivor stats {sstats:?} != fresh {stats_fresh:?}"
                    ));
                }
                // The measured-objective census: per-type counts must
                // match a from-scratch DCE'd census exactly (both the
                // materialized survivor's and the fresh pass's — the
                // survivor is the same netlist up to renumbering), and
                // the live-cell list must agree with `cells_out`.
                let hist = *inc.survivor_histogram();
                if hist != surv.cell_histogram() {
                    return Err(format!(
                        "step {step}: census {hist:?} != survivor {:?}",
                        surv.cell_histogram()
                    ));
                }
                if hist != fresh.cell_histogram() {
                    return Err(format!(
                        "step {step}: census {hist:?} != fresh {:?}",
                        fresh.cell_histogram()
                    ));
                }
                if hist.total() != stats_inc.cells_out
                    || inc.live_cell_ids().len() != stats_inc.cells_out
                {
                    return Err(format!(
                        "step {step}: census totals drifted from cells_out {}",
                        stats_inc.cells_out
                    ));
                }
                check_equiv(&inc, &fresh, &batch)
                    .map_err(|e| format!("step {step}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_param_gate_folds_both_ways() {
        // and(x, p): p=1 -> wire to x (0 cells); p=0 -> constant 0.
        let mut nl = Netlist::new();
        let x = nl.input();
        let p = nl.param(0);
        let g = nl.and(x, p);
        nl.output("y", vec![g]);
        let tpl = Template::new(nl, 1);
        let mut inc = IncrementalSynth::new(tpl.clone());

        let on = BitVec::ones(1);
        let stats = inc.set_params(&on);
        assert_eq!(stats.cells_out, 0);
        let batch = pack_vectors(&[vec![false], vec![true]]);
        let (fresh, _) = optimize(&tpl.instantiate(&on));
        check_equiv(&inc, &fresh, &batch).unwrap();

        let off = BitVec::zeros(1);
        let stats = inc.set_params(&off);
        assert_eq!(stats.cells_out, 0);
        let (fresh, _) = optimize(&tpl.instantiate(&off));
        check_equiv(&inc, &fresh, &batch).unwrap();
    }

    #[test]
    fn arena_converges_on_revisited_bindings() {
        // Flipping a binding A -> B -> A must not grow the arena on the
        // second visit: every cone re-emission dedups onto existing
        // nodes. This is the property that keeps long GA runs bounded.
        let mut rng = Rng::new(42);
        let tpl = random_template(&mut rng);
        let a = prop::gen::bits(&mut rng, tpl.n_params, 0.5);
        let mut b = a.clone();
        b.flip(0);
        let mut inc = IncrementalSynth::new(tpl);
        inc.set_params(&a);
        inc.set_params(&b);
        inc.set_params(&a);
        let len_after_first_cycle = inc.arena().len();
        let stats_a = inc.set_params(&a);
        inc.set_params(&b);
        let stats_a2 = inc.set_params(&a);
        assert_eq!(inc.arena().len(), len_after_first_cycle, "arena must not grow");
        assert_eq!(stats_a, stats_a2, "stats must be reproducible");
    }

    #[test]
    fn no_flip_resynth_is_stable() {
        let mut rng = Rng::new(7);
        let tpl = random_template(&mut rng);
        let params = prop::gen::bits(&mut rng, tpl.n_params, 0.5);
        let mut inc = IncrementalSynth::new(tpl);
        let s1 = inc.set_params(&params);
        let len = inc.arena().len();
        let s2 = inc.set_params(&params);
        assert_eq!(s1, s2);
        assert_eq!(inc.arena().len(), len);
    }
}
