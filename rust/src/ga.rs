//! NSGA-II multi-objective genetic optimizer (paper §III-D1).
//!
//! Optimizes accumulation-approximation chromosomes (bit vectors over all
//! summand bits) against `M` objectives, all minimized. The whole core —
//! evaluation traits, non-dominated sorting, constrained domination,
//! crowding distance and the environmental-selection loop — is
//! const-generic over the objective arity `M` (default 2, the paper's
//! accuracy/area pair), so any future cost axis (delay, energy) drops in
//! without touching the algorithm:
//!
//! * objective 0 is always the classification accuracy *loss* w.r.t. the
//!   QAT model (train set) — the accuracy-bound constraint applies to it;
//! * objectives 1.. are hardware costs: by default the full-adder area
//!   surrogate ([`crate::area::AreaModel`]); the circuit-in-the-loop
//!   backend can swap in *measured* EGFET area, dynamic power, and/or
//!   critical-path delay of each chromosome's synthesized survivor
//!   (`--objective fa|area|power|delay|area+power|area+power+delay`,
//!   [`crate::egfet::CostObjective`] — the joint modes run three- and
//!   four-objective fronts). A delay axis can additionally carry a hard
//!   timing cap (`--max-delay`, [`Constraints::max_delay`]) that rides
//!   the same constrained-domination rule as the accuracy bound, so
//!   timing-infeasible designs lose to every timing-feasible one and
//!   never appear on the reported front.
//!
//! Per the paper: the initial population is biased toward
//! non-approximated bits, candidates whose accuracy loss exceeds 15% are
//! discouraged (constrained domination à la Deb), random bit-flip
//! mutation and uniform crossover traverse the space, and the outcome is
//! the non-dominated accuracy/cost front. All M-generic routines are
//! pinned against a naive brute-force oracle at M=2 and M=3
//! (`rust/tests/nsga_oracle.rs`) and the M=2 instantiation is pinned
//! bit-identical to the pre-generalization two-objective implementation
//! (`rust/tests/nsga_backcompat.rs`).

use crate::config::GaSpec;
use crate::util::telemetry::{self, Counter, Gauge};
use crate::util::{threads, BitVec, Rng};
// detlint: allow-file(std-hash) — batch-dedup map below is lookup-only
// (first-occurrence order comes from the `uniq` Vec, never iteration).
use std::collections::HashMap;

/// One evaluation worker's scratch state.
///
/// A worker owns whatever mutable machinery its backend needs per thread
/// — the circuit-in-the-loop backend parks an incremental-synthesis
/// arena and a wave cache here — and scores one genome at a time.
/// Contract: `eval_one` must be a *pure function of the genome and the
/// shared read-only state*; per-worker scratch may only accelerate it,
/// never change it. That contract is what makes the parallel fan-out
/// bit-identical to serial evaluation (pinned by
/// `rust/tests/ga_determinism.rs`).
///
/// Workers live for exactly one [`evaluate_parallel`] batch — one GA
/// generation — so a worker's drop hook doubles as the generation
/// boundary. The circuit backend relies on this: its shared-cone memo
/// (DESIGN.md §2/§4) is flushed on drop, scoping the memo to the
/// generation by construction.
pub trait EvalWorker<const M: usize = 2> {
    /// Score one genome as `[accuracy_loss, cost, ...]` (all minimized;
    /// axis 0 is the loss the constraint applies to, axes 1.. are the
    /// backend's configured cost objectives — FA surrogate by default).
    fn eval_one(&mut self, genome: &BitVec) -> [f64; M];
}

/// Chromosome evaluator: shared read-only state (`Sync`) plus a factory
/// of per-worker scratch evaluators.
///
/// Implemented by the native integer-model evaluator, by the PJRT
/// evaluator that runs the AOT-compiled Layer-2/Layer-1 program, and by
/// the circuit-in-the-loop evaluator that wave-simulates the synthesized
/// netlist (`crate::runtime::evaluator`). [`Nsga2`] fans each
/// generation's offspring across a `util::threads` worker pool
/// ([`evaluate_parallel`]); each worker evaluates genomes through its
/// own [`EvalWorker`], and results are reduced back in genome order, so
/// the outcome is independent of scheduling.
pub trait Evaluator<const M: usize = 2>: Sync {
    /// Create one worker's scratch evaluator (borrowing the shared
    /// state). Called once per worker thread per evaluated batch.
    fn worker(&self) -> Box<dyn EvalWorker<M> + '_>;

    /// Optional whole-batch fast path. Backends whose parallelism lives
    /// elsewhere (the PJRT evaluator dispatches population tiles to XLA)
    /// return `Some`; everyone else inherits `None` and takes the
    /// worker fan-out.
    fn evaluate_batch(&self, genomes: &[BitVec]) -> Option<Vec<[f64; M]>> {
        let _ = genomes;
        None
    }

    /// Evaluate a batch of genomes (one `[f64; M]` per input), fanning
    /// out over the default worker count. Convenience surface for tests
    /// and benches; [`Nsga2`] calls [`evaluate_parallel`] with its
    /// configured `jobs` instead.
    fn evaluate(&self, genomes: &[BitVec]) -> Vec<[f64; M]> {
        evaluate_parallel(self, genomes, threads::default_jobs())
    }
}

/// Population-parallel evaluation: dedup the batch (NSGA-II offspring
/// routinely repeat chromosomes), fan the unique genomes across `jobs`
/// workers — each with its own [`EvalWorker`] scratch — and scatter the
/// results back in genome order.
///
/// Bit-identical to serial evaluation for any `jobs`: unique genomes are
/// claimed off an atomic cursor but written back by index, dedup follows
/// first-occurrence order, and `EvalWorker::eval_one` is pure per genome
/// (see the trait contract).
pub fn evaluate_parallel<const M: usize, E: Evaluator<M> + ?Sized>(
    ev: &E,
    genomes: &[BitVec],
    jobs: usize,
) -> Vec<[f64; M]> {
    telemetry::count(Counter::GaEvaluateCalls, 1);
    telemetry::count(Counter::GaGenomesIn, genomes.len() as u64);
    if let Some(objs) = ev.evaluate_batch(genomes) {
        assert_eq!(objs.len(), genomes.len(), "evaluator returned wrong arity");
        return objs;
    }
    // Dedup in first-occurrence order; `which[k]` maps batch position ->
    // unique index.
    let mut uniq: Vec<&BitVec> = Vec::new();
    let mut slot: HashMap<&BitVec, usize> = HashMap::new();
    let mut which = Vec::with_capacity(genomes.len());
    for g in genomes {
        let k = *slot.entry(g).or_insert_with(|| {
            uniq.push(g);
            uniq.len() - 1
        });
        which.push(k);
    }
    telemetry::count(Counter::GaGenomesUnique, uniq.len() as u64);
    let _sp = crate::span!("evaluate");
    let uniq_objs = threads::par_map_with(
        uniq.len(),
        jobs.max(1),
        || ev.worker(),
        |w, i| w.eval_one(uniq[i]),
    );
    which.into_iter().map(|k| uniq_objs[k]).collect()
}

/// Generations between ring-migration steps of the island model —
/// how often the shard→island assignment rotates (see
/// [`evaluate_islands`]). Override with
/// [`Nsga2::with_migration_interval`].
pub const DEFAULT_MIGRATION_INTERVAL: usize = 4;

/// Island-sharded population evaluation (`--islands K`).
///
/// The batch is globally deduped exactly like [`evaluate_parallel`]
/// (first-occurrence order), then the unique-genome list is split into
/// `K` *contiguous shards*, and island `k` evaluates shard
/// `(k + round) % K` through its own `par_map_with` fan-out — its own
/// worker states, i.e. its own leased synthesis arenas and wave caches
/// in the circuit backend. `round` advances at migration boundaries
/// (every [`Nsga2::migration_interval`] generations), rotating the
/// shard→island assignment one step around the ring: that is the
/// deterministic ring migration. Because workers are pure per genome
/// (the [`EvalWorker`] contract), the rotation changes only *which
/// island's warm state serves which population slice* — work
/// attribution — never any score.
///
/// Bit-identical to the single-island run at any `K`, any `round`, and
/// any `jobs` width, by construction:
///
/// * the dedup is global, so `ga.genomes_unique` and the memo hit/miss
///   stream cannot depend on `K`;
/// * shards are contiguous slices of the unique list and are
///   reassembled in shard order before the scatter, exactly restoring
///   [`evaluate_parallel`]'s unique-genome result order;
/// * every deterministic [`Counter`] fires once per logical item on
///   the same items (`ga.evaluate_calls` counts the batch, not the
///   islands), so counter totals match the single-island run too.
///
/// Pinned by `rust/tests/island_determinism.rs` across
/// `--islands {1,2,4}` × `--jobs {1,8}`.
pub fn evaluate_islands<const M: usize, E: Evaluator<M> + ?Sized>(
    ev: &E,
    genomes: &[BitVec],
    jobs: usize,
    islands: usize,
    round: usize,
) -> Vec<[f64; M]> {
    let islands = islands.max(1);
    if islands == 1 {
        return evaluate_parallel(ev, genomes, jobs);
    }
    telemetry::count(Counter::GaEvaluateCalls, 1);
    telemetry::count(Counter::GaGenomesIn, genomes.len() as u64);
    if let Some(objs) = ev.evaluate_batch(genomes) {
        assert_eq!(objs.len(), genomes.len(), "evaluator returned wrong arity");
        return objs;
    }
    // Global dedup in first-occurrence order — identical to
    // `evaluate_parallel`.
    let mut uniq: Vec<&BitVec> = Vec::new();
    let mut slot: HashMap<&BitVec, usize> = HashMap::new();
    let mut which = Vec::with_capacity(genomes.len());
    for g in genomes {
        let k = *slot.entry(g).or_insert_with(|| {
            uniq.push(g);
            uniq.len() - 1
        });
        which.push(k);
    }
    telemetry::count(Counter::GaGenomesUnique, uniq.len() as u64);
    let _sp = crate::span!("evaluate");
    // Contiguous shard bounds over the unique list (last shard may be
    // short or empty when K doesn't divide the batch).
    let shard_size = uniq.len().div_ceil(islands);
    let bounds = |c: usize| -> (usize, usize) {
        let lo = (c * shard_size).min(uniq.len());
        let hi = ((c + 1) * shard_size).min(uniq.len());
        (lo, hi)
    };
    let inner_jobs = jobs.max(1).div_ceil(islands).max(1);
    // The islands fan out concurrently, each running its own inner
    // worker pool; nested `par_map_with` merges each island's telemetry
    // block into its island thread, and the outer map merges those into
    // the caller — totals flow up the whole tree as usual.
    let per_island: Vec<(usize, Vec<[f64; M]>)> = threads::par_map(islands, islands, |k| {
        let c = (k + round) % islands;
        let (lo, hi) = bounds(c);
        let objs = threads::par_map_with(
            hi - lo,
            inner_jobs,
            || ev.worker(),
            |w, i| w.eval_one(uniq[lo + i]),
        );
        (c, objs)
    });
    // Reassemble shards in shard order (undoing the ring rotation).
    let mut uniq_objs: Vec<Option<[f64; M]>> = vec![None; uniq.len()];
    for (c, objs) in per_island {
        let (lo, _) = bounds(c);
        for (i, o) in objs.into_iter().enumerate() {
            uniq_objs[lo + i] = Some(o);
        }
    }
    which.into_iter().map(|k| uniq_objs[k].expect("shard covered index")).collect()
}

/// One individual of the population.
#[derive(Clone, Debug)]
pub struct Individual<const M: usize = 2> {
    pub genome: BitVec,
    /// `[accuracy_loss, cost, ...]`, all minimized.
    pub objs: [f64; M],
}

/// Result of a GA run.
#[derive(Clone, Debug)]
pub struct GaResult<const M: usize = 2> {
    /// Final population (rank-sorted).
    pub population: Vec<Individual<M>>,
    /// Non-dominated feasible front.
    pub front: Vec<Individual<M>>,
    /// Objective history: per generation, best feasible primary cost
    /// (objective 1) at <=2% and <=5% accuracy loss (for convergence
    /// logging; arity-independent on purpose so logs stay comparable).
    pub history: Vec<(f64, f64)>,
}

/// The feasibility side of constrained domination: the accuracy-loss
/// bound on objective 0 (always), plus an optional hard cap on one cost
/// axis — the `--max-delay` timing constraint (`(axis, cap)`, where
/// `axis` is the objective's delay slot,
/// [`crate::egfet::CostObjective::delay_axis`]). Violations are summed
/// into one scalar, so Deb's rule stays a total preorder: feasible
/// beats infeasible, less-violating beats more-violating, and plain
/// Pareto dominance decides among the feasible.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Constraints {
    /// Maximum admissible accuracy loss (objective 0).
    pub acc_loss_bound: f64,
    /// Optional `(objective axis, cap)` hard constraint — `--max-delay`
    /// in milliseconds on the delay axis.
    pub max_delay: Option<(usize, f64)>,
}

impl Constraints {
    /// The legacy constraint set: accuracy bound only.
    pub fn loss_only(acc_loss_bound: f64) -> Constraints {
        Constraints { acc_loss_bound, max_delay: None }
    }

    /// Total constraint violation of an objective vector (0 = feasible).
    pub fn violation<const M: usize>(&self, o: &[f64; M]) -> f64 {
        let mut v = (o[0] - self.acc_loss_bound).max(0.0);
        if let Some((axis, cap)) = self.max_delay {
            v += (o[axis] - cap).max(0.0);
        }
        v
    }

    /// Whether an objective vector satisfies every constraint.
    pub fn feasible<const M: usize>(&self, o: &[f64; M]) -> bool {
        self.violation(o) == 0.0
    }
}

/// Non-dominated sorting: returns the front index of every individual
/// (0 = best front). Uses the constrained-domination rule with the
/// accuracy-loss bound on objective 0: feasible dominates infeasible;
/// among infeasible, lower violation dominates.
pub fn non_dominated_sort<const M: usize>(objs: &[[f64; M]], bound: f64) -> Vec<usize> {
    non_dominated_sort_by(objs, &Constraints::loss_only(bound))
}

/// [`non_dominated_sort`] under a full [`Constraints`] set (accuracy
/// bound + optional timing cap).
pub fn non_dominated_sort_by<const M: usize>(
    objs: &[[f64; M]],
    constraints: &Constraints,
) -> Vec<usize> {
    let n = objs.len();
    let mut dominated_by = vec![0usize; n];
    let mut dominates: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            if dominates_constrained_by(&objs[i], &objs[j], constraints) {
                dominates[i].push(j);
            } else if dominates_constrained_by(&objs[j], &objs[i], constraints) {
                dominated_by[i] += 1;
            }
        }
    }
    let mut rank = vec![usize::MAX; n];
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    let mut r = 0;
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            rank[i] = r;
            for &j in &dominates[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        current = next;
        r += 1;
    }
    rank
}

/// Deb's constrained-domination: feasibility first (violation of the
/// accuracy bound on objective 0), Pareto second.
pub fn dominates_constrained<const M: usize>(a: &[f64; M], b: &[f64; M], bound: f64) -> bool {
    dominates_constrained_by(a, b, &Constraints::loss_only(bound))
}

/// [`dominates_constrained`] under a full [`Constraints`] set: the same
/// Deb rule, with the violation scalar summing every constraint
/// (accuracy bound + optional timing cap).
pub fn dominates_constrained_by<const M: usize>(
    a: &[f64; M],
    b: &[f64; M],
    constraints: &Constraints,
) -> bool {
    let va = constraints.violation(a);
    let vb = constraints.violation(b);
    if va == 0.0 && vb > 0.0 {
        return true;
    }
    if va > 0.0 && vb == 0.0 {
        return false;
    }
    if va > 0.0 && vb > 0.0 {
        return va < vb;
    }
    dominates(a, b)
}

/// Plain Pareto dominance (all objectives minimized): no axis worse,
/// at least one strictly better.
pub fn dominates<const M: usize>(a: &[f64; M], b: &[f64; M]) -> bool {
    let mut strictly_better = false;
    for k in 0..M {
        if a[k] > b[k] {
            return false;
        }
        if a[k] < b[k] {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Crowding distance within one front (NSGA-II diversity measure):
/// per-objective span-normalized nearest-neighbor gaps, summed over all
/// M axes; extreme points of every axis get infinite distance.
pub fn crowding_distance<const M: usize>(objs: &[[f64; M]], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    for obj in 0..M {
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            objs[front[a]][obj].partial_cmp(&objs[front[b]][obj]).unwrap()
        });
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let span = objs[front[order[m - 1]]][obj] - objs[front[order[0]]][obj];
        if span <= 0.0 {
            continue;
        }
        for w in 1..m - 1 {
            let prev = objs[front[order[w - 1]]][obj];
            let next = objs[front[order[w + 1]]][obj];
            dist[order[w]] += (next - prev) / span;
        }
    }
    dist
}

/// Extract the feasible non-dominated front from a set of individuals.
pub fn pareto_front<const M: usize>(pop: &[Individual<M>], bound: f64) -> Vec<Individual<M>> {
    pareto_front_by(pop, &Constraints::loss_only(bound))
}

/// [`pareto_front`] under a full [`Constraints`] set: individuals
/// violating *any* constraint (accuracy bound or timing cap) are
/// excluded outright — with `--max-delay` active, every front member is
/// guaranteed to meet the cap.
pub fn pareto_front_by<const M: usize>(
    pop: &[Individual<M>],
    constraints: &Constraints,
) -> Vec<Individual<M>> {
    let mut front: Vec<Individual<M>> = Vec::new();
    for ind in pop {
        if !constraints.feasible(&ind.objs) {
            continue;
        }
        if pop
            .iter()
            .any(|o| constraints.feasible(&o.objs) && dominates(&o.objs, &ind.objs))
        {
            continue;
        }
        // Dedup identical objective points.
        if front.iter().any(|f| f.objs == ind.objs) {
            continue;
        }
        front.push(ind.clone());
    }
    front.sort_by(|a, b| a.objs[0].partial_cmp(&b.objs[0]).unwrap());
    front
}

/// Deterministic front-union merge: concatenate per-shard fronts *in
/// shard order* and re-extract the feasible non-dominated front.
///
/// When the shards are contiguous slices of one population (the island
/// model's invariant, [`evaluate_islands`]), this reproduces
/// `pareto_front_by(whole population)` bit-identically, genome identity
/// included: a globally non-dominated individual survives its own
/// shard's front (fewer competitors) and then the merge; a shard-local
/// survivor dominated by another shard's member dies in the merge; and
/// because concatenating contiguous shards in shard order restores the
/// population order, the first-occurrence dedup of identical objective
/// vectors picks the same representative either way. Pinned by the
/// island determinism suite.
pub fn merge_front_union<const M: usize>(
    shard_fronts: &[Vec<Individual<M>>],
    constraints: &Constraints,
) -> Vec<Individual<M>> {
    let union: Vec<Individual<M>> = shard_fronts.iter().flatten().cloned().collect();
    pareto_front_by(&union, constraints)
}

/// The optimizer, const-generic over objective arity `M` (objective 0
/// is always the constrained accuracy loss).
pub struct Nsga2<'a, const M: usize = 2> {
    pub spec: GaSpec,
    pub genome_len: usize,
    pub evaluator: &'a dyn Evaluator<M>,
    /// Worker threads of the evaluation fan-out; `0` = auto
    /// ([`threads::default_jobs`]). Any value yields bit-identical
    /// results — jobs only sets how wide each generation evaluates.
    pub jobs: usize,
    /// Extra domain-informed individuals injected into the initial
    /// population (e.g. [`crate::accum::truncation_seeds`]).
    pub seeds: Vec<BitVec>,
    /// Optional `(objective axis, cap)` hard timing constraint
    /// (`--max-delay` on the objective's delay axis) folded into
    /// constrained domination alongside the accuracy bound.
    pub max_delay: Option<(usize, f64)>,
    /// Island count of the evaluation sharding (`--islands`; `1` =
    /// classic single-island run). Any value yields bit-identical
    /// results — see [`evaluate_islands`].
    pub islands: usize,
    /// Generations between ring-migration steps (shard→island rotation)
    /// of the island model; ignored at `islands == 1`.
    pub migration_interval: usize,
}

impl<'a, const M: usize> Nsga2<'a, M> {
    pub fn new(spec: GaSpec, genome_len: usize, evaluator: &'a dyn Evaluator<M>) -> Self {
        Nsga2 {
            spec,
            genome_len,
            evaluator,
            jobs: 0,
            seeds: Vec::new(),
            max_delay: None,
            islands: 1,
            migration_interval: DEFAULT_MIGRATION_INTERVAL,
        }
    }

    /// Builder-style seed injection.
    pub fn with_seeds(mut self, seeds: Vec<BitVec>) -> Self {
        self.seeds = seeds;
        self
    }

    /// Builder-style worker count (`0` = auto).
    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs;
        self
    }

    /// Builder-style island count (`0`/`1` = single island). Evaluation
    /// shards across `islands` sub-fan-outs with deterministic ring
    /// migration; results are bit-identical at any count.
    pub fn with_islands(mut self, islands: usize) -> Self {
        self.islands = islands.max(1);
        self
    }

    /// Builder-style migration interval: generations between ring
    /// rotations of the shard→island assignment (must be >= 1).
    pub fn with_migration_interval(mut self, interval: usize) -> Self {
        assert!(interval >= 1, "migration interval must be >= 1");
        self.migration_interval = interval;
        self
    }

    /// Builder-style timing cap: `Some((axis, cap_ms))` makes objective
    /// `axis` a hard constraint (`--max-delay`). The axis must be a
    /// cost axis (`1..M`); `None` leaves selection unconstrained.
    pub fn with_max_delay(mut self, max_delay: Option<(usize, f64)>) -> Self {
        if let Some((axis, _)) = max_delay {
            assert!(
                (1..M).contains(&axis),
                "delay axis {axis} out of range for arity {M}"
            );
        }
        self.max_delay = max_delay;
        self
    }

    /// The full constraint set selection runs under.
    fn constraints(&self) -> Constraints {
        Constraints { acc_loss_bound: self.spec.acc_loss_bound, max_delay: self.max_delay }
    }

    /// Tally `--max-delay` violations in one evaluated batch. Runs on
    /// the GA thread over the full (pre-dedup) objective stream, so the
    /// tally is a pure function of the genome sequence — deterministic
    /// across `--jobs` widths, hence a `Counter`.
    fn count_violations(&self, objs: &[[f64; M]]) {
        if let Some((axis, cap)) = self.max_delay {
            let n = objs.iter().filter(|o| o[axis] > cap).count();
            telemetry::count(Counter::GaConstraintViolations, n as u64);
        }
    }

    fn resolved_jobs(&self) -> usize {
        if self.jobs == 0 {
            threads::default_jobs()
        } else {
            self.jobs
        }
    }

    /// Run the optimization; `log` receives one line per generation.
    pub fn run(&self, mut log: impl FnMut(usize, &GaResult<M>)) -> GaResult<M> {
        let mut rng = Rng::new(self.spec.seed ^ 0x4E53_4741);
        let pop_size = self.spec.population.max(4);

        // Biased initial population (paper: semi-random chromosomes biased
        // toward non-approximated summand bits) + the exact chromosome as
        // an anchor so accuracy loss 0 is always reachable.
        let mut genomes: Vec<BitVec> = Vec::with_capacity(pop_size);
        genomes.push(BitVec::ones(self.genome_len));
        for seed in self.seeds.iter().take(pop_size.saturating_sub(1)) {
            assert_eq!(seed.len(), self.genome_len, "seed length mismatch");
            genomes.push(seed.clone());
        }
        while genomes.len() < pop_size {
            // Mostly biased toward keeping bits (paper §III-D1), with a
            // diverse low-keep tail for exploration.
            let keep = if rng.chance(0.7) {
                self.spec.init_keep_prob - 0.1 * rng.f64()
            } else {
                0.45 + 0.5 * rng.f64()
            };
            let bools: Vec<bool> =
                (0..self.genome_len).map(|_| rng.chance(keep)).collect();
            genomes.push(BitVec::from_bools(&bools));
        }
        let jobs = self.resolved_jobs();
        let constraints = self.constraints();
        // Island model: the initial population evaluates at ring round 0;
        // each generation's offspring at round `generation /
        // migration_interval`, so the shard→island assignment rotates at
        // fixed generation boundaries. Round only steers work placement
        // (`evaluate_islands`); scores are round-independent.
        let objs = evaluate_islands(self.evaluator, &genomes, jobs, self.islands, 0);
        self.count_violations(&objs);
        let mut pop: Vec<Individual<M>> = genomes
            .into_iter()
            .zip(objs)
            .map(|(genome, objs)| Individual { genome, objs })
            .collect();

        let mut history = Vec::new();
        for generation in 0..self.spec.generations {
            let _sp = crate::span!("generation");
            telemetry::count(Counter::GaGenerations, 1);
            // --- variation: binary tournament -> crossover -> mutation
            let ranks = non_dominated_sort_by(
                &pop.iter().map(|i| i.objs).collect::<Vec<_>>(),
                &constraints,
            );
            let crowd = full_crowding(&pop, &ranks);
            let mut offspring_genomes = Vec::with_capacity(pop_size);
            while offspring_genomes.len() < pop_size {
                let p1 = tournament(&mut rng, &ranks, &crowd);
                let p2 = tournament(&mut rng, &ranks, &crowd);
                let (mut c1, mut c2) = if rng.chance(self.spec.crossover_rate) {
                    uniform_crossover(&mut rng, &pop[p1].genome, &pop[p2].genome)
                } else {
                    (pop[p1].genome.clone(), pop[p2].genome.clone())
                };
                mutate(&mut rng, &mut c1, self.spec.mutation_rate);
                mutate(&mut rng, &mut c2, self.spec.mutation_rate);
                offspring_genomes.push(c1);
                if offspring_genomes.len() < pop_size {
                    offspring_genomes.push(c2);
                }
            }
            let n_off = offspring_genomes.len();
            // detlint: allow(wallclock) — debug-level throughput log only,
            // never feeds scores or selection.
            let t0 = std::time::Instant::now();
            let round = generation / self.migration_interval;
            let off_objs =
                evaluate_islands(self.evaluator, &offspring_genomes, jobs, self.islands, round);
            self.count_violations(&off_objs);
            if telemetry::log_enabled(telemetry::Level::Debug) {
                let dt = t0.elapsed().as_secs_f64().max(1e-9);
                telemetry::debug(
                    "ga",
                    &format!(
                        "gen {generation}: {n_off} genomes in {:.1} ms \
                         ({:.0} genomes/s, jobs {jobs})",
                        dt * 1e3,
                        n_off as f64 / dt
                    ),
                );
            }
            let offspring: Vec<Individual<M>> = offspring_genomes
                .into_iter()
                .zip(off_objs)
                .map(|(genome, objs)| Individual { genome, objs })
                .collect();

            // --- environmental selection on the merged population
            pop.extend(offspring);
            pop = select(pop, pop_size, &constraints);
            telemetry::gauge(Gauge::GaPopulation, pop.len() as u64);

            // --- logging
            let best2 = best_area_at(&pop, 0.02);
            let best5 = best_area_at(&pop, 0.05);
            history.push((best2, best5));
            let snapshot = GaResult {
                front: pareto_front_by(&pop, &constraints),
                population: Vec::new(),
                history: history.clone(),
            };
            log(generation, &snapshot);
        }

        let front = pareto_front_by(&pop, &constraints);
        GaResult { population: pop, front, history }
    }
}

fn full_crowding<const M: usize>(pop: &[Individual<M>], ranks: &[usize]) -> Vec<f64> {
    let objs: Vec<[f64; M]> = pop.iter().map(|i| i.objs).collect();
    let max_rank = ranks.iter().copied().max().unwrap_or(0);
    let mut crowd = vec![0.0; pop.len()];
    for r in 0..=max_rank {
        let front: Vec<usize> = (0..pop.len()).filter(|&i| ranks[i] == r).collect();
        let d = crowding_distance(&objs, &front);
        for (k, &i) in front.iter().enumerate() {
            crowd[i] = d[k];
        }
    }
    crowd
}

fn tournament(rng: &mut Rng, ranks: &[usize], crowd: &[f64]) -> usize {
    let a = rng.below(ranks.len());
    let b = rng.below(ranks.len());
    if ranks[a] < ranks[b] || (ranks[a] == ranks[b] && crowd[a] > crowd[b]) {
        a
    } else {
        b
    }
}

fn uniform_crossover(rng: &mut Rng, a: &BitVec, b: &BitVec) -> (BitVec, BitVec) {
    let mut c1 = a.clone();
    let mut c2 = b.clone();
    for i in 0..a.len() {
        if rng.chance(0.5) {
            let (va, vb) = (a.get(i), b.get(i));
            c1.set(i, vb);
            c2.set(i, va);
        }
    }
    (c1, c2)
}

fn mutate(rng: &mut Rng, g: &mut BitVec, rate: f64) {
    // Expected flips = rate * len; sample count then positions (fast for
    // the low rates the paper uses).
    let expected = rate * g.len() as f64;
    let n_flips = {
        // Poisson-ish: floor + bernoulli remainder.
        let base = expected.floor() as usize;
        base + usize::from(rng.chance(expected - base as f64))
    };
    for _ in 0..n_flips {
        let i = rng.below(g.len());
        g.flip(i);
    }
}

/// NSGA-II environmental selection: fill by fronts, break the last front
/// by crowding distance.
fn select<const M: usize>(
    pop: Vec<Individual<M>>,
    target: usize,
    constraints: &Constraints,
) -> Vec<Individual<M>> {
    let objs: Vec<[f64; M]> = pop.iter().map(|i| i.objs).collect();
    let ranks = non_dominated_sort_by(&objs, constraints);
    let max_rank = ranks.iter().copied().max().unwrap_or(0);
    let mut out: Vec<Individual<M>> = Vec::with_capacity(target);
    for r in 0..=max_rank {
        let front: Vec<usize> = (0..pop.len()).filter(|&i| ranks[i] == r).collect();
        if out.len() + front.len() <= target {
            for &i in &front {
                out.push(pop[i].clone());
            }
        } else {
            let d = crowding_distance(&objs, &front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| d[b].partial_cmp(&d[a]).unwrap());
            for &k in order.iter().take(target - out.len()) {
                out.push(pop[front[k]].clone());
            }
            break;
        }
        if out.len() == target {
            break;
        }
    }
    out
}

/// Smallest primary cost (objective 1) among individuals with accuracy
/// loss <= `loss`.
pub fn best_area_at<const M: usize>(pop: &[Individual<M>], loss: f64) -> f64 {
    pop.iter()
        .filter(|i| i.objs[0] <= loss)
        .map(|i| i.objs[1])
        .fold(f64::INFINITY, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GaSpec;
    use crate::util::prop;

    /// Toy evaluator: loss = fraction of zero bits in the first half
    /// (removing early bits hurts "accuracy"), area = count of ones
    /// (keeping bits costs area). True Pareto front: remove only
    /// second-half bits.
    struct Toy {
        len: usize,
    }
    struct ToyWorker<'a> {
        ev: &'a Toy,
    }
    impl EvalWorker for ToyWorker<'_> {
        fn eval_one(&mut self, g: &BitVec) -> [f64; 2] {
            let half = self.ev.len / 2;
            let zeros_front = (0..half).filter(|&i| !g.get(i)).count() as f64 / half as f64;
            [0.3 * zeros_front, g.count_ones() as f64]
        }
    }
    impl Evaluator for Toy {
        fn worker(&self) -> Box<dyn EvalWorker + '_> {
            Box::new(ToyWorker { ev: self })
        }
    }

    fn spec() -> GaSpec {
        GaSpec {
            population: 40,
            generations: 25,
            mutation_rate: 0.02,
            crossover_rate: 0.9,
            acc_loss_bound: 0.15,
            init_keep_prob: 0.9,
            seed: 5,
        }
    }

    #[test]
    fn toy_converges_to_second_half_removal() {
        let toy = Toy { len: 40 };
        let ga: Nsga2<2> = Nsga2::new(spec(), 40, &toy);
        let result = ga.run(|_, _| {});
        // Expect a zero-loss solution with area close to 20 (only first
        // half kept).
        let best = result
            .front
            .iter()
            .filter(|i| i.objs[0] == 0.0)
            .map(|i| i.objs[1])
            .fold(f64::INFINITY, f64::min);
        // Ideal is 20 (only the first half kept); anything well below the
        // 40-bit exact genome demonstrates convergence.
        assert!(best <= 27.0, "best zero-loss area {best}");
    }

    #[test]
    fn front_is_mutually_non_dominating() {
        let toy = Toy { len: 30 };
        let ga: Nsga2<2> = Nsga2::new(spec(), 30, &toy);
        let result = ga.run(|_, _| {});
        for a in &result.front {
            for b in &result.front {
                assert!(
                    !dominates(&a.objs, &b.objs),
                    "front contains dominated point {:?} < {:?}",
                    a.objs,
                    b.objs
                );
            }
        }
        assert!(!result.front.is_empty());
    }

    #[test]
    fn respects_accuracy_bound_in_front() {
        let toy = Toy { len: 30 };
        let ga: Nsga2<2> = Nsga2::new(spec(), 30, &toy);
        let result = ga.run(|_, _| {});
        for ind in &result.front {
            assert!(ind.objs[0] <= 0.15 + 1e-12);
        }
    }

    #[test]
    fn non_dominated_sort_ranks() {
        // Three points: A dominates B; C incomparable to both on a
        // different trade-off.
        let objs = vec![[0.0, 1.0], [0.1, 2.0], [0.05, 0.5]];
        let ranks = non_dominated_sort(&objs, 1.0);
        assert_eq!(ranks[0], 0);
        assert_eq!(ranks[2], 0);
        assert_eq!(ranks[1], 1);
    }

    #[test]
    fn constrained_domination_feasible_first() {
        // Infeasible (loss 0.5 > bound 0.15) loses to any feasible point.
        let objs = vec![[0.5, 0.0], [0.1, 100.0]];
        let ranks = non_dominated_sort(&objs, 0.15);
        assert_eq!(ranks[1], 0);
        assert_eq!(ranks[0], 1);
    }

    #[test]
    fn constraints_loss_only_matches_legacy_rule() {
        // The delegating wrappers must not change the legacy semantics:
        // with no timing cap, the _by variants are the old functions.
        let c = Constraints::loss_only(0.15);
        let pts = [[0.0, 1.0], [0.1, 2.0], [0.5, 0.1], [0.2, 0.0]];
        for a in &pts {
            assert_eq!(c.violation(a), (a[0] - 0.15).max(0.0));
            for b in &pts {
                assert_eq!(
                    dominates_constrained(a, b, 0.15),
                    dominates_constrained_by(a, b, &c)
                );
            }
        }
        let ranks_old = non_dominated_sort(&pts, 0.15);
        let ranks_new = non_dominated_sort_by(&pts, &c);
        assert_eq!(ranks_old, ranks_new);
    }

    #[test]
    fn timing_cap_drives_constrained_domination() {
        // Axis 2 is "delay" with a 10.0 cap: a timing violator loses to
        // any timing-feasible point even when it Pareto-dominates it,
        // and among violators the smaller violation wins.
        let c = Constraints { acc_loss_bound: 0.15, max_delay: Some((2, 10.0)) };
        let feasible = [0.1, 50.0, 9.0];
        let violator = [0.0, 1.0, 12.0]; // better loss+cost, late
        let worse_violator = [0.0, 1.0, 20.0];
        assert!(c.feasible(&feasible));
        assert!(!c.feasible(&violator));
        assert_eq!(c.violation(&violator), 2.0);
        assert!(dominates_constrained_by(&feasible, &violator, &c));
        assert!(!dominates_constrained_by(&violator, &feasible, &c));
        assert!(dominates_constrained_by(&violator, &worse_violator, &c));
        // Violations sum across constraints: loss + delay.
        let double = [0.25, 1.0, 12.0];
        assert_eq!(c.violation(&double), (0.25 - 0.15) + 2.0);
    }

    #[test]
    fn pareto_front_by_excludes_timing_violators() {
        let c = Constraints { acc_loss_bound: 0.15, max_delay: Some((1, 10.0)) };
        let mk = |objs: [f64; 2]| Individual { genome: BitVec::zeros(4), objs };
        let pop = vec![
            mk([0.0, 12.0]), // dominates everything but violates the cap
            mk([0.05, 9.0]),
            mk([0.1, 8.0]),
            mk([0.2, 5.0]), // violates the accuracy bound
        ];
        let front = pareto_front_by(&pop, &c);
        let objs: Vec<[f64; 2]> = front.iter().map(|i| i.objs).collect();
        assert_eq!(objs, vec![[0.05, 9.0], [0.1, 8.0]]);
        // Without the cap the fast violator takes over the front.
        let unconstrained = pareto_front(&pop, 0.15);
        assert_eq!(unconstrained[0].objs, [0.0, 12.0]);
    }

    #[test]
    fn ga_front_meets_max_delay_and_counts_violations() {
        // End to end: with axis 1 capped, every front member meets the
        // cap, and the violation tally lands in the deterministic
        // counter block.
        let toy = Toy { len: 30 };
        let cap = 20.0;
        let before = telemetry::thread_block();
        let result = Nsga2::<2>::new(spec(), 30, &toy)
            .with_jobs(1)
            .with_max_delay(Some((1, cap)))
            .run(|_, _| {});
        let d = telemetry::thread_block().delta(&before);
        for ind in &result.front {
            assert!(ind.objs[1] <= cap, "front member over cap: {:?}", ind.objs);
        }
        assert!(!result.front.is_empty(), "capped run still yields a front");
        // The all-ones anchor (area 30 > cap) alone guarantees at least
        // one violation was evaluated and tallied.
        assert!(
            d.counters[Counter::GaConstraintViolations as usize] >= 1,
            "violations must be counted"
        );
    }

    #[test]
    fn max_delay_jobs_determinism() {
        // The capped run must stay bit-identical across jobs widths —
        // constraint handling lives entirely on the GA thread.
        let toy = Toy { len: 24 };
        let run = |jobs| {
            let before = telemetry::thread_block();
            let r = Nsga2::<2>::new(spec(), 24, &toy)
                .with_jobs(jobs)
                .with_max_delay(Some((1, 18.0)))
                .run(|_, _| {});
            let d = telemetry::thread_block().delta(&before);
            let objs: Vec<[f64; 2]> = r.front.iter().map(|i| i.objs).collect();
            (objs, r.history, d.counters[Counter::GaConstraintViolations as usize])
        };
        assert_eq!(run(1), run(8));
    }

    #[test]
    #[should_panic(expected = "delay axis 0 out of range")]
    fn max_delay_rejects_loss_axis() {
        let toy = Toy { len: 8 };
        let _ = Nsga2::<2>::new(spec(), 8, &toy).with_max_delay(Some((0, 1.0)));
    }

    #[test]
    fn crowding_extremes_infinite() {
        let objs = vec![[0.0, 3.0], [0.1, 2.0], [0.2, 1.0]];
        let front = vec![0, 1, 2];
        let d = crowding_distance(&objs, &front);
        assert!(d[0].is_infinite());
        assert!(d[2].is_infinite());
        assert!(d[1].is_finite());
    }

    #[test]
    fn prop_sort_rank0_is_nondominated() {
        prop::check("rank0 non-dominated", |rng, _| {
            let n = 3 + rng.below(20);
            let objs: Vec<[f64; 2]> =
                (0..n).map(|_| [rng.f64(), rng.f64() * 100.0]).collect();
            let ranks = non_dominated_sort(&objs, 2.0); // everything feasible
            for i in 0..n {
                if ranks[i] == 0 {
                    for j in 0..n {
                        if dominates(&objs[j], &objs[i]) {
                            return Err(format!("rank0 point {i} dominated by {j}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn history_tracks_generations() {
        let toy = Toy { len: 20 };
        let mut gens_seen = 0;
        let ga: Nsga2<2> = Nsga2::new(spec(), 20, &toy);
        let result = ga.run(|g, _| {
            gens_seen = g + 1;
        });
        assert_eq!(gens_seen, 25);
        assert_eq!(result.history.len(), 25);
    }

    #[test]
    fn deterministic_given_seed() {
        let toy = Toy { len: 24 };
        let r1 = Nsga2::<2>::new(spec(), 24, &toy).run(|_, _| {});
        let r2 = Nsga2::<2>::new(spec(), 24, &toy).run(|_, _| {});
        let o1: Vec<[f64; 2]> = r1.front.iter().map(|i| i.objs).collect();
        let o2: Vec<[f64; 2]> = r2.front.iter().map(|i| i.objs).collect();
        assert_eq!(o1, o2);
    }

    #[test]
    fn panicking_worker_propagates_and_evaluator_survives() {
        // The generation-level panic audit: one chromosome whose
        // evaluation panics must fail the whole `evaluate_parallel` call
        // loudly (scope join re-raises — never a hang), and the shared
        // evaluator state must stay usable for the next generation.
        struct Bomb {
            memo: crate::util::ShardedMap<BitVec, [f64; 2]>,
        }
        struct BombWorker<'a> {
            ev: &'a Bomb,
        }
        impl EvalWorker for BombWorker<'_> {
            fn eval_one(&mut self, g: &BitVec) -> [f64; 2] {
                if let Some(hit) = self.ev.memo.get(g) {
                    return hit;
                }
                if g.count_ones() == 0 {
                    panic!("all-zero genome");
                }
                let objs = [0.0, g.count_ones() as f64];
                self.ev.memo.insert(g.clone(), objs);
                objs
            }
        }
        impl Evaluator for Bomb {
            fn worker(&self) -> Box<dyn EvalWorker + '_> {
                Box::new(BombWorker { ev: self })
            }
        }

        let ev = Bomb { memo: crate::util::ShardedMap::new() };
        let mut genomes: Vec<BitVec> = (1..=24)
            .map(|i| {
                let bools: Vec<bool> = (0..16).map(|b| b < i % 16 + 1).collect();
                BitVec::from_bools(&bools)
            })
            .collect();
        genomes.insert(13, BitVec::zeros(16));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            evaluate_parallel(&ev, &genomes, 4)
        }));
        assert!(r.is_err(), "a panicking evaluation must propagate");
        // Same evaluator, sane batch: the memo (possibly poisoned
        // mid-probe) must keep serving.
        genomes.remove(13);
        let objs = evaluate_parallel(&ev, &genomes, 4);
        assert_eq!(objs.len(), genomes.len());
        for (g, o) in genomes.iter().zip(&objs) {
            assert_eq!(o[1], g.count_ones() as f64);
        }
    }

    #[test]
    fn evaluate_parallel_matches_serial_and_dedups() {
        let toy = Toy { len: 32 };
        let mut rng = Rng::new(23);
        let mut genomes: Vec<BitVec> = (0..40)
            .map(|_| {
                let bools: Vec<bool> = (0..32).map(|_| rng.chance(0.5)).collect();
                BitVec::from_bools(&bools)
            })
            .collect();
        // Inject duplicates so the dedup/scatter path is exercised.
        let dup = genomes[0].clone();
        genomes.push(dup.clone());
        genomes.insert(7, dup);
        let serial = evaluate_parallel(&toy, &genomes, 1);
        let parallel = evaluate_parallel(&toy, &genomes, 8);
        assert_eq!(serial, parallel);
        assert_eq!(serial.len(), genomes.len());
        assert_eq!(serial[0], serial[7]);
        assert_eq!(serial[0], *serial.last().unwrap());
    }

    #[test]
    fn evaluate_islands_matches_parallel_any_shape() {
        // The sharded path must be bit-identical to `evaluate_parallel`
        // for every island count, ring round, and jobs width — including
        // K > unique genomes (empty shards) and duplicated inputs.
        let toy = Toy { len: 32 };
        let mut rng = Rng::new(91);
        let mut genomes: Vec<BitVec> = (0..37)
            .map(|_| {
                let bools: Vec<bool> = (0..32).map(|_| rng.chance(0.5)).collect();
                BitVec::from_bools(&bools)
            })
            .collect();
        let dup = genomes[3].clone();
        genomes.push(dup.clone());
        genomes.insert(11, dup);
        let reference = evaluate_parallel(&toy, &genomes, 1);
        for islands in [1, 2, 4, 7, 64] {
            for round in [0, 1, 2, 5] {
                for jobs in [1, 8] {
                    let got = evaluate_islands(&toy, &genomes, jobs, islands, round);
                    assert_eq!(
                        got, reference,
                        "islands {islands}, round {round}, jobs {jobs}"
                    );
                }
            }
        }
        // Empty batch never panics.
        assert!(evaluate_islands(&toy, &[], 4, 3, 1).is_empty());
    }

    #[test]
    fn evaluate_islands_counters_match_single_island() {
        // The deterministic counter totals are part of the contract: the
        // island path must count the same events as one island.
        let toy = Toy { len: 16 };
        let genomes: Vec<BitVec> = (0..9)
            .map(|i| {
                let bools: Vec<bool> = (0..16).map(|b| b <= i).collect();
                BitVec::from_bools(&bools)
            })
            .collect();
        let counts = |islands: usize| {
            let before = telemetry::thread_block();
            let _ = evaluate_islands(&toy, &genomes, 8, islands, 1);
            telemetry::thread_block().delta(&before).counters
        };
        let one = counts(1);
        for islands in [2, 3, 4] {
            assert_eq!(counts(islands), one, "islands {islands}");
        }
        assert_eq!(one[Counter::GaEvaluateCalls as usize], 1);
        assert_eq!(one[Counter::GaGenomesIn as usize], 9);
        assert_eq!(one[Counter::GaGenomesUnique as usize], 9);
    }

    #[test]
    fn merge_front_union_matches_global_front() {
        // Contiguous shards of one population: per-shard fronts merged
        // by front union must reproduce the global front bit-identically
        // (genomes included) — the island model's merge argument.
        let mut rng = Rng::new(57);
        let c = Constraints { acc_loss_bound: 0.6, max_delay: Some((1, 80.0)) };
        for trial in 0..20 {
            let n = 8 + rng.below(40);
            let pop: Vec<Individual<2>> = (0..n)
                .map(|i| {
                    // Coarse grid so identical objective vectors (the
                    // dedup path) and infeasible points both occur.
                    let objs =
                        [(rng.below(8) as f64) * 0.1, (rng.below(10) as f64) * 10.0];
                    let bools: Vec<bool> = (0..8).map(|b| (i >> b) & 1 == 1).collect();
                    Individual { genome: BitVec::from_bools(&bools), objs }
                })
                .collect();
            let global = pareto_front_by(&pop, &c);
            for islands in [1usize, 2, 3, 5] {
                let shard_size = pop.len().div_ceil(islands);
                let shard_fronts: Vec<Vec<Individual<2>>> = (0..islands)
                    .map(|k| {
                        let lo = (k * shard_size).min(pop.len());
                        let hi = ((k + 1) * shard_size).min(pop.len());
                        pareto_front_by(&pop[lo..hi], &c)
                    })
                    .collect();
                let merged = merge_front_union(&shard_fronts, &c);
                let key = |f: &[Individual<2>]| -> Vec<(Vec<bool>, [f64; 2])> {
                    f.iter().map(|i| (i.genome.iter().collect(), i.objs)).collect()
                };
                assert_eq!(
                    key(&merged),
                    key(&global),
                    "trial {trial}, islands {islands}"
                );
            }
        }
    }

    #[test]
    fn islands_do_not_change_ga_result() {
        // The island tentpole invariant at GA level: any island count ×
        // jobs width produces a bit-identical GaResult, including the
        // per-generation log stream.
        let toy = Toy { len: 30 };
        let run = |islands: usize, jobs: usize| {
            let mut logs = Vec::new();
            let r = Nsga2::<2>::new(spec(), 30, &toy)
                .with_jobs(jobs)
                .with_islands(islands)
                .with_migration_interval(2)
                .run(|g, snap| logs.push((g, snap.history.clone())));
            let fronts: Vec<(Vec<bool>, [f64; 2])> =
                r.front.iter().map(|i| (i.genome.iter().collect(), i.objs)).collect();
            let pops: Vec<(Vec<bool>, [f64; 2])> = r
                .population
                .iter()
                .map(|i| (i.genome.iter().collect(), i.objs))
                .collect();
            (fronts, pops, r.history, logs)
        };
        let reference = run(1, 1);
        for islands in [2, 4] {
            for jobs in [1, 8] {
                assert_eq!(run(islands, jobs), reference, "islands {islands}, jobs {jobs}");
            }
        }
    }

    #[test]
    fn jobs_do_not_change_ga_result() {
        // The tentpole invariant at GA level: any worker count produces a
        // bit-identical GaResult (fronts, objectives, history, logs).
        let toy = Toy { len: 30 };
        let mut log1 = Vec::new();
        let mut log8 = Vec::new();
        let r1 = Nsga2::<2>::new(spec(), 30, &toy).with_jobs(1).run(|g, snap| {
            log1.push((g, snap.history.clone()));
        });
        let r8 = Nsga2::<2>::new(spec(), 30, &toy).with_jobs(8).run(|g, snap| {
            log8.push((g, snap.history.clone()));
        });
        assert_eq!(log1, log8);
        assert_eq!(r1.history, r8.history);
        let pair = |r: &GaResult| -> (Vec<[f64; 2]>, Vec<BitVec>) {
            (
                r.population.iter().map(|i| i.objs).collect(),
                r.population.iter().map(|i| i.genome.clone()).collect(),
            )
        };
        assert_eq!(pair(&r1), pair(&r8));
        let fronts = |r: &GaResult| -> Vec<(Vec<bool>, [f64; 2])> {
            r.front.iter().map(|i| (i.genome.iter().collect(), i.objs)).collect()
        };
        assert_eq!(fronts(&r1), fronts(&r8));
    }
}
