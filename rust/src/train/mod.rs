//! Training system: float pre-training + power-of-2 QAT (paper §III-A/B).
//!
//! Two QAT engines share one interface:
//! * [`train_native`] — the in-crate float trainer (`FloatMlp::train`)
//!   with straight-through po2/QRelu quantizers;
//! * [`PjrtTrainer`] — drives the AOT-compiled `train_step_<ds>` program
//!   (Layer-2 JAX forward+backward+Adam) from Rust, one minibatch per
//!   PJRT dispatch. The paper's QKeras QAT maps to this path.
//!
//! Both end in [`crate::model::QuantMlp::from_float`], which extracts the
//! integer po2 model and calibrates the QRelu truncation.

pub mod pjrt;

use crate::config::RunConfig;
use crate::datasets::{QuantDataset, Split};
use crate::model::float_mlp::TrainOpts;
use crate::model::{FloatMlp, QuantMlp};

pub use pjrt::PjrtTrainer;

/// A trained + quantized model with its bookkeeping accuracies.
#[derive(Clone, Debug)]
pub struct TrainedModel {
    pub float: FloatMlp,
    pub qmlp: QuantMlp,
    /// Float model accuracy on the test split (the paper's baseline
    /// accuracy column).
    pub acc_float_test: f64,
    /// Quantized (QAT-only) accuracy on train — the GA's reference.
    pub acc_q_train: f64,
    /// Quantized (QAT-only) accuracy on test (Table III "QAT Only").
    pub acc_q_test: f64,
}

fn base_opts(cfg: &RunConfig) -> TrainOpts {
    TrainOpts {
        epochs: cfg.train.epochs,
        batch_size: cfg.train.batch_size,
        lr: cfg.train.lr,
        seed: cfg.train.seed,
        qat_po2: false,
        weight_decay: 1e-4,
        class_balance: true,
    }
}

/// Float pre-training with a small randomized restart search (the paper
/// trains with scikit-learn's randomized parameter optimization +
/// cross-validation): seeds x learning rates, scored on the train split.
/// Shared by the native pipeline and the PJRT pipeline (which runs QAT
/// through the AOT `train_step` afterwards).
pub fn train_float_search(cfg: &RunConfig, split: &Split) -> FloatMlp {
    let opts = base_opts(cfg);
    let mut best: Option<(f64, FloatMlp)> = None;
    for seed_off in 0..3u64 {
        for lr_mul in [1.0, 2.5] {
            let mut cand = FloatMlp::init(cfg.topology, cfg.train.seed + seed_off);
            cand.train(
                &split.train,
                &TrainOpts {
                    lr: cfg.train.lr * lr_mul,
                    seed: cfg.train.seed + seed_off,
                    ..opts.clone()
                },
            );
            let score = cand.accuracy(&split.train, false);
            if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                best = Some((score, cand));
            }
        }
    }
    best.unwrap().1
}

/// Native two-phase training: float restart search, then po2 QAT
/// fine-tune.
pub fn train_native(cfg: &RunConfig, split: &Split, qtrain: &QuantDataset, qtest: &QuantDataset) -> TrainedModel {
    let base_opts = base_opts(cfg);
    let float = train_float_search(cfg, split);
    let acc_float_test = float.accuracy(&split.test, false);

    // QAT fine-tune at reduced learning rates, keeping the better run
    // (paper: "QAT requires only few retraining epochs, even for the
    // most complex printed MLPs").
    let mut best_q: Option<(f64, FloatMlp)> = None;
    for lr_mul in [0.4, 0.1] {
        let mut qat = float.clone();
        qat.train(
            &split.train,
            &TrainOpts {
                epochs: (cfg.train.epochs / 2).max(10),
                lr: cfg.train.lr * lr_mul,
                qat_po2: true,
                weight_decay: 0.0,
                class_balance: false,
                ..base_opts.clone()
            },
        );
        let score = qat.accuracy(&split.train, true);
        if best_q.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
            best_q = Some((score, qat));
        }
    }
    let qat = best_q.unwrap().1;
    finish(float, qat, qtrain, qtest, acc_float_test)
}

/// Shared tail: quantize, calibrate, score.
pub fn finish(
    float: FloatMlp,
    qat: FloatMlp,
    qtrain: &QuantDataset,
    qtest: &QuantDataset,
    acc_float_test: f64,
) -> TrainedModel {
    let qmlp = QuantMlp::from_float(&qat, qtrain);
    let acc_q_train = qmlp.accuracy(qtrain, None);
    let acc_q_test = qmlp.accuracy(qtest, None);
    TrainedModel { float, qmlp, acc_float_test, acc_q_train, acc_q_test }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::builtin;
    use crate::datasets;

    #[test]
    fn native_training_pipeline() {
        let cfg = builtin::tiny();
        let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
        let tm = train_native(&cfg, &split, &qtrain, &qtest);
        assert!(tm.acc_float_test > 0.8, "float acc {}", tm.acc_float_test);
        assert!(
            tm.acc_q_test > tm.acc_float_test - 0.15,
            "QAT lost too much: {} vs {}",
            tm.acc_q_test,
            tm.acc_float_test
        );
        // Quantized weights must be po2 (sign/shift pairs by construction).
        assert!(tm.qmlp.l1.w.iter().any(|w| w.sign != 0));
    }
}
