//! PJRT-driven QAT: the Rust trainer owns the epoch/minibatch loop and
//! dispatches the AOT-compiled `train_step_<ds>` program (Layer-2 JAX
//! QAT forward + backward + Adam, lowered once at build time) for every
//! step. Parameters and optimizer state live in Rust between steps.

use crate::config::RunConfig;
use crate::datasets::{Dataset, QuantDataset, Split};
use crate::fixedpoint::INPUT_BITS;
use crate::model::FloatMlp;
use crate::runtime::{lit_f32, lit_f32_scalar, lit_i32, lit_i32_scalar, Literal, Runtime};
use crate::train::TrainedModel;
use crate::util::Rng;
use anyhow::Result;

/// QAT trainer over the `train_step` artifact.
pub struct PjrtTrainer<'rt> {
    runtime: &'rt Runtime,
    name: String,
}

impl<'rt> PjrtTrainer<'rt> {
    pub fn new(runtime: &'rt Runtime, name: &str) -> PjrtTrainer<'rt> {
        PjrtTrainer { runtime, name: name.to_string() }
    }

    /// Fine-tune `float` with QAT for `epochs`; returns the QAT weights.
    ///
    /// Inputs are snapped to the 4-bit grid (x_int/16) so training sees
    /// exactly the distribution the hardware will.
    pub fn finetune(
        &self,
        float: &FloatMlp,
        train: &Dataset,
        epochs: usize,
        lr: f64,
        seed: u64,
    ) -> Result<(FloatMlp, f32)> {
        let exe = self.runtime.load(&format!("train_step_{}", self.name))?;
        let bt = self.runtime.manifest.bt;
        let topo = float.topo;
        let (n0, h, o) = (topo.n_in, topo.n_hidden, topo.n_out);

        // Calibrate the QRelu range once, exactly like the native QAT.
        let act_max = {
            let mut probe = float.clone();
            probe.calibrate_act_max(train);
            probe.act_max
        };

        // QAT fine-tuning uses uniform sample weights (class re-balancing
        // would fight the already-learned decision boundaries — same
        // choice as the native QAT path).
        let class_w: Vec<f32> = vec![1.0; o];

        // Flatten parameters + Adam state.
        let flat = |m: &Vec<Vec<f64>>| -> Vec<f32> {
            m.iter().flatten().map(|&v| v as f32).collect()
        };
        let mut w1 = flat(&float.w1);
        let mut b1: Vec<f32> = float.b1.iter().map(|&v| v as f32).collect();
        let mut w2 = flat(&float.w2);
        let mut b2: Vec<f32> = float.b2.iter().map(|&v| v as f32).collect();
        let mut m_state = [vec![0f32; w1.len()], vec![0f32; h], vec![0f32; w2.len()], vec![0f32; o]];
        let mut v_state = m_state.clone();
        let mut step = 0i32;
        let mut last_loss = f32::NAN;

        // 4-bit-snapped inputs.
        let snap = |v: f64| -> f32 {
            let q = crate::fixedpoint::quantize_input(v, INPUT_BITS);
            q as f32 / (1u32 << INPUT_BITS) as f32
        };
        let n = train.y.len();
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(seed ^ 0x504A_5254);

        for _epoch in 0..epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(bt) {
                // Fixed batch shape: wrap around when the tail is short.
                let mut xb = vec![0f32; bt * n0];
                let mut yb = vec![0i32; bt];
                let mut swb = vec![0f32; bt];
                for k in 0..bt {
                    let idx = chunk[k % chunk.len()];
                    for j in 0..n0 {
                        xb[k * n0 + j] = snap(train.x[idx][j]);
                    }
                    yb[k] = train.y[idx] as i32;
                    // Tail wrap repeats samples; keep their weight so the
                    // batch mean stays unbiased across the epoch.
                    swb[k] = class_w[train.y[idx] % o];
                }
                let args: Vec<Literal> = vec![
                    lit_f32(&w1, &[h as i64, n0 as i64])?,
                    lit_f32(&b1, &[h as i64])?,
                    lit_f32(&w2, &[o as i64, h as i64])?,
                    lit_f32(&b2, &[o as i64])?,
                    lit_f32(&m_state[0], &[h as i64, n0 as i64])?,
                    lit_f32(&m_state[1], &[h as i64])?,
                    lit_f32(&m_state[2], &[o as i64, h as i64])?,
                    lit_f32(&m_state[3], &[o as i64])?,
                    lit_f32(&v_state[0], &[h as i64, n0 as i64])?,
                    lit_f32(&v_state[1], &[h as i64])?,
                    lit_f32(&v_state[2], &[o as i64, h as i64])?,
                    lit_f32(&v_state[3], &[o as i64])?,
                    lit_i32_scalar(step),
                    lit_f32(&xb, &[bt as i64, n0 as i64])?,
                    lit_i32(&yb, &[bt as i64])?,
                    lit_f32(&swb, &[bt as i64])?,
                    lit_f32_scalar(lr as f32),
                    lit_f32_scalar(act_max as f32),
                ];
                let outs = exe.run(&args)?;
                w1 = outs[0].to_vec::<f32>()?;
                b1 = outs[1].to_vec::<f32>()?;
                w2 = outs[2].to_vec::<f32>()?;
                b2 = outs[3].to_vec::<f32>()?;
                for (k, slot) in m_state.iter_mut().enumerate() {
                    *slot = outs[4 + k].to_vec::<f32>()?;
                }
                for (k, slot) in v_state.iter_mut().enumerate() {
                    *slot = outs[8 + k].to_vec::<f32>()?;
                }
                step = outs[12].to_vec::<i32>()?[0];
                last_loss = outs[13].to_vec::<f32>()?[0];
            }
        }

        // Rebuild a FloatMlp from the trained parameters.
        let unflat = |v: &[f32], rows: usize, cols: usize| -> Vec<Vec<f64>> {
            (0..rows)
                .map(|r| (0..cols).map(|c| v[r * cols + c] as f64).collect())
                .collect()
        };
        let out = FloatMlp {
            topo,
            w1: unflat(&w1, h, n0),
            b1: b1.iter().map(|&v| v as f64).collect(),
            w2: unflat(&w2, o, h),
            b2: b2.iter().map(|&v| v as f64).collect(),
            act_max,
        };
        Ok((out, last_loss))
    }

    /// Full pipeline tail: float model in, [`TrainedModel`] out. Tries
    /// two QAT learning rates and keeps the better integer model (same
    /// policy as the native trainer).
    pub fn train(
        &self,
        cfg: &RunConfig,
        float: &FloatMlp,
        split: &Split,
        qtrain: &QuantDataset,
        qtest: &QuantDataset,
    ) -> Result<TrainedModel> {
        let acc_float_test = float.accuracy(&split.test, false);
        let epochs = (cfg.train.epochs / 2).max(10);
        let mut best: Option<(f64, FloatMlp)> = None;
        for lr_mul in [0.4, 0.1] {
            for seed_off in 0..2u64 {
                let (qat, _loss) = self.finetune(
                    float,
                    &split.train,
                    epochs,
                    cfg.train.lr * lr_mul,
                    cfg.train.seed + seed_off,
                )?;
                let score =
                    crate::model::QuantMlp::from_float(&qat, qtrain).accuracy(qtrain, None);
                if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                    best = Some((score, qat));
                }
            }
        }
        let qat = best.unwrap().1;
        Ok(crate::train::finish(float.clone(), qat, qtrain, qtest, acc_float_test))
    }
}
