//! # printed-mlp
//!
//! Reproduction of *"Bespoke Approximation of Multiplication-Accumulation
//! and Activation Targeting Printed Multilayer Perceptrons"* (Afentaki et
//! al., ICCAD 2023) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate is the Layer-3 coordinator: it owns the design-automation
//! pipeline (train → QAT → genetic accumulation approximation → approximate
//! Argmax → gate-level synthesis → hardware analysis → Pareto reporting)
//! and drives AOT-compiled XLA programs (Layer-2 JAX model calling the
//! Layer-1 Pallas masked-MAC kernel) through PJRT (behind the `xla`
//! cargo feature; stubbed out in the default offline build).
//!
//! Circuit evaluation runs on two engines in [`sim`]: the scalar
//! reference simulator and the bit-parallel *wave* engine
//! ([`sim::wave`]) — 64 input vectors per pass over `u64` lane words —
//! which powers toggle-activity measurement, the hardware-equivalence
//! sweeps, and the circuit-in-the-loop GA backend
//! ([`runtime::evaluator::CircuitEvaluator`], `--backend circuit`).
//!
//! Synthesis is a pass manager ([`synth`]) over a gate-level IR that
//! also has a parameterized [`netlist::Template`] form: mask-controlled
//! summand bits are `Param` literal sites, so the circuit backend can
//! re-synthesize each chromosome incrementally ([`synth::incremental`],
//! `--synth incremental|full`) — only the fanout cones of flipped mask
//! bits are re-simplified and re-simulated.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index.

// Compiler-enforced no-unsafe discipline (DESIGN.md §7): exactly two
// sanctioned sites carry a scoped `#[allow(unsafe_code)]` with a SAFETY
// argument — the disjoint-slot output pointer in `util::threads` and the
// PJRT executable's Send/Sync impls in `runtime::pjrt`. Everything else,
// including `vendor/anyhow` (`#![forbid(unsafe_code)]`), is unsafe-free;
// a new `unsafe` block anywhere else fails the build.
#![deny(unsafe_code)]

pub mod util;
pub mod config;
pub mod fixedpoint;
pub mod datasets;
pub mod model;
pub mod accum;
pub mod area;
pub mod ga;
pub mod hungarian;
pub mod argmax;
pub mod netlist;
pub mod synth;
pub mod egfet;
pub mod sim;
pub mod sc;
pub mod baselines;
pub mod train;
pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod bench;
