//! Fixed-point and power-of-2 quantization utilities.
//!
//! The whole hardware flow works in a pure-integer domain (the bespoke
//! circuit has no floats); this module defines the exact mapping between
//! the float model produced by QAT and the integer model that the genetic
//! optimizer, the netlist generator, and the PJRT evaluator all share.
//!
//! ## Value semantics (the numeric contract of DESIGN.md §2)
//!
//! * A layer input is an unsigned integer `a ∈ [0, 2^A)` representing the
//!   real value `a · 2^in_scale_log2`.
//! * A power-of-2 weight is `sign · 2^e` with `e ∈ [a_exp-7, a_exp]` where
//!   `2^a_exp ≥ max|w|` over the layer (8-bit po2 container: sign + 3-bit
//!   normalized shift + zero flag). Its integer form is the shift
//!   `k = e - (a_exp - 7) ∈ [0, 7]`.
//! * A product is `a << k` — pure wiring in the bespoke circuit — with
//!   real scale `2^(in_scale_log2 + a_exp - 7)` (the *column scale* of the
//!   layer's adder trees).
//! * QRelu(8) truncates `t` LSBs then clips to `[0, 255]`.

/// Maximum normalized shift of a po2 weight. The paper's 8-bit po2
/// container (QKeras `quantized_po2(8)`) leaves ample exponent range; a
/// 4-bit exponent window (sign + 4-bit shift, zero flag) is the
/// hardware-sane equivalent: weights below `2^(a_exp-15)` of the layer
/// maximum flush to zero.
pub const MAX_SHIFT: u32 = 15;

/// Number of input bits fed to the first layer (paper §III-A: 4-bit).
pub const INPUT_BITS: u32 = 4;

/// Activation bits out of QRelu (paper §III-C1: 8-bit).
pub const ACT_BITS: u32 = 8;

/// A quantized power-of-2 weight: `sign * 2^(a_exp - 7 + shift)`.
///
/// `sign == 0` encodes a zero weight (no summand at all).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QWeight {
    pub sign: i8,
    pub shift: u8,
}

impl QWeight {
    pub const ZERO: QWeight = QWeight { sign: 0, shift: 0 };

    /// True if this weight contributes a summand.
    #[inline]
    pub fn is_nonzero(&self) -> bool {
        self.sign != 0
    }

    /// Signed integer multiplier value `sign << shift` (column-scale units).
    #[inline]
    pub fn int_value(&self) -> i64 {
        self.sign as i64 * (1i64 << self.shift)
    }
}

/// Quantize a float weight to the nearest power of two within the layer's
/// normalized exponent window `[a_exp-MAX_SHIFT, a_exp]`.
///
/// Rounding is done in log-domain (`round(log2|w|)`), matching the QKeras
/// `quantized_po2` behaviour; magnitudes below half the smallest
/// representable power flush to zero.
pub fn quantize_po2(w: f64, a_exp: i32) -> QWeight {
    if w == 0.0 || !w.is_finite() {
        return QWeight::ZERO;
    }
    let sign = if w > 0.0 { 1i8 } else { -1i8 };
    let e = w.abs().log2().round() as i32;
    let e_min = a_exp - MAX_SHIFT as i32;
    // Flush-to-zero below the representable window.
    if (w.abs().log2() + 0.5) < e_min as f64 {
        return QWeight::ZERO;
    }
    let e_clipped = e.clamp(e_min, a_exp);
    QWeight { sign, shift: (e_clipped - e_min) as u8 }
}

/// Per-layer exponent scale: smallest `a_exp` with `2^a_exp >= max|w|`.
pub fn layer_a_exp(weights: &[f64]) -> i32 {
    let maxabs = weights.iter().fold(0.0f64, |m, &w| m.max(w.abs()));
    if maxabs == 0.0 {
        0
    } else {
        maxabs.log2().ceil() as i32
    }
}

/// Reconstruct the real value of a [`QWeight`] under a layer scale.
pub fn dequantize_po2(q: QWeight, a_exp: i32) -> f64 {
    q.sign as f64 * (2f64).powi(a_exp - MAX_SHIFT as i32 + q.shift as i32)
}

/// Quantize a normalized feature in `[0,1]` to an unsigned integer of
/// `bits` bits (floor — truncation, per paper §III-A).
pub fn quantize_input(x: f64, bits: u32) -> u32 {
    let max = (1u32 << bits) - 1;
    let v = (x * (1u32 << bits) as f64).floor() as i64;
    v.clamp(0, max as i64) as u32
}

/// Number of bits needed to represent the non-negative integer `v`.
pub fn bits_for(v: u64) -> u32 {
    if v == 0 {
        1
    } else {
        64 - v.leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn quantize_po2_exact_powers() {
        // a_exp = 0 -> representable exponents [-MAX_SHIFT, 0].
        for e in -(MAX_SHIFT as i32)..=0 {
            let w = (2f64).powi(e);
            let q = quantize_po2(w, 0);
            assert_eq!(q.sign, 1);
            assert_eq!(q.shift as i32, e + MAX_SHIFT as i32);
            assert_eq!(dequantize_po2(q, 0), w);
            let qn = quantize_po2(-w, 0);
            assert_eq!(qn.sign, -1);
        }
    }

    #[test]
    fn quantize_po2_zero_and_flush() {
        assert_eq!(quantize_po2(0.0, 0), QWeight::ZERO);
        // Far below 2^-15 flushes to zero.
        assert_eq!(quantize_po2(1e-9, 0), QWeight::ZERO);
        assert_eq!(quantize_po2(-1e-9, 0), QWeight::ZERO);
    }

    #[test]
    fn quantize_po2_clips_above() {
        // 10.0 with a_exp=2 clips to 2^2 = 4.
        let q = quantize_po2(10.0, 2);
        assert_eq!(dequantize_po2(q, 2), 4.0);
    }

    #[test]
    fn quantize_po2_rounds_log_domain() {
        // 3.0: log2(3)=1.585 -> rounds to e=2.
        let q = quantize_po2(3.0, 3);
        assert_eq!(dequantize_po2(q, 3), 4.0);
        // 2.5: log2=1.32 -> e=1 -> 2.0
        let q = quantize_po2(2.5, 3);
        assert_eq!(dequantize_po2(q, 3), 2.0);
    }

    #[test]
    fn layer_a_exp_covers_max() {
        assert_eq!(layer_a_exp(&[0.3, -0.9, 0.5]), 0);
        assert_eq!(layer_a_exp(&[1.5, -0.2]), 1);
        assert_eq!(layer_a_exp(&[]), 0);
        assert_eq!(layer_a_exp(&[0.0]), 0);
    }

    #[test]
    fn quantize_input_truncates() {
        assert_eq!(quantize_input(0.0, 4), 0);
        assert_eq!(quantize_input(0.999, 4), 15);
        assert_eq!(quantize_input(1.0, 4), 15); // clamp
        assert_eq!(quantize_input(0.5, 4), 8);
        assert_eq!(quantize_input(0.49, 4), 7);
    }

    #[test]
    fn bits_for_basics() {
        assert_eq!(bits_for(0), 1);
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(2), 2);
        assert_eq!(bits_for(255), 8);
        assert_eq!(bits_for(256), 9);
    }

    #[test]
    fn prop_quantization_error_bounded() {
        // Relative error of po2 quantization within the representable
        // window is at most sqrt(2) (log-domain rounding to nearest).
        prop::check("po2 relative error", |rng, _| {
            let w = (rng.f64() * 2.0 - 1.0) * 4.0;
            if w.abs() < 0.05 {
                return Ok(());
            }
            let a = layer_a_exp(&[w]);
            let q = quantize_po2(w, a);
            let back = dequantize_po2(q, a);
            let ratio = (back / w).abs();
            if !(0.70..=1.42).contains(&ratio) {
                return Err(format!("w={w} back={back} ratio={ratio}"));
            }
            if (back > 0.0) != (w > 0.0) {
                return Err(format!("sign flip w={w} back={back}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_input_quant_monotone() {
        prop::check("input quant monotone", |rng, _| {
            let a = rng.f64();
            let b = rng.f64();
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            if quantize_input(lo, 4) > quantize_input(hi, 4) {
                return Err(format!("non-monotone at {lo},{hi}"));
            }
            Ok(())
        });
    }
}
