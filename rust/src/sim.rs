//! Levelized gate-level logic simulator.
//!
//! Replaces the commercial simulation step (Synopsys VCS) of the paper's
//! flow: every generated circuit is functionally verified against the
//! integer model on concrete vectors (the equivalence chain of
//! DESIGN.md §2), and the toggle activity it reports feeds the dynamic
//! power estimate in `crate::egfet`.

use crate::netlist::{Gate, Netlist};
use std::collections::HashMap;

/// Evaluate a netlist on one input vector; returns named output buses as
/// bit vectors (LSB first).
pub fn eval(nl: &Netlist, inputs: &[bool]) -> HashMap<String, Vec<bool>> {
    let values = eval_nodes(nl, inputs);
    nl.outputs
        .iter()
        .map(|(name, bus)| {
            (name.clone(), bus.iter().map(|&n| values[n as usize]).collect())
        })
        .collect()
}

/// Evaluate and return the value of every node (single forward pass —
/// the gate list is topologically ordered by construction).
pub fn eval_nodes(nl: &Netlist, inputs: &[bool]) -> Vec<bool> {
    let mut v = vec![false; nl.gates.len()];
    for (i, g) in nl.gates.iter().enumerate() {
        v[i] = match *g {
            Gate::Input(idx) => {
                *inputs.get(idx as usize).unwrap_or_else(|| {
                    panic!("input {idx} missing ({} provided)", inputs.len())
                })
            }
            Gate::Const(c) => c,
            Gate::Not(a) => !v[a as usize],
            Gate::And(a, b) => v[a as usize] & v[b as usize],
            Gate::Or(a, b) => v[a as usize] | v[b as usize],
            Gate::Xor(a, b) => v[a as usize] ^ v[b as usize],
            Gate::Nand(a, b) => !(v[a as usize] & v[b as usize]),
            Gate::Nor(a, b) => !(v[a as usize] | v[b as usize]),
            Gate::Xnor(a, b) => !(v[a as usize] ^ v[b as usize]),
            Gate::Mux(s, a, b) => {
                if v[s as usize] {
                    v[b as usize]
                } else {
                    v[a as usize]
                }
            }
        };
    }
    v
}

/// Interpret an output bus as an unsigned integer.
pub fn bus_to_u64(bits: &[bool]) -> u64 {
    bits.iter().enumerate().map(|(i, &b)| (b as u64) << i).sum()
}

/// Interpret an output bus as a signed (two's-complement) integer.
pub fn bus_to_i64(bits: &[bool]) -> i64 {
    let raw = bus_to_u64(bits) as i64;
    let w = bits.len() as u32;
    if w < 64 && bits.last() == Some(&true) {
        raw - (1i64 << w)
    } else {
        raw
    }
}

/// Pack an unsigned integer into an input bit vector (LSB first).
pub fn u64_to_bits(v: u64, width: u32) -> Vec<bool> {
    (0..width).map(|i| (v >> i) & 1 == 1).collect()
}

/// Average toggle activity per cell over a set of input vectors —
/// the activity factor used by the dynamic power model. Returns the
/// fraction of (cell, consecutive-vector) pairs whose value flipped.
pub fn toggle_activity(nl: &Netlist, vectors: &[Vec<bool>]) -> f64 {
    if vectors.len() < 2 || nl.cell_count() == 0 {
        return 0.0;
    }
    let mut prev = eval_nodes(nl, &vectors[0]);
    let mut toggles = 0u64;
    let mut slots = 0u64;
    for vec in &vectors[1..] {
        let cur = eval_nodes(nl, vec);
        for (i, g) in nl.gates.iter().enumerate() {
            if g.is_cell() {
                slots += 1;
                if cur[i] != prev[i] {
                    toggles += 1;
                }
            }
        }
        prev = cur;
    }
    toggles as f64 / slots as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::Netlist;

    #[test]
    fn gate_truth_tables() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let and = nl.and(a, b);
        let or = nl.or(a, b);
        let xor = nl.xor(a, b);
        let nand = nl.nand(a, b);
        let nor = nl.nor(a, b);
        let xnor = nl.xnor(a, b);
        let not = nl.not(a);
        nl.output("all", vec![and, or, xor, nand, nor, xnor, not]);
        for (va, vb) in [(false, false), (false, true), (true, false), (true, true)] {
            let out = &eval(&nl, &[va, vb])["all"];
            assert_eq!(out[0], va & vb);
            assert_eq!(out[1], va | vb);
            assert_eq!(out[2], va ^ vb);
            assert_eq!(out[3], !(va & vb));
            assert_eq!(out[4], !(va | vb));
            assert_eq!(out[5], !(va ^ vb));
            assert_eq!(out[6], !va);
        }
    }

    #[test]
    fn mux_truth_table() {
        let mut nl = Netlist::new();
        let s = nl.input();
        let a = nl.input();
        let b = nl.input();
        let m = nl.mux(s, a, b);
        nl.output("m", vec![m]);
        assert_eq!(eval(&nl, &[false, true, false])["m"][0], true); // sel=0 -> a
        assert_eq!(eval(&nl, &[true, true, false])["m"][0], false); // sel=1 -> b
    }

    #[test]
    fn signed_conversion() {
        assert_eq!(bus_to_i64(&[true, true, true]), -1);
        assert_eq!(bus_to_i64(&[false, true, false]), 2);
        assert_eq!(bus_to_i64(&[true, false, false]), 1);
        assert_eq!(bus_to_u64(&[true, false, true]), 5);
    }

    #[test]
    fn roundtrip_bits() {
        for v in 0..64u64 {
            assert_eq!(bus_to_u64(&u64_to_bits(v, 6)), v);
        }
    }

    #[test]
    fn toggle_activity_bounds() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let n = nl.not(a);
        nl.output("y", vec![n]);
        // Alternating input -> the NOT gate toggles every step.
        let vectors = vec![vec![false], vec![true], vec![false], vec![true]];
        assert_eq!(toggle_activity(&nl, &vectors), 1.0);
        // Constant input -> no toggles.
        let vectors = vec![vec![true]; 4];
        assert_eq!(toggle_activity(&nl, &vectors), 0.0);
    }
}
