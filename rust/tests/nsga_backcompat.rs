//! Back-compat regression: the const-generic NSGA-II instantiated at
//! `M = 2` must be **bit-identical** to the pre-generalization
//! two-objective implementation.
//!
//! The `legacy` module below is a frozen verbatim copy of the
//! `[f64; 2]`-hard-wired GA core as it stood before the arity refactor
//! (PR 4's `ga.rs`): non-dominated sort, constrained domination,
//! crowding, environmental selection, tournament/crossover/mutation and
//! the full `run` loop, including its RNG seeding and draw order. Both
//! GAs are driven by the same evaluators through the same
//! `evaluate_parallel` engine, so any divergence in fronts, population,
//! history or the per-generation log stream is a behavior change in the
//! generic code — exactly what this suite exists to catch.
//!
//! Coverage follows the issue: seeded two-objective runs through the
//! circuit backend with `--objective fa` and `--objective area`, each
//! checked at `--jobs 1` and `--jobs 8`.

use printed_mlp::config::{builtin, GaSpec};
use printed_mlp::datasets;
use printed_mlp::egfet::CostObjective;
use printed_mlp::ga::{evaluate_parallel, Evaluator, GaResult, Nsga2};
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::{FloatMlp, QuantMlp};
use printed_mlp::runtime::evaluator::CircuitEvaluator;
use printed_mlp::util::{BitVec, Rng};

/// The pre-refactor two-objective NSGA-II, frozen. Do not "improve" this
/// code: its value is that it does not change.
mod legacy {
    use super::*;

    #[derive(Clone, Debug)]
    pub struct Individual {
        pub genome: BitVec,
        pub objs: [f64; 2],
    }

    #[derive(Clone, Debug)]
    pub struct LegacyResult {
        pub population: Vec<Individual>,
        pub front: Vec<Individual>,
        pub history: Vec<(f64, f64)>,
    }

    fn non_dominated_sort(objs: &[[f64; 2]], bound: f64) -> Vec<usize> {
        let n = objs.len();
        let mut dominated_by = vec![0usize; n];
        let mut dominates_lists: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                if dominates_constrained(&objs[i], &objs[j], bound) {
                    dominates_lists[i].push(j);
                } else if dominates_constrained(&objs[j], &objs[i], bound) {
                    dominated_by[i] += 1;
                }
            }
        }
        let mut rank = vec![usize::MAX; n];
        let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
        let mut r = 0;
        while !current.is_empty() {
            let mut next = Vec::new();
            for &i in &current {
                rank[i] = r;
                for &j in &dominates_lists[i] {
                    dominated_by[j] -= 1;
                    if dominated_by[j] == 0 {
                        next.push(j);
                    }
                }
            }
            current = next;
            r += 1;
        }
        rank
    }

    fn dominates_constrained(a: &[f64; 2], b: &[f64; 2], bound: f64) -> bool {
        let va = (a[0] - bound).max(0.0);
        let vb = (b[0] - bound).max(0.0);
        if va == 0.0 && vb > 0.0 {
            return true;
        }
        if va > 0.0 && vb == 0.0 {
            return false;
        }
        if va > 0.0 && vb > 0.0 {
            return va < vb;
        }
        dominates(a, b)
    }

    fn dominates(a: &[f64; 2], b: &[f64; 2]) -> bool {
        (a[0] <= b[0] && a[1] <= b[1]) && (a[0] < b[0] || a[1] < b[1])
    }

    fn crowding_distance(objs: &[[f64; 2]], front: &[usize]) -> Vec<f64> {
        let m = front.len();
        let mut dist = vec![0.0f64; m];
        if m <= 2 {
            return vec![f64::INFINITY; m];
        }
        for obj in 0..2 {
            let mut order: Vec<usize> = (0..m).collect();
            order.sort_by(|&a, &b| {
                objs[front[a]][obj].partial_cmp(&objs[front[b]][obj]).unwrap()
            });
            dist[order[0]] = f64::INFINITY;
            dist[order[m - 1]] = f64::INFINITY;
            let span = objs[front[order[m - 1]]][obj] - objs[front[order[0]]][obj];
            if span <= 0.0 {
                continue;
            }
            for w in 1..m - 1 {
                let prev = objs[front[order[w - 1]]][obj];
                let next = objs[front[order[w + 1]]][obj];
                dist[order[w]] += (next - prev) / span;
            }
        }
        dist
    }

    fn pareto_front(pop: &[Individual], bound: f64) -> Vec<Individual> {
        let mut front: Vec<Individual> = Vec::new();
        for ind in pop {
            if ind.objs[0] > bound {
                continue;
            }
            if pop.iter().any(|o| o.objs[0] <= bound && dominates(&o.objs, &ind.objs)) {
                continue;
            }
            if front.iter().any(|f| f.objs == ind.objs) {
                continue;
            }
            front.push(ind.clone());
        }
        front.sort_by(|a, b| a.objs[0].partial_cmp(&b.objs[0]).unwrap());
        front
    }

    fn full_crowding(pop: &[Individual], ranks: &[usize]) -> Vec<f64> {
        let objs: Vec<[f64; 2]> = pop.iter().map(|i| i.objs).collect();
        let max_rank = ranks.iter().copied().max().unwrap_or(0);
        let mut crowd = vec![0.0; pop.len()];
        for r in 0..=max_rank {
            let front: Vec<usize> = (0..pop.len()).filter(|&i| ranks[i] == r).collect();
            let d = crowding_distance(&objs, &front);
            for (k, &i) in front.iter().enumerate() {
                crowd[i] = d[k];
            }
        }
        crowd
    }

    fn tournament(rng: &mut Rng, ranks: &[usize], crowd: &[f64]) -> usize {
        let a = rng.below(ranks.len());
        let b = rng.below(ranks.len());
        if ranks[a] < ranks[b] || (ranks[a] == ranks[b] && crowd[a] > crowd[b]) {
            a
        } else {
            b
        }
    }

    fn uniform_crossover(rng: &mut Rng, a: &BitVec, b: &BitVec) -> (BitVec, BitVec) {
        let mut c1 = a.clone();
        let mut c2 = b.clone();
        for i in 0..a.len() {
            if rng.chance(0.5) {
                let (va, vb) = (a.get(i), b.get(i));
                c1.set(i, vb);
                c2.set(i, va);
            }
        }
        (c1, c2)
    }

    fn mutate(rng: &mut Rng, g: &mut BitVec, rate: f64) {
        let expected = rate * g.len() as f64;
        let n_flips = {
            let base = expected.floor() as usize;
            base + usize::from(rng.chance(expected - base as f64))
        };
        for _ in 0..n_flips {
            let i = rng.below(g.len());
            g.flip(i);
        }
    }

    fn select(pop: Vec<Individual>, target: usize, bound: f64) -> Vec<Individual> {
        let objs: Vec<[f64; 2]> = pop.iter().map(|i| i.objs).collect();
        let ranks = non_dominated_sort(&objs, bound);
        let max_rank = ranks.iter().copied().max().unwrap_or(0);
        let mut out: Vec<Individual> = Vec::with_capacity(target);
        for r in 0..=max_rank {
            let front: Vec<usize> = (0..pop.len()).filter(|&i| ranks[i] == r).collect();
            if out.len() + front.len() <= target {
                for &i in &front {
                    out.push(pop[i].clone());
                }
            } else {
                let d = crowding_distance(&objs, &front);
                let mut order: Vec<usize> = (0..front.len()).collect();
                order.sort_by(|&a, &b| d[b].partial_cmp(&d[a]).unwrap());
                for &k in order.iter().take(target - out.len()) {
                    out.push(pop[front[k]].clone());
                }
                break;
            }
            if out.len() == target {
                break;
            }
        }
        out
    }

    fn best_area_at(pop: &[Individual], loss: f64) -> f64 {
        pop.iter()
            .filter(|i| i.objs[0] <= loss)
            .map(|i| i.objs[1])
            .fold(f64::INFINITY, f64::min)
    }

    /// The pre-refactor `Nsga2::run`, verbatim (evaluation delegated to
    /// the crate's `evaluate_parallel`, as before the refactor).
    pub fn run(
        spec: &GaSpec,
        genome_len: usize,
        evaluator: &dyn Evaluator<2>,
        seeds: &[BitVec],
        jobs: usize,
        mut log: impl FnMut(usize, &LegacyResult),
    ) -> LegacyResult {
        let mut rng = Rng::new(spec.seed ^ 0x4E53_4741);
        let pop_size = spec.population.max(4);

        let mut genomes: Vec<BitVec> = Vec::with_capacity(pop_size);
        genomes.push(BitVec::ones(genome_len));
        for seed in seeds.iter().take(pop_size.saturating_sub(1)) {
            assert_eq!(seed.len(), genome_len, "seed length mismatch");
            genomes.push(seed.clone());
        }
        while genomes.len() < pop_size {
            let keep = if rng.chance(0.7) {
                spec.init_keep_prob - 0.1 * rng.f64()
            } else {
                0.45 + 0.5 * rng.f64()
            };
            let bools: Vec<bool> = (0..genome_len).map(|_| rng.chance(keep)).collect();
            genomes.push(BitVec::from_bools(&bools));
        }
        let objs = evaluate_parallel(evaluator, &genomes, jobs);
        let mut pop: Vec<Individual> = genomes
            .into_iter()
            .zip(objs)
            .map(|(genome, objs)| Individual { genome, objs })
            .collect();

        let mut history = Vec::new();
        for generation in 0..spec.generations {
            let ranks = non_dominated_sort(
                &pop.iter().map(|i| i.objs).collect::<Vec<_>>(),
                spec.acc_loss_bound,
            );
            let crowd = full_crowding(&pop, &ranks);
            let mut offspring_genomes = Vec::with_capacity(pop_size);
            while offspring_genomes.len() < pop_size {
                let p1 = tournament(&mut rng, &ranks, &crowd);
                let p2 = tournament(&mut rng, &ranks, &crowd);
                let (mut c1, mut c2) = if rng.chance(spec.crossover_rate) {
                    uniform_crossover(&mut rng, &pop[p1].genome, &pop[p2].genome)
                } else {
                    (pop[p1].genome.clone(), pop[p2].genome.clone())
                };
                mutate(&mut rng, &mut c1, spec.mutation_rate);
                mutate(&mut rng, &mut c2, spec.mutation_rate);
                offspring_genomes.push(c1);
                if offspring_genomes.len() < pop_size {
                    offspring_genomes.push(c2);
                }
            }
            let off_objs = evaluate_parallel(evaluator, &offspring_genomes, jobs);
            let offspring: Vec<Individual> = offspring_genomes
                .into_iter()
                .zip(off_objs)
                .map(|(genome, objs)| Individual { genome, objs })
                .collect();

            pop.extend(offspring);
            pop = select(pop, pop_size, spec.acc_loss_bound);

            let best2 = best_area_at(&pop, 0.02);
            let best5 = best_area_at(&pop, 0.05);
            history.push((best2, best5));
            let snapshot = LegacyResult {
                front: pareto_front(&pop, spec.acc_loss_bound),
                population: Vec::new(),
                history: history.clone(),
            };
            log(generation, &snapshot);
        }

        let front = pareto_front(&pop, spec.acc_loss_bound);
        LegacyResult { population: pop, front, history }
    }
}

// ---------------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------------

fn tiny_setup() -> (QuantMlp, printed_mlp::datasets::QuantDataset, f64) {
    let cfg = builtin::tiny();
    let (split, qtrain, _) = datasets::load(&cfg.dataset);
    let mut mlp = FloatMlp::init(cfg.topology, 1);
    mlp.train(&split.train, &TrainOpts { epochs: 20, ..Default::default() });
    let qmlp = QuantMlp::from_float(&mlp, &qtrain);
    let base = qmlp.accuracy(&qtrain, None);
    (qmlp, qtrain, base)
}

fn ga_spec() -> GaSpec {
    let mut spec = builtin::tiny().ga;
    spec.population = 16;
    spec.generations = 3;
    spec
}

/// Everything observable about a run, in comparable form.
type Fingerprint = (
    Vec<(Vec<bool>, [f64; 2])>,
    Vec<(Vec<bool>, [f64; 2])>,
    Vec<(f64, f64)>,
    Vec<(usize, Vec<(f64, f64)>)>,
);

fn fingerprint_generic(result: &GaResult<2>, log: Vec<(usize, Vec<(f64, f64)>)>) -> Fingerprint {
    let pack = |inds: &[printed_mlp::ga::Individual<2>]| -> Vec<(Vec<bool>, [f64; 2])> {
        inds.iter().map(|i| (i.genome.iter().collect(), i.objs)).collect()
    };
    (pack(&result.population), pack(&result.front), result.history.clone(), log)
}

fn fingerprint_legacy(
    result: &legacy::LegacyResult,
    log: Vec<(usize, Vec<(f64, f64)>)>,
) -> Fingerprint {
    let pack = |inds: &[legacy::Individual]| -> Vec<(Vec<bool>, [f64; 2])> {
        inds.iter().map(|i| (i.genome.iter().collect(), i.objs)).collect()
    };
    (pack(&result.population), pack(&result.front), result.history.clone(), log)
}

/// Domain-informed seed chromosomes, as the coordinator injects them.
fn seeds(qmlp: &QuantMlp) -> Vec<BitVec> {
    let map = printed_mlp::accum::GenomeMap::new(qmlp);
    let t = qmlp.act_shift as u8;
    printed_mlp::accum::truncation_seeds(&map, &[t / 2, t], &[0, 2])
}

/// Run generic-vs-legacy on fresh circuit evaluators and assert
/// bit-identity of the full fingerprint.
fn check_backcompat(objective: CostObjective, jobs: usize) {
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let seeds = seeds(&qmlp);
    let spec = ga_spec();

    let generic_ev =
        CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(objective);
    let mut generic_log = Vec::new();
    let generic = Nsga2::<2>::new(spec.clone(), glen, &generic_ev)
        .with_seeds(seeds.clone())
        .with_jobs(jobs)
        .run(|g, snap| generic_log.push((g, snap.history.clone())));

    let legacy_ev =
        CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(objective);
    let mut legacy_log = Vec::new();
    let legacy = legacy::run(&spec, glen, &legacy_ev, &seeds, jobs.max(1), |g, snap| {
        legacy_log.push((g, snap.history.clone()))
    });

    assert_eq!(
        fingerprint_generic(&generic, generic_log),
        fingerprint_legacy(&legacy, legacy_log),
        "objective {objective:?} jobs {jobs}: generic GA diverged from the frozen \
         pre-refactor implementation"
    );
}

#[test]
fn generic_matches_legacy_fa_jobs_1() {
    check_backcompat(CostObjective::Fa, 1);
}

#[test]
fn generic_matches_legacy_fa_jobs_8() {
    check_backcompat(CostObjective::Fa, 8);
}

#[test]
fn generic_matches_legacy_measured_area_jobs_1() {
    check_backcompat(CostObjective::Area, 1);
}

#[test]
fn generic_matches_legacy_measured_area_jobs_8() {
    check_backcompat(CostObjective::Area, 8);
}
