//! Integration: the full coordinator pipeline and the experiment
//! harnesses at smoke scale.

use printed_mlp::bench::{Scale, Study};
use printed_mlp::config::builtin;
use printed_mlp::coordinator::{EvalBackend, Pipeline, PipelineOpts};
use printed_mlp::egfet::PowerSource;
use printed_mlp::synth::SynthMode;

fn smoke_opts(backend: EvalBackend) -> PipelineOpts {
    PipelineOpts { backend, max_hw_points: 2, ..Default::default() }
}

#[test]
fn full_pipeline_produces_consistent_report() {
    let mut cfg = builtin::tiny();
    cfg.ga.population = 30;
    cfg.ga.generations = 4;
    let r = Pipeline::new(cfg, smoke_opts(EvalBackend::Native)).run().unwrap();

    let baseline = r.baseline_hw.as_ref().unwrap();
    // Monotone story of the paper: baseline > QAT-only > holistic.
    assert!(baseline.area_cm2 > r.qat_hw.area_cm2);
    for d in &r.designs {
        assert!(d.hw_full.area_cm2 <= r.qat_hw.area_cm2 * 1.05);
        // 0.6 V saves power vs 1 V on the same netlist.
        assert!(d.hw_0p6v.power_mw < d.hw_full.power_mw);
        // Battery classification consistent with the budget.
        match d.power_source {
            PowerSource::None => assert!(d.hw_0p6v.power_mw > 30.0),
            s => assert!(d.hw_0p6v.power_mw <= s.budget_mw()),
        }
        // Test accuracies are probabilities.
        assert!((0.0..=1.0).contains(&d.acc_test_full));
    }
    // The exact-genome fallback guarantees at least one design close to
    // QAT-only accuracy.
    let best_acc = r.designs.iter().map(|d| d.acc_test_accum).fold(0.0, f64::max);
    assert!(best_acc >= r.trained.acc_q_test - 0.02);
}

#[test]
fn pipeline_circuit_backend_end_to_end() {
    // Circuit-in-the-loop: GA fitness measured on the synthesized
    // netlist through the wave simulator, end to end via the coordinator.
    let mut cfg = builtin::tiny();
    cfg.ga.population = 16;
    cfg.ga.generations = 2;
    let r = Pipeline::new(cfg, smoke_opts(EvalBackend::Circuit)).run().unwrap();
    assert_eq!(r.backend_used, "circuit");
    assert!(!r.front.is_empty());
    assert!(!r.designs.is_empty());
    // The gate-level netlists are bit-equivalent to the integer model, so
    // the exact-genome anchor still scores exactly zero loss.
    assert!(r.front.iter().any(|i| i.objs[0] == 0.0));
    for d in &r.designs {
        assert!((0.0..=1.0).contains(&d.acc_test_full));
        assert!(d.hw_0p6v.power_mw < d.hw_full.power_mw);
    }
}

#[test]
fn circuit_and_native_backends_agree_on_front_semantics() {
    // Same config, same seeds: because circuit-level accuracy equals the
    // integer model's (hardware equivalence), both backends walk the
    // same GA trajectory and land on the same Pareto objectives.
    let mut cfg = builtin::tiny();
    cfg.ga.population = 12;
    cfg.ga.generations = 2;
    let rn = Pipeline::new(cfg.clone(), smoke_opts(EvalBackend::Native)).run().unwrap();
    let rc = Pipeline::new(cfg, smoke_opts(EvalBackend::Circuit)).run().unwrap();
    let on: Vec<Vec<f64>> = rn.front.iter().map(|i| i.objs.clone()).collect();
    let oc: Vec<Vec<f64>> = rc.front.iter().map(|i| i.objs.clone()).collect();
    assert_eq!(on, oc);
}

#[test]
fn circuit_synth_modes_bit_identical_fronts() {
    // Acceptance: `--backend circuit --synth incremental` must be
    // bit-identical in classification (hence GA trajectory and front)
    // to `--synth full`.
    let mut cfg = builtin::tiny();
    cfg.ga.population = 12;
    cfg.ga.generations = 2;
    let mut full_opts = smoke_opts(EvalBackend::Circuit);
    full_opts.synth = SynthMode::Full;
    let rf = Pipeline::new(cfg.clone(), full_opts).run().unwrap();
    let ri = Pipeline::new(cfg, smoke_opts(EvalBackend::Circuit)).run().unwrap();
    let of: Vec<Vec<f64>> = rf.front.iter().map(|i| i.objs.clone()).collect();
    let oi: Vec<Vec<f64>> = ri.front.iter().map(|i| i.objs.clone()).collect();
    assert_eq!(of, oi);
}

#[test]
fn pipeline_deterministic_given_config() {
    let mut cfg = builtin::tiny();
    cfg.ga.population = 20;
    cfg.ga.generations = 3;
    let r1 = Pipeline::new(cfg.clone(), smoke_opts(EvalBackend::Native)).run().unwrap();
    let r2 = Pipeline::new(cfg, smoke_opts(EvalBackend::Native)).run().unwrap();
    assert_eq!(r1.baseline_acc_test, r2.baseline_acc_test);
    assert_eq!(r1.trained.acc_q_test, r2.trained.acc_q_test);
    let a1: Vec<u64> = r1.designs.iter().map(|d| d.area_fa).collect();
    let a2: Vec<u64> = r2.designs.iter().map(|d| d.area_fa).collect();
    assert_eq!(a1, a2);
}

#[test]
fn study_harnesses_smoke() {
    // Table II at smoke scale: the surrogate must rank-correlate highly
    // even on the tiny MLP.
    let t2 = printed_mlp::bench::table2(Scale::Smoke);
    assert!(t2.contains("tiny"));

    let mut study = Study::new(Scale::Smoke, EvalBackend::Native);
    let t3 = printed_mlp::bench::table3(&mut study);
    assert!(t3.contains("tiny"));
    let f4 = printed_mlp::bench::fig4(&mut study);
    assert!(f4.contains("tiny"));
    let t4 = printed_mlp::bench::table4(&mut study);
    assert!(t4.contains("tiny"));
    let t5 = printed_mlp::bench::table5(&mut study);
    assert!(t5.contains("tiny"));
}
