//! Determinism of the island-model GA (`--islands K`): with the same
//! seed, every island count must produce a bit-identical `GaResult` —
//! fronts (genomes + objectives), final population, convergence
//! history, and the per-generation log stream — at every worker width.
//! Islands shard *evaluation* of the globally deduped batch with a
//! deterministic ring rotation of the shard→island assignment at fixed
//! generation boundaries, then merge by Pareto union; selection still
//! sees the whole population, so `K` is a pure throughput/attribution
//! knob, exactly like `--jobs`.
//!
//! This is the contract `pmlp serve` leans on: a resident server may
//! pick any island count per request and still answer bit-identically
//! to a fresh single-island process.

use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::ga::{Evaluator, GaResult, Nsga2, DEFAULT_MIGRATION_INTERVAL};
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::{FloatMlp, QuantMlp};
use printed_mlp::runtime::evaluator::{CircuitEvaluator, NativeEvaluator};
use printed_mlp::util::telemetry;
use printed_mlp::util::BitVec;

fn tiny_setup() -> (QuantMlp, printed_mlp::datasets::QuantDataset, f64) {
    let cfg = builtin::tiny();
    let (split, qtrain, _) = datasets::load(&cfg.dataset);
    let mut mlp = FloatMlp::init(cfg.topology, 1);
    mlp.train(&split.train, &TrainOpts { epochs: 20, ..Default::default() });
    let qmlp = QuantMlp::from_float(&mlp, &qtrain);
    let base = qmlp.accuracy(&qtrain, None);
    (qmlp, qtrain, base)
}

fn ga_spec() -> printed_mlp::config::GaSpec {
    let mut spec = builtin::tiny().ga;
    spec.population = 16;
    // Long enough to cross a migration boundary (interval 4) so the
    // ring actually rotates mid-run.
    spec.generations = 5;
    spec
}

/// Everything observable about a run, in comparable form — same shape
/// as `ga_determinism.rs` fingerprints.
type RunFingerprint<const M: usize> = (
    Vec<(Vec<bool>, [f64; M])>,
    Vec<(Vec<bool>, [f64; M])>,
    Vec<(f64, f64)>,
    Vec<(usize, Vec<(f64, f64)>)>,
);

fn fingerprint<const M: usize>(
    result: &GaResult<M>,
    log: Vec<(usize, Vec<(f64, f64)>)>,
) -> RunFingerprint<M> {
    let pack = |inds: &[printed_mlp::ga::Individual<M>]| -> Vec<(Vec<bool>, [f64; M])> {
        inds.iter().map(|i| (i.genome.iter().collect(), i.objs)).collect()
    };
    (pack(&result.population), pack(&result.front), result.history.clone(), log)
}

/// Run the GA at a given (islands, jobs) cell and fingerprint the
/// outcome.
fn run_at<const M: usize>(
    ev: &dyn Evaluator<M>,
    genome_len: usize,
    seeds: &[BitVec],
    islands: usize,
    jobs: usize,
) -> RunFingerprint<M> {
    let mut log = Vec::new();
    let result = Nsga2::new(ga_spec(), genome_len, ev)
        .with_seeds(seeds.to_vec())
        .with_jobs(jobs)
        .with_islands(islands)
        .run(|generation, snap| log.push((generation, snap.history.clone())));
    fingerprint(&result, log)
}

#[test]
fn native_islands_1_2_4_jobs_1_8_bit_identical() {
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let ev = NativeEvaluator::new(&qmlp, &qtrain, base);
    let reference = run_at::<2>(&ev, glen, &[], 1, 1);
    for islands in [1usize, 2, 4] {
        for jobs in [1usize, 8] {
            assert_eq!(
                run_at::<2>(&ev, glen, &[], islands, jobs),
                reference,
                "islands={islands} jobs={jobs}"
            );
        }
    }
}

#[test]
fn circuit_incremental_islands_matrix_bit_identical() {
    // Fresh evaluator per cell: each has its own memo and worker-arena
    // pool, so agreement cannot come from shared caches — the island
    // sharding itself must be deterministic.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let reference = {
        let ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
        run_at::<2>(&ev, glen, &[], 1, 1)
    };
    for islands in [1usize, 2, 4] {
        for jobs in [1usize, 8] {
            let ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
            assert_eq!(
                run_at::<2>(&ev, glen, &[], islands, jobs),
                reference,
                "islands={islands} jobs={jobs}"
            );
        }
    }
}

#[test]
fn circuit_joint_delay_islands_bit_identical() {
    // The hardest determinism surface — 4-D objectives reading the
    // per-worker arena arrival tables — must also be island-invariant.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let reference = {
        let ev = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base);
        run_at::<4>(&ev, glen, &[], 1, 1)
    };
    for islands in [2usize, 4] {
        let ev = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base);
        assert_eq!(run_at::<4>(&ev, glen, &[], islands, 8), reference, "islands={islands}");
    }
}

#[test]
fn migration_interval_is_observationally_neutral() {
    // Ring rotation changes which island *evaluates* a genome, never
    // what the evaluation returns, so the interval is unobservable in
    // the GaResult (it only redistributes attribution/work).
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let ev = NativeEvaluator::new(&qmlp, &qtrain, base);
    let reference = run_at::<2>(&ev, glen, &[], 1, 1);
    for interval in [1usize, 2, DEFAULT_MIGRATION_INTERVAL, 7] {
        let mut log = Vec::new();
        let result = Nsga2::new(ga_spec(), glen, &ev)
            .with_jobs(8)
            .with_islands(3)
            .with_migration_interval(interval)
            .run(|generation, snap| log.push((generation, snap.history.clone())));
        assert_eq!(fingerprint(&result, log), reference, "interval={interval}");
    }
}

/// Telemetry counters this thread accumulated over one GA run at the
/// given (islands, jobs) cell — worker blocks merge into the calling
/// thread's block, so the before/after delta is isolated from
/// concurrently running tests.
fn counters_during<const M: usize>(
    ev: &dyn Evaluator<M>,
    genome_len: usize,
    islands: usize,
    jobs: usize,
) -> Vec<(&'static str, u64)> {
    let before = telemetry::thread_block();
    let _ = run_at::<M>(ev, genome_len, &[], islands, jobs);
    telemetry::thread_block().delta(&before).counters_named()
}

#[test]
fn circuit_counters_island_invariant() {
    // The deterministic counter stream is part of the contract: islands
    // shard the already-deduped batch, so `ga.evaluate_calls`,
    // `ga.genomes_unique`, and the memo hit/miss totals all match the
    // single-island run exactly. Fresh evaluator per cell.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let reference = {
        let ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
        counters_during::<2>(&ev, glen, 1, 1)
    };
    assert!(!reference.is_empty());
    for islands in [2usize, 4] {
        for jobs in [1usize, 8] {
            let ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
            assert_eq!(
                counters_during::<2>(&ev, glen, islands, jobs),
                reference,
                "islands={islands} jobs={jobs}"
            );
        }
    }
}

#[test]
fn more_islands_than_population_still_bit_identical() {
    // Degenerate sharding: more islands than unique genomes leaves some
    // islands empty every round — the merge must cope and the result
    // must not move.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let ev = NativeEvaluator::new(&qmlp, &qtrain, base);
    let reference = run_at::<2>(&ev, glen, &[], 1, 1);
    assert_eq!(run_at::<2>(&ev, glen, &[], 64, 8), reference);
}
