//! Telemetry subsystem integration suite: the `metrics.json` schema
//! round-trip through `util::json`, the per-worker counter-block merge
//! at the `par_map_with` writeback, and the global enable switch.
//!
//! Counter *values* asserted here always come from this thread's
//! before/after block delta — never from the process-global registry —
//! so concurrently running tests in this binary can't perturb them. The
//! tests that toggle the (process-global) enable switch or read the
//! global registry serialize on `GATE`.

use printed_mlp::util::json::Json;
use printed_mlp::util::telemetry::{self, Counter, Work};
use printed_mlp::util::threads;
use std::sync::{Mutex, MutexGuard, PoisonError};

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

#[test]
fn metrics_json_schema_round_trips() {
    let _g = gate();
    // Populate every section so the round-trip exercises real content
    // (including the timing-closure signals: the arrival-table work
    // stat and the constrained-domination violation counter).
    telemetry::count(Counter::GaGenomesIn, 42);
    telemetry::count(Counter::GaConstraintViolations, 3);
    telemetry::work(Work::SynthRewrites, 7);
    telemetry::work(Work::SynthArrivalRecomputes, 11);
    telemetry::cone_size(5);
    {
        let _outer = telemetry::span("it_roundtrip");
        let _inner = telemetry::span("inner");
    }
    let metrics = telemetry::snapshot();
    let json = telemetry::metrics_json(&metrics);
    let text = json.to_string_pretty();
    let back = Json::parse(&text).expect("metrics.json must parse");
    assert_eq!(back, json, "round-trip through util::json must be lossless");

    // The documented schema: version tag + every key always present.
    assert_eq!(back.get("schema").and_then(Json::as_str), Some(telemetry::SCHEMA));
    let counters = back.get("counters").and_then(Json::as_obj).expect("counters section");
    for name in telemetry::COUNTER_NAMES {
        assert!(counters.contains_key(name), "missing counter key '{name}'");
    }
    let work = back.get("work").and_then(Json::as_obj).expect("work section");
    for name in telemetry::WORK_NAMES {
        assert!(work.contains_key(name), "missing work key '{name}'");
    }
    assert_eq!(
        work.get("synth.cone_hist").and_then(Json::as_arr).map(<[Json]>::len),
        Some(telemetry::CONE_HIST_BUCKETS)
    );
    let gauges = back.get("gauges").and_then(Json::as_obj).expect("gauges section");
    for name in telemetry::GAUGE_NAMES {
        assert!(gauges.contains_key(name), "missing gauge key '{name}'");
    }
    let timers = back.get("timers_ms").and_then(Json::as_obj).expect("timers section");
    let span = timers.get("it_roundtrip").expect("span recorded");
    assert!(span.get("calls").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0);
    assert!(span.get("total_ms").is_some());
    assert!(timers.contains_key("it_roundtrip.inner"), "nested span path");
    assert!(back.get("log_level").and_then(Json::as_str).is_some());

    // The values this test contributed are visible in the global totals
    // (other tests can only add, never subtract).
    let ga_in = counters.get("ga.genomes_in").and_then(Json::as_f64).unwrap();
    assert!(ga_in >= 42.0);
    let viol = counters.get("ga.constraint_violations").and_then(Json::as_f64).unwrap();
    assert!(viol >= 3.0);
    let arr = work.get("synth.arrival_recomputes").and_then(Json::as_f64).unwrap();
    assert!(arr >= 11.0);
}

#[test]
fn baseline_scopes_a_second_in_process_run() {
    // The run-scoping bugfix: counters/work/timers are process-lifetime
    // accumulators, so a second in-process run (a bench loop, every
    // `pmlp serve` request) must report `snapshot_since(baseline())`
    // deltas, not everything since process start.
    let _g = gate();
    let counter_of = |m: &telemetry::Metrics, name: &str| -> u64 {
        m.counters.iter().find(|(n, _)| *n == name).unwrap().1
    };
    // First "run".
    let base1 = telemetry::baseline();
    telemetry::count(Counter::CoordDesignsSynthesized, 4);
    telemetry::work(Work::SynthRewrites, 10);
    {
        let _sp = telemetry::span("it_run_scoped");
    }
    let m1 = telemetry::snapshot_since(&base1);
    assert_eq!(counter_of(&m1, "coordinator.designs_synthesized"), 4);
    assert!(m1.timers.iter().any(|(p, _, _)| p == "it_run_scoped"));

    // Second "run" in the same process must not inherit the first.
    let base2 = telemetry::baseline();
    telemetry::count(Counter::CoordDesignsSynthesized, 1);
    let m2 = telemetry::snapshot_since(&base2);
    assert_eq!(counter_of(&m2, "coordinator.designs_synthesized"), 1);
    let rewrites = m2.work.iter().find(|(n, _)| *n == "synth.rewrites").unwrap().1;
    assert_eq!(rewrites, 0, "run 1's work must not leak into run 2's report");
    // Run 1's span doesn't reappear: its call count didn't advance.
    assert!(m2.timers.iter().all(|(p, _, _)| p != "it_run_scoped"));
    // The JSON export still writes every key even when deltas are zero.
    let json = telemetry::metrics_json(&m2);
    let counters = json.get("counters").and_then(Json::as_obj).expect("counters section");
    assert!(counters.contains_key("ga.genomes_in"));
}

#[test]
fn worker_counter_blocks_merge_width_independent() {
    let _g = gate();
    let run = |threads: usize| {
        let before = telemetry::thread_block();
        threads::par_map(257, threads, |i| {
            telemetry::count(Counter::MemoHits, 1);
            if i % 3 == 0 {
                telemetry::work(Work::WaveCacheHits, 1);
            }
            i
        });
        telemetry::thread_block().delta(&before)
    };
    let serial = run(1);
    let parallel = run(8);
    // Counters AND (for a fixed per-item workload like this synthetic
    // one) work stats merge to identical totals at any width.
    assert_eq!(serial, parallel);
    assert_eq!(serial.counters[Counter::MemoHits as usize], 257);
    assert_eq!(serial.work[Work::WaveCacheHits as usize], 86);
}

#[test]
fn disabled_telemetry_collects_nothing() {
    let _g = gate();
    let before = telemetry::thread_block();
    telemetry::set_enabled(false);
    telemetry::count(Counter::GaGenomesIn, 5);
    telemetry::work(Work::SynthRewrites, 5);
    telemetry::cone_size(4);
    telemetry::set_enabled(true);
    assert_eq!(telemetry::thread_block(), before);
}

#[test]
fn counters_named_pairs_names_with_values() {
    let _g = gate();
    let before = telemetry::thread_block();
    telemetry::count(Counter::SynthSetParams, 9);
    let named = telemetry::thread_block().delta(&before).counters_named();
    assert_eq!(named.len(), telemetry::N_COUNTERS);
    let (_, v) = named.iter().find(|(n, _)| *n == "synth.set_params").unwrap();
    assert_eq!(*v, 9);
}
