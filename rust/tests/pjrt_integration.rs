//! Integration: the AOT bridge. Verifies the equivalence chain
//! `HLO-via-PJRT == native Rust integer model` (DESIGN.md §2) and that
//! the PJRT-driven QAT trainer learns.
//!
//! These tests need `make artifacts` (at least the `tiny` topology); they
//! skip gracefully when artifacts are absent so `cargo test` works on a
//! fresh checkout.

use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::ga::Evaluator;
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::FloatMlp;
use printed_mlp::model::QuantMlp;
use printed_mlp::runtime::evaluator::NativeEvaluator;
use printed_mlp::runtime::{PjrtEvaluator, Runtime};
use printed_mlp::train::PjrtTrainer;
use printed_mlp::util::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("masked_acc_tiny.hlo.txt").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn tiny_model() -> (QuantMlp, datasets::QuantDataset, datasets::QuantDataset) {
    let cfg = builtin::tiny();
    let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
    let mut mlp = FloatMlp::init(cfg.topology, 1);
    mlp.train(&split.train, &TrainOpts { epochs: 30, ..Default::default() });
    (QuantMlp::from_float(&mlp, &qtrain), qtrain, qtest)
}

#[test]
fn pjrt_evaluator_matches_native_exactly() {
    let Some(rt) = runtime_or_skip() else { return };
    let (qmlp, qtrain, _) = tiny_model();
    let base = qmlp.accuracy(&qtrain, None);
    let native = NativeEvaluator::new(&qmlp, &qtrain, base);
    let pjrt = PjrtEvaluator::new(&rt, "tiny", &qmlp, &qtrain, base).expect("pjrt eval");

    let mut rng = Rng::new(42);
    // Mix of exact, dense and sparse genomes, more than one tile (P=16).
    let mut genomes = vec![native.map.exact_genome()];
    for _ in 0..37 {
        let p = 0.4 + 0.6 * rng.f64();
        genomes.push(native.map.random_genome(&mut rng, p));
    }
    let native_objs = native.evaluate(&genomes);
    let pjrt_objs = pjrt.evaluate(&genomes);
    assert_eq!(native_objs.len(), pjrt_objs.len());
    for (i, (n, p)) in native_objs.iter().zip(&pjrt_objs).enumerate() {
        assert!(
            (n[0] - p[0]).abs() < 1e-9,
            "genome {i}: accuracy loss differs native={} pjrt={}",
            n[0],
            p[0]
        );
        assert_eq!(n[1], p[1], "genome {i}: area estimate differs");
    }
}

#[test]
fn pjrt_trainer_learns_tiny() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = builtin::tiny();
    let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
    let mut float = FloatMlp::init(cfg.topology, 1);
    float.train(&split.train, &TrainOpts { epochs: 30, ..Default::default() });

    let trainer = PjrtTrainer::new(&rt, "tiny");
    let tm = trainer.train(&cfg, &float, &split, &qtrain, &qtest).expect("train");
    assert!(
        tm.acc_q_test > 0.70,
        "PJRT-QAT quantized accuracy too low: {}",
        tm.acc_q_test
    );
    assert!(
        tm.acc_q_test > tm.acc_float_test - 0.2,
        "QAT lost too much accuracy: {} vs float {}",
        tm.acc_q_test,
        tm.acc_float_test
    );
}

#[test]
fn pjrt_ga_smoke() {
    // A short NSGA-II run entirely on the PJRT evaluator.
    let Some(rt) = runtime_or_skip() else { return };
    let (qmlp, qtrain, _) = tiny_model();
    let base = qmlp.accuracy(&qtrain, None);
    let pjrt = PjrtEvaluator::new(&rt, "tiny", &qmlp, &qtrain, base).expect("pjrt eval");
    let mut spec = builtin::tiny().ga;
    spec.population = 16;
    spec.generations = 3;
    let glen = pjrt.genome_map().len();
    let ga: printed_mlp::ga::Nsga2<2> = printed_mlp::ga::Nsga2::new(spec, glen, &pjrt);
    let result = ga.run(|_, _| {});
    assert!(!result.front.is_empty());
    // The exact anchor guarantees a zero-loss point on the front.
    assert!(result.front.iter().any(|i| i.objs[0] == 0.0));
}
