//! Acceptance suite of the measured-hardware objective pipeline:
//! `pmlp run --backend circuit --objective power` must produce a Pareto
//! front whose cost axis equals the EGFET analysis of the synthesized
//! survivor for every front member, the joint `--objective area+power`
//! mode must produce a 3-D front whose area *and* power axes are both
//! pinned to the same roll-up (with Pareto-sane 2-D projections), the
//! the four-objective `--objective area+power+delay` mode must add a
//! delay axis bit-identical to the from-scratch critical path of the
//! survivor with every front member inside the `--max-delay` cap, the
//! measured objectives must refuse backends that cannot provide them,
//! and the FA surrogate must stay rank-faithful to the measured area it
//! stands in for.

use printed_mlp::config::builtin;
use printed_mlp::coordinator::{EvalBackend, Pipeline, PipelineOpts};
use printed_mlp::datasets;
use printed_mlp::egfet::{
    analyze, analyze_histogram, critical_path_ms, measured_activity, CostObjective, Library,
};
use printed_mlp::netlist::mlp::{build_mlp_template, ArgmaxMode};
use printed_mlp::sim::wave;
use printed_mlp::synth::optimize;

fn tiny_cfg() -> printed_mlp::config::RunConfig {
    let mut cfg = builtin::tiny();
    cfg.ga.population = 16;
    cfg.ga.generations = 2;
    cfg
}

#[test]
fn power_front_cost_equals_survivor_analysis_end_to_end() {
    // The acceptance pin: for every front member of a measured-power
    // run, re-synthesize the survivor from scratch (the template flow
    // the evaluator itself is pinned against), measure its toggle
    // activity under the same full-train-set stimulus, and check the
    // front's cost axis — bit-exact against the histogram roll-up, and
    // to float-summation order against `egfet::analyze`.
    let cfg = tiny_cfg();
    let opts = PipelineOpts {
        backend: EvalBackend::Circuit,
        objective: CostObjective::Power,
        max_hw_points: 2,
        ..Default::default()
    };
    let r = Pipeline::new(cfg.clone(), opts).run().expect("pipeline");
    assert_eq!(r.backend_used, "circuit");
    assert_eq!(r.objective, CostObjective::Power);
    assert!(!r.front.is_empty());

    let qmlp = &r.trained.qmlp;
    let (_, qtrain, _) = datasets::load(&cfg.dataset);
    let vectors: Vec<Vec<bool>> = qtrain
        .x
        .iter()
        .map(|row| wave::encode_features(row, qmlp.l1.in_bits))
        .collect();
    let tpl = build_mlp_template(qmlp, &ArgmaxMode::Exact);
    let lib = Library::egfet_1v();
    for (k, ind) in r.front.iter().enumerate() {
        let (surv, _) = optimize(&tpl.instantiate(&ind.genome));
        let act = measured_activity(&surv, &vectors);
        let (_, power_mw) = analyze_histogram(&surv.cell_histogram(), &lib, act);
        assert_eq!(
            ind.objs[1], power_mw,
            "front member {k}: cost axis must equal the survivor roll-up bit-exactly"
        );
        let hw = analyze(&surv, &lib, cfg.hw.clock_ms, act);
        assert!(
            (ind.objs[1] - hw.power_mw).abs() <= 1e-9 * hw.power_mw.max(1.0),
            "front member {k}: cost {} vs egfet::analyze {}",
            ind.objs[1],
            hw.power_mw
        );
    }
    // Designs carry the full measured objective vector alongside the
    // (recomputed) FA surrogate, so reports stay comparable across
    // objectives. Front members sit within the accuracy bound, so their
    // survivors cannot be empty — measured power is strictly positive.
    for d in &r.designs {
        assert_eq!(d.objs.len(), 2, "power runs carry [loss, power]");
        assert!(d.objs[1] > 0.0, "design cost {} must be measured power", d.objs[1]);
    }
}

#[test]
fn measured_area_front_matches_survivor_area() {
    // Same pin for `--objective area` (no activity involvement — pure
    // census roll-up).
    let cfg = tiny_cfg();
    let opts = PipelineOpts {
        backend: EvalBackend::Circuit,
        objective: CostObjective::Area,
        max_hw_points: 2,
        ..Default::default()
    };
    let r = Pipeline::new(cfg.clone(), opts).run().expect("pipeline");
    let qmlp = &r.trained.qmlp;
    let tpl = build_mlp_template(qmlp, &ArgmaxMode::Exact);
    let lib = Library::egfet_1v();
    for ind in &r.front {
        let (surv, _) = optimize(&tpl.instantiate(&ind.genome));
        let (area_cm2, _) = analyze_histogram(&surv.cell_histogram(), &lib, 0.25);
        assert_eq!(ind.objs[1], area_cm2);
    }
}

#[test]
fn joint_front_axes_pinned_to_survivor_rollup_and_projections_non_dominated() {
    // The three-objective acceptance pin: `--objective area+power` must
    // produce a 3-D front whose area AND power axes both equal the
    // `analyze_histogram` roll-up of the re-synthesized survivor
    // bit-exactly (same template flow, same full-train-set stimulus),
    // and whose 2-D slices behave like Pareto fronts: the projected
    // front (`bench::front_projection`) is mutually non-dominating, and
    // every 3-D member either survives the projection or is dominated
    // in it by a member that does — dominated only because the dropped
    // axis is what earned its seat.
    let cfg = tiny_cfg();
    let opts = PipelineOpts {
        backend: EvalBackend::Circuit,
        objective: CostObjective::AreaPower,
        max_hw_points: 2,
        ..Default::default()
    };
    let r = Pipeline::new(cfg.clone(), opts).run().expect("pipeline");
    assert_eq!(r.backend_used, "circuit");
    assert_eq!(r.objective, CostObjective::AreaPower);
    assert!(!r.front.is_empty());

    let qmlp = &r.trained.qmlp;
    let (_, qtrain, _) = datasets::load(&cfg.dataset);
    let vectors: Vec<Vec<bool>> = qtrain
        .x
        .iter()
        .map(|row| wave::encode_features(row, qmlp.l1.in_bits))
        .collect();
    let tpl = build_mlp_template(qmlp, &ArgmaxMode::Exact);
    let lib = Library::egfet_1v();
    for (k, ind) in r.front.iter().enumerate() {
        assert_eq!(ind.objs.len(), 3, "joint front member {k} must carry 3 axes");
        let (surv, _) = optimize(&tpl.instantiate(&ind.genome));
        let act = measured_activity(&surv, &vectors);
        let (area_cm2, power_mw) = analyze_histogram(&surv.cell_histogram(), &lib, act);
        assert_eq!(
            ind.objs[1], area_cm2,
            "front member {k}: area axis must equal the survivor roll-up bit-exactly"
        );
        assert_eq!(
            ind.objs[2], power_mw,
            "front member {k}: power axis must equal the survivor roll-up bit-exactly"
        );
        let hw = analyze(&surv, &lib, cfg.hw.clock_ms, act);
        assert!(
            (ind.objs[1] - hw.area_cm2).abs() <= 1e-9 * hw.area_cm2.max(1.0)
                && (ind.objs[2] - hw.power_mw).abs() <= 1e-9 * hw.power_mw.max(1.0),
            "front member {k}: axes must match egfet::analyze to summation order"
        );
    }
    // 3-D mutual non-domination of the front itself.
    for a in &r.front {
        for b in &r.front {
            let dom = a.objs.iter().zip(&b.objs).all(|(x, y)| x <= y)
                && a.objs.iter().zip(&b.objs).any(|(x, y)| x < y);
            assert!(!dom, "3-D front contains dominated point {:?} < {:?}", b.objs, a.objs);
        }
    }
    // Each 2-D slice: the projected front is mutually non-dominating,
    // and covers the whole 3-D front (member kept or 2-D-dominated by a
    // kept point).
    for axis in [1usize, 2] {
        let proj = printed_mlp::bench::front_projection(&r.front, axis);
        assert!(!proj.is_empty());
        let dom2 = |a: (f64, f64), b: (f64, f64)| {
            (a.0 <= b.0 && a.1 <= b.1) && (a.0 < b.0 || a.1 < b.1)
        };
        for &a in &proj {
            for &b in &proj {
                assert!(!dom2(a, b), "axis {axis}: projection keeps dominated {b:?}");
            }
        }
        for ind in &r.front {
            let p = (ind.objs[0], ind.objs[axis]);
            let covered = proj.contains(&p) || proj.iter().any(|&q| dom2(q, p));
            assert!(covered, "axis {axis}: member {p:?} neither kept nor dominated");
        }
    }
    // Designs carry all three axes.
    for d in &r.designs {
        assert_eq!(d.objs.len(), 3, "joint-run designs carry [loss, area, power]");
    }
}

#[test]
fn joint_delay_front_pinned_to_critical_path_and_meets_cap() {
    // The timing-closure acceptance pin: `--objective area+power+delay`
    // must produce a 4-D front whose area/power axes equal the survivor
    // roll-up bit-exactly (as in the 3-D pin), whose delay axis equals
    // `egfet::critical_path_ms` of the from-scratch re-synthesized
    // survivor bit-exactly — the incremental arena's arrival table and
    // the fresh timing walk fold the same max/+ DAG — and every member
    // of which meets the default `--max-delay` cap (the dataset's clock
    // budget; tiny = 200 ms) via constrained domination.
    let cfg = tiny_cfg();
    let opts = PipelineOpts {
        backend: EvalBackend::Circuit,
        objective: CostObjective::AreaPowerDelay,
        max_hw_points: 2,
        ..Default::default()
    };
    let r = Pipeline::new(cfg.clone(), opts).run().expect("pipeline");
    assert_eq!(r.backend_used, "circuit");
    assert_eq!(r.objective, CostObjective::AreaPowerDelay);
    assert!(!r.front.is_empty());

    let qmlp = &r.trained.qmlp;
    let (_, qtrain, _) = datasets::load(&cfg.dataset);
    let vectors: Vec<Vec<bool>> = qtrain
        .x
        .iter()
        .map(|row| wave::encode_features(row, qmlp.l1.in_bits))
        .collect();
    let tpl = build_mlp_template(qmlp, &ArgmaxMode::Exact);
    let lib = Library::egfet_1v();
    for (k, ind) in r.front.iter().enumerate() {
        assert_eq!(ind.objs.len(), 4, "joint-delay front member {k} must carry 4 axes");
        let (surv, _) = optimize(&tpl.instantiate(&ind.genome));
        let act = measured_activity(&surv, &vectors);
        let (area_cm2, power_mw) = analyze_histogram(&surv.cell_histogram(), &lib, act);
        assert_eq!(ind.objs[1], area_cm2, "front member {k}: area axis");
        assert_eq!(ind.objs[2], power_mw, "front member {k}: power axis");
        assert_eq!(
            ind.objs[3],
            critical_path_ms(&surv, &lib),
            "front member {k}: delay axis must equal the survivor's critical path bit-exactly"
        );
        let hw = analyze(&surv, &lib, cfg.hw.clock_ms, act);
        assert_eq!(
            ind.objs[3], hw.delay_ms,
            "front member {k}: delay axis must equal egfet::analyze"
        );
        assert!(ind.objs[3] > 0.0, "front member {k}: survivor has cells, delay > 0");
        assert!(
            ind.objs[3] <= cfg.hw.clock_ms,
            "front member {k}: delay {} misses the {} ms clock budget",
            ind.objs[3],
            cfg.hw.clock_ms
        );
    }
    // 4-D mutual non-domination of the front itself.
    for a in &r.front {
        for b in &r.front {
            let dom = a.objs.iter().zip(&b.objs).all(|(x, y)| x <= y)
                && a.objs.iter().zip(&b.objs).any(|(x, y)| x < y);
            assert!(!dom, "4-D front contains dominated point {:?} < {:?}", b.objs, a.objs);
        }
    }
    // Each 2-D slice (loss×area, loss×power, loss×delay) is mutually
    // non-dominating and covers the whole 4-D front.
    for axis in [1usize, 2, 3] {
        let proj = printed_mlp::bench::front_projection(&r.front, axis);
        assert!(!proj.is_empty());
        let dom2 = |a: (f64, f64), b: (f64, f64)| {
            (a.0 <= b.0 && a.1 <= b.1) && (a.0 < b.0 || a.1 < b.1)
        };
        for &a in &proj {
            for &b in &proj {
                assert!(!dom2(a, b), "axis {axis}: projection keeps dominated {b:?}");
            }
        }
        for ind in &r.front {
            let p = (ind.objs[0], ind.objs[axis]);
            let covered = proj.contains(&p) || proj.iter().any(|&q| dom2(q, p));
            assert!(covered, "axis {axis}: member {p:?} neither kept nor dominated");
        }
    }
    // Designs carry all four axes.
    for d in &r.designs {
        assert_eq!(d.objs.len(), 4, "joint-delay designs carry [loss, area, power, delay]");
    }
}

#[test]
fn explicit_max_delay_is_respected() {
    // A user-supplied `--max-delay` tighter than the clock budget must
    // bound every front member's delay axis (pareto_front_by drops
    // violators; the GA steers around them via constrained domination).
    let cfg = tiny_cfg();
    let clock = cfg.hw.clock_ms;
    let opts = PipelineOpts {
        backend: EvalBackend::Circuit,
        objective: CostObjective::AreaPowerDelay,
        max_delay_ms: Some(clock * 0.75),
        max_hw_points: 2,
        ..Default::default()
    };
    let r = Pipeline::new(cfg, opts).run().expect("pipeline");
    for (k, ind) in r.front.iter().enumerate() {
        assert!(
            ind.objs[3] <= clock * 0.75,
            "front member {k}: delay {} exceeds explicit cap {}",
            ind.objs[3],
            clock * 0.75
        );
    }
}

#[test]
fn measured_objective_requires_circuit_backend() {
    for backend in [EvalBackend::Auto, EvalBackend::Native] {
        for objective in [
            CostObjective::Power,
            CostObjective::Delay,
            CostObjective::AreaPower,
            CostObjective::AreaPowerDelay,
        ] {
            let opts = PipelineOpts {
                backend,
                objective,
                ..Default::default()
            };
            let err = Pipeline::new(tiny_cfg(), opts).run();
            assert!(
                err.is_err(),
                "{backend:?} must reject measured objective {objective:?}"
            );
        }
    }
}

#[test]
fn max_delay_requires_delay_objective() {
    // `--max-delay` constrains a delay axis; objectives without one
    // must refuse it up front rather than silently ignore the cap.
    for objective in [CostObjective::Fa, CostObjective::Area, CostObjective::AreaPower] {
        let opts = PipelineOpts {
            backend: EvalBackend::Circuit,
            objective,
            max_delay_ms: Some(100.0),
            ..Default::default()
        };
        let err = Pipeline::new(tiny_cfg(), opts).run();
        assert!(err.is_err(), "{objective:?} must reject --max-delay");
    }
}

#[test]
fn fa_surrogate_rank_correlates_with_measured_area() {
    // The satellite pinning why `fa` stays an acceptable default: on
    // sampled genomes (the Table II harness's sampling), the FA
    // surrogate must rank-order designs like the measured EGFET area
    // objective does. The paper reports >=0.96 against synthesized area;
    // the tiny CI model with 40 samples clears 0.85 with margin.
    let rho = printed_mlp::bench::spearman_fa_vs_measured("tiny", 40);
    assert!(rho >= 0.85, "Spearman(FA, measured area) = {rho}");
}
