//! Acceptance suite of the measured-hardware objective pipeline:
//! `pmlp run --backend circuit --objective power` must produce a Pareto
//! front whose cost axis equals the EGFET analysis of the synthesized
//! survivor for every front member, the measured objectives must refuse
//! backends that cannot provide them, and the FA surrogate must stay
//! rank-faithful to the measured area it stands in for.

use printed_mlp::config::builtin;
use printed_mlp::coordinator::{EvalBackend, Pipeline, PipelineOpts};
use printed_mlp::datasets;
use printed_mlp::egfet::{
    analyze, analyze_histogram, measured_activity, CostObjective, Library,
};
use printed_mlp::netlist::mlp::{build_mlp_template, ArgmaxMode};
use printed_mlp::sim::wave;
use printed_mlp::synth::optimize;

fn tiny_cfg() -> printed_mlp::config::RunConfig {
    let mut cfg = builtin::tiny();
    cfg.ga.population = 16;
    cfg.ga.generations = 2;
    cfg
}

#[test]
fn power_front_cost_equals_survivor_analysis_end_to_end() {
    // The acceptance pin: for every front member of a measured-power
    // run, re-synthesize the survivor from scratch (the template flow
    // the evaluator itself is pinned against), measure its toggle
    // activity under the same full-train-set stimulus, and check the
    // front's cost axis — bit-exact against the histogram roll-up, and
    // to float-summation order against `egfet::analyze`.
    let cfg = tiny_cfg();
    let opts = PipelineOpts {
        backend: EvalBackend::Circuit,
        objective: CostObjective::Power,
        max_hw_points: 2,
        ..Default::default()
    };
    let r = Pipeline::new(cfg.clone(), opts).run().expect("pipeline");
    assert_eq!(r.backend_used, "circuit");
    assert_eq!(r.objective, CostObjective::Power);
    assert!(!r.front.is_empty());

    let qmlp = &r.trained.qmlp;
    let (_, qtrain, _) = datasets::load(&cfg.dataset);
    let vectors: Vec<Vec<bool>> = qtrain
        .x
        .iter()
        .map(|row| wave::encode_features(row, qmlp.l1.in_bits))
        .collect();
    let tpl = build_mlp_template(qmlp, &ArgmaxMode::Exact);
    let lib = Library::egfet_1v();
    for (k, ind) in r.front.iter().enumerate() {
        let (surv, _) = optimize(&tpl.instantiate(&ind.genome));
        let act = measured_activity(&surv, &vectors);
        let (_, power_mw) = analyze_histogram(&surv.cell_histogram(), &lib, act);
        assert_eq!(
            ind.objs[1], power_mw,
            "front member {k}: cost axis must equal the survivor roll-up bit-exactly"
        );
        let hw = analyze(&surv, &lib, cfg.hw.clock_ms, act);
        assert!(
            (ind.objs[1] - hw.power_mw).abs() <= 1e-9 * hw.power_mw.max(1.0),
            "front member {k}: cost {} vs egfet::analyze {}",
            ind.objs[1],
            hw.power_mw
        );
    }
    // Designs carry the measured cost alongside the (recomputed) FA
    // surrogate, so reports stay comparable across objectives. Front
    // members sit within the accuracy bound, so their survivors cannot
    // be empty — measured power is strictly positive.
    for d in &r.designs {
        assert!(d.cost > 0.0, "design cost {} must be measured power", d.cost);
    }
}

#[test]
fn measured_area_front_matches_survivor_area() {
    // Same pin for `--objective area` (no activity involvement — pure
    // census roll-up).
    let cfg = tiny_cfg();
    let opts = PipelineOpts {
        backend: EvalBackend::Circuit,
        objective: CostObjective::Area,
        max_hw_points: 2,
        ..Default::default()
    };
    let r = Pipeline::new(cfg.clone(), opts).run().expect("pipeline");
    let qmlp = &r.trained.qmlp;
    let tpl = build_mlp_template(qmlp, &ArgmaxMode::Exact);
    let lib = Library::egfet_1v();
    for ind in &r.front {
        let (surv, _) = optimize(&tpl.instantiate(&ind.genome));
        let (area_cm2, _) = analyze_histogram(&surv.cell_histogram(), &lib, 0.25);
        assert_eq!(ind.objs[1], area_cm2);
    }
}

#[test]
fn measured_objective_requires_circuit_backend() {
    for backend in [EvalBackend::Auto, EvalBackend::Native] {
        let opts = PipelineOpts {
            backend,
            objective: CostObjective::Power,
            ..Default::default()
        };
        let err = Pipeline::new(tiny_cfg(), opts).run();
        assert!(err.is_err(), "{backend:?} must reject measured objectives");
    }
}

#[test]
fn fa_surrogate_rank_correlates_with_measured_area() {
    // The satellite pinning why `fa` stays an acceptable default: on
    // sampled genomes (the Table II harness's sampling), the FA
    // surrogate must rank-order designs like the measured EGFET area
    // objective does. The paper reports >=0.96 against synthesized area;
    // the tiny CI model with 40 samples clears 0.85 with margin.
    let rho = printed_mlp::bench::spearman_fa_vs_measured("tiny", 40);
    assert!(rho >= 0.85, "Spearman(FA, measured area) = {rho}");
}
