//! Integration pins of the resident `pmlp serve` server: warm state
//! (parked studies, evaluator memos, design-kernel cache) only ever
//! skips re-computation — every response is bit-identical to what a
//! fresh process would answer for the same request — and the island
//! model rides the same contract over the wire (`"islands": K` changes
//! nothing but attribution). Also drives the TCP accept loop end to
//! end on a loopback listener.
//!
//! Strict telemetry-counter assertions (e.g. the metrics delta showing
//! `coordinator.designs_synthesized == 0` on a repeat) live in the CI
//! serve smoke leg, which runs the binary single-threaded; here tests
//! share one process-global telemetry registry, so we pin the
//! process-local `designs_synthesized` response field instead.

use printed_mlp::coordinator::serve::{serve_lines, serve_listener, Server};
use printed_mlp::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread;

const REQ: &str = r#"{"dataset":"tiny","ga":{"population":16,"generations":2},"max_hw_points":2,"synth_baseline":false,"id":1}"#;

/// Feed a request stream through a fresh server, collect one parsed
/// response per line.
fn responses(input: &str) -> Vec<Json> {
    let mut server = Server::new();
    let mut out = Vec::new();
    serve_lines(&mut server, input.as_bytes(), &mut out).expect("serve");
    String::from_utf8(out)
        .expect("utf8")
        .lines()
        .map(|l| Json::parse(l).expect("response json"))
        .collect()
}

#[test]
fn warm_repeat_and_islands_request_answer_bit_identically() {
    // Three requests down one session: the cold build, an exact repeat,
    // and the same design problem asked with `islands: 4, jobs: 8`. All
    // three must report the same Pareto result; the two warm ones must
    // synthesize nothing (every selected genome hits the kernel cache —
    // the island run selects the same genomes because island evaluation
    // is bit-identical).
    let islands_req = r#"{"dataset":"tiny","ga":{"population":16,"generations":2},"max_hw_points":2,"synth_baseline":false,"islands":4,"jobs":8,"id":3}"#;
    let rs = responses(&format!("{REQ}\n{REQ}\n{islands_req}\n"));
    assert_eq!(rs.len(), 3);
    for r in &rs {
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(
            r.get("metrics").and_then(|m| m.get("schema")).and_then(Json::as_str),
            Some("pmlp.metrics/1")
        );
        let result = r.get("result").expect("result");
        assert!(result.get("front").is_some());
        assert!(result.get("front_hw").is_some());
    }
    assert_eq!(rs[0].get("warm_study").and_then(Json::as_bool), Some(false));
    assert_eq!(rs[1].get("warm_study").and_then(Json::as_bool), Some(true));
    assert_eq!(rs[2].get("warm_study").and_then(Json::as_bool), Some(true));
    assert!(rs[0].get("designs_synthesized").and_then(Json::as_f64).unwrap() > 0.0);
    assert_eq!(rs[1].get("designs_synthesized").and_then(Json::as_f64), Some(0.0));
    assert_eq!(rs[2].get("designs_synthesized").and_then(Json::as_f64), Some(0.0));
    assert_eq!(rs[0].get("result"), rs[1].get("result"));
    assert_eq!(rs[0].get("result"), rs[2].get("result"));
    // Ids echo per request even though the study is shared.
    assert_eq!(rs[0].get("id").and_then(Json::as_f64), Some(1.0));
    assert_eq!(rs[2].get("id").and_then(Json::as_f64), Some(3.0));
}

#[test]
fn later_requests_are_isolated_from_warm_state() {
    // A different GA budget asked after a warm-up must answer exactly
    // what a fresh process answers for it alone: parked memos and
    // kernels may only be consulted, never leak one request's
    // trajectory into another's.
    let other = r#"{"dataset":"tiny","ga":{"population":16,"generations":3,"seed":99},"max_hw_points":2,"synth_baseline":false,"id":2}"#;
    let warm = responses(&format!("{REQ}\n{other}\n"));
    let cold = responses(&format!("{other}\n"));
    assert_eq!(warm.len(), 2);
    assert_eq!(cold.len(), 1);
    assert_eq!(warm[1].get("ok").and_then(Json::as_bool), Some(true));
    // Same study (the key ignores the GA budget), fresh trajectory.
    assert_eq!(warm[1].get("warm_study").and_then(Json::as_bool), Some(true));
    assert_eq!(warm[1].get("result"), cold[0].get("result"));
}

#[test]
fn tcp_connections_share_warm_state() {
    // End-to-end over loopback: bind port 0, run the accept loop on its
    // own thread, and ask the same design question on two separate
    // connections — the second must hit the parked study.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    thread::spawn(move || {
        let mut server = Server::new();
        let _ = serve_listener(listener, &mut server);
    });
    let ask = |payload: &str| -> Json {
        let mut stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
        writeln!(stream, "{payload}").expect("send");
        stream.flush().expect("flush");
        let mut line = String::new();
        reader.read_line(&mut line).expect("read response");
        Json::parse(line.trim_end()).expect("response json")
        // Dropping the streams closes the connection — the server's
        // per-connection loop sees EOF and goes back to accepting.
    };
    let a = ask(REQ);
    let b = ask(REQ);
    assert_eq!(a.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(b.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(a.get("warm_study").and_then(Json::as_bool), Some(false));
    assert_eq!(b.get("warm_study").and_then(Json::as_bool), Some(true));
    assert_eq!(b.get("designs_synthesized").and_then(Json::as_f64), Some(0.0));
    assert_eq!(a.get("result"), b.get("result"));
}
