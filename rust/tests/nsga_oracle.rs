//! Brute-force oracle suite for the const-generic NSGA-II core.
//!
//! `ga::non_dominated_sort` uses Deb's O(n²) domination-count algorithm
//! with a BFS front peel, and `ga::crowding_distance` a per-axis
//! sort-and-gap pass. Both are now generic over the objective arity `M`;
//! this suite pins them — at M=2 and M=3 — against naive O(n²·M)
//! reference implementations written independently below (iterative
//! front peeling; scalar per-axis gap accumulation), over seeded random
//! objective sets that deliberately include:
//!
//! * duplicated points (identical objective vectors must share a front
//!   and split crowding symmetrically),
//! * constraint-violating points (accuracy loss above the bound —
//!   Deb's feasibility-first rule),
//! * degenerate axes (a constant objective contributes nothing to
//!   crowding and must not divide by its zero span).
//!
//! All comparisons are exact (`==` on ranks, bitwise on distances): the
//! oracle recomputes the same real-number quantities in the same IEEE
//! order per axis, so any divergence is a logic change, not float noise.

use printed_mlp::ga::{crowding_distance, dominates, dominates_constrained, non_dominated_sort};
use printed_mlp::util::prop::{self, PropConfig};
use printed_mlp::util::Rng;

// ---------------------------------------------------------------------------
// Naive references
// ---------------------------------------------------------------------------

/// Deb's constrained domination, restated from the definition.
fn ref_dominates_constrained<const M: usize>(a: &[f64; M], b: &[f64; M], bound: f64) -> bool {
    let va = (a[0] - bound).max(0.0);
    let vb = (b[0] - bound).max(0.0);
    match (va > 0.0, vb > 0.0) {
        (false, true) => true,
        (true, false) => false,
        (true, true) => va < vb,
        (false, false) => {
            (0..M).all(|k| a[k] <= b[k]) && (0..M).any(|k| a[k] < b[k])
        }
    }
}

/// Iterative front peeling: rank r = the points no *unranked* point
/// constrained-dominates. O(n² · M) per level, no counting tricks.
fn ref_rank<const M: usize>(objs: &[[f64; M]], bound: f64) -> Vec<usize> {
    let n = objs.len();
    let mut rank = vec![usize::MAX; n];
    let mut assigned = 0;
    let mut r = 0;
    while assigned < n {
        let level: Vec<usize> = (0..n)
            .filter(|&i| {
                rank[i] == usize::MAX
                    && (0..n).all(|j| {
                        j == i
                            || rank[j] != usize::MAX
                            || !ref_dominates_constrained(&objs[j], &objs[i], bound)
                    })
            })
            .collect();
        assert!(!level.is_empty(), "front peeling stuck at rank {r}");
        for &i in &level {
            rank[i] = r;
        }
        assigned += level.len();
        r += 1;
    }
    rank
}

/// Scalar crowding distance: per axis, stable-sort the front by the
/// axis value (ties keep front order, like any stable sort), give the
/// two boundary points infinite distance, and add the span-normalized
/// neighbor gap to each interior point. Axes with zero span are skipped.
fn ref_crowding<const M: usize>(objs: &[[f64; M]], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let mut dist = vec![0.0f64; m];
    for axis in 0..M {
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            objs[front[a]][axis].partial_cmp(&objs[front[b]][axis]).unwrap()
        });
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let span = objs[front[order[m - 1]]][axis] - objs[front[order[0]]][axis];
        if span <= 0.0 {
            continue;
        }
        for w in 1..m - 1 {
            let gap = objs[front[order[w + 1]]][axis] - objs[front[order[w - 1]]][axis];
            dist[order[w]] += gap / span;
        }
    }
    dist
}

// ---------------------------------------------------------------------------
// Random objective-set generator (the adversarial shapes the issue names)
// ---------------------------------------------------------------------------

/// A seeded random objective set: mostly uniform points, with injected
/// duplicates, constraint violators (axis 0 above `bound`) and — with
/// some probability — one axis collapsed to a constant.
fn gen_objs<const M: usize>(rng: &mut Rng, bound: f64) -> Vec<[f64; M]> {
    let n = 1 + rng.below(36);
    let mut objs: Vec<[f64; M]> = (0..n)
        .map(|_| {
            let mut o = [0.0f64; M];
            for v in o.iter_mut() {
                *v = rng.f64() * 2.0; // axis 0 straddles typical bounds
            }
            // Force a visible share of constraint violators.
            if rng.chance(0.25) {
                o[0] = bound + rng.f64();
            }
            o
        })
        .collect();
    // Duplicate some points verbatim (NSGA-II offspring repeat a lot).
    let n0 = objs.len();
    for _ in 0..rng.below(4) {
        let src = objs[rng.below(n0)];
        objs.push(src);
    }
    // Occasionally flatten one axis to a constant (degenerate span).
    if rng.chance(0.3) {
        let axis = rng.below(M);
        let v = rng.f64();
        for o in objs.iter_mut() {
            o[axis] = v;
        }
    }
    // Occasionally flatten *everything* (all-equal points).
    if rng.chance(0.1) {
        let proto = objs[0];
        for o in objs.iter_mut() {
            *o = proto;
        }
    }
    rng.shuffle(&mut objs);
    objs
}

/// The full oracle check for one arity: ranks equal the peeled
/// reference, and crowding distances are bitwise-equal per front.
fn check_arity<const M: usize>(name: &'static str) {
    prop::check_with(
        PropConfig { cases: 120, seed: 0x0A0C1E ^ M as u64 },
        name,
        |rng, _| {
            let bound = 0.5 + rng.f64();
            let objs = gen_objs::<M>(rng, bound);
            let got = non_dominated_sort(&objs, bound);
            let want = ref_rank(&objs, bound);
            if got != want {
                return Err(format!("ranks diverge:\n got {got:?}\nwant {want:?}\nobjs {objs:?}"));
            }
            let max_rank = *want.iter().max().unwrap();
            for r in 0..=max_rank {
                let front: Vec<usize> =
                    (0..objs.len()).filter(|&i| want[i] == r).collect();
                let got_d = crowding_distance(&objs, &front);
                let want_d = ref_crowding(&objs, &front);
                // Bitwise equality, infinities included.
                let same = got_d.len() == want_d.len()
                    && got_d
                        .iter()
                        .zip(&want_d)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    return Err(format!(
                        "crowding diverges on front {r}:\n got {got_d:?}\nwant {want_d:?}\nfront {front:?}\nobjs {objs:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn sort_and_crowding_match_bruteforce_m2() {
    check_arity::<2>("nsga oracle M=2");
}

#[test]
fn sort_and_crowding_match_bruteforce_m3() {
    check_arity::<3>("nsga oracle M=3");
}

#[test]
fn dominance_truth_table_m3() {
    // Hand-checked 3-D cases: equality never dominates, one strictly
    // better axis with the rest equal does, and a single worse axis
    // breaks dominance no matter how much better the others are.
    let a = [0.1, 1.0, 2.0];
    assert!(!dominates(&a, &a), "a point must not dominate itself");
    assert!(dominates(&[0.1, 1.0, 1.9], &a));
    assert!(dominates(&[0.1, 0.9, 2.0], &a));
    assert!(!dominates(&[0.1, 0.9, 2.1], &a), "worse power axis");
    assert!(!dominates(&a, &[0.1, 0.9, 2.1]), "better power, worse area");
    assert!(dominates(&[0.0, 0.0, 0.0], &a));
}

#[test]
fn constrained_dominance_matches_reference_m3() {
    prop::check_with(
        PropConfig { cases: 200, ..Default::default() },
        "constrained dominance M=3",
        |rng, _| {
            let bound = rng.f64();
            let mk = |rng: &mut Rng| {
                let mut o = [0.0f64; 3];
                for v in o.iter_mut() {
                    *v = rng.f64() * 2.0;
                }
                o
            };
            let a = mk(rng);
            let b = mk(rng);
            let got = dominates_constrained(&a, &b, bound);
            let want = ref_dominates_constrained(&a, &b, bound);
            if got != want {
                return Err(format!("{a:?} vs {b:?} @bound {bound}: {got} != {want}"));
            }
            Ok(())
        },
    );
}

#[test]
fn duplicated_points_share_rank_and_stable_ties_pin_crowding() {
    // Two identical points never dominate each other: same front. Put
    // the duplicate pair strictly inside the front on both axes; the
    // stable per-axis sort keeps the pair in front order, so the first
    // copy reads the gap toward the cheaper neighbor and the second
    // toward the pricier one — distinct finite distances. Pinned here
    // (and bitwise against the oracle) so the tie-breaking contract
    // (stable sort by axis value) stays fixed.
    let objs: Vec<[f64; 2]> = vec![[0.0, 2.0], [0.1, 1.0], [0.1, 1.0], [0.2, 0.5]];
    let ranks = non_dominated_sort(&objs, 1.0);
    assert_eq!(ranks, vec![0, 0, 0, 0]);
    let front: Vec<usize> = (0..4).collect();
    let d = crowding_distance(&objs, &front);
    let want = ref_crowding(&objs, &front);
    assert!(d.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
    assert!(d[0].is_infinite());
    assert!(d[3].is_infinite());
    assert!(d[1].is_finite() && d[2].is_finite());
    assert!(
        d[1] < d[2],
        "stable ties: first duplicate neighbors the cheaper side ({} vs {})",
        d[1],
        d[2]
    );
}

#[test]
fn degenerate_axis_contributes_nothing() {
    // A constant axis must be skipped (zero span), leaving crowding
    // driven entirely by the live axes — identical to dropping the axis.
    let objs3: Vec<[f64; 3]> = vec![
        [0.0, 5.0, 0.7],
        [0.1, 4.0, 0.7],
        [0.2, 3.0, 0.7],
        [0.3, 2.0, 0.7],
    ];
    let objs2: Vec<[f64; 2]> = objs3.iter().map(|o| [o[0], o[1]]).collect();
    let front: Vec<usize> = (0..4).collect();
    let d3 = crowding_distance(&objs3, &front);
    let d2 = crowding_distance(&objs2, &front);
    assert_eq!(d3, d2, "constant third axis must not change crowding");
    // All-degenerate: every axis constant -> all distances stay at the
    // boundary-infinity / zero baseline, no NaN from 0/0.
    let flat: Vec<[f64; 3]> = vec![[1.0, 1.0, 1.0]; 5];
    let d = crowding_distance(&flat, &[0, 1, 2, 3, 4]);
    assert!(d.iter().all(|v| !v.is_nan()));
    assert_eq!(d, ref_crowding(&flat, &[0, 1, 2, 3, 4]));
}

#[test]
fn violators_always_rank_behind_feasible_points() {
    // Feasibility first: any feasible point outranks every violator,
    // and violators order among themselves by violation size only.
    let bound = 0.15;
    let objs: Vec<[f64; 3]> = vec![
        [0.90, 0.1, 0.1], // big violation, tiny cost
        [0.14, 9.0, 9.0], // feasible, horrible cost
        [0.20, 0.2, 0.2], // small violation
        [0.00, 5.0, 5.0], // feasible
    ];
    let ranks = non_dominated_sort(&objs, bound);
    assert_eq!(ranks, ref_rank(&objs, bound));
    assert!(ranks[1] < ranks[2] && ranks[1] < ranks[0]);
    assert!(ranks[3] < ranks[2] && ranks[3] < ranks[0]);
    assert!(ranks[2] < ranks[0], "smaller violation ranks first");
}
